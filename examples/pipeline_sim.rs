//! Full-scale §5.3 reproduction: GPT-3 (96 layers, hidden 12288) on 64
//! simulated A100s, 10 000 requests with Zipf(0.4) lengths in [1K, 4K] at
//! P:D = 10, chunk 256 — the Fig. 12 experiment at the paper's size.
//!
//!     cargo run --release --example pipeline_sim [n_requests]

use sarathi::figures::fig12_pipeline;
use sarathi::util::Summary;

fn main() {
    let n: usize = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(10_000);
    println!("simulating {n} requests on 64 A100s (TP8xPP8 vs 8xTP8)...");
    let t0 = std::time::Instant::now();
    let out = fig12_pipeline::simulate(n);
    println!("wall time: {:.1}s\n", t0.elapsed().as_secs_f64());

    let bubbles = |r: &sarathi::simulator::ClusterResult| {
        let mut s = Summary::new();
        for rep in &r.per_replica {
            for &b in &rep.bubble_per_request {
                s.add(b);
            }
        }
        s
    };
    let bo = bubbles(&out.orca_pp);
    let bs = bubbles(&out.sarathi_pp);
    println!("Fig12a median bubble/request: orca {:.2}s  sarathi {:.2}s  ({:.2}x reduction; paper: 6.29x)",
        bo.percentile(50.0), bs.percentile(50.0), bo.percentile(50.0) / bs.percentile(50.0).max(1e-9));
    println!("Fig12b makespan: orca-pp {:.0}s  sarathi-pp {:.0}s  tp-only {:.0}s",
        out.orca_pp.makespan, out.sarathi_pp.makespan, out.tp_only.makespan);
    println!("  sarathi vs orca-pp:  {:.2}x (paper: 1.91x)",
        out.orca_pp.makespan / out.sarathi_pp.makespan);
    println!("  tp-only vs orca-pp:  {:.2}x (paper: 1.28x)",
        out.orca_pp.makespan / out.tp_only.makespan);
    println!("  sarathi vs tp-only:  {:.2}x (paper: 1.48x)",
        out.tp_only.makespan / out.sarathi_pp.makespan);
}
