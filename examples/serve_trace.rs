//! End-to-end serving driver (the DESIGN.md E13 experiment): load the tiny
//! model through PJRT and serve a batched request trace under each
//! scheduling policy, reporting real latency/throughput.
//!
//!     make artifacts && cargo run --release --features pjrt --example serve_trace
//!
//! All three layers compose here: Pallas kernels (inside the AOT HLO), the
//! JAX model graph, and the rust coordinator scheduling real decode-maximal
//! batches. The run is recorded in EXPERIMENTS.md §E13.

use std::path::PathBuf;

use sarathi::config::{SchedulerConfig, SchedulerKind};
use sarathi::coordinator::{make_scheduler, Engine, KvManager, RequestPool};
use sarathi::runtime::{GenRequest, ModelRuntime, RealExecutor};
use sarathi::util::error::Result;
use sarathi::util::{Rng, Summary};
use sarathi::workload::RequestSpec;

fn main() -> Result<()> {
    let dir = PathBuf::from(
        std::env::args().nth(1).unwrap_or_else(|| "artifacts".to_string()),
    );
    let n_requests = 12usize;
    let decode_len = 12usize;

    // synthetic trace: mixed prompt lengths, all arriving at t=0
    let mut rng = Rng::new(2024);
    let prompts: Vec<Vec<i32>> = (0..n_requests)
        .map(|i| {
            let len = 16 + (i * 17) % 80;
            (0..len).map(|_| rng.usize(0, 255) as i32).collect()
        })
        .collect();
    let specs: Vec<RequestSpec> = prompts
        .iter()
        .map(|p| RequestSpec { prompt_len: p.len(), decode_len, arrival: 0.0, prefix: None })
        .collect();
    let total_tokens: usize =
        specs.iter().map(|s| s.prompt_len + s.decode_len - 1).sum();

    println!("trace: {n_requests} requests, {total_tokens} total tokens\n");
    println!(
        "{:<14} {:>6} {:>9} {:>11} {:>11} {:>11}",
        "scheduler", "iters", "wall_s", "tok/s", "p50_lat_s", "p99_lat_s"
    );

    let mut reference: Option<Vec<Vec<i32>>> = None;
    for kind in [
        SchedulerKind::RequestLevel,
        SchedulerKind::OrcaBest,
        SchedulerKind::Sarathi,
    ] {
        let rt = ModelRuntime::load(&dir)?;
        let slots = rt.manifest.model.usable_slots();
        let chunk = rt.manifest.max_chunk();
        let cfg = SchedulerConfig {
            kind,
            chunk_size: chunk,
            tile_align: chunk,
            max_batch: slots,
            token_budget: chunk.max(slots),
            block_size: 0,
            watermark_blocks: 0,
            preemption: sarathi::config::PreemptionMode::Swap,
            reject_infeasible: false,
            prefix_share: false,
            max_prefix_wait: sarathi::coordinator::Admission::DEFAULT_MAX_PREFIX_WAIT,
            bypass_window: sarathi::coordinator::Admission::DEFAULT_BYPASS_WINDOW,
        };
        let gen: Vec<GenRequest> = prompts.iter().map(|p| GenRequest::new(p.clone())).collect();
        let mut engine = Engine::new(
            RequestPool::from_specs(&specs),
            KvManager::new(slots),
            make_scheduler(&cfg),
            Box::new(RealExecutor::new(rt, gen)),
        );
        let t0 = std::time::Instant::now();
        engine.run();
        let wall = t0.elapsed().as_secs_f64();

        // completion latency per request in engine (measured) time
        let mut lat = Summary::new();
        for r in engine.pool.iter() {
            lat.add(r.completed_at.unwrap() - r.arrival);
        }
        println!(
            "{:<14} {:>6} {:>9.2} {:>11.1} {:>11.3} {:>11.3}",
            cfg.kind.name(),
            engine.metrics.iterations.len(),
            wall,
            total_tokens as f64 / wall,
            lat.percentile(50.0),
            lat.percentile(99.0),
        );

        let exec = engine.executor.as_any().downcast_ref::<RealExecutor>().unwrap();
        if let Some(e) = &exec.error {
            sarathi::bail!("runtime error under {}: {e}", cfg.kind.name());
        }
        let outputs: Vec<Vec<i32>> = exec.requests.iter().map(|g| g.generated.clone()).collect();
        match &reference {
            None => reference = Some(outputs),
            Some(r) => assert_eq!(r, &outputs, "scheduling changed generated tokens!"),
        }
    }
    println!("\nall schedulers produced identical tokens ✓");
    Ok(())
}
