//! Quickstart: the smallest possible end-to-end use of the stack.
//!
//! With the `pjrt` feature (and `make artifacts`), the AOT-compiled tiny
//! model loads through PJRT and generates text greedily (the "tokenizer"
//! is byte-level, vocab 256, so any ASCII prompt works; the weights are
//! synthetic, so the continuation is gibberish — the point is the full
//! path HLO text -> PJRT compile -> chunked prefill -> decode loop):
//!
//!     make artifacts && cargo run --release --features pjrt --example quickstart
//!
//! Without it (the default offline build), the calibrated cost-model
//! simulator stands in: the same engine loop serves a small workload with
//! the hybrid token-budget scheduler over a paged KV pool — the CI smoke
//! path, no artifacts required:
//!
//!     cargo run --release --example quickstart

use sarathi::util::error::Result;

#[cfg(feature = "pjrt")]
fn main() -> Result<()> {
    use sarathi::runtime::ModelRuntime;
    use std::path::PathBuf;

    let dir = PathBuf::from(
        std::env::args().nth(1).unwrap_or_else(|| "artifacts".to_string()),
    );
    let mut rt = ModelRuntime::load(&dir)?;
    println!(
        "model: tiny ({} layers, hidden {}, vocab {}) on {}",
        rt.manifest.model.layers,
        rt.manifest.model.hidden,
        rt.manifest.model.vocab,
        rt.platform()
    );

    let prompt_text = "Chunked prefills let decodes piggyback for free.";
    let prompt: Vec<i32> = prompt_text.bytes().map(|b| b as i32).collect();
    println!("prompt: {prompt_text:?} ({} byte-tokens)", prompt.len());

    let t0 = std::time::Instant::now();
    let out = rt.generate_greedy(&prompt, 0, 24)?;
    let dt = t0.elapsed().as_secs_f64();

    let text: String = out
        .iter()
        .map(|&t| {
            let b = t as u8;
            if b.is_ascii_graphic() || b == b' ' { b as char } else { '.' }
        })
        .collect();
    println!("generated {} tokens in {:.3}s ({:.1} tok/s): {text:?}",
        out.len(), dt, out.len() as f64 / dt);
    println!("steps executed: {}", rt.steps);
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn main() -> Result<()> {
    use sarathi::config::{Deployment, GpuConfig, ModelConfig, SchedulerConfig};
    use sarathi::coordinator::{
        make_scheduler, Engine, KvManager, LatencyReport, RequestPool, SimExecutor,
    };
    use sarathi::costmodel::CostModel;
    use sarathi::workload::uniform_population;

    println!("pjrt feature off — quickstart over the calibrated cost model");
    let d = Deployment::new(ModelConfig::llama13b(), GpuConfig::a6000(), 2048);
    let block_size = 32;
    let cfg = SchedulerConfig::hybrid(256, 2 * d.max_batch_size()).with_block_size(block_size);
    let pop = uniform_population(12, 1024, 10.0);
    let mut engine = Engine::new(
        RequestPool::from_specs(&pop),
        KvManager::paged(d.kv_blocks(block_size), block_size),
        make_scheduler(&cfg),
        Box::new(SimExecutor::new(CostModel::for_deployment(&d))),
    );
    engine.run();
    let m = &engine.metrics;
    let lat = LatencyReport::from_pool(&engine.pool);
    println!(
        "served {} requests in {} iterations: {:.0} tok/s, p99 TBT {:.1} ms, peak {} active",
        pop.len(),
        m.iterations.len(),
        m.wall_throughput(),
        lat.tbt.percentile(99.0) * 1e3,
        m.peak_active(),
    );
    assert!(engine.pool.all_complete(), "quickstart must serve everything");
    Ok(())
}
