//! Quickstart: load the AOT-compiled tiny model through PJRT and generate
//! text greedily — the smallest possible end-to-end use of the stack.
//!
//!     make artifacts && cargo run --release --features pjrt --example quickstart
//!
//! The "tokenizer" is byte-level (vocab 256), so any ASCII prompt works;
//! the model has synthetic weights, so the continuation is gibberish — the
//! point is the full path: HLO text -> PJRT compile -> chunked prefill ->
//! decode loop, all from rust.

use std::path::PathBuf;

use sarathi::runtime::ModelRuntime;
use sarathi::util::error::Result;

fn main() -> Result<()> {
    let dir = PathBuf::from(
        std::env::args().nth(1).unwrap_or_else(|| "artifacts".to_string()),
    );
    let mut rt = ModelRuntime::load(&dir)?;
    println!(
        "model: tiny ({} layers, hidden {}, vocab {}) on {}",
        rt.manifest.model.layers,
        rt.manifest.model.hidden,
        rt.manifest.model.vocab,
        rt.platform()
    );

    let prompt_text = "Chunked prefills let decodes piggyback for free.";
    let prompt: Vec<i32> = prompt_text.bytes().map(|b| b as i32).collect();
    println!("prompt: {prompt_text:?} ({} byte-tokens)", prompt.len());

    let t0 = std::time::Instant::now();
    let out = rt.generate_greedy(&prompt, 0, 24)?;
    let dt = t0.elapsed().as_secs_f64();

    let text: String = out
        .iter()
        .map(|&t| {
            let b = t as u8;
            if b.is_ascii_graphic() || b == b' ' { b as char } else { '.' }
        })
        .collect();
    println!("generated {} tokens in {:.3}s ({:.1} tok/s): {text:?}",
        out.len(), dt, out.len() as f64 / dt);
    println!("steps executed: {}", rt.steps);
    Ok(())
}
