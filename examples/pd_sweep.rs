//! P:D-ratio sweep (the Fig. 9 / §5.1.3 experiment as a standalone tool):
//! prints SARATHI's end-to-end gain over the baseline across P:D ratios
//! and chunk sizes for a chosen sequence length, and marks the analytic
//! optimum P:D = C/(B−1).
//!
//!     cargo run --release --example pd_sweep [seq_len]

use sarathi::config::{Deployment, GpuConfig, ModelConfig, SchedulerConfig};
use sarathi::figures::common::{run_engine, steady_population, tokens_per_ms};

fn main() {
    let l: usize = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(1024);
    let d = Deployment::new(ModelConfig::llama13b(), GpuConfig::a6000(), l);
    let b = d.max_batch_size();
    println!("LLaMA-13B/A6000, L={l}, B={b} (capacity formula)\n");
    println!("{:>6}  {:>10}  {:>9}  {:>9}  {:>9}", "P:D", "base tok/ms", "C=128", "C=256", "C=512");
    for pd in [1.0f64, 2.0, 5.0, 10.0, 14.0, 20.0, 28.0, 50.0, 100.0, 200.0] {
        let pop = steady_population(b, l, pd, 4);
        let base = tokens_per_ms(&run_engine(&d, &SchedulerConfig::baseline(b), &pop));
        print!("{pd:>6.0}  {base:>10.2}");
        for chunk in [128usize, 256, 512] {
            let t = tokens_per_ms(&run_engine(&d, &SchedulerConfig::sarathi(chunk, b), &pop));
            print!("  {:>8.2}x", t / base);
        }
        println!();
    }
    println!("\nanalytic optimum per chunk: P:D = C/(B-1) = {:.0} / {:.0} / {:.0}",
        128.0 / (b as f64 - 1.0), 256.0 / (b as f64 - 1.0), 512.0 / (b as f64 - 1.0));
}
