//! End-to-end benchmark: regenerate every paper table/figure and time each
//! harness (one bench per paper artifact, per deliverable (d)). The printed
//! rows double as the reproduction record consumed by EXPERIMENTS.md.

mod bench_util;
use bench_util::{bench, header};

fn main() {
    header("paper figure/table regeneration (one bench per artifact)");
    for (name, f) in sarathi::figures::all() {
        bench(name, || {
            let tables = f();
            assert!(!tables.is_empty());
            std::hint::black_box(&tables);
        });
    }

    header("rendered output (for the record)");
    let out = std::path::Path::new("out");
    for t in sarathi::figures::run_named("all", out).expect("figures") {
        println!("{}", t.render());
    }
}
