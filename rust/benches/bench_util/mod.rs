//! Minimal bench harness (criterion is unavailable offline): warm up, run
//! timed iterations, print mean/min ns per op in a stable format.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub mean_ns: f64,
    pub min_ns: f64,
    pub iters: usize,
}

/// Time `f` adaptively: warm up, then run enough iterations to pass ~0.2 s.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    // warmup
    for _ in 0..3 {
        f();
    }
    // estimate per-call time
    let t0 = Instant::now();
    f();
    let est = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((0.2 / est) as usize).clamp(5, 100_000);
    let mut min = f64::MAX;
    let t0 = Instant::now();
    for _ in 0..iters {
        let t1 = Instant::now();
        f();
        min = min.min(t1.elapsed().as_secs_f64());
    }
    let total = t0.elapsed().as_secs_f64();
    let r = BenchResult {
        name: name.to_string(),
        mean_ns: total / iters as f64 * 1e9,
        min_ns: min * 1e9,
        iters,
    };
    println!(
        "{:<44} {:>12.0} ns/op (min {:>12.0}, {} iters)",
        r.name, r.mean_ns, r.min_ns, r.iters
    );
    r
}

pub fn header(title: &str) {
    println!("\n=== {title} ===");
}
