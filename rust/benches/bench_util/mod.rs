//! Minimal bench harness (criterion is unavailable offline): warm up, run
//! timed iterations, print mean/min ns per op in a stable format, and emit
//! machine-readable `BENCH_*.json` artifacts (hand-rolled writer — the
//! crate stays zero-dependency) so CI can track the trajectory and gate on
//! regressions against a committed baseline.
#![allow(dead_code)] // each bench binary uses a subset of the harness

use std::path::PathBuf;
use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub mean_ns: f64,
    pub min_ns: f64,
    pub iters: usize,
}

/// Time `f` adaptively: warm up, then run enough iterations to pass ~0.2 s.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    // warmup
    for _ in 0..3 {
        f();
    }
    // estimate per-call time
    let t0 = Instant::now();
    f();
    let est = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((0.2 / est) as usize).clamp(5, 100_000);
    let mut min = f64::MAX;
    let t0 = Instant::now();
    for _ in 0..iters {
        let t1 = Instant::now();
        f();
        min = min.min(t1.elapsed().as_secs_f64());
    }
    let total = t0.elapsed().as_secs_f64();
    let r = BenchResult {
        name: name.to_string(),
        mean_ns: total / iters as f64 * 1e9,
        min_ns: min * 1e9,
        iters,
    };
    println!(
        "{:<44} {:>12.0} ns/op (min {:>12.0}, {} iters)",
        r.name, r.mean_ns, r.min_ns, r.iters
    );
    r
}

/// Time ONE invocation of `f` in seconds — for long, self-contained runs
/// (the cluster sweep) where repeating the whole simulation is the noise
/// reduction, not inner-loop iteration counts.
pub fn bench_once<T>(name: &str, f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    let secs = t0.elapsed().as_secs_f64();
    println!("{name:<44} {secs:>12.3} s");
    (out, secs)
}

pub fn header(title: &str) {
    println!("\n=== {title} ===");
}

/// True when the CI-sized run was requested (`cargo bench --bench X --
/// --quick`, or BENCH_QUICK=1).
pub fn quick() -> bool {
    std::env::args().any(|a| a == "--quick") || std::env::var_os("BENCH_QUICK").is_some()
}

/// Where `BENCH_*.json` artifacts land: `$BENCH_OUT_DIR`, else
/// `target/bench/` under the cargo working directory.
pub fn out_dir() -> PathBuf {
    std::env::var_os("BENCH_OUT_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("target/bench"))
}

/// Encode a finite f64 (JSON has no NaN/inf — those become `null`).
pub fn json_f64(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.6}")
    } else {
        "null".to_string()
    }
}

/// Encode per-op results as a JSON array of objects.
pub fn json_results(results: &[BenchResult]) -> String {
    let rows: Vec<String> = results
        .iter()
        .map(|r| {
            format!(
                "{{\"name\": \"{}\", \"mean_ns\": {}, \"min_ns\": {}, \"iters\": {}}}",
                r.name.replace('"', "'"),
                json_f64(r.mean_ns),
                json_f64(r.min_ns),
                r.iters
            )
        })
        .collect();
    format!("[{}]", rows.join(", "))
}

/// Write one flat JSON object to `out_dir()/file`. `fields` values must
/// already be encoded JSON (use [`json_f64`] / [`json_results`]).
pub fn write_json(file: &str, fields: &[(&str, String)]) -> PathBuf {
    let dir = out_dir();
    std::fs::create_dir_all(&dir).expect("create bench output dir");
    let path = dir.join(file);
    let body: Vec<String> = fields.iter().map(|(k, v)| format!("  \"{k}\": {v}")).collect();
    std::fs::write(&path, format!("{{\n{}\n}}\n", body.join(",\n"))).expect("write bench json");
    println!("wrote {}", path.display());
    path
}

/// Read field `key` out of a committed baseline JSON file. Returns None
/// when the file is missing, the field is absent, or its value is `null`
/// (the bootstrap state before any baseline has been recorded). The parse
/// is deliberately naive — the baseline is a flat object this harness
/// itself wrote.
pub fn baseline_f64(path: &str, key: &str) -> Option<f64> {
    let text = std::fs::read_to_string(path).ok()?;
    let needle = format!("\"{key}\"");
    let rest = &text[text.find(&needle)? + needle.len()..];
    let rest = rest.trim_start().strip_prefix(':')?.trim_start();
    let end = rest
        .find(|c: char| !(c.is_ascii_digit() || c == '.' || c == '-' || c == '+' || c == 'e'))
        .unwrap_or(rest.len());
    rest[..end].parse().ok()
}
