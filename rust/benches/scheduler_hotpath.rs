//! L3 hot-path microbenchmarks: batch composition, KV slot management,
//! cost-model evaluation, profiler prediction, and a full engine iteration
//! — the pieces inside the serving loop (perf pass targets, DESIGN.md §8).
//!
//! All fixture construction (populations, pools, schedulers) happens
//! OUTSIDE the timed closures so each number measures the operation it
//! names, not `RequestPool::from_specs`. Results land in
//! `target/bench/BENCH_hotpath.json` (see bench_util) for CI tracking.

mod bench_util;
use bench_util::{bench, header, json_results, write_json};

use sarathi::config::{GpuConfig, ModelConfig, SchedulerConfig};
use sarathi::coordinator::{derived_path, make_scheduler, Engine, KvManager, RequestPool, SimExecutor};
use sarathi::costmodel::{BatchShape, CostModel};
use sarathi::profiler::Profiler;
use sarathi::workload::uniform_population;

fn main() {
    let cm = CostModel::new(ModelConfig::llama13b(), GpuConfig::a6000());
    let mut results = Vec::new();

    header("cost model");
    let hybrid = BatchShape::hybrid(239, 512, &vec![1024; 17]);
    results.push(bench("costmodel::iteration(hybrid b18)", || {
        std::hint::black_box(cm.iteration_time(&hybrid));
    }));
    let decode = BatchShape::decode_only(&vec![1024; 27]);
    results.push(bench("costmodel::iteration(decode b27)", || {
        std::hint::black_box(cm.iteration_time(&decode));
    }));

    header("profiler");
    let prof = Profiler::build(cm.clone(), 4096, 32);
    results.push(bench("profiler::build(4k x 32)", || {
        std::hint::black_box(Profiler::build(cm.clone(), 4096, 32));
    }));
    results.push(bench("profiler::predict(hybrid)", || {
        std::hint::black_box(prof.predict(&hybrid));
    }));

    header("kv manager");
    results.push(bench("kv alloc/release x18", || {
        let mut kv = KvManager::new(18);
        let slots: Vec<usize> = (0..18).map(|_| kv.alloc().unwrap()).collect();
        for s in slots {
            kv.release(s);
        }
    }));

    // radix prefix store: longest-match lookup down a conversation-depth
    // chain — 32 ready nodes x 8 blocks x 16 tokens (a 4096-token resident
    // path), probed with a deeper content path so the walk descends every
    // node before stopping. The admission hot path runs this per template
    // arrival.
    let bs = 16;
    let seg = 8;
    let chain_blocks = 256;
    let mut radix_kv = KvManager::paged(chain_blocks + 32, bs);
    let chain = derived_path(42, chain_blocks);
    for s in 0..chain_blocks / seg {
        let hash = 1_000 + s as u64;
        let run = radix_kv.alloc_n(seg).expect("pool sized for the chain");
        radix_kv.register_path_prefix(
            hash,
            &chain[..(s + 1) * seg],
            s * seg * bs,
            (s + 1) * seg * bs,
            &run,
        );
        radix_kv.mark_prefix_ready(hash);
    }
    let probe = derived_path(42, chain_blocks + 16);
    results.push(bench("kv::lookup_path_match(32-node deep chain)", || {
        std::hint::black_box(radix_kv.lookup_path_match(&probe).ready_tokens);
    }));

    header("scheduler");
    // fixtures hoisted: the first call admits everything, so the steady
    // state this measures is admission no-op + batch composition — the
    // per-iteration cost the engine actually pays
    let pop = uniform_population(18, 1024, 15.0);
    let mut pool = RequestPool::from_specs(&pop);
    let mut kv = KvManager::new(18);
    let mut s = make_scheduler(&SchedulerConfig::sarathi(256, 18));
    results.push(bench("sarathi schedule (steady state)", || {
        std::hint::black_box(s.schedule(&mut pool, &mut kv, 0.0));
    }));

    header("engine end-to-end (simulated)");
    // the population is fixed; Engine::new stays inside (run() consumes
    // the pool) but is measured separately so the run number is readable
    let pop = uniform_population(18, 1024, 15.0);
    results.push(bench("engine::new 18 reqs", || {
        std::hint::black_box(Engine::new(
            RequestPool::from_specs(&pop),
            KvManager::new(18),
            make_scheduler(&SchedulerConfig::sarathi(256, 18)),
            Box::new(SimExecutor::new(cm.clone())),
        ));
    }));
    results.push(bench("engine::run 18 reqs L=1K sarathi", || {
        let mut e = Engine::new(
            RequestPool::from_specs(&pop),
            KvManager::new(18),
            make_scheduler(&SchedulerConfig::sarathi(256, 18)),
            Box::new(SimExecutor::new(cm.clone())),
        );
        e.run();
        std::hint::black_box(e.metrics.recorded_count());
    }));

    write_json(
        "BENCH_hotpath.json",
        &[("schema", "\"BENCH_hotpath.v1\"".to_string()), ("results", json_results(&results))],
    );
}
