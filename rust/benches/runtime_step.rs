//! Real-runtime step benchmarks over the PJRT CPU client: per-step cost of
//! prefill-chunk / decode / hybrid artifacts, and the fusion check — the
//! hybrid step should cost ~one prefill step, NOT prefill + decode
//! (the decode-maximal claim on the real path).
//!
//! Skipped (with a note) when artifacts/ is absent.

mod bench_util;
use bench_util::{bench, header};

use sarathi::runtime::ModelRuntime;
use sarathi::util::Rng;

fn main() {
    let dir = std::path::PathBuf::from("artifacts");
    if !dir.join("manifest.txt").exists() {
        println!("artifacts/ missing — run `make artifacts` first; skipping runtime bench");
        return;
    }
    let mut rt = ModelRuntime::load(&dir).expect("load artifacts");
    let mut rng = Rng::new(9);
    let prompt: Vec<i32> = (0..32).map(|_| rng.usize(0, 255) as i32).collect();

    header("PJRT real-model step costs (tiny model, CPU)");
    rt.prefill_all(&prompt, 0).unwrap();

    let r_pre = bench("prefill_chunk c=32", || {
        rt.prefill_chunk(&prompt, 1, 0).unwrap();
    });
    let r_dec = bench("decode d=4 lanes", || {
        rt.decode(&[(1, 0, 33), (2, 6, 1), (3, 6, 2), (4, 6, 3)]).unwrap();
    });
    let r_hyb = bench("hybrid c=32 + d=4", || {
        rt.hybrid(&prompt, 2, 0, &[(1, 0, 33), (2, 6, 1), (3, 6, 2), (4, 6, 3)]).unwrap();
    });

    header("decode-maximal fusion on the real path");
    let marginal = (r_hyb.mean_ns - r_pre.mean_ns).max(0.0);
    println!(
        "hybrid-over-prefill marginal: {:.0} ns vs decode-only {:.0} ns ({:.1}% of a full decode step)",
        marginal,
        r_dec.mean_ns,
        marginal / r_dec.mean_ns * 100.0
    );
    println!("steps executed: {}", rt.steps);
}
