//! Cluster-scale macro benchmark: 8 hybrid replicas behind the
//! prefix-affinity router serving a bursty shared-prefix workload, with
//! prefix sharing on — the full routed hot path (heap-driven event loop,
//! allocation-free iteration path, parallel replica execution) end to end.
//!
//! Measures the same sweep twice — `threads = 1` (the serial heap loop)
//! and `threads = 0` (one worker per core) — asserts the two runs are
//! BITWISE identical, and writes `target/bench/BENCH_cluster.json` with
//! both wall-clock times and the speedup. When the committed baseline
//! (`benches/baseline/BENCH_cluster.baseline.json`, override with
//! `$BENCH_BASELINE`) carries a recorded `serial_secs`, a measured serial
//! time more than 2× slower FAILS the bench (exit 1) — the CI regression
//! gate. A `null` baseline (the bootstrap state) warns and passes.
//!
//! A second, timing-only **64-replica scale point** (first step of the
//! "hundreds of replicas" profiling item) rides along: per-replica
//! split-RNG workload shards, one parallel routed run, extra `scale_*`
//! keys in the same JSON. It is NOT part of the regression gate — the
//! gate reads `serial_secs`/`quick_serial_secs` only.
//!
//! `--quick` (or `BENCH_QUICK=1`) runs the CI-sized sweep: same shape,
//! fewer requests.

mod bench_util;
use bench_util::{baseline_f64, bench_once, header, json_f64, quick, write_json};

use sarathi::config::{Deployment, GpuConfig, ModelConfig, ParallelConfig};
use sarathi::coordinator::sched::HybridScheduler;
use sarathi::coordinator::{KvManager, Scheduler};
use sarathi::simulator::{ClusterResult, ClusterSim, PrefixAffinity};
use sarathi::util::Rng;
use sarathi::workload::{
    sharded_shared_prefix_population, shared_prefix_population, with_template_burst_arrivals,
    RequestSpec,
};

const REPLICAS: usize = 8;
const SCALE_REPLICAS: usize = 64;

fn deployment_of(replicas: usize) -> Deployment {
    Deployment::new(ModelConfig::llama13b(), GpuConfig::a6000(), 2048)
        .with_parallel(ParallelConfig::tp_pp(1, 1).with_replicas(replicas))
}

fn deployment() -> Deployment {
    deployment_of(REPLICAS)
}

/// Bursty shared-prefix traffic: 16 templates (Zipf 0.55 fanout,
/// 384-token prefixes, 64–256 unique tokens at P:D 4) in per-template
/// bursts of 6 on a Poisson(64/s) timeline — enough concurrent load that
/// all 8 replicas hold work between dispatch instants.
fn workload(n: usize) -> Vec<RequestSpec> {
    let mut rng = Rng::new(12345);
    let pop = shared_prefix_population(&mut rng, n, 16, 0.55, 384, 64, 256, 4.0);
    with_template_burst_arrivals(&mut rng, pop, 64.0, 6)
}

fn sweep(cluster: &ClusterSim, pop: &[RequestSpec], threads: usize) -> ClusterResult {
    let mut router = PrefixAffinity::new(PrefixAffinity::DEFAULT_SPILL);
    cluster.run_routed_threads(
        pop,
        &mut router,
        || KvManager::paged(128, 32),
        None,
        || {
            Box::new(HybridScheduler::new(256, 8, 2).with_prefix_share(true))
                as Box<dyn Scheduler + Send>
        },
        threads,
    )
}

fn main() {
    let quick = quick();
    let n = if quick { 400 } else { 2000 };
    let cores = std::thread::available_parallelism().map_or(1, |c| c.get());
    header(&format!(
        "cluster sweep: {REPLICAS} replicas x {n} requests (affinity router, \
         prefix-share on, {cores} cores)"
    ));

    let cluster = ClusterSim::new(deployment());
    let pop = workload(n);

    let (serial, serial_secs) =
        bench_once("run_routed threads=1 (serial heap loop)", || sweep(&cluster, &pop, 1));
    let (parallel, parallel_secs) =
        bench_once("run_routed threads=0 (one per core)", || sweep(&cluster, &pop, 0));

    // the thread count is a wall-clock knob ONLY: both sweeps must agree
    // bit for bit, request by request
    assert_eq!(serial.completions.len(), parallel.completions.len());
    for (i, (a, b)) in serial.completions.iter().zip(&parallel.completions).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "request {i}: serial {a} != parallel {b} — thread count changed the simulation"
        );
    }
    assert!(serial.completions.iter().all(|t| !t.is_nan()), "every request must complete");

    let speedup = serial_secs / parallel_secs.max(1e-12);
    println!("speedup: {speedup:.2}x over {cores} cores, makespan {:.2}s", serial.makespan);

    // 64-replica scale point: per-replica split-RNG shards (shard i is
    // bit-stable under replica-count changes), one parallel routed run,
    // timing recorded but NOT regression-gated
    let per_replica = if quick { 8 } else { 25 };
    let scale_n = SCALE_REPLICAS * per_replica;
    header(&format!(
        "scale point: {SCALE_REPLICAS} replicas x {scale_n} requests (split-RNG shards)"
    ));
    let shards = sharded_shared_prefix_population(
        &Rng::new(777),
        SCALE_REPLICAS,
        per_replica,
        16,
        0.55,
        384,
        64,
        256,
        4.0,
        8.0,
    );
    let scale_pop: Vec<RequestSpec> = shards.into_iter().flatten().collect();
    let scale_cluster = ClusterSim::new(deployment_of(SCALE_REPLICAS));
    let (scale, scale_secs) = bench_once(
        &format!("run_routed threads=0 ({SCALE_REPLICAS} replicas)"),
        || sweep(&scale_cluster, &scale_pop, 0),
    );
    assert!(
        scale.completions.iter().all(|t| !t.is_nan()),
        "scale point: every request must complete"
    );
    println!("scale makespan {:.2}s, prefix_hits {}", scale.makespan, scale.prefix_hits());

    write_json(
        "BENCH_cluster.json",
        &[
            ("schema", "\"BENCH_cluster.v1\"".to_string()),
            ("quick", quick.to_string()),
            ("replicas", REPLICAS.to_string()),
            ("requests", n.to_string()),
            ("cores", cores.to_string()),
            ("serial_secs", json_f64(serial_secs)),
            ("parallel_secs", json_f64(parallel_secs)),
            ("speedup", json_f64(speedup)),
            ("makespan", json_f64(serial.makespan)),
            ("prefix_hits", serial.prefix_hits().to_string()),
            ("scale_replicas", SCALE_REPLICAS.to_string()),
            ("scale_requests", scale_n.to_string()),
            ("scale_secs", json_f64(scale_secs)),
            ("scale_makespan", json_f64(scale.makespan)),
            ("scale_prefix_hits", scale.prefix_hits().to_string()),
        ],
    );

    // regression gate: only quick-vs-quick / full-vs-full comparisons make
    // sense, so the baseline key is sized by mode
    let key = if quick { "quick_serial_secs" } else { "serial_secs" };
    let path = std::env::var("BENCH_BASELINE")
        .unwrap_or_else(|_| "benches/baseline/BENCH_cluster.baseline.json".to_string());
    match baseline_f64(&path, key) {
        Some(base) if serial_secs > 2.0 * base => {
            eprintln!(
                "REGRESSION: serial sweep {serial_secs:.3}s > 2x baseline {base:.3}s ({path})"
            );
            std::process::exit(1);
        }
        Some(base) => {
            println!("baseline ok: {serial_secs:.3}s vs {base:.3}s recorded ({path})");
        }
        None => {
            println!("no committed baseline for {key} in {path} — bootstrap run, gate skipped");
        }
    }
}
