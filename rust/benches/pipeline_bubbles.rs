//! Pipeline-simulator benchmark: the Fig.-12 cluster simulation at several
//! scales (the §5.3 experiment is the heaviest harness in the repo — this
//! bench tracks the simulator's own performance, reqs simulated per
//! second of wall time).

mod bench_util;
use bench_util::{bench, header};

use sarathi::figures::fig12_pipeline;

fn main() {
    header("fig12 cluster simulation (3 deployments per run)");
    for n in [200usize, 1000, 4000] {
        let r = bench(&format!("simulate {n} requests"), || {
            std::hint::black_box(fig12_pipeline::simulate(n).sarathi_pp.makespan);
        });
        let reqs_per_s = n as f64 / (r.mean_ns / 1e9) * 3.0;
        println!("    -> {reqs_per_s:.0} simulated requests/s of wall time");
    }
}
