//! Cross-module integration over the simulated testbed: the paper's
//! headline numbers, end to end — capacity formula → scheduler → engine →
//! metrics → figure harness, plus profiler-vs-cost-model consistency on
//! randomized batch shapes.

use sarathi::config::{Deployment, GpuConfig, ModelConfig, SchedulerConfig};
use sarathi::costmodel::{BatchShape, CostModel, DecodeItem, PrefillItem};
use sarathi::figures::common::{run_engine, steady_population};
use sarathi::profiler::Profiler;
use sarathi::util::prop::check;

#[test]
fn headline_llama13b_a6000_gain() {
    // Table 4 row 1: L=1K, B=6, P:D=50 → paper gain 1.33×, decode 5.45×.
    let d = Deployment::new(ModelConfig::llama13b(), GpuConfig::a6000(), 1024);
    let pop = steady_population(6, 1024, 50.0, 8);
    let base = run_engine(&d, &SchedulerConfig::baseline(6), &pop);
    let sar = run_engine(&d, &SchedulerConfig::sarathi(256, 6), &pop);
    let gain = sar.throughput() / base.throughput();
    let dsp = base.decode_time_per_token() / sar.decode_time_per_token();
    assert!((1.05..1.8).contains(&gain), "gain {gain} (paper 1.33)");
    assert!(dsp > 2.0, "decode speedup {dsp} (paper 5.45)");
}

#[test]
fn headline_llama33b_a100_gain() {
    // Table 4 row 4: L=1K, B=10, P:D=28 → paper gain 1.25×, decode 3.83×.
    let d = Deployment::new(ModelConfig::llama33b(), GpuConfig::a100(), 1024);
    assert_eq!(d.max_batch_size(), 10, "capacity formula must give the paper's B");
    let pop = steady_population(10, 1024, 28.0, 8);
    let base = run_engine(&d, &SchedulerConfig::baseline(10), &pop);
    let sar = run_engine(&d, &SchedulerConfig::sarathi(256, 10), &pop);
    let gain = sar.throughput() / base.throughput();
    assert!((1.03..1.7).contains(&gain), "gain {gain} (paper 1.25)");
}

#[test]
fn optimal_pd_ratio_tracks_c_over_b_minus_1() {
    // §5.1.3's analytic optimum: sweep P:D for (C=256, B=18) and check the
    // best gain lands near 256/17 ≈ 15 rather than at the extremes.
    let d = Deployment::new(ModelConfig::llama13b(), GpuConfig::a6000(), 1024);
    let mut best = (0.0f64, 0.0f64);
    for pd in [2.0f64, 5.0, 10.0, 15.0, 30.0, 60.0, 120.0, 200.0] {
        let pop = steady_population(18, 1024, pd, 4);
        let base = run_engine(&d, &SchedulerConfig::baseline(18), &pop);
        let sar = run_engine(&d, &SchedulerConfig::sarathi(256, 18), &pop);
        let gain = sar.throughput() / base.throughput();
        if gain > best.1 {
            best = (pd, gain);
        }
    }
    assert!((5.0..=60.0).contains(&best.0), "optimum at P:D {}", best.0);
    assert!(best.1 > 1.1, "peak gain {}", best.1);
}

#[test]
fn profiler_tracks_cost_model_on_random_shapes() {
    let cm = CostModel::new(ModelConfig::llama13b(), GpuConfig::a6000());
    let prof = Profiler::build(cm.clone(), 4096, 32);
    check("profiler-vs-model", 80, |case| {
        let kind = case.rng.usize(0, 2);
        let shape = match kind {
            0 => {
                let c = case.rng.usize(1, 2048);
                let h = case.rng.usize(0, 2000);
                BatchShape::prefill_only(&[(c, h)])
            }
            1 => {
                let lanes = case.rng.usize(1, 32);
                let kv = case.rng.usize(1, 4000);
                BatchShape::decode_only(&vec![kv; lanes])
            }
            _ => {
                let c = case.rng.usize(32, 512);
                let lanes = case.rng.usize(1, 31);
                let kv = case.rng.usize(64, 3500);
                BatchShape {
                    prefill: vec![PrefillItem { chunk: c, history: 0 }],
                    decode: vec![DecodeItem { kv_len: kv }; lanes],
                }
            }
        };
        let truth = cm.iteration_time(&shape);
        let pred = prof.predict(&shape);
        let err = (pred - truth).abs() / truth;
        // the paper validates its simulator within 5%; hybrids interpolate
        // across two tables so allow slightly more there
        let bound = if kind == 2 { 0.12 } else { 0.06 };
        if err > bound {
            return Err(format!("shape {shape:?}: err {err:.3}"));
        }
        Ok(())
    });
}

#[test]
fn figures_harness_runs_clean() {
    // every figure module must produce non-empty tables without panicking
    // (this is the `figures all` path minus CSV output)
    for (name, f) in sarathi::figures::all() {
        let tables = f();
        assert!(!tables.is_empty(), "{name} produced no tables");
        for t in &tables {
            assert!(!t.rows.is_empty(), "{name}: empty table {}", t.title);
        }
    }
}
