//! PR-5 acceptance: cluster-level request routing (prefix-affinity +
//! load-aware dispatch over the interleaved multi-replica simulation).
//!
//! The headline scenario: a 4-replica deployment serving a shared-prefix
//! Zipf workload (12 templates, per-template bursty Poisson arrivals, one
//! undersized shared paged pool per replica). `PrefixAffinity` must beat
//! `RoundRobin` by ≥2× on the aggregate prefix-hit rate while keeping the
//! load-imbalance statistic (max/mean dispatch-sampled outstanding work)
//! ≤ 1.25 and P99 TTFT no worse — with zero wedge panics across 24 seeds.
//!
//! All margins pre-validated with the Python mirror
//! (/tmp/router_mirror.py — per-replica event-driven hybrid scheduler
//! with paged KV, prefix sharing, bounded waits, LIFO preemption, LRU
//! cold-run reclaim under the same routed dispatch; identical xoshiro
//! workload draws). Mirror measurements over seeds 1..=24: hit-rate ratio
//! 2.36× (floor 2.0), affinity imbalance mean 1.126 (ceiling 1.25), P99
//! TTFT ratio 0.57 (ceiling 1.0), 0 wedges, 0 fallbacks.

use sarathi::config::{Deployment, GpuConfig, ModelConfig, ParallelConfig};
use sarathi::coordinator::sched::HybridScheduler;
use sarathi::coordinator::{KvManager, Scheduler};
use sarathi::simulator::{ClusterResult, ClusterSim, PipelineResult, RoundRobin};
use sarathi::util::{percentile, Rng};
use sarathi::workload::{
    shared_prefix_population, with_poisson_arrivals, with_template_burst_arrivals,
    zipf_population, RequestSpec,
};

/// 4 × (tp=1, pp=1) LLaMA-13B replica groups on A6000s.
fn four_replica_deployment() -> Deployment {
    Deployment::new(ModelConfig::llama13b(), GpuConfig::a6000(), 2048)
        .with_parallel(ParallelConfig::tp_pp(1, 1).with_replicas(4))
}

/// The acceptance workload for one seed: 280 requests over 12 templates
/// (Zipf 0.55 fanout, 384-token prefixes, unique parts of 64–256 tokens at
/// P:D 4), arriving in per-template bursts of 6 on a Poisson(48/s)
/// timeline. Template identities are salted per seed so rendezvous
/// placement luck averages out across seeds (mirroring production, where
/// template ids are content hashes, not tiny integers).
fn acceptance_workload(seed: u64) -> Vec<RequestSpec> {
    let mut rng = Rng::new(seed);
    let mut pop = shared_prefix_population(&mut rng, 280, 12, 0.55, 384, 64, 256, 4.0);
    for s in pop.iter_mut() {
        if let Some(p) = s.prefix.as_mut() {
            p.id += seed * 7919;
        }
    }
    with_template_burst_arrivals(&mut rng, pop, 48.0, 6)
}

fn hybrid_sched() -> Box<dyn Scheduler + Send + 'static> {
    Box::new(HybridScheduler::new(256, 8, 2).with_prefix_share(true))
}

/// One policy's aggregate over all seeds.
#[derive(Default)]
struct Agg {
    hits: usize,
    fallbacks: usize,
    ttfts: Vec<f64>,
    imbalances: Vec<f64>,
}

fn run_policy(
    cluster: &ClusterSim,
    seeds: &[u64],
    make_router: &mut dyn FnMut() -> Box<dyn sarathi::simulator::RoutePolicy>,
) -> Agg {
    let mut agg = Agg::default();
    for &seed in seeds {
        // a FRESH router per seed: a carried-over round-robin cursor
        // would silently drift off the documented g % R dispatch if the
        // per-seed request count stopped dividing the replica count
        let mut router = make_router();
        let pop = acceptance_workload(seed);
        // undersized per-replica pool: 32 blocks × 32 tokens holds ~1
        // pinned 384-token run + live tails, but nowhere near all 12
        // templates — the residency pressure affinity routing exploits
        let res = cluster.run_routed(
            &pop,
            &mut *router,
            || KvManager::paged(32, 32),
            None,
            hybrid_sched,
        );
        assert!(
            res.completions.iter().all(|t| !t.is_nan()),
            "{} seed {seed}: every request must complete (no wedge, no starvation)",
            res.router,
        );
        agg.hits += res.prefix_hits();
        agg.fallbacks += res.prefix_fallbacks();
        agg.imbalances.push(res.load_imbalance());
        for rep in &res.per_replica {
            agg.ttfts.extend_from_slice(rep.latency.ttft.samples());
        }
    }
    agg
}

/// The ISSUE-5 acceptance criterion. Margins: mirror hit ratio 2.36× vs
/// the 2.0 floor, imbalance 1.126 vs the 1.25 ceiling, TTFT ratio 0.57
/// vs the 1.0 ceiling. Zero wedge panics = this test not panicking
/// across all 24 seeds × both policies.
#[test]
fn affinity_beats_round_robin_on_hit_rate_without_imbalance() {
    let seeds: Vec<u64> = (1..=24).collect();
    let cluster = ClusterSim::new(four_replica_deployment());
    use sarathi::simulator::{PrefixAffinity, RoutePolicy};
    let rr = run_policy(&cluster, &seeds, &mut || {
        Box::new(RoundRobin::new()) as Box<dyn RoutePolicy>
    });
    let aff = run_policy(&cluster, &seeds, &mut || {
        Box::new(PrefixAffinity::new(1.0)) as Box<dyn RoutePolicy>
    });

    println!(
        "router acceptance: hits aff={} rr={}, fallbacks aff={} rr={}, \
         imbalances aff={:?}",
        aff.hits, rr.hits, aff.fallbacks, rr.fallbacks, aff.imbalances
    );
    assert!(rr.hits > 0, "round-robin still hits within bursts");
    let ratio = aff.hits as f64 / rr.hits as f64;
    assert!(
        ratio >= 2.0,
        "affinity must at least double the aggregate hit rate: {} vs {} = {ratio:.2}x",
        aff.hits,
        rr.hits
    );

    let imb_mean: f64 = aff.imbalances.iter().sum::<f64>() / aff.imbalances.len() as f64;
    assert!(
        imb_mean <= 1.25,
        "affinity load imbalance (max/mean outstanding tokens) {imb_mean:.3} > 1.25 \
         (per-seed: {:?})",
        aff.imbalances.iter().map(|x| (x * 100.0).round() / 100.0).collect::<Vec<_>>()
    );

    let p99_rr = percentile(&rr.ttfts, 99.0);
    let p99_aff = percentile(&aff.ttfts, 99.0);
    assert!(
        p99_aff <= p99_rr,
        "affinity P99 TTFT must be no worse: {p99_aff:.3}s vs rr {p99_rr:.3}s"
    );
}

/// RoundRobin routing must reproduce the pre-refactor static `g % R`
/// partition BYTE-FOR-BYTE: the same per-request completion times (bit
/// patterns, not approximations) as running each partition to completion
/// in isolation through `PipelineSim::run_shared` — which is exactly what
/// the old `ClusterSim::run_with_kv` did.
#[test]
fn round_robin_routing_reproduces_the_static_partition_bitwise() {
    let replicas = 3;
    let d = Deployment::new(ModelConfig::llama13b(), GpuConfig::a6000(), 2048)
        .with_parallel(ParallelConfig::tp_pp(1, 2).with_replicas(replicas));
    let cluster = ClusterSim::new(d);
    let mut rng = Rng::new(33);
    let pop = zipf_population(&mut rng, 60, 0.4, 256, 1024, 10.0);
    let pop = with_poisson_arrivals(&mut rng, pop, 6.0);

    let make_kv = || KvManager::paged(40, 32);
    let make_sched =
        || Box::new(HybridScheduler::new(256, 8, 2)) as Box<dyn Scheduler + Send>;

    let routed = cluster.run_routed(&pop, &mut RoundRobin::new(), make_kv, Some(8), make_sched);
    assert!(routed.replica_of.iter().enumerate().all(|(g, &ri)| ri == g % replicas));

    // the pre-refactor semantics, reconstructed: static partition, each
    // replica run to completion in isolation
    let mut expected = vec![f64::NAN; pop.len()];
    let mut expected_makespan = 0.0f64;
    for (ri, sim) in cluster.sims.iter().enumerate() {
        let local: Vec<RequestSpec> = pop
            .iter()
            .enumerate()
            .filter(|(g, _)| g % replicas == ri)
            .map(|(_, s)| s.clone())
            .collect();
        let res = sim.run_shared(&local, make_kv(), Some(8), make_sched);
        for (li, (g, _)) in
            pop.iter().enumerate().filter(|(g, _)| g % replicas == ri).enumerate()
        {
            expected[g] = res.completions[li];
        }
        expected_makespan = expected_makespan.max(res.makespan);
    }

    for (g, (&got, &want)) in routed.completions.iter().zip(expected.iter()).enumerate() {
        assert_eq!(
            got.to_bits(),
            want.to_bits(),
            "request {g}: routed {got} != static {want}"
        );
    }
    assert_eq!(routed.makespan.to_bits(), expected_makespan.to_bits());
}

/// Satellite regression: `ClusterResult::latency()` must aggregate
/// per-replica reports sample-exactly — merged P99 equals the percentile
/// over the pooled samples (hand-computed here), and the `prefix_wait`
/// histogram is merged too (it used to be dropped on the floor).
#[test]
fn merged_latency_matches_a_hand_computed_merge() {
    let mut a = PipelineResult::default();
    for v in 1..=50 {
        a.latency.ttft.add(v as f64);
    }
    a.latency.prefix_wait.add(0.25);
    let mut b = PipelineResult::default();
    for v in 51..=100 {
        b.latency.ttft.add(v as f64);
    }
    b.latency.prefix_wait.add(0.75);
    let res = ClusterResult { per_replica: vec![a, b], ..Default::default() };
    let merged = res.latency();
    assert_eq!(merged.ttft.count(), 100);
    // hand-computed: P99 over 1..=100 interpolates rank 98.01 → 99.01
    assert!(
        (merged.ttft.percentile(99.0) - 99.01).abs() < 1e-9,
        "merged P99 {} != 99.01",
        merged.ttft.percentile(99.0)
    );
    // identical (bitwise) to the percentile over pooled samples
    let pooled: Vec<f64> = res
        .per_replica
        .iter()
        .flat_map(|r| r.latency.ttft.samples().iter().copied())
        .collect();
    assert_eq!(
        merged.ttft.percentile(99.0).to_bits(),
        percentile(&pooled, 99.0).to_bits()
    );
    assert_eq!(merged.prefix_wait.count(), 2, "prefix_wait histogram is merged");
    assert!((merged.prefix_wait.mean() - 0.5).abs() < 1e-12);
}

/// The cluster JSONL trace: every record carries its `replica` tag, the
/// merge is time-ordered, and the per-record schema matches the engine's.
#[test]
fn cluster_jsonl_records_carry_the_replica_tag() {
    let cluster = ClusterSim::new(four_replica_deployment());
    let pop = acceptance_workload(5);
    let res = cluster.run_routed(
        &pop,
        &mut RoundRobin::new(),
        || KvManager::paged(32, 32),
        None,
        hybrid_sched,
    );
    let path = std::env::temp_dir().join("sarathi_cluster_router_trace.jsonl");
    res.write_jsonl(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), res.total_iterations());
    let mut seen = [false; 4];
    let mut last_start = f64::NEG_INFINITY;
    for line in &lines {
        assert!(line.starts_with("{\"iter\":"), "schema prefix: {line}");
        assert!(line.contains("\"prefix_hits\":"), "engine fields present: {line}");
        let tag = line
            .split("\"replica\":")
            .nth(1)
            .and_then(|s| s.trim_end_matches('}').parse::<usize>().ok())
            .expect("every record carries a replica tag");
        assert!(tag < 4);
        seen[tag] = true;
        let start: f64 = line
            .split("\"start\":")
            .nth(1)
            .and_then(|s| s.split(',').next())
            .and_then(|s| s.parse().ok())
            .unwrap();
        assert!(start >= last_start, "merged trace is time-ordered");
        last_start = start;
    }
    assert!(seen.iter().all(|&s| s), "all four replicas appear in the trace");
    std::fs::remove_file(&path).ok();
}

/// Dispatch sees consistent state: a JSQ router over an asymmetric
/// workload must spread outstanding work far more evenly than routing
/// everything round-robin would suggest — and every request still
/// completes under interleaved replica clocks.
#[test]
fn jsq_balances_outstanding_work_across_replicas() {
    let cluster = ClusterSim::new(four_replica_deployment());
    let mut rng = Rng::new(77);
    // heavy-tailed lengths: round-robin lands some replicas many long
    // requests; JSQ should not
    let pop = zipf_population(&mut rng, 160, 0.9, 256, 1600, 8.0);
    let pop = with_poisson_arrivals(&mut rng, pop, 40.0);
    let mut jsq = sarathi::simulator::LeastOutstandingTokens::new();
    let res = cluster.run_routed(
        &pop,
        &mut jsq,
        || KvManager::paged(64, 32),
        None,
        || Box::new(HybridScheduler::new(256, 8, 2)) as Box<dyn Scheduler + Send>,
    );
    assert!(res.completions.iter().all(|t| !t.is_nan()));
    assert_eq!(res.router, "jsq");
    assert!(
        res.load_imbalance() < 1.2,
        "jsq imbalance {:.3} (means {:?})",
        res.load_imbalance(),
        res.mean_outstanding
    );
    // every replica served a fair share of requests
    let mut counts = [0usize; 4];
    for &ri in &res.replica_of {
        counts[ri] += 1;
    }
    assert!(counts.iter().all(|&c| c >= 160 / 8), "dispatch counts {counts:?}");
}
