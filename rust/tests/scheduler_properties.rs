//! Property tests over the coordinator: randomized workloads, every
//! scheduler, checked against the structural invariants of §4 on every
//! iteration (the hand-rolled prop driver stands in for proptest — see
//! util::prop).

use sarathi::config::{GpuConfig, ModelConfig};
use sarathi::coordinator::sched::{
    HybridScheduler, OrcaScheduler, RequestLevelScheduler, SarathiScheduler,
};
use sarathi::coordinator::{
    Batch, Engine, Executor, KvManager, RequestPool, Scheduler, SimExecutor, StepOutcome,
};
use sarathi::costmodel::CostModel;
use sarathi::util::prop::{check, Case};
use sarathi::workload::RequestSpec;

fn rand_workload(case: &mut Case) -> Vec<RequestSpec> {
    let n = 1 + case.rng.usize(0, 3 + case.size);
    (0..n)
        .map(|_| RequestSpec {
            prompt_len: case.rng.usize(1, 600),
            decode_len: case.rng.usize(1, 40),
            arrival: case.rng.f64() * 0.5,
            prefix: None,
        })
        .collect()
}

fn make_sched(case: &mut Case, max_batch: usize) -> (Box<dyn Scheduler>, &'static str) {
    match case.rng.usize(0, 4) {
        0 => (Box::new(RequestLevelScheduler::new(max_batch)), "request-level"),
        1 => (Box::new(OrcaScheduler::best(max_batch)), "orca-best"),
        2 => (Box::new(OrcaScheduler::worst(max_batch)), "orca-worst"),
        3 => {
            let budget = *case.rng.choose(&[64usize, 128, 256]);
            (Box::new(HybridScheduler::new(budget.max(max_batch), max_batch, 0)), "hybrid")
        }
        _ => {
            let chunk = *case.rng.choose(&[64usize, 128, 256, 512]);
            (Box::new(SarathiScheduler::new(chunk, max_batch, 128)), "sarathi")
        }
    }
}

/// Executor wrapper that validates every scheduled batch before running it.
struct ValidatingExec {
    inner: SimExecutor,
    max_batch: usize,
    batches: Vec<(usize, usize, usize)>, // (chunks, prefill_tokens, decodes)
}

impl Executor for ValidatingExec {
    fn execute(&mut self, batch: &Batch, pool: &RequestPool) -> StepOutcome {
        batch.validate(pool, self.max_batch).expect("invalid batch");
        self.batches.push((batch.n_prefill_chunks(), batch.prefill_tokens(), batch.n_decodes()));
        self.inner.execute(batch, pool)
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

#[test]
fn every_scheduler_produces_only_valid_batches_and_completes() {
    check("valid batches, full completion", 60, |case| {
        let specs = rand_workload(case);
        let max_batch = case.rng.usize(1, 8);
        let (sched, _name) = make_sched(case, max_batch);
        let cm = CostModel::new(ModelConfig::llama13b(), GpuConfig::a6000());
        let exec = ValidatingExec { inner: SimExecutor::new(cm), max_batch, batches: vec![] };
        let mut e = Engine::new(
            RequestPool::from_specs(&specs),
            KvManager::new(max_batch),
            sched,
            Box::new(exec),
        );
        e.run();
        if !e.pool.all_complete() {
            return Err("engine finished with incomplete requests".into());
        }
        // token conservation
        let p_expect: usize = specs.iter().map(|s| s.prompt_len).sum();
        let d_expect: usize = specs.iter().map(|s| s.decode_len - 1).sum();
        if e.metrics.total_prefill_tokens() != p_expect {
            return Err(format!(
                "prefill tokens {} != {}",
                e.metrics.total_prefill_tokens(),
                p_expect
            ));
        }
        if e.metrics.total_decode_tokens() != d_expect {
            return Err(format!(
                "decode tokens {} != {}",
                e.metrics.total_decode_tokens(),
                d_expect
            ));
        }
        // every slot returned
        if e.kv.available() != max_batch {
            return Err("leaked KV blocks".into());
        }
        Ok(())
    });
}

#[test]
fn sarathi_batches_are_decode_maximal_and_tile_bounded() {
    check("sarathi composition invariants", 60, |case| {
        let specs = rand_workload(case);
        let max_batch = case.rng.usize(2, 10);
        let chunk = *case.rng.choose(&[128usize, 256, 512]);
        let cm = CostModel::new(ModelConfig::llama13b(), GpuConfig::a6000());
        let exec = ValidatingExec { inner: SimExecutor::new(cm), max_batch, batches: vec![] };
        let mut e = Engine::new(
            RequestPool::from_specs(&specs),
            KvManager::new(max_batch),
            Box::new(SarathiScheduler::new(chunk, max_batch, 128)),
            Box::new(exec),
        );
        e.run();
        let exec = e.executor.as_any().downcast_ref::<ValidatingExec>().unwrap();
        for &(chunks, p_tokens, decodes) in &exec.batches {
            // §4.3: at most ONE prefill chunk per batch
            if chunks > 1 {
                return Err(format!("{chunks} prefill chunks in one batch"));
            }
            // §4.4: fused token count never exceeds the chunk budget C
            if chunks == 1 && p_tokens + decodes > chunk {
                return Err(format!(
                    "fused tokens {} exceed chunk budget {chunk}",
                    p_tokens + decodes
                ));
            }
            // piggyback cap: decodes ≤ B−1 when a chunk is present
            if chunks == 1 && decodes > max_batch - 1 {
                return Err(format!("{decodes} piggybacked decodes with B={max_batch}"));
            }
        }
        Ok(())
    });
}

#[test]
fn orca_worst_never_mixes_phases() {
    check("orca-worst phase separation", 40, |case| {
        let specs = rand_workload(case);
        let max_batch = case.rng.usize(1, 8);
        let cm = CostModel::new(ModelConfig::llama13b(), GpuConfig::a6000());
        let exec = ValidatingExec { inner: SimExecutor::new(cm), max_batch, batches: vec![] };
        let mut e = Engine::new(
            RequestPool::from_specs(&specs),
            KvManager::new(max_batch),
            Box::new(OrcaScheduler::worst(max_batch)),
            Box::new(exec),
        );
        e.run();
        let exec = e.executor.as_any().downcast_ref::<ValidatingExec>().unwrap();
        for &(chunks, _p, decodes) in &exec.batches {
            if chunks > 0 && decodes > 0 {
                return Err("orca-worst mixed prefill and decode".into());
            }
        }
        Ok(())
    });
}

#[test]
fn completion_times_ordered_after_arrivals() {
    check("completion after arrival, first token before completion", 40, |case| {
        let specs = rand_workload(case);
        let max_batch = case.rng.usize(1, 6);
        let (sched, _n) = make_sched(case, max_batch);
        let cm = CostModel::new(ModelConfig::llama13b(), GpuConfig::a6000());
        let mut e = Engine::new(
            RequestPool::from_specs(&specs),
            KvManager::new(max_batch),
            sched,
            Box::new(SimExecutor::new(cm)),
        );
        e.run();
        for r in e.pool.iter() {
            let done = r.completed_at.ok_or("missing completion")?;
            let first = r.first_token_at.ok_or("missing first token")?;
            if done + 1e-12 < r.arrival {
                return Err(format!("completed {done} before arrival {}", r.arrival));
            }
            if first > done + 1e-12 {
                return Err("first token after completion".into());
            }
        }
        Ok(())
    });
}

#[test]
fn metrics_attribution_is_nonnegative_and_bounded() {
    check("marginal decode attribution sane", 40, |case| {
        let specs = rand_workload(case);
        let max_batch = case.rng.usize(2, 8);
        let chunk = *case.rng.choose(&[128usize, 256]);
        let cm = CostModel::new(ModelConfig::llama13b(), GpuConfig::a6000());
        let mut e = Engine::new(
            RequestPool::from_specs(&specs),
            KvManager::new(max_batch),
            Box::new(SarathiScheduler::new(chunk, max_batch, 128)),
            Box::new(SimExecutor::new(cm)),
        );
        e.run();
        for rec in e.metrics.iter_records() {
            if rec.elapsed <= 0.0 {
                return Err("non-positive iteration time".into());
            }
            if let Some(alone) = rec.prefill_alone {
                if alone > rec.elapsed + 1e-12 {
                    return Err(format!(
                        "prefill-alone {alone} exceeds hybrid {}",
                        rec.elapsed
                    ));
                }
            }
        }
        let d = e.metrics.decode_time_per_token();
        if d < 0.0 {
            return Err("negative decode time per token".into());
        }
        Ok(())
    });
}
