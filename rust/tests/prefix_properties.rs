//! Property suite for ref-counted, copy-on-write prefix sharing over the
//! paged KV block map (alongside kv_properties.rs, which covers the
//! unshared allocator).
//!
//! Refcounted allocators are exactly where silent double-frees and leaks
//! hide, so the invariants are checked after EVERY step, not just at the
//! end:
//!
//! * **Refcount conservation** — for every block, the allocator's refcount
//!   equals the number of request tables holding it plus the number of
//!   registered prefix runs pinning it.
//! * **No double-free / no leak** — `allocated() + available() ==
//!   capacity()` throughout; after all requests release and all prefixes
//!   are evicted, every block is free.
//! * **COW discipline** — a request only ever appends into blocks with
//!   refcount 1 (its private tail); the leading `shared_blocks` of its
//!   table are exactly a registered run's head; `fork_block` never hands
//!   out a block with refcount > 1.
//!
//! The engine property drives random admit / preempt / complete
//! interleavings (Zipf template fanout under block pressure) across 45
//! seeds via the deterministic `check` harness.

use std::collections::HashMap;

use sarathi::config::{GpuConfig, ModelConfig};
use sarathi::coordinator::sched::HybridScheduler;
use sarathi::coordinator::{derived_path, Engine, KvManager, RequestPool, SimExecutor};
use sarathi::costmodel::CostModel;
use sarathi::util::prop::check;
use sarathi::workload::{
    shared_prefix_population, with_poisson_arrivals, PrefixSpec, RequestSpec,
};

/// Refcount conservation over the whole system: every block's refcount
/// equals its holders (active request tables + registered prefix pins).
fn check_refcounts(pools: &[&RequestPool], kv: &KvManager) -> Result<(), String> {
    let mut held: HashMap<usize, usize> = HashMap::new();
    for pool in pools {
        for &id in pool.active_ids() {
            for &b in &pool.get(id).blocks {
                *held.entry(b).or_insert(0) += 1;
            }
        }
    }
    for (_, _, run) in kv.registered_prefixes() {
        for &b in run {
            *held.entry(b).or_insert(0) += 1;
        }
    }
    for b in 0..kv.capacity() {
        let expect = held.get(&b).copied().unwrap_or(0);
        if kv.ref_count(b) != expect {
            return Err(format!(
                "block {b}: refcount {} != {expect} holders (request tables + pins)",
                kv.ref_count(b)
            ));
        }
    }
    if kv.allocated() + kv.available() != kv.capacity() {
        return Err("allocated + available != capacity".into());
    }
    Ok(())
}

/// COW discipline per active request: the table splits into a shared head
/// (a registered run's prefix, refcount ≥ 1 from the pin) and a private
/// tail every block of which has refcount exactly 1 — so growth/appends
/// can never mutate shared content.
fn check_split_tables(pool: &RequestPool, kv: &KvManager) -> Result<(), String> {
    for &id in pool.active_ids() {
        let r = pool.get(id);
        if r.shared_blocks > r.blocks.len() {
            return Err(format!("request {id}: shared head exceeds its table"));
        }
        for &b in &r.blocks[r.shared_blocks..] {
            if kv.ref_count(b) != 1 {
                return Err(format!(
                    "request {id}: private block {b} has refcount {} — an append would \
                     mutate shared content",
                    kv.ref_count(b)
                ));
            }
        }
        if r.shared_blocks > 0 {
            let pfx = r.spec.prefix.as_ref().ok_or("untagged request holds a shared head")?;
            let Some((_, run)) = kv.lookup_prefix(pfx.id) else {
                return Err(format!(
                    "request {id}: shared head but its prefix is not resident"
                ));
            };
            if run[..r.shared_blocks] != r.blocks[..r.shared_blocks] {
                return Err(format!(
                    "request {id}: shared head is not the registered run's head"
                ));
            }
            if r.shared_tokens != r.shared_blocks * kv.block_size() {
                return Err(format!(
                    "request {id}: shared_tokens {} != {} full blocks",
                    r.shared_tokens, r.shared_blocks
                ));
            }
        } else if r.shared_tokens != 0 {
            return Err(format!("request {id}: shared tokens without a shared head"));
        }
    }
    Ok(())
}

/// Wait-for-edge discipline (PR-4 bounded cache-aware admission): an edge
/// only lives on a queued, prefix-tagged, non-fallback request — admission
/// resolves it, fallback drops it, and it can never outlive either.
fn check_wait_discipline(pool: &RequestPool) -> Result<(), String> {
    for r in pool.iter() {
        if r.prefix_wait.is_some() {
            if r.is_admitted() {
                return Err(format!("request {}: admitted but still holds a wait edge", r.id));
            }
            if r.prefix_fallback {
                return Err(format!("request {}: fallback still holds a wait edge", r.id));
            }
            if r.spec.prefix.is_none() {
                return Err(format!("request {}: untagged request waits on a prefix", r.id));
            }
        }
    }
    Ok(())
}

/// One engine step with `Engine::run`-style wedge demotion: a stall with a
/// queued prefix waiter forces the oldest waiter's fallback instead of
/// failing the property.
fn step_or_demote(e: &mut Engine<'_>) -> Result<(), String> {
    if !e.step() {
        if let Some(id) = e.pool.oldest_prefix_waiter() {
            let now = e.now;
            // demote to the deepest READY ancestor on the waiter's
            // content path (0 = plain full-price miss) — Engine::run's rule
            let ready = match e.pool.get(id).spec.prefix.as_ref() {
                Some(pfx) if !pfx.path.is_empty() => {
                    let bs = e.kv.block_size().max(1);
                    let cap = e.pool.get(id).spec.prompt_len.saturating_sub(1);
                    let kb = (pfx.len.min(cap) / bs).min(pfx.path.len());
                    if kb > 0 {
                        e.kv.lookup_path_match(&pfx.path[..kb]).ready_tokens
                    } else {
                        0
                    }
                }
                _ => 0,
            };
            e.pool.force_prefix_fallback(id, now, ready);
            return Ok(());
        }
        return Err("engine wedged with no waiter to demote".into());
    }
    Ok(())
}

/// Allocator-level churn: random share/fork/release/register/evict against
/// a hand-maintained reference model of per-block holder counts.
#[test]
fn allocator_churn_conserves_refcounts_and_never_leaks() {
    check("refcounted allocator churn", 60, |case| {
        let bs = *case.rng.choose(&[4usize, 8, 16]);
        let num_blocks = case.rng.usize(4, 40);
        let mut kv = KvManager::paged(num_blocks, bs);
        // model: request tables + registered prefixes, as holder lists
        let mut tables: Vec<Vec<usize>> = Vec::new();
        let mut registered: Vec<(u64, Vec<usize>)> = Vec::new();
        let mut next_hash = 0u64;
        for _ in 0..150 {
            match case.rng.usize(0, 4) {
                // allocate a fresh table
                0 => {
                    let want = case.rng.usize(1, 4);
                    let before = kv.available();
                    let reclaimable = kv.reclaimable();
                    match kv.alloc_n(want) {
                        Some(t) => tables.push(t),
                        None => {
                            if before + reclaimable >= want {
                                return Err("alloc failed with funds available".into());
                            }
                            if kv.available() != before {
                                return Err("failed alloc must not leak".into());
                            }
                        }
                    }
                }
                // share an existing table's blocks as a new sharer
                1 if !tables.is_empty() => {
                    let i = case.rng.usize(0, tables.len() - 1);
                    let t = tables[i].clone();
                    tables.push(kv.share_seq(&t));
                }
                // COW-fork the last block of a table
                2 if !tables.is_empty() => {
                    let i = case.rng.usize(0, tables.len() - 1);
                    let last = tables[i].len() - 1;
                    let b = tables[i][last];
                    let rc_before = kv.ref_count(b);
                    match kv.fork_block(b) {
                        Some(nb) => {
                            if rc_before > 1 {
                                if nb == b {
                                    return Err("fork returned a shared block".into());
                                }
                                if kv.ref_count(b) != rc_before - 1 {
                                    return Err("fork did not move the reference".into());
                                }
                            } else if nb != b {
                                return Err("fork of a private block must be identity".into());
                            }
                            if kv.ref_count(nb) == 0 {
                                return Err("fork returned a free block".into());
                            }
                            tables[i][last] = nb;
                        }
                        None => {
                            if rc_before == 1 || kv.available() + kv.reclaimable() > 0 {
                                return Err("fork failed with funds available".into());
                            }
                        }
                    }
                }
                // register a table's head as a prefix (one level, no
                // nesting: skip heads that overlap an existing run)
                3 if !tables.is_empty() => {
                    let i = case.rng.usize(0, tables.len() - 1);
                    let n_run = case.rng.usize(1, tables[i].len());
                    let run: Vec<usize> = tables[i][..n_run].to_vec();
                    let overlaps = registered
                        .iter()
                        .any(|(_, r)| r.iter().any(|b| run.contains(b)));
                    if !overlaps {
                        let tokens = (n_run - 1) * bs + case.rng.usize(1, bs);
                        kv.register_prefix(next_hash, tokens, &run);
                        registered.push((next_hash, run));
                        next_hash += 1;
                    }
                }
                // release a table (a sharer completes / is preempted)
                _ if !tables.is_empty() => {
                    let i = case.rng.usize(0, tables.len() - 1);
                    let t = tables.swap_remove(i);
                    kv.release_seq(t); // double free would panic
                }
                _ => {}
            }
            // drop registrations the allocator reclaimed under pressure
            registered.retain(|(h, _)| kv.lookup_prefix(*h).is_some());
            // refcount conservation against the reference model
            let mut held: HashMap<usize, usize> = HashMap::new();
            for t in &tables {
                for &b in t {
                    *held.entry(b).or_insert(0) += 1;
                }
            }
            for (_, run) in &registered {
                for &b in run {
                    *held.entry(b).or_insert(0) += 1;
                }
            }
            for b in 0..kv.capacity() {
                let expect = held.get(&b).copied().unwrap_or(0);
                if kv.ref_count(b) != expect {
                    return Err(format!(
                        "block {b}: refcount {} != {expect} model holders",
                        kv.ref_count(b)
                    ));
                }
            }
            if kv.allocated() + kv.available() != kv.capacity() {
                return Err("allocated + available != capacity".into());
            }
        }
        // teardown: all sharers release, all prefixes evicted → empty pool
        for t in tables.drain(..) {
            kv.release_seq(t);
        }
        for (h, _) in registered.drain(..) {
            kv.evict_prefix(h);
        }
        if kv.available() != kv.capacity() {
            return Err("blocks leaked after full release + eviction".into());
        }
        Ok(())
    });
}

/// Full-engine churn: shared-prefix template traffic over a tight paged
/// pool, so admissions hit, miss, fork, preempt and resume across ≥40
/// seeds — with the refcount/COW/no-leak invariants checked after every
/// single engine step.
#[test]
fn engine_interleavings_conserve_refcounts_without_double_free_or_leak() {
    let mut total_preemptions = 0usize;
    let mut total_hits = 0usize;
    check("prefix sharing under admit/preempt/complete churn", 45, |case| {
        let n = 6 + case.rng.usize(0, 6 + case.size / 2);
        let num_templates = case.rng.usize(1, 3);
        let bs = *case.rng.choose(&[8usize, 16, 32]);
        let prefix_len = case.rng.usize(bs, 4 * bs); // partial blocks likely
        let specs = shared_prefix_population(
            &mut case.rng,
            n,
            num_templates,
            0.8,
            prefix_len,
            8,
            48,
            2.0,
        );
        let watermark = case.rng.usize(0, 2);
        // pool sized to the single largest lifetime footprint plus pins
        // plus a little slack — tight enough that growth preempts often
        let peak = specs.iter().map(|s| s.prompt_len + s.decode_len).max().unwrap();
        let probe = KvManager::paged(1, bs);
        let pins = num_templates * probe.blocks_needed(prefix_len);
        let num_blocks =
            probe.blocks_needed(peak + 1) + pins + watermark + 1 + case.rng.usize(0, 4);
        let max_batch = case.rng.usize(2, 6);
        let budget = (*case.rng.choose(&[32usize, 64])).max(max_batch);

        let cm = CostModel::new(ModelConfig::llama13b(), GpuConfig::a6000());
        let mut e = Engine::new(
            RequestPool::from_specs(&specs),
            KvManager::paged(num_blocks, bs),
            Box::new(
                HybridScheduler::new(budget, max_batch, watermark).with_prefix_share(true),
            ),
            Box::new(SimExecutor::new(cm)),
        );
        // drive step by step so invariants hold at every boundary, not
        // just at the end of the run
        let mut steps = 0usize;
        while !e.pool.all_complete() {
            steps += 1;
            if steps > 200_000 {
                return Err("runaway engine".into());
            }
            step_or_demote(&mut e)?;
            check_refcounts(&[&e.pool], &e.kv)?;
            check_split_tables(&e.pool, &e.kv)?;
            check_wait_discipline(&e.pool)?;
            e.kv.assert_radix_invariants();
        }
        // token conservation with compute skips
        let skipped: usize = e.pool.iter().map(|r| r.prefix_skipped_tokens).sum();
        let p_expect: usize = specs.iter().map(|s| s.prompt_len).sum();
        let d_expect: usize = specs.iter().map(|s| s.decode_len - 1).sum();
        if e.metrics.total_prefill_tokens() + skipped != p_expect {
            return Err(format!(
                "prefill {} + skipped {skipped} != {p_expect}",
                e.metrics.total_prefill_tokens()
            ));
        }
        if e.metrics.total_decode_tokens() != d_expect {
            return Err(format!(
                "decode tokens {} != {d_expect}",
                e.metrics.total_decode_tokens()
            ));
        }
        // per-request and metrics hit counters agree
        let per_req_hits: usize = e.pool.iter().map(|r| r.prefix_hits).sum();
        if e.metrics.prefix_hits != per_req_hits {
            return Err(format!(
                "metrics hits {} != per-request {per_req_hits}",
                e.metrics.prefix_hits
            ));
        }
        // no leak: only registered pins may hold blocks now
        check_refcounts(&[&e.pool], &e.kv)?;
        let pinned: usize = e.kv.registered_prefixes().map(|(_, _, run)| run.len()).sum();
        if e.kv.available() + pinned != num_blocks {
            return Err(format!(
                "leak: {} free + {pinned} pinned != {num_blocks}",
                e.kv.available()
            ));
        }
        // evicting every prefix must drain the pool completely
        let hashes: Vec<u64> = e.kv.registered_prefixes().map(|(h, _, _)| h).collect();
        for h in hashes {
            e.kv.evict_prefix(h);
        }
        if e.kv.available() != num_blocks {
            return Err("blocks leaked past prefix eviction".into());
        }
        total_preemptions += e.metrics.preemptions;
        total_hits += e.metrics.prefix_hits;
        Ok(())
    });
    // the generator is tuned so both sharing AND block pressure actually
    // bite across the 45 seeds
    assert!(total_hits > 50, "only {total_hits} prefix hits — template fanout broken?");
    assert!(
        total_preemptions > 10,
        "only {total_preemptions} preemptions — pressure generator broken?"
    );
}

/// "No waiter waits forever": high-preemption storm seeds (seeds of this
/// shape wedged the PR-3 gate, which broke FCFS on a blocked head with no
/// fallback). Long prefixes over a small token budget starve registrant
/// fills for many iterations while Poisson arrivals queue waiters behind
/// them; a 2×-peak pool keeps decode growth preempting. Every blocked
/// request must resolve — admit as a hit, fall back as a full-price miss,
/// or complete — with the wait-edge discipline, refcount and COW
/// invariants checked after every step. Margins mirror-validated
/// (/tmp/prefix_mirror2.py over these exact 30 seeds: 13 fallbacks on 9
/// seeds, 60 preemptions, 504 hits, zero wedges).
#[test]
fn no_waiter_waits_forever_under_preemption_storms() {
    let mut total_fallbacks = 0usize;
    let mut total_preemptions = 0usize;
    let mut total_hits = 0usize;
    check("bounded prefix-waits under preemption storms", 30, |case| {
        let n = 16 + case.rng.usize(0, 12 + case.size / 2);
        let num_templates = 2 + case.rng.usize(0, 2);
        let bs = *case.rng.choose(&[16usize, 32]);
        let prefix_len = 8 * bs + case.rng.usize(0, 4 * bs);
        let specs =
            shared_prefix_population(&mut case.rng, n, num_templates, 0.8, prefix_len, 8, 48, 0.5);
        let specs = with_poisson_arrivals(&mut case.rng, specs, 8.0);
        let watermark = case.rng.usize(0, 2);
        let max_wait = case.rng.usize(2, 6);
        let peak = specs.iter().map(|s| s.prompt_len + s.decode_len).max().unwrap();
        let probe = KvManager::paged(1, bs);
        let num_blocks =
            2 * probe.blocks_needed(peak + 1) + watermark + 1 + case.rng.usize(0, 4);
        let max_batch = case.rng.usize(4, 8);
        let budget = 24usize.max(max_batch);

        let cm = CostModel::new(ModelConfig::llama13b(), GpuConfig::a6000());
        let mut e = Engine::new(
            RequestPool::from_specs(&specs),
            KvManager::paged(num_blocks, bs),
            Box::new(
                HybridScheduler::new(budget, max_batch, watermark)
                    .with_prefix_share(true)
                    .with_max_prefix_wait(max_wait),
            ),
            Box::new(SimExecutor::new(cm)),
        );
        let mut steps = 0usize;
        while !e.pool.all_complete() {
            steps += 1;
            if steps > 400_000 {
                return Err("runaway engine".into());
            }
            step_or_demote(&mut e)?;
            check_refcounts(&[&e.pool], &e.kv)?;
            check_split_tables(&e.pool, &e.kv)?;
            check_wait_discipline(&e.pool)?;
            e.kv.assert_radix_invariants();
        }
        // every blocked request resolved; no edge survives the run
        for r in e.pool.iter() {
            if r.completed_at.is_none() {
                return Err(format!("request {} never completed", r.id));
            }
            if r.is_prefix_waiting() {
                return Err(format!("request {} holds a wait edge at the end", r.id));
            }
        }
        // event accounting agrees with per-request state
        let per_req_fallbacks = e.pool.iter().filter(|r| r.prefix_fallback).count();
        if e.metrics.prefix_fallbacks != per_req_fallbacks {
            return Err(format!(
                "metrics fallbacks {} != per-request {per_req_fallbacks}",
                e.metrics.prefix_fallbacks
            ));
        }
        let per_req_waits: usize = e.pool.iter().map(|r| r.prefix_wait_iters).sum();
        if e.metrics.prefix_wait_iterations != per_req_waits {
            return Err(format!(
                "metrics wait iters {} != per-request {per_req_waits}",
                e.metrics.prefix_wait_iterations
            ));
        }
        total_fallbacks += e.metrics.prefix_fallbacks;
        total_preemptions += e.metrics.preemptions;
        total_hits += e.metrics.prefix_hits;
        Ok(())
    });
    // the storm generator must actually exercise the fallback machinery
    assert!(total_fallbacks > 0, "no fallbacks — the storm generator lost its teeth");
    assert!(total_preemptions > 10, "only {total_preemptions} preemptions");
    assert!(total_hits > 100, "only {total_hits} hits — sharing still must win overall");
}

/// Everything one engine run observes, in comparable form. Completion
/// times as raw bit patterns: "equivalent" means bitwise, not close.
#[derive(Debug, PartialEq)]
struct RunTrace {
    completions: Vec<u64>,
    skipped_per_request: Vec<usize>,
    hits: usize,
    partial_hits: usize,
    partial_hit_tokens: usize,
    fallbacks: usize,
    preemptions: usize,
    prefill_tokens: usize,
    decode_tokens: usize,
    peak_blocks: usize,
    peak_shared: usize,
}

fn trace_run(specs: &[RequestSpec], num_blocks: usize, bs: usize) -> Result<RunTrace, String> {
    let cm = CostModel::new(ModelConfig::llama13b(), GpuConfig::a6000());
    let mut e = Engine::new(
        RequestPool::from_specs(specs),
        KvManager::paged(num_blocks, bs),
        Box::new(
            HybridScheduler::new(64, 6, 1)
                .with_prefix_share(true)
                // bounded-wait expiry is the one seam where a content
                // path legitimately beats a flat tag (a demoted path
                // salvages the ready partial match); push it out of
                // reach so this test compares the COMMON admission paths
                .with_max_prefix_wait(100_000),
        ),
        Box::new(SimExecutor::new(cm)),
    );
    e.run();
    e.kv.assert_radix_invariants();
    let mut completions = Vec::new();
    let mut skipped = Vec::new();
    for r in e.pool.iter() {
        completions.push(r.completed_at.ok_or_else(|| format!("request {} wedged", r.id))?.to_bits());
        skipped.push(r.prefix_skipped_tokens);
    }
    Ok(RunTrace {
        completions,
        skipped_per_request: skipped,
        hits: e.metrics.prefix_hits,
        partial_hits: e.metrics.prefix_partial_hits,
        partial_hit_tokens: e.metrics.prefix_partial_hit_tokens,
        fallbacks: e.metrics.prefix_fallbacks,
        preemptions: e.metrics.preemptions,
        prefill_tokens: e.metrics.total_prefill_tokens(),
        decode_tokens: e.metrics.total_decode_tokens(),
        peak_blocks: e.metrics.peak_kv_blocks_in_use(),
        peak_shared: e.metrics.peak_shared_kv_tokens(),
    })
}

/// Drop-in equivalence (the tentpole's regression gate): on single-path,
/// non-overlapping template workloads, lowering every flat `{id, len}`
/// tag to its explicit derived content path — exactly the lowering
/// registration performs internally — must change NOTHING observable.
/// First arrivals take the content-path-miss branch instead of the flat
/// one, but both plans are field-identical (same run, same registration,
/// same skip of 0); followers resolve by hash in both modes. Compared
/// bitwise on completions and exactly on every sharing counter, across
/// 20 seeds. The pool is sized so nothing preempts and no wait ever
/// expires: fallback demotion is the one seam where the two forms
/// legitimately diverge (asserted zero here).
#[test]
fn derived_path_tags_are_bitwise_equivalent_to_flat_tags() {
    check("radix drop-in equivalence vs flat index", 20, |case| {
        let bs = *case.rng.choose(&[8usize, 16, 32]);
        let n = 12 + case.rng.usize(0, 12);
        let num_templates = case.rng.usize(1, 3);
        let prefix_len = case.rng.usize(2 * bs, 6 * bs);
        let flat = with_poisson_arrivals(
            &mut case.rng,
            shared_prefix_population(&mut case.rng, n, num_templates, 0.8, prefix_len, 8, 48, 2.0),
            6.0,
        );
        let pathy: Vec<RequestSpec> = flat
            .iter()
            .map(|s| {
                let p = s.prefix.as_ref().expect("template populations tag every request");
                let mut s2 = s.clone();
                s2.prefix =
                    Some(PrefixSpec::with_path(p.id, p.len, derived_path(p.id, p.len / bs)));
                s2
            })
            .collect();
        // ample pool: every live footprint plus every pin fits at once
        let probe = KvManager::paged(1, bs);
        let num_blocks = flat
            .iter()
            .map(|s| probe.blocks_needed(s.prompt_len + s.decode_len + 1))
            .sum::<usize>()
            + num_templates * probe.blocks_needed(prefix_len)
            + 4;
        let a = trace_run(&flat, num_blocks, bs)?;
        let b = trace_run(&pathy, num_blocks, bs)?;
        if a.fallbacks != 0 || b.fallbacks != 0 {
            return Err(format!(
                "equivalence precondition violated: fallbacks {} / {}",
                a.fallbacks, b.fallbacks
            ));
        }
        if a != b {
            return Err(format!("flat and path-lowered runs diverged:\n{a:#?}\nvs\n{b:#?}"));
        }
        Ok(())
    });
}
