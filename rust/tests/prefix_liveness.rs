//! Liveness acceptance for deadlock-free cache-aware admission — the
//! ROADMAP scenario reconstructed: multiple templates over an UNDERSIZED
//! shared paged pool, two pipeline streams, Poisson arrivals, preemption
//! storms. Under the PR-3 gate a fresh same-template arrival waited
//! unboundedly for an in-flight prefix fill; with the filler preempted (or
//! budget-starved) behind the waiter's own FCFS queue head, that circular
//! wait surfaced as the loud "pipeline wedged" panic.
//!
//! The claims under test, over 24 seeds of the storm workload (4
//! templates × 384-token prefixes, decode-heavy unique parts at P:D 0.34,
//! Poisson 6 req/s, a 30-block × 32-token pool shared by both streams,
//! token budget 32 so fills starve under load, `max_prefix_wait = 4`):
//!
//! 1. **Zero wedge panics** — every run completes every request (no NaN
//!    completions; a panic fails the test outright).
//! 2. **The fallback machinery fires** — `prefix_fallbacks > 0` across the
//!    seeds: bounded waits actually degrade to full-price misses under the
//!    storm, they are not dead code.
//! 3. **Bounded TTFT inflation** — P99 TTFT of the fallback victims is no
//!    worse than P99 TTFT of the SAME workload with sharing disabled: a
//!    fallback is never worse than never having cached.
//!
//! Margins pre-validated with the Python mirror of the Rng + cost model +
//! event-driven two-stream pipeline extended with the wait/fallback state
//! machine (/tmp/liveness_mirror.py): 12–16 fallbacks on 9–12 of the 24
//! seeds, zero wedges, and a fallback-vs-baseline P99 TTFT ratio of
//! ≈ 0.60, stable under ±20% stage-time perturbation (the profiler
//! interpolation differs from the raw cost model).

use sarathi::config::{Deployment, GpuConfig, ModelConfig, ParallelConfig};
use sarathi::coordinator::sched::HybridScheduler;
use sarathi::coordinator::{KvManager, Scheduler};
use sarathi::costmodel::CostModel;
use sarathi::profiler::Profiler;
use sarathi::simulator::{PipelineResult, PipelineSim};
use sarathi::util::{Rng, Summary};
use sarathi::workload::{shared_prefix_population, with_poisson_arrivals, RequestSpec};

const SEEDS: u64 = 24;
const N: usize = 60;
const TEMPLATES: usize = 4;
const PREFIX_LEN: usize = 384;
const BLOCKS: usize = 30;
const BS: usize = 32;
const BUDGET: usize = 32;
const MAX_BATCH: usize = 8;
const WATERMARK: usize = 1;
const MAX_WAIT: usize = 4;
const RATE: f64 = 6.0;

fn pp2_sim() -> PipelineSim {
    let d = Deployment::new(ModelConfig::llama13b(), GpuConfig::a6000(), 2048)
        .with_parallel(ParallelConfig::tp_pp(1, 2));
    PipelineSim::new(Profiler::build(CostModel::for_deployment(&d), d.max_seq_len, 16), 2)
}

fn storm_workload(seed: u64) -> Vec<RequestSpec> {
    let mut rng = Rng::new(seed);
    let pop = shared_prefix_population(&mut rng, N, TEMPLATES, 0.8, PREFIX_LEN, 16, 64, 0.34);
    with_poisson_arrivals(&mut rng, pop, RATE)
}

fn run(sim: &PipelineSim, specs: &[RequestSpec], share: bool) -> PipelineResult {
    sim.run_shared(specs, KvManager::paged(BLOCKS, BS), None, || {
        Box::new(
            HybridScheduler::new(BUDGET, MAX_BATCH, WATERMARK)
                .with_prefix_share(share)
                .with_max_prefix_wait(MAX_WAIT),
        ) as Box<dyn Scheduler + Send>
    })
}

#[test]
fn cross_stream_preemption_storms_never_wedge_and_fallbacks_stay_cheap() {
    let mut total_fallbacks = 0usize;
    let mut total_hits = 0usize;
    let mut total_preemptions = 0usize;
    let mut total_wait_iters = 0usize;
    let mut fallback_ttft = Summary::new();
    let mut off_ttft = Summary::new();
    let sim = pp2_sim();
    for seed in 0..SEEDS {
        let specs = storm_workload(1000 + seed);
        // sharing ON: seeds of this shape wedged the PR-3 gate; every run
        // must now complete (a "pipeline wedged" panic fails the test)
        let on = run(&sim, &specs, true);
        assert!(
            on.completions.iter().all(|t| !t.is_nan()),
            "seed {seed}: a request starved under cache-aware admission"
        );
        assert!(on.first_tokens.iter().all(|t| !t.is_nan()));
        total_fallbacks += on.metrics.prefix_fallbacks;
        total_hits += on.metrics.prefix_hits;
        total_preemptions += on.metrics.preemptions;
        total_wait_iters += on.metrics.prefix_wait_iterations;
        for (g, &fb) in on.prefix_fallback.iter().enumerate() {
            if fb {
                fallback_ttft.add(on.first_tokens[g] - specs[g].arrival);
            }
        }
        // sharing OFF on the SAME workload: the never-cached baseline
        let off = run(&sim, &specs, false);
        assert!(off.completions.iter().all(|t| !t.is_nan()));
        assert_eq!(off.metrics.prefix_fallbacks, 0, "no sharing, no fallbacks");
        for (g, &t) in off.first_tokens.iter().enumerate() {
            off_ttft.add(t - specs[g].arrival);
        }
    }
    // the storm must actually bite — and the wait/fallback machinery with it
    assert!(total_preemptions > 0, "storm workload stopped preempting");
    assert!(total_hits > 0, "storm workload stopped hitting the cache");
    assert!(total_wait_iters > 0, "nobody ever waited on a fill");
    assert!(
        total_fallbacks > 0,
        "no prefix_fallbacks on any of {SEEDS} seeds — bounded waits never expired"
    );
    // bounded TTFT inflation for the fallback victims (mirror: ratio 0.60)
    assert!(
        fallback_ttft.percentile(99.0) <= off_ttft.percentile(99.0),
        "fallback P99 TTFT {:.2}s exceeds the no-share baseline P99 {:.2}s — \
         a fallback must never be worse than never having cached",
        fallback_ttft.percentile(99.0),
        off_ttft.percentile(99.0)
    );
}
