//! PR-6 acceptance: the multi-threaded routed cluster loop is a pure
//! wall-clock optimization. For every thread count, every seed, the
//! simulation must be BITWISE identical to the serial heap-driven loop —
//! same completion times bit for bit, same merged JSONL trace byte for
//! byte. Replicas only synchronize at dispatch instants and share no
//! state in between, so any divergence is a real scheduling/ordering bug,
//! not float noise — hence `to_bits`, not tolerances.

use sarathi::config::{Deployment, GpuConfig, ModelConfig, ParallelConfig};
use sarathi::coordinator::sched::HybridScheduler;
use sarathi::coordinator::{KvManager, Scheduler};
use sarathi::simulator::{ClusterResult, ClusterSim, PrefixAffinity};
use sarathi::util::Rng;
use sarathi::workload::{shared_prefix_population, with_template_burst_arrivals, RequestSpec};

const REPLICAS: usize = 8;
const SEEDS: u64 = 8;
const THREADS: [usize; 3] = [2, 4, 8];

fn cluster() -> ClusterSim {
    ClusterSim::new(
        Deployment::new(ModelConfig::llama13b(), GpuConfig::a6000(), 2048)
            .with_parallel(ParallelConfig::tp_pp(1, 1).with_replicas(REPLICAS)),
    )
}

/// Bursty shared-prefix traffic (salted template ids per seed, like the
/// router acceptance suite) — prefix waits, preemptions and bypasses all
/// fire, so the determinism claim covers the gnarly paths too.
fn workload(seed: u64) -> Vec<RequestSpec> {
    let mut rng = Rng::new(seed);
    let mut pop = shared_prefix_population(&mut rng, 160, 12, 0.55, 384, 64, 256, 4.0);
    for s in pop.iter_mut() {
        if let Some(p) = s.prefix.as_mut() {
            p.id += seed * 7919;
        }
    }
    with_template_burst_arrivals(&mut rng, pop, 48.0, 6)
}

fn run(cluster: &ClusterSim, pop: &[RequestSpec], threads: usize) -> ClusterResult {
    let mut router = PrefixAffinity::new(PrefixAffinity::DEFAULT_SPILL);
    cluster.run_routed_threads(
        pop,
        &mut router,
        || KvManager::paged(32, 32),
        None,
        || {
            Box::new(HybridScheduler::new(256, 8, 2).with_prefix_share(true))
                as Box<dyn Scheduler + Send>
        },
        threads,
    )
}

fn jsonl_of(res: &ClusterResult, tag: &str) -> String {
    let name = format!("sarathi_determinism_{tag}_{}.jsonl", std::process::id());
    let path = std::env::temp_dir().join(name);
    res.write_jsonl(&path).expect("write jsonl trace");
    let text = std::fs::read_to_string(&path).expect("read jsonl trace back");
    let _ = std::fs::remove_file(&path);
    text
}

#[test]
fn threaded_routed_runs_are_bitwise_identical_to_serial() {
    let cluster = cluster();
    for seed in 1..=SEEDS {
        let pop = workload(seed);
        let serial = run(&cluster, &pop, 1);
        assert!(
            serial.completions.iter().all(|t| !t.is_nan()),
            "seed {seed}: every request must complete"
        );
        let serial_trace = jsonl_of(&serial, &format!("s{seed}_t1"));
        for threads in THREADS {
            let threaded = run(&cluster, &pop, threads);
            assert_eq!(
                serial.completions.len(),
                threaded.completions.len(),
                "seed {seed} threads {threads}: completion count diverged"
            );
            for (i, (a, b)) in
                serial.completions.iter().zip(&threaded.completions).enumerate()
            {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "seed {seed} threads {threads} request {i}: {a} != {b}"
                );
            }
            let threaded_trace = jsonl_of(&threaded, &format!("s{seed}_t{threads}"));
            assert_eq!(
                serial_trace, threaded_trace,
                "seed {seed} threads {threads}: merged JSONL trace diverged"
            );
        }
    }
}

/// threads=0 (auto: one worker per core) goes through the same parallel
/// machinery with a machine-dependent worker count — it too must match.
#[test]
fn auto_thread_count_matches_serial() {
    let cluster = cluster();
    let pop = workload(99);
    let serial = run(&cluster, &pop, 1);
    let auto = run(&cluster, &pop, 0);
    for (a, b) in serial.completions.iter().zip(&auto.completions) {
        assert_eq!(a.to_bits(), b.to_bits(), "threads=0 diverged from serial");
    }
}
