//! Integration tests over the real PJRT runtime: load AOT artifacts, serve
//! the tiny model, and verify the SARATHI scheduling invariants hold on the
//! real execution path (not just the simulator).
//!
//! These require `make artifacts` and the `pjrt` cargo feature (the xla
//! PJRT bindings are not available offline); they are skipped (with a
//! note) if the artifacts directory is missing.
#![cfg(feature = "pjrt")]

use std::path::PathBuf;

use sarathi::coordinator::{Engine, KvManager, RequestPool};
use sarathi::coordinator::sched::{OrcaScheduler, RequestLevelScheduler, SarathiScheduler};
use sarathi::runtime::{GenRequest, ModelRuntime, RealExecutor};
use sarathi::util::Rng;
use sarathi::workload::RequestSpec;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

fn runtime() -> Option<ModelRuntime> {
    artifacts_dir().map(|d| ModelRuntime::load(&d).expect("loading artifacts"))
}

fn rand_prompt(rng: &mut Rng, n: usize, vocab: usize) -> Vec<i32> {
    (0..n).map(|_| rng.usize(0, vocab - 1) as i32).collect()
}

#[test]
fn loads_and_generates() {
    let Some(mut rt) = runtime() else { return };
    assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
    let mut rng = Rng::new(3);
    let vocab = rt.manifest.model.vocab;
    let prompt = rand_prompt(&mut rng, 40, vocab);
    let out = rt.generate_greedy(&prompt, 0, 8).expect("generate");
    assert_eq!(out.len(), 8);
    assert!(out.iter().all(|&t| (t as usize) < vocab));
}

#[test]
fn generation_is_deterministic_across_sessions() {
    let Some(mut rt) = runtime() else { return };
    let mut rng = Rng::new(4);
    let prompt = rand_prompt(&mut rng, 33, rt.manifest.model.vocab);
    let a = rt.generate_greedy(&prompt, 0, 6).unwrap();
    rt.reset_kv().unwrap();
    let b = rt.generate_greedy(&prompt, 0, 6).unwrap();
    assert_eq!(a, b);
}

#[test]
fn chunked_prefill_equals_coarse_prefill() {
    // §4.2 equivalence on the REAL path: prefilling in 16-token chunks and
    // in 32-token chunks yields identical greedy continuations.
    let Some(mut rt) = runtime() else { return };
    let mut rng = Rng::new(5);
    let vocab = rt.manifest.model.vocab;
    let prompt = rand_prompt(&mut rng, 48, vocab);

    // fine chunks (bucket 16)
    let mut last = None;
    for start in (0..48).step_by(16) {
        let out = rt.prefill_chunk(&prompt[start..start + 16], 0, start).unwrap();
        last = Some(out.logits);
    }
    let fine = last.unwrap();

    rt.reset_kv().unwrap();
    // coarse chunks (bucket 32): 32 + 16
    rt.prefill_chunk(&prompt[..32], 0, 0).unwrap();
    let coarse = rt.prefill_chunk(&prompt[32..48], 0, 32).unwrap().logits;

    let max_err = fine
        .iter()
        .zip(&coarse)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < 1e-3, "chunked-prefill mismatch: {max_err}");
}

#[test]
fn hybrid_step_matches_separate_execution() {
    // decode-maximal fusion must not change values (§4.3): run a chunk +
    // decode lane fused, and the same work separately, compare logits.
    let Some(mut rt) = runtime() else { return };
    let mut rng = Rng::new(6);
    let vocab = rt.manifest.model.vocab;
    let a_prompt = rand_prompt(&mut rng, 32, vocab);
    let b_prompt = rand_prompt(&mut rng, 16, vocab);

    // request A prefilled in slot 0; first token known
    let a_logits = rt.prefill_all(&a_prompt, 0).unwrap();
    let a_tok = sarathi::runtime::argmax(&a_logits) as i32;

    // separate: B chunk in slot 1, then A decode
    rt.prefill_chunk(&b_prompt, 1, 0).unwrap();
    let sep = rt.decode(&[(a_tok, 0, 32)]).unwrap().logits[0].clone();

    // fused: reset, rebuild A state, then hybrid(B chunk, A decode)
    rt.reset_kv().unwrap();
    rt.prefill_all(&a_prompt, 0).unwrap();
    let (_, d_out) = rt.hybrid(&b_prompt, 1, 0, &[(a_tok, 0, 32)]).unwrap();
    let fused = &d_out.logits[0];

    let max_err = sep
        .iter()
        .zip(fused)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < 1e-3, "hybrid fusion changed logits: {max_err}");
}

/// Full end-to-end: the SARATHI engine drives the REAL model and every
/// request generates its full decode budget; output tokens must be
/// identical to the baseline scheduler's (scheduling must never change
/// results, only performance).
#[test]
fn engine_over_real_model_all_schedulers_agree() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rng = Rng::new(7);

    let prompts: Vec<Vec<i32>> = (0..4)
        .map(|i| rand_prompt(&mut rng, 24 + 8 * i, 256))
        .collect();
    let decode_len = 6usize;

    let mut results: Vec<Vec<Vec<i32>>> = Vec::new();
    type SchedFactory = fn(usize) -> Box<dyn sarathi::coordinator::Scheduler>;
    let factories: Vec<SchedFactory> = vec![
        |b| Box::new(RequestLevelScheduler::new(b)),
        |b| Box::new(OrcaScheduler::best(b)),
        |b| Box::new(SarathiScheduler::new(16, b, 16)),
    ];
    for make in factories {
        let rt = ModelRuntime::load(&dir).unwrap();
        let slots = rt.manifest.model.usable_slots();
        let gen_reqs: Vec<GenRequest> =
            prompts.iter().map(|p| GenRequest::new(p.clone())).collect();
        let specs: Vec<RequestSpec> = prompts
            .iter()
            .map(|p| RequestSpec { prompt_len: p.len(), decode_len, arrival: 0.0, prefix: None })
            .collect();
        let exec = RealExecutor::new(rt, gen_reqs);
        let mut engine = Engine::new(
            RequestPool::from_specs(&specs),
            KvManager::new(slots),
            make(slots),
            Box::new(exec),
        );
        engine.run();
        assert!(engine.pool.all_complete());
        // recover executor state via the downcast hook
        let exec = engine
            .executor
            .as_any()
            .downcast_ref::<RealExecutor>()
            .expect("executor is RealExecutor");
        assert!(exec.error.is_none(), "runtime error: {:?}", exec.error);
        let outs: Vec<Vec<i32>> = exec.requests.iter().map(|g| g.generated.clone()).collect();
        for o in &outs {
            assert_eq!(o.len(), decode_len);
        }
        results.push(outs);
    }
    // scheduling policy must not change the generated tokens
    assert_eq!(results[0], results[1], "orca-best diverged from baseline");
    assert_eq!(results[0], results[2], "sarathi diverged from baseline");
}
