//! Property tests over the pipeline-parallel simulator: randomized
//! workloads, stage counts and schedulers; the discrete-event invariants
//! must hold in every case — including over one shared paged KvManager
//! per replica with cross-stream preemption (mirroring
//! tests/kv_properties.rs at the pipeline level).

use sarathi::config::{Deployment, GpuConfig, ModelConfig, ParallelConfig};
use sarathi::coordinator::sched::{HybridScheduler, OrcaScheduler, SarathiScheduler};
use sarathi::coordinator::{KvManager, Scheduler};
use sarathi::costmodel::CostModel;
use sarathi::profiler::Profiler;
use sarathi::simulator::{PipelineResult, PipelineSim};
use sarathi::util::prop::{check, Case};
use sarathi::workload::RequestSpec;

fn rand_specs(case: &mut Case) -> Vec<RequestSpec> {
    let n = 2 + case.rng.usize(0, 6 + case.size);
    (0..n)
        .map(|_| RequestSpec {
            prompt_len: case.rng.usize(64, 2048),
            decode_len: case.rng.usize(1, 64),
            arrival: 0.0,
            prefix: None,
        })
        .collect()
}

fn rand_sim(case: &mut Case) -> (PipelineSim, usize) {
    let pp = *case.rng.choose(&[1usize, 2, 4, 8]);
    let d = Deployment::new(ModelConfig::gpt3(), GpuConfig::a100(), 4096)
        .with_parallel(ParallelConfig::tp_pp(8, pp))
        .with_batch_cap(16);
    let profiler = Profiler::build(CostModel::for_deployment(&d), 4096, 17);
    (PipelineSim::new(profiler, pp).with_trace(), pp)
}

fn rand_run(case: &mut Case) -> (PipelineResult, usize, usize) {
    let (sim, pp) = rand_sim(case);
    let specs = rand_specs(case);
    let slots = case.rng.usize(2, 16);
    let use_sarathi = case.rng.f64() < 0.5;
    let res = if use_sarathi {
        let chunk = *case.rng.choose(&[128usize, 256]);
        sim.run(&specs, slots, || {
            Box::new(SarathiScheduler::new(chunk, slots, 128)) as Box<dyn Scheduler + Send>
        })
    } else {
        sim.run(&specs, slots, || Box::new(OrcaScheduler::best(slots)) as Box<dyn Scheduler + Send>)
    };
    (res, specs.len(), pp)
}

#[test]
fn every_request_completes_exactly_once() {
    check("pipeline completion", 40, |case| {
        let (res, n, _pp) = rand_run(case);
        if res.completions.len() != n {
            return Err("completions length mismatch".into());
        }
        if res.completions.iter().any(|t| t.is_nan()) {
            return Err("request never completed".into());
        }
        if res.completions.iter().any(|&t| t < 0.0 || t > res.makespan + 1e-9) {
            return Err("completion outside [0, makespan]".into());
        }
        Ok(())
    });
}

#[test]
fn stage_executions_never_overlap() {
    check("per-stage mutual exclusion", 40, |case| {
        let (res, _n, pp) = rand_run(case);
        for stage in 0..pp {
            let mut evs: Vec<(f64, f64)> = res
                .trace
                .iter()
                .filter(|e| e.stage == stage)
                .map(|e| (e.start, e.end))
                .collect();
            evs.sort_by(|a, b| a.0.total_cmp(&b.0));
            for w in evs.windows(2) {
                if w[1].0 + 1e-12 < w[0].1 {
                    return Err(format!(
                        "stage {stage}: overlap {:?} then {:?}",
                        w[0], w[1]
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn micro_batches_flow_forward_through_stages() {
    check("stage ordering per micro-batch", 40, |case| {
        let (res, _n, pp) = rand_run(case);
        if pp < 2 {
            return Ok(());
        }
        use std::collections::HashMap;
        let mut per_mb: HashMap<usize, Vec<(usize, f64)>> = HashMap::new();
        for e in &res.trace {
            per_mb.entry(e.micro_batch).or_default().push((e.stage, e.start));
        }
        for (mb, mut stages) in per_mb {
            stages.sort_by_key(|&(s, _)| s);
            if stages.len() != pp {
                return Err(format!("mb {mb} visited {} stages, expected {pp}", stages.len()));
            }
            for w in stages.windows(2) {
                if w[1].1 + 1e-12 < w[0].1 {
                    return Err(format!("mb {mb}: stage {} starts before stage {}", w[1].0, w[0].0));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn bubble_accounting_is_consistent() {
    check("bubble bookkeeping", 40, |case| {
        let (res, _n, pp) = rand_run(case);
        if res.total_bubble < -1e-12 {
            return Err("negative total bubble".into());
        }
        if res.bubble_per_request.iter().any(|&b| b < 0.0) {
            return Err("negative per-request bubble".into());
        }
        // busy time == Σ stage executions; bounded by pp × makespan
        let busy_from_trace: f64 = res.trace.iter().map(|e| e.end - e.start).sum();
        if (busy_from_trace - res.total_busy).abs() > 1e-6 * res.total_busy.max(1.0) {
            return Err(format!(
                "busy mismatch: trace {busy_from_trace} vs {}",
                res.total_busy
            ));
        }
        if res.total_busy > pp as f64 * res.makespan + 1e-6 {
            return Err("busy exceeds stages × makespan".into());
        }
        Ok(())
    });
}

/// Randomized shared-paged-pool runs: pp streams over ONE KvManager,
/// pools sized tight enough that cross-stream preemption fires in a
/// healthy share of cases.
#[test]
fn shared_paged_pool_conserves_tokens_and_blocks() {
    let mut total_preemptions = 0usize;
    check("pipeline shared paged pool", 40, |case| {
        let pp = *case.rng.choose(&[2usize, 4]);
        let d = Deployment::new(ModelConfig::gpt3(), GpuConfig::a100(), 4096)
            .with_parallel(ParallelConfig::tp_pp(8, pp))
            .with_batch_cap(8);
        let profiler = Profiler::build(CostModel::for_deployment(&d), 4096, 9);
        let sim = PipelineSim::new(profiler, pp);

        let n = pp + case.rng.usize(0, 6 + case.size);
        let specs: Vec<RequestSpec> = (0..n)
            .map(|_| RequestSpec {
                prompt_len: case.rng.usize(64, 768),
                decode_len: case.rng.usize(8, 64),
                arrival: case.rng.f64() * 0.5,
                prefix: None,
            })
            .collect();
        let bs = *case.rng.choose(&[32usize, 64, 128]);
        let watermark = case.rng.usize(0, 2);
        // the pool must fit the single largest request plus the watermark
        // (the admission feasibility guard panics below that by design);
        // random slack keeps decode growth preempting often
        let peak = specs.iter().map(|s| s.prompt_len + s.decode_len).max().unwrap();
        let probe = KvManager::paged(1, bs);
        let num_blocks = probe.blocks_needed(peak + 1) + watermark + case.rng.usize(0, 8);
        let budget = *case.rng.choose(&[128usize, 256]);

        let res = sim.run_shared(&specs, KvManager::paged(num_blocks, bs), Some(4), || {
            Box::new(HybridScheduler::new(budget, 4, watermark)) as Box<dyn Scheduler + Send>
        });

        // every request completes exactly once, inside the makespan
        if res.completions.iter().any(|t| t.is_nan()) {
            return Err("request never completed".into());
        }
        if res.completions.iter().any(|&t| t < 0.0 || t > res.makespan + 1e-9) {
            return Err("completion outside [0, makespan]".into());
        }
        // token conservation: scheduled work matches the workload exactly
        // even under cross-stream preemption (swap semantics, no
        // recomputed tokens)
        let p_expect: usize = specs.iter().map(|s| s.prompt_len).sum();
        let d_expect: usize = specs.iter().map(|s| s.decode_len - 1).sum();
        if res.metrics.total_prefill_tokens() != p_expect {
            return Err(format!(
                "prefill tokens {} != {p_expect}",
                res.metrics.total_prefill_tokens()
            ));
        }
        if res.metrics.total_decode_tokens() != d_expect {
            return Err(format!(
                "decode tokens {} != {d_expect}",
                res.metrics.total_decode_tokens()
            ));
        }
        // no cross-stream double-free: the run's final record must show
        // every block back in the pool (a double release would have
        // panicked inside KvManager already; this checks for leaks)
        if let Some(last) = res.metrics.last_record() {
            if last.kv_blocks_in_use != 0 {
                return Err(format!("{} blocks leaked", last.kv_blocks_in_use));
            }
            if last.kv_blocks_total != num_blocks {
                return Err("pool capacity drifted".into());
            }
        }
        // latency stamping is live (the seed's drifted apply lost it)
        if res.latency.ttft.count() != n {
            return Err(format!("ttft count {} != {n}", res.latency.ttft.count()));
        }
        total_preemptions += res.metrics.preemptions;
        Ok(())
    });
    // the generator is tuned so the shared pool actually runs dry: across
    // the 40 cases a healthy number of cross-stream preemptions must fire
    assert!(
        total_preemptions > 10,
        "only {total_preemptions} preemptions across all cases — pressure generator broken?"
    );
}

#[test]
fn single_stage_is_bubble_free() {
    check("pp=1 has zero bubbles", 25, |case| {
        let d = Deployment::new(ModelConfig::gpt3(), GpuConfig::a100(), 4096)
            .with_parallel(ParallelConfig::tp_pp(8, 1))
            .with_batch_cap(8);
        let profiler = Profiler::build(CostModel::for_deployment(&d), 4096, 9);
        let sim = PipelineSim::new(profiler, 1);
        let specs = rand_specs(case);
        let res = sim.run(&specs, 8, || {
            Box::new(OrcaScheduler::best(8)) as Box<dyn Scheduler + Send>
        });
        if res.total_bubble != 0.0 {
            return Err(format!("pp=1 bubble {}", res.total_bubble));
        }
        Ok(())
    });
}
