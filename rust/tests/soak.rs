//! Acceptance tests for the steady-state soak harness: bounded memory
//! under long horizons, lossless iteration-trace streaming, and the
//! online SLO control loop (Sarathi-Serve arXiv 2403.02310 §5).
//!
//! Headline claims:
//! * a soak run's retained state (pool requests, iteration records, exact
//!   TBT samples) stays FLAT between horizon checkpoints while the
//!   completed-request count keeps rising — memory is independent of the
//!   horizon;
//! * short closed-loop runs report percentiles bitwise-identical to the
//!   historical sort-and-index path (the `Summary` rework is invisible
//!   below its exact-path capacity);
//! * across a diurnal load shift, the AIMD-controlled run holds both a
//!   TBT and a TTFT SLO that every static token budget fails on one side.
//!
//! The load-shift test is self-calibrating: it measures the two static
//! extremes first and derives the SLO thresholds from THEIR behavior, so
//! it pins the control loop's physics rather than absolute cost-model
//! constants.

use sarathi::config::{GpuConfig, ModelConfig};
use sarathi::coordinator::{
    ControllerConfig, Engine, HybridScheduler, KvManager, LatencyReport, RequestPool, SimExecutor,
};
use sarathi::costmodel::CostModel;
use sarathi::simulator::{run_soak, SoakOpts, SoakReport};
use sarathi::util::{percentile, Rng, Summary};
use sarathi::workload::{with_poisson_arrivals, zipf_population, RateCurve, SoakWorkload};

/// LLaMA-13B on A6000 — the calibrated testbed every other acceptance
/// suite uses — with a paged KV pool big enough that admission, not
/// capacity, is the binding constraint.
fn soak_engine(budget: usize) -> Engine<'static> {
    let cm = CostModel::new(ModelConfig::llama13b(), GpuConfig::a6000());
    Engine::new(
        RequestPool::new(),
        KvManager::paged(512, 32),
        Box::new(HybridScheduler::new(budget, 16, 2)),
        Box::new(SimExecutor::new(cm)),
    )
}

/// Satellite pin: the bounded-memory `Summary` rework must be invisible
/// on short runs. Every latency distribution a closed-loop run reports
/// stays on the exact path and answers percentile queries with bits
/// identical to the free sort-and-index `percentile()` the reports used
/// historically.
#[test]
fn short_closed_loop_percentiles_are_bitwise_identical_to_the_free_path() {
    let mut rng = Rng::new(11);
    let pop = zipf_population(&mut rng, 40, 0.4, 128, 1024, 4.0);
    let pop = with_poisson_arrivals(&mut rng, pop, 2.0);
    let cm = CostModel::new(ModelConfig::llama13b(), GpuConfig::a6000());
    let mut e = Engine::new(
        RequestPool::from_specs(&pop),
        KvManager::paged(512, 32),
        Box::new(HybridScheduler::new(256, 16, 2)),
        Box::new(SimExecutor::new(cm)),
    );
    e.run();
    assert!(e.pool.all_complete());
    let rep = LatencyReport::from_pool(&e.pool);
    for (name, s) in [("ttft", &rep.ttft), ("tbt", &rep.tbt), ("normalized", &rep.normalized)] {
        assert!(s.count() > 0, "{name} must have samples");
        assert!(!s.is_sketched(), "{name}: short runs stay on the exact path");
        let raw = s.samples().to_vec();
        for p in [0.0, 25.0, 50.0, 90.0, 99.0, 100.0] {
            assert_eq!(
                s.percentile(p).to_bits(),
                percentile(&raw, p).to_bits(),
                "{name} p{p} diverged from the historical sort-and-index path"
            );
        }
        assert_eq!(s.min().to_bits(), percentile(&raw, 0.0).to_bits());
        assert_eq!(s.max().to_bits(), percentile(&raw, 100.0).to_bits());
    }
}

/// The leak detector: a horizon long enough to spill the TBT distribution
/// past [`Summary::EXACT_CAP`] must show flat retained-memory counters
/// between late checkpoints while completions keep growing, and the
/// streamed JSONL trace must hold every iteration ever recorded.
#[test]
fn soak_memory_is_flat_while_completions_grow() {
    let mut e = soak_engine(256);
    // decode-heavy traffic (≈95 token gaps per request) over 160 s crosses
    // the 8192-sample exact-path cap long before the compared checkpoints;
    // drift and a flash crowd exercise the full regenerating workload
    let mut w = SoakWorkload::new(21, RateCurve::steady(1.5).with_flash(40.0, 6.0, 2.0))
        .with_lengths((32, 96), (64, 128))
        .with_drift(0.3, 60.0);
    let path = std::env::temp_dir().join("sarathi_soak_leak_test.jsonl");
    let _ = std::fs::remove_file(&path);
    let mut opts = SoakOpts::new(160.0, 16.0);
    opts.jsonl = Some(path.clone());
    let rep = run_soak(&mut e, &mut w, &opts).unwrap();

    assert_eq!(rep.checkpoints.len(), 10);
    assert!(rep.tbt.is_sketched(), "only {} gaps — horizon too short to spill", rep.tbt.count());
    let (a, b) = (&rep.checkpoints[6], &rep.checkpoints[9]);
    assert!(b.completed > a.completed, "completions must keep growing");
    assert_eq!(a.retained_tbt_samples, b.retained_tbt_samples, "TBT samples must stay flat");
    assert_eq!(a.retained_records, b.retained_records, "record retention must stay flat");
    assert_eq!(a.retained_records, 0, "the stream drains every record at each flush");
    for c in &rep.checkpoints {
        assert!(c.retained_tbt_samples <= Summary::EXACT_CAP);
        assert!(c.retained_requests < 256, "pool held {} at t={}", c.retained_requests, c.at);
    }
    assert!(e.pool.base() > 0, "retirement must have advanced the pool base");

    // the trace is lossless: every recorded iteration is on disk
    assert_eq!(rep.jsonl_dropped, 0);
    assert_eq!(rep.jsonl_records, rep.iterations);
    let text = std::fs::read_to_string(&path).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), rep.jsonl_records);
    assert!(lines.iter().all(|l| l.starts_with('{') && l.ends_with('}')));
    let _ = std::fs::remove_file(&path);
}

/// One soak run over the shared diurnal load-shift scenario.
fn run_shifted(budget: usize, ctl: Option<ControllerConfig>) -> SoakReport {
    let mut e = soak_engine(budget);
    // rate swings 0.48 → 1.92 req/s over an 80 s period: the peak makes a
    // small budget drip prompts (TTFT pain), the prompt lengths make a big
    // budget stretch iterations (TBT pain for the decodes riding along)
    let mut w = SoakWorkload::new(33, RateCurve::steady(1.2).with_diurnal(0.6, 80.0))
        .with_lengths((256, 768), (24, 72));
    let mut opts = SoakOpts::new(160.0, 8.0);
    opts.controller = ctl;
    run_soak(&mut e, &mut w, &opts).unwrap()
}

/// Steady-state TBT: the median of the non-empty windowed P99s over the
/// second half of the horizon (robust to single-window excursions and to
/// the controller's warm-up descent from the budget ceiling).
fn late_window_p99(rep: &SoakReport) -> f64 {
    let half = rep.checkpoints.len() / 2;
    let mut xs: Vec<f64> =
        rep.checkpoints[half..].iter().map(|c| c.p99_tbt).filter(|&x| x > 0.0).collect();
    assert!(!xs.is_empty(), "no late windows carried TBT gaps");
    xs.sort_by(f64::total_cmp);
    xs[xs.len() / 2]
}

/// THE acceptance test (ISSUE tentpole): across a diurnal load shift,
/// derive a TBT SLO and a TTFT SLO from the measured behavior of the two
/// static budget extremes such that each extreme fails exactly one of
/// them — then show the AIMD-controlled run holds BOTH.
#[test]
fn controller_holds_both_slos_where_every_static_budget_fails_one() {
    const LO: usize = 48;
    const HI: usize = 768;
    let lo = run_shifted(LO, None);
    let hi = run_shifted(HI, None);
    let (tbt_lo, tbt_hi) = (late_window_p99(&lo), late_window_p99(&hi));
    let (ttft_lo, ttft_hi) = (lo.ttft.percentile(99.0), hi.ttft.percentile(99.0));

    // the trade-off the controller navigates must actually exist: the big
    // budget buys TTFT with TBT, the small budget the reverse
    assert!(tbt_hi > tbt_lo * 1.2, "no TBT spread: lo={tbt_lo:.4} hi={tbt_hi:.4}");
    assert!(ttft_lo > ttft_hi * 1.2, "no TTFT spread: lo={ttft_lo:.4} hi={ttft_hi:.4}");

    // place each SLO between the extremes, weighted toward the extreme
    // that fails it — failure of the statics is then true by construction,
    // and the margins test the CONTROLLER, not the threshold placement
    let tbt_slo = tbt_lo.powf(0.25) * tbt_hi.powf(0.75);
    let ttft_slo = ttft_hi.powf(0.25) * ttft_lo.powf(0.75);
    assert!(tbt_hi > tbt_slo && ttft_hi <= ttft_slo, "static HI must fail exactly the TBT SLO");
    assert!(ttft_lo > ttft_slo && tbt_lo <= tbt_slo, "static LO must fail exactly the TTFT SLO");

    // the controller targets the geometric midpoint of the measured TBT
    // range — comfortably inside the SLO it must hold
    let target = (tbt_lo * tbt_hi).sqrt();
    let ctl = run_shifted(HI, Some(ControllerConfig::new(target, LO, HI)));
    assert!(ctl.controller_ticks > 0 && ctl.controller_adjustments > 0, "the loop never acted");
    assert!(ctl.final_token_budget < HI, "the budget never backed off the ceiling");

    let tbt_ctl = late_window_p99(&ctl);
    let ttft_ctl = ctl.ttft.percentile(99.0);
    assert!(
        tbt_ctl <= tbt_slo,
        "TBT SLO missed: {tbt_ctl:.4} > {tbt_slo:.4} (lo={tbt_lo:.4} hi={tbt_hi:.4})"
    );
    assert!(
        ttft_ctl <= ttft_slo,
        "TTFT SLO missed: {ttft_ctl:.4} > {ttft_slo:.4} (lo={ttft_lo:.4} hi={ttft_hi:.4})"
    );
}
