//! PR-9 acceptance: radix-tree prefix store with subtree-granular sharing
//! + router residency digests, measured on conversation-tree traffic
//! (shared system prompt, divergent branches, multi-turn follow-ups —
//! every turn's prefix is unique as a whole, so the flat whole-template
//! index can never share it; only block-granular content paths can).
//!
//! Gates (ISSUE-9):
//!   1. The radix store shares ≥1.3× more KV tokens than the flat index
//!      at equal-or-lower peak block occupancy.
//!   2. Digest-based `PrefixAffinity` beats its dispatch-history mode on
//!      the token-weighted prefix-hit rate at ≤1.25 load imbalance, with
//!      binary hits and pooled P99 TTFT no worse.
//!
//! Margins pre-validated with the Python mirror (/tmp/radix_mirror.py,
//! same conversation-tree generator and admission semantics, 8 seeds):
//! sharing ratio ~7× vs the 1.3× floor (the flat index shares ~0 tokens
//! here — every turn's hash is new), digest/history token-weighted ratio
//! 1.17–1.23× vs the 1.1× floor, digest imbalance ≤1.23 vs the 1.25
//! ceiling, binary hit rate never below history's.

use sarathi::config::{Deployment, GpuConfig, ModelConfig, ParallelConfig};
use sarathi::coordinator::sched::HybridScheduler;
use sarathi::coordinator::{Engine, KvManager, RequestPool, Scheduler, SimExecutor};
use sarathi::costmodel::CostModel;
use sarathi::simulator::{ClusterSim, PrefixAffinity, RoutePolicy};
use sarathi::util::{percentile, Rng};
use sarathi::workload::{
    conversation_tree_population, with_poisson_arrivals, PrefixSpec, RequestSpec,
};

const BS: usize = 32;

/// The mirror's scenario: 24 conversations over 4 branches of a 256-token
/// system prompt (branch arms of 128), 4 turns each, 64–256 unique prompt
/// tokens and 32–128 decoded tokens per turn, arriving turn-major on a
/// Poisson(24/s) timeline.
fn conversation_pop(seed: u64) -> Vec<RequestSpec> {
    let mut rng = Rng::new(seed);
    let pop = conversation_tree_population(&mut rng, 24, 4, 256, 128, 4, 64, 256, 32, 128, BS);
    with_poisson_arrivals(&mut rng, pop, 24.0)
}

fn hybrid_sched() -> Box<dyn Scheduler + Send + 'static> {
    Box::new(HybridScheduler::new(256, 8, 2).with_prefix_share(true))
}

/// One engine run; returns (shared KV tokens, peak blocks in use, hits).
fn run_engine(specs: &[RequestSpec], num_blocks: usize) -> (usize, usize, usize) {
    let cm = CostModel::new(ModelConfig::llama13b(), GpuConfig::a6000());
    let mut e = Engine::new(
        RequestPool::from_specs(specs),
        KvManager::paged(num_blocks, BS),
        Box::new(HybridScheduler::new(256, 8, 2).with_prefix_share(true)),
        Box::new(SimExecutor::new(cm)),
    );
    e.run();
    e.kv.assert_radix_invariants();
    for r in e.pool.iter() {
        assert!(r.completed_at.is_some(), "request {} never completed", r.id);
    }
    let shared: usize = e.pool.iter().map(|r| r.prefix_skipped_tokens).sum();
    (shared, e.metrics.peak_kv_blocks_in_use(), e.metrics.prefix_hits)
}

/// Gate 1. The flat baseline is the SAME population with every tag
/// stripped to its `{id, len}` form — each turn's id is a fresh content
/// hash, so the flat index registers everything and shares nothing; the
/// radix tree attaches each turn under its parent's resident path and
/// shares the whole conversation history block-for-block.
#[test]
fn radix_store_outshares_flat_index_at_lower_occupancy() {
    let mut radix_shared = 0usize;
    let mut flat_shared = 0usize;
    for seed in 1..=4u64 {
        let pop = conversation_pop(seed);
        let flat_pop: Vec<RequestSpec> = pop
            .iter()
            .map(|s| {
                let p = s.prefix.as_ref().expect("conversation turns are always tagged");
                let mut s2 = s.clone();
                s2.prefix = Some(PrefixSpec::whole(p.id, p.len));
                s2
            })
            .collect();
        let num_blocks = 2048; // identical pools; only the index differs
        let (r_sh, r_peak, r_hits) = run_engine(&pop, num_blocks);
        let (f_sh, f_peak, f_hits) = run_engine(&flat_pop, num_blocks);
        println!(
            "seed {seed}: radix shared={r_sh} peak={r_peak} hits={r_hits} | \
             flat shared={f_sh} peak={f_peak} hits={f_hits}"
        );
        assert!(
            r_peak <= f_peak,
            "seed {seed}: radix peak occupancy {r_peak} blocks exceeds flat {f_peak}"
        );
        assert!(r_hits >= f_hits, "seed {seed}: radix hits {r_hits} below flat {f_hits}");
        radix_shared += r_sh;
        flat_shared += f_sh;
    }
    assert!(
        radix_shared as f64 >= 1.3 * flat_shared.max(1) as f64,
        "radix must share ≥1.3× the flat index: {radix_shared} vs {flat_shared}"
    );
    // ... and the win must be real, not 1 token vs 0: at minimum the
    // non-registrant first turns re-use the system+branch head
    assert!(
        radix_shared > 10_000,
        "only {radix_shared} shared tokens across 4 seeds — sharing machinery inert?"
    );
}

/// One routing policy aggregated over the seeds.
#[derive(Default)]
struct RouteAgg {
    hits: usize,
    partial_hit_tokens: usize,
    imbalances: Vec<f64>,
    ttfts: Vec<f64>,
}

fn run_routing(cluster: &ClusterSim, seeds: &[u64], digest: bool) -> RouteAgg {
    let mut agg = RouteAgg::default();
    for &seed in seeds {
        let mut router: Box<dyn RoutePolicy> = if digest {
            Box::new(PrefixAffinity::new(1.25))
        } else {
            Box::new(PrefixAffinity::history(1.25))
        };
        let pop = conversation_pop(seed);
        // 512 blocks × 32 tokens per replica: roughly six full
        // conversation chains — residency pressure is what the digest
        // exploits and the history heuristic cannot see
        let res =
            cluster.run_routed(&pop, &mut *router, || KvManager::paged(512, BS), None, hybrid_sched);
        assert!(
            res.completions.iter().all(|t| !t.is_nan()),
            "{} seed {seed}: every request must complete",
            res.router
        );
        agg.hits += res.prefix_hits();
        for rep in &res.per_replica {
            agg.partial_hit_tokens += rep.metrics.prefix_partial_hit_tokens;
            agg.ttfts.extend_from_slice(rep.latency.ttft.samples());
        }
        agg.imbalances.push(res.load_imbalance());
    }
    agg
}

/// Gate 2. History mode rendezvous-hashes each turn's own (unique) id —
/// effectively random placement, so a conversation's turns scatter and
/// every replica re-prefills the chain. Digest mode reads the replicas'
/// residency digests and sends each turn to the replica actually holding
/// its parent's KV.
#[test]
fn digest_routing_beats_history_on_token_weighted_hits() {
    let seeds: Vec<u64> = (1..=6).collect();
    let d = Deployment::new(ModelConfig::llama13b(), GpuConfig::a6000(), 2048)
        .with_parallel(ParallelConfig::tp_pp(1, 1).with_replicas(4));
    let cluster = ClusterSim::new(d);
    let dig = run_routing(&cluster, &seeds, true);
    let his = run_routing(&cluster, &seeds, false);
    println!(
        "digest: hits={} tok={} imb={:?} | history: hits={} tok={} imb={:?}",
        dig.hits, dig.partial_hit_tokens, dig.imbalances, his.hits, his.partial_hit_tokens,
        his.imbalances
    );
    assert!(his.partial_hit_tokens > 0, "history must still hit the warm branch heads");
    assert!(
        dig.partial_hit_tokens as f64 >= 1.1 * his.partial_hit_tokens as f64,
        "digest must serve ≥1.1× the cached tokens: {} vs {}",
        dig.partial_hit_tokens,
        his.partial_hit_tokens
    );
    assert!(
        dig.hits >= his.hits,
        "digest binary hits regressed: {} vs {}",
        dig.hits,
        his.hits
    );
    let imb_mean: f64 = dig.imbalances.iter().sum::<f64>() / dig.imbalances.len() as f64;
    assert!(
        imb_mean <= 1.25,
        "digest load imbalance {imb_mean:.3} > 1.25 (per-seed: {:?})",
        dig.imbalances
    );
    let p99_dig = percentile(&dig.ttfts, 99.0);
    let p99_his = percentile(&his.ttfts, 99.0);
    assert!(
        p99_dig <= p99_his * 1.05,
        "digest pooled P99 TTFT must be no worse: {p99_dig:.3}s vs history {p99_his:.3}s"
    );
}
