//! Acceptance: Sarathi-Serve-style hybrid token-budget micro-batches over
//! ONE shared paged `KvManager` per replica (arXiv 2403.02310 tested at
//! the pipeline level), with preemption priced the way DistServe prices
//! KV movement (arXiv 2401.09670).
//!
//! The claims under test, all over the SAME shared paged pool (the honest
//! per-replica KV budget — B×L_max tokens — not the seed's
//! pp×-overcommitted per-stream slots):
//!
//! 1. hybrid token-budget micro-batches cut the median per-request bubble
//!    time well below Orca's;
//! 2. while keeping P99 time-between-tokens no worse than request-level
//!    SARATHI (the budget bounds every fused iteration, so decode stalls
//!    shrink — Sarathi-Serve's low-TBT claim);
//! 3. and on an undersized pool, preemption fires with swap time > 0
//!    visible in `Metrics` and the JSONL trace, token conservation and
//!    block accounting intact.
//!
//! Margins pre-validated against a Python mirror of the cost model +
//! pipeline simulator: hybrid/orca median bubble ≈ 0.20 (asserted < 0.5),
//! hybrid/sarathi P99 TBT ≈ 0.65 (asserted ≤ 1.0), undersized run ≈ 40
//! preemptions / 0.56 s swap (asserted > 0).

use sarathi::config::{Deployment, GpuConfig, ModelConfig, ParallelConfig, PreemptionMode};
use sarathi::coordinator::sched::{HybridScheduler, OrcaScheduler, SarathiScheduler};
use sarathi::coordinator::{KvManager, Scheduler, SwapCost};
use sarathi::costmodel::CostModel;
use sarathi::profiler::Profiler;
use sarathi::simulator::{PipelineResult, PipelineSim};
use sarathi::util::Rng;
use sarathi::workload::{zipf_population, RequestSpec};

fn deployment(pp: usize) -> Deployment {
    Deployment::new(ModelConfig::gpt3(), GpuConfig::a100(), 4096)
        .with_parallel(ParallelConfig::tp_pp(8, pp))
}

fn sim(pp: usize) -> PipelineSim {
    let d = deployment(pp);
    let profiler = Profiler::build(CostModel::for_deployment(&d), 4096, 32);
    PipelineSim::new(profiler, pp)
        .with_swap_cost(SwapCost::for_deployment(&d, PreemptionMode::Swap))
}

fn workload(n: usize, pd: f64) -> Vec<RequestSpec> {
    let mut rng = Rng::new(42);
    zipf_population(&mut rng, n, 0.4, 1024, 4096, pd)
}

/// The honest shared per-replica pool: B=27 worst-case slots' worth of
/// tokens as 128-token paged blocks (27 × 4096 / 128 = 864 blocks).
const BLOCK: usize = 128;
const SHARED_BLOCKS: usize = 27 * 4096 / BLOCK;

fn run_shared(
    sim: &PipelineSim,
    specs: &[RequestSpec],
    mk: impl Fn() -> Box<dyn Scheduler + Send>,
) -> PipelineResult {
    sim.run_shared(specs, KvManager::paged(SHARED_BLOCKS, BLOCK), Some(27), || mk())
}

#[test]
fn hybrid_cuts_bubbles_vs_orca_with_tbt_no_worse_than_sarathi() {
    let specs = workload(400, 10.0);
    let sim = sim(8);
    let orca = run_shared(&sim, &specs, || Box::new(OrcaScheduler::best(27)));
    let sarathi = run_shared(&sim, &specs, || Box::new(SarathiScheduler::new(256, 27, 128)));
    let hybrid = run_shared(&sim, &specs, || Box::new(HybridScheduler::new(128, 27, 4)));

    for (name, r) in [("orca", &orca), ("sarathi", &sarathi), ("hybrid", &hybrid)] {
        assert!(
            r.completions.iter().all(|t| !t.is_nan()),
            "{name}: request dropped on the shared pool"
        );
    }

    // (1) token-budget micro-batches cut the median per-request bubble
    // well below Orca's full-prompt ones (mirror: 0.20×)
    let med = |r: &PipelineResult| r.bubble_summary().percentile(50.0);
    assert!(
        med(&hybrid) < 0.5 * med(&orca),
        "median bubble: hybrid={} !< 0.5 x orca={}",
        med(&hybrid),
        med(&orca)
    );

    // (2) P99 TBT no worse than request-level SARATHI (mirror: 0.65×) —
    // TBT exists at all for pipeline runs because stamping now goes
    // through the engine-shared StepApplier
    assert!(hybrid.latency.tbt.count() > 0 && sarathi.latency.tbt.count() > 0);
    let p99 = |r: &PipelineResult| r.latency.tbt.percentile(99.0);
    assert!(
        p99(&hybrid) <= p99(&sarathi),
        "p99 TBT: hybrid={} !<= sarathi={}",
        p99(&hybrid),
        p99(&sarathi)
    );

    // the tighter budget also finishes sooner than Orca end-to-end
    assert!(hybrid.makespan < orca.makespan);
}

#[test]
fn undersized_shared_pool_preempts_with_visible_swap_time() {
    // decode-heavy load (P:D = 3) over a pool an order of magnitude below
    // peak demand: growth must preempt across streams, each eviction
    // paying KV-bytes-over-PCIe
    let specs = workload(64, 3.0);
    let sim = sim(4);
    let res = sim.run_shared(&specs, KvManager::paged(60, BLOCK), Some(8), || {
        Box::new(HybridScheduler::new(128, 8, 0)) as Box<dyn Scheduler + Send>
    });

    assert!(res.completions.iter().all(|t| !t.is_nan()), "everyone still completes");
    assert!(res.metrics.preemptions > 0, "undersized pool must preempt");
    assert!(res.metrics.total_swap_time() > 0.0, "preemption swap time must be charged");

    // token conservation under costed cross-stream preemption (swap
    // semantics: progress is never recomputed)
    let p_expect: usize = specs.iter().map(|s| s.prompt_len).sum();
    let d_expect: usize = specs.iter().map(|s| s.decode_len - 1).sum();
    assert_eq!(res.metrics.total_prefill_tokens(), p_expect);
    assert_eq!(res.metrics.total_decode_tokens(), d_expect);

    // block accounting: the final record shows every block returned
    let last = res.metrics.last_record().unwrap();
    assert_eq!(last.kv_blocks_in_use, 0, "blocks leaked");
    assert_eq!(last.kv_blocks_total, 60);

    // swap time appears in the JSONL trace
    let path = std::env::temp_dir().join("sarathi_pipeline_hybrid_trace.jsonl");
    res.metrics.write_jsonl(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert_eq!(text.lines().count(), res.metrics.recorded_count());
    let swapped: Vec<&str> =
        text.lines().filter(|l| !l.contains("\"swap_time\":0.000000")).collect();
    assert!(
        !swapped.is_empty(),
        "at least one iteration must carry positive swap time in the trace"
    );
    std::fs::remove_file(&path).ok();
}
