//! PR-7 acceptance: prefill/decode disaggregation as a first-class
//! deployment mode. Three claims:
//!
//! 1. **Conservation + overlap** — every KV export the prefill side
//!    begins lands on a decode replica exactly once, the per-request
//!    `kv_transfer_time` books match the fabric's records, and transfers
//!    ride an overlapped copy stream (fabric busy while compute
//!    advances), never the compute clock.
//! 2. **Determinism** — the round-based handoff driver is BITWISE
//!    identical across `--threads` counts (completions, TTFT, max TBT,
//!    transfer times, merged JSONL trace), same stance as the routed
//!    cluster suite: `to_bits`, not tolerances.
//! 3. **Goodput crossover** — under a TBT-tight SLO disaggregation wins
//!    (decode replicas never interleave prefill chunks, so the worst
//!    token gap shrinks); under a TTFT-tight SLO colocation wins (every
//!    replica owns prefill capacity and no prompt pays a wire hop). The
//!    SLO knees are self-calibrated from the two runs' own medians, so
//!    the test pins the ORDERING the paper's disaggregation argument
//!    predicts, not cost-model constants.

use sarathi::config::{Deployment, GpuConfig, ModelConfig, ParallelConfig};
use sarathi::coordinator::sched::SarathiScheduler;
use sarathi::coordinator::{KvManager, Scheduler};
use sarathi::simulator::{ClusterResult, ClusterSim, RoundRobin, Topology};
use sarathi::util::Rng;
use sarathi::workload::{with_poisson_arrivals, zipf_population, RequestSpec};

const REPLICAS: usize = 4;
const PREFILL_REPLICAS: usize = 1;
const CAP: usize = 12;
/// The a6000 saturation chunk (§4.2): colocated hybrid iterations carry a
/// 512-token chunk (~2× a batched decode-only iteration), which is
/// exactly the prefill interference disaggregation removes — the TBT side
/// of the crossover lives on this gap.
const CHUNK: usize = 512;
/// Arrival rate putting the single prefill replica near saturation
/// (~0.9 utilization) while four colocated replicas sit near ~0.45 — the
/// TTFT side of the crossover lives on this asymmetry.
const RATE: f64 = 2.3;

/// 4 whole-model LLaMA-13B replicas over a 200 Gbps fabric (NVLink-class;
/// the disaggregation regime the paper's §6 discussion targets — the
/// wire hop must not dominate a decode iteration).
fn cluster() -> ClusterSim {
    let mut gpu = GpuConfig::a6000();
    gpu.interconnect_gbps = 200.0;
    ClusterSim::new(
        Deployment::new(ModelConfig::llama13b(), gpu, 2048)
            .with_parallel(ParallelConfig::tp_pp(1, 1).with_replicas(REPLICAS)),
    )
}

/// Long prompts, real decode phases: totals Zipf in [1024, 2048] split
/// P:D = 16 (decode runs of ~60-120 tokens — enough for per-request TBT
/// to mean something), open-loop Poisson arrivals.
fn workload(seed: u64, n: usize) -> Vec<RequestSpec> {
    let mut rng = Rng::new(seed);
    let pop = zipf_population(&mut rng, n, 0.4, 1024, 2048, 16.0);
    with_poisson_arrivals(&mut rng, pop, RATE)
}

fn run(topology: Topology, pop: &[RequestSpec], threads: usize) -> ClusterResult {
    let mut router = RoundRobin::default();
    cluster().run_topology(
        topology,
        pop,
        &mut router,
        || KvManager::new(CAP),
        Some(CAP),
        || Box::new(SarathiScheduler::new(CHUNK, CAP, 128)) as Box<dyn Scheduler + Send>,
        threads,
    )
}

fn disagg() -> Topology {
    Topology::Disagg { prefill_replicas: PREFILL_REPLICAS }
}

fn median(xs: &[f64]) -> f64 {
    let mut v: Vec<f64> = xs.iter().copied().filter(|x| !x.is_nan()).collect();
    assert!(!v.is_empty(), "median of an empty/NaN-only sample");
    v.sort_by(f64::total_cmp);
    v[v.len() / 2]
}

#[test]
fn disagg_conserves_kv_and_overlaps_transfers_with_compute() {
    let pop = workload(11, 96);
    let res = run(disagg(), &pop, 1);
    assert!(
        res.completions.iter().all(|t| !t.is_nan()),
        "every request must complete under disaggregation"
    );
    assert_eq!(res.topology, "disagg");
    let fabric = res.fabric.as_ref().expect("disagg result carries its fabric");

    // conservation: one export per decode-bearing prompt, each delivered
    // exactly once — the driver's own assert plus the public books
    let expect = pop.iter().filter(|s| s.decode_len > 1).count();
    assert_eq!(fabric.records.len(), expect, "one transfer per handed-off prompt");
    assert!(fabric.is_conserved(), "exports must balance deliveries");
    assert!(fabric.busy_time() > 0.0, "the fabric moved real bytes");
    assert!(res.transfer_busy >= fabric.busy_time());

    for rec in &fabric.records {
        assert!(
            rec.src < PREFILL_REPLICAS && rec.dst >= PREFILL_REPLICAS,
            "KV flows prefill -> decode only (got {} -> {})",
            rec.src,
            rec.dst
        );
        assert!(rec.finish > rec.start && rec.start >= rec.ready_at, "causal transfer timing");
        // the per-request metric is exactly the fabric's queue + wire time
        assert_eq!(
            res.kv_transfer_time[rec.request].to_bits(),
            rec.kv_transfer_time().to_bits(),
            "request {} kv_transfer_time diverged from its record",
            rec.request
        );
        assert!(res.kv_transfer_time[rec.request] > 0.0);
        // the decode side cannot finish before its KV landed, and the
        // stitched TBT gap must cover the handoff
        assert!(res.completions[rec.request] > rec.finish);
        assert!(res.max_tbt[rec.request] >= res.kv_transfer_time[rec.request] - 1e-12);
    }

    // overlap: some transfer is on the wire while some replica is mid
    // iteration — the copy stream does not stop the compute clock
    let overlapped = fabric.records.iter().any(|rec| {
        res.per_replica.iter().any(|rep| {
            rep.metrics.iter_records().any(|it| {
                it.started_at < rec.finish && rec.start < it.started_at + it.elapsed
            })
        })
    });
    assert!(overlapped, "KV transfers must overlap compute, not serialize it");
}

#[test]
fn disagg_is_bitwise_identical_across_thread_counts() {
    for seed in [5u64, 23] {
        let pop = workload(seed, 64);
        let serial = run(disagg(), &pop, 1);
        let serial_trace = jsonl_of(&serial, &format!("s{seed}_t1"));
        // 0 = auto (one worker per core): machine-dependent count, same bits
        for threads in [2usize, 4, 0] {
            let threaded = run(disagg(), &pop, threads);
            for (name, a, b) in [
                ("completions", &serial.completions, &threaded.completions),
                ("ttft", &serial.ttft, &threaded.ttft),
                ("max_tbt", &serial.max_tbt, &threaded.max_tbt),
                ("kv_transfer_time", &serial.kv_transfer_time, &threaded.kv_transfer_time),
            ] {
                assert_eq!(a.len(), b.len());
                for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
                    assert!(
                        x.to_bits() == y.to_bits(),
                        "seed {seed} threads {threads} request {i}: {name} {x} != {y}"
                    );
                }
            }
            let threaded_trace = jsonl_of(&threaded, &format!("s{seed}_t{threads}"));
            assert_eq!(
                serial_trace, threaded_trace,
                "seed {seed} threads {threads}: merged JSONL trace diverged"
            );
        }
    }
}

fn jsonl_of(res: &ClusterResult, tag: &str) -> String {
    let name = format!("sarathi_disagg_{tag}_{}.jsonl", std::process::id());
    let path = std::env::temp_dir().join(name);
    res.write_jsonl(&path).expect("write jsonl trace");
    let text = std::fs::read_to_string(&path).expect("read jsonl trace back");
    let _ = std::fs::remove_file(&path);
    text
}

#[test]
fn goodput_crossover_tracks_slo_tightness() {
    let pop = workload(11, 120);
    let colo = run(Topology::Colocated, &pop, 1);
    let dis = run(disagg(), &pop, 1);
    for (name, res) in [("colocated", &colo), ("disagg", &dis)] {
        assert!(
            res.completions.iter().all(|t| !t.is_nan()),
            "{name}: every request must complete"
        );
    }

    // the two regimes' signatures, measured not assumed: decode-only
    // replicas shrink the worst token gap (no saturation-sized chunk ever
    // lands between a request's tokens); concentrating prefill on one
    // near-saturated replica and adding a wire hop costs first-token
    // latency
    let (colo_tbt, dis_tbt) = (median(&colo.max_tbt), median(&dis.max_tbt));
    assert!(
        dis_tbt < colo_tbt,
        "disagg must cut the median worst token gap ({dis_tbt:.4}s vs {colo_tbt:.4}s)"
    );
    let (colo_ttft, dis_ttft) = (median(&colo.ttft), median(&dis.ttft));
    assert!(
        colo_ttft < dis_ttft,
        "colocated must keep the median TTFT lead ({colo_ttft:.4}s vs {dis_ttft:.4}s)"
    );

    // TBT-tight knee (TTFT unconstrained): the midpoint of the medians —
    // most disagg requests sit under it, most colocated above
    let tbt_knee = 0.5 * (colo_tbt + dis_tbt);
    let (colo_frac, _) = colo.goodput(f64::INFINITY, tbt_knee);
    let (dis_frac, _) = dis.goodput(f64::INFINITY, tbt_knee);
    assert!(
        dis_frac > colo_frac,
        "TBT-tight SLO ({tbt_knee:.4}s): disagg goodput {dis_frac:.3} must beat \
         colocated {colo_frac:.3}"
    );

    // TTFT-tight knee (TBT unconstrained): the ordering flips
    let ttft_knee = 0.5 * (colo_ttft + dis_ttft);
    let (colo_frac, _) = colo.goodput(ttft_knee, f64::INFINITY);
    let (dis_frac, _) = dis.goodput(ttft_knee, f64::INFINITY);
    assert!(
        colo_frac > dis_frac,
        "TTFT-tight SLO ({ttft_knee:.4}s): colocated goodput {colo_frac:.3} must beat \
         disagg {dis_frac:.3}"
    );
}
