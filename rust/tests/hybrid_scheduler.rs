//! Acceptance tests for the stall-free token-budget policy over paged KV
//! (the Sarathi-Serve production form of SARATHI's batching, evaluated the
//! DistServe way: TTFT/TBT as first-class metrics).
//!
//! Headline claims, asserted on the calibrated cost-model sim:
//! * under Poisson arrivals the hybrid policy reaches a LOWER P99
//!   time-between-tokens than the seed SarathiScheduler at equal-or-better
//!   throughput;
//! * on a Zipf-length population the paged KvManager admits strictly more
//!   concurrent requests than the §4.3.1 worst-case slot formula;
//! * preemption events are visible in `Metrics`.

use sarathi::config::{Deployment, GpuConfig, ModelConfig};
use sarathi::coordinator::sched::{HybridScheduler, SarathiScheduler};
use sarathi::coordinator::{Engine, KvManager, LatencyReport, RequestPool, Scheduler, SimExecutor};
use sarathi::costmodel::CostModel;
use sarathi::util::Rng;
use sarathi::workload::{with_poisson_arrivals, zipf_population, RequestSpec};

/// The shared testbed: LLaMA-13B on A6000 at L=2048, Zipf(0.4) lengths in
/// [256, 2048] at P:D = 5, Poisson arrivals. Decode-heavy enough that the
/// §4.3.1 slot cap visibly starves the seed scheduler's decode phase.
fn testbed() -> (Deployment, Vec<RequestSpec>) {
    let d = Deployment::new(ModelConfig::llama13b(), GpuConfig::a6000(), 2048);
    let mut rng = Rng::new(9);
    let pop = zipf_population(&mut rng, 150, 0.4, 256, 2048, 5.0);
    let pop = with_poisson_arrivals(&mut rng, pop, 1.2);
    (d, pop)
}

fn run(d: &Deployment, pop: &[RequestSpec], kv: KvManager, sched: Box<dyn Scheduler>) -> Engine<'static> {
    let mut e = Engine::new(
        RequestPool::from_specs(pop),
        kv,
        sched,
        Box::new(SimExecutor::new(CostModel::for_deployment(d))),
    );
    e.run();
    assert!(e.pool.all_complete());
    e
}

#[test]
fn hybrid_beats_sarathi_p99_tbt_at_equal_or_better_throughput() {
    let (d, pop) = testbed();
    let b = d.max_batch_size(); // the seed's worst-case slot count

    // seed configuration: slot KV (degenerate blocks), C=256, B slots
    let sar = run(
        &d,
        &pop,
        KvManager::new(b),
        Box::new(SarathiScheduler::new(256, b, 128)),
    );
    // hybrid: same GPU memory as a paged block pool, token budget 128,
    // up to 2B concurrent sequences, 2-block admission watermark
    let hyb = run(
        &d,
        &pop,
        KvManager::paged(d.kv_blocks(32), 32),
        Box::new(HybridScheduler::new(128, 2 * b, 2)),
    );

    let sar_tbt = LatencyReport::from_pool(&sar.pool).tbt;
    let hyb_tbt = LatencyReport::from_pool(&hyb.pool).tbt;
    let (sp99, hp99) = (sar_tbt.percentile(99.0), hyb_tbt.percentile(99.0));
    assert!(
        hp99 < sp99 * 0.97,
        "p99 TBT: hybrid {:.1}ms !< sarathi {:.1}ms",
        hp99 * 1e3,
        sp99 * 1e3
    );

    let (st, ht) = (sar.metrics.throughput(), hyb.metrics.throughput());
    assert!(
        ht >= st * 1.05,
        "throughput: hybrid {ht:.0} tok/s !>= sarathi {st:.0} tok/s"
    );
}

#[test]
fn paged_kv_admits_more_than_worst_case_slot_formula() {
    let (d, pop) = testbed();
    let b = d.max_batch_size();
    let hyb = run(
        &d,
        &pop,
        KvManager::paged(d.kv_blocks(32), 32),
        Box::new(HybridScheduler::new(128, 2 * b, 2)),
    );
    // the Zipf population's actual lengths run well under the 2048-token
    // worst case, so block-granular accounting fits strictly more
    // concurrent requests into the SAME memory than the slot formula
    assert!(
        hyb.metrics.peak_active() > b,
        "peak concurrency {} !> worst-case B={b}",
        hyb.metrics.peak_active()
    );
    // and the per-iteration records expose the occupancy that proves it
    assert!(hyb.metrics.iter_records().any(|r| r.n_active > b));
}

#[test]
fn preemption_events_are_visible_in_metrics() {
    let (d, pop) = testbed();
    let b = d.max_batch_size();
    let hyb = run(
        &d,
        &pop,
        KvManager::paged(d.kv_blocks(32), 32),
        Box::new(HybridScheduler::new(128, 2 * b, 2)),
    );
    // admission runs close to the memory edge, so decode growth must
    // occasionally preempt — and the metrics must show it, both in total
    // and on the per-iteration records
    assert!(hyb.metrics.preemptions > 0, "no preemptions recorded");
    let per_iter: usize = hyb.metrics.iter_records().map(|r| r.preemptions).sum();
    assert_eq!(per_iter, hyb.metrics.preemptions);
    let per_req: usize = hyb.pool.iter().map(|r| r.preemptions).sum();
    assert_eq!(per_req, hyb.metrics.preemptions);
}

#[test]
fn hybrid_matches_sarathi_on_its_home_turf() {
    // sanity guard against regressions in the seed policy's sweet spot: a
    // steady uniform P:D=50 workload where decode-maximal batching shines.
    // The hybrid policy (budget 256 = the chunk) must stay within 10% of
    // SarathiScheduler's throughput under identical degenerate slots.
    let d = Deployment::new(ModelConfig::llama13b(), GpuConfig::a6000(), 1024);
    let pop: Vec<RequestSpec> = (0..24)
        .map(|_| RequestSpec { prompt_len: 1004, decode_len: 20, arrival: 0.0, prefix: None })
        .collect();
    let b = 6usize;
    let sar = run(&d, &pop, KvManager::new(b), Box::new(SarathiScheduler::new(256, b, 128)));
    let hyb = run(&d, &pop, KvManager::new(b), Box::new(HybridScheduler::new(256, b, 0)));
    let ratio = hyb.metrics.throughput() / sar.metrics.throughput();
    assert!(ratio > 0.9, "hybrid/sarathi throughput ratio {ratio}");
}
