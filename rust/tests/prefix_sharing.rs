//! Acceptance: copy-on-write prefix sharing over the paged KV block map
//! (vLLM-style PagedAttention sharing, arXiv 2309.06180, on SARATHI's
//! stall-free hybrid stack).
//!
//! The claims under test, on the SAME paged pool and the SAME template
//! workload (4 shared 520-token prefixes, Zipf-0.8 fanout — the 520-token
//! prefix is deliberately NOT block-aligned so every hit exercises the
//! copy-on-write fork of the partial last block):
//!
//! 1. prefix sharing sustains ≥ 1.3× the peak concurrent requests of the
//!    no-sharing baseline (mirror: 12 vs 7 = 1.71×);
//! 2. at strictly LOWER peak KV occupancy (mirror: 94 vs 127 blocks =
//!    0.74×) — shared blocks are counted once, and sharers only pay for
//!    their private tails;
//! 3. with identical completion counts and token conservation: scheduled
//!    prefill plus cache-served (skipped) tokens equal the workload's
//!    prompts exactly, decode tokens match to the token;
//! 4. prefix hits and shared-KV occupancy are visible in `Metrics` and
//!    the JSONL trace (what the CI smoke step greps for).
//!
//! Timing is honest: a registered run is NOT servable until the
//! registrant's prefill has computed the covered tokens (readiness
//! gating), so the win below includes the warm-up in which co-arriving
//! same-template requests wait for the in-flight fill.
//!
//! Margins pre-validated with the PR-2 Python mirror of the Rng + cost
//! model + engine, extended with the pin/fork/readiness bookkeeping
//! (/tmp/prefix_mirror.py): sharing also finishes the closed-loop run
//! 4.1× sooner (8.82 s vs 36.56 s simulated) since resident prefixes
//! skip their prefill compute.

use sarathi::config::{GpuConfig, ModelConfig};
use sarathi::coordinator::sched::HybridScheduler;
use sarathi::coordinator::{Engine, KvManager, RequestPool, SimExecutor};
use sarathi::costmodel::CostModel;
use sarathi::util::Rng;
use sarathi::workload::{shared_prefix_population, RequestSpec};

const BLOCKS: usize = 128;
const BS: usize = 32;
const MAX_BATCH: usize = 12;

/// The shared-template workload: 160 requests over 4 templates with a
/// 520-token shared prefix each (16¼ blocks — partial last block → COW
/// fork on every hit), unique parts of 16–64 tokens at P:D = 3, Zipf(0.8)
/// template fanout, all present at t = 0 (closed loop).
fn workload() -> Vec<RequestSpec> {
    let mut rng = Rng::new(17);
    shared_prefix_population(&mut rng, 160, 4, 0.8, 520, 16, 64, 3.0)
}

fn run(specs: &[RequestSpec], share: bool) -> Engine<'static> {
    let cm = CostModel::new(ModelConfig::llama13b(), GpuConfig::a6000());
    let mut e = Engine::new(
        RequestPool::from_specs(specs),
        KvManager::paged(BLOCKS, BS),
        Box::new(HybridScheduler::new(128, MAX_BATCH, 2).with_prefix_share(share)),
        Box::new(SimExecutor::new(cm)),
    );
    e.run();
    e
}

#[test]
fn sharing_lifts_peak_concurrency_and_cuts_peak_occupancy_on_the_same_pool() {
    let specs = workload();
    let on = run(&specs, true);
    let off = run(&specs, false);

    // identical completion counts: every request finishes in both runs
    assert!(on.pool.all_complete() && off.pool.all_complete());
    let done = |e: &Engine| e.pool.iter().filter(|r| r.completed_at.is_some()).count();
    assert_eq!(done(&on), specs.len());
    assert_eq!(done(&off), specs.len());

    // (1) ≥ 1.3× peak concurrent requests on the same pool (mirror 1.71×)
    let (pa_on, pa_off) = (on.metrics.peak_active(), off.metrics.peak_active());
    assert!(
        pa_on as f64 >= 1.3 * pa_off as f64,
        "peak concurrency: sharing {pa_on} !>= 1.3 x baseline {pa_off}"
    );

    // (2) strictly lower peak KV occupancy (mirror 94 vs 127 blocks)
    let (pb_on, pb_off) =
        (on.metrics.peak_kv_blocks_in_use(), off.metrics.peak_kv_blocks_in_use());
    assert!(
        pb_on < pb_off,
        "peak KV occupancy: sharing {pb_on} !< baseline {pb_off} blocks"
    );
    assert!(
        (pb_on as f64) <= 0.85 * pb_off as f64,
        "occupancy win collapsed: {pb_on} / {pb_off} blocks"
    );

    // (3) token conservation. Baseline schedules every prompt token;
    // sharing schedules prompt − cache-served, and the books must balance
    // to the token. Decode work is identical.
    let total_p: usize = specs.iter().map(|s| s.prompt_len).sum();
    let total_d: usize = specs.iter().map(|s| s.decode_len - 1).sum();
    assert_eq!(off.metrics.total_prefill_tokens(), total_p);
    assert_eq!(off.metrics.total_decode_tokens(), total_d);
    let skipped: usize = on.pool.iter().map(|r| r.prefix_skipped_tokens).sum();
    assert_eq!(on.metrics.total_prefill_tokens() + skipped, total_p);
    assert_eq!(on.metrics.total_decode_tokens(), total_d);
    assert!(skipped > 0, "hits must serve prefill from the resident cache");

    // the baseline never touches the sharing machinery
    assert_eq!(off.metrics.prefix_hits, 0);
    assert_eq!(off.metrics.peak_shared_kv_tokens(), 0);
    assert_eq!(off.kv.num_prefixes(), 0);

    // sharing: every non-registrant admission hits (4 templates register)
    assert!(on.metrics.prefix_hits >= specs.len() - 4, "hits {}", on.metrics.prefix_hits);
    assert!(on.metrics.peak_shared_kv_tokens() > 0);

    // and the cache-served prefills finish the closed-loop run sooner
    // (mirror: 8.8 s vs 36.6 s — assert a loose 0.75×)
    assert!(
        on.now < 0.75 * off.now,
        "sharing makespan {:.1}s !< 0.75 x baseline {:.1}s",
        on.now,
        off.now
    );

    // block accounting: everything returned except the resident pins
    let pinned: usize = on.kv.registered_prefixes().map(|(_, _, run)| run.len()).sum();
    assert_eq!(on.kv.available() + pinned, BLOCKS);
    assert_eq!(off.kv.available(), BLOCKS);
}

#[test]
fn prefix_hits_and_shared_occupancy_land_in_the_jsonl_trace() {
    let specs = workload();
    let on = run(&specs, true);
    assert!(on.metrics.prefix_hits > 0);

    let path = std::env::temp_dir().join("sarathi_prefix_sharing_trace.jsonl");
    on.metrics.write_jsonl(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert_eq!(text.lines().count(), on.metrics.recorded_count());
    // per-iteration hit counts sum to the metrics total…
    let hits: usize = text
        .lines()
        .filter_map(|l| {
            let tail = l.split("\"prefix_hits\":").nth(1)?;
            tail.split(&[',', '}'][..]).next()?.parse::<usize>().ok()
        })
        .sum();
    assert_eq!(hits, on.metrics.prefix_hits);
    // …and shared occupancy is visibly non-zero while sharers run
    // (shared_kv_tokens is followed by the partial-hit fields now, so
    // probe with the trailing comma, not a closing brace)
    assert!(
        text.lines().any(|l| !l.contains("\"shared_kv_tokens\":0,")
            && l.contains("\"shared_kv_tokens\":")),
        "no iteration reports shared KV occupancy"
    );
    // the radix partial-hit fields are part of the schema on every line
    assert!(
        text.lines().all(|l| l.contains("\"prefix_partial_hits\":")
            && l.contains("\"prefix_partial_hit_tokens\":")),
        "partial-hit fields missing from the JSONL schema"
    );
    std::fs::remove_file(&path).ok();
}

/// The COW edge is on the acceptance path, not just in unit tests: with a
/// 520-token prefix on 32-token blocks, every sharer forks the partial
/// 17th block — so the shared head is exactly 16 blocks (512 tokens) and
/// no sharer's table ever references a block with a co-sharer's private
/// tokens.
#[test]
fn misaligned_prefix_shares_full_blocks_and_forks_the_partial_tail() {
    let specs = workload();
    let on = run(&specs, true);
    for r in on.pool.iter() {
        // post-run: tables returned; the per-request lifetime counters
        // prove the split was in effect
        if r.prefix_hits > 0 {
            assert_eq!(r.prefix_skipped_tokens, 520.min(r.spec.prompt_len - 1));
        }
    }
    // the resident runs cover the full 520 tokens (17 blocks, partial pin)
    for (_, tokens, run) in on.kv.registered_prefixes() {
        assert_eq!(tokens, 520);
        assert_eq!(run.len(), 17);
    }
}
