//! Property tests for the token-granular paged KV block allocator and the
//! engine's preemption path (alongside scheduler_properties.rs):
//!
//! * alloc/extend/release churn never double-frees and never loses blocks,
//! * allocated blocks never exceed capacity; failed calls change nothing,
//! * under an undersized pool the engine preempts, yet every request
//!   completes, token conservation holds, and every block comes back.

use sarathi::coordinator::sched::HybridScheduler;
use sarathi::coordinator::{Engine, KvManager, RequestPool, SimExecutor};
use sarathi::config::{GpuConfig, ModelConfig};
use sarathi::costmodel::CostModel;
use sarathi::util::prop::check;
use sarathi::workload::RequestSpec;

#[test]
fn churn_preserves_allocator_invariants() {
    check("paged alloc/extend/release churn", 60, |case| {
        let bs = *case.rng.choose(&[4usize, 8, 16, 64]);
        let num_blocks = case.rng.usize(1, 40);
        let mut kv = KvManager::paged(num_blocks, bs);
        // model: live sequences as (tokens, table)
        let mut seqs: Vec<(usize, Vec<usize>)> = Vec::new();
        for _ in 0..200 {
            match case.rng.usize(0, 2) {
                // start a new sequence with a random initial footprint
                0 => {
                    let tokens = case.rng.usize(1, 3 * bs);
                    let mut table = Vec::new();
                    let before = kv.available();
                    if kv.extend_to(&mut table, tokens) {
                        if table.len() != kv.blocks_needed(tokens) {
                            return Err("table size != blocks_needed".into());
                        }
                        seqs.push((tokens, table));
                    } else {
                        if kv.available() != before || !table.is_empty() {
                            return Err("failed extend must be a no-op".into());
                        }
                    }
                }
                // grow a random sequence
                1 if !seqs.is_empty() => {
                    let i = case.rng.usize(0, seqs.len() - 1);
                    let grow = case.rng.usize(1, 2 * bs);
                    let target = seqs[i].0 + grow;
                    let len_before = seqs[i].1.len();
                    let avail_before = kv.available();
                    if kv.extend_to(&mut seqs[i].1, target) {
                        seqs[i].0 = target;
                        if seqs[i].1.len() != kv.blocks_needed(target) {
                            return Err("grown table size != blocks_needed".into());
                        }
                    } else if seqs[i].1.len() != len_before || kv.available() != avail_before {
                        return Err("failed grow must be a no-op".into());
                    }
                }
                // release a random sequence
                _ if !seqs.is_empty() => {
                    let i = case.rng.usize(0, seqs.len() - 1);
                    let (_, table) = seqs.swap_remove(i);
                    kv.release_seq(table); // double-free would panic
                }
                _ => {}
            }
            // global invariants after every operation
            let held: usize = seqs.iter().map(|(_, t)| t.len()).sum();
            if kv.allocated() != held {
                return Err(format!("allocated {} != held {held}", kv.allocated()));
            }
            if kv.allocated() + kv.available() != kv.capacity() {
                return Err("allocated + available != capacity".into());
            }
            // no block owned twice
            let mut seen = std::collections::HashSet::new();
            for (_, t) in &seqs {
                for &b in t {
                    if !seen.insert(b) {
                        return Err(format!("block {b} owned twice"));
                    }
                }
            }
        }
        for (_, t) in seqs.drain(..) {
            kv.release_seq(t);
        }
        if kv.available() != kv.capacity() {
            return Err("blocks leaked after full release".into());
        }
        Ok(())
    });
}

#[test]
fn preempted_requests_eventually_complete_and_conserve_tokens() {
    let mut total_preemptions = 0usize;
    check("engine preemption under block pressure", 60, |case| {
        let n = 1 + case.rng.usize(0, 3 + case.size);
        let specs: Vec<RequestSpec> = (0..n)
            .map(|_| RequestSpec {
                prompt_len: case.rng.usize(16, 240),
                decode_len: case.rng.usize(1, 24),
                arrival: case.rng.f64() * 0.2,
                prefix: None,
            })
            .collect();
        let bs = *case.rng.choose(&[8usize, 16, 32]);
        let watermark = case.rng.usize(0, 2);
        // pool sized to fit the single largest request plus the watermark
        // (anything smaller trips the admission feasibility guard by
        // design), plus a little random slack — tight enough that decode
        // growth forces preemptions in a healthy share of cases
        let peak = specs.iter().map(|s| s.prompt_len + s.decode_len).max().unwrap();
        let probe = KvManager::paged(1, bs);
        let num_blocks = probe.blocks_needed(peak + 1) + watermark + case.rng.usize(0, 6);
        let max_batch = case.rng.usize(2, 8);
        let budget = (*case.rng.choose(&[32usize, 64, 128])).max(max_batch);

        let cm = CostModel::new(ModelConfig::llama13b(), GpuConfig::a6000());
        let mut e = Engine::new(
            RequestPool::from_specs(&specs),
            KvManager::paged(num_blocks, bs),
            Box::new(HybridScheduler::new(budget, max_batch, watermark)),
            Box::new(SimExecutor::new(cm)),
        );
        e.run();

        if !e.pool.all_complete() {
            return Err("incomplete requests".into());
        }
        // token conservation under preemption (swap semantics: progress is
        // never recomputed, so scheduled tokens match the workload exactly)
        let p_expect: usize = specs.iter().map(|s| s.prompt_len).sum();
        let d_expect: usize = specs.iter().map(|s| s.decode_len - 1).sum();
        if e.metrics.total_prefill_tokens() != p_expect {
            return Err(format!(
                "prefill tokens {} != {p_expect}",
                e.metrics.total_prefill_tokens()
            ));
        }
        if e.metrics.total_decode_tokens() != d_expect {
            return Err(format!(
                "decode tokens {} != {d_expect}",
                e.metrics.total_decode_tokens()
            ));
        }
        // every block returned
        if e.kv.available() != num_blocks {
            return Err("leaked KV blocks".into());
        }
        // metrics agree with per-request preemption counters
        let per_req: usize = e.pool.iter().map(|r| r.preemptions).sum();
        if e.metrics.preemptions != per_req {
            return Err(format!(
                "metrics preemptions {} != per-request {per_req}",
                e.metrics.preemptions
            ));
        }
        // timestamps: tokens are monotone (streaming gaps can't go
        // negative), first token precedes completion
        if e.pool.tbt_summary().count() > 0 && e.pool.tbt_summary().min() < 0.0 {
            return Err("negative token gap: stamps not monotone".into());
        }
        for r in e.pool.iter() {
            let first = r.first_token_at.ok_or("missing first token")?;
            let done = r.completed_at.ok_or("missing completion")?;
            if first > done + 1e-12 {
                return Err("first token after completion".into());
            }
        }
        total_preemptions += e.metrics.preemptions;
        Ok(())
    });
    // the generator is tuned so block pressure actually bites: across the
    // 60 cases a healthy number of preemption events must have fired
    // (prompt-reserving admission makes preemption rare but decode growth
    // past tight pools still triggers it — ~33 events at these seeds)
    assert!(
        total_preemptions > 10,
        "only {total_preemptions} preemptions across all cases — pressure generator broken?"
    );
}
