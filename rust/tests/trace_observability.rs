//! PR-10 acceptance: event-sourced request tracing. Four claims:
//!
//! 1. **Conservation** — the per-request latency decomposition
//!    (`ttft = queue_wait + prefix_wait + swap + kv_transfer + compute`,
//!    `e2e = ttft + decode`) reproduces the measured TTFT / end-to-end
//!    latency BITWISE on the engine, pipeline and disaggregated paths,
//!    across 20 seeds — `to_bits`, not tolerances (the compute/decode
//!    components are conservation-checked residuals by construction, so
//!    any divergence is a bookkeeping bug, not float noise).
//! 2. **Determinism** — the canonically-merged lifecycle event stream
//!    and the breakdowns are identical at `--threads {1, 2, 4}` on both
//!    the routed colocated cluster and the disaggregated handoff driver
//!    (the PR-5/6 invariant extended to the trace layer).
//! 3. **Zero-cost toggle** — enabling tracing changes NO simulation
//!    output: completions/TTFT/TBT bitwise identical with the sink on
//!    and off; untraced results carry no events/breakdowns so their
//!    JSONL stays byte-identical to the pre-trace schema.
//! 4. **Export validity** — the Chrome-trace export is one well-formed
//!    JSON document with process/thread metadata, non-empty batch and
//!    bubble spans, kv-transfer lanes on disagg, and the JSONL schema
//!    version on every record (round-tripped through a file).

use sarathi::config::{Deployment, GpuConfig, ModelConfig, ParallelConfig};
use sarathi::coordinator::sched::SarathiScheduler;
use sarathi::coordinator::trace::breakdowns_from_pools;
use sarathi::coordinator::{
    Engine, EventKind, KvManager, RequestPool, Scheduler, SimExecutor, TraceSink,
};
use sarathi::costmodel::CostModel;
use sarathi::profiler::Profiler;
use sarathi::report::timeline::chrome_trace_json;
use sarathi::simulator::{ClusterResult, ClusterSim, PipelineSim, RoundRobin, Topology};
use sarathi::util::Rng;
use sarathi::workload::{with_poisson_arrivals, zipf_population, RequestSpec};

const SEEDS: u64 = 20;
const THREADS: [usize; 3] = [1, 2, 4];
const TRACE_CAP: usize = 1 << 18;

/// Long prompts with real decode phases (the cluster_disagg shape) at a
/// size small enough to sweep 20 seeds x 3 thread counts.
fn workload(seed: u64, n: usize, rate: f64) -> Vec<RequestSpec> {
    let mut rng = Rng::new(seed);
    let pop = zipf_population(&mut rng, n, 0.4, 1024, 2048, 16.0);
    with_poisson_arrivals(&mut rng, pop, rate)
}

fn deployment(replicas: usize) -> Deployment {
    let mut gpu = GpuConfig::a6000();
    gpu.interconnect_gbps = 200.0;
    Deployment::new(ModelConfig::llama13b(), gpu, 2048)
        .with_parallel(ParallelConfig::tp_pp(1, 1).with_replicas(replicas))
}

fn run_cluster(
    topology: Topology,
    pop: &[RequestSpec],
    threads: usize,
    traced: bool,
) -> ClusterResult {
    let mut cluster = ClusterSim::new(deployment(4));
    if traced {
        cluster = cluster.with_trace_cap(TRACE_CAP);
    }
    let mut router = RoundRobin::default();
    cluster.run_topology(
        topology,
        pop,
        &mut router,
        || KvManager::new(12),
        Some(12),
        || Box::new(SarathiScheduler::new(512, 12, 128)) as Box<dyn Scheduler + Send>,
        threads,
    )
}

fn disagg() -> Topology {
    Topology::Disagg { prefill_replicas: 1 }
}

/// One ULP of a positive finite float.
fn ulp(x: f64) -> f64 {
    f64::from_bits(x.to_bits() + 1) - x
}

/// Assert the component re-sum reproduces `target` bitwise or, on a
/// round-to-even tie (where no residual can), within one ULP.
fn assert_resum_tight(resum: f64, target: f64, what: &str) {
    if resum.to_bits() != target.to_bits() {
        assert!(
            (resum - target).abs() <= 2.0 * ulp(target),
            "{what}: component re-sum {resum} drifted past 2 ULP from {target}"
        );
    }
}

/// Assert every breakdown in `res` reproduces the cluster's measured
/// TTFT and end-to-end latency bitwise, with a tight component re-sum.
fn assert_cluster_conservation(res: &ClusterResult, pop: &[RequestSpec], tag: &str) {
    assert!(!res.breakdowns.is_empty(), "{tag}: traced run must carry breakdowns");
    for bd in &res.breakdowns {
        let g = bd.request;
        let measured_ttft = res.ttft[g];
        assert!(!measured_ttft.is_nan(), "{tag}: breakdown for a request with no first token");
        assert_eq!(
            bd.total_ttft().to_bits(),
            measured_ttft.to_bits(),
            "{tag} request {g}: decomposition does not conserve TTFT \
             ({} vs measured {measured_ttft})",
            bd.total_ttft(),
        );
        assert_resum_tight(bd.resummed_ttft(), measured_ttft, tag);
        if bd.completed {
            let e2e = res.completions[g] - pop[g].arrival;
            assert_eq!(
                bd.total_e2e().to_bits(),
                e2e.to_bits(),
                "{tag} request {g}: decomposition does not conserve e2e"
            );
            assert_resum_tight(bd.resummed_e2e(), e2e, tag);
        }
    }
}

#[test]
fn engine_decomposition_conserves_bitwise_across_seeds() {
    for seed in 1..=SEEDS {
        let pop = workload(seed, 40, 8.0);
        let d = Deployment::new(ModelConfig::llama13b(), GpuConfig::a6000(), 2048);
        let mut pool = RequestPool::new();
        pool.trace = TraceSink::enabled(TRACE_CAP);
        for s in &pop {
            pool.push(s.clone());
        }
        let mut e = Engine::new(
            pool,
            KvManager::new(12),
            Box::new(SarathiScheduler::new(512, 12, 128)),
            Box::new(SimExecutor::new(CostModel::for_deployment(&d))),
        );
        e.run();
        let bds = breakdowns_from_pools(std::slice::from_ref(&e.pool), &e.applier.swap, None);
        assert!(!bds.is_empty(), "seed {seed}: no breakdowns");
        for bd in &bds {
            let r = e.pool.get(bd.request);
            let ttft = r.first_token_at.expect("breakdown implies a first token") - r.arrival;
            assert_eq!(
                bd.total_ttft().to_bits(),
                ttft.to_bits(),
                "seed {seed} request {}: TTFT not conserved",
                bd.request
            );
            assert_resum_tight(bd.resummed_ttft(), ttft, "engine");
            if let Some(done) = r.completed_at {
                let e2e = done - r.arrival;
                assert_eq!(
                    bd.total_e2e().to_bits(),
                    e2e.to_bits(),
                    "seed {seed} request {}: e2e not conserved",
                    bd.request
                );
                assert_resum_tight(bd.resummed_e2e(), e2e, "engine");
            }
        }
        // the engine's sink saw the whole lifecycle: every first token has
        // its FirstToken event, every batch its span
        let events = e.pool.trace.drain();
        assert!(events.iter().any(|ev| matches!(ev.kind, EventKind::BatchSpan { .. })));
        assert!(events.iter().any(|ev| matches!(ev.kind, EventKind::FirstToken { .. })));
    }
}

#[test]
fn pipeline_decomposition_conserves_bitwise_across_seeds() {
    let d = Deployment::new(ModelConfig::llama13b(), GpuConfig::a6000(), 2048)
        .with_parallel(ParallelConfig::tp_pp(1, 2));
    let profiler = Profiler::build(CostModel::for_deployment(&d), d.max_seq_len, 16);
    let sim = PipelineSim::new(profiler, 2);
    for seed in 1..=SEEDS {
        let pop = workload(seed, 32, 6.0);
        let res = sim.run_shared_traced(
            &pop,
            KvManager::new(24),
            Some(12),
            || Box::new(SarathiScheduler::new(512, 12, 128)) as Box<dyn Scheduler + Send>,
            Some(TRACE_CAP),
        );
        assert!(!res.breakdowns.is_empty(), "seed {seed}: no breakdowns");
        for bd in &res.breakdowns {
            let ttft = res.first_tokens[bd.request] - pop[bd.request].arrival;
            assert_eq!(
                bd.total_ttft().to_bits(),
                ttft.to_bits(),
                "seed {seed} request {}: pipeline TTFT not conserved",
                bd.request
            );
            assert_resum_tight(bd.resummed_ttft(), ttft, "pipeline");
            if bd.completed {
                let e2e = res.completions[bd.request] - pop[bd.request].arrival;
                assert_eq!(
                    bd.total_e2e().to_bits(),
                    e2e.to_bits(),
                    "seed {seed} request {}: pipeline e2e not conserved",
                    bd.request
                );
                assert_resum_tight(bd.resummed_e2e(), e2e, "pipeline");
            }
        }
        // pp=2 stages with uneven micro-batches: barrier-wait bubbles and
        // per-stage batch spans must both appear in the merged stream
        assert!(res
            .events
            .iter()
            .any(|ev| matches!(ev.kind, EventKind::BatchSpan { .. })));
    }
}

#[test]
fn disagg_decomposition_conserves_and_stitches_the_handoff() {
    for seed in 1..=SEEDS {
        let pop = workload(seed, 32, 2.0);
        let res = run_cluster(disagg(), &pop, 1, true);
        assert_cluster_conservation(&res, &pop, &format!("disagg seed {seed}"));
        // the stitched breakdowns carry the fabric's wire time
        let moved: Vec<_> = res.breakdowns.iter().filter(|b| b.kv_transfer > 0.0).collect();
        assert!(!moved.is_empty(), "seed {seed}: no handoff reached a breakdown");
        for bd in moved {
            assert_eq!(
                bd.kv_transfer.to_bits(),
                res.kv_transfer_time[bd.request].to_bits(),
                "seed {seed}: breakdown wire time diverged from the cluster books"
            );
        }
    }
}

#[test]
fn merged_event_stream_is_identical_across_thread_counts() {
    for (tag, topology) in [("colocated", Topology::Colocated), ("disagg", disagg())] {
        for seed in [3u64, 7, 13, 19] {
            let pop = workload(seed, 32, 2.0);
            let base = run_cluster(topology, &pop, THREADS[0], true);
            assert!(!base.events.is_empty(), "{tag} seed {seed}: no events traced");
            // conservation holds on the routed path too, not just disagg
            assert_cluster_conservation(&base, &pop, &format!("{tag} seed {seed}"));
            for &threads in &THREADS[1..] {
                let other = run_cluster(topology, &pop, threads, true);
                assert_eq!(
                    base.events, other.events,
                    "{tag} seed {seed}: merged event stream diverged at threads={threads}"
                );
                assert_eq!(
                    base.breakdowns, other.breakdowns,
                    "{tag} seed {seed}: breakdowns diverged at threads={threads}"
                );
            }
        }
    }
}

#[test]
fn tracing_toggle_changes_no_simulation_output() {
    for (tag, topology) in [("colocated", Topology::Colocated), ("disagg", disagg())] {
        let pop = workload(42, 48, 2.0);
        let traced = run_cluster(topology, &pop, 2, true);
        let untraced = run_cluster(topology, &pop, 2, false);
        for (i, (a, b)) in traced.completions.iter().zip(&untraced.completions).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{tag} request {i}: completion diverged");
        }
        for (i, (a, b)) in traced.ttft.iter().zip(&untraced.ttft).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{tag} request {i}: ttft diverged");
        }
        for (i, (a, b)) in traced.max_tbt.iter().zip(&untraced.max_tbt).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{tag} request {i}: max_tbt diverged");
        }
        // the untraced result is schema-identical to the pre-trace layout:
        // no events, no breakdown lines in its JSONL
        assert!(untraced.events.is_empty());
        assert!(untraced.breakdowns.is_empty());
        assert!(!traced.events.is_empty());
    }
}

// ---- export validity -------------------------------------------------

/// Minimal structural JSON check: balanced braces/brackets outside of
/// strings, no trailing garbage. Not a full parser — enough to catch a
/// malformed emitter without a serde dependency.
fn assert_balanced_json(doc: &str, tag: &str) {
    let (mut brace, mut bracket) = (0i64, 0i64);
    let mut in_str = false;
    let mut escape = false;
    for c in doc.chars() {
        if escape {
            escape = false;
            continue;
        }
        match c {
            '\\' if in_str => escape = true,
            '"' => in_str = !in_str,
            '{' if !in_str => brace += 1,
            '}' if !in_str => brace -= 1,
            '[' if !in_str => bracket += 1,
            ']' if !in_str => bracket -= 1,
            _ => {}
        }
        assert!(brace >= 0 && bracket >= 0, "{tag}: closer before opener");
    }
    assert!(!in_str, "{tag}: unterminated string");
    assert_eq!((brace, bracket), (0, 0), "{tag}: unbalanced JSON");
}

/// Extract `"key":<integer>` from a JSON line (first occurrence).
fn json_int_field(line: &str, key: &str) -> Option<i64> {
    let pat = format!("\"{key}\":");
    let at = line.find(&pat)? + pat.len();
    let digits: String =
        line[at..].chars().take_while(|c| c.is_ascii_digit() || *c == '-').collect();
    digits.parse().ok()
}

#[test]
fn every_jsonl_record_round_trips_with_the_schema_version() {
    let pop = workload(5, 32, 2.0);
    let res = run_cluster(disagg(), &pop, 1, true);
    let path = std::env::temp_dir()
        .join(format!("sarathi_trace_obs_{}.jsonl", std::process::id()));
    res.write_jsonl(&path).expect("write jsonl");
    let text = std::fs::read_to_string(&path).expect("read jsonl back");
    let _ = std::fs::remove_file(&path);

    let mut kinds = std::collections::BTreeSet::new();
    let mut lines = 0usize;
    for line in text.lines() {
        lines += 1;
        assert!(line.starts_with('{') && line.ends_with('}'), "not an object: {line}");
        assert_balanced_json(line, "jsonl line");
        let v = json_int_field(line, "schema_version")
            .unwrap_or_else(|| panic!("no schema_version in {line}"));
        assert_eq!(
            v,
            sarathi::coordinator::metrics::JSONL_SCHEMA_VERSION as i64,
            "stale schema_version in {line}"
        );
        // record kind = the top-level tag (transfer records nest a
        // "request" field, so substring matching would be too loose)
        for k in ["iter", "transfer", "request", "transfer_stream"] {
            if line.starts_with(&format!("{{\"{k}\":")) {
                kinds.insert(k);
            }
        }
    }
    assert!(lines > 0, "empty trace");
    // iteration records, transfer records + summary, and the traced
    // breakdowns all coexist in one stream
    for k in ["iter", "transfer", "request"] {
        assert!(kinds.contains(k), "missing {k} records in the merged JSONL");
    }
}

#[test]
fn chrome_trace_export_is_valid_with_bubbles_and_transfer_lanes() {
    let pop = workload(9, 32, 2.0);
    let res = run_cluster(disagg(), &pop, 1, true);
    let doc = chrome_trace_json(&res.events);
    assert_balanced_json(&doc, "chrome trace");
    assert!(doc.starts_with('{') && doc.trim_end().ends_with('}'));
    for needle in [
        "\"traceEvents\":[",
        "\"displayTimeUnit\":\"ms\"",
        "\"schema_version\":",
        "\"ph\":\"M\"",          // process/thread name metadata
        "\"cat\":\"batch\"",     // iteration spans
        "\"cat\":\"bubble\"",    // classified idle intervals
        "\"cat\":\"kv-transfer\"", // fabric lanes (disagg)
        "\"cat\":\"lifecycle\"", // per-request instants
        "kv-transfer \u{2192} replica", // transfer thread naming
    ] {
        assert!(doc.contains(needle), "chrome trace missing {needle}");
    }
    // batch spans annotate their composition for the timeline tooltip
    assert!(doc.contains("\"prefill_tokens\":"));
    assert!(doc.contains("\"decode_tokens\":"));
    // per-token events are deliberately kept OUT of the export
    assert!(!doc.contains("token-emitted"));
}
