//! `sarathi` — CLI launcher for the SARATHI reproduction.
//!
//! Subcommands:
//!   figures [all|fig3..fig13|table2|table4] [--out DIR]
//!       regenerate the paper's tables/figures (prints rows, writes CSVs)
//!   serve [--requests N] [--decode N] [--scheduler S] [--json-out PATH]
//!       serve a synthetic trace with the chosen policy. With the `pjrt`
//!       feature the tiny model runs for real through PJRT
//!       ([--artifacts DIR]); without it the calibrated cost model stands
//!       in (LLaMA-13B on A6000).
//!   simulate [--requests N] [--scheduler S] [--rate R] [--budget T]
//!            [--block-size B] [--json-out PATH]
//!       engine-level simulation at scale: Zipf(0.4) lengths, Poisson
//!       arrivals, paged KV — prints throughput and TTFT/TBT/normalized
//!       latency percentiles. (The §5.3 pipeline cluster comparison lives
//!       under `figures fig12`.)
//!   calibration
//!       print the cost-model calibration summary
//!
//! Schedulers: sarathi | hybrid | orca-best | orca-worst | baseline.
//! `--json-out` writes one JSON object per iteration (shape, elapsed, KV
//! blocks in use, preemptions) — the simulator-trace idiom.

use std::path::{Path, PathBuf};

use sarathi::config::{Deployment, GpuConfig, ModelConfig, SchedulerConfig, SchedulerKind};
use sarathi::coordinator::{make_scheduler, Engine, KvManager, LatencyReport, RequestPool};
use sarathi::figures;
use sarathi::util::error::Result;
use sarathi::util::Rng;
use sarathi::workload::{with_poisson_arrivals, zipf_population, RequestSpec};

fn flag_value(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

/// Parse `--name value`, erroring on a present-but-unparsable value — a
/// silent fallback to the default would run a different experiment than
/// the one the user asked for.
fn parse_flag<T: std::str::FromStr>(args: &[String], name: &str, default: T) -> Result<T> {
    match flag_value(args, name) {
        None => Ok(default),
        Some(v) => v.parse().map_err(|_| sarathi::err!("invalid value {v:?} for {name}")),
    }
}

fn scheduler_kind(args: &[String], default: &str) -> Result<SchedulerKind> {
    let name = flag_value(args, "--scheduler").unwrap_or_else(|| default.to_string());
    SchedulerKind::parse(&name).ok_or_else(|| {
        sarathi::err!("unknown scheduler {name} (try: sarathi, hybrid, orca-best, orca-worst, baseline)")
    })
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("figures") => cmd_figures(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("simulate") => cmd_simulate(&args[1..]),
        Some("calibration") => cmd_calibration(),
        _ => {
            eprintln!(
                "usage: sarathi <figures|serve|simulate|calibration> [options]\n\
                 \n\
                 figures [all|fig3..fig13|table2|table4] [--out DIR]\n\
                 serve [--artifacts DIR] [--requests N] [--decode N]\n\
                 \x20      [--scheduler sarathi|hybrid|orca-best|orca-worst|baseline]\n\
                 \x20      [--json-out PATH]\n\
                 simulate [--requests N] [--scheduler S] [--rate R] [--budget T]\n\
                 \x20      [--block-size B] [--json-out PATH]\n\
                 calibration"
            );
            std::process::exit(2);
        }
    }
}

fn cmd_figures(args: &[String]) -> Result<()> {
    let name = args
        .iter()
        .find(|a| !a.starts_with("--"))
        .cloned()
        .unwrap_or_else(|| "all".to_string());
    let out = PathBuf::from(flag_value(args, "--out").unwrap_or_else(|| "out".into()));
    let tables = figures::run_named(&name, &out)?;
    for t in tables {
        println!("{}", t.render());
    }
    println!("(CSV written to {})", out.display());
    Ok(())
}

/// Print the shared post-run report (throughput + latency percentiles +
/// preemptions) and write the JSONL trace if requested.
fn report_run(engine: &Engine, json_out: Option<&Path>) -> Result<()> {
    let m = &engine.metrics;
    println!(
        "iterations={} prefill_tokens={} decode_tokens={} preemptions={} peak_active={}",
        m.iterations.len(),
        m.total_prefill_tokens(),
        m.total_decode_tokens(),
        m.preemptions,
        m.peak_active(),
    );
    println!("throughput={:.1} tok/s (simulated time {:.2}s)", m.throughput(), m.total_time());
    let lat = LatencyReport::from_pool(&engine.pool);
    let pct = |s: &sarathi::util::Summary| {
        (s.percentile(50.0) * 1e3, s.percentile(99.0) * 1e3)
    };
    let (t50, t99) = pct(&lat.ttft);
    println!("ttft_ms p50={t50:.1} p99={t99:.1}");
    let (b50, b99) = pct(&lat.tbt);
    println!("tbt_ms p50={b50:.1} p99={b99:.1}");
    let (n50, n99) = pct(&lat.normalized);
    println!("normalized_latency_ms_per_token p50={n50:.1} p99={n99:.1}");
    if let Some(path) = json_out {
        m.write_jsonl(path)?;
        println!("trace: {} iterations -> {}", m.iterations.len(), path.display());
    }
    Ok(())
}

/// Real PJRT serving (tiny model from AOT artifacts).
#[cfg(feature = "pjrt")]
fn cmd_serve(args: &[String]) -> Result<()> {
    use sarathi::runtime::{GenRequest, ModelRuntime, RealExecutor};
    use sarathi::util::error::Context;

    let dir = PathBuf::from(flag_value(args, "--artifacts").unwrap_or_else(|| "artifacts".into()));
    let n: usize = parse_flag(args, "--requests", 6)?;
    let decode_len: usize = parse_flag(args, "--decode", 16)?;
    let kind = scheduler_kind(args, "sarathi")?;
    let json_out = flag_value(args, "--json-out").map(PathBuf::from);

    let rt = ModelRuntime::load(&dir)?;
    println!("loaded {} artifacts on {}", rt.manifest.artifacts.len(), rt.platform());
    let slots = rt.manifest.model.usable_slots();
    let vocab = rt.manifest.model.vocab;
    let max_len = rt.manifest.model.max_len;

    let mut rng = Rng::new(11);
    let prompts: Vec<Vec<i32>> = (0..n)
        .map(|i| {
            let len = (24 + 13 * i) % (max_len - decode_len - 1).min(96) + 16;
            (0..len).map(|_| rng.usize(0, vocab - 1) as i32).collect()
        })
        .collect();
    let specs: Vec<RequestSpec> = prompts
        .iter()
        .map(|p| RequestSpec { prompt_len: p.len(), decode_len, arrival: 0.0 })
        .collect();

    // the real KV layout is one row per request — the degenerate block
    // size; hybrid runs with its token budget over the same layout
    let cfg = SchedulerConfig {
        kind,
        chunk_size: rt.manifest.max_chunk(),
        tile_align: rt.manifest.max_chunk(),
        max_batch: slots,
        token_budget: rt.manifest.max_chunk().max(slots),
        block_size: 0,
        watermark_blocks: 0,
    };

    let gen_reqs: Vec<GenRequest> = prompts.iter().map(|p| GenRequest::new(p.clone())).collect();
    let exec = RealExecutor::new(rt, gen_reqs);
    let mut engine = Engine::new(
        RequestPool::from_specs(&specs),
        KvManager::new(slots),
        make_scheduler(&cfg),
        Box::new(exec),
    );
    let t0 = std::time::Instant::now();
    engine.run();
    let wall = t0.elapsed().as_secs_f64();

    println!("scheduler={} requests={n} wall={wall:.2}s", kind.name());
    report_run(&engine, json_out.as_deref())?;
    let exec = engine
        .executor
        .as_any()
        .downcast_ref::<RealExecutor>()
        .context("executor is RealExecutor")?;
    if let Some(e) = &exec.error {
        sarathi::bail!("runtime error: {e}");
    }
    for (i, g) in exec.requests.iter().enumerate().take(3) {
        println!("request {i}: prompt {} tokens -> {:?}", g.prompt.len(), g.generated);
    }
    Ok(())
}

/// Cost-model serving stand-in (no PJRT): same CLI, same report, LLaMA-13B
/// on A6000 via the calibrated simulator.
#[cfg(not(feature = "pjrt"))]
fn cmd_serve(args: &[String]) -> Result<()> {
    use sarathi::coordinator::SimExecutor;
    use sarathi::costmodel::CostModel;

    let n: usize = parse_flag(args, "--requests", 6)?;
    let decode_len: usize = parse_flag(args, "--decode", 16)?;
    let kind = scheduler_kind(args, "sarathi")?;
    let json_out = flag_value(args, "--json-out").map(PathBuf::from);
    let block_size: usize = parse_flag(args, "--block-size", 0)?;

    let d = Deployment::new(ModelConfig::llama13b(), GpuConfig::a6000(), 2048);
    let b = d.max_batch_size();
    println!(
        "pjrt feature off — serving the calibrated cost model (LLaMA-13B on A6000, B={b})"
    );

    let mut rng = Rng::new(11);
    let specs: Vec<RequestSpec> = (0..n)
        .map(|_| RequestSpec {
            prompt_len: rng.usize(128, 1024),
            decode_len,
            arrival: 0.0,
        })
        .collect();

    let budget: usize = parse_flag(args, "--budget", 256)?.max(2 * b);
    // paging is meaningful only under the hybrid policy's memory-aware
    // admission; the slot policies' uncapped FCFS gate would admit the
    // whole queue one block at a time (same rule as cmd_simulate)
    let paged = kind == SchedulerKind::Hybrid && block_size > 0;
    let cfg = SchedulerConfig {
        kind,
        chunk_size: 256,
        tile_align: 128,
        max_batch: if kind == SchedulerKind::Hybrid { 2 * b } else { b },
        token_budget: budget,
        block_size: if paged { block_size } else { 0 },
        watermark_blocks: if paged { 2 } else { 0 },
    };
    let kv = if paged {
        KvManager::paged(d.kv_blocks(block_size), block_size)
    } else {
        KvManager::new(b)
    };

    let cm = CostModel::for_deployment(&d);
    let mut engine = Engine::new(
        RequestPool::from_specs(&specs),
        kv,
        make_scheduler(&cfg),
        Box::new(SimExecutor::new(cm)),
    );
    engine.run();
    println!("scheduler={} requests={n} effective_token_budget={}", kind.name(), cfg.token_budget);
    report_run(&engine, json_out.as_deref())
}

/// Engine-level simulation at scale: Zipf sequence lengths, Poisson
/// arrivals, paged KV — the production-shaped testbed for the hybrid
/// policy (the §5.3 pipeline cluster comparison is `figures fig12`).
fn cmd_simulate(args: &[String]) -> Result<()> {
    use sarathi::coordinator::SimExecutor;
    use sarathi::costmodel::CostModel;

    let n: usize = parse_flag(args, "--requests", 2000)?;
    let kind = scheduler_kind(args, "hybrid")?;
    let rate: f64 = parse_flag(args, "--rate", 1.5)?;
    let budget: usize = parse_flag(args, "--budget", 256)?;
    let block_size: usize = parse_flag(args, "--block-size", 32)?;
    let json_out = flag_value(args, "--json-out").map(PathBuf::from);

    let d = Deployment::new(ModelConfig::llama13b(), GpuConfig::a6000(), 2048);
    let b = d.max_batch_size();
    let mut rng = Rng::new(7);
    let pop = zipf_population(&mut rng, n, 0.4, 256, 2048, 10.0);
    let pop = with_poisson_arrivals(&mut rng, pop, rate);

    // slot policies get the §4.3.1 worst-case slots; the hybrid policy gets
    // the same memory as a paged block pool
    let paged = kind == SchedulerKind::Hybrid && block_size > 0;
    let kv = if paged {
        KvManager::paged(d.kv_blocks(block_size), block_size)
    } else {
        KvManager::new(b)
    };
    let cfg = SchedulerConfig {
        kind,
        chunk_size: 256,
        tile_align: 128,
        max_batch: if paged { 4 * b } else { b },
        token_budget: budget.max(4 * b),
        block_size: if paged { block_size } else { 0 },
        watermark_blocks: if paged { 2 } else { 0 },
    };

    println!(
        "LLaMA-13B on A6000: {n} requests, Zipf(0.4) in [256,2048], P:D=10, \
         Poisson {rate} req/s, scheduler={} effective_token_budget={} {}",
        kind.name(),
        cfg.token_budget,
        if paged {
            format!("(paged KV: {} blocks x {block_size} tokens)", kv.capacity())
        } else {
            format!("(slot KV: B={b})")
        }
    );
    let t0 = std::time::Instant::now();
    let mut engine = Engine::new(
        RequestPool::from_specs(&pop),
        kv,
        make_scheduler(&cfg),
        Box::new(SimExecutor::new(CostModel::for_deployment(&d))),
    );
    engine.run();
    println!("simulated in {:.2}s wall", t0.elapsed().as_secs_f64());
    report_run(&engine, json_out.as_deref())
}

fn cmd_calibration() -> Result<()> {
    use sarathi::costmodel::{BatchShape, CostModel};
    for (m, g) in [
        (ModelConfig::llama13b(), GpuConfig::a6000()),
        (ModelConfig::llama33b(), GpuConfig::a100()),
        (ModelConfig::gpt3(), GpuConfig::a100()),
    ] {
        let cm = CostModel::new(m.clone(), g.clone());
        let prefill = cm.iteration_time(&BatchShape::prefill_only(&[(1024, 0)])) / 1024.0;
        let decode = cm.iteration_time(&BatchShape::decode_only(&[1024]));
        println!(
            "{:<12} on {:<6}: prefill {:.3} ms/tok  decode(B=1) {:.2} ms/tok  ratio {:>5.0}x  saturation {} tok",
            m.name,
            g.name,
            prefill * 1e3,
            decode * 1e3,
            decode / prefill,
            cm.saturation_tokens(),
        );
    }
    Ok(())
}
