//! `sarathi` — CLI launcher for the SARATHI reproduction.
//!
//! Subcommands:
//!   figures [all|fig3..fig13|table2|table4] [--out DIR]
//!       regenerate the paper's tables/figures (prints rows, writes CSVs)
//!   serve [--artifacts DIR] [--requests N] [--decode N] [--scheduler S]
//!       serve the tiny model for real through PJRT with the chosen policy
//!   simulate [--requests N]
//!       run the §5.3 GPT-3 64-GPU cluster comparison at full scale
//!   calibration
//!       print the cost-model calibration summary

use std::path::PathBuf;

use sarathi::config::{SchedulerKind, SchedulerConfig};
use sarathi::coordinator::{Engine, KvManager, RequestPool, make_scheduler};
use sarathi::figures;
use sarathi::runtime::{GenRequest, ModelRuntime, RealExecutor};
use sarathi::util::Rng;
use sarathi::workload::RequestSpec;

fn flag_value(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("figures") => cmd_figures(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("simulate") => cmd_simulate(&args[1..]),
        Some("calibration") => cmd_calibration(),
        _ => {
            eprintln!(
                "usage: sarathi <figures|serve|simulate|calibration> [options]\n\
                 \n\
                 figures [all|fig3..fig13|table2|table4] [--out DIR]\n\
                 serve [--artifacts DIR] [--requests N] [--decode N] [--scheduler sarathi|orca-best|orca-worst|baseline]\n\
                 simulate [--requests N]\n\
                 calibration"
            );
            std::process::exit(2);
        }
    }
}

fn cmd_figures(args: &[String]) -> anyhow::Result<()> {
    let name = args
        .iter()
        .find(|a| !a.starts_with("--"))
        .cloned()
        .unwrap_or_else(|| "all".to_string());
    let out = PathBuf::from(flag_value(args, "--out").unwrap_or_else(|| "out".into()));
    let tables = figures::run_named(&name, &out)?;
    for t in tables {
        println!("{}", t.render());
    }
    println!("(CSV written to {})", out.display());
    Ok(())
}

fn cmd_serve(args: &[String]) -> anyhow::Result<()> {
    let dir = PathBuf::from(flag_value(args, "--artifacts").unwrap_or_else(|| "artifacts".into()));
    let n: usize = flag_value(args, "--requests").and_then(|v| v.parse().ok()).unwrap_or(6);
    let decode_len: usize = flag_value(args, "--decode").and_then(|v| v.parse().ok()).unwrap_or(16);
    let sched_name = flag_value(args, "--scheduler").unwrap_or_else(|| "sarathi".into());

    let rt = ModelRuntime::load(&dir)?;
    println!("loaded {} artifacts on {}", rt.manifest.artifacts.len(), rt.platform());
    let slots = rt.manifest.model.usable_slots();
    let vocab = rt.manifest.model.vocab;
    let max_len = rt.manifest.model.max_len;

    let mut rng = Rng::new(11);
    let prompts: Vec<Vec<i32>> = (0..n)
        .map(|i| {
            let len = (24 + 13 * i) % (max_len - decode_len - 1).min(96) + 16;
            (0..len).map(|_| rng.usize(0, vocab - 1) as i32).collect()
        })
        .collect();
    let specs: Vec<RequestSpec> = prompts
        .iter()
        .map(|p| RequestSpec { prompt_len: p.len(), decode_len, arrival: 0.0 })
        .collect();

    let kind = match sched_name.as_str() {
        "sarathi" => SchedulerKind::Sarathi,
        "orca-best" => SchedulerKind::OrcaBest,
        "orca-worst" => SchedulerKind::OrcaWorst,
        "baseline" => SchedulerKind::RequestLevel,
        other => anyhow::bail!("unknown scheduler {other}"),
    };
    let cfg = SchedulerConfig {
        kind,
        chunk_size: rt.manifest.max_chunk(),
        tile_align: rt.manifest.max_chunk(),
        max_batch: slots,
    };

    let gen_reqs: Vec<GenRequest> = prompts.iter().map(|p| GenRequest::new(p.clone())).collect();
    let exec = RealExecutor::new(rt, gen_reqs);
    let mut engine = Engine::new(
        RequestPool::from_specs(&specs),
        KvManager::new(slots),
        make_scheduler(&cfg),
        Box::new(exec),
    );
    let t0 = std::time::Instant::now();
    engine.run();
    let wall = t0.elapsed().as_secs_f64();

    let m = &engine.metrics;
    println!(
        "scheduler={sched_name} requests={n} iterations={} wall={:.2}s",
        m.iterations.len(),
        wall
    );
    println!(
        "prefill_tokens={} decode_tokens={} throughput={:.1} tok/s",
        m.total_prefill_tokens(),
        m.total_decode_tokens(),
        (m.total_prefill_tokens() + m.total_decode_tokens()) as f64 / wall
    );
    let exec = engine.executor.as_any().downcast_ref::<RealExecutor>().unwrap();
    if let Some(e) = &exec.error {
        anyhow::bail!("runtime error: {e}");
    }
    for (i, g) in exec.requests.iter().enumerate().take(3) {
        println!("request {i}: prompt {} tokens -> {:?}", g.prompt.len(), g.generated);
    }
    Ok(())
}

fn cmd_simulate(args: &[String]) -> anyhow::Result<()> {
    let n: usize = flag_value(args, "--requests").and_then(|v| v.parse().ok()).unwrap_or(10_000);
    println!("GPT-3 on 64 simulated A100s, {n} requests (Zipf 0.4, P:D=10) ...");
    let t0 = std::time::Instant::now();
    let out = sarathi::figures::fig12_pipeline::simulate(n);
    println!("simulated in {:.2}s", t0.elapsed().as_secs_f64());
    println!(
        "orca tp8-pp8:    makespan {:.1}s  (median bubble {:.2}s)",
        out.orca_pp.makespan,
        out.orca_pp.per_replica[0].bubble_summary().percentile(50.0)
    );
    println!(
        "sarathi tp8-pp8: makespan {:.1}s  (median bubble {:.2}s)",
        out.sarathi_pp.makespan,
        out.sarathi_pp.per_replica[0].bubble_summary().percentile(50.0)
    );
    println!("tp8 x8 replicas: makespan {:.1}s", out.tp_only.makespan);
    println!(
        "sarathi speedup: {:.2}x vs orca-pp, {:.2}x vs tp-only",
        out.orca_pp.makespan / out.sarathi_pp.makespan,
        out.tp_only.makespan / out.sarathi_pp.makespan
    );
    Ok(())
}

fn cmd_calibration() -> anyhow::Result<()> {
    use sarathi::config::{GpuConfig, ModelConfig};
    use sarathi::costmodel::{BatchShape, CostModel};
    for (m, g) in [
        (ModelConfig::llama13b(), GpuConfig::a6000()),
        (ModelConfig::llama33b(), GpuConfig::a100()),
        (ModelConfig::gpt3(), GpuConfig::a100()),
    ] {
        let cm = CostModel::new(m.clone(), g.clone());
        let prefill = cm.iteration_time(&BatchShape::prefill_only(&[(1024, 0)])) / 1024.0;
        let decode = cm.iteration_time(&BatchShape::decode_only(&[1024]));
        println!(
            "{:<12} on {:<6}: prefill {:.3} ms/tok  decode(B=1) {:.2} ms/tok  ratio {:>5.0}x  saturation {} tok",
            m.name,
            g.name,
            prefill * 1e3,
            decode * 1e3,
            decode / prefill,
            cm.saturation_tokens(),
        );
    }
    Ok(())
}
