//! `sarathi` — CLI launcher for the SARATHI reproduction.
//!
//! Subcommands:
//!   figures [all|fig3..fig13|table2|table4] [--out DIR]
//!       regenerate the paper's tables/figures (prints rows, writes CSVs)
//!   serve [--requests N] [--decode N] [--scheduler S] [--rate R]
//!         [--json-out PATH]
//!         [--prefix-share [--num-templates T] [--prefix-len L]]
//!         [--max-prefix-wait K] [--bypass-window W]
//!       serve a synthetic trace with the chosen policy. With the `pjrt`
//!       feature the tiny model runs for real through PJRT
//!       ([--artifacts DIR]); without it the calibrated cost model stands
//!       in (LLaMA-13B on A6000). `--rate R` (cost-model path) switches
//!       to open-loop Poisson arrivals at R req/s so the JSONL trace
//!       captures idle-gap behavior; the default (0) keeps the seed's
//!       all-at-t=0 closed loop.
//!   simulate [--requests N] [--scheduler S] [--rate R] [--budget T]
//!            [--block-size B] [--kv-blocks K] [--pp P]
//!            [--replicas R [--router rr|jsq|affinity|affinity-hist]
//!             [--spill-factor F]]
//!            [--topology colocated|disagg|split [--prefill-replicas K]
//!             [--interconnect-gbps G] [--ttft-slo S] [--tbt-slo S]]
//!            [--preemption swap|recompute]
//!            [--prefix-share [--num-templates T] [--prefix-len L]]
//!            [--max-prefix-wait K] [--bypass-window W]
//!            [--json-out PATH] [--trace-out PATH]
//!       engine-level simulation at scale: Zipf(0.4) lengths, Poisson
//!       arrivals, paged KV — prints throughput and TTFT/TBT/normalized
//!       latency percentiles. With `--pp P` (P > 1) the same workload
//!       runs through the pipeline-parallel simulator instead: P streams
//!       over ONE shared KV pool per replica (paged under
//!       `--scheduler hybrid --block-size N`), preemption swaps priced at
//!       PCIe bandwidth, bubble accounting in the report. With
//!       `--replicas R` (R > 1) the workload is served by a CLUSTER of R
//!       identical replicas behind a request router (`--router`):
//!       round-robin, join-shortest-queue by outstanding work, or
//!       rendezvous-hash prefix affinity with a power-of-two load shed
//!       (`--spill-factor`); the report gains the aggregate prefix-hit
//!       rate, per-replica peak KV occupancy and the load-imbalance
//!       statistic, and every JSONL record carries its `replica`. (The
//!       §5.3 GPT-3 cluster comparison lives under `figures fig12`.)
//!       `--topology disagg` dedicates `--prefill-replicas K` replicas to
//!       chunked prefills and hands each finished prompt's KV to a decode
//!       replica over a costed copy stream (`--interconnect-gbps`, default
//!       the GPU's fabric rating) that overlaps compute; `split` keeps the
//!       handoff on-device over two intra-replica lanes. The report gains
//!       SLO goodput (`--ttft-slo`/`--tbt-slo`, seconds), per-request
//!       `kv_transfer_time`, and transfer-stream utilization; each KV
//!       handoff lands in the JSONL trace as a `transfer` record.
//!       `--prefix-share` switches the workload to template traffic — T
//!       shared prompt prefixes of L tokens, Zipf request fanout — and
//!       turns on copy-on-write prefix sharing over the paged block map
//!       (requires `--scheduler hybrid` with a block size); prefix hits
//!       and shared-KV occupancy land in the report and JSONL trace.
//!       `--workload conversation` (with `--prefix-share`) swaps in
//!       conversation-TREE traffic: a shared system prompt fans into
//!       branch scaffolds and multi-turn sessions whose every turn
//!       carries its accumulated content path, so the radix store shares
//!       ancestor subtrees between requests whose template ids never
//!       repeat — partial (ancestor-depth) hits and their skipped tokens
//!       land in the report and JSONL. `--router affinity-hist` keeps the
//!       legacy dispatch-history rendezvous affinity for comparison with
//!       the digest-scored default.
//!
//!       **Soak mode** (`serve` cost-model path and single-engine
//!       `simulate`): `--horizon-secs H` replaces the fixed request count
//!       with a REGENERATING workload served for H simulated seconds —
//!       a diurnal rate curve (`--diurnal-amp A --diurnal-period P`),
//!       periodic flash crowds pinned to the hottest template
//!       (`--flash-every E --flash-dur D --flash-mult M`) and sinusoidal
//!       prompt/output length drift (`--drift-amp A --drift-period P`).
//!       Memory stays bounded no matter the horizon: terminal requests
//!       retire off the pool, iteration records stream to `--json-out`
//!       every `--flush-every F` simulated seconds (windowed retention
//!       otherwise), and latency distributions spill to quantile sketches.
//!       `--target-p99-tbt T` (hybrid only) closes an online AIMD control
//!       loop over the token budget toward a P99 time-between-tokens of T
//!       seconds, plus prefix-wait adaptation; `--ttft-slo`/`--tbt-slo`
//!       gate per-request goodput. Progress lines print at each flush.
//!   calibration
//!       print the cost-model calibration summary
//!
//! Schedulers: sarathi | hybrid | orca-best | orca-worst | baseline.
//! `--max-prefix-wait K` bounds cache-aware admission waits (K consecutive
//! no-progress attempts degrade the waiter to a full-price miss; 0 = never
//! wait); `--bypass-window W` lets up to W followers admit past an
//! observably stalled waiting head (0 = strict FCFS).
//! `--json-out` writes one JSON object per iteration (shape, elapsed, KV
//! blocks in use, preemptions, swap time) — the simulator-trace idiom.
//! `--trace-out` (simulate) turns on the lifecycle event bus and writes a
//! Chrome-trace / Perfetto timeline: replicas as processes, pp streams and
//! KV-transfer lanes as threads, batch spans annotated with their
//! prefill/decode composition and idle gaps classified
//! (no-work / kv-starved / budget-capped / barrier-wait); the report
//! gains the conservation-checked per-request TTFT decomposition
//! (`queue_wait + prefix_wait + swap + kv_transfer + compute`, carrying
//! the measured TTFT bitwise with residual-checked components).
//! Open-loop paths (`serve`, `simulate`) REJECT requests that could never
//! fit the KV pool (terminal state + metrics counter) instead of
//! panicking; figure-repro paths keep the loud panic.

use std::path::{Path, PathBuf};

use sarathi::config::{
    Deployment, GpuConfig, ModelConfig, ParallelConfig, PreemptionMode, SchedulerConfig,
    SchedulerKind,
};
use sarathi::coordinator::{
    make_scheduler, Admission, ControllerConfig, Engine, KvManager, LatencyReport, Metrics,
    RequestPool, SwapCost, TraceSink,
};
use sarathi::figures;
use sarathi::simulator::{run_soak, ClusterSim, PipelineSim, RouterKind, SoakOpts, Topology};
use sarathi::util::error::Result;
use sarathi::util::Rng;
use sarathi::workload::{
    with_poisson_arrivals, zipf_population, RateCurve, RequestSpec, SoakWorkload,
};

/// Event-ring capacity per sink for `--trace-out` runs: sized for the
/// CLI-scale workloads (the ring pre-allocates at most the library
/// default and grows on demand, so small runs stay small); overflow is
/// dropped-and-counted, never unbounded.
const CLI_TRACE_CAP: usize = 1 << 20;

fn flag_value(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

/// Boolean presence flag (`--prefix-share` style).
fn has_flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

/// Parse `--name value`, erroring on a present-but-unparsable value — a
/// silent fallback to the default would run a different experiment than
/// the one the user asked for.
fn parse_flag<T: std::str::FromStr>(args: &[String], name: &str, default: T) -> Result<T> {
    match flag_value(args, name) {
        None => Ok(default),
        Some(v) => v.parse().map_err(|_| sarathi::err!("invalid value {v:?} for {name}")),
    }
}

fn scheduler_kind(args: &[String], default: &str) -> Result<SchedulerKind> {
    let name = flag_value(args, "--scheduler").unwrap_or_else(|| default.to_string());
    SchedulerKind::parse(&name).ok_or_else(|| {
        sarathi::err!("unknown scheduler {name} (try: sarathi, hybrid, orca-best, orca-worst, baseline)")
    })
}

fn preemption_mode(args: &[String]) -> Result<PreemptionMode> {
    let name = flag_value(args, "--preemption").unwrap_or_else(|| "swap".to_string());
    PreemptionMode::parse(&name)
        .ok_or_else(|| sarathi::err!("unknown preemption mode {name} (try: swap, recompute)"))
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("figures") => cmd_figures(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("simulate") => cmd_simulate(&args[1..]),
        Some("calibration") => cmd_calibration(),
        _ => {
            eprintln!(
                "usage: sarathi <figures|serve|simulate|calibration> [options]\n\
                 \n\
                 figures [all|fig3..fig13|table2|table4] [--out DIR]\n\
                 serve [--artifacts DIR] [--requests N] [--decode N] [--rate R]\n\
                 \x20      [--scheduler sarathi|hybrid|orca-best|orca-worst|baseline]\n\
                 \x20      [--prefix-share] [--num-templates T] [--prefix-len L]\n\
                 \x20      [--max-prefix-wait K] [--bypass-window W]\n\
                 \x20      [--json-out PATH]\n\
                 simulate [--requests N] [--scheduler S] [--rate R] [--budget T]\n\
                 \x20      [--block-size B] [--kv-blocks K] [--pp P]\n\
                 \x20      [--replicas R] [--router rr|jsq|affinity|affinity-hist]\n\
                 \x20      [--spill-factor F]\n\
                 \x20      [--threads T]  (cluster only; 0 = one per core, default 1)\n\
                 \x20      [--topology colocated|disagg|split] [--prefill-replicas K]\n\
                 \x20      [--interconnect-gbps G] [--ttft-slo S] [--tbt-slo S]\n\
                 \x20      [--preemption swap|recompute]\n\
                 \x20      [--prefix-share] [--num-templates T] [--prefix-len L]\n\
                 \x20      [--workload unique|conversation]\n\
                 \x20      [--max-prefix-wait K] [--bypass-window W]\n\
                 \x20      [--json-out PATH] [--trace-out PATH]\n\
                 \x20      [--horizon-secs H] [--flush-every F] [--target-p99-tbt T]\n\
                 \x20      [--exact-arrivals]\n\
                 \x20      [--diurnal-amp A] [--diurnal-period P]\n\
                 \x20      [--flash-every E] [--flash-dur D] [--flash-mult M]\n\
                 \x20      [--drift-amp A] [--drift-period P]  (soak mode)\n\
                 calibration"
            );
            std::process::exit(2);
        }
    }
}

fn cmd_figures(args: &[String]) -> Result<()> {
    let name = args
        .iter()
        .find(|a| !a.starts_with("--"))
        .cloned()
        .unwrap_or_else(|| "all".to_string());
    let out = PathBuf::from(flag_value(args, "--out").unwrap_or_else(|| "out".into()));
    let tables = figures::run_named(&name, &out)?;
    for t in tables {
        println!("{}", t.render());
    }
    println!("(CSV written to {})", out.display());
    Ok(())
}

/// Print latency percentiles and write the JSONL trace if requested.
fn report_latency(lat: &LatencyReport, m: &Metrics, json_out: Option<&Path>) -> Result<()> {
    let pct = |s: &sarathi::util::Summary| {
        (s.percentile(50.0) * 1e3, s.percentile(99.0) * 1e3)
    };
    let (t50, t99) = pct(&lat.ttft);
    println!("ttft_ms p50={t50:.1} p99={t99:.1}");
    let (b50, b99) = pct(&lat.tbt);
    println!("tbt_ms p50={b50:.1} p99={b99:.1}");
    let (n50, n99) = pct(&lat.normalized);
    println!("normalized_latency_ms_per_token p50={n50:.1} p99={n99:.1}");
    if lat.prefix_wait.count() > 0 {
        let (w50, w99) = pct(&lat.prefix_wait);
        println!(
            "prefix_wait_ms p50={w50:.1} p99={w99:.1} waiters={}",
            lat.prefix_wait.count()
        );
    }
    if let Some(path) = json_out {
        m.write_jsonl(path)?;
        println!("trace: {} iterations -> {}", m.recorded_count(), path.display());
    }
    Ok(())
}

/// Print the shared post-run report (throughput + latency percentiles +
/// preemptions) and write the JSONL trace if requested.
fn report_run(engine: &Engine, json_out: Option<&Path>) -> Result<()> {
    let m = &engine.metrics;
    println!(
        "iterations={} prefill_tokens={} decode_tokens={} preemptions={} rejections={} \
         peak_active={}",
        m.recorded_count(),
        m.total_prefill_tokens(),
        m.total_decode_tokens(),
        m.preemptions,
        m.rejections,
        m.peak_active(),
    );
    println!(
        "prefix_hits={} prefix_fallbacks={} prefix_wait_iters={} skipped_prefill_tokens={} \
         peak_shared_kv_tokens={} peak_kv_blocks_in_use={}",
        m.prefix_hits,
        m.prefix_fallbacks,
        m.prefix_wait_iterations,
        engine.pool.iter().map(|r| r.prefix_skipped_tokens).sum::<usize>(),
        m.peak_shared_kv_tokens(),
        m.peak_kv_blocks_in_use(),
    );
    // radix partial (ancestor-depth) hits: requests whose template id was
    // never registered but whose content path matched a resident subtree
    println!(
        "prefix_partial_hits={} partial_hit_tokens={} mean_hit_depth_tokens={:.1}",
        m.prefix_partial_hits,
        m.prefix_partial_hit_tokens,
        if m.prefix_partial_hits > 0 {
            m.prefix_partial_hit_tokens as f64 / m.prefix_partial_hits as f64
        } else {
            0.0
        },
    );
    // wall-clock throughput is the headline: idle gaps (open-loop Poisson
    // arrivals) and swap transfers belong in the denominator. Busy-time
    // throughput (iteration time only) rides along for comparison with
    // the closed-loop figures.
    println!(
        "throughput={:.1} tok/s over {:.2}s wall-clock (busy-time {:.1} tok/s over {:.2}s; \
         swap {:.3}s)",
        m.wall_throughput(),
        m.wall_clock_span(),
        m.throughput(),
        m.total_time(),
        m.total_swap_time(),
    );
    let lat = LatencyReport::from_pool(&engine.pool);
    report_latency(&lat, m, json_out)
}

/// Real PJRT serving (tiny model from AOT artifacts).
#[cfg(feature = "pjrt")]
fn cmd_serve(args: &[String]) -> Result<()> {
    use sarathi::runtime::{GenRequest, ModelRuntime, RealExecutor};
    use sarathi::util::error::Context;

    let dir = PathBuf::from(flag_value(args, "--artifacts").unwrap_or_else(|| "artifacts".into()));
    let n: usize = parse_flag(args, "--requests", 6)?;
    let decode_len: usize = parse_flag(args, "--decode", 16)?;
    let kind = scheduler_kind(args, "sarathi")?;
    let json_out = flag_value(args, "--json-out").map(PathBuf::from);

    if has_flag(args, "--prefix-share") {
        sarathi::bail!(
            "--prefix-share needs the paged cost-model path (build without the pjrt \
             feature); the real runtime serves one degenerate KV row per request"
        );
    }
    if flag_value(args, "--rate").is_some() {
        sarathi::bail!(
            "--rate (open-loop Poisson arrivals) runs on the simulated clock — use \
             the cost-model path (build without the pjrt feature)"
        );
    }
    if flag_value(args, "--horizon-secs").is_some() {
        sarathi::bail!(
            "--horizon-secs (soak mode) runs on the simulated clock — use the \
             cost-model path (build without the pjrt feature)"
        );
    }

    let rt = ModelRuntime::load(&dir)?;
    println!("loaded {} artifacts on {}", rt.manifest.artifacts.len(), rt.platform());
    let slots = rt.manifest.model.usable_slots();
    let vocab = rt.manifest.model.vocab;
    let max_len = rt.manifest.model.max_len;

    let mut rng = Rng::new(11);
    let prompts: Vec<Vec<i32>> = (0..n)
        .map(|i| {
            let len = (24 + 13 * i) % (max_len - decode_len - 1).min(96) + 16;
            (0..len).map(|_| rng.usize(0, vocab - 1) as i32).collect()
        })
        .collect();
    let specs: Vec<RequestSpec> = prompts
        .iter()
        .map(|p| RequestSpec { prompt_len: p.len(), decode_len, arrival: 0.0, prefix: None })
        .collect();

    // the real KV layout is one row per request — the degenerate block
    // size; hybrid runs with its token budget over the same layout
    let cfg = SchedulerConfig {
        kind,
        chunk_size: rt.manifest.max_chunk(),
        tile_align: rt.manifest.max_chunk(),
        max_batch: slots,
        token_budget: rt.manifest.max_chunk().max(slots),
        block_size: 0,
        watermark_blocks: 0,
        preemption: PreemptionMode::Swap,
        // serving stance: an oversized request is rejected, not a crash
        reject_infeasible: true,
        prefix_share: false,
        max_prefix_wait: Admission::DEFAULT_MAX_PREFIX_WAIT,
        bypass_window: Admission::DEFAULT_BYPASS_WINDOW,
    };

    let gen_reqs: Vec<GenRequest> = prompts.iter().map(|p| GenRequest::new(p.clone())).collect();
    let exec = RealExecutor::new(rt, gen_reqs);
    let mut engine = Engine::new(
        RequestPool::from_specs(&specs),
        KvManager::new(slots),
        make_scheduler(&cfg),
        Box::new(exec),
    );
    let t0 = std::time::Instant::now();
    engine.run();
    let wall = t0.elapsed().as_secs_f64();

    println!("scheduler={} requests={n} wall={wall:.2}s", kind.name());
    report_run(&engine, json_out.as_deref())?;
    let exec = engine
        .executor
        .as_any()
        .downcast_ref::<RealExecutor>()
        .context("executor is RealExecutor")?;
    if let Some(e) = &exec.error {
        sarathi::bail!("runtime error: {e}");
    }
    for (i, g) in exec.requests.iter().enumerate().take(3) {
        println!("request {i}: prompt {} tokens -> {:?}", g.prompt.len(), g.generated);
    }
    Ok(())
}

/// Cost-model serving stand-in (no PJRT): same CLI, same report, LLaMA-13B
/// on A6000 via the calibrated simulator.
#[cfg(not(feature = "pjrt"))]
fn cmd_serve(args: &[String]) -> Result<()> {
    use sarathi::coordinator::SimExecutor;
    use sarathi::costmodel::CostModel;

    let n: usize = parse_flag(args, "--requests", 6)?;
    let decode_len: usize = parse_flag(args, "--decode", 16)?;
    let kind = scheduler_kind(args, "sarathi")?;
    let json_out = flag_value(args, "--json-out").map(PathBuf::from);
    let block_size: usize = parse_flag(args, "--block-size", 0)?;
    let preemption = preemption_mode(args)?;
    let prefix = PrefixOpts::parse(args)?;
    // 0 (the default) keeps the seed's closed loop: everything at t=0
    let rate: f64 = parse_flag(args, "--rate", 0.0)?;
    if rate < 0.0 {
        sarathi::bail!("--rate must be non-negative");
    }
    let wait = WaitOpts::parse(args)?;
    let soak = SoakCliOpts::parse(args)?;
    if soak.is_some() {
        if rate <= 0.0 {
            sarathi::bail!(
                "--horizon-secs regenerates open-loop traffic and needs --rate > 0 \
                 (req/s at the diurnal midpoint)"
            );
        }
        if flag_value(args, "--requests").is_some() {
            sarathi::bail!("--requests and --horizon-secs are different stopping rules; pick one");
        }
        if soak.unwrap().target_p99_tbt > 0.0 && kind != SchedulerKind::Hybrid {
            sarathi::bail!(
                "--target-p99-tbt adapts the hybrid token budget; use --scheduler hybrid"
            );
        }
    }

    let d = Deployment::new(ModelConfig::llama13b(), GpuConfig::a6000(), 2048);
    let b = d.max_batch_size();
    println!(
        "pjrt feature off — serving the calibrated cost model (LLaMA-13B on A6000, B={b})"
    );

    // paging is meaningful only under the hybrid policy's memory-aware
    // admission; the slot policies' uncapped FCFS gate would admit the
    // whole queue one block at a time (same rule as cmd_simulate)
    let paged = kind == SchedulerKind::Hybrid && block_size > 0;
    if prefix.share && !paged {
        sarathi::bail!(
            "--prefix-share requires --scheduler hybrid with --block-size > 0 \
             (sharing lives on the paged block map)"
        );
    }

    let mut rng = Rng::new(11);
    // template traffic is the ONE workload shape shared with simulate
    // (PrefixOpts::population); it draws its own decode lengths, so
    // --decode only shapes the non-template path
    let specs: Vec<RequestSpec> = if prefix.share {
        prefix.population(&mut rng, n, block_size)
    } else {
        (0..n)
            .map(|_| RequestSpec {
                prompt_len: rng.usize(128, 1024),
                decode_len,
                arrival: 0.0,
                prefix: None,
            })
            .collect()
    };
    // open-loop serving: Poisson arrivals instead of the all-at-t=0
    // closed loop, so the trace shows idle-gap (steady-state) behavior
    let specs = if rate > 0.0 {
        with_poisson_arrivals(&mut rng, specs, rate)
    } else {
        specs
    };

    let budget: usize = parse_flag(args, "--budget", 256)?.max(2 * b);
    let cfg = SchedulerConfig {
        kind,
        chunk_size: 256,
        tile_align: 128,
        max_batch: if kind == SchedulerKind::Hybrid { 2 * b } else { b },
        token_budget: budget,
        block_size: if paged { block_size } else { 0 },
        watermark_blocks: if paged { 2 } else { 0 },
        preemption,
        reject_infeasible: true,
        prefix_share: prefix.share,
        max_prefix_wait: wait.max_prefix_wait,
        bypass_window: wait.bypass_window,
    };
    let kv = if paged {
        KvManager::paged(d.kv_blocks(block_size), block_size)
    } else {
        KvManager::new(b)
    };

    let cm = CostModel::for_deployment(&d);
    let pool = if soak.is_some() {
        // soak mode regenerates its own arrivals; the pool starts empty
        RequestPool::new()
    } else {
        RequestPool::from_specs(&specs)
    };
    let mut engine = Engine::new(pool, kv, make_scheduler(&cfg), Box::new(SimExecutor::new(cm)))
        .with_swap_cost(SwapCost::for_deployment(&d, preemption));
    if let Some(so) = &soak {
        println!(
            "scheduler={} soak horizon={}s rate={rate} req/s effective_token_budget={}",
            kind.name(),
            so.horizon,
            cfg.token_budget,
        );
        let mut w = so.workload(rate, &prefix);
        return run_soak_cli(so, &mut engine, &cfg, &mut w, None, None, json_out.as_deref(), None);
    }
    engine.run();
    println!(
        "scheduler={} requests={n} effective_token_budget={} arrivals={}",
        kind.name(),
        cfg.token_budget,
        if rate > 0.0 { format!("poisson {rate} req/s") } else { "closed-loop t=0".into() },
    );
    report_run(&engine, json_out.as_deref())
}

/// `--max-prefix-wait` / `--bypass-window` fallback-policy knobs shared by
/// serve/simulate (the PR-4 ROADMAP follow-up): how long cache-aware
/// admission waits on an in-flight prefix fill before degrading to a
/// full-price miss, and how many followers may bypass a stalled waiting
/// head. `0` keeps its admission-gate semantics — never wait / window
/// closed.
#[derive(Clone, Copy, Debug)]
struct WaitOpts {
    max_prefix_wait: usize,
    bypass_window: usize,
}

impl WaitOpts {
    fn parse(args: &[String]) -> Result<Self> {
        Ok(WaitOpts {
            max_prefix_wait: parse_flag(
                args,
                "--max-prefix-wait",
                Admission::DEFAULT_MAX_PREFIX_WAIT,
            )?,
            bypass_window: parse_flag(args, "--bypass-window", Admission::DEFAULT_BYPASS_WINDOW)?,
        })
    }
}

/// Soak-mode flags shared by serve/simulate: a wall-clock horizon of
/// regenerating, time-varying traffic instead of a fixed request count.
/// `parse` returns `None` when `--horizon-secs` is absent (and bails if a
/// satellite soak flag was passed without it — running a different
/// experiment than the one asked for must be loud).
#[derive(Clone, Copy, Debug)]
struct SoakCliOpts {
    horizon: f64,
    flush_every: f64,
    /// 0 = no control loop (observe-only soak).
    target_p99_tbt: f64,
    diurnal_amp: f64,
    diurnal_period: f64,
    flash_every: f64,
    flash_dur: f64,
    flash_mult: f64,
    drift_amp: f64,
    drift_period: f64,
    /// Exact nonhomogeneous-Poisson arrivals by thinning instead of the
    /// legacy per-gap rate approximation (satellite of the radix PR; the
    /// old path stays bit-stable as the default).
    exact_arrivals: bool,
}

impl SoakCliOpts {
    fn parse(args: &[String]) -> Result<Option<Self>> {
        let horizon: f64 = parse_flag(args, "--horizon-secs", 0.0)?;
        if horizon <= 0.0 {
            if has_flag(args, "--exact-arrivals") {
                sarathi::bail!("--exact-arrivals is a soak-mode flag and needs --horizon-secs > 0");
            }
            const SOAK_ONLY: [&str; 9] = [
                "--flush-every",
                "--target-p99-tbt",
                "--diurnal-amp",
                "--diurnal-period",
                "--flash-every",
                "--flash-dur",
                "--flash-mult",
                "--drift-amp",
                "--drift-period",
            ];
            if let Some(f) = SOAK_ONLY.into_iter().find(|&f| flag_value(args, f).is_some()) {
                sarathi::bail!("{f} is a soak-mode flag and needs --horizon-secs > 0");
            }
            return Ok(None);
        }
        let o = SoakCliOpts {
            horizon,
            flush_every: parse_flag(args, "--flush-every", 10.0)?,
            target_p99_tbt: parse_flag(args, "--target-p99-tbt", 0.0)?,
            diurnal_amp: parse_flag(args, "--diurnal-amp", 0.0)?,
            diurnal_period: parse_flag(args, "--diurnal-period", 300.0)?,
            flash_every: parse_flag(args, "--flash-every", 0.0)?,
            flash_dur: parse_flag(args, "--flash-dur", 10.0)?,
            flash_mult: parse_flag(args, "--flash-mult", 3.0)?,
            drift_amp: parse_flag(args, "--drift-amp", 0.0)?,
            drift_period: parse_flag(args, "--drift-period", 300.0)?,
            exact_arrivals: has_flag(args, "--exact-arrivals"),
        };
        if o.flush_every <= 0.0 || o.flush_every > o.horizon {
            sarathi::bail!("--flush-every must be in (0, --horizon-secs]");
        }
        if !(0.0..1.0).contains(&o.diurnal_amp) || !(0.0..1.0).contains(&o.drift_amp) {
            sarathi::bail!("--diurnal-amp/--drift-amp are fractions in [0, 1)");
        }
        if o.diurnal_period <= 0.0 || o.drift_period <= 0.0 {
            sarathi::bail!("--diurnal-period/--drift-period must be positive seconds");
        }
        if o.flash_every > 0.0 && !(0.0 < o.flash_dur && o.flash_dur < o.flash_every) {
            sarathi::bail!("--flash-dur must fit inside --flash-every");
        }
        if o.flash_mult < 1.0 {
            sarathi::bail!("--flash-mult must be >= 1 (a flash crowd adds load)");
        }
        if o.target_p99_tbt < 0.0 {
            sarathi::bail!("--target-p99-tbt is a deadline in seconds and must be positive");
        }
        Ok(Some(o))
    }

    /// The regenerating workload this soak run serves.
    fn workload(&self, rate: f64, prefix: &PrefixOpts) -> SoakWorkload {
        let mut curve = RateCurve::steady(rate);
        if self.diurnal_amp > 0.0 {
            curve = curve.with_diurnal(self.diurnal_amp, self.diurnal_period);
        }
        if self.flash_every > 0.0 {
            curve = curve.with_flash(self.flash_every, self.flash_dur, self.flash_mult);
        }
        let mut w = SoakWorkload::new(7, curve).with_lengths((256, 1800), (25, 200));
        if self.drift_amp > 0.0 {
            w = w.with_drift(self.drift_amp, self.drift_period);
        }
        if prefix.share {
            w = w.with_templates(prefix.num_templates, prefix.prefix_len, 0.8);
        }
        if self.exact_arrivals {
            w = w.with_exact_arrivals();
        }
        w
    }
}

/// Drive a configured engine through soak mode and print the report
/// (shared by cost-model serve and single-engine simulate).
#[allow(clippy::too_many_arguments)]
fn run_soak_cli(
    so: &SoakCliOpts,
    engine: &mut Engine,
    cfg: &SchedulerConfig,
    workload: &mut SoakWorkload,
    ttft_slo: Option<f64>,
    tbt_slo: Option<f64>,
    json_out: Option<&Path>,
    trace_out: Option<&Path>,
) -> Result<()> {
    let mut opts = SoakOpts::new(so.horizon, so.flush_every);
    opts.jsonl = json_out.map(Path::to_path_buf);
    opts.progress = true;
    opts.ttft_slo = ttft_slo;
    opts.tbt_slo = tbt_slo;
    if so.target_p99_tbt > 0.0 {
        opts.controller =
            Some(ControllerConfig::new(so.target_p99_tbt, cfg.max_batch, cfg.token_budget));
    }
    let t0 = std::time::Instant::now();
    let rep = run_soak(engine, workload, &opts)?;
    println!(
        "soaked {:.0}s of simulated traffic in {:.2}s wall",
        rep.elapsed,
        t0.elapsed().as_secs_f64()
    );
    println!(
        "arrivals={} completed={} rejected={} iterations={}",
        rep.arrivals, rep.completed, rep.rejected, rep.iterations
    );
    if let (Some(first), Some(last)) = (rep.checkpoints.first(), rep.checkpoints.last()) {
        println!(
            "retained first->last checkpoint: requests {}->{} records {}->{} tbt_samples {}->{}",
            first.retained_requests,
            last.retained_requests,
            first.retained_records,
            last.retained_records,
            first.retained_tbt_samples,
            last.retained_tbt_samples,
        );
    }
    println!(
        "controller_ticks={} controller_adjustments={} final_token_budget={} \
         final_max_prefix_wait={}",
        rep.controller_ticks,
        rep.controller_adjustments,
        rep.final_token_budget,
        rep.final_max_prefix_wait,
    );
    if ttft_slo.is_some() || tbt_slo.is_some() {
        println!("goodput {}/{} = {:.3}", rep.goodput_pass, rep.goodput_total, rep.goodput());
    }
    let pct = |s: &sarathi::util::Summary| (s.percentile(50.0) * 1e3, s.percentile(99.0) * 1e3);
    let (t50, t99) = pct(&rep.ttft);
    println!("ttft_ms p50={t50:.1} p99={t99:.1}");
    let (b50, b99) = pct(&rep.tbt);
    println!("tbt_ms p50={b50:.1} p99={b99:.1}");
    let (n50, n99) = pct(&rep.normalized);
    println!("normalized_latency_ms_per_token p50={n50:.1} p99={n99:.1}");
    if let Some(path) = json_out {
        println!("trace: {} iterations -> {}", rep.jsonl_records, path.display());
        if rep.jsonl_dropped > 0 {
            println!(
                "warning: {} records evicted before the stream drained them \
                 (flush faster or raise the retain cap)",
                rep.jsonl_dropped
            );
        }
    }
    if let Some(path) = trace_out {
        sarathi::report::timeline::write_chrome_trace(path, &rep.events)?;
        println!(
            "timeline: {} events (hw={} dropped={}) -> {}",
            rep.events.len(),
            rep.trace_high_water,
            rep.trace_dropped,
            path.display()
        );
    }
    Ok(())
}

/// `--prefix-share` workload options shared by serve/simulate: template
/// traffic (N shared prefixes, Zipf fanout) instead of fully-unique
/// prompts, with copy-on-write sharing enabled at the admission gate.
#[derive(Clone, Copy, Debug)]
struct PrefixOpts {
    share: bool,
    /// `--workload conversation`: multi-turn conversation-tree traffic
    /// whose requests carry block-granular content paths (unique template
    /// ids — only a radix store can share their ancestor subtrees).
    conversation: bool,
    num_templates: usize,
    prefix_len: usize,
}

impl PrefixOpts {
    fn parse(args: &[String]) -> Result<Self> {
        let workload = flag_value(args, "--workload").unwrap_or_else(|| "unique".to_string());
        let conversation = match workload.as_str() {
            "unique" | "zipf" | "template" => false,
            "conversation" => true,
            other => sarathi::bail!("unknown workload {other} (try: unique, conversation)"),
        };
        let opts = PrefixOpts {
            share: has_flag(args, "--prefix-share"),
            conversation,
            num_templates: parse_flag(args, "--num-templates", 8)?,
            prefix_len: parse_flag(args, "--prefix-len", 256)?,
        };
        if opts.conversation && !opts.share {
            sarathi::bail!(
                "--workload conversation carries content-path prefixes and needs \
                 --prefix-share (radix sharing over the paged block map)"
            );
        }
        if opts.share && opts.num_templates == 0 {
            sarathi::bail!("--num-templates must be at least 1");
        }
        if opts.share && opts.prefix_len == 0 {
            sarathi::bail!("--prefix-len must be at least 1");
        }
        Ok(opts)
    }

    /// The workload: conversation-tree traffic under `--workload
    /// conversation`, template traffic under `--prefix-share`, the classic
    /// Zipf(0.4) population otherwise (identical to the seed behavior).
    /// `block_size` grounds conversation content paths at the paged
    /// store's block granularity.
    fn population(&self, rng: &mut Rng, n: usize, block_size: usize) -> Vec<RequestSpec> {
        if self.conversation {
            let turns = 4;
            let conversations = (n / turns).max(1);
            sarathi::workload::conversation_tree_population(
                rng,
                conversations,
                self.num_templates.max(1),
                self.prefix_len,
                (self.prefix_len / 2).max(1),
                turns,
                32,
                128,
                16,
                64,
                block_size.max(1),
            )
        } else if self.share {
            sarathi::workload::shared_prefix_population(
                rng,
                n,
                self.num_templates,
                0.8,
                self.prefix_len,
                64,
                512,
                10.0,
            )
        } else {
            zipf_population(rng, n, 0.4, 256, 2048, 10.0)
        }
    }

    fn describe(&self) -> String {
        if self.conversation {
            format!(
                "conversation trees ({}-token system prompt, {} branches x {} tokens, \
                 4 turns, unique part in [32,128])",
                self.prefix_len,
                self.num_templates.max(1),
                (self.prefix_len / 2).max(1),
            )
        } else if self.share {
            format!(
                "{} templates x {}-token shared prefixes (Zipf 0.8 fanout), unique part \
                 in [64,512] at P:D=10",
                self.num_templates, self.prefix_len
            )
        } else {
            "Zipf(0.4) in [256,2048], P:D=10".to_string()
        }
    }
}

/// Engine-level simulation at scale: Zipf sequence lengths, Poisson
/// arrivals, paged KV — the production-shaped testbed for the hybrid
/// policy (the §5.3 pipeline cluster comparison is `figures fig12`).
/// `--pp P` switches to the pipeline-parallel simulator over one shared
/// KV pool per replica.
fn cmd_simulate(args: &[String]) -> Result<()> {
    use sarathi::coordinator::SimExecutor;
    use sarathi::costmodel::CostModel;

    let n: usize = parse_flag(args, "--requests", 2000)?;
    let kind = scheduler_kind(args, "hybrid")?;
    let rate: f64 = parse_flag(args, "--rate", 1.5)?;
    if rate <= 0.0 {
        // rng.exp(0) would hand every request a +inf arrival and the run
        // would "succeed" with garbage — simulate is inherently open-loop
        sarathi::bail!("--rate must be positive (simulate is open-loop; serve does closed-loop)");
    }
    let budget: usize = parse_flag(args, "--budget", 256)?;
    let block_size: usize = parse_flag(args, "--block-size", 32)?;
    // 0 = size the paged pool from the deployment's real KV budget; a
    // positive value overrides it (e.g. a deliberately undersized pool for
    // wedge-regression smoke runs)
    let kv_blocks: usize = parse_flag(args, "--kv-blocks", 0)?;
    let pp: usize = parse_flag(args, "--pp", 1)?;
    let replicas: usize = parse_flag(args, "--replicas", 1)?;
    if replicas == 0 {
        sarathi::bail!("--replicas must be at least 1");
    }
    let router_name = flag_value(args, "--router").unwrap_or_else(|| "rr".to_string());
    let router_kind = RouterKind::parse(&router_name)
        .ok_or_else(|| {
            sarathi::err!("unknown router {router_name} (try: rr, jsq, affinity, affinity-hist)")
        })?;
    let spill_factor: f64 = parse_flag(args, "--spill-factor", 1.0)?;
    if spill_factor < 0.0 {
        sarathi::bail!("--spill-factor must be non-negative");
    }
    // 1 = the serial heap-driven loop (default), N > 1 = replica execution
    // over N worker threads, 0 = one worker per available core; every
    // setting produces bitwise-identical results (replicas only sync at
    // dispatch instants), so this is purely a wall-clock knob
    let threads: usize = parse_flag(args, "--threads", 1)?;
    // silently measuring "affinity routing" on a single engine would be
    // worse than an error (same stance as the --prefix-share pairing rule)
    let soaking = flag_value(args, "--horizon-secs").is_some();
    if replicas == 1
        && (flag_value(args, "--router").is_some()
            || flag_value(args, "--spill-factor").is_some()
            || flag_value(args, "--threads").is_some()
            || flag_value(args, "--topology").is_some()
            || flag_value(args, "--prefill-replicas").is_some()
            || flag_value(args, "--interconnect-gbps").is_some()
            // SLO deadlines also gate soak-mode goodput on one engine
            || (!soaking
                && (flag_value(args, "--ttft-slo").is_some()
                    || flag_value(args, "--tbt-slo").is_some())))
    {
        sarathi::bail!(
            "--router/--spill-factor/--threads/--topology/--prefill-replicas/\
             --interconnect-gbps/--ttft-slo/--tbt-slo need --replicas > 1 \
             (they are cluster layers; the SLO flags also apply to soak mode)"
        );
    }
    let topology_name = flag_value(args, "--topology").unwrap_or_else(|| "colocated".to_string());
    let prefill_replicas: usize = parse_flag(args, "--prefill-replicas", replicas.max(2) / 2)?;
    let topology = Topology::parse(&topology_name, prefill_replicas).ok_or_else(|| {
        sarathi::err!("unknown topology {topology_name} (try: colocated, disagg, split)")
    })?;
    // contradictory deployment flags fail loudly rather than silently
    // running a different experiment than the one asked for
    match topology {
        Topology::Disagg { prefill_replicas } => {
            if prefill_replicas == 0 || prefill_replicas >= replicas {
                sarathi::bail!(
                    "--topology disagg needs 1 <= --prefill-replicas < --replicas \
                     (got prefill_replicas={prefill_replicas}, replicas={replicas}); \
                     a cluster with no decode replicas can never emit a token"
                );
            }
        }
        _ => {
            if flag_value(args, "--prefill-replicas").is_some() {
                sarathi::bail!(
                    "--prefill-replicas applies only to --topology disagg \
                     ({topology_name} has no dedicated prefill phase owners)"
                );
            }
        }
    }
    if topology != Topology::Colocated && pp > 1 {
        sarathi::bail!(
            "--topology {topology_name} assigns whole model replicas per phase and \
             requires --pp 1; combine pipeline parallelism with --topology colocated"
        );
    }
    let interconnect_gbps: Option<f64> = match flag_value(args, "--interconnect-gbps") {
        None => None,
        Some(v) => {
            let g: f64 = v
                .parse()
                .map_err(|_| sarathi::err!("invalid value {v:?} for --interconnect-gbps"))?;
            if g <= 0.0 {
                sarathi::bail!("--interconnect-gbps must be positive (KV bytes must move)");
            }
            Some(g)
        }
    };
    let ttft_slo: f64 = parse_flag(args, "--ttft-slo", 1.0)?;
    let tbt_slo: f64 = parse_flag(args, "--tbt-slo", 0.2)?;
    if ttft_slo <= 0.0 || tbt_slo <= 0.0 {
        sarathi::bail!("--ttft-slo and --tbt-slo are deadlines in seconds and must be positive");
    }
    let preemption = preemption_mode(args)?;
    let json_out = flag_value(args, "--json-out").map(PathBuf::from);
    let trace_out = flag_value(args, "--trace-out").map(PathBuf::from);
    let prefix = PrefixOpts::parse(args)?;
    let wait = WaitOpts::parse(args)?;
    if prefix.share && !(kind == SchedulerKind::Hybrid && block_size > 0) {
        sarathi::bail!(
            "--prefix-share requires --scheduler hybrid with --block-size > 0 \
             (sharing lives on the paged block map)"
        );
    }
    let soak = SoakCliOpts::parse(args)?;
    if let Some(so) = &soak {
        if replicas > 1 || pp > 1 {
            sarathi::bail!(
                "--horizon-secs drives one engine; soak mode needs --replicas 1 and --pp 1"
            );
        }
        if flag_value(args, "--requests").is_some() {
            sarathi::bail!("--requests and --horizon-secs are different stopping rules; pick one");
        }
        if so.target_p99_tbt > 0.0 && kind != SchedulerKind::Hybrid {
            sarathi::bail!(
                "--target-p99-tbt adapts the hybrid token budget; use --scheduler hybrid"
            );
        }
    }

    if replicas > 1 {
        return simulate_cluster(SimOpts {
            n,
            kind,
            rate,
            budget,
            block_size,
            kv_blocks,
            pp,
            replicas,
            router_kind,
            spill_factor,
            threads,
            topology,
            interconnect_gbps,
            ttft_slo,
            tbt_slo,
            preemption,
            prefix,
            wait,
            json_out,
            trace_out,
        });
    }
    if pp > 1 {
        return simulate_pipeline(
            n, kind, rate, budget, block_size, kv_blocks, pp, preemption, prefix, wait, json_out,
            trace_out,
        );
    }

    let d = Deployment::new(ModelConfig::llama13b(), GpuConfig::a6000(), 2048);
    let b = d.max_batch_size();
    let mut rng = Rng::new(7);
    let pop = prefix.population(&mut rng, n, block_size);
    let pop = with_poisson_arrivals(&mut rng, pop, rate);

    // slot policies get the §4.3.1 worst-case slots; the hybrid policy gets
    // the same memory as a paged block pool (or the --kv-blocks override)
    let paged = kind == SchedulerKind::Hybrid && block_size > 0;
    let kv = if paged {
        let blocks = if kv_blocks > 0 { kv_blocks } else { d.kv_blocks(block_size) };
        KvManager::paged(blocks, block_size)
    } else {
        KvManager::new(b)
    };
    let cfg = SchedulerConfig {
        kind,
        chunk_size: 256,
        tile_align: 128,
        max_batch: if paged { 4 * b } else { b },
        token_budget: budget.max(4 * b),
        block_size: if paged { block_size } else { 0 },
        watermark_blocks: if paged { 2 } else { 0 },
        preemption,
        reject_infeasible: true,
        prefix_share: prefix.share,
        max_prefix_wait: wait.max_prefix_wait,
        bypass_window: wait.bypass_window,
    };

    if let Some(so) = &soak {
        println!(
            "LLaMA-13B on A6000: soak horizon={}s flush={}s, base rate {rate} req/s, \
             scheduler={} effective_token_budget={} {}",
            so.horizon,
            so.flush_every,
            kind.name(),
            cfg.token_budget,
            if paged {
                format!("(paged KV: {} blocks x {block_size} tokens)", kv.capacity())
            } else {
                format!("(slot KV: B={b})")
            }
        );
        let mut engine = Engine::new(
            RequestPool::new(),
            kv,
            make_scheduler(&cfg),
            Box::new(SimExecutor::new(CostModel::for_deployment(&d))),
        )
        .with_swap_cost(SwapCost::for_deployment(&d, preemption));
        if trace_out.is_some() {
            // the soak loop drains this ring every flush window, so the
            // footprint stays bounded even over long horizons
            engine.pool.trace = TraceSink::enabled(CLI_TRACE_CAP);
        }
        let mut w = so.workload(rate, &prefix);
        // SLO deadlines gate goodput only when explicitly asked for
        let ttft = flag_value(args, "--ttft-slo").is_some().then_some(ttft_slo);
        let tbt = flag_value(args, "--tbt-slo").is_some().then_some(tbt_slo);
        return run_soak_cli(
            so,
            &mut engine,
            &cfg,
            &mut w,
            ttft,
            tbt,
            json_out.as_deref(),
            trace_out.as_deref(),
        );
    }

    println!(
        "LLaMA-13B on A6000: {n} requests, {}, Poisson {rate} req/s, \
         scheduler={} effective_token_budget={} {}",
        prefix.describe(),
        kind.name(),
        cfg.token_budget,
        if paged {
            format!("(paged KV: {} blocks x {block_size} tokens)", kv.capacity())
        } else {
            format!("(slot KV: B={b})")
        }
    );
    let t0 = std::time::Instant::now();
    // the sink must be live BEFORE requests are pushed so arrival events
    // are captured; an untraced run keeps the zero-cost disabled sink
    let mut pool = RequestPool::new();
    if trace_out.is_some() {
        pool.trace = TraceSink::enabled(CLI_TRACE_CAP);
    }
    for s in &pop {
        pool.push(s.clone());
    }
    let mut engine = Engine::new(
        pool,
        kv,
        make_scheduler(&cfg),
        Box::new(SimExecutor::new(CostModel::for_deployment(&d))),
    )
    .with_swap_cost(SwapCost::for_deployment(&d, preemption));
    engine.run();
    println!("simulated in {:.2}s wall", t0.elapsed().as_secs_f64());
    if let Some(path) = &trace_out {
        let events = engine.pool.trace.drain();
        let bds = sarathi::coordinator::trace::breakdowns_from_pools(
            std::slice::from_ref(&engine.pool),
            &engine.applier.swap,
            None,
        );
        println!("{}", sarathi::coordinator::trace::breakdown_summary(&bds));
        sarathi::report::timeline::write_chrome_trace(path, &events)?;
        println!(
            "timeline: {} events ({} dropped) -> {}",
            events.len(),
            engine.pool.trace.dropped(),
            path.display()
        );
    }
    report_run(&engine, json_out.as_deref())
}

/// Pipeline-mode simulate: LLaMA-13B split across `pp` stages, `pp`
/// micro-batch streams over ONE shared per-replica KV pool — paged when
/// the hybrid policy runs with `--block-size N`, the seed's degenerate
/// slots otherwise. Preemption swaps are priced at the GPU's host (PCIe)
/// bandwidth and show up in the report and the JSONL trace.
#[allow(clippy::too_many_arguments)]
fn simulate_pipeline(
    n: usize,
    kind: SchedulerKind,
    rate: f64,
    budget: usize,
    block_size: usize,
    kv_blocks: usize,
    pp: usize,
    preemption: PreemptionMode,
    prefix: PrefixOpts,
    wait: WaitOpts,
    json_out: Option<PathBuf>,
    trace_out: Option<PathBuf>,
) -> Result<()> {
    use sarathi::costmodel::CostModel;
    use sarathi::profiler::Profiler;

    let model = ModelConfig::llama13b();
    if model.n_layers % pp != 0 {
        sarathi::bail!("--pp {pp} must divide {} layers", model.n_layers);
    }
    let d = Deployment::new(model, GpuConfig::a6000(), 2048)
        .with_parallel(ParallelConfig::tp_pp(1, pp));
    let b = d.max_batch_size();
    let mut rng = Rng::new(7);
    let pop = prefix.population(&mut rng, n, block_size);
    let pop = with_poisson_arrivals(&mut rng, pop, rate);

    let paged = kind == SchedulerKind::Hybrid && block_size > 0;
    let kv = if paged {
        let blocks = if kv_blocks > 0 { kv_blocks } else { d.kv_blocks(block_size) };
        KvManager::paged(blocks, block_size)
    } else {
        // degenerate: the seed's per-stream slot capacity, one shared pool
        KvManager::new(pp * b)
    };
    let cfg = SchedulerConfig {
        kind,
        chunk_size: 256,
        tile_align: 128,
        max_batch: b,
        token_budget: budget.max(2 * b),
        block_size: if paged { block_size } else { 0 },
        watermark_blocks: if paged { 2 } else { 0 },
        preemption,
        reject_infeasible: true,
        prefix_share: prefix.share,
        max_prefix_wait: wait.max_prefix_wait,
        bypass_window: wait.bypass_window,
    };
    println!(
        "LLaMA-13B on A6000, PP={pp}: {n} requests, {}, Poisson {rate} req/s, \
         scheduler={} effective_token_budget={} {}",
        prefix.describe(),
        kind.name(),
        cfg.token_budget,
        if paged {
            format!("(shared paged KV: {} blocks x {block_size} tokens)", kv.capacity())
        } else {
            format!("(shared slot KV: {} slots, {} per stream)", pp * b, b)
        }
    );

    let profiler = Profiler::build(CostModel::for_deployment(&d), d.max_seq_len, b + 1);
    let sim = PipelineSim::new(profiler, pp)
        .with_swap_cost(SwapCost::for_deployment(&d, preemption));
    let t0 = std::time::Instant::now();
    let trace_cap = trace_out.as_ref().map(|_| CLI_TRACE_CAP);
    let res = sim.run_shared_traced(&pop, kv, Some(b), || make_scheduler(&cfg), trace_cap);
    println!("simulated in {:.2}s wall", t0.elapsed().as_secs_f64());

    let bubbles = res.bubble_summary();
    println!(
        "makespan={:.2}s micro_batches={} utilization={:.3} preemptions={} rejections={} \
         swap_time={:.3}s prefix_hits={} prefix_partial_hits={} partial_hit_tokens={} \
         prefix_fallbacks={} prefix_wait_iters={} peak_shared_kv_tokens={}",
        res.makespan,
        res.micro_batches,
        res.utilization(),
        res.metrics.preemptions,
        res.metrics.rejections,
        res.metrics.total_swap_time(),
        res.metrics.prefix_hits,
        res.metrics.prefix_partial_hits,
        res.metrics.prefix_partial_hit_tokens,
        res.metrics.prefix_fallbacks,
        res.metrics.prefix_wait_iterations,
        res.metrics.peak_shared_kv_tokens(),
    );
    println!(
        "bubble_per_request_s p50={:.3} p99={:.3} total_bubble={:.2}s",
        bubbles.percentile(50.0),
        bubbles.percentile(99.0),
        res.total_bubble,
    );
    if let Some(path) = &trace_out {
        println!("{}", sarathi::coordinator::trace::breakdown_summary(&res.breakdowns));
        sarathi::report::timeline::write_chrome_trace(path, &res.events)?;
        println!("timeline: {} events -> {}", res.events.len(), path.display());
    }
    report_latency(&res.latency, &res.metrics, json_out.as_deref())
}

/// Options bundle for the cluster-mode simulate (keeps the argument list
/// within clippy's bounds and the call site readable).
struct SimOpts {
    n: usize,
    kind: SchedulerKind,
    rate: f64,
    budget: usize,
    block_size: usize,
    kv_blocks: usize,
    pp: usize,
    replicas: usize,
    router_kind: RouterKind,
    spill_factor: f64,
    threads: usize,
    topology: Topology,
    interconnect_gbps: Option<f64>,
    ttft_slo: f64,
    tbt_slo: f64,
    preemption: PreemptionMode,
    prefix: PrefixOpts,
    wait: WaitOpts,
    json_out: Option<PathBuf>,
    trace_out: Option<PathBuf>,
}

/// Cluster-mode simulate: `replicas` identical PP=`pp` LLaMA-13B replica
/// groups behind a request router. Requests are dispatched one at a time
/// in arrival order by the chosen policy over every replica's cache-aware
/// outstanding work; each replica runs the same scheduler stack as the
/// pipeline path over its own shared KV pool. Template traffic arrives in
/// per-template bursts (the temporal locality a prefix-affinity router
/// exploits); untagged traffic degenerates to the plain Poisson process.
fn simulate_cluster(o: SimOpts) -> Result<()> {
    use sarathi::workload::with_template_burst_arrivals;

    let SimOpts {
        n,
        kind,
        rate,
        budget,
        block_size,
        kv_blocks,
        pp,
        replicas,
        router_kind,
        spill_factor,
        threads,
        topology,
        interconnect_gbps,
        ttft_slo,
        tbt_slo,
        preemption,
        prefix,
        wait,
        json_out,
        trace_out,
    } = o;
    let model = ModelConfig::llama13b();
    if model.n_layers % pp != 0 {
        sarathi::bail!("--pp {pp} must divide {} layers", model.n_layers);
    }
    let mut gpu = GpuConfig::a6000();
    if let Some(gbps) = interconnect_gbps {
        gpu.interconnect_gbps = gbps;
    }
    let d = Deployment::new(model, gpu, 2048)
        .with_parallel(ParallelConfig::tp_pp(1, pp).with_replicas(replicas));
    let b = d.max_batch_size();
    let mut rng = Rng::new(7);
    let pop = prefix.population(&mut rng, n, block_size);
    let pop = with_template_burst_arrivals(&mut rng, pop, rate, 6);

    let paged = kind == SchedulerKind::Hybrid && block_size > 0;
    let cfg = SchedulerConfig {
        kind,
        chunk_size: 256,
        tile_align: 128,
        max_batch: b,
        token_budget: budget.max(2 * b),
        block_size: if paged { block_size } else { 0 },
        watermark_blocks: if paged { 2 } else { 0 },
        preemption,
        reject_infeasible: true,
        prefix_share: prefix.share,
        max_prefix_wait: wait.max_prefix_wait,
        bypass_window: wait.bypass_window,
    };
    let blocks = if kv_blocks > 0 { kv_blocks } else { d.kv_blocks(block_size.max(1)) };
    println!(
        "LLaMA-13B on A6000, {replicas} replicas x PP={pp}, topology={}: {n} requests, {}, \
         Poisson {rate} req/s (template bursts of 6), router={} spill_factor={spill_factor} \
         threads={threads} scheduler={} effective_token_budget={} {}",
        topology.name(),
        prefix.describe(),
        router_kind.name(),
        kind.name(),
        cfg.token_budget,
        if paged {
            format!("(per-replica paged KV: {blocks} blocks x {block_size} tokens)")
        } else {
            format!("(per-replica slot KV: {} slots)", pp.max(1) * b)
        }
    );

    let mut cluster =
        ClusterSim::new(d.clone()).with_swap_cost(SwapCost::for_deployment(&d, preemption));
    if trace_out.is_some() {
        cluster = cluster.with_trace_cap(CLI_TRACE_CAP);
    }
    let mut router = router_kind.build(spill_factor);
    let t0 = std::time::Instant::now();
    let res = cluster.run_topology(
        topology,
        &pop,
        &mut *router,
        || {
            if paged {
                KvManager::paged(blocks, block_size)
            } else {
                KvManager::new(pp.max(1) * b)
            }
        },
        Some(b),
        || make_scheduler(&cfg),
        threads,
    );
    println!("simulated in {:.2}s wall", t0.elapsed().as_secs_f64());

    let rejections: usize = res.per_replica.iter().map(|r| r.metrics.rejections).sum();
    println!(
        "makespan={:.2}s micro_batches={} preemptions={} rejections={rejections} \
         swap_time={:.3}s",
        res.makespan,
        res.total_iterations(),
        res.preemptions(),
        res.total_swap_time(),
    );
    println!(
        "prefix_hits={} prefix_hit_rate={:.3} prefix_fallbacks={} load_imbalance={:.3}",
        res.prefix_hits(),
        res.prefix_hit_rate(),
        res.prefix_fallbacks(),
        res.load_imbalance(),
    );
    let partial_hits: usize =
        res.per_replica.iter().map(|r| r.metrics.prefix_partial_hits).sum();
    let partial_tokens: usize =
        res.per_replica.iter().map(|r| r.metrics.prefix_partial_hit_tokens).sum();
    println!(
        "prefix_partial_hits={partial_hits} partial_hit_tokens={partial_tokens} \
         mean_hit_depth_tokens={:.1}",
        if partial_hits > 0 { partial_tokens as f64 / partial_hits as f64 } else { 0.0 },
    );
    println!(
        "per_replica peak_kv_blocks={:?} mean_outstanding_tokens={:?}",
        res.peak_kv_blocks_per_replica(),
        res.mean_outstanding.iter().map(|x| x.round() as i64).collect::<Vec<_>>(),
    );
    println!(
        "per_replica bubble_s={:?}",
        res.replica_bubbles().iter().map(|b| (b * 1e3).round() / 1e3).collect::<Vec<_>>(),
    );
    let lat = res.latency();
    let pct = |s: &sarathi::util::Summary| (s.percentile(50.0) * 1e3, s.percentile(99.0) * 1e3);
    let (t50, t99) = pct(&lat.ttft);
    println!("ttft_ms p50={t50:.1} p99={t99:.1}");
    let (b50, b99) = pct(&lat.tbt);
    println!("tbt_ms p50={b50:.1} p99={b99:.1}");
    let (n50, n99) = pct(&lat.normalized);
    println!("normalized_latency_ms_per_token p50={n50:.1} p99={n99:.1}");
    if lat.prefix_wait.count() > 0 {
        let (w50, w99) = pct(&lat.prefix_wait);
        println!("prefix_wait_ms p50={w50:.1} p99={w99:.1} waiters={}", lat.prefix_wait.count());
    }
    let (frac, gput) = res.goodput(ttft_slo, tbt_slo);
    println!(
        "goodput ttft_slo={ttft_slo:.3}s tbt_slo={tbt_slo:.3}s attained_frac={frac:.3} \
         rate={gput:.3} req/s"
    );
    if let Some(fabric) = &res.fabric {
        if fabric.records.is_empty() {
            println!("kv_transfers=0 (handoffs stayed on-device; the fabric moved no bytes)");
        } else {
            let mut times = sarathi::util::Summary::new();
            let mut bytes = 0.0;
            for rec in &fabric.records {
                times.add(rec.kv_transfer_time());
                bytes += rec.bytes;
            }
            println!(
                "kv_transfers={} transfer_bytes={bytes:.3e} transfer_busy={:.3}s \
                 stream_utilization={:.3} conserved={} kv_transfer_time_ms p50={:.1} p99={:.1}",
                fabric.records.len(),
                fabric.busy_time(),
                fabric.utilization(res.makespan),
                fabric.is_conserved(),
                times.percentile(50.0) * 1e3,
                times.percentile(99.0) * 1e3,
            );
        }
    }
    if let Some(path) = &trace_out {
        println!("{}", sarathi::coordinator::trace::breakdown_summary(&res.breakdowns));
        sarathi::report::timeline::write_chrome_trace(path, &res.events)?;
        println!("timeline: {} events -> {}", res.events.len(), path.display());
    }
    if let Some(path) = json_out {
        res.write_jsonl(&path)?;
        println!("trace: {} replica-tagged records -> {}", res.total_iterations(), path.display());
    }
    Ok(())
}

fn cmd_calibration() -> Result<()> {
    use sarathi::costmodel::{BatchShape, CostModel};
    for (m, g) in [
        (ModelConfig::llama13b(), GpuConfig::a6000()),
        (ModelConfig::llama33b(), GpuConfig::a100()),
        (ModelConfig::gpt3(), GpuConfig::a100()),
    ] {
        let cm = CostModel::new(m.clone(), g.clone());
        let prefill = cm.iteration_time(&BatchShape::prefill_only(&[(1024, 0)])) / 1024.0;
        let decode = cm.iteration_time(&BatchShape::decode_only(&[1024]));
        println!(
            "{:<12} on {:<6}: prefill {:.3} ms/tok  decode(B=1) {:.2} ms/tok  ratio {:>5.0}x  saturation {} tok",
            m.name,
            g.name,
            prefill * 1e3,
            decode * 1e3,
            decode / prefill,
            cm.saturation_tokens(),
        );
    }
    Ok(())
}
