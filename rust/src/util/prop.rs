//! Miniature property-testing driver (proptest is unavailable offline).
//!
//! `check(name, cases, f)` runs `f` against `cases` deterministic seeds; on
//! failure it performs a simple halving shrink over the seed-derived size
//! hint and panics with the seed so the case can be replayed exactly.

use super::rng::Rng;

/// A generated test case: the PRNG plus a size hint the generator may use to
/// scale structure sizes. Shrinking lowers `size` first.
pub struct Case {
    pub rng: Rng,
    pub size: usize,
    pub seed: u64,
}

/// Run `f` for `cases` generated cases. `f` returns Err(msg) on property
/// violation; panics with the failing seed (after shrinking the size hint).
pub fn check<F>(name: &str, cases: usize, mut f: F)
where
    F: FnMut(&mut Case) -> Result<(), String>,
{
    for i in 0..cases {
        let seed = 0x5EED_0000 + i as u64;
        let size = 1 + (i % 50);
        let mut case = Case { rng: Rng::new(seed), size, seed };
        if let Err(msg) = f(&mut case) {
            // shrink: retry with progressively smaller size hints to report
            // the smallest reproduction.
            let mut best = (size, msg);
            let mut s = size / 2;
            while s >= 1 {
                let mut c = Case { rng: Rng::new(seed), size: s, seed };
                match f(&mut c) {
                    Err(m) => {
                        best = (s, m);
                        if s == 1 {
                            break;
                        }
                        s /= 2;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property '{name}' failed (seed={seed:#x}, size={}): {}",
                best.0, best.1
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("trivially true", 25, |_| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 25);
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_seed() {
        check("always fails", 5, |_| Err("boom".into()));
    }

    #[test]
    fn case_rng_is_deterministic_per_seed() {
        let mut first = Vec::new();
        check("collect", 3, |c| {
            first.push(c.rng.next_u64());
            Ok(())
        });
        let mut second = Vec::new();
        check("collect", 3, |c| {
            second.push(c.rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
