//! Statistics helpers used by the metrics collector and the figure harness.
//!
//! [`Summary`] is **bounded-memory**: it keeps raw samples (exact
//! percentiles, bitwise-identical to [`percentile`] over the same data)
//! only up to [`Summary::EXACT_CAP`]; past that it degrades to a
//! log-linear quantile sketch with ~1% relative error and O(1) memory —
//! the difference between a soak run whose latency summaries grow without
//! bound and one that holds steady for hours. Count / sum / min / max are
//! always exact (streamed), so means and extrema never degrade.

use std::sync::OnceLock;

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Linear-interpolated percentile over an ALREADY SORTED slice — the one
/// shared interpolation so [`percentile`] and the exact [`Summary`] path
/// are bitwise-identical by construction.
fn percentile_sorted(v: &[f64], p: f64) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

/// Linear-interpolated percentile (p in [0, 100]) over a copy of the data.
/// One-shot convenience; report paths querying several percentiles of the
/// same data should use a [`Summary`], whose sort is cached across calls.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(f64::total_cmp);
    percentile_sorted(&v, p)
}

// Log-linear sketch geometry (fixed constants so any two sketches merge
// bucket-for-bucket): buckets cover [1e-9, 1e9) seconds with ratio
// gamma = 1.01 — ceil(ln(1e18)/ln(1.01)) buckets, ≤0.5% representative
// error at the geometric bucket midpoint.
const SKETCH_MIN: f64 = 1e-9;
const SKETCH_MIN_LN: f64 = -20.72326583694641; // ln(1e-9)
const GAMMA_LN: f64 = 0.009_950_330_853_155_723; // ln(1.01)
const N_BUCKETS: usize = 4166;

#[derive(Clone, Debug)]
struct Sketch {
    /// Samples ≤ [`SKETCH_MIN`] (zero gaps, underflow) or non-finite.
    under: u64,
    buckets: Vec<u64>,
}

impl Sketch {
    fn new() -> Self {
        Sketch { under: 0, buckets: vec![0; N_BUCKETS] }
    }

    fn add(&mut self, x: f64) {
        if !(x > SKETCH_MIN) {
            self.under += 1;
            return;
        }
        let idx = ((x.ln() - SKETCH_MIN_LN) / GAMMA_LN) as usize;
        self.buckets[idx.min(N_BUCKETS - 1)] += 1;
    }

    fn absorb(&mut self, other: &Sketch) {
        self.under += other.under;
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += *b;
        }
    }

    /// Geometric midpoint of bucket `i` — the representative value.
    fn rep(i: usize) -> f64 {
        (SKETCH_MIN_LN + (i as f64 + 0.5) * GAMMA_LN).exp()
    }

    /// Value at rank `r` (0-based, fractional ranks floor to the bucket
    /// containing them), clamped to the exact [min, max] envelope.
    fn value_at_rank(&self, r: f64, min: f64, max: f64) -> f64 {
        let target = r.max(0.0) as u64;
        let mut cum = self.under;
        if target < cum {
            return min;
        }
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if target < cum {
                return Self::rep(i).clamp(min, max);
            }
        }
        max
    }

    /// (value, cumulative fraction) per non-empty bucket.
    fn cdf(&self, total: u64, min: f64, max: f64) -> Vec<(f64, f64)> {
        let mut out = Vec::new();
        let mut cum = 0u64;
        if self.under > 0 {
            cum += self.under;
            out.push((min, cum as f64 / total as f64));
        }
        for (i, &c) in self.buckets.iter().enumerate() {
            if c > 0 {
                cum += c;
                out.push((Self::rep(i).clamp(min, max), cum as f64 / total as f64));
            }
        }
        out
    }
}

/// Streaming summary (count / sum / mean / min / max, always exact) plus
/// percentile support: raw samples up to [`Summary::EXACT_CAP`] (bitwise
/// match with [`percentile`]), a log-linear sketch beyond it. The sort
/// backing percentile queries is computed once and cached until the next
/// mutation, so report paths asking p50 + p99 back-to-back sort once.
#[derive(Clone, Debug)]
pub struct Summary {
    samples: Vec<f64>,
    /// Lazily-sorted copy of `samples`; invalidated by add/merge.
    sorted: OnceLock<Vec<f64>>,
    count: usize,
    sum: f64,
    min: f64,
    max: f64,
    sketch: Option<Box<Sketch>>,
}

impl Default for Summary {
    fn default() -> Self {
        Summary {
            samples: Vec::new(),
            sorted: OnceLock::new(),
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            sketch: None,
        }
    }
}

impl Summary {
    /// Raw samples retained before the sketch takes over. Large enough
    /// that every closed-loop experiment's percentile pins stay exact
    /// (and bitwise-stable); small enough to bound a soak run.
    pub const EXACT_CAP: usize = 8192;

    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        self.sorted.take();
        match &mut self.sketch {
            Some(s) => s.add(x),
            None => {
                self.samples.push(x);
                if self.samples.len() > Self::EXACT_CAP {
                    self.spill_to_sketch();
                }
            }
        }
    }

    /// Move every retained sample into the sketch and drop the raw vec.
    fn spill_to_sketch(&mut self) {
        let mut s = Box::new(Sketch::new());
        for &x in &self.samples {
            s.add(x);
        }
        self.samples = Vec::new();
        self.sketch = Some(s);
    }

    pub fn count(&self) -> usize {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Smallest sample; 0.0 when empty (like [`mean`](Self::mean) and
    /// [`percentile`](Self::percentile) — ±inf must never leak into a
    /// printed report).
    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Largest sample; 0.0 when empty.
    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        if p <= 0.0 {
            return self.min;
        }
        if p >= 100.0 {
            return self.max;
        }
        match &self.sketch {
            Some(s) => {
                let rank = (p / 100.0) * (self.count - 1) as f64;
                s.value_at_rank(rank, self.min, self.max)
            }
            None => percentile_sorted(self.sorted_samples(), p),
        }
    }

    fn sorted_samples(&self) -> &[f64] {
        self.sorted.get_or_init(|| {
            let mut v = self.samples.clone();
            v.sort_by(f64::total_cmp);
            v
        })
    }

    /// Retained raw samples — the full data while in exact mode, EMPTY
    /// once the sketch has taken over (callers needing raw data must stay
    /// under [`EXACT_CAP`](Self::EXACT_CAP)).
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    /// Raw samples currently held in memory (the soak leak-detector's
    /// counter: flat between checkpoints once the sketch engages).
    pub fn retained_samples(&self) -> usize {
        self.samples.len()
    }

    /// True once the summary has spilled to the bounded sketch.
    pub fn is_sketched(&self) -> bool {
        self.sketch.is_some()
    }

    /// Fold another summary's samples into this one (cross-replica
    /// latency aggregation). Exact while the combined count fits
    /// [`EXACT_CAP`](Self::EXACT_CAP); sketched beyond it.
    pub fn merge(&mut self, other: &Summary) {
        if other.count == 0 {
            return;
        }
        self.sorted.take();
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        let fits_exact = self.sketch.is_none()
            && other.sketch.is_none()
            && self.samples.len() + other.samples.len() <= Self::EXACT_CAP;
        if fits_exact {
            self.samples.extend_from_slice(&other.samples);
            return;
        }
        if self.sketch.is_none() {
            self.spill_to_sketch();
        }
        let s = self.sketch.as_mut().unwrap();
        match &other.sketch {
            Some(o) => s.absorb(o),
            None => {
                for &x in &other.samples {
                    s.add(x);
                }
            }
        }
    }

    /// Empirical CDF as (value, fraction<=value) points, for Fig-12a-style
    /// plots. Exact per-sample points in exact mode; one point per
    /// non-empty bucket once sketched.
    pub fn cdf(&self) -> Vec<(f64, f64)> {
        match &self.sketch {
            Some(s) => s.cdf(self.count as u64, self.min, self.max),
            None => {
                let v = self.sorted_samples();
                let n = v.len() as f64;
                v.iter().enumerate().map(|(i, &x)| (x, (i + 1) as f64 / n)).collect()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_unsorted_input() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_cdf_monotone() {
        let mut s = Summary::new();
        for x in [5.0, 1.0, 3.0] {
            s.add(x);
        }
        let cdf = s.cdf();
        assert_eq!(cdf.len(), 3);
        assert!(cdf.windows(2).all(|w| w[0].0 <= w[1].0 && w[0].1 < w[1].1));
        assert_eq!(cdf.last().unwrap().1, 1.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
    }

    /// Satellite regression: an EMPTY summary used to report min = +inf
    /// and max = −inf, leaking `inf` into printed reports. All aggregate
    /// queries now agree on 0.0 for no data.
    #[test]
    fn empty_summary_reports_zero_not_infinity() {
        let s = Summary::new();
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 0.0);
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.percentile(50.0), 0.0);
        assert_eq!(s.sum(), 0.0);
        assert!(s.min().is_finite() && s.max().is_finite());
    }

    /// The exact path must be BITWISE identical to the free-function
    /// percentile over the same data — the pin that keeps every existing
    /// closed-loop percentile reproducible across the bounded-memory
    /// rework.
    #[test]
    fn exact_path_is_bitwise_identical_to_free_percentile() {
        let mut s = Summary::new();
        let mut xs = Vec::new();
        let mut v: u64 = 0x9E3779B97F4A7C15;
        for _ in 0..1000 {
            v = v.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let x = (v >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
            xs.push(x);
            s.add(x);
        }
        for p in [0.0, 1.0, 25.0, 50.0, 90.0, 99.0, 99.9, 100.0] {
            assert_eq!(
                s.percentile(p).to_bits(),
                percentile(&xs, p).to_bits(),
                "p{p} diverged from the exact reference"
            );
        }
        assert!(!s.is_sketched());
        assert_eq!(s.retained_samples(), 1000);
    }

    /// Percentile queries cache the sort; a mutation after a query must
    /// invalidate the cache, not serve stale order.
    #[test]
    fn sort_cache_invalidates_on_mutation() {
        let mut s = Summary::new();
        for x in [3.0, 1.0, 2.0] {
            s.add(x);
        }
        assert_eq!(s.percentile(100.0), 3.0);
        s.add(10.0);
        assert_eq!(s.percentile(100.0), 10.0);
        assert!((s.percentile(50.0) - 2.5).abs() < 1e-12);
        let mut other = Summary::new();
        other.add(0.5);
        s.merge(&other);
        assert_eq!(s.percentile(0.0), 0.5);
    }

    /// Past the cap the summary spills to the sketch: memory stops
    /// growing, extrema/mean stay exact, percentiles hold ~1% relative
    /// error.
    #[test]
    fn sketch_bounds_memory_and_keeps_percentiles_close() {
        let mut s = Summary::new();
        let n = 3 * Summary::EXACT_CAP;
        let mut xs = Vec::with_capacity(n);
        let mut v: u64 = 42;
        for _ in 0..n {
            v = v.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            // spread over several decades, like latency samples
            let u = (v >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
            let x = 1e-4 * (u * 9.0f64.ln()).exp();
            xs.push(x);
            s.add(x);
        }
        assert!(s.is_sketched());
        assert_eq!(s.retained_samples(), 0, "raw samples are dropped after the spill");
        assert_eq!(s.count(), n);
        let exact_min = xs.iter().copied().fold(f64::INFINITY, f64::min);
        let exact_max = xs.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        assert_eq!(s.min().to_bits(), exact_min.to_bits(), "min stays exact");
        assert_eq!(s.max().to_bits(), exact_max.to_bits(), "max stays exact");
        assert!((s.mean() - mean(&xs)).abs() < 1e-12 * mean(&xs).abs().max(1.0));
        for p in [50.0, 90.0, 99.0] {
            let exact = percentile(&xs, p);
            let approx = s.percentile(p);
            let rel = (approx - exact).abs() / exact;
            assert!(rel < 0.02, "p{p}: sketch {approx} vs exact {exact} (rel {rel})");
        }
        assert_eq!(s.percentile(0.0), s.min());
        assert_eq!(s.percentile(100.0), s.max());
        let cdf = s.cdf();
        assert!(cdf.len() <= N_BUCKETS + 1, "cdf is bucket-bounded");
        assert!(cdf.windows(2).all(|w| w[0].0 <= w[1].0 && w[0].1 <= w[1].1));
        assert!((cdf.last().unwrap().1 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn merge_spills_when_the_combined_count_exceeds_the_cap() {
        let mut a = Summary::new();
        let mut b = Summary::new();
        for i in 0..Summary::EXACT_CAP {
            a.add(i as f64 * 1e-3 + 1e-3);
            b.add(i as f64 * 1e-3 + 1e-3);
        }
        assert!(!a.is_sketched() && !b.is_sketched());
        a.merge(&b);
        assert!(a.is_sketched(), "combined count exceeds the cap");
        assert_eq!(a.count(), 2 * Summary::EXACT_CAP);
        assert_eq!(a.min(), 1e-3);
        let p50 = a.percentile(50.0);
        let expect = Summary::EXACT_CAP as f64 / 2.0 * 1e-3;
        assert!((p50 - expect).abs() / expect < 0.02, "{p50} vs {expect}");
        // sketched + exact merge keeps counting
        let mut c = Summary::new();
        c.add(5.0);
        a.merge(&c);
        assert_eq!(a.count(), 2 * Summary::EXACT_CAP + 1);
        assert_eq!(a.max(), 5.0);
    }

    #[test]
    fn merge_of_exact_summaries_stays_exact_under_the_cap() {
        let mut a = Summary::new();
        let mut b = Summary::new();
        for x in [1.0, 3.0] {
            a.add(x);
        }
        for x in [2.0, 4.0] {
            b.add(x);
        }
        a.merge(&b);
        assert!(!a.is_sketched());
        assert_eq!(a.count(), 4);
        assert_eq!(a.samples().len(), 4);
        assert!((a.percentile(50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn sketch_handles_underflow_and_zero_samples() {
        let mut s = Summary::new();
        for _ in 0..=Summary::EXACT_CAP {
            s.add(0.0);
        }
        assert!(s.is_sketched());
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.percentile(50.0), 0.0, "underflow bucket reports the exact min");
    }
}
