//! Statistics helpers used by the metrics collector and the figure harness.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Linear-interpolated percentile (p in [0, 100]) over a copy of the data.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

/// Streaming summary (count / mean / min / max) plus retained samples for
/// percentile queries.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn sum(&self) -> f64 {
        self.samples.iter().sum()
    }

    pub fn mean(&self) -> f64 {
        mean(&self.samples)
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn percentile(&self, p: f64) -> f64 {
        percentile(&self.samples, p)
    }

    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    /// Fold another summary's samples into this one (cross-replica
    /// latency aggregation).
    pub fn merge(&mut self, other: &Summary) {
        self.samples.extend_from_slice(&other.samples);
    }

    /// Empirical CDF as (value, fraction<=value) points, for Fig-12a-style
    /// plots.
    pub fn cdf(&self) -> Vec<(f64, f64)> {
        let mut v = self.samples.clone();
        v.sort_by(f64::total_cmp);
        let n = v.len() as f64;
        v.into_iter().enumerate().map(|(i, x)| (x, (i + 1) as f64 / n)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_unsorted_input() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_cdf_monotone() {
        let mut s = Summary::new();
        for x in [5.0, 1.0, 3.0] {
            s.add(x);
        }
        let cdf = s.cdf();
        assert_eq!(cdf.len(), 3);
        assert!(cdf.windows(2).all(|w| w[0].0 <= w[1].0 && w[0].1 < w[1].1));
        assert_eq!(cdf.last().unwrap().1, 1.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
    }
}
