//! Minimal error plumbing (the offline crate set has no `anyhow`, so we
//! carry a string-backed error with the same ergonomics: an [`err!`]
//! constructor macro, [`bail!`], and a [`Context`] extension trait).

use std::fmt;

/// A string-backed error. Conversions from the std error types the crate
/// actually hits keep `?` working everywhere.
pub struct Error(String);

impl Error {
    pub fn msg(m: impl Into<String>) -> Self {
        Error(m.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error(e.to_string())
    }
}

impl From<std::num::ParseIntError> for Error {
    fn from(e: std::num::ParseIntError) -> Self {
        Error(e.to_string())
    }
}

impl From<std::num::ParseFloatError> for Error {
    fn from(e: std::num::ParseFloatError) -> Self {
        Error(e.to_string())
    }
}

impl From<String> for Error {
    fn from(s: String) -> Self {
        Error(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Self {
        Error(s.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

/// Attach context to any displayable error (anyhow's `Context`, minus the
/// error chain — the message is flattened).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error(format!("{c}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error(c.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string (anyhow's `anyhow!`).
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] (anyhow's `bail!`).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        "12x".parse::<u32>().context("parsing the answer")?;
        unreachable!()
    }

    #[test]
    fn context_flattens_messages() {
        let e = fails().unwrap_err();
        assert!(e.to_string().starts_with("parsing the answer: "));
    }

    #[test]
    fn macros_build_and_bail() {
        fn f(x: usize) -> Result<usize> {
            if x == 0 {
                bail!("zero is not allowed (got {x})");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(0).unwrap_err().to_string(), "zero is not allowed (got 0)");
        let e: Error = err!("code {}", 42);
        assert_eq!(format!("{e:?}"), "code 42");
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        assert_eq!(v.context("missing").unwrap_err().to_string(), "missing");
        assert_eq!(Some(5u8).context("missing").unwrap(), 5);
    }
}
