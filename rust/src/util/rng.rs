//! Deterministic PRNG (SplitMix64 seeding a xoshiro256**) used by the
//! workload generator, the simulator and the property tests. No external
//! crates; reproducibility across runs is required for the experiment
//! harness (every figure states its seed).

#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

/// The SplitMix64 finalizer: a fast, well-mixed u64 → u64 permutation.
/// Doubles as a standalone hash (e.g. rendezvous-routing scores) so the
/// mixing constants live in exactly one place.
pub fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    mix64(*state)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// xoshiro256** next.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.next_u64() % (hi - lo + 1)
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range(lo as u64, hi as u64) as usize
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize(0, xs.len() - 1)]
    }

    /// Exponential with the given rate (inter-arrival times). The rate
    /// must be positive (a zero/negative rate would yield infinite or
    /// negative gaps), and the draw is nudged strictly positive: the
    /// one-in-2^53 zero draw of [`f64`](Self::f64) would otherwise
    /// produce a 0.0 gap — tied arrival times that violate the
    /// strictly-increasing assumption the cluster dispatcher's tie-breaks
    /// and `with_template_burst_arrivals` rely on.
    pub fn exp(&mut self, rate: f64) -> f64 {
        exp_transform(self.f64(), rate)
    }

    /// Derive an independent child stream for `salt` without touching
    /// this generator's state: the child is seeded from a mix64 hash of
    /// (state, salt), so `rng.split(0)`, `rng.split(1)`, … give per-replica
    /// generators whose sequences don't overlap the parent's and are
    /// stable however many replicas a sweep uses (PR-6 leftover: cluster
    /// sweeps previously drew every replica's workload from ONE sequence,
    /// so changing the replica count reshuffled everyone's requests).
    pub fn split(&self, salt: u64) -> Rng {
        Rng::new(
            mix64(self.s[0] ^ mix64(self.s[2]))
                ^ mix64(salt.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(1)),
        )
    }

    /// Bounded Zipf(theta) over [lo, hi] by inverse-CDF on precomputed
    /// weights — the distribution §5.3 samples sequence lengths from.
    pub fn zipf(&mut self, theta: f64, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo >= 1 && hi >= lo);
        // Rejection-free discrete inverse CDF would need a table; for the
        // modest ranges used (sequence lengths) we approximate with the
        // continuous inverse CDF of a truncated Pareto-like density
        // f(x) ~ x^-theta, which matches the discrete Zipf closely for
        // theta < 1 and large supports.
        let a = 1.0 - theta;
        let (lo_f, hi_f) = (lo as f64, (hi + 1) as f64);
        let u = self.f64();
        let x = (lo_f.powf(a) + u * (hi_f.powf(a) - lo_f.powf(a))).powf(1.0 / a);
        (x as u64).clamp(lo, hi)
    }
}

/// The inverse-CDF exponential transform behind [`Rng::exp`], exposed so
/// its edge cases are directly testable: `u` is a uniform draw in [0, 1).
/// Bitwise-identical to the historical `-(1-u).ln()/rate` for every
/// nonzero draw; the u = 0 corner returns the smallest positive f64
/// instead of a zero gap.
pub fn exp_transform(u: f64, rate: f64) -> f64 {
    assert!(rate > 0.0, "exponential rate must be positive, got {rate}");
    let x = -(1.0 - u).ln() / rate;
    if x > 0.0 {
        x
    } else {
        f64::MIN_POSITIVE
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn split_streams_are_independent_and_stable() {
        let parent = Rng::new(42);
        // deterministic per salt, distinct across salts and from the parent
        let mut a = parent.split(0);
        let mut a2 = parent.split(0);
        let mut b = parent.split(1);
        let mut p = parent.clone();
        let (xa, xa2, xb, xp) = (a.next_u64(), a2.next_u64(), b.next_u64(), p.next_u64());
        assert_eq!(xa, xa2);
        assert_ne!(xa, xb);
        assert_ne!(xa, xp);
        // splitting is non-consuming: the parent stream is untouched
        let mut p2 = Rng::new(42);
        assert_eq!(p2.next_u64(), xp);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_inclusive_bounds() {
        let mut r = Rng::new(9);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..2000 {
            let x = r.range(3, 5);
            assert!((3..=5).contains(&x));
            saw_lo |= x == 3;
            saw_hi |= x == 5;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn zipf_respects_bounds_and_skew() {
        let mut r = Rng::new(11);
        let mut lows = 0usize;
        let n = 10_000;
        for _ in 0..n {
            let x = r.zipf(0.4, 1024, 4096);
            assert!((1024..=4096).contains(&x));
            if x < 2048 {
                lows += 1;
            }
        }
        // skewed toward small values: analytic CDF at 2048 for θ=0.4 over
        // [1024,4096] is ≈0.40, vs 0.33 for uniform
        let frac = lows as f64 / n as f64;
        assert!((0.36..0.46).contains(&frac), "frac={frac}");
    }

    /// Satellite regression: the u = 0 uniform draw used to produce a
    /// 0.0 inter-arrival gap (tied arrivals); it must now be strictly
    /// positive, every other draw is bitwise-unchanged, and a
    /// non-positive rate fails loudly instead of yielding inf/negative
    /// gaps.
    #[test]
    fn exp_gaps_are_strictly_positive_and_unchanged_otherwise() {
        assert!(exp_transform(0.0, 2.0) > 0.0, "zero draw must not tie arrivals");
        assert_eq!(exp_transform(0.0, 2.0), f64::MIN_POSITIVE);
        for u in [1e-16, 0.25, 0.5, 0.999999] {
            let expect = -(1.0 - u as f64).ln() / 3.0;
            assert_eq!(exp_transform(u, 3.0).to_bits(), expect.to_bits());
        }
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.exp(1.5) > 0.0);
        }
    }

    #[test]
    #[should_panic(expected = "rate must be positive")]
    fn exp_rejects_a_zero_rate() {
        let _ = exp_transform(0.5, 0.0);
    }

    #[test]
    fn exp_mean_close_to_inverse_rate() {
        let mut r = Rng::new(5);
        let n = 20_000;
        let m: f64 = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((m - 0.5).abs() < 0.02, "mean={m}");
    }
}
