//! Small self-contained utilities: deterministic PRNG, statistics helpers,
//! and a miniature property-testing driver (the offline crate set has no
//! `rand`/`proptest`, so we carry our own).

pub mod prop;
pub mod rng;
pub mod stats;

pub use rng::Rng;
pub use stats::{mean, percentile, Summary};
