//! Small self-contained utilities: deterministic PRNG, statistics helpers,
//! string-backed error plumbing, and a miniature property-testing driver
//! (the offline crate set has no `rand`/`proptest`/`anyhow`, so we carry
//! our own).

pub mod error;
pub mod prop;
pub mod rng;
pub mod stats;

pub use error::{Context, Error, Result};
pub use rng::{exp_transform, mix64, Rng};
pub use stats::{mean, percentile, Summary};
