//! # SARATHI — chunked-prefills + decode-maximal batching
//!
//! A reproduction of *"SARATHI: Efficient LLM Inference by Piggybacking
//! Decodes with Chunked Prefills"* (Agrawal et al., 2023) as a three-layer
//! Rust + JAX + Pallas serving stack:
//!
//! * **L3 (this crate)** — the coordinator: request routing, the SARATHI
//!   scheduler (chunked prefills, decode-maximal batches) plus the
//!   Sarathi-Serve-style stall-free token-budget `HybridScheduler`,
//!   token-granular paged KV-cache management with preemption, a
//!   pipeline-parallel discrete-event runtime simulator, and the PJRT
//!   runtime that serves a real model from AOT-compiled HLO (cargo
//!   feature `pjrt`).
//! * **L2/L1 (python/compile)** — the JAX model and Pallas kernels, lowered
//!   once at build time to `artifacts/*.hlo.txt`; Python is never on the
//!   request path.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured record of every table and figure.

pub mod config;
pub mod coordinator;
pub mod costmodel;
pub mod figures;
pub mod profiler;
pub mod report;
pub mod runtime;
pub mod simulator;
pub mod util;
pub mod workload;
