//! Table 4 — peak end-to-end throughput gains across the two model/GPU
//! testbeds and three sequence lengths, at the paper's per-row batch size
//! and P:D ratio (chunk 256).
//!
//! Each row runs the full engine (steady-state population) under the
//! request-level baseline and SARATHI, reporting decode speedup and
//! end-to-end gain. Paper rows: LLaMA-13B/A6000 1.33×/1.26×/1.22× and
//! LLaMA-33B/A100 1.25×/1.22×/1.14× (gains), decode speedups 5.45×–2.51×
//! and 3.83×–4.25×–3.51×.

use crate::config::{Deployment, SchedulerConfig};
use crate::figures::common::{run_engine, steady_population, llama13b_a6000, llama33b_a100};
use crate::report::{x, Table};

pub struct Row {
    pub model: &'static str,
    pub seq_len: usize,
    pub batch: usize,
    pub pd: f64,
    pub decode_speedup: f64,
    pub gain: f64,
}

pub fn compute() -> (Table, Vec<Row>) {
    let mut t = Table::new(
        "Table4 peak throughput gains (chunk=256)",
        &["model(gpu)", "seq_len", "batch", "P:D", "decode_speedup", "throughput_gain"],
    );
    let cases: Vec<(&'static str, Deployment, usize, usize, f64)> = vec![
        // paper's Table 4 rows: (name, deployment, L, B, P:D)
        ("llama-13b(a6000)", llama13b_a6000(1024), 1024, 6, 50.0),
        ("llama-13b(a6000)", llama13b_a6000(2048), 2048, 6, 50.0),
        ("llama-13b(a6000)", llama13b_a6000(3072), 3072, 6, 50.0),
        ("llama-33b(a100)", llama33b_a100(1024), 1024, 10, 28.0),
        ("llama-33b(a100)", llama33b_a100(2048), 2048, 5, 63.0),
        ("llama-33b(a100)", llama33b_a100(3072), 3072, 3, 127.0),
    ];
    let mut rows = Vec::new();
    for (name, d, l, b, pd) in cases {
        let pop = steady_population(b, l, pd, 6);
        let base = run_engine(&d, &SchedulerConfig::baseline(b), &pop);
        let sar = run_engine(&d, &SchedulerConfig::sarathi(256, b), &pop);
        let gain = sar.throughput() / base.throughput();
        let dsp = base.decode_time_per_token() / sar.decode_time_per_token();
        t.row(vec![
            name.into(),
            l.to_string(),
            b.to_string(),
            format!("{pd:.0}:1"),
            x(dsp),
            x(gain),
        ]);
        rows.push(Row { model: name, seq_len: l, batch: b, pd, decode_speedup: dsp, gain });
    }
    (t, rows)
}

pub fn run() -> Vec<Table> {
    vec![compute().0]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gains_positive_everywhere() {
        let (_, rows) = compute();
        for r in &rows {
            assert!(r.gain > 1.05, "{} L={}: gain {}", r.model, r.seq_len, r.gain);
            assert!(r.decode_speedup > 1.5, "{} L={}: dsp {}", r.model, r.seq_len, r.decode_speedup);
        }
    }

    #[test]
    fn gain_declines_with_sequence_length_on_a6000() {
        // paper: 1.33 → 1.26 → 1.22 (attention share grows with L)
        let (_, rows) = compute();
        let g: Vec<f64> = rows.iter().filter(|r| r.model.contains("13b")).map(|r| r.gain).collect();
        assert!(g[0] > g[2], "gains {g:?}");
    }

    #[test]
    fn gains_in_paper_ballpark() {
        // paper range: 1.14×–1.33× end-to-end
        let (_, rows) = compute();
        for r in &rows {
            assert!((1.02..1.8).contains(&r.gain), "{} L={}: {}", r.model, r.seq_len, r.gain);
        }
    }
}
