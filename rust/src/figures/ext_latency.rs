//! Extension experiment E14 (not a paper figure): decode-stall latency.
//!
//! §5.2 claims: *"adding a longer prefill sequence in a running batch can
//! delay the ongoing decodes, which in turn increases the latency of these
//! ongoing requests in Orca scheduling. SARATHI avoids this due to the use
//! of smaller chunk prefills."* The paper asserts but never measures it —
//! this harness does: staggered arrivals keep prefills landing amid
//! running decodes; we record every output token's timestamp and report
//! the time-between-tokens (TBT) distribution per scheduler. Orca-best's
//! tail TBT is a full-prompt prefill; SARATHI's is one chunk.

use crate::config::SchedulerConfig;
use crate::coordinator::{make_scheduler, Engine, KvManager, RequestPool, SimExecutor};
use crate::costmodel::CostModel;
use crate::figures::common::llama13b_a6000;
use crate::report::{ms, Table};
use crate::util::Summary;
use crate::workload::RequestSpec;

fn workload() -> Vec<RequestSpec> {
    // long prompts arriving while earlier requests decode — the §5.2 stall
    // scenario
    (0..24)
        .map(|i| RequestSpec {
            prompt_len: 1024,
            decode_len: 64,
            arrival: i as f64 * 0.08,
            prefix: None,
        })
        .collect()
}

pub fn tbt_summary(cfg: &SchedulerConfig) -> Summary {
    let d = llama13b_a6000(2048);
    let pop = workload();
    let mut engine = Engine::new(
        RequestPool::from_specs(&pop),
        KvManager::new(cfg.max_batch),
        make_scheduler(cfg),
        Box::new(SimExecutor::new(CostModel::for_deployment(&d))),
    );
    engine.run();
    engine.pool.tbt_summary().clone()
}

pub fn run() -> Vec<Table> {
    let b = 12usize;
    let mut t = Table::new(
        "E14(ext) time-between-tokens under prefill interference (ms)",
        &["scheduler", "p50", "p90", "p99", "max_stall"],
    );
    for cfg in [
        SchedulerConfig::orca_best(b),
        SchedulerConfig::sarathi(256, b),
        SchedulerConfig::sarathi(128, b),
        SchedulerConfig::hybrid(256, b),
        SchedulerConfig::hybrid(128, b),
    ] {
        let name = match (cfg.kind, cfg.chunk_size, cfg.token_budget) {
            (crate::config::SchedulerKind::Hybrid, _, t) => format!("hybrid (T={t})"),
            (_, 0, _) => cfg.kind.name().to_string(),
            (_, c, _) => format!("{} (C={c})", cfg.kind.name()),
        };
        let s = tbt_summary(&cfg);
        t.row(vec![
            name,
            ms(s.percentile(50.0)),
            ms(s.percentile(90.0)),
            ms(s.percentile(99.0)),
            ms(s.max()),
        ]);
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sarathi_caps_decode_stalls() {
        let b = 12usize;
        let orca = tbt_summary(&SchedulerConfig::orca_best(b));
        let sar = tbt_summary(&SchedulerConfig::sarathi(256, b));
        // Orca's worst stall spans a full 1024-token prefill; SARATHI's
        // spans one 256-token chunk — at least 2× shorter
        assert!(
            sar.max() < orca.max() / 2.0,
            "max stall: sarathi {} vs orca {}",
            sar.max(),
            orca.max()
        );
        // and the tail (p99) improves too
        assert!(sar.percentile(99.0) < orca.percentile(99.0));
    }

    #[test]
    fn smaller_chunks_mean_smaller_stalls() {
        let b = 12usize;
        let c256 = tbt_summary(&SchedulerConfig::sarathi(256, b));
        let c128 = tbt_summary(&SchedulerConfig::sarathi(128, b));
        assert!(c128.max() <= c256.max() * 1.05, "{} vs {}", c128.max(), c256.max());
    }

    #[test]
    fn hybrid_budget_bounds_stalls_below_a_bigger_chunk() {
        // the token budget bounds EVERY iteration's fused token count, so a
        // T=128 hybrid's worst decode stall sits below a C=256 SARATHI's
        let b = 12usize;
        let sar = tbt_summary(&SchedulerConfig::sarathi(256, b));
        let hyb = tbt_summary(&SchedulerConfig::hybrid(128, b));
        assert!(
            hyb.max() < sar.max(),
            "max stall: hybrid {} vs sarathi {}",
            hyb.max(),
            sar.max()
        );
    }

    #[test]
    fn gaps_are_positive_and_finite() {
        let s = tbt_summary(&SchedulerConfig::sarathi(256, 12));
        assert!(s.count() > 0);
        assert!(s.min() >= 0.0 && s.max().is_finite());
    }
}
