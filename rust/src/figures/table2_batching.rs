//! Table 2 — per-iteration operation times for the three batching schemes
//! (LLaMA-13B on A6000): prefill-only (4 × 1024-token prompts),
//! decode-only (4 lanes at KV 1024), and decode-maximal (one 1021-token
//! chunk + 3 piggybacked decodes).
//!
//! The reproduction target is the relation the paper draws from the table:
//! piggybacked decodes cost an order of magnitude less per token than
//! decode-only ones (12.49 → 1.2 ms in the paper).

use crate::costmodel::{BatchShape, CostModel, DecodeItem, PrefillItem};
use crate::figures::common::llama13b_a6000;
use crate::report::{f3, ms, Table};

pub struct Rows {
    pub prefill_per_tok: f64,
    pub decode_only_per_tok: f64,
    pub piggyback_per_tok: f64,
}

pub fn compute() -> (Table, Rows) {
    let cm = CostModel::for_deployment(&llama13b_a6000(1024));

    let mut t = Table::new(
        "Table2 per-token prefill/decode time (ms), LLaMA-13B/A6000",
        &["scheme", "linear_ms", "attn_ms", "total_ms", "prefill/tok", "decode/tok"],
    );

    // prefill-only: 4 prompts of 1024
    let p = BatchShape::prefill_only(&[(1024, 0); 4]);
    let bd_p = cm.iteration(&p);
    let prefill_per_tok = bd_p.total() / 1024.0; // the paper divides by L
    t.row(vec![
        "prefill-only".into(),
        ms(bd_p.linear()),
        ms(bd_p.attn()),
        ms(bd_p.total()),
        f3(prefill_per_tok * 1e3),
        "-".into(),
    ]);

    // decode-only: batch of 4 at sequence length 1024
    let d = BatchShape::decode_only(&[1024; 4]);
    let bd_d = cm.iteration(&d);
    let decode_only_per_tok = bd_d.total() / 4.0;
    t.row(vec![
        "decode-only".into(),
        ms(bd_d.linear()),
        ms(bd_d.attn()),
        ms(bd_d.total()),
        "-".into(),
        f3(decode_only_per_tok * 1e3),
    ]);

    // decode-maximal: 1021-token chunk + 3 decodes at KV 1024
    let h = BatchShape {
        prefill: vec![PrefillItem { chunk: 1021, history: 0 }],
        decode: vec![DecodeItem { kv_len: 1024 }; 3],
    };
    let bd_h = cm.iteration(&h);
    let alone = cm.iteration_time(&BatchShape::prefill_only(&[(1021, 0)]));
    let piggyback_per_tok = (bd_h.total() - alone) / 3.0;
    t.row(vec![
        "decode-maximal".into(),
        ms(bd_h.linear()),
        ms(bd_h.attn()),
        ms(bd_h.total()),
        f3(alone / 1021.0 * 1e3),
        f3(piggyback_per_tok * 1e3),
    ]);

    (t, Rows { prefill_per_tok, decode_only_per_tok, piggyback_per_tok })
}

pub fn run() -> Vec<Table> {
    vec![compute().0]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn piggybacked_decodes_are_order_of_magnitude_cheaper() {
        let (_, r) = compute();
        let speedup = r.decode_only_per_tok / r.piggyback_per_tok;
        // paper: 12.49 / 1.2 ≈ 10.4×
        assert!(speedup > 5.0, "speedup={speedup}");
    }

    #[test]
    fn decode_only_to_prefill_ratio_matches_paper_scale() {
        let (_, r) = compute();
        // paper: 12.49 vs 0.229 ≈ 55× at B=4... our accounting divides the
        // 4-prompt batch by L, same as the paper's convention
        let ratio = r.decode_only_per_tok / r.prefill_per_tok;
        assert!((10.0..120.0).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn attention_is_minor_for_prefill_heavy_rows() {
        let (t, _) = compute();
        let lin: f64 = t.rows[0][1].parse().unwrap();
        let attn: f64 = t.rows[0][2].parse().unwrap();
        assert!(attn < lin * 0.35, "attn {attn} vs linear {lin}");
    }
}
