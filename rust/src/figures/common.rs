//! Shared experiment plumbing: deployments, engine runs, derived metrics.

use crate::config::{Deployment, GpuConfig, ModelConfig, SchedulerConfig};
use crate::coordinator::{make_scheduler, Engine, KvManager, Metrics, RequestPool, SimExecutor};
use crate::costmodel::CostModel;
use crate::workload::{uniform_population, RequestSpec};

/// LLaMA-13B on A6000 — the paper's primary single-GPU testbed.
pub fn llama13b_a6000(max_seq: usize) -> Deployment {
    Deployment::new(ModelConfig::llama13b(), GpuConfig::a6000(), max_seq)
}

/// LLaMA-33B on A100 — the second single-GPU testbed.
pub fn llama33b_a100(max_seq: usize) -> Deployment {
    Deployment::new(ModelConfig::llama33b(), GpuConfig::a100(), max_seq)
}

/// Run one scheduler over a population on the simulated deployment;
/// returns the metrics.
pub fn run_engine(d: &Deployment, sched: &SchedulerConfig, pop: &[RequestSpec]) -> Metrics {
    let cm = CostModel::for_deployment(d);
    let mut engine = Engine::new(
        RequestPool::from_specs(pop),
        KvManager::new(sched.max_batch),
        make_scheduler(sched),
        Box::new(SimExecutor::new(cm)),
    );
    engine.run();
    engine.metrics
}

/// Steady-state population (§5.1 style): `waves` × max-batch identical
/// requests at `seq_len`/`pd`, enough to amortize warmup/tail.
pub fn steady_population(b: usize, seq_len: usize, pd: f64, waves: usize) -> Vec<RequestSpec> {
    uniform_population(b * waves, seq_len, pd)
}

/// Normalized throughput in tokens/ms (the paper's Fig. 9/11 unit).
pub fn tokens_per_ms(m: &Metrics) -> f64 {
    m.throughput() / 1e3
}
