//! Fig. 4 — (a) prefill/decode throughput vs token count / batch size;
//! (b) per-operator arithmetic intensity in the two phases.
//!
//! Shapes to reproduce: prefill throughput saturates near B×L ≈ 512 on
//! A6000 (~180 tokens/ms for one layer); decode throughput grows ~linearly
//! in batch and only approaches compute-bound at ~256 lanes; decode
//! arithmetic intensity is orders of magnitude below prefill's.

use crate::costmodel::{BatchShape, CostModel, Op};
use crate::figures::common::llama13b_a6000;
use crate::report::{f3, Table};

pub fn run() -> Vec<Table> {
    let d = llama13b_a6000(4096);
    let cm = CostModel::for_deployment(&d);
    let layers = cm.model.n_layers as f64;

    // (a) prefill throughput vs total tokens (single layer, like the paper)
    let mut ta = Table::new(
        "Fig4a prefill/decode throughput (single LLaMA-13B layer, A6000)",
        &["phase", "tokens_or_batch", "tokens/ms/layer"],
    );
    for tokens in [64usize, 128, 256, 512, 1024, 2048, 4096] {
        // B×L composition like the paper: sequences cap at 1024 so the
        // token axis scales batch, not quadratic attention
        let seq = tokens.min(1024);
        let reqs = vec![(seq, 0); tokens / seq];
        let t_model = cm.iteration_time(&BatchShape::prefill_only(&reqs));
        let per_layer = t_model / layers;
        ta.row(vec![
            "prefill".into(),
            tokens.to_string(),
            f3(tokens as f64 / (per_layer * 1e3)),
        ]);
    }
    for b in [1usize, 4, 16, 64, 128, 256] {
        // single-layer profile (the paper fits 40× larger decode batches by
        // profiling one layer — §3.1)
        let t_model = cm.iteration_time(&BatchShape::decode_only(&vec![1024; b]));
        let per_layer = t_model / layers;
        ta.row(vec!["decode".into(), b.to_string(), f3(b as f64 / (per_layer * 1e3))]);
    }

    // (b) arithmetic intensity per op, prefill (1024 tokens) vs decode (1)
    let mut tb = Table::new(
        "Fig4b arithmetic intensity (FLOPs/byte), 1K sequence",
        &["op", "prefill", "decode"],
    );
    for (name, op) in [
        ("preproj", Op::PreProj),
        ("attn", Op::Attn),
        ("postproj", Op::PostProj),
        ("ffn_ln1", Op::FfnLn1),
        ("ffn_ln2", Op::FfnLn2),
    ] {
        tb.row(vec![
            name.into(),
            f3(cm.arithmetic_intensity(op, 1024, 0)),
            f3(cm.arithmetic_intensity(op, 1, 1024)),
        ]);
    }
    vec![ta, tb]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn col(t: &Table, phase: &str) -> Vec<(usize, f64)> {
        t.rows
            .iter()
            .filter(|r| r[0] == phase)
            .map(|r| (r[1].parse().unwrap(), r[2].parse().unwrap()))
            .collect()
    }

    #[test]
    fn prefill_throughput_saturates() {
        let tables = run();
        let pre = col(&tables[0], "prefill");
        let at = |n: usize| pre.iter().find(|&&(t, _)| t == n).unwrap().1;
        // saturated regime ~flat: 1024 vs 4096 within 10%
        assert!((at(1024) - at(4096)).abs() / at(4096) < 0.10);
        // sub-saturated regime clearly lower
        assert!(at(128) < 0.75 * at(1024), "{} vs {}", at(128), at(1024));
        // ~180 tokens/ms/layer at saturation (paper §3.1) — allow ±35%
        assert!((120.0..250.0).contains(&at(1024)), "{}", at(1024));
    }

    #[test]
    fn decode_throughput_grows_with_batch() {
        let tables = run();
        let dec = col(&tables[0], "decode");
        assert!(dec.windows(2).all(|w| w[1].1 > w[0].1), "{dec:?}");
        // decode at B=1 is far below prefill saturation
        let pre1024 = col(&tables[0], "prefill").iter().find(|&&(t, _)| t == 1024).unwrap().1;
        assert!(dec[0].1 < pre1024 / 50.0);
    }

    #[test]
    fn decode_ai_orders_of_magnitude_below_prefill() {
        let tables = run();
        for r in &tables[1].rows {
            let p: f64 = r[1].parse().unwrap();
            let d: f64 = r[2].parse().unwrap();
            assert!(p > 50.0 * d, "{}: prefill {p} vs decode {d}", r[0]);
        }
    }
}
