//! Regeneration harness for every table and figure in the paper's
//! evaluation (§3 and §5). Each submodule exposes `run() -> Vec<Table>`
//! printing the same rows/series the paper reports; the CLI
//! (`sarathi figures <name>|all`) renders them and writes CSVs to `out/`.
//!
//! Absolute milliseconds come from the calibrated cost model (DESIGN.md §3)
//! — the *shape* of each result (who wins, by what factor, where the
//! crossovers fall) is the reproduction target, recorded against the
//! paper's numbers in EXPERIMENTS.md.

pub mod common;
pub mod ext_latency;
pub mod fig11_orca;
pub mod fig12_pipeline;
pub mod fig13_ablation;
pub mod fig3_per_token;
pub mod fig4_throughput;
pub mod fig5_bubbles;
pub mod fig7_tile;
pub mod fig8_decode_speedup;
pub mod fig9_pd_ratio;
pub mod fig10_breakdown;
pub mod table2_batching;
pub mod table4_peak;

use crate::report::Table;
use crate::util::error::Result;

/// All experiments, in paper order: (name, runner).
pub fn all() -> Vec<(&'static str, fn() -> Vec<Table>)> {
    vec![
        ("fig3", fig3_per_token::run),
        ("fig4", fig4_throughput::run),
        ("fig5", fig5_bubbles::run),
        ("table2", table2_batching::run),
        ("fig7", fig7_tile::run),
        ("fig8", fig8_decode_speedup::run),
        ("table4", table4_peak::run),
        ("fig9", fig9_pd_ratio::run),
        ("fig10", fig10_breakdown::run),
        ("fig11", fig11_orca::run),
        ("fig12", fig12_pipeline::run),
        ("fig13", fig13_ablation::run),
        ("ext-latency", ext_latency::run),
    ]
}

/// Run one experiment by name ("all" runs everything); returns rendered
/// tables after writing CSVs under `out_dir`.
pub fn run_named(name: &str, out_dir: &std::path::Path) -> Result<Vec<Table>> {
    let experiments = all();
    let mut tables = Vec::new();
    let mut matched = false;
    for (n, f) in experiments {
        if name == "all" || name == n {
            matched = true;
            for t in f() {
                let fname = t.title.split_whitespace().next().unwrap_or("table").to_lowercase();
                let fname = format!("{n}_{}", fname.replace(['/', ':'], "_"));
                t.write_csv(out_dir, &fname)?;
                tables.push(t);
            }
        }
    }
    if !matched {
        crate::bail!("unknown experiment {name:?} (try: all, fig3..fig13, table2, table4)");
    }
    Ok(tables)
}
