//! Fig. 12 — GPT-3 on 64 simulated A100s (§5.3): (a) CDF of pipeline
//! bubble time per request, (b) request completion-time curves for the
//! three deployments:
//!
//!   1. TP8×PP8, Orca-best scheduling, B=27
//!   2. TP8×PP8, SARATHI (chunk 256), B=27
//!   3. 8 replicas × TP8 (no PP), Orca-best, B=11
//!
//! Workload: 10K requests would match the paper exactly; the default here
//! is 2 000 (same distribution — Zipf(0.4) lengths in [1K,4K], P:D=10) so
//! `figures all` stays fast; the pipeline_sim example runs the full 10K.
//!
//! Headlines: SARATHI cuts the median per-request bubble ~6× and finishes
//! ~1.9× sooner than Orca TP-PP; TP-only lands in between.

use crate::config::{Deployment, GpuConfig, ModelConfig, ParallelConfig, PreemptionMode};
use crate::coordinator::sched::{HybridScheduler, OrcaScheduler, SarathiScheduler};
use crate::coordinator::SwapCost;
use crate::report::{f3, Table};
use crate::simulator::{ClusterResult, ClusterSim};
use crate::util::{Rng, Summary};
use crate::workload::{zipf_population, RequestSpec};

/// Paged-KV block size for the hybrid TP-PP scenario (tokens).
pub const HYBRID_BLOCK: usize = 128;

pub struct Fig12Outcome {
    pub orca_pp: ClusterResult,
    pub sarathi_pp: ClusterResult,
    pub tp_only: ClusterResult,
    /// Sarathi-Serve-style extension: token-budget micro-batches over ONE
    /// shared paged pool per replica (the honest per-stage KV budget, not
    /// the seed's pp×-overcommitted per-stream slots), swaps priced at
    /// PCIe bandwidth.
    pub hybrid_pp: ClusterResult,
}

pub fn deployments() -> (Deployment, Deployment) {
    let tp_pp = Deployment::new(ModelConfig::gpt3(), GpuConfig::a100(), 4096)
        .with_parallel(ParallelConfig::tp_pp(8, 8))
        .with_batch_cap(27);
    let tp_only = Deployment::new(ModelConfig::gpt3(), GpuConfig::a100(), 4096)
        .with_parallel(ParallelConfig::tp_pp(8, 1).with_replicas(8))
        .with_batch_cap(11);
    (tp_pp, tp_only)
}

pub fn workload(n: usize) -> Vec<RequestSpec> {
    let mut rng = Rng::new(0xF16_12);
    zipf_population(&mut rng, n, 0.4, 1024, 4096, 10.0)
}

pub fn simulate(n_requests: usize) -> Fig12Outcome {
    let specs = workload(n_requests);
    let (tp_pp, tp_only) = deployments();
    let cluster_pp = ClusterSim::new(tp_pp.clone());
    let orca_pp = cluster_pp.run(&specs, || Box::new(OrcaScheduler::best(27)));
    let sarathi_pp = cluster_pp.run(&specs, || Box::new(SarathiScheduler::new(256, 27, 128)));
    let tp_only = ClusterSim::new(tp_only).run(&specs, || Box::new(OrcaScheduler::best(11)));
    let hybrid_pp = ClusterSim::new(tp_pp.clone())
        .with_swap_cost(SwapCost::for_deployment(&tp_pp, PreemptionMode::Swap))
        .run_paged(&specs, HYBRID_BLOCK, || {
            Box::new(HybridScheduler::new(256, 27, 2))
        });
    Fig12Outcome { orca_pp, sarathi_pp, tp_only, hybrid_pp }
}

fn bubbles(r: &ClusterResult) -> Summary {
    let mut s = Summary::new();
    for rep in &r.per_replica {
        for &b in &rep.bubble_per_request {
            s.add(b);
        }
    }
    s
}

pub fn run() -> Vec<Table> {
    let out = simulate(2000);

    let mut ta = Table::new(
        "Fig12a pipeline bubble time per request (s), GPT-3 64xA100",
        &["percentile", "orca_tp_pp", "sarathi_tp_pp", "reduction", "hybrid_paged_pp"],
    );
    let (bo, bs) = (bubbles(&out.orca_pp), bubbles(&out.sarathi_pp));
    let bh = bubbles(&out.hybrid_pp);
    for p in [10.0, 25.0, 50.0, 75.0, 90.0, 99.0] {
        let o = bo.percentile(p);
        let s = bs.percentile(p);
        ta.row(vec![
            format!("p{p:.0}"),
            f3(o),
            f3(s),
            if s > 0.0 { format!("{:.2}x", o / s) } else { "inf".into() },
            f3(bh.percentile(p)),
        ]);
    }

    let mut tb = Table::new(
        "Fig12b completion times (s)",
        &["requests_done", "orca_tp_pp", "sarathi_tp_pp", "tp_only_8rep", "hybrid_paged_pp"],
    );
    let n = out.orca_pp.completions.len();
    for frac in [0.25, 0.5, 0.75, 1.0] {
        let k = ((n as f64 * frac) as usize).max(1);
        tb.row(vec![
            k.to_string(),
            f3(out.orca_pp.time_to_complete(k)),
            f3(out.sarathi_pp.time_to_complete(k)),
            f3(out.tp_only.time_to_complete(k)),
            f3(out.hybrid_pp.time_to_complete(k)),
        ]);
    }
    let speedup_orca = out.orca_pp.makespan / out.sarathi_pp.makespan;
    let speedup_tponly = out.tp_only.makespan / out.sarathi_pp.makespan;
    let speedup_hybrid = out.hybrid_pp.makespan / out.sarathi_pp.makespan;
    tb.row(vec![
        "sarathi speedup".into(),
        format!("{speedup_orca:.2}x"),
        "1.00x".into(),
        format!("{speedup_tponly:.2}x"),
        format!("{speedup_hybrid:.2}x"),
    ]);

    // the hybrid run holds the honest per-replica KV budget: preemption
    // swap traffic (KV bytes over PCIe) is part of its makespan
    let mut tc = Table::new(
        "Fig12c hybrid paged-KV accounting (per cluster run)",
        &["metric", "value"],
    );
    let lat = out.hybrid_pp.latency();
    tc.row(vec!["p50_tbt_s".into(), f3(lat.tbt.percentile(50.0))]);
    tc.row(vec!["p99_tbt_s".into(), f3(lat.tbt.percentile(99.0))]);
    tc.row(vec!["p99_ttft_s".into(), f3(lat.ttft.percentile(99.0))]);
    tc.row(vec!["preemptions".into(), out.hybrid_pp.preemptions().to_string()]);
    tc.row(vec!["swap_time_s".into(), f3(out.hybrid_pp.total_swap_time())]);
    vec![ta, tb, tc]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig12_headlines() {
        let out = simulate(800);
        let (bo, bs) = (bubbles(&out.orca_pp), bubbles(&out.sarathi_pp));
        // (a) median bubble reduction is large (paper: 6.29×)
        let med_red = bo.percentile(50.0) / bs.percentile(50.0).max(1e-9);
        assert!(med_red > 4.0, "median bubble reduction {med_red}");
        // (b) sarathi-PP < tp-only < orca-PP in makespan (paper: 1.91× and
        // 1.28× vs orca-PP)
        assert!(out.sarathi_pp.makespan < out.tp_only.makespan);
        assert!(out.tp_only.makespan < out.orca_pp.makespan);
        let speedup = out.orca_pp.makespan / out.sarathi_pp.makespan;
        assert!((1.3..2.8).contains(&speedup), "speedup {speedup}");
        // the paged hybrid scenario serves everything from the honest
        // (non-overcommitted) per-replica KV budget
        assert!(out.hybrid_pp.completions.iter().all(|t| !t.is_nan()));
        assert!(out.hybrid_pp.latency().tbt.count() > 0);
    }
}
