//! Fig. 5 — demonstration that iteration-level scheduling still has
//! pipeline bubbles: a 2-stage PP schedule (GPT-3, TP-8 inside each stage,
//! B = 27 like §5.3) traced stage by stage, Orca vs SARATHI.
//!
//! The Orca trace exhibits the three bubble classes the paper names:
//! PB1 (consecutive prefills of different length), PB2 (prefill followed
//! by a much-shorter decode iteration) and PB3 (decode KV-length
//! variance). SARATHI's uniform batches shrink the gaps by ~6×.

use crate::config::{Deployment, GpuConfig, ModelConfig, ParallelConfig};
use crate::coordinator::sched::{OrcaScheduler, SarathiScheduler};
use crate::costmodel::CostModel;
use crate::profiler::Profiler;
use crate::report::{ms, Table};
use crate::simulator::{PipelineResult, PipelineSim};
use crate::util::Rng;
use crate::workload::{zipf_population, RequestSpec};

fn workload() -> Vec<RequestSpec> {
    let mut rng = Rng::new(5);
    zipf_population(&mut rng, 120, 0.4, 1024, 4096, 10.0)
}

pub fn simulate() -> (PipelineResult, PipelineResult) {
    let d = Deployment::new(ModelConfig::gpt3(), GpuConfig::a100(), 4096)
        .with_parallel(ParallelConfig::tp_pp(8, 2))
        .with_batch_cap(27);
    let profiler = Profiler::build(CostModel::for_deployment(&d), 4096, 28);
    let sim = PipelineSim::new(profiler, 2).with_trace();
    let specs = workload();
    let orca = sim.run(&specs, 27, || Box::new(OrcaScheduler::best(27)));
    let sarathi = sim.run(&specs, 27, || Box::new(SarathiScheduler::new(256, 27, 128)));
    (orca, sarathi)
}

pub fn run() -> Vec<Table> {
    let (orca, sarathi) = simulate();
    let mut out = Vec::new();
    for (name, res) in [("orca", &orca), ("sarathi", &sarathi)] {
        let mut t = Table::new(
            &format!("Fig5 2-stage pipeline trace, first iterations ({name})"),
            &["mb", "stream", "stage", "start_ms", "end_ms", "bubble_ms", "p_tok", "d_tok"],
        );
        for ev in res.trace.iter().take(32) {
            t.row(vec![
                ev.micro_batch.to_string(),
                ev.stream.to_string(),
                ev.stage.to_string(),
                ms(ev.start),
                ms(ev.end),
                ms(ev.gap),
                ev.tokens.0.to_string(),
                ev.tokens.1.to_string(),
            ]);
        }
        t.row(vec![
            "total".into(),
            "-".into(),
            "-".into(),
            "-".into(),
            ms(res.makespan),
            ms(res.total_bubble),
            "-".into(),
            "-".into(),
        ]);
        out.push(t);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn orca_schedule_has_bubbles_sarathi_fewer() {
        let (orca, sarathi) = simulate();
        assert!(orca.total_bubble > 0.0, "orca trace shows no bubbles");
        assert!(
            sarathi.total_bubble < orca.total_bubble / 3.0,
            "sarathi {} !< orca {}/3",
            sarathi.total_bubble,
            orca.total_bubble
        );
        assert!(sarathi.makespan < orca.makespan);
    }

    #[test]
    fn orca_bubble_variance_comes_from_batch_nonuniformity() {
        // micro-batch durations: Orca's spread far exceeds SARATHI's — the
        // §3.3 mechanism behind the bubbles
        let (orca, sarathi) = simulate();
        let spread = |r: &PipelineResult| {
            let durs: Vec<f64> =
                r.trace.iter().filter(|e| e.stage == 0).map(|e| e.end - e.start).collect();
            let mean = durs.iter().sum::<f64>() / durs.len() as f64;
            let var = durs.iter().map(|d| (d - mean).powi(2)).sum::<f64>() / durs.len() as f64;
            var.sqrt() / mean
        };
        assert!(spread(&orca) > 2.0 * spread(&sarathi), "{} vs {}", spread(&orca), spread(&sarathi));
    }

    #[test]
    fn trace_is_well_formed() {
        let (orca, _) = simulate();
        for ev in &orca.trace {
            assert!(ev.end >= ev.start && ev.gap >= 0.0);
            assert!(ev.stage < 2);
        }
    }
}
