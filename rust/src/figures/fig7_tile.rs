//! Fig. 7 — the tile-quantization effect: one token past a multiple of the
//! 128-wide tile bumps the whole iteration's cost (the paper measures
//! 256→257 tokens: 69.8 → 92.33 ms, a 32% jump from a single token).

use crate::costmodel::{BatchShape, CostModel};
use crate::figures::common::llama13b_a6000;
use crate::report::{ms, Table};

pub fn run() -> Vec<Table> {
    let cm = CostModel::for_deployment(&llama13b_a6000(1024));
    let mut t = Table::new(
        "Fig7 tile quantization of iteration time, LLaMA-13B/A6000",
        &["seq_len", "iter_ms", "delta_vs_prev"],
    );
    let mut prev: Option<f64> = None;
    for l in [128usize, 129, 192, 256, 257, 320, 384, 385, 448, 512] {
        let time = cm.iteration_time(&BatchShape::prefill_only(&[(l, 0)]));
        let delta = prev.map(|p| format!("{:+.1}%", (time / p - 1.0) * 100.0)).unwrap_or("-".into());
        t.row(vec![l.to_string(), ms(time), delta]);
        prev = Some(time);
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::BatchShape;

    #[test]
    fn one_token_past_tile_boundary_jumps() {
        let cm = CostModel::for_deployment(&llama13b_a6000(1024));
        let t = |l: usize| cm.iteration_time(&BatchShape::prefill_only(&[(l, 0)]));
        // crossing 256 -> 257 costs a visible jump (paper: +32%)
        assert!(t(257) / t(256) > 1.10, "jump {:.3}", t(257) / t(256));
        // within a bucket the cost is ~flat
        assert!((t(257) - t(384)).abs() / t(384) < 0.03);
        // doubling 128 -> 256 costs much less than 2× (paper: +27%)
        let dbl = t(256) / t(128);
        assert!((1.05..1.8).contains(&dbl), "128->256 ratio {dbl}");
    }

    #[test]
    fn table_has_all_probe_points() {
        let t = &run()[0];
        assert_eq!(t.rows.len(), 10);
        assert!(t.rows.iter().any(|r| r[0] == "257"));
    }
}
