//! Fig. 8 — decode-only speedup of SARATHI over the baseline vs batch
//! size, for sequence lengths 1K/2K/3K (LLaMA-13B on A6000, chunk 256).
//!
//! Methodology follows §5.1.1 exactly: baseline decode time per token =
//! decode-only iteration / B; SARATHI's = (hybrid − prefill-alone) / d with
//! d = B−1 piggybacked lanes. Speedups fall with batch size and sequence
//! length but stay in the 2.8–10× band.

use crate::config::Deployment;
use crate::costmodel::{BatchShape, CostModel};
use crate::figures::common::llama13b_a6000;
use crate::report::{x, Table};

pub fn decode_speedup(d: &Deployment, chunk: usize, b: usize, kv: usize) -> f64 {
    let cm = CostModel::for_deployment(d);
    let lanes = b - 1;
    // §4.4 tile alignment: chunk shrinks so chunk + lanes == C
    let c_eff = chunk - lanes;
    let hybrid = BatchShape::hybrid(c_eff, 0, &vec![kv; lanes]);
    let alone = BatchShape::prefill_only(&[(c_eff, 0)]);
    let marginal = (cm.iteration_time(&hybrid) - cm.iteration_time(&alone)) / lanes as f64;
    let baseline = cm.iteration_time(&BatchShape::decode_only(&vec![kv; b])) / b as f64;
    baseline / marginal
}

pub fn run() -> Vec<Table> {
    let mut t = Table::new(
        "Fig8 decode speedup vs batch size (chunk=256), LLaMA-13B/A6000",
        &["seq_len", "batch", "speedup"],
    );
    for (l, b_max) in [(1024usize, 18usize), (2048, 9), (3072, 6)] {
        let d = llama13b_a6000(l);
        for b in [2usize, 4, 6, 9, 12, 18] {
            if b > b_max {
                continue;
            }
            t.row(vec![l.to_string(), b.to_string(), x(decode_speedup(&d, 256, b, l))]);
        }
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn speedups() -> Vec<(usize, usize, f64)> {
        run()[0]
            .rows
            .iter()
            .map(|r| {
                (
                    r[0].parse().unwrap(),
                    r[1].parse().unwrap(),
                    r[2].trim_end_matches('x').parse().unwrap(),
                )
            })
            .collect()
    }

    #[test]
    fn speedups_in_paper_band() {
        // paper: 2.8×–10× across the sweep
        for (l, b, s) in speedups() {
            assert!(s > 1.5, "L={l} B={b}: speedup {s}");
            assert!(s < 40.0, "L={l} B={b}: speedup {s} implausibly high");
        }
    }

    #[test]
    fn speedup_falls_with_batch_and_seq_len() {
        let all = speedups();
        let get = |l: usize, b: usize| all.iter().find(|&&(ll, bb, _)| ll == l && bb == b).map(|&(_, _, s)| s);
        // larger batch → baseline amortizes → smaller speedup
        assert!(get(1024, 2).unwrap() > get(1024, 18).unwrap());
        // longer sequence → attention share grows → smaller speedup
        assert!(get(1024, 4).unwrap() > get(3072, 4).unwrap());
    }
}
