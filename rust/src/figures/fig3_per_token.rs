//! Fig. 3 — per-token prefill vs decode time across batch sizes, split by
//! operator (LLaMA-13B on A6000, sequence length 1024).
//!
//! Paper's observations to reproduce: prefill per-token cost is ~flat in
//! batch size; decode per-token cost is ~200×/100×/16.7× prefill at
//! B = 1/2/18; linear decode ops amortize with batch while decode
//! attention does not (memory-bound).

use crate::costmodel::{BatchShape, CostModel};
use crate::figures::common::llama13b_a6000;
use crate::report::{f3, Table};

pub fn run() -> Vec<Table> {
    let d = llama13b_a6000(1024);
    let cm = CostModel::for_deployment(&d);
    let l = 1024usize;

    let mut t = Table::new(
        "Fig3 per-token time (ms), LLaMA-13B/A6000, L=1024",
        &["batch", "phase", "preproj", "attn", "postproj", "ffn", "others", "total/tok", "decode:prefill"],
    );

    for b in [1usize, 2, 4, 8, 12, 18] {
        let prefill = BatchShape::prefill_only(&vec![(l, 0); b]);
        let bd_p = cm.iteration(&prefill);
        let tokens_p = (b * l) as f64;
        let per_tok_p = bd_p.total() / tokens_p;

        let decode = BatchShape::decode_only(&vec![l; b]);
        let bd_d = cm.iteration(&decode);
        let per_tok_d = bd_d.total() / b as f64;

        t.row(vec![
            b.to_string(),
            "prefill".into(),
            f3(bd_p.preproj / tokens_p * 1e3),
            f3(bd_p.attn() / tokens_p * 1e3),
            f3(bd_p.postproj / tokens_p * 1e3),
            f3((bd_p.ffn_ln1 + bd_p.ffn_ln2) / tokens_p * 1e3),
            f3(bd_p.others / tokens_p * 1e3),
            f3(per_tok_p * 1e3),
            "-".into(),
        ]);
        t.row(vec![
            b.to_string(),
            "decode".into(),
            f3(bd_d.preproj / b as f64 * 1e3),
            f3(bd_d.attn() / b as f64 * 1e3),
            f3(bd_d.postproj / b as f64 * 1e3),
            f3((bd_d.ffn_ln1 + bd_d.ffn_ln2) / b as f64 * 1e3),
            f3(bd_d.others / b as f64 * 1e3),
            f3(per_tok_d * 1e3),
            format!("{:.1}x", per_tok_d / per_tok_p),
        ]);
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reproduces_paper_ratios() {
        let t = &run()[0];
        // decode rows carry the ratio in the last column
        let ratio = |b: &str| -> f64 {
            t.rows
                .iter()
                .find(|r| r[0] == b && r[1] == "decode")
                .unwrap()
                .last()
                .unwrap()
                .trim_end_matches('x')
                .parse()
                .unwrap()
        };
        // paper: 200×, 100×, 16.7× at B = 1, 2, 18
        assert!((120.0..280.0).contains(&ratio("1")), "{}", ratio("1"));
        assert!((60.0..140.0).contains(&ratio("2")), "{}", ratio("2"));
        assert!((10.0..30.0).contains(&ratio("18")), "{}", ratio("18"));
        // ratio falls monotonically with batch size
        assert!(ratio("1") > ratio("2") && ratio("2") > ratio("18"));
    }

    #[test]
    fn prefill_per_token_flat_in_batch() {
        let t = &run()[0];
        let totals: Vec<f64> = t
            .rows
            .iter()
            .filter(|r| r[1] == "prefill")
            .map(|r| r[7].parse().unwrap())
            .collect();
        let (min, max) = totals.iter().fold((f64::MAX, 0.0f64), |(lo, hi), &x| (lo.min(x), hi.max(x)));
        assert!(max / min < 1.15, "prefill per-token varies: {min}..{max}");
    }
}
