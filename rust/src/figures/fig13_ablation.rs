//! Fig. 13 — ablation of chunked-prefills (LLaMA-13B/A6000):
//! (a) attention-time overhead of chunking vs chunk size,
//! (b) total prefill-time overhead vs chunk size,
//! (c) end-to-end throughput vs chunk size when combined with
//!     decode-maximal batching.
//!
//! Shapes: chunk 64 ≈ 3× attention / ~5× prefill overhead; 256/512 keep
//! prefill loss within ~20%/10%; e2e throughput peaks at 256 (tile
//! multiples beat non-multiples like 320).

use crate::config::SchedulerConfig;
use crate::costmodel::{BatchShape, CostModel};
use crate::figures::common::{llama13b_a6000, run_engine, steady_population, tokens_per_ms};
use crate::report::{f3, Table};

/// Total attention time of prefilling `l` tokens in chunks of `c`
/// (per-layer units cancel in the ratios).
fn chunked_attn_time(cm: &CostModel, l: usize, c: usize) -> f64 {
    let mut t = 0.0;
    let mut start = 0;
    while start < l {
        let len = c.min(l - start);
        t += cm.attn_prefill_time(len, start);
        start += len;
    }
    t
}

fn chunked_prefill_time(cm: &CostModel, l: usize, c: usize) -> f64 {
    let mut t = 0.0;
    let mut start = 0;
    while start < l {
        let len = c.min(l - start);
        t += cm.iteration_time(&BatchShape::prefill_only(&[(len, start)]));
        start += len;
    }
    t
}

pub fn run() -> Vec<Table> {
    let cm = CostModel::for_deployment(&llama13b_a6000(3072));
    let chunks = [64usize, 128, 256, 320, 512];

    let mut ta = Table::new(
        "Fig13a chunked-prefill attention overhead (ratio vs full prefill)",
        &["chunk", "L=1024", "L=2048", "L=3072"],
    );
    let mut tb = Table::new(
        "Fig13b chunked-prefill total overhead (ratio vs full prefill)",
        &["chunk", "L=1024", "L=2048", "L=3072"],
    );
    for &c in &chunks {
        let mut ra = vec![c.to_string()];
        let mut rb = vec![c.to_string()];
        for l in [1024usize, 2048, 3072] {
            ra.push(f3(chunked_attn_time(&cm, l, c) / cm.attn_prefill_time(l, 0)));
            rb.push(f3(
                chunked_prefill_time(&cm, l, c)
                    / cm.iteration_time(&BatchShape::prefill_only(&[(l, 0)])),
            ));
        }
        ta.row(ra);
        tb.row(rb);
    }

    // (c) end-to-end throughput vs chunk size with decode-maximal batching
    let (l, b) = (1024usize, 18usize);
    let d = llama13b_a6000(l);
    let mut tc = Table::new(
        "Fig13c end-to-end throughput vs chunk size (L=1K, B=18, tokens/ms)",
        &["chunk", "throughput", "vs_baseline"],
    );
    let pd = 256.0 / (b as f64 - 1.0);
    let pop = steady_population(b, l, pd, 4);
    let base = tokens_per_ms(&run_engine(&d, &SchedulerConfig::baseline(b), &pop));
    tc.row(vec!["baseline".into(), f3(base), "1.00x".into()]);
    for &c in &chunks {
        let thpt = tokens_per_ms(&run_engine(&d, &SchedulerConfig::sarathi(c, b), &pop));
        tc.row(vec![c.to_string(), f3(thpt), format!("{:.2}x", thpt / base)]);
    }
    vec![ta, tb, tc]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_chunks_cost_more_attention() {
        let t = &run()[0];
        let at = |chunk: &str| -> f64 {
            t.rows.iter().find(|r| r[0] == chunk).unwrap()[3].parse().unwrap()
        };
        // paper: chunk 64 ≈ 3× attention overhead; monotone in 1/chunk
        assert!(at("64") > 2.0, "{}", at("64"));
        assert!(at("64") > at("128") && at("128") > at("256") && at("256") > at("512"));
        // chunking never reduces attention time
        assert!(at("512") >= 1.0);
    }

    #[test]
    fn prefill_overhead_bounds_match_paper() {
        let t = &run()[1];
        let at = |chunk: &str| -> f64 {
            t.rows.iter().find(|r| r[0] == chunk).unwrap()[1].parse().unwrap()
        };
        // paper: 256 within ~20%, 512 within ~10%, 64 up to ~5×
        assert!(at("256") < 1.35, "{}", at("256"));
        assert!(at("512") < 1.20, "{}", at("512"));
        assert!(at("64") > 1.8, "{}", at("64"));
    }

    #[test]
    fn tile_multiple_beats_non_multiple() {
        // Fig. 13c: chunk 256 (tile multiple) outperforms 320
        let t = &run()[2];
        let get = |chunk: &str| -> f64 {
            t.rows.iter().find(|r| r[0] == chunk).unwrap()[1].parse().unwrap()
        };
        assert!(get("256") >= get("320"), "{} vs {}", get("256"), get("320"));
        // and the best chunk beats the baseline end to end
        let base = get("baseline");
        assert!(get("256") > base, "{} !> {}", get("256"), base);
    }
}
