//! Fig. 10 — total time split by operator, baseline vs SARATHI, across
//! batch sizes for chunk 256/512 at the balanced P:D (LLaMA-13B/A6000).
//!
//! Shapes to reproduce: the fused linear operators shrink (ffn most, up to
//! ~1.6×); attention time *rises* slightly under SARATHI (chunked KV
//! re-reads); the net is the end-to-end gain.

use crate::config::SchedulerConfig;
use crate::costmodel::OpBreakdown;
use crate::figures::common::{llama13b_a6000, run_engine, steady_population};
use crate::report::{ms, Table};

fn fmt_row(scheme: &str, l: usize, c: usize, b: usize, bd: &OpBreakdown) -> Vec<String> {
    vec![
        scheme.into(),
        l.to_string(),
        c.to_string(),
        b.to_string(),
        ms(bd.preproj),
        ms(bd.attn()),
        ms(bd.postproj),
        ms(bd.ffn_ln1 + bd.ffn_ln2),
        ms(bd.total()),
    ]
}

pub fn run() -> Vec<Table> {
    let mut t = Table::new(
        "Fig10 op-time breakdown, baseline vs SARATHI (balanced P:D)",
        &["scheme", "seq_len", "chunk", "batch", "preproj", "attn", "postproj", "ffn", "total"],
    );
    for chunk in [256usize, 512] {
        for (l, b_max) in [(1024usize, 18usize), (2048, 9), (3072, 6)] {
            for b in [6usize, 12, 18] {
                if b > b_max {
                    continue;
                }
                let d = llama13b_a6000(l);
                let pd = chunk as f64 / (b as f64 - 1.0); // balanced (§5.1.4)
                let pop = steady_population(b, l, pd, 3);
                let base = run_engine(&d, &SchedulerConfig::baseline(b), &pop);
                let sar = run_engine(&d, &SchedulerConfig::sarathi(chunk, b), &pop);
                t.row(fmt_row("baseline", l, chunk, b, &base.op_totals()));
                t.row(fmt_row("sarathi", l, chunk, b, &sar.op_totals()));
            }
        }
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Pair {
        base: Vec<f64>,
        sar: Vec<f64>,
    }

    fn pairs() -> Vec<Pair> {
        let t = &run()[0];
        t.rows
            .chunks(2)
            .map(|w| Pair {
                base: w[0][4..].iter().map(|c| c.parse().unwrap()).collect(),
                sar: w[1][4..].iter().map(|c| c.parse().unwrap()).collect(),
            })
            .collect()
    }

    #[test]
    fn linear_ops_shrink_under_sarathi() {
        // ffn (index 3) and total (index 4) improve in most configurations
        let mut ffn_wins = 0;
        let all = pairs();
        for p in &all {
            if p.sar[3] < p.base[3] {
                ffn_wins += 1;
            }
        }
        assert!(ffn_wins * 3 >= all.len() * 2, "ffn shrank in only {ffn_wins}/{}", all.len());
    }

    #[test]
    fn attention_rises_under_sarathi() {
        // chunked prefills re-read the KV prefix → attention time up
        let all = pairs();
        let rises = all.iter().filter(|p| p.sar[1] > p.base[1]).count();
        assert!(rises * 3 >= all.len() * 2, "attn rose in only {rises}/{}", all.len());
    }

    #[test]
    fn totals_improve_at_balanced_pd() {
        let all = pairs();
        let wins = all.iter().filter(|p| p.sar[4] < p.base[4]).count();
        assert!(wins * 3 >= all.len() * 2, "total improved in only {wins}/{}", all.len());
    }
}
