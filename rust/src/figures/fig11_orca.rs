//! Fig. 11 — comparison with Orca's iteration-level scheduling
//! (LLaMA-13B/A6000).
//!
//! (a) sequence-length sweep at the optimal P:D = C/(B−1): Orca-worst ≈
//! baseline; Orca-best gains a little at 1K then fades with L; SARATHI
//! keeps 1.2×+.
//! (b) P:D sweep at L=1K, B=18: SARATHI-256 wins the low-P:D regime,
//! SARATHI-512 the high one; Orca-best is flatter and peaks much later
//! (it is the C = max-seq-len special case).

use crate::config::SchedulerConfig;
use crate::figures::common::{llama13b_a6000, run_engine, steady_population, tokens_per_ms};
use crate::report::{f3, Table};

pub fn run() -> Vec<Table> {
    // (a) sequence-length sweep at optimal P:D, chunk 256
    let mut ta = Table::new(
        "Fig11a throughput vs seq length at optimal P:D (tokens/ms)",
        &["seq_len", "batch", "baseline", "orca_worst", "orca_best", "sarathi256", "sarathi_gain"],
    );
    for (l, b) in [(1024usize, 18usize), (2048, 9), (3072, 6)] {
        let d = llama13b_a6000(l);
        let pd = 256.0 / (b as f64 - 1.0);
        let pop = steady_population(b, l, pd, 4);
        let base = tokens_per_ms(&run_engine(&d, &SchedulerConfig::baseline(b), &pop));
        let worst = tokens_per_ms(&run_engine(&d, &SchedulerConfig::orca_worst(b), &pop));
        let best = tokens_per_ms(&run_engine(&d, &SchedulerConfig::orca_best(b), &pop));
        let sar = tokens_per_ms(&run_engine(&d, &SchedulerConfig::sarathi(256, b), &pop));
        ta.row(vec![
            l.to_string(),
            b.to_string(),
            f3(base),
            f3(worst),
            f3(best),
            f3(sar),
            format!("{:.2}x", sar / base),
        ]);
    }

    // (b) P:D sweep at L=1K, B=18
    let mut tb = Table::new(
        "Fig11b throughput vs P:D (L=1K, B=18, tokens/ms)",
        &["P:D", "baseline", "orca_best", "sarathi256", "sarathi512"],
    );
    let (l, b) = (1024usize, 18usize);
    let d = llama13b_a6000(l);
    for pd in [2.0f64, 5.0, 10.0, 14.0, 28.0, 50.0, 100.0, 200.0] {
        let pop = steady_population(b, l, pd, 4);
        tb.row(vec![
            format!("{pd:.0}"),
            f3(tokens_per_ms(&run_engine(&d, &SchedulerConfig::baseline(b), &pop))),
            f3(tokens_per_ms(&run_engine(&d, &SchedulerConfig::orca_best(b), &pop))),
            f3(tokens_per_ms(&run_engine(&d, &SchedulerConfig::sarathi(256, b), &pop))),
            f3(tokens_per_ms(&run_engine(&d, &SchedulerConfig::sarathi(512, b), &pop))),
        ]);
    }
    vec![ta, tb]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig11a_sarathi_beats_orca_everywhere() {
        let t = &run()[0];
        for r in &t.rows {
            let best: f64 = r[4].parse().unwrap();
            let sar: f64 = r[5].parse().unwrap();
            assert!(sar > best, "L={}: sarathi {sar} !> orca-best {best}", r[0]);
        }
    }

    #[test]
    fn fig11a_orca_worst_tracks_baseline() {
        let t = &run()[0];
        for r in &t.rows {
            let base: f64 = r[2].parse().unwrap();
            let worst: f64 = r[3].parse().unwrap();
            assert!((worst - base).abs() / base < 0.10, "L={}: worst {worst} vs base {base}", r[0]);
        }
    }

    #[test]
    fn fig11b_chunk512_wins_high_pd_regime() {
        let tables = run();
        let t = &tables[1];
        let get = |pd: &str, col: usize| -> f64 {
            t.rows.iter().find(|r| r[0] == pd).unwrap()[col].parse().unwrap()
        };
        // at the highest P:D, chunk 512 ≥ chunk 256 (paper: optimal P:D
        // shifts right with chunk size)
        assert!(get("200", 4) >= get("200", 3) * 0.98);
        // at the lowest P:D, chunk 256 ≥ chunk 512
        assert!(get("5", 3) >= get("5", 4) * 0.98);
    }
}
