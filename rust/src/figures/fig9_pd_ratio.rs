//! Fig. 9 — normalized throughput (tokens/ms) vs P:D ratio for chunk sizes
//! 128/256/512 and sequence lengths 1K/2K/3K (LLaMA-13B on A6000, B = max
//! fit per L).
//!
//! Shapes to reproduce: the SARATHI gain peaks near P:D = C/(B−1)
//! (§5.1.3), the peak moves right as the chunk grows, and chunk 128 trails
//! 256/512 because tiny chunks hurt prefill efficiency more than the extra
//! piggybacking helps.

use crate::config::SchedulerConfig;
use crate::figures::common::{llama13b_a6000, run_engine, steady_population, tokens_per_ms};
use crate::report::{f3, Table};

const PD_GRID: [f64; 8] = [2.0, 5.0, 10.0, 14.0, 28.0, 50.0, 100.0, 200.0];

pub fn run() -> Vec<Table> {
    let mut out = Vec::new();
    for (l, b) in [(1024usize, 18usize), (2048, 9), (3072, 6)] {
        let d = llama13b_a6000(l);
        let mut t = Table::new(
            &format!("Fig9 normalized throughput vs P:D, L={l}, B={b}"),
            &["P:D", "baseline", "chunk128", "chunk256", "chunk512", "best_gain"],
        );
        for &pd in &PD_GRID {
            let pop = steady_population(b, l, pd, 4);
            let base = tokens_per_ms(&run_engine(&d, &SchedulerConfig::baseline(b), &pop));
            let mut cells = vec![format!("{pd:.0}"), f3(base)];
            let mut best: f64 = 0.0;
            for chunk in [128usize, 256, 512] {
                let thpt = tokens_per_ms(&run_engine(&d, &SchedulerConfig::sarathi(chunk, b), &pop));
                best = best.max(thpt / base);
                cells.push(f3(thpt));
            }
            cells.push(format!("{best:.2}x"));
            t.row(cells);
        }
        out.push(t);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gains(table: &Table, col: usize) -> Vec<(f64, f64)> {
        table
            .rows
            .iter()
            .map(|r| {
                let pd: f64 = r[0].parse().unwrap();
                let base: f64 = r[1].parse().unwrap();
                let v: f64 = r[col].parse().unwrap();
                (pd, v / base)
            })
            .collect()
    }

    #[test]
    fn chunk256_peaks_near_c_over_b_minus_1() {
        // L=1K, B=18 → C/(B−1) = 256/17 ≈ 15; the paper's peak is at P:D=14
        let tables = run();
        let g = gains(&tables[0], 3);
        let peak = g.iter().cloned().fold((0.0, 0.0), |m, x| if x.1 > m.1 { x } else { m });
        assert!((5.0..=50.0).contains(&peak.0), "peak at P:D={}", peak.0);
        assert!(peak.1 > 1.1, "peak gain {}", peak.1);
    }

    #[test]
    fn peak_moves_right_with_chunk_size() {
        let tables = run();
        let peak_pd = |col: usize| {
            gains(&tables[0], col)
                .into_iter()
                .fold((0.0, 0.0), |m, x| if x.1 > m.1 { x } else { m })
                .0
        };
        assert!(peak_pd(4) >= peak_pd(3), "512 peak {} < 256 peak {}", peak_pd(4), peak_pd(3));
    }

    #[test]
    fn gains_hold_over_wide_pd_range() {
        // paper: "improvements still around 10% over a large range"
        let tables = run();
        let g = gains(&tables[0], 3);
        let above = g.iter().filter(|&&(_, gain)| gain > 1.05).count();
        assert!(above >= g.len() / 2, "only {above}/{} P:D points gain >5%", g.len());
    }
}
