//! The compute shape of one scheduled iteration, as the cost model sees it.

/// One prefill chunk: C new tokens whose queries attend to `history`
/// already-cached tokens of the same request (plus the chunk itself,
/// causally).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PrefillItem {
    pub chunk: usize,
    pub history: usize,
}

/// One decode lane: a single new token attending to `kv_len` cached tokens.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DecodeItem {
    pub kv_len: usize,
}

/// The composition of one iteration's batch. Linear operators run over
/// `total_tokens()` fused rows; attention is costed per item.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct BatchShape {
    pub prefill: Vec<PrefillItem>,
    pub decode: Vec<DecodeItem>,
}

impl BatchShape {
    /// `(chunk, history)` pairs.
    pub fn prefill_only(items: &[(usize, usize)]) -> Self {
        BatchShape {
            prefill: items.iter().map(|&(c, h)| PrefillItem { chunk: c, history: h }).collect(),
            decode: vec![],
        }
    }

    /// KV lengths of the decode lanes.
    pub fn decode_only(kv_lens: &[usize]) -> Self {
        BatchShape {
            prefill: vec![],
            decode: kv_lens.iter().map(|&k| DecodeItem { kv_len: k }).collect(),
        }
    }

    /// One chunk + decode lanes — the decode-maximal composition.
    pub fn hybrid(chunk: usize, history: usize, kv_lens: &[usize]) -> Self {
        BatchShape {
            prefill: vec![PrefillItem { chunk, history }],
            decode: kv_lens.iter().map(|&k| DecodeItem { kv_len: k }).collect(),
        }
    }

    pub fn prefill_tokens(&self) -> usize {
        self.prefill.iter().map(|p| p.chunk).sum()
    }

    pub fn decode_tokens(&self) -> usize {
        self.decode.len()
    }

    /// Rows of the fused linear-operator matrix.
    pub fn total_tokens(&self) -> usize {
        self.prefill_tokens() + self.decode_tokens()
    }

    pub fn is_empty(&self) -> bool {
        self.prefill.is_empty() && self.decode.is_empty()
    }

    /// A decode-maximal batch has exactly one prefill chunk (§4.3).
    pub fn is_decode_maximal(&self) -> bool {
        self.prefill.len() == 1 && !self.decode.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_accounting() {
        let s = BatchShape::hybrid(256, 512, &[100, 200, 300]);
        assert_eq!(s.prefill_tokens(), 256);
        assert_eq!(s.decode_tokens(), 3);
        assert_eq!(s.total_tokens(), 259);
        assert!(s.is_decode_maximal());
    }

    #[test]
    fn constructors() {
        assert_eq!(BatchShape::prefill_only(&[(128, 0), (64, 128)]).prefill_tokens(), 192);
        assert_eq!(BatchShape::decode_only(&[1, 2, 3]).decode_tokens(), 3);
        assert!(BatchShape::default().is_empty());
        assert!(!BatchShape::prefill_only(&[(8, 0), (8, 0)]).is_decode_maximal());
    }
}
