//! Roofline GPU cost model — the substrate standing in for the paper's
//! physical A6000/A100 testbeds (DESIGN.md §3).
//!
//! Every operator of the Table-1 decoder block is costed as
//! `max(flops / achieved_flops, bytes / achieved_bw) + launch_overhead`,
//! with matmul token dimensions rounded up to the hardware tile (the
//! Fig.-7 tile-quantization effect). The achieved-rate calibration
//! constants live in `GpuConfig` and are fit to the paper's published
//! measurements; all *structural* effects the paper builds on —
//! memory-bound decodes, compute-saturated prefills, quadratic attention,
//! chunking overhead from KV re-reads — fall out of the arithmetic.

mod batch_shape;

pub use batch_shape::{BatchShape, DecodeItem, PrefillItem};

use crate::config::{Deployment, GpuConfig, ModelConfig};

/// The six operator groups of the paper's §2.1.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Op {
    PreProj,
    Attn,
    PostProj,
    FfnLn1,
    FfnLn2,
    Others,
}

pub const LINEAR_OPS: [Op; 4] = [Op::PreProj, Op::PostProj, Op::FfnLn1, Op::FfnLn2];

/// Per-iteration time split by operator group, in seconds, for the layers
/// owned by ONE pipeline stage (pp=1 ⇒ the whole model).
#[derive(Clone, Debug, Default)]
pub struct OpBreakdown {
    pub preproj: f64,
    pub attn_prefill: f64,
    pub attn_decode: f64,
    pub postproj: f64,
    pub ffn_ln1: f64,
    pub ffn_ln2: f64,
    pub others: f64,
    pub comm: f64,
}

impl OpBreakdown {
    pub fn linear(&self) -> f64 {
        self.preproj + self.postproj + self.ffn_ln1 + self.ffn_ln2
    }

    pub fn attn(&self) -> f64 {
        self.attn_prefill + self.attn_decode
    }

    pub fn total(&self) -> f64 {
        self.linear() + self.attn() + self.others + self.comm
    }

    pub fn op(&self, op: Op) -> f64 {
        match op {
            Op::PreProj => self.preproj,
            Op::Attn => self.attn(),
            Op::PostProj => self.postproj,
            Op::FfnLn1 => self.ffn_ln1,
            Op::FfnLn2 => self.ffn_ln2,
            Op::Others => self.others,
        }
    }
}

/// Fraction of block runtime attributed to `others` (layernorms,
/// activations, residuals) — the paper measures <5% (§3.1).
const OTHERS_FRACTION: f64 = 0.04;

#[derive(Clone, Debug)]
pub struct CostModel {
    pub model: ModelConfig,
    pub gpu: GpuConfig,
    /// Tensor-parallel degree: shards flops/bytes of every op.
    pub tp: usize,
    /// Layers executed by one pipeline stage.
    pub layers_per_stage: usize,
}

impl CostModel {
    pub fn new(model: ModelConfig, gpu: GpuConfig) -> Self {
        let layers = model.n_layers;
        CostModel { model, gpu, tp: 1, layers_per_stage: layers }
    }

    pub fn for_deployment(d: &Deployment) -> Self {
        let layers = d.model.n_layers / d.parallel.pp;
        CostModel { model: d.model.clone(), gpu: d.gpu.clone(), tp: d.parallel.tp, layers_per_stage: layers }
    }

    fn bytes_per_el(&self) -> f64 {
        self.model.bytes_per_param as f64
    }

    /// Round the matmul token dimension up to the hardware tile — thread
    /// blocks past the boundary do wasted work (§4.4, Fig. 7).
    pub fn tile_round_up(&self, tokens: usize) -> usize {
        let t = self.gpu.tile;
        tokens.div_ceil(t) * t
    }

    /// Fig.-4a saturation point: the token count at which linear matmuls
    /// reach full utilization, scaled from the per-GPU reference (hidden
    /// 5120) — wider layers saturate at fewer tokens (§4.2: GPT-3 peaks at
    /// chunk 256 on A100 while LLaMA-13B needs 512 on A6000).
    pub fn sat_tokens(&self) -> f64 {
        let h = self.model.hidden as f64;
        (self.gpu.sat_tokens_ref * (5120.0 / h).powi(2)).max(1.0)
    }

    /// Matmul utilization ramp below the saturation point (latency-bound
    /// small GEMMs). util ∈ (alpha, 1].
    fn mm_util(&self, tokens_padded: f64) -> f64 {
        let a = self.gpu.sat_ramp_alpha;
        (a + (1.0 - a) * tokens_padded / self.sat_tokens()).min(1.0)
    }

    /// One linear operator [k,n] applied to `tokens` rows, per layer.
    fn linear_op_time(&self, tokens: usize, k: usize, n: usize) -> f64 {
        if tokens == 0 {
            return 0.0;
        }
        let m = self.tile_round_up(tokens) as f64;
        let (k, n) = (k as f64, n as f64 / self.tp as f64);
        let b = self.bytes_per_el();
        let flops = 2.0 * m * k * n;
        let bytes = (k * n + m * (k + n)) * b; // weights + activations
        let t_compute = flops / (self.gpu.matmul_flops() * self.mm_util(m));
        let t_memory = bytes / self.gpu.weight_bw();
        t_compute.max(t_memory) + self.gpu.kernel_overhead_s
    }

    /// Per-layer time of each linear op over a fused batch of `tokens` rows.
    pub fn linear_layer_times(&self, tokens: usize) -> (f64, f64, f64, f64) {
        let h = self.model.hidden;
        let h2 = self.model.ffn_hidden;
        (
            self.linear_op_time(tokens, h, 3 * h), // preproj [H,3H]
            self.linear_op_time(tokens, h, h),     // postproj [H,H]
            self.linear_op_time(tokens, h, h2),    // ffn_ln1 [H,H2]
            self.linear_op_time(tokens, h2, h),    // ffn_ln2 [H2,H]
        )
    }

    /// Attention-kernel utilization ramp over the query count (few-query
    /// chunks underutilize SMs — the second component of the §4.2 chunking
    /// overhead, Fig. 13a).
    fn attn_util(&self, queries: f64) -> f64 {
        let a = self.gpu.attn_ramp_alpha;
        (a + (1.0 - a) * queries / self.gpu.attn_sat_tokens).min(1.0)
    }

    /// Attention time for one prefill chunk (per layer): the chunk's C
    /// queries attend to `history + C` keys — every chunk after the first
    /// re-reads the whole KV prefix (the §4.2 chunking overhead).
    pub fn attn_prefill_time(&self, chunk: usize, history: usize) -> f64 {
        if chunk == 0 {
            return 0.0;
        }
        let h = self.model.hidden as f64 / self.tp as f64;
        let c = chunk as f64;
        let hist = history as f64;
        let b = self.bytes_per_el();
        // QK^T + PV: 2 matmuls, each 2·H·(sum over queries of visible keys)
        let visible = c * hist + c * (c + 1.0) / 2.0;
        let flops = 4.0 * h * visible;
        // KV prefix re-read + chunk q/k/v/out activations
        let bytes = (hist + c) * 2.0 * h * b + 4.0 * c * h * b;
        let t_compute = flops / (self.gpu.attn_flops() * self.attn_util(c));
        let t = t_compute.max(bytes / self.gpu.attn_bw());
        t + self.gpu.kernel_overhead_s
    }

    /// Attention time for a batch of decode lanes (per layer). Memory-bound:
    /// each lane streams its whole KV row.
    pub fn attn_decode_time(&self, kv_lens: &[usize]) -> f64 {
        if kv_lens.is_empty() {
            return 0.0;
        }
        let h = self.model.hidden as f64 / self.tp as f64;
        let b = self.bytes_per_el();
        let total_kv: f64 = kv_lens.iter().map(|&k| (k + 1) as f64).sum();
        let flops = 4.0 * h * total_kv;
        let bytes = total_kv * 2.0 * h * b;
        let t = (flops / self.gpu.attn_flops()).max(bytes / self.gpu.attn_bw());
        t + self.gpu.kernel_overhead_s
    }

    /// TP all-reduce time per layer (two per layer — §2.3), for `tokens`
    /// rows of activations.
    fn comm_time(&self, tokens: usize) -> f64 {
        if self.tp == 1 || tokens == 0 {
            return 0.0;
        }
        let bytes = 2.0 * tokens as f64 * self.model.hidden as f64 * self.bytes_per_el();
        // ring all-reduce moves 2·(tp-1)/tp of the buffer per GPU
        let factor = 2.0 * (self.tp as f64 - 1.0) / self.tp as f64;
        bytes * factor / (self.gpu.allreduce_bw_gbps * 1e9)
    }

    /// Full iteration breakdown for one batch on one pipeline stage.
    ///
    /// Linear ops run over the *fused* token count (prefill chunks +
    /// decode lanes together — decode-maximal fusion); attention runs
    /// separately per phase, as the paper prescribes (§4.3.1).
    pub fn iteration(&self, shape: &BatchShape) -> OpBreakdown {
        let tokens = shape.total_tokens();
        let layers = self.layers_per_stage as f64;
        let (pre, post, f1, f2) = self.linear_layer_times(tokens);
        let attn_p: f64 = shape
            .prefill
            .iter()
            .map(|p| self.attn_prefill_time(p.chunk, p.history))
            .sum();
        let kv_lens: Vec<usize> = shape.decode.iter().map(|d| d.kv_len).collect();
        let attn_d = self.attn_decode_time(&kv_lens);
        let mut bd = OpBreakdown {
            preproj: pre * layers,
            attn_prefill: attn_p * layers,
            attn_decode: attn_d * layers,
            postproj: post * layers,
            ffn_ln1: f1 * layers,
            ffn_ln2: f2 * layers,
            others: 0.0,
            comm: self.comm_time(tokens) * layers,
        };
        bd.others = (bd.linear() + bd.attn()) * OTHERS_FRACTION;
        bd
    }

    /// Total iteration time, seconds.
    pub fn iteration_time(&self, shape: &BatchShape) -> f64 {
        self.iteration(shape).total()
    }

    /// Time for the un-fused baseline to run the same work as a hybrid
    /// batch: prefill-only batch then decode-only batch (two iterations).
    pub fn split_time(&self, shape: &BatchShape) -> f64 {
        let p = BatchShape { prefill: shape.prefill.clone(), decode: vec![] };
        let d = BatchShape { prefill: vec![], decode: shape.decode.clone() };
        let mut t = 0.0;
        if !shape.prefill.is_empty() {
            t += self.iteration_time(&p);
        }
        if !shape.decode.is_empty() {
            t += self.iteration_time(&d);
        }
        t
    }

    /// Arithmetic intensity (FLOPs per byte of memory traffic) of one
    /// operator for a batch processing `tokens` rows against `kv_len`
    /// context (Fig. 4b). For linear ops the phase only enters through the
    /// token count; for attention the phase changes the query count.
    pub fn arithmetic_intensity(&self, op: Op, tokens: usize, kv_len: usize) -> f64 {
        let h = self.model.hidden as f64;
        let h2 = self.model.ffn_hidden as f64;
        let b = self.bytes_per_el();
        let t = tokens as f64;
        let lin = |k: f64, n: f64| (2.0 * t * k * n) / ((k * n + t * (k + n)) * b);
        match op {
            Op::PreProj => lin(h, 3.0 * h),
            Op::PostProj => lin(h, h),
            Op::FfnLn1 => lin(h, h2),
            Op::FfnLn2 => lin(h2, h),
            Op::Attn => {
                // queries = tokens, visible keys ≈ kv_len + tokens
                let vis = t * (kv_len as f64 + (t + 1.0) / 2.0);
                let flops = 4.0 * h * vis;
                let bytes = ((kv_len as f64 + t) * 2.0 * h + 4.0 * t * h) * b;
                flops / bytes
            }
            Op::Others => 1.0, // elementwise: O(1) flops per byte
        }
    }

    /// Saturation point: smallest tile-aligned token count at which the
    /// linear GEMMs run at full utilization (§4.2's "chunk size that
    /// saturates the GPU").
    pub fn saturation_tokens(&self) -> usize {
        let t = self.sat_tokens().ceil() as usize;
        t.div_ceil(self.gpu.tile) * self.gpu.tile
    }

    /// Seconds per token to rebuild a preempted request's KV by
    /// re-prefilling at the saturated rate — the price of
    /// [`crate::config::PreemptionMode::Recompute`] on resume. Uses a
    /// saturation-sized zero-history chunk (recompute restarts from token
    /// 0, and a resume would batch it as large as the budget allows).
    pub fn recompute_time_per_token(&self) -> f64 {
        let chunk = self.saturation_tokens().max(1);
        self.iteration_time(&BatchShape::prefill_only(&[(chunk, 0)])) / chunk as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuConfig, ModelConfig};

    fn cm() -> CostModel {
        CostModel::new(ModelConfig::llama13b(), GpuConfig::a6000())
    }

    /// Fig. 3: decode per-token cost at B=1 is ~200× prefill per-token.
    #[test]
    fn decode_to_prefill_ratio_at_b1() {
        let m = cm();
        let prefill = BatchShape::prefill_only(&[(1024, 0)]);
        let t_prefill_per_tok = m.iteration_time(&prefill) / 1024.0;
        let decode = BatchShape::decode_only(&[1024]);
        let t_decode_per_tok = m.iteration_time(&decode);
        let ratio = t_decode_per_tok / t_prefill_per_tok;
        assert!((120.0..280.0).contains(&ratio), "ratio={ratio}");
    }

    /// Fig. 3: at B=2 the ratio halves (~100×) — weight stream is shared.
    #[test]
    fn decode_cost_halves_at_b2() {
        let m = cm();
        let d1 = m.iteration_time(&BatchShape::decode_only(&[1024]));
        let d2 = m.iteration_time(&BatchShape::decode_only(&[1024, 1024])) / 2.0;
        let ratio = d1 / d2;
        assert!((1.7..2.1).contains(&ratio), "ratio={ratio}");
    }

    /// Prefill saturates at ~512 tokens for LLaMA-13B on A6000 (§3.1);
    /// A100 needs more tokens (§5.1.2); wider models saturate earlier
    /// (GPT-3 at ~256 on A100, §4.2).
    #[test]
    fn saturation_points() {
        let a6000 = cm().saturation_tokens();
        assert_eq!(a6000, 512, "a6000 sat={a6000}");
        let a100 = CostModel::new(ModelConfig::llama13b(), GpuConfig::a100()).saturation_tokens();
        assert!(a100 > a6000, "a100={a100} a6000={a6000}");
        let gpt3 = CostModel::new(ModelConfig::gpt3(), GpuConfig::a100()).saturation_tokens();
        assert!((128..=384).contains(&gpt3), "gpt3-on-a100 sat={gpt3}");
    }

    /// Fig. 4a: prefill per-token time is near-constant once saturated, and
    /// a 256-token chunk loses only ~12.5% peak throughput (§4.2).
    #[test]
    fn chunk_256_loses_modest_prefill_efficiency() {
        let m = cm();
        let per_tok = |c: usize| m.iteration_time(&BatchShape::prefill_only(&[(c, 0)])) / c as f64;
        let loss = per_tok(256) / per_tok(2048);
        assert!((1.03..1.45).contains(&loss), "loss={loss}");
        // chunk 64 is far worse (Fig. 13b shows ~5× overall prefill cost)
        assert!(per_tok(64) / per_tok(2048) > 2.0);
    }

    /// Table 2 structure: piggybacked decodes cost ~an order of magnitude
    /// less than decode-only ones.
    #[test]
    fn decode_maximal_marginal_cost() {
        let m = cm();
        // hybrid: one 1021-token chunk + 3 decodes at kv=1024
        let hybrid = BatchShape {
            prefill: vec![PrefillItem { chunk: 1021, history: 0 }],
            decode: vec![DecodeItem { kv_len: 1024 }; 3],
        };
        let prefill_only = BatchShape::prefill_only(&[(1021, 0)]);
        let marginal = (m.iteration_time(&hybrid) - m.iteration_time(&prefill_only)) / 3.0;
        let decode_only = m.iteration_time(&BatchShape::decode_only(&[1024; 4])) / 4.0;
        let speedup = decode_only / marginal;
        assert!(speedup > 5.0, "speedup={speedup}");
    }

    /// Fig. 7: crossing a tile boundary by one token bumps iteration time.
    #[test]
    fn tile_quantization_jump() {
        let m = cm();
        let t256 = m.iteration_time(&BatchShape::prefill_only(&[(256, 0)]));
        let t257 = m.iteration_time(&BatchShape::prefill_only(&[(257, 0)]));
        let t384 = m.iteration_time(&BatchShape::prefill_only(&[(384, 0)]));
        assert!(t257 > t256 * 1.05, "jump too small: {t256} -> {t257}");
        // within the same tile bucket the cost is flat
        assert!((t257 - t384).abs() / t384 < 0.02);
    }

    /// §4.2: chunking a prefill re-reads the KV prefix — N chunks cost more
    /// attention time than one full prefill, and smaller chunks cost more.
    #[test]
    fn chunked_prefill_attention_overhead() {
        let m = cm();
        let full: f64 = m.attn_prefill_time(1024, 0);
        let chunks_256: f64 = (0..4).map(|i| m.attn_prefill_time(256, i * 256)).sum();
        let chunks_64: f64 = (0..16).map(|i| m.attn_prefill_time(64, i * 64)).sum();
        assert!(chunks_256 > full);
        assert!(chunks_64 > chunks_256);
        // Fig. 13a: overhead at chunk 64 is large (~3× in the paper)
        assert!(chunks_64 / full > 1.5, "ratio={}", chunks_64 / full);
    }

    /// Attention is a small fraction of a prefill-heavy iteration (Table 2).
    #[test]
    fn attention_is_small_fraction_of_prefill() {
        let m = cm();
        let bd = m.iteration(&BatchShape::prefill_only(&[(1024, 0); 4]));
        assert!(bd.attn() / bd.total() < 0.25, "attn frac {}", bd.attn() / bd.total());
    }

    /// TP reduces per-GPU time but adds communication.
    #[test]
    fn tp_scaling() {
        let mut m8 = CostModel::new(ModelConfig::gpt3(), GpuConfig::a100());
        m8.tp = 8;
        let m1 = CostModel::new(ModelConfig::gpt3(), GpuConfig::a100());
        let shape = BatchShape::prefill_only(&[(512, 0)]);
        let t8 = m8.iteration_time(&shape);
        let t1 = m1.iteration_time(&shape);
        assert!(t8 < t1, "tp8 {t8} < tp1 {t1}");
        assert!(m8.iteration(&shape).comm > 0.0);
    }

    /// Fused hybrid beats running the same work split in two (the paper's
    /// core claim, Table 2 / Fig. 8).
    #[test]
    fn fusion_beats_split() {
        let m = cm();
        let hybrid = BatchShape {
            prefill: vec![PrefillItem { chunk: 256, history: 0 }],
            decode: vec![DecodeItem { kv_len: 1024 }; 17],
        };
        assert!(m.iteration_time(&hybrid) < m.split_time(&hybrid));
    }

    #[test]
    fn empty_batch_costs_nothing_but_overhead() {
        let m = cm();
        let t = m.iteration_time(&BatchShape::default());
        assert!(t < 1e-3, "{t}");
    }
}
