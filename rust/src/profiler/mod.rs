//! Profile-driven runtime prediction — the paper's §5.3 simulation
//! methodology, built as its own substrate.
//!
//! The paper: *"We first profile the runtime for each operation … for
//! various batch sizes and sequence lengths … Finally, we build a
//! regression model to extrapolate and predict these values for missing
//! data points"*, validated to within 5% of the empirical values.
//!
//! Here the "empirical" source is the calibrated roofline cost model (our
//! testbed — DESIGN.md §3); this module builds the sparse profile grid and
//! the interpolating predictor exactly as the paper does, and the pipeline
//! simulator consumes *only* the predictor, mirroring the paper's
//! separation between profiling and simulation.

use crate::costmodel::{BatchShape, CostModel};

/// Piecewise-linear interpolation table over one axis.
#[derive(Clone, Debug)]
struct Axis {
    pts: Vec<usize>,
}

impl Axis {
    fn log_grid(max: usize) -> Self {
        let mut pts = vec![0usize, 1, 2, 4, 8, 16, 32, 64, 128, 192, 256, 384, 512, 768, 1024];
        let mut v = 1536;
        while v <= max {
            pts.push(v);
            v += 512;
        }
        pts.retain(|&p| p <= max);
        if *pts.last().unwrap() != max {
            pts.push(max);
        }
        Axis { pts }
    }

    /// Grid on tile multiples — the token axes must be tile-aligned because
    /// tile quantization makes the cost a step function between multiples
    /// (interpolating across a step would smear Fig. 7's jumps).
    fn tile_grid(tile: usize, max: usize) -> Self {
        let mut pts: Vec<usize> = (0..=max.div_ceil(tile)).map(|i| i * tile).collect();
        if *pts.last().unwrap() < max.div_ceil(tile) * tile {
            pts.push(max.div_ceil(tile) * tile);
        }
        Axis { pts }
    }

    /// Bracketing indices and interpolation weight for a query point.
    fn locate(&self, x: usize) -> (usize, usize, f64) {
        if x <= self.pts[0] {
            return (0, 0, 0.0);
        }
        if x >= *self.pts.last().unwrap() {
            let i = self.pts.len() - 1;
            return (i, i, 0.0);
        }
        let hi = self.pts.partition_point(|&p| p < x);
        let lo = hi - 1;
        if self.pts[hi] == x {
            return (hi, hi, 0.0);
        }
        let w = (x - self.pts[lo]) as f64 / (self.pts[hi] - self.pts[lo]) as f64;
        (lo, hi, w)
    }
}

/// Profiled + regressed iteration-time predictor for one deployment stage.
///
/// Three tables are built, matching how the simulator composes batches:
///  * prefill-chunk time over (chunk, history)
///  * decode-batch time over (lanes, kv_len)
///  * fused hybrid linear uplift over total tokens
#[derive(Clone, Debug)]
pub struct Profiler {
    cm: CostModel,
    chunk_axis: Axis,
    hist_axis: Axis,
    lanes_axis: Axis,
    kv_axis: Axis,
    /// t_prefill[chunk][hist]
    t_prefill: Vec<Vec<f64>>,
    /// t_decode[lanes][kv]
    t_decode: Vec<Vec<f64>>,
    /// Marginal hybrid time over (chunk, lanes), profiled at two KV
    /// lengths; queries regress linearly in the mean KV (the attention
    /// share of the marginal cost is linear in context length).
    t_hybrid_extra_lo: Vec<Vec<f64>>,
    t_hybrid_extra_hi: Vec<Vec<f64>>,
    lo_kv: usize,
    ref_kv: usize,
}

impl Profiler {
    /// Profile the deployment over a grid bounded by `max_seq_len` tokens
    /// and `max_batch` decode lanes.
    pub fn build(cm: CostModel, max_seq_len: usize, max_batch: usize) -> Self {
        // chunk axis on tile multiples (tile quantization is a step
        // function); queries round the chunk up to the padded size.
        let chunk_axis = Axis::tile_grid(cm.gpu.tile, max_seq_len);
        let hist_axis = Axis::log_grid(max_seq_len);
        let lanes_axis = Axis { pts: (0..=max_batch).collect() };
        let kv_axis = Axis::log_grid(max_seq_len);
        let ref_kv = max_seq_len / 2;

        let t_prefill = chunk_axis
            .pts
            .iter()
            .map(|&c| {
                hist_axis
                    .pts
                    .iter()
                    .map(|&h| {
                        if c == 0 {
                            0.0
                        } else {
                            cm.iteration_time(&BatchShape::prefill_only(&[(c, h)]))
                        }
                    })
                    .collect()
            })
            .collect();

        let t_decode = lanes_axis
            .pts
            .iter()
            .map(|&n| {
                kv_axis
                    .pts
                    .iter()
                    .map(|&kv| {
                        if n == 0 {
                            0.0
                        } else {
                            cm.iteration_time(&BatchShape::decode_only(&vec![kv; n]))
                        }
                    })
                    .collect()
            })
            .collect();

        let lo_kv = 1usize;
        // The marginal table is profiled on ALIGNED hybrids — chunk shrunk
        // so chunk + lanes lands on the grid's (tile-multiple) fused size,
        // exactly the §4.4 composition the SARATHI scheduler emits. Queries
        // key on the tile-padded fused token count, so tile-boundary
        // crossings never smear across grid cells.
        let extra_table = |kv: usize| -> Vec<Vec<f64>> {
            chunk_axis
                .pts
                .iter()
                .map(|&fused| {
                    lanes_axis
                        .pts
                        .iter()
                        .map(|&n| {
                            if fused == 0 || n == 0 {
                                0.0
                            } else {
                                let c = fused.saturating_sub(n).max(1);
                                let hybrid = BatchShape::hybrid(c, 0, &vec![kv; n]);
                                let alone = BatchShape::prefill_only(&[(c, 0)]);
                                cm.iteration_time(&hybrid) - cm.iteration_time(&alone)
                            }
                        })
                        .collect()
                })
                .collect()
        };
        let t_hybrid_extra_lo = extra_table(lo_kv);
        let t_hybrid_extra_hi = extra_table(ref_kv);

        Profiler {
            cm,
            chunk_axis,
            hist_axis,
            lanes_axis,
            kv_axis,
            t_prefill,
            t_decode,
            t_hybrid_extra_lo,
            t_hybrid_extra_hi,
            lo_kv,
            ref_kv,
        }
    }

    fn bilinear(table: &[Vec<f64>], a: (usize, usize, f64), b: (usize, usize, f64)) -> f64 {
        let (a0, a1, wa) = a;
        let (b0, b1, wb) = b;
        let f00 = table[a0][b0];
        let f01 = table[a0][b1];
        let f10 = table[a1][b0];
        let f11 = table[a1][b1];
        f00 * (1.0 - wa) * (1.0 - wb) + f01 * (1.0 - wa) * wb + f10 * wa * (1.0 - wb) + f11 * wa * wb
    }

    /// Predicted prefill-only iteration time. The chunk is queried at its
    /// tile-padded size (matching the hardware's step-function cost).
    pub fn prefill_time(&self, chunk: usize, history: usize) -> f64 {
        let padded = self.cm.tile_round_up(chunk);
        Self::bilinear(
            &self.t_prefill,
            self.chunk_axis.locate(padded),
            self.hist_axis.locate(history),
        )
    }

    /// Predicted decode-only iteration time (lanes at ~equal kv lengths;
    /// heterogeneous batches query the mean kv — the regression treatment).
    pub fn decode_time(&self, lanes: usize, mean_kv: usize) -> f64 {
        Self::bilinear(
            &self.t_decode,
            self.lanes_axis.locate(lanes),
            self.kv_axis.locate(mean_kv),
        )
    }

    /// Predicted time for an arbitrary batch shape (what the pipeline
    /// simulator calls per micro-batch).
    pub fn predict(&self, shape: &BatchShape) -> f64 {
        if shape.is_empty() {
            return 0.0;
        }
        let lanes = shape.decode.len();
        let mean_kv = if lanes == 0 {
            0
        } else {
            shape.decode.iter().map(|d| d.kv_len).sum::<usize>() / lanes
        };
        match (shape.prefill.len(), lanes) {
            (0, _) => self.decode_time(lanes, mean_kv),
            (_, 0) => shape.prefill.iter().map(|p| self.prefill_time(p.chunk, p.history)).sum(),
            _ => {
                // hybrid: base prefill evaluated at the padded-fused size
                // minus the lanes (so a tile boundary crossed by the fused
                // batch is charged), plus the aligned-marginal table for
                // the decode lanes, regressed linearly in mean KV.
                let fused = self.cm.tile_round_up(shape.prefill_tokens() + lanes);
                let hist = shape.prefill.first().map(|p| p.history).unwrap_or(0);
                let base = self.prefill_time(fused.saturating_sub(lanes).max(1), hist);
                let a = self.chunk_axis.locate(fused);
                let b = self.lanes_axis.locate(lanes);
                let lo = Self::bilinear(&self.t_hybrid_extra_lo, a, b);
                let hi = Self::bilinear(&self.t_hybrid_extra_hi, a, b);
                // linear-in-kv regression between the two profiled points
                let w = ((mean_kv as f64 - self.lo_kv as f64)
                    / (self.ref_kv as f64 - self.lo_kv as f64))
                    .max(0.0);
                base + lo + (hi - lo) * w
            }
        }
    }

    pub fn cost_model(&self) -> &CostModel {
        &self.cm
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuConfig, ModelConfig};
    use crate::costmodel::CostModel;

    fn profiler() -> Profiler {
        let cm = CostModel::new(ModelConfig::llama13b(), GpuConfig::a6000());
        Profiler::build(cm, 4096, 32)
    }

    /// The paper validates its simulator to within 5% of empirical values;
    /// hold the predictor to the same bar on grid-off points.
    #[test]
    fn predictor_within_5pct_of_model_prefill() {
        let p = profiler();
        for (c, h) in [(100, 0), (300, 300), (777, 1111), (2000, 1000), (513, 0)] {
            let truth = p.cm.iteration_time(&BatchShape::prefill_only(&[(c, h)]));
            let pred = p.prefill_time(c, h);
            let err = (pred - truth).abs() / truth;
            assert!(err < 0.05, "chunk={c} hist={h} err={err:.3}");
        }
    }

    #[test]
    fn predictor_within_5pct_of_model_decode() {
        let p = profiler();
        for (n, kv) in [(1, 500), (4, 1000), (7, 333), (18, 900), (25, 3000)] {
            let truth = p.cm.iteration_time(&BatchShape::decode_only(&vec![kv; n]));
            let pred = p.decode_time(n, kv);
            let err = (pred - truth).abs() / truth;
            assert!(err < 0.05, "lanes={n} kv={kv} err={err:.3}");
        }
    }

    #[test]
    fn hybrid_prediction_close_to_model() {
        let p = profiler();
        for (c, n, kv) in [(256, 3, 1000), (512, 17, 800), (128, 9, 2048)] {
            let shape = BatchShape::hybrid(c, 0, &vec![kv; n]);
            let truth = p.cm.iteration_time(&shape);
            let pred = p.predict(&shape);
            let err = (pred - truth).abs() / truth;
            assert!(err < 0.10, "c={c} n={n} kv={kv} err={err:.3}");
        }
    }

    #[test]
    fn exact_on_grid_points() {
        let p = profiler();
        let truth = p.cm.iteration_time(&BatchShape::prefill_only(&[(256, 512)]));
        assert!((p.prefill_time(256, 512) - truth).abs() < 1e-12);
    }

    #[test]
    fn empty_shape_is_free() {
        assert_eq!(profiler().predict(&BatchShape::default()), 0.0);
    }
}
