//! Table/CSV reporting for the figure harness: aligned console tables that
//! mirror the paper's rows, plus CSV files under out/ for plotting.
//!
//! [`timeline`] renders the coordinator's lifecycle event stream as a
//! Chrome trace-event / Perfetto JSON timeline (`--trace-out`).

pub mod timeline;

use std::fmt::Write as _;
use std::fs;
use std::path::Path;

/// A simple column-aligned table with a title.
#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "## {}", self.title);
        let line = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(out, "{}", line(&self.headers, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * (widths.len() - 1);
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        out
    }

    /// Write as CSV (headers + rows) to `dir/name.csv`.
    pub fn write_csv(&self, dir: &Path, name: &str) -> std::io::Result<()> {
        fs::create_dir_all(dir)?;
        let mut s = String::new();
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let _ = writeln!(s, "{}", self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        for row in &self.rows {
            let _ = writeln!(s, "{}", row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        fs::write(dir.join(format!("{name}.csv")), s)
    }
}

/// Format seconds as milliseconds with 2 decimals.
pub fn ms(t: f64) -> String {
    format!("{:.2}", t * 1e3)
}

/// Format a ratio as `1.23x`.
pub fn x(r: f64) -> String {
    format!("{r:.2}x")
}

/// Format a float with 3 significant decimals.
pub fn f3(v: f64) -> String {
    format!("{v:.3}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_columns() {
        let mut t = Table::new("demo", &["a", "long-header"]);
        t.row(vec!["xxxx".into(), "1".into()]);
        let r = t.render();
        assert!(r.contains("## demo"));
        assert!(r.lines().count() >= 4);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_mismatch_panics() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("demo", &["a"]);
        t.row(vec!["va,l".into()]);
        let dir = std::env::temp_dir().join("sarathi_test_csv");
        t.write_csv(&dir, "t").unwrap();
        let s = std::fs::read_to_string(dir.join("t.csv")).unwrap();
        assert!(s.contains("\"va,l\""));
    }
}
