//! Chrome trace-event / Perfetto JSON export of the coordinator's
//! lifecycle event stream (`--trace-out trace.json`).
//!
//! Layout follows the trace-event convention: each replica is a
//! *process* (`pid`), each pp stream / pipeline stage is a *thread*
//! (`tid`), and each interconnect transfer lane is an extra thread
//! under the SOURCE replica's process (`tid = 1000 + dst`). Batch
//! executions, bubbles and KV handoffs are complete (`"ph":"X"`) spans;
//! request lifecycle edges are instants (`"ph":"i"`). Per-token
//! `TokenEmitted` events are deliberately NOT exported — at one instant
//! per generated token they dominate file size while the batch spans
//! already show decode cadence; the decomposition consumes them
//! upstream instead.
//!
//! Times are simulated seconds scaled to the format's microseconds.
//! Open the file at <https://ui.perfetto.dev> or `chrome://tracing`.

use std::path::Path;

use crate::coordinator::metrics::{ensure_parent_dir, JSONL_SCHEMA_VERSION};
use crate::coordinator::trace::{EventKind, TraceEvent};

/// Transfer-lane threads live at `tid = TRANSFER_TID_BASE + dst` under
/// the source replica's process, clear of real stream/stage lanes.
pub const TRANSFER_TID_BASE: u64 = 1000;

fn us(t: f64) -> f64 {
    t * 1e6
}

/// Render `events` as one Chrome trace-event JSON document. Events
/// should already be canonically merged ([`merge_streams`]) — the
/// format itself is order-insensitive, but a deterministic input keeps
/// the output byte-stable across `--threads`.
///
/// [`merge_streams`]: crate::coordinator::trace::merge_streams
pub fn chrome_trace_json(events: &[TraceEvent]) -> String {
    use std::collections::BTreeSet;
    use std::fmt::Write as _;
    let mut pids: BTreeSet<u32> = BTreeSet::new();
    let mut tids: BTreeSet<(u32, u64)> = BTreeSet::new();
    for e in events {
        if let EventKind::KvTransfer { src, dst, .. } = &e.kind {
            pids.insert(*src as u32);
            tids.insert((*src as u32, TRANSFER_TID_BASE + *dst as u64));
        } else {
            pids.insert(e.replica);
            tids.insert((e.replica, e.lane as u64));
        }
    }
    let mut out = String::with_capacity(256 + events.len() * 128);
    let _ = write!(
        out,
        "{{\"schema_version\":{JSONL_SCHEMA_VERSION},\"displayTimeUnit\":\"ms\",\
         \"traceEvents\":["
    );
    let mut first = true;
    let mut emit = |out: &mut String, first: &mut bool, obj: String| {
        if !*first {
            out.push(',');
        }
        *first = false;
        out.push_str(&obj);
    };
    // process / thread naming metadata first (viewers apply it anywhere,
    // but leading metadata keeps the file skimmable)
    for &pid in &pids {
        emit(
            &mut out,
            &mut first,
            format!(
                "{{\"ph\":\"M\",\"pid\":{pid},\"name\":\"process_name\",\
                 \"args\":{{\"name\":\"replica {pid}\"}}}}"
            ),
        );
    }
    for &(pid, tid) in &tids {
        let name = if tid >= TRANSFER_TID_BASE {
            format!("kv-transfer \u{2192} replica {}", tid - TRANSFER_TID_BASE)
        } else {
            format!("stream {tid}")
        };
        emit(
            &mut out,
            &mut first,
            format!(
                "{{\"ph\":\"M\",\"pid\":{pid},\"tid\":{tid},\"name\":\"thread_name\",\
                 \"args\":{{\"name\":\"{name}\"}}}}"
            ),
        );
    }
    for e in events {
        let (pid, tid) = (e.replica, e.lane as u64);
        let obj = match &e.kind {
            EventKind::BatchSpan {
                batch,
                end,
                prefill_tokens,
                decode_tokens,
                n_prefill,
                n_decode,
                budget_capped,
            } => format!(
                "{{\"ph\":\"X\",\"pid\":{pid},\"tid\":{tid},\"ts\":{:.3},\"dur\":{:.3},\
                 \"name\":\"batch {batch}\",\"cat\":\"batch\",\"args\":{{\
                 \"prefill_tokens\":{prefill_tokens},\"decode_tokens\":{decode_tokens},\
                 \"n_prefill\":{n_prefill},\"n_decode\":{n_decode},\
                 \"budget_capped\":{budget_capped}}}}}",
                us(e.at),
                us(end - e.at),
            ),
            EventKind::Bubble { end, class } => format!(
                "{{\"ph\":\"X\",\"pid\":{pid},\"tid\":{tid},\"ts\":{:.3},\"dur\":{:.3},\
                 \"name\":\"{}\",\"cat\":\"bubble\",\"args\":{{\"class\":\"{}\"}}}}",
                us(e.at),
                us(end - e.at),
                class.as_str(),
                class.as_str(),
            ),
            EventKind::KvTransfer { request, src, dst, end } => format!(
                "{{\"ph\":\"X\",\"pid\":{src},\"tid\":{},\"ts\":{:.3},\"dur\":{:.3},\
                 \"name\":\"kv req {request}\",\"cat\":\"kv-transfer\",\"args\":{{\
                 \"request\":{request},\"src\":{src},\"dst\":{dst}}}}}",
                TRANSFER_TID_BASE + *dst as u64,
                us(e.at),
                us(end - e.at),
            ),
            EventKind::Arrived { request } => lifecycle(pid, tid, e.at, "arrived", *request, ""),
            EventKind::Queued { request } => lifecycle(pid, tid, e.at, "queued", *request, ""),
            EventKind::PrefixWaitStart { request, hash } => lifecycle(
                pid,
                tid,
                e.at,
                "prefix-wait-start",
                *request,
                &format!(",\"hash\":{hash}"),
            ),
            EventKind::PrefixWaitEnd { request, hash, fallback } => lifecycle(
                pid,
                tid,
                e.at,
                "prefix-wait-end",
                *request,
                &format!(",\"hash\":{hash},\"fallback\":{fallback}"),
            ),
            EventKind::Admitted { request, shared_tokens, private_tokens } => lifecycle(
                pid,
                tid,
                e.at,
                "admitted",
                *request,
                &format!(",\"shared_tokens\":{shared_tokens},\"private_tokens\":{private_tokens}"),
            ),
            EventKind::Resumed { request, swap_tokens } => lifecycle(
                pid,
                tid,
                e.at,
                "resumed",
                *request,
                &format!(",\"swap_tokens\":{swap_tokens}"),
            ),
            EventKind::ChunkScheduled { request, batch, start, len } => lifecycle(
                pid,
                tid,
                e.at,
                "chunk",
                *request,
                &format!(",\"batch\":{batch},\"start\":{start},\"len\":{len}"),
            ),
            EventKind::Preempted { request, evicted_tokens } => lifecycle(
                pid,
                tid,
                e.at,
                "preempted",
                *request,
                &format!(",\"evicted_tokens\":{evicted_tokens}"),
            ),
            EventKind::FirstToken { request } => {
                lifecycle(pid, tid, e.at, "first-token", *request, "")
            }
            EventKind::TokenEmitted { .. } => continue,
            EventKind::Completed { request } => {
                lifecycle(pid, tid, e.at, "completed", *request, "")
            }
            EventKind::Rejected { request } => lifecycle(pid, tid, e.at, "rejected", *request, ""),
        };
        emit(&mut out, &mut first, obj);
    }
    out.push_str("]}");
    out
}

fn lifecycle(pid: u32, tid: u64, at: f64, name: &str, request: usize, extra: &str) -> String {
    format!(
        "{{\"ph\":\"i\",\"pid\":{pid},\"tid\":{tid},\"ts\":{:.3},\"s\":\"t\",\
         \"name\":\"{name}\",\"cat\":\"lifecycle\",\"args\":{{\"request\":{request}{extra}}}}}",
        us(at),
    )
}

/// Write the Chrome trace for `events` to `path`.
pub fn write_chrome_trace(path: &Path, events: &[TraceEvent]) -> std::io::Result<()> {
    ensure_parent_dir(path)?;
    std::fs::write(path, chrome_trace_json(events))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::trace::BubbleClass;

    fn ev(at: f64, replica: u32, lane: u32, seq: u64, kind: EventKind) -> TraceEvent {
        TraceEvent { at, replica, lane, seq, kind }
    }

    #[test]
    fn export_names_processes_threads_and_span_categories() {
        let events = vec![
            ev(
                0.0,
                0,
                0,
                0,
                EventKind::BatchSpan {
                    batch: 0,
                    end: 0.5,
                    prefill_tokens: 256,
                    decode_tokens: 4,
                    n_prefill: 1,
                    n_decode: 4,
                    budget_capped: false,
                },
            ),
            ev(0.5, 0, 0, 1, EventKind::Bubble { end: 0.75, class: BubbleClass::KvStarved }),
            ev(0.2, 1, 0, 0, EventKind::KvTransfer { request: 3, src: 1, dst: 2, end: 0.4 }),
            ev(0.1, 0, 0, 2, EventKind::FirstToken { request: 7 }),
            ev(0.15, 0, 0, 3, EventKind::TokenEmitted { request: 7 }),
        ];
        let json = chrome_trace_json(&events);
        assert!(json.starts_with("{\"schema_version\":2,"));
        assert!(json.contains("\"traceEvents\":["));
        assert!(json.ends_with("]}"));
        // processes 0 and 1 named; transfer lane thread under the source
        assert!(json.contains("\"args\":{\"name\":\"replica 0\"}"));
        assert!(json.contains("\"args\":{\"name\":\"replica 1\"}"));
        assert!(json.contains(&format!("\"tid\":{}", TRANSFER_TID_BASE + 2)));
        // spans carry their categories and annotations
        assert!(json.contains("\"cat\":\"batch\""));
        assert!(json.contains("\"prefill_tokens\":256"));
        assert!(json.contains("\"cat\":\"bubble\""));
        assert!(json.contains("\"class\":\"kv-starved\""));
        assert!(json.contains("\"cat\":\"kv-transfer\""));
        // batch span: ts 0, dur 0.5 s = 500000 µs
        assert!(json.contains("\"dur\":500000.000"));
        // lifecycle instant present; per-token events skipped
        assert!(json.contains("\"name\":\"first-token\""));
        assert!(!json.contains("token-emitted"));
        // balanced braces/brackets — cheap structural sanity
        let opens = json.matches('{').count();
        let closes = json.matches('}').count();
        assert_eq!(opens, closes);
        assert_eq!(json.matches('[').count(), json.matches(']').count());
    }

    #[test]
    fn empty_stream_is_still_a_valid_document() {
        let json = chrome_trace_json(&[]);
        assert_eq!(
            json,
            format!(
                "{{\"schema_version\":{JSONL_SCHEMA_VERSION},\
                 \"displayTimeUnit\":\"ms\",\"traceEvents\":[]}}"
            )
        );
    }

    #[test]
    fn write_creates_parent_dirs_and_the_file() {
        let dir = std::env::temp_dir().join("sarathi_test_timeline");
        let path = dir.join("nested").join("trace.json");
        let events =
            vec![ev(1.0, 0, 0, 0, EventKind::Bubble { end: 2.0, class: BubbleClass::NoWork })];
        write_chrome_trace(&path, &events).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"no-work\""));
        std::fs::remove_dir_all(&dir).ok();
    }
}
