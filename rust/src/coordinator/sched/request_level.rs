//! FasterTransformer-style request-level scheduling (§4.1, §5.1 baseline):
//! pick a batch of requests, run prefill-only then decode-only iterations,
//! and admit the next batch only when *every* request in the current one
//! has completed.

use super::super::batch::{Batch, WorkItem};
use super::super::kv::KvManager;
use super::super::pool::RequestPool;
use super::super::request::Phase;
use super::admission::InfeasiblePolicy;
use super::{Admission, Scheduler};

pub struct RequestLevelScheduler {
    max_batch: usize,
    /// The ids of the batch currently being driven to completion.
    running: Vec<usize>,
    /// Panic (closed-loop default) or reject (open-loop serving) requests
    /// whose lifetime KV can never fit the pool.
    infeasible: InfeasiblePolicy,
}

impl RequestLevelScheduler {
    pub fn new(max_batch: usize) -> Self {
        RequestLevelScheduler {
            max_batch,
            running: Vec::new(),
            infeasible: InfeasiblePolicy::Panic,
        }
    }

    pub fn with_infeasible(mut self, policy: InfeasiblePolicy) -> Self {
        self.infeasible = policy;
        self
    }
}

impl Scheduler for RequestLevelScheduler {
    fn admission(&self) -> Admission {
        Admission::default().with_infeasible(self.infeasible)
    }

    /// Request-level admission: a whole new batch at once, and only after
    /// the previous batch fully drains — the policy's defining delay.
    /// Overrides `admit_capped` (not `admit`) so the pipeline's
    /// per-stream cap reaches the custom logic too.
    fn admit_capped(
        &mut self,
        pool: &mut RequestPool,
        kv: &mut KvManager,
        now: f64,
        extra_cap: Option<usize>,
    ) {
        // retire members that no longer hold KV: completed ones, and any
        // preempted member (swapped back to Queued by the engine) — the
        // latter is re-admitted FCFS with a later batch instead of wedging
        // the loop as a permanently-queued "running" request
        self.running.retain(|&id| pool.get(id).is_admitted());
        if !self.running.is_empty() {
            return;
        }
        let mut gate = self.admission();
        if let Some(cap) = extra_cap {
            gate.max_active = Some(gate.max_active.map_or(cap, |m| m.min(cap)));
        }
        while self.running.len() < self.max_batch {
            let Some(id) = pool.next_queued(now) else { break };
            if !gate.try_admit_one(pool, kv, id, now) {
                if pool.get(id).rejected_at.is_some() {
                    continue; // rejected as infeasible: keep filling the batch
                }
                break;
            }
            self.running.push(id);
        }
    }

    fn compose(&mut self, pool: &mut RequestPool, _kv: &mut KvManager, _now: f64) -> Batch {
        // prefill-only first: every un-prefilled request submits its FULL
        // remaining prompt in one go (no chunking in the baseline).
        let prefills: Vec<WorkItem> = self
            .running
            .iter()
            .map(|&id| pool.get(id))
            .filter(|r| r.phase() == Phase::Prefill)
            .map(|r| WorkItem::PrefillChunk { req: r.id, start: r.prefilled, len: r.remaining_prompt() })
            .collect();
        if !prefills.is_empty() {
            return Batch::new(prefills);
        }

        // then decode-only until the whole batch drains
        let decodes: Vec<WorkItem> = self
            .running
            .iter()
            .map(|&id| pool.get(id))
            .filter(|r| r.is_decode_ready() && r.remaining_decode() > 0)
            .map(|r| WorkItem::Decode { req: r.id })
            .collect();
        Batch::new(decodes)
    }

    fn name(&self) -> &'static str {
        "request-level"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::RequestSpec;

    fn setup(n: usize) -> (RequestPool, KvManager) {
        let specs: Vec<RequestSpec> =
            (0..n)
                .map(|_| RequestSpec { prompt_len: 64, decode_len: 3, arrival: 0.0, prefix: None })
                .collect();
        (RequestPool::from_specs(&specs), KvManager::new(4))
    }

    #[test]
    fn prefills_whole_prompts_then_decodes() {
        let (mut pool, mut kv) = setup(2);
        let mut s = RequestLevelScheduler::new(4);
        let b = s.schedule(&mut pool, &mut kv, 0.0);
        assert_eq!(b.n_prefill_chunks(), 2);
        assert_eq!(b.prefill_tokens(), 128); // full prompts, no chunking
        assert!(b.validate(&pool, 4).is_ok());
        // apply: both prefilled
        let items: Vec<_> = b.prefill_items().collect();
        for (req, _, len) in items {
            let r = pool.get_mut(req);
            r.prefilled += len;
            r.decoded = 1;
        }
        let b = s.schedule(&mut pool, &mut kv, 1.0);
        assert_eq!(b.n_prefill_chunks(), 0);
        assert_eq!(b.n_decodes(), 2);
    }

    #[test]
    fn reject_policy_skips_infeasible_without_stalling_the_batch() {
        // an infeasible head-of-queue request must be rejected and the
        // batch filled from the traffic behind it (open-loop stance)
        let specs = [
            // 64 blocks: never fits
            RequestSpec { prompt_len: 1024, decode_len: 3, arrival: 0.0, prefix: None },
            RequestSpec { prompt_len: 64, decode_len: 3, arrival: 0.0, prefix: None },
            RequestSpec { prompt_len: 64, decode_len: 3, arrival: 0.0, prefix: None },
        ];
        let mut pool = RequestPool::from_specs(&specs);
        let mut kv = KvManager::paged(16, 16);
        let mut s = RequestLevelScheduler::new(4).with_infeasible(InfeasiblePolicy::Reject);
        let b = s.schedule(&mut pool, &mut kv, 0.0);
        assert_eq!(pool.rejected_count(), 1);
        assert_eq!(b.n_prefill_chunks(), 2, "batch filled past the rejected request");
    }

    #[test]
    fn pipeline_cap_bounds_request_level_admission() {
        // the per-stream cap reaches the custom admit_capped override
        let (mut pool, mut kv) = setup(6);
        let mut s = RequestLevelScheduler::new(4);
        s.admit_capped(&mut pool, &mut kv, 0.0, Some(2));
        assert_eq!(pool.active_count(), 2, "extra cap tightens the batch");
    }

    #[test]
    fn no_admission_until_batch_drains() {
        let (mut pool, mut kv) = setup(6);
        let mut s = RequestLevelScheduler::new(4);
        let b = s.schedule(&mut pool, &mut kv, 0.0);
        assert_eq!(b.n_prefill_chunks(), 4); // batch cap
        // requests 4,5 stay queued even though a slot-less schedule happens
        assert_eq!(pool.in_phase(Phase::Queued).len(), 2);
        // finish the four
        for id in 0..4 {
            let r = pool.get_mut(id);
            r.prefilled = 64;
            r.decoded = 3;
            let blocks = pool.complete(id, 1.0);
            kv.release_seq(blocks);
        }
        let b = s.schedule(&mut pool, &mut kv, 2.0);
        assert_eq!(b.n_prefill_chunks(), 2); // the stragglers enter as a new batch
    }
}
