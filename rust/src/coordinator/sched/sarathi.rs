//! The SARATHI scheduler: chunked-prefills + decode-maximal batching (§4).
//!
//! Every iteration carries at most ONE prefill chunk, sized so the fused
//! token count (chunk + piggybacked decodes) stays tile-aligned (§4.4), and
//! fills the remaining batch slots with every ready decode (§4.3). Prefills
//! are served FCFS, one request chunked to completion at a time.

use super::super::batch::{Batch, WorkItem};
use super::super::kv::KvManager;
use super::super::pool::RequestPool;
use super::super::request::Phase;
use super::admission::InfeasiblePolicy;
use super::{Admission, Scheduler};

pub struct SarathiScheduler {
    /// Target chunk size C (tokens) — the tile-aligned budget for the fused
    /// token count of a decode-maximal batch.
    chunk_size: usize,
    /// Max batch size B from the §4.3.1 capacity formula. At most B−1
    /// decodes piggyback beside the chunk.
    max_batch: usize,
    /// Tile size for the §4.4 alignment rule.
    tile: usize,
    /// Panic (closed-loop default) or reject (open-loop serving) requests
    /// whose lifetime KV can never fit the pool.
    infeasible: InfeasiblePolicy,
}

impl SarathiScheduler {
    pub fn new(chunk_size: usize, max_batch: usize, tile: usize) -> Self {
        assert!(chunk_size > 0, "chunk size must be positive");
        SarathiScheduler { chunk_size, max_batch, tile, infeasible: InfeasiblePolicy::Panic }
    }

    pub fn with_infeasible(mut self, policy: InfeasiblePolicy) -> Self {
        self.infeasible = policy;
        self
    }

    pub fn chunk_size(&self) -> usize {
        self.chunk_size
    }

    /// §4.4's second rule: the chunk budget should be a tile multiple so
    /// the fused matmul dimension stays tile-aligned. Misaligned chunks
    /// (e.g. 320 with tile 128) pay the Fig.-7 quantization penalty —
    /// Fig. 13c measures exactly that. The autotuner only proposes aligned
    /// candidates; this flags hand-picked misaligned configurations.
    pub fn is_tile_aligned(&self) -> bool {
        self.chunk_size % self.tile == 0
    }

    /// §4.4: with n_d piggybacked decodes, shrink the chunk to C − n_d so
    /// the fused matmul token dimension stays at the tile-aligned C.
    fn chunk_budget(&self, n_decodes: usize) -> usize {
        self.chunk_size.saturating_sub(n_decodes).max(1)
    }
}

impl Scheduler for SarathiScheduler {
    fn admission(&self) -> Admission {
        Admission::default().with_infeasible(self.infeasible)
    }

    fn token_budget(&self) -> Option<usize> {
        Some(self.chunk_size)
    }

    fn compose(&mut self, pool: &mut RequestPool, _kv: &mut KvManager, _now: f64) -> Batch {
        // every ready decode piggybacks (up to B−1 when a chunk rides along)
        let decoding: Vec<usize> = pool
            .in_phase_iter(Phase::Decode)
            .filter(|&id| pool.get(id).remaining_decode() > 0)
            .collect();
        let prefilling = pool.first_in_phase(Phase::Prefill);

        let mut items = Vec::new();
        if let Some(id) = prefilling {
            let n_d = decoding.len().min(self.max_batch - 1);
            let budget = self.chunk_budget(n_d);
            let r = pool.get(id);
            let len = budget.min(r.remaining_prompt());
            items.push(WorkItem::PrefillChunk { req: id, start: r.prefilled, len });
            for &d in decoding.iter().take(n_d) {
                items.push(WorkItem::Decode { req: d });
            }
        } else {
            // no prefill work: plain decode-only iteration
            for &d in decoding.iter().take(self.max_batch) {
                items.push(WorkItem::Decode { req: d });
            }
        }
        Batch::new(items)
    }

    fn name(&self) -> &'static str {
        "sarathi"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::RequestSpec;

    fn setup(n_decoding: usize, prompt: usize) -> (RequestPool, KvManager) {
        let mut pool = RequestPool::new();
        let mut kv = KvManager::new(32);
        for _ in 0..n_decoding {
            let spec = RequestSpec { prompt_len: 64, decode_len: 20, arrival: 0.0, prefix: None };
            let id = pool.push(spec);
            let slot = kv.alloc().unwrap();
            pool.admit(id, vec![slot], 0.0);
            let r = pool.get_mut(id);
            r.prefilled = 64;
            r.decoded = 1;
        }
        pool.push(RequestSpec { prompt_len: prompt, decode_len: 20, arrival: 0.0, prefix: None });
        (pool, kv)
    }

    #[test]
    fn decode_maximal_composition() {
        let (mut pool, mut kv) = setup(3, 1000);
        let mut s = SarathiScheduler::new(256, 8, 128);
        let b = s.schedule(&mut pool, &mut kv, 0.0);
        assert!(b.is_decode_maximal());
        assert_eq!(b.n_decodes(), 3);
        // §4.4 alignment: fused tokens == C exactly (chunk shrank by n_d)
        assert_eq!(b.prefill_tokens(), 256 - 3);
        assert_eq!(b.total_tokens(), 256);
        assert!(b.validate(&pool, 8).is_ok());
    }

    #[test]
    fn single_prefill_chunk_per_batch() {
        // two requests awaiting prefill: only the first is chunked
        let (mut pool, mut kv) = setup(0, 1000);
        pool.push(RequestSpec { prompt_len: 500, decode_len: 5, arrival: 0.0, prefix: None });
        let mut s = SarathiScheduler::new(128, 8, 128);
        let b = s.schedule(&mut pool, &mut kv, 0.0);
        assert_eq!(b.n_prefill_chunks(), 1);
        assert_eq!(b.prefill_items().next().unwrap().0, 0);
    }

    #[test]
    fn final_chunk_is_partial() {
        let (mut pool, mut kv) = setup(0, 300);
        let mut s = SarathiScheduler::new(256, 8, 128);
        let b = s.schedule(&mut pool, &mut kv, 0.0);
        assert_eq!(b.prefill_tokens(), 256);
        let (req, _, len) = b.prefill_items().next().unwrap();
        pool.get_mut(req).prefilled += len;
        let b2 = s.schedule(&mut pool, &mut kv, 0.1);
        assert_eq!(b2.prefill_tokens(), 44); // 300 − 256
    }

    #[test]
    fn decode_only_when_no_prefills_pending() {
        let (mut pool, mut kv) = setup(4, 64);
        // finish the prefill of the last request
        let id = 4;
        let slot = kv.alloc().unwrap();
        pool.admit(id, vec![slot], 0.0);
        let r = pool.get_mut(id);
        r.prefilled = 64;
        r.decoded = 1;
        let mut s = SarathiScheduler::new(256, 8, 128);
        let b = s.schedule(&mut pool, &mut kv, 0.0);
        assert_eq!(b.n_prefill_chunks(), 0);
        assert_eq!(b.n_decodes(), 5);
    }

    #[test]
    fn piggyback_cap_is_b_minus_one() {
        let (mut pool, mut kv) = setup(10, 1000);
        let mut s = SarathiScheduler::new(256, 4, 128);
        let b = s.schedule(&mut pool, &mut kv, 0.0);
        assert_eq!(b.n_decodes(), 3); // B − 1
        assert_eq!(b.len(), 4);
    }

    #[test]
    fn chunk_budget_never_zero() {
        let s = SarathiScheduler::new(16, 64, 128);
        assert_eq!(s.chunk_budget(63), 1);
    }

    #[test]
    fn tile_alignment_flag() {
        assert!(SarathiScheduler::new(256, 8, 128).is_tile_aligned());
        assert!(!SarathiScheduler::new(320, 8, 128).is_tile_aligned());
    }
}
