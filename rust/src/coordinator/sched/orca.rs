//! Orca iteration-level scheduling (§5.2's comparison points).
//!
//! Orca admits/retires requests at iteration granularity but always submits
//! a request's *entire* prompt as one prefill. The paper evaluates two
//! envelope cases:
//!
//! * **best case** — the full prefill of exactly one new request overlaps
//!   the ongoing decodes in a mixed batch (a special case of SARATHI with
//!   C = max sequence length, as §5.2 notes);
//! * **worst case** — all requests begin and end together, so batches
//!   degenerate to prefill-only / decode-only (no overlap).

use super::super::batch::{Batch, WorkItem};
use super::super::kv::KvManager;
use super::super::pool::RequestPool;
use super::super::request::Phase;
use super::admission::InfeasiblePolicy;
use super::{Admission, Scheduler};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OrcaMode {
    Best,
    Worst,
}

pub struct OrcaScheduler {
    mode: OrcaMode,
    max_batch: usize,
    /// Panic (closed-loop default) or reject (open-loop serving) requests
    /// whose lifetime KV can never fit the pool.
    infeasible: InfeasiblePolicy,
}

impl OrcaScheduler {
    pub fn best(max_batch: usize) -> Self {
        OrcaScheduler { mode: OrcaMode::Best, max_batch, infeasible: InfeasiblePolicy::Panic }
    }

    pub fn worst(max_batch: usize) -> Self {
        OrcaScheduler { mode: OrcaMode::Worst, max_batch, infeasible: InfeasiblePolicy::Panic }
    }

    pub fn with_infeasible(mut self, policy: InfeasiblePolicy) -> Self {
        self.infeasible = policy;
        self
    }
}

impl Scheduler for OrcaScheduler {
    fn admission(&self) -> Admission {
        Admission::default().with_infeasible(self.infeasible)
    }

    fn compose(&mut self, pool: &mut RequestPool, _kv: &mut KvManager, _now: f64) -> Batch {
        let prefilling = pool.in_phase(Phase::Prefill);
        let decoding: Vec<usize> = pool
            .in_phase_iter(Phase::Decode)
            .filter(|&id| pool.get(id).remaining_decode() > 0)
            .collect();

        let mut items = Vec::new();
        match self.mode {
            OrcaMode::Best => {
                // one full prefill piggybacks on the running decodes
                if let Some(&id) = prefilling.first() {
                    // (whole list needed only in Worst mode; Best uses the
                    // first — kept as a slice op since the list is ≤ B)
                    let r = pool.get(id);
                    items.push(WorkItem::PrefillChunk {
                        req: id,
                        start: r.prefilled,
                        len: r.remaining_prompt(),
                    });
                }
                for &id in decoding.iter().take(self.max_batch - items.len()) {
                    items.push(WorkItem::Decode { req: id });
                }
            }
            OrcaMode::Worst => {
                // no overlap: drain prefills first, then decodes
                if !prefilling.is_empty() {
                    for &id in prefilling.iter().take(self.max_batch) {
                        let r = pool.get(id);
                        items.push(WorkItem::PrefillChunk {
                            req: id,
                            start: r.prefilled,
                            len: r.remaining_prompt(),
                        });
                    }
                } else {
                    for &id in decoding.iter().take(self.max_batch) {
                        items.push(WorkItem::Decode { req: id });
                    }
                }
            }
        }
        Batch::new(items)
    }

    fn name(&self) -> &'static str {
        match self.mode {
            OrcaMode::Best => "orca-best",
            OrcaMode::Worst => "orca-worst",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::RequestSpec;

    fn setup() -> (RequestPool, KvManager) {
        let specs: Vec<RequestSpec> =
            (0..4)
                .map(|_| RequestSpec {
                    prompt_len: 100,
                    decode_len: 10,
                    arrival: 0.0,
                    prefix: None,
                })
                .collect();
        let mut pool = RequestPool::from_specs(&specs);
        let mut kv = KvManager::new(8);
        // requests 0,1 already decoding
        for id in 0..2 {
            let slot = kv.alloc().unwrap();
            pool.admit(id, vec![slot], 0.0);
            let r = pool.get_mut(id);
            r.prefilled = 100;
            r.decoded = 1;
        }
        (pool, kv)
    }

    #[test]
    fn best_case_mixes_one_full_prefill_with_decodes() {
        let (mut pool, mut kv) = setup();
        let mut s = OrcaScheduler::best(8);
        let b = s.schedule(&mut pool, &mut kv, 0.0);
        assert_eq!(b.n_prefill_chunks(), 1);
        assert_eq!(b.prefill_tokens(), 100); // FULL prompt, not a chunk
        assert_eq!(b.n_decodes(), 2);
        assert!(b.validate(&pool, 8).is_ok());
    }

    #[test]
    fn worst_case_never_mixes() {
        let (mut pool, mut kv) = setup();
        let mut s = OrcaScheduler::worst(8);
        let b = s.schedule(&mut pool, &mut kv, 0.0);
        // prefills pending -> prefill-only
        assert!(b.n_prefill_chunks() > 0);
        assert_eq!(b.n_decodes(), 0);
    }

    #[test]
    fn best_case_decode_only_when_no_prefills() {
        let (mut pool, mut kv) = setup();
        // finish all prefills
        for id in 2..4 {
            let slot = kv.alloc().unwrap();
            pool.admit(id, vec![slot], 0.0);
            let r = pool.get_mut(id);
            r.prefilled = 100;
            r.decoded = 1;
        }
        let mut s = OrcaScheduler::best(8);
        let b = s.schedule(&mut pool, &mut kv, 0.0);
        assert_eq!(b.n_prefill_chunks(), 0);
        assert_eq!(b.n_decodes(), 4);
    }

    #[test]
    fn respects_batch_cap() {
        let (mut pool, mut kv) = setup();
        let mut s = OrcaScheduler::best(2);
        let b = s.schedule(&mut pool, &mut kv, 0.0);
        assert!(b.len() <= 2);
    }
}
