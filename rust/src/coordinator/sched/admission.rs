//! Memory-aware, watermark-based admission over the paged KV pool, with
//! copy-on-write prefix sharing.
//!
//! Admission is the first half of every scheduling step (the second is
//! batch composition — see [`super::Scheduler`]). The gate reserves the
//! request's prompt footprint up front and its live KV on swap-in (see
//! [`Admission::blocks_required`]); only decode growth extends the table
//! later, which is what the watermark buffers. Under the degenerate block
//! size everything collapses to the seed's one-slot-per-request rule, so
//! the paper experiments reproduce unchanged.
//!
//! With [`Admission::prefix_share`] on (and a paged pool), a request whose
//! [`PrefixSpec`] names a prefix already resident in the allocator's index
//! reserves only its NON-shared tokens: the resident run is ref-count
//! shared into the head of its block table, the partially-filled last
//! prefix block is copy-on-write forked ([`KvManager::fork_block`]) so the
//! request can append without mutating shared content, and the prefill
//! compute for the covered tokens is skipped (their KV already exists).
//! A miss admits normally and then *registers* the request's table head as
//! the template's resident run, so every later arrival of the template
//! hits. Watermark math and swap-in costing both work on the private
//! footprint — shared blocks are neither reserved twice nor moved.
//!
//! The watermark reserves free blocks for decode growth of already-running
//! requests (vLLM-style): admitting greedily to zero free blocks would
//! force a preemption on the very next decode step.
//!
//! [`PrefixSpec`]: crate::workload::PrefixSpec

use super::super::kv::KvManager;
use super::super::pool::RequestPool;
use crate::workload::RequestSpec;

/// What the gate does with a request that could NEVER complete in this
/// pool (its lifetime KV peak exceeds capacity even when empty).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum InfeasiblePolicy {
    /// Panic loudly — the right behavior for figure-repro / closed-loop
    /// runs, where an undersized pool means the experiment itself is
    /// misconfigured.
    #[default]
    Panic,
    /// Reject the request into a terminal [`Rejected`] state
    /// ([`RequestPool::reject`]) and keep serving co-running traffic —
    /// the right behavior for `serve`/open-loop paths, where one oversized
    /// request must not crash the server.
    ///
    /// [`Rejected`]: crate::coordinator::request::Phase::Rejected
    Reject,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Admission {
    /// Free blocks kept in reserve for decode growth of running requests.
    pub watermark_blocks: usize,
    /// Cap on concurrently admitted sequences (Sarathi-Serve's
    /// `max_num_seqs`). `None` bounds admission by memory alone — the seed
    /// policies' behavior, where the slot pool itself is the cap.
    pub max_active: Option<usize>,
    /// Panic or reject on requests that can never fit the pool.
    pub infeasible: InfeasiblePolicy,
    /// Serve prefix-tagged requests from the allocator's resident-prefix
    /// index (copy-on-write sharing). Off by default: the baseline pays
    /// for every prompt token, prefix-tagged or not.
    pub prefix_share: bool,
    /// Bounded cache-aware waiting: a waiter whose registrant made no
    /// prefill progress for this many consecutive admission attempts
    /// degrades to a full-price MISS and admits normally
    /// ([`RequestPool::force_prefix_fallback`]). 0 disables waiting
    /// entirely (every would-be wait is an immediate fallback).
    pub max_prefix_wait: usize,
    /// Bounded head-of-line bypass: when the queue head's prefix wait is
    /// observably STALLED (at least one no-progress attempt), up to this
    /// many arrived followers may be tried past it. A productive wait
    /// (the fill is advancing) keeps the FCFS gate, so healthy template
    /// warm-up stays serialized and the sharing win is not eroded;
    /// fairness degrades gracefully — by a window, not absolutely.
    pub bypass_window: usize,
}

impl Default for Admission {
    fn default() -> Self {
        Admission {
            watermark_blocks: 0,
            max_active: None,
            infeasible: InfeasiblePolicy::default(),
            prefix_share: false,
            max_prefix_wait: Self::DEFAULT_MAX_PREFIX_WAIT,
            bypass_window: Self::DEFAULT_BYPASS_WINDOW,
        }
    }
}

/// Whether the gate passes one request, and if not, why — the wait
/// outcome needs its own arm so `try_admit_one` can tick the waiter's
/// stall clock without conflating it with a memory/cap refusal.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum GateVerdict {
    Pass,
    /// Waiting on an in-flight prefix fill (cache-aware admission).
    Waiting,
    /// Memory/cap refusal (or infeasible under the Reject policy).
    Blocked,
}

/// How admission will cover one request's KV footprint: what it can share
/// from a resident prefix run, what must be copy-on-write forked, and how
/// many fresh blocks the gate has to reserve.
#[derive(Clone, Debug, Default)]
struct SharePlan {
    /// Resident run blocks to ref-share into the table head (empty = no
    /// sharing: a miss, an untagged request, or a degenerate pool).
    run: Vec<usize>,
    /// Leading table blocks that stay SHARED after the fork below — the
    /// head of the request's split block table.
    shared_head: usize,
    /// Tokens resident in those shared head blocks (`shared_head` full
    /// blocks' worth).
    shared_tokens: usize,
    /// Prompt tokens whose prefill compute the resident KV serves.
    skip_tokens: usize,
    /// Copy-on-write fork the partially-filled last prefix block (the
    /// request appends into that block's token range).
    fork: bool,
    /// Fresh blocks to allocate: private tail + any COW fork copy.
    new_blocks: usize,
    /// On a miss of a prefix-tagged request: register a token span of the
    /// request's content path from the new table, pinning the run for
    /// later sharers.
    register: Option<RegisterPlan>,
    /// The run is a PARTIAL (radix) match of the request's content path,
    /// not a whole-template hit — accounted separately so hit-depth
    /// stats can tell a conversation-turn extension from a replay.
    partial: bool,
    /// The template's run is registered but its KV is still being
    /// computed by the registrant: this request waits (cache-aware
    /// admission) instead of paying full price for KV about to exist.
    blocked: bool,
}

/// The registration half of a [`SharePlan`]: pin `(start_tokens,
/// cov_tokens]` of the request's content path under `hash` (`start_tokens`
/// 0 with an empty path is the flat whole-template form).
#[derive(Clone, Copy, Debug)]
struct RegisterPlan {
    hash: u64,
    start_tokens: usize,
    cov_tokens: usize,
}

impl Admission {
    /// Default bound on consecutive no-progress waits before the fallback
    /// (the fallback-policy knob; see [`Self::max_prefix_wait`]).
    pub const DEFAULT_MAX_PREFIX_WAIT: usize = 8;
    /// Default head-of-line bypass window behind a stalled waiter.
    pub const DEFAULT_BYPASS_WINDOW: usize = 4;

    pub fn with_watermark(watermark_blocks: usize) -> Self {
        Admission { watermark_blocks, ..Self::default() }
    }

    pub fn with_max_active(mut self, max_active: usize) -> Self {
        self.max_active = Some(max_active);
        self
    }

    pub fn with_infeasible(mut self, policy: InfeasiblePolicy) -> Self {
        self.infeasible = policy;
        self
    }

    /// Enable (or disable) copy-on-write prefix sharing at this gate.
    pub fn with_prefix_share(mut self, on: bool) -> Self {
        self.prefix_share = on;
        self
    }

    /// Set the bounded-wait fallback knob (consecutive no-progress
    /// attempts before a waiter degrades to a full-price miss).
    pub fn with_max_prefix_wait(mut self, k: usize) -> Self {
        self.max_prefix_wait = k;
        self
    }

    /// Set the head-of-line bypass window behind a stalled waiter
    /// (0 restores the strict PR-3 gate).
    pub fn with_bypass_window(mut self, window: usize) -> Self {
        self.bypass_window = window;
        self
    }

    /// Tokens request `id` must cover at admission: the full prompt up
    /// front (vLLM-style), or a swapped-out request's whole live KV plus
    /// the next token.
    fn target_tokens(pool: &RequestPool, id: usize) -> usize {
        let r = pool.get(id);
        r.spec.prompt_len.max(r.kv_len() + 1).max(1)
    }

    /// Plan to share `run` (covering `tokens` prompt tokens, clamped to
    /// `cap`) into the head of a table needing `total` blocks. `skip`
    /// grants the compute skip (a servable hit); the resuming filler
    /// re-shares without one. `None` when nothing is coverable.
    fn share_from_run(
        kv: &KvManager,
        run: &[usize],
        tokens: usize,
        cap: usize,
        total: usize,
        skip: bool,
    ) -> Option<SharePlan> {
        let cov = tokens.min(cap);
        let n_run = kv.blocks_needed(cov);
        if n_run == 0 {
            return None;
        }
        // the run's partial last block holds prefix tokens (the filler
        // writes them there in place); a sharer about to APPEND its own
        // tokens into that block's range COW-forks a private copy first
        let fork = cov % kv.block_size() != 0;
        Some(SharePlan {
            run: run[..n_run].to_vec(),
            shared_head: n_run - fork as usize,
            shared_tokens: cov - cov % kv.block_size(),
            skip_tokens: if skip { cov } else { 0 },
            fork,
            new_blocks: total - n_run + fork as usize,
            register: None,
            partial: false,
            blocked: false,
        })
    }

    /// Build the share plan for admitting `id` right now. Pure: allocates
    /// nothing, so the gate and the admit path cannot disagree.
    fn plan(&self, pool: &RequestPool, kv: &KvManager, id: usize) -> SharePlan {
        let total = kv.blocks_needed(Self::target_tokens(pool, id)).max(1);
        let plain = SharePlan { new_blocks: total, ..SharePlan::default() };
        if !self.prefix_share || kv.is_degenerate() {
            return plain;
        }
        let Some(pfx) = pool.get(id).spec.prefix.as_ref() else {
            return plain;
        };
        // never cover the full prompt: the final prefill chunk must run to
        // produce the request's first output token
        let cap = pool.get(id).spec.prompt_len.saturating_sub(1);
        let bs = kv.block_size();
        // a fallback victim demoted out of its wait: its tag covers at
        // most the ready match it demoted to — it never waits again and
        // never registers. Sticky so the charge is predictable; a
        // path-less (flat) fallback stays a full-price miss forever.
        if pool.get(id).prefix_fallback {
            let want = pool.get(id).fallback_ready_tokens.min(cap);
            if want < bs || pfx.path.is_empty() {
                return plain;
            }
            let m = kv.lookup_path_match(&pfx.path[..(want / bs).min(pfx.path.len())]);
            let share = m.ready_tokens.min(want);
            if share == 0
                || self.sharer_lifetime_need(kv, &pool.get(id).spec, share) > kv.capacity()
            {
                return plain;
            }
            return match Self::share_from_run(kv, &m.ready_run, share, cap, total, true) {
                Some(mut p) => {
                    p.partial = true;
                    p
                }
                None => plain,
            };
        }
        if let Some((tokens, run)) = kv.lookup_servable(pfx.id) {
            // a hit that could never COMPLETE as a sharer — the pinned run
            // (which this sharer's own table keeps resident) plus its
            // private peak exceeds the pool — pays full price instead of
            // livelocking through an endless grow/preempt/resume cycle
            if self.sharer_lifetime_need(kv, &pool.get(id).spec, tokens) > kv.capacity() {
                return plain;
            }
            // servable hit: share the resident head, skip its compute
            Self::share_from_run(kv, &run, tokens, cap, total, true).unwrap_or(plain)
        } else if let Some((tokens, run)) = kv.lookup_prefix(pfx.id) {
            // registered but not yet computed (the fill is in flight or
            // its filler is swapped out).
            let prefilled = pool.get(id).prefilled;
            if prefilled >= tokens {
                // already produced every covered token itself (a resumed
                // request whose original run was since reclaimed): the
                // whole footprint swaps back in at full price
                plain
            } else if prefilled > 0 {
                // the preempted filler: re-share the pinned head it was
                // filling — its computed KV lives THERE, so swap-in only
                // moves its private tail, and holding the head again
                // lets its prefill flip the run servable when it crosses
                // the covered tokens (liveness: without this, a filler
                // preempted mid-fill could never ready its run and every
                // fresh same-template arrival would wait forever). No
                // compute skip: the fill resumes for real.
                Self::share_from_run(kv, &run, tokens, cap, total, false).unwrap_or(plain)
            } else {
                // fresh same-template arrivals WAIT for the in-flight
                // fill instead of paying full price for KV about to
                // exist (cache-aware admission). FCFS-fair like the
                // memory gate: a waiting head holds the queue.
                SharePlan { blocked: true, ..plain }
            }
        } else if !pfx.path.is_empty() {
            // content-path miss: share the longest resident READY match
            // from the radix tree, register the uncovered tail under this
            // request's own hash, and wait (bounded) when a deeper
            // ancestor's fill is still in flight.
            let cov = pfx.len.min(cap);
            let kb = (cov / bs).min(pfx.path.len());
            if kb == 0 {
                return plain; // sub-block prefixes are never cached
            }
            let m = kv.lookup_path_match(&pfx.path[..kb]);
            let prefilled = pool.get(id).prefilled;
            if m.attach_tokens > m.ready_tokens && prefilled == 0 {
                // the wait binds to the deepest unready ancestor: its
                // fill is in flight, so this request waits like a
                // same-template arrival instead of paying for KV about
                // to exist
                return SharePlan { blocked: true, ..plain };
            }
            if m.ready_tokens > 0
                && self.sharer_lifetime_need(kv, &pool.get(id).spec, m.ready_tokens)
                    > kv.capacity()
            {
                return plain;
            }
            // the tail (ready, cov] registers only when it attaches
            // exactly at the ready frontier (an unready sibling span
            // there belongs to its own in-flight registrant) and covers
            // at least one new full block
            let can_register = m.attach_tokens == m.ready_tokens && kb > m.ready_tokens / bs;
            let n_run = m.ready_tokens / bs;
            if n_run == 0 && !can_register {
                return plain;
            }
            let fork = can_register && cov % bs != 0;
            SharePlan {
                shared_head: if can_register {
                    kv.blocks_needed(cov) - fork as usize
                } else {
                    n_run
                },
                shared_tokens: if can_register { cov - cov % bs } else { m.ready_tokens },
                skip_tokens: if prefilled == 0 { m.ready_tokens } else { 0 },
                fork,
                new_blocks: total - n_run + fork as usize,
                register: if can_register {
                    Some(RegisterPlan {
                        hash: pfx.id,
                        start_tokens: m.ready_tokens,
                        cov_tokens: cov,
                    })
                } else {
                    None
                },
                partial: n_run > 0,
                blocked: false,
                run: m.ready_run,
            }
        } else {
            // flat miss: admit normally, then register the table head as
            // the template's resident run. Content contract: the
            // registrant prefills every COVERED token (1..=cov) into the
            // pinned run in place — including the partial last block — and
            // its OWN suffix tokens go into the +1 COW fork taken at
            // admission, so the pinned partial always ends up holding
            // exactly the prefix content sharers later fork-copy from.
            // Nobody reads the run before the fill completes (readiness
            // gate). Sub-block prefixes are never cached (no full block
            // to share).
            let cov = pfx.len.min(cap);
            if cov < bs {
                return plain;
            }
            let fork = cov % bs != 0;
            SharePlan {
                run: Vec::new(),
                shared_head: kv.blocks_needed(cov) - fork as usize,
                shared_tokens: cov - cov % bs,
                skip_tokens: 0,
                fork,
                new_blocks: total + fork as usize,
                register: Some(RegisterPlan { hash: pfx.id, start_tokens: 0, cov_tokens: cov }),
                partial: false,
                blocked: false,
            }
        }
    }

    /// Fresh blocks request `id` needs to be admitted right now: the full
    /// prompt is reserved up front (vLLM-style — prefill length is known,
    /// so a running chunked prefill never has to grab blocks mid-flight
    /// and the watermark only has to absorb decode growth); a swapped-out
    /// request needs its whole KV footprint plus the next token back.
    /// Tokens covered by a resident shared prefix are NOT reserved — that
    /// is the admission-side win of prefix sharing.
    pub fn blocks_required(&self, pool: &RequestPool, kv: &KvManager, id: usize) -> usize {
        self.plan(pool, kv, id).new_blocks
    }

    /// Pool blocks that must be simultaneously resident for `spec` to
    /// complete AS A SHARER of a servable run covering `cov_tokens`: the
    /// run itself (this sharer's table references it for its whole life,
    /// so it can never be reclaimed out from under the peak) plus the
    /// private tail — at its lifetime peak, or at admission together with
    /// the watermark, whichever binds. The watermark only gates ADMISSION
    /// headroom, not the peak: decode growth past admission is allowed to
    /// run the pool to zero free blocks.
    fn sharer_lifetime_need(&self, kv: &KvManager, spec: &RequestSpec, cov_tokens: usize) -> usize {
        let peak = spec.prompt_len + spec.decode_len.saturating_sub(1);
        let cov = cov_tokens.min(spec.prompt_len.saturating_sub(1));
        let n_run = kv.blocks_needed(cov);
        let fork = (cov % kv.block_size() != 0) as usize;
        let private_admit = kv.blocks_needed(spec.prompt_len.max(1)) - n_run + fork;
        let private_peak = kv.blocks_needed(peak.max(1)) - n_run + fork;
        n_run + private_peak.max(private_admit + self.watermark_blocks)
    }

    /// True when `id` could run to COMPLETION in an empty pool: its
    /// lifetime KV peak (`prompt + decode − 1` tokens, both known in the
    /// spec) plus the watermark fits the pool. Shared by
    /// [`can_admit`](Self::can_admit) and
    /// [`try_admit_one`](Self::try_admit_one) so the two entry points
    /// cannot disagree about an infeasible request.
    ///
    /// A resident prefix run can rescue a request the full-price check
    /// rejects: the run stays resident either way (it is pinned and the
    /// sharer references it), but the watermark then only has to cover
    /// admission headroom over the PRIVATE tail — not the full peak
    /// ([`sharer_lifetime_need`](Self::sharer_lifetime_need)). The rescue
    /// counts a run that is still FILLING too ([`KvManager::lookup_prefix`],
    /// ready or not): such a request waits like any other same-template
    /// arrival and admits as a hit once the fill completes — gating the
    /// rescue on servability would panic/reject it one iteration before
    /// the wait machinery could hold it. Note the rescue is evaluated
    /// against the CURRENT cache state: if the run is reclaimed (or the
    /// wait degrades to the inert-tag fallback) while such a request
    /// still queues, the request becomes infeasible again — under
    /// [`InfeasiblePolicy::Panic`] that is a (correct, loud) mid-run
    /// panic for a request that only ever fit WITH the cache.
    pub fn is_feasible(&self, pool: &RequestPool, kv: &KvManager, id: usize) -> bool {
        let r = pool.get(id);
        let spec = &r.spec;
        let peak = spec.prompt_len + spec.decode_len.saturating_sub(1);
        let lifetime = kv.blocks_needed(peak.max(1));
        if lifetime.saturating_add(self.watermark_blocks) <= kv.capacity() {
            return true; // feasible at full price, cache or no cache
        }
        if self.prefix_share && !kv.is_degenerate() && !r.prefix_fallback {
            if let Some(pfx) = spec.prefix.as_ref() {
                if let Some(tokens) = kv.lookup_prefix_tokens(pfx.id) {
                    return self.sharer_lifetime_need(kv, spec, tokens) <= kv.capacity();
                }
                // a READY radix match of the content path rescues too —
                // the sharer pins exactly that run, so only the private
                // remainder counts against the pool
                let cap = spec.prompt_len.saturating_sub(1);
                let kb = (pfx.len.min(cap) / kv.block_size()).min(pfx.path.len());
                if kb > 0 {
                    let ready = kv.lookup_path_match(&pfx.path[..kb]).ready_tokens;
                    if ready > 0 {
                        return self.sharer_lifetime_need(kv, spec, ready) <= kv.capacity();
                    }
                }
            }
        }
        false
    }

    /// Under [`InfeasiblePolicy::Panic`], panic loudly on an infeasible
    /// request. Without that guard an oversized request is admitted on its
    /// prompt footprint, grows to the memory wall, preempts every
    /// co-running request, and only then wedges the engine with no hint at
    /// the cause.
    fn panic_infeasible(&self, pool: &RequestPool, kv: &KvManager, id: usize) -> ! {
        let spec = &pool.get(id).spec;
        let peak = spec.prompt_len + spec.decode_len.saturating_sub(1);
        let lifetime = kv.blocks_needed(peak.max(1));
        panic!(
            "request {id} can never complete: its KV peaks at {peak} tokens = {lifetime} blocks \
             (+{} watermark) but the pool only has {} — undersized paged KV pool for this workload",
            self.watermark_blocks,
            kv.capacity()
        );
    }

    /// The gate's decision for `id` without allocating, returning the
    /// [`SharePlan`] it was judged on so the admit path can reuse it
    /// instead of re-planning (the plan is pure, so a `Pass` plan is
    /// exactly the plan `try_admit_one` executes). `None` plans come from
    /// the early cap/infeasible refusals, which never planned at all.
    /// Panics (like [`try_admit_one`](Self::try_admit_one)) when the
    /// request could never be admitted at all and the policy is
    /// [`InfeasiblePolicy::Panic`]; under [`InfeasiblePolicy::Reject`] an
    /// infeasible request is merely `Blocked` without mutating anything.
    fn verdict_with_plan(
        &self,
        pool: &RequestPool,
        kv: &KvManager,
        id: usize,
    ) -> (GateVerdict, Option<SharePlan>) {
        if let Some(cap) = self.max_active {
            if pool.active_count() >= cap {
                return (GateVerdict::Blocked, None);
            }
        }
        if !self.is_feasible(pool, kv, id) {
            match self.infeasible {
                InfeasiblePolicy::Panic => self.panic_infeasible(pool, kv, id),
                InfeasiblePolicy::Reject => return (GateVerdict::Blocked, None),
            }
        }
        let plan = self.plan(pool, kv, id);
        if plan.blocked {
            return (GateVerdict::Waiting, Some(plan)); // in-flight prefix fill
        }
        // funds = free blocks + cold prefixes the allocator would reclaim
        // under pressure — EXCLUDING the run this admission is about to
        // share (sharing pins it hot, so its blocks can't be funds; the
        // exclusion is run-granular because a radix match may pin only
        // part of a chain). try_admit_one shares first, allocates second,
        // so a checked gate can never fail to allocate below.
        let funds = kv.available() + kv.reclaimable_excluding(&plan.run);
        if funds >= plan.new_blocks.saturating_add(self.watermark_blocks) {
            (GateVerdict::Pass, Some(plan))
        } else {
            (GateVerdict::Blocked, Some(plan))
        }
    }

    /// Plan-less [`verdict_with_plan`](Self::verdict_with_plan).
    fn verdict(&self, pool: &RequestPool, kv: &KvManager, id: usize) -> GateVerdict {
        self.verdict_with_plan(pool, kv, id).0
    }

    /// True if the gate passes for `id` without allocating (see
    /// [`verdict`](Self::verdict) for the panic/reject behavior).
    pub fn can_admit(&self, pool: &RequestPool, kv: &KvManager, id: usize) -> bool {
        self.verdict(pool, kv, id) == GateVerdict::Pass
    }

    /// One tick of `id`'s bounded prefix wait: compare the run's fill
    /// progress (and stall events — a preempted filler counts as a stall
    /// even if the fill also advanced) against the waiter's last
    /// observation. `max_prefix_wait` consecutive no-progress ticks force
    /// the full-price fallback.
    fn tick_prefix_wait(&self, pool: &mut RequestPool, kv: &KvManager, id: usize, now: f64) {
        use super::super::request::PrefixWaitState;
        let Some(pfx) = pool.get(id).spec.prefix.clone() else { return };
        // an exact-hash wait watches the registrant's fill; a path wait
        // (the hash itself is unregistered) watches progress along the
        // content path, whose unready frontier is the ancestor being
        // filled
        let cap = pool.get(id).spec.prompt_len.saturating_sub(1);
        let kb = (pfx.len.min(cap) / kv.block_size().max(1)).min(pfx.path.len());
        let (fill, stall_events) = match kv.prefix_fill_state(pfx.id) {
            Some(s) => s,
            None if kb > 0 => kv.path_fill_state(&pfx.path[..kb]),
            None => (0, 0),
        };
        pool.note_prefix_wait_tick();
        let r = pool.get_mut(id);
        r.prefix_wait_iters += 1;
        let stalled = if let Some(w) = r.prefix_wait.as_mut() {
            if fill > w.last_fill && stall_events == w.last_stall_events {
                w.stalled_iters = 0; // the fill is advancing: keep waiting
            } else {
                w.stalled_iters += 1; // stalled, or the filler was preempted
            }
            w.last_fill = fill;
            w.last_stall_events = stall_events;
            w.stalled_iters
        } else {
            r.prefix_wait = Some(PrefixWaitState {
                hash: pfx.id,
                last_fill: fill,
                last_stall_events: stall_events,
                stalled_iters: 0,
                since: now,
            });
            0
        };
        if stalled >= self.max_prefix_wait {
            // demote to the deepest READY match instead of full price:
            // the fallback plan re-shares what is already servable and
            // only the stalled remainder is paid for
            let ready = if kb > 0 { kv.lookup_path_match(&pfx.path[..kb]).ready_tokens } else { 0 };
            pool.force_prefix_fallback(id, now, ready);
        }
    }

    /// Admit `id` if the gate passes, allocating its initial block table —
    /// sharing the head from a resident prefix run (COW-forking its
    /// partial last block) when the plan says so, and registering the run
    /// on a prefix miss.
    ///
    /// An infeasible request panics under [`InfeasiblePolicy::Panic`]
    /// (loudly, like the allocator's double-free); under
    /// [`InfeasiblePolicy::Reject`] it is moved to the terminal
    /// `Rejected` state and false is returned.
    pub fn try_admit_one(
        &self,
        pool: &mut RequestPool,
        kv: &mut KvManager,
        id: usize,
        now: f64,
    ) -> bool {
        if self.infeasible == InfeasiblePolicy::Reject && !self.is_feasible(pool, kv, id) {
            pool.reject(id, now);
            return false;
        }
        // the verdict carries the plan it was judged on, so the admit path
        // below never re-plans — one prefix-index walk per attempt, not
        // three
        let plan = match self.verdict_with_plan(pool, kv, id) {
            (GateVerdict::Pass, plan) => plan.expect("a passing gate always carries a plan"),
            (GateVerdict::Blocked, plan) => {
                // a leftover wait edge whose fill has since resolved (the
                // plan no longer waits) ends HERE: the request is now
                // memory- or cap-gated like everyone else, and a stale
                // `stalled` edge must not keep the FCFS bypass window
                // open for a head that is no longer cache-waiting. A
                // plan-carrying Blocked is by construction non-waiting
                // (waiting plans verdict `Waiting`); only the early
                // cap-gated refusal (no plan) must still plan to check.
                if pool.get(id).is_prefix_waiting() {
                    let still_waits = match plan {
                        Some(_) => false,
                        None => self.plan(pool, kv, id).blocked,
                    };
                    if !still_waits {
                        pool.finalize_prefix_wait(id, now);
                    }
                }
                return false;
            }
            (GateVerdict::Waiting, _) => {
                // the wait-for edge ticks once per attempt; K consecutive
                // no-progress ticks degrade it to a full-price miss that
                // may admit on this very attempt (with a fresh plan: the
                // fallback rewrote the request's prefix tag)
                self.tick_prefix_wait(pool, kv, id, now);
                if !pool.get(id).prefix_fallback {
                    return false;
                }
                match self.verdict_with_plan(pool, kv, id) {
                    (GateVerdict::Pass, plan) => {
                        plan.expect("a passing gate always carries a plan")
                    }
                    _ => return false,
                }
            }
        };
        // the wait (if any) resolves right here — as a servable hit, a
        // re-registration, or the forced fallback — so finalize its time
        pool.finalize_prefix_wait(id, now);
        let target = Self::target_tokens(pool, id);
        // 1. the shared head: reference the resident run, then COW-fork
        //    its partial last block before this request can append into it
        let mut blocks = kv.share_seq(&plan.run);
        // reserve the lifetime-peak table capacity once, so per-token
        // decode growth never reallocates this request's block table
        let peak = {
            let s = &pool.get(id).spec;
            s.prompt_len + s.decode_len
        };
        blocks.reserve(kv.blocks_needed(peak.max(1)).saturating_sub(blocks.len()));
        if plan.fork && plan.register.is_none() {
            let last = blocks.len() - 1;
            blocks[last] =
                kv.fork_block(blocks[last]).expect("admission gate checked availability");
        }
        // 2. the private tail
        let grown = kv.extend_to(&mut blocks, target);
        assert!(grown, "admission gate checked availability");
        // 3. a miss registers the head as the template's resident run,
        //    then forks the (now shared) partial block for its own tail
        if let Some(reg) = plan.register {
            let sb = reg.start_tokens / kv.block_size();
            let n_run = kv.blocks_needed(reg.cov_tokens);
            let path = pool
                .get(id)
                .spec
                .prefix
                .as_ref()
                .map(|p| p.path.clone())
                .unwrap_or_default();
            if path.is_empty() {
                kv.register_prefix(reg.hash, reg.cov_tokens, &blocks[..n_run]);
            } else {
                let kb = reg.cov_tokens / kv.block_size();
                kv.register_path_prefix(
                    reg.hash,
                    &path[..kb],
                    reg.start_tokens,
                    reg.cov_tokens,
                    &blocks[sb..n_run],
                );
            }
            if plan.fork {
                blocks[n_run - 1] =
                    kv.fork_block(blocks[n_run - 1]).expect("admission gate checked availability");
            }
            // a re-registrant that already computed the covered tokens
            // (its original run was reclaimed while it was swapped out)
            // restores them with this admission's swap-in: the run is
            // servable immediately, not gated on a prefill it will
            // never run again
            if pool.get(id).prefilled >= reg.cov_tokens {
                kv.mark_prefix_ready(reg.hash);
            }
        }
        // the split goes on the request BEFORE admit() so swap-in costing
        // sees only the private tokens — except for a (re-)registrant,
        // whose "shared" head tokens did cross the host link (nothing was
        // resident), so they must stay in the swap-in count
        if plan.register.is_none() {
            let r = pool.get_mut(id);
            r.shared_blocks = plan.shared_head;
            r.shared_tokens = plan.shared_tokens;
        }
        pool.admit(id, blocks, now);
        // 4. skip prefill compute for covered tokens (first admission
        //    only: a resumed request's progress already includes them)
        let r = pool.get_mut(id);
        if plan.register.is_some() {
            r.shared_blocks = plan.shared_head;
            r.shared_tokens = plan.shared_tokens;
        }
        let served = plan.skip_tokens.saturating_sub(r.prefilled);
        if r.prefilled < plan.skip_tokens {
            r.prefix_skipped_tokens += plan.skip_tokens - r.prefilled;
            r.prefilled = plan.skip_tokens;
        }
        if !plan.run.is_empty() {
            r.prefix_hits += 1;
            pool.note_prefix_hit();
            if plan.partial {
                // partial-hit accounting: a radix match served `served`
                // leading tokens without covering the whole template
                pool.note_prefix_partial_hit(served);
            }
            // LRU stamp: sharing from the run keeps it hot in reclaim order
            if let Some(pfx) = pool.get(id).spec.prefix.as_ref() {
                if plan.partial {
                    kv.touch_path(&pfx.path[..plan.run.len().min(pfx.path.len())]);
                } else {
                    kv.touch_prefix(pfx.id);
                }
            }
        }
        true
    }

    /// Admit arrived, queued requests FCFS while the gate passes (the
    /// shared iteration-level admission rule). Returns how many were
    /// admitted. Under [`InfeasiblePolicy::Reject`], infeasible requests
    /// are rejected and skipped so they never head-of-line-block the
    /// co-running traffic behind them.
    ///
    /// A queue head whose prefix wait is observably STALLED (its
    /// registrant made no progress since the last attempt) no longer
    /// holds the gate either: up to [`bypass_window`](Self::bypass_window)
    /// arrived followers are tried past it, so one wedged template cannot
    /// starve unrelated traffic. A *productive* wait (the fill is
    /// advancing) keeps strict FCFS — that is what preserves the serialized
    /// warm-up, and with it the sharing win, on healthy workloads.
    pub fn admit_fcfs(&self, pool: &mut RequestPool, kv: &mut KvManager, now: f64) -> usize {
        let mut admitted = 0;
        while let Some(id) = pool.next_queued(now) {
            if self.try_admit_one(pool, kv, id, now) {
                admitted += 1;
                continue;
            }
            if pool.get(id).rejected_at.is_some() {
                continue; // rejected as infeasible: keep draining FCFS
            }
            let head_stalled = pool.get(id).prefix_wait.is_some_and(|w| w.stalled_iters >= 1);
            if head_stalled && self.bypass_window > 0 {
                // bounded: the arrival-sorted queued slice is walked lazily,
                // so at most window+1 entries are ever examined — NOT the
                // whole arrived backlog like the old `arrived_queued`
                // collect (the tiny collect below is what lets
                // try_admit_one take `&mut pool`)
                let window: Vec<usize> = pool
                    .queued_ids()
                    .iter()
                    .copied()
                    .take_while(|&q| pool.get(q).arrival <= now)
                    .filter(|&q| q != id)
                    .take(self.bypass_window)
                    .collect();
                for q in window {
                    if self.try_admit_one(pool, kv, q, now) {
                        admitted += 1;
                    }
                }
            }
            break;
        }
        admitted
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::RequestSpec;

    fn pool_of(n: usize) -> RequestPool {
        let specs: Vec<RequestSpec> =
            (0..n)
                .map(|_| RequestSpec { prompt_len: 64, decode_len: 8, arrival: 0.0, prefix: None })
                .collect();
        RequestPool::from_specs(&specs)
    }

    #[test]
    fn degenerate_admission_is_one_slot_per_request() {
        let mut pool = pool_of(5);
        let mut kv = KvManager::new(3);
        let n = Admission::default().admit_fcfs(&mut pool, &mut kv, 0.0);
        assert_eq!(n, 3);
        assert_eq!(kv.available(), 0);
        assert_eq!(pool.active_count(), 3);
        for id in 0..3 {
            assert_eq!(pool.get(id).blocks.len(), 1);
        }
    }

    #[test]
    fn admission_reserves_the_full_prompt() {
        let mut pool = pool_of(2);
        let mut kv = KvManager::paged(8, 16);
        let adm = Admission::default();
        // 64-token prompt = 4 blocks reserved at admission, so chunked
        // prefill never needs to allocate mid-flight
        assert_eq!(adm.blocks_required(&pool, &kv, 0), 4);
        assert!(adm.try_admit_one(&mut pool, &mut kv, 0, 0.0));
        assert_eq!(pool.get(0).blocks.len(), 4);
        let mut table = std::mem::take(&mut pool.get_mut(0).blocks);
        assert!(kv.extend_to(&mut table, 64), "prefill growth is a no-op");
        assert_eq!(table.len(), 4);
        pool.get_mut(0).blocks = table;
    }

    #[test]
    fn watermark_holds_back_headroom() {
        let mut pool = pool_of(5);
        let mut kv = KvManager::paged(8, 16);
        // each 64-token prompt needs 4 blocks; with a 2-block watermark
        // only one request fits (the second would leave < 2 free)
        let n = Admission::with_watermark(2).admit_fcfs(&mut pool, &mut kv, 0.0);
        assert_eq!(n, 1, "second admission would eat the growth headroom");
        assert_eq!(kv.available(), 4);
    }

    #[test]
    fn preempted_request_needs_its_full_footprint() {
        let mut pool = pool_of(2);
        let mut kv = KvManager::paged(8, 16);
        let adm = Admission::default();
        assert!(adm.try_admit_one(&mut pool, &mut kv, 0, 0.0));
        // progress past the prompt (64 prefilled + 9 decoded), then preempt
        {
            let r = pool.get_mut(0);
            r.prefilled = 64;
            r.decoded = 10;
        }
        assert!(kv.extend_to(&mut pool.get_mut(0).blocks, 73));
        let blocks = pool.preempt(0, 1.0);
        kv.release_seq(blocks);
        // swap-in needs the whole live footprint: 74 tokens = 5 blocks
        assert_eq!(adm.blocks_required(&pool, &kv, 0), 5);
        // with only 2 free blocks the swap-in must NOT pass
        let held = kv.alloc_n(6).unwrap();
        assert!(!adm.can_admit(&pool, &kv, 0));
        kv.release_seq(held);
        assert!(adm.try_admit_one(&mut pool, &mut kv, 0, 2.0));
        assert_eq!(pool.get(0).blocks.len(), 5);
    }

    #[test]
    fn prefix_miss_registers_and_hit_reserves_only_private_blocks() {
        use crate::workload::PrefixSpec;
        // template: 40-token prefix (3 blocks of 16, last partial), each
        // request adds 24 unique prompt tokens → prompt 64 = 4 blocks
        let spec = RequestSpec {
            prompt_len: 64,
            decode_len: 8,
            arrival: 0.0,
            prefix: Some(PrefixSpec::whole(7, 40)),
        };
        let mut pool = RequestPool::from_specs(&[spec.clone(), spec.clone(), spec]);
        let mut kv = KvManager::paged(16, 16);
        let adm = Admission::default().with_prefix_share(true);

        // miss: full prompt (4 blocks) + 1 COW fork block for the
        // registrant's own suffix
        assert_eq!(adm.blocks_required(&pool, &kv, 0), 5);
        assert!(adm.try_admit_one(&mut pool, &mut kv, 0, 0.0));
        assert_eq!(kv.num_prefixes(), 1);
        let r0 = pool.get(0);
        assert_eq!(r0.blocks.len(), 4);
        assert_eq!(r0.shared_blocks, 2, "two FULL prefix blocks stay shared");
        assert_eq!(r0.shared_tokens, 32);
        assert_eq!(r0.prefix_hits, 0, "the registrant is a miss");
        assert_eq!(r0.prefilled, 0, "the registrant computes its whole prompt");
        let r0_head: Vec<usize> = r0.blocks[..2].to_vec();
        // 4 table blocks + the pinned partial original = 5 allocated
        assert_eq!(kv.allocated(), 5);

        // while the registrant is still computing the prefix, the run is
        // indexed but not servable: same-template arrivals WAIT
        assert!(!kv.is_prefix_ready(7));
        assert!(!adm.can_admit(&pool, &kv, 1), "must wait for the in-flight fill");
        assert!(!adm.try_admit_one(&mut pool, &mut kv, 1, 0.05));
        assert!(pool.get(1).rejected_at.is_none(), "waiting is not rejection");
        // the registrant's prefill crosses the covered tokens → servable
        // (the engine flips this through StepApplier; unit-flip here)
        kv.mark_prefix_ready(7);

        // hit: only the non-shared footprint is reserved — 4 total minus
        // 3 run blocks plus 1 fork = 2 fresh blocks
        assert_eq!(adm.blocks_required(&pool, &kv, 1), 2);
        assert!(adm.try_admit_one(&mut pool, &mut kv, 1, 0.1));
        let r1_blocks = {
            let r1 = pool.get(1);
            assert_eq!(r1.blocks.len(), 4);
            assert_eq!(r1.shared_blocks, 2);
            assert_eq!(r1.shared_tokens, 32);
            assert_eq!(r1.prefix_hits, 1);
            assert_eq!(r1.prefilled, 40, "resident KV serves all but the prompt tail");
            assert_eq!(r1.prefix_skipped_tokens, 40);
            // skipped prompt tokens stay inside the prefix coverage
            assert!(r1.prefilled < 64);
            r1.blocks.clone()
        };
        assert_eq!(pool.take_prefix_hits(), 1);
        // sharer adds its fork copy + 1 private block
        assert_eq!(kv.allocated(), 7);
        // the shared head is the SAME physical run for both sharers
        assert_eq!(r0_head[..], r1_blocks[..2]);
        assert!(kv.is_shared(r1_blocks[0]));
        // tails are private, refcount 1
        for &b in &r1_blocks[2..] {
            assert_eq!(kv.ref_count(b), 1);
        }
        // occupancy counts each shared block once: fragmentation over
        // private live + resident prefix tokens never underflows
        let frag = kv.internal_fragmentation(pool.live_private_kv_tokens());
        assert!(frag <= kv.allocated() * 16);
    }

    #[test]
    fn prefix_share_off_ignores_tags_and_degenerate_pools_never_share() {
        use crate::workload::PrefixSpec;
        let spec = RequestSpec {
            prompt_len: 64,
            decode_len: 8,
            arrival: 0.0,
            prefix: Some(PrefixSpec::whole(3, 48)),
        };
        // sharing off: the tag is inert, baseline reservation applies
        let mut pool = RequestPool::from_specs(&[spec.clone(), spec.clone()]);
        let mut kv = KvManager::paged(16, 16);
        let adm = Admission::default();
        assert_eq!(adm.blocks_required(&pool, &kv, 0), 4);
        assert!(adm.try_admit_one(&mut pool, &mut kv, 0, 0.0));
        assert_eq!(kv.num_prefixes(), 0);
        assert_eq!(pool.get(0).shared_blocks, 0);
        assert_eq!(adm.blocks_required(&pool, &kv, 1), 4, "second pays full price");
        // degenerate pool: sharing on is a no-op (slots hold private KV)
        let mut pool = RequestPool::from_specs(&[spec.clone(), spec.clone()]);
        let mut kv = KvManager::new(4);
        let adm = Admission::default().with_prefix_share(true);
        assert!(adm.try_admit_one(&mut pool, &mut kv, 0, 0.0));
        assert_eq!(kv.num_prefixes(), 0);
        assert!(adm.try_admit_one(&mut pool, &mut kv, 1, 0.0));
        assert_eq!(pool.get(1).prefix_hits, 0);
        assert_eq!(pool.get(1).prefilled, 0);
    }

    #[test]
    fn block_aligned_prefix_shares_without_a_fork() {
        use crate::workload::PrefixSpec;
        // 32-token prefix on 16-token blocks: no partial block, no fork
        let spec = RequestSpec {
            prompt_len: 48,
            decode_len: 4,
            arrival: 0.0,
            prefix: Some(PrefixSpec::whole(9, 32)),
        };
        let mut pool = RequestPool::from_specs(&[spec.clone(), spec.clone()]);
        let mut kv = KvManager::paged(8, 16);
        let adm = Admission::default().with_prefix_share(true);
        // registrant: exactly the prompt footprint, no fork block
        assert_eq!(adm.blocks_required(&pool, &kv, 0), 3);
        assert!(adm.try_admit_one(&mut pool, &mut kv, 0, 0.0));
        assert_eq!(pool.get(0).shared_blocks, 2);
        assert_eq!(pool.get(0).shared_tokens, 32);
        assert_eq!(kv.allocated(), 3);
        kv.mark_prefix_ready(9);
        // hit: 3 total − 2 shared = 1 fresh block
        assert_eq!(adm.blocks_required(&pool, &kv, 1), 1);
        assert!(adm.try_admit_one(&mut pool, &mut kv, 1, 0.0));
        assert_eq!(pool.get(1).prefilled, 32);
        assert_eq!(kv.allocated(), 4);
    }

    #[test]
    fn watermark_math_uses_the_shared_aware_reservation() {
        use crate::workload::PrefixSpec;
        let spec = RequestSpec {
            prompt_len: 64,
            decode_len: 8,
            arrival: 0.0,
            prefix: Some(PrefixSpec::whole(1, 48)),
        };
        let mut pool = RequestPool::from_specs(&[spec.clone(), spec.clone(), spec]);
        // 7 blocks: the registrant takes 4, leaving 3 free
        let mut kv = KvManager::paged(7, 16);
        let adm = Admission::with_watermark(2).with_prefix_share(true);
        assert!(adm.try_admit_one(&mut pool, &mut kv, 0, 0.0));
        assert_eq!(kv.available(), 3);
        kv.mark_prefix_ready(1);
        // a full-price admission would need 4 + 2 watermark > 3 free; the
        // hit needs only 1 fresh block (4 − 3 run) + 2 watermark = 3 ✓
        assert_eq!(adm.blocks_required(&pool, &kv, 1), 1);
        assert!(adm.try_admit_one(&mut pool, &mut kv, 1, 0.1));
        assert_eq!(kv.available(), 2);
        // the next hit fails the watermark check without panicking
        assert!(!adm.can_admit(&pool, &kv, 2));
    }

    /// Tentpole guarantee (1): a waiter whose registrant makes no prefill
    /// progress for `max_prefix_wait` consecutive attempts degrades to a
    /// full-price MISS and admits normally — it does not wait forever.
    #[test]
    fn stalled_fill_degrades_the_waiter_to_a_full_price_miss() {
        use crate::workload::PrefixSpec;
        let spec = RequestSpec {
            prompt_len: 64,
            decode_len: 8,
            arrival: 0.0,
            prefix: Some(PrefixSpec::whole(7, 40)),
        };
        let mut pool = RequestPool::from_specs(&[spec.clone(), spec.clone()]);
        let mut kv = KvManager::paged(16, 16);
        let adm = Admission::default().with_prefix_share(true).with_max_prefix_wait(3);
        assert!(adm.try_admit_one(&mut pool, &mut kv, 0, 0.0));
        // the registrant never advances its fill (preempted / starved in
        // another stream): each attempt ticks the waiter's stall clock
        for i in 1..=3 {
            assert!(!adm.try_admit_one(&mut pool, &mut kv, 1, 0.1 * i as f64));
            assert!(pool.get(1).is_prefix_waiting());
        }
        // attempt 4 observes the 3rd consecutive no-progress tick: the
        // wait degrades and the request admits at full price in one pass
        assert!(adm.try_admit_one(&mut pool, &mut kv, 1, 1.0));
        let r = pool.get(1);
        assert!(r.prefix_fallback);
        assert!(!r.is_prefix_waiting());
        assert_eq!(r.prefix_hits, 0, "a fallback is a miss, not a hit");
        assert_eq!(r.prefilled, 0, "full price: no compute skip");
        assert_eq!(r.shared_blocks, 0);
        assert_eq!(r.prefix_wait_iters, 4);
        assert!(r.prefix_wait_time > 0.0, "the wait-time histogram sees the wait");
        assert_eq!(pool.take_prefix_fallbacks(), 1);
        assert_eq!(pool.take_prefix_wait_ticks(), 4);
    }

    /// A fill that keeps advancing resets the stall clock — healthy
    /// warm-up waits are never charged the fallback.
    #[test]
    fn registrant_progress_resets_the_waiters_stall_clock() {
        use crate::workload::PrefixSpec;
        let spec = RequestSpec {
            prompt_len: 64,
            decode_len: 8,
            arrival: 0.0,
            prefix: Some(PrefixSpec::whole(7, 40)),
        };
        let mut pool = RequestPool::from_specs(&[spec.clone(), spec.clone()]);
        let mut kv = KvManager::paged(16, 16);
        let adm = Admission::default().with_prefix_share(true).with_max_prefix_wait(2);
        assert!(adm.try_admit_one(&mut pool, &mut kv, 0, 0.0));
        assert!(!adm.try_admit_one(&mut pool, &mut kv, 1, 0.1)); // init
        assert!(!adm.try_admit_one(&mut pool, &mut kv, 1, 0.2)); // stall 1
        kv.note_prefix_fill(7, 16); // the registrant's chunk lands
        assert!(!adm.try_admit_one(&mut pool, &mut kv, 1, 0.3)); // progress: reset
        assert!(!adm.try_admit_one(&mut pool, &mut kv, 1, 0.4)); // stall 1
        assert!(!pool.get(1).prefix_fallback, "progress bought more patience");
        assert!(adm.try_admit_one(&mut pool, &mut kv, 1, 0.5)); // stall 2 = K
        assert!(pool.get(1).prefix_fallback);
    }

    /// Preempting the filler counts as an immediate stall tick even when
    /// the fill also advanced in the same interval — preemption is
    /// first-class in the waiter's progress reasoning.
    #[test]
    fn filler_preemption_ticks_the_stall_clock_despite_progress() {
        use crate::workload::PrefixSpec;
        let spec = RequestSpec {
            prompt_len: 64,
            decode_len: 8,
            arrival: 0.0,
            prefix: Some(PrefixSpec::whole(7, 40)),
        };
        let mut pool = RequestPool::from_specs(&[spec.clone(), spec.clone()]);
        let mut kv = KvManager::paged(16, 16);
        let adm = Admission::default().with_prefix_share(true).with_max_prefix_wait(2);
        assert!(adm.try_admit_one(&mut pool, &mut kv, 0, 0.0));
        assert!(!adm.try_admit_one(&mut pool, &mut kv, 1, 0.1)); // init
        kv.note_prefix_fill(7, 16);
        kv.note_prefix_filler_preempted(7);
        assert!(!adm.try_admit_one(&mut pool, &mut kv, 1, 0.2)); // stall 1
        kv.note_prefix_fill(7, 32);
        kv.note_prefix_filler_preempted(7);
        // stall 2 = K: two preemption storms outweigh the partial progress
        assert!(adm.try_admit_one(&mut pool, &mut kv, 1, 0.3));
        assert!(pool.get(1).prefix_fallback);
    }

    /// Tentpole guarantee (2): a queue head whose wait is observably
    /// stalled no longer holds the FCFS gate — feasible followers admit
    /// through a bounded bypass window. A productive (advancing) wait
    /// keeps strict FCFS, and window 0 restores the PR-3 gate.
    #[test]
    fn stalled_waiting_head_does_not_block_feasible_followers() {
        use crate::workload::PrefixSpec;
        let tpl = RequestSpec {
            prompt_len: 64,
            decode_len: 8,
            arrival: 0.0,
            prefix: Some(PrefixSpec::whole(3, 40)),
        };
        let plain = RequestSpec { prompt_len: 32, decode_len: 4, arrival: 0.2, prefix: None };
        let mut pool = RequestPool::from_specs(&[tpl.clone(), tpl.clone(), plain.clone(), plain.clone()]);
        let mut kv = KvManager::paged(24, 16);
        let adm = Admission::default().with_prefix_share(true);
        // pass 1: the registrant admits; the same-template follower's
        // first attempt initializes its wait (not yet stalled, no bypass)
        assert_eq!(adm.admit_fcfs(&mut pool, &mut kv, 0.1), 1);
        assert!(pool.get(1).is_prefix_waiting());
        // pass 2: the fill made no progress -> the head is STALLED, and
        // the plain requests behind it admit through the bypass window
        assert_eq!(adm.admit_fcfs(&mut pool, &mut kv, 0.3), 2);
        assert!(pool.get(1).is_prefix_waiting(), "the head keeps waiting");
        assert!(pool.get(2).is_admitted() && pool.get(3).is_admitted());
        // window 0: the stalled head holds the gate absolutely (old gate)
        let mut pool = RequestPool::from_specs(&[tpl.clone(), tpl.clone(), plain.clone(), plain.clone()]);
        let mut kv = KvManager::paged(24, 16);
        let strict = adm.with_bypass_window(0);
        assert_eq!(strict.admit_fcfs(&mut pool, &mut kv, 0.1), 1);
        assert_eq!(strict.admit_fcfs(&mut pool, &mut kv, 0.3), 0);
        assert!(!pool.get(2).is_admitted() && !pool.get(3).is_admitted());
    }

    /// Satellite regression: `is_feasible` must subtract servable shared
    /// coverage from the lifetime peak. A long-prompt template request
    /// whose covered tokens live in the pinned resident run — and whose
    /// private footprint fits — was rejected/panicked as infeasible when
    /// the peak was computed from the full `prompt_len`.
    #[test]
    fn servable_prefix_coverage_counts_against_the_lifetime_peak() {
        use crate::workload::PrefixSpec;
        let registrant = RequestSpec {
            prompt_len: 144,
            decode_len: 4,
            arrival: 0.0,
            prefix: Some(PrefixSpec::whole(11, 128)),
        };
        let follower = RequestSpec {
            prompt_len: 160,
            decode_len: 32,
            arrival: 0.1,
            prefix: Some(PrefixSpec::whole(11, 128)),
        };
        let mut pool = RequestPool::from_specs(&[registrant, follower]);
        let mut kv = KvManager::paged(12, 16);
        let adm = Admission::with_watermark(2).with_prefix_share(true);
        assert!(adm.try_admit_one(&mut pool, &mut kv, 0, 0.0));
        // the rescue already counts the run while it is still FILLING: the
        // follower WAITS here (registered, unready) instead of panicking
        // as infeasible one iteration before the fill completes
        assert!(adm.is_feasible(&pool, &kv, 1), "a filling run already rescues");
        assert!(!adm.try_admit_one(&mut pool, &mut kv, 1, 0.02));
        assert!(pool.get(1).is_prefix_waiting(), "held by the wait, not rejected");
        kv.mark_prefix_ready(11); // the registrant's fill, unit-flipped
        // full price the follower can never fit: peak 160+31 = 191 tokens
        // = 12 blocks + 2 watermark > 12 — the plain gate agrees
        assert!(!Admission::with_watermark(2).is_feasible(&pool, &kv, 1));
        // but 8 of those blocks are the resident servable run: private
        // lifetime = 12 − 8 = 4 blocks + 2 watermark fits easily
        assert!(adm.is_feasible(&pool, &kv, 1), "covered tokens are not private peak");
        assert_eq!(adm.blocks_required(&pool, &kv, 1), 2, "10 total − 8 shared");
        // and it actually admits once the registrant's table frees up
        {
            let r = pool.get_mut(0);
            r.prefilled = 144;
            r.decoded = 4;
        }
        let blocks = pool.complete(0, 0.05);
        kv.release_seq(blocks);
        assert!(adm.try_admit_one(&mut pool, &mut kv, 1, 0.1));
        let r = pool.get(1);
        assert_eq!(r.shared_blocks, 8);
        assert_eq!(r.prefilled, 128, "the resident run serves the covered prefill");
        // the rescue is NOT a blank check: the sharer's own table keeps
        // the run resident, so a request whose run + private peak exceeds
        // the pool can never complete as a sharer and stays infeasible —
        // admitting it would livelock in grow/preempt/resume forever
        let probe = RequestPool::from_specs(&[RequestSpec {
            prompt_len: 160,
            decode_len: 96, // peak 255 tokens: 8 run + 8 private > 12 blocks
            arrival: 0.2,
            prefix: Some(PrefixSpec::whole(11, 128)),
        }]);
        assert!(!adm.is_feasible(&probe, &kv, 0), "run + private peak exceeds the pool");
    }

    /// A servable hit that could never complete AS A SHARER (run +
    /// private peak > pool) but fits at full price must plan plain — the
    /// cheaper up-front reservation would buy an endless
    /// grow/preempt/resume livelock.
    #[test]
    fn sharer_infeasible_hit_pays_full_price_instead_of_livelocking() {
        use crate::workload::PrefixSpec;
        let reg = RequestSpec {
            prompt_len: 48,
            decode_len: 4,
            arrival: 0.0,
            prefix: Some(PrefixSpec::whole(5, 40)),
        };
        // peak 64 + 96 = 160 tokens = exactly the 10-block pool: feasible
        // at full price, but as a sharer it would need the 3 pinned run
        // blocks + 8 private (7 tail + 1 COW fork) = 11 > 10
        let follower = RequestSpec {
            prompt_len: 64,
            decode_len: 97,
            arrival: 0.1,
            prefix: Some(PrefixSpec::whole(5, 40)),
        };
        let mut pool = RequestPool::from_specs(&[reg, follower]);
        let mut kv = KvManager::paged(10, 16);
        let adm = Admission::default().with_prefix_share(true);
        assert!(adm.try_admit_one(&mut pool, &mut kv, 0, 0.0));
        kv.mark_prefix_ready(5);
        {
            let r = pool.get_mut(0);
            r.prefilled = 48;
            r.decoded = 4;
        }
        let blocks = pool.complete(0, 0.05);
        kv.release_seq(blocks);
        assert!(adm.is_feasible(&pool, &kv, 1), "feasible at full price");
        assert_eq!(adm.blocks_required(&pool, &kv, 1), 4, "plain reservation, no share");
        assert!(adm.try_admit_one(&mut pool, &mut kv, 1, 0.1));
        let r = pool.get(1);
        assert_eq!(r.prefix_hits, 0, "the oversized sharer never shares");
        assert_eq!(r.shared_blocks, 0);
        assert_eq!(r.prefilled, 0, "no compute skip at full price");
    }

    #[test]
    #[should_panic(expected = "undersized paged KV pool")]
    fn oversized_request_is_rejected_loudly() {
        // a 64-token prompt needs 4 blocks; a 3-block pool can never admit
        // it — better an immediate, named panic than a silent engine wedge
        let mut pool = pool_of(1);
        let mut kv = KvManager::paged(3, 16);
        Admission::default().try_admit_one(&mut pool, &mut kv, 0, 0.0);
    }

    #[test]
    fn reject_policy_drops_the_oversized_request_and_serves_the_rest() {
        // same oversized request as the panic test, but co-running traffic
        // behind it must keep flowing in serve/open-loop mode
        let mut pool = RequestPool::from_specs(&[
            // 16 blocks: never fits
            RequestSpec { prompt_len: 256, decode_len: 8, arrival: 0.0, prefix: None },
            RequestSpec { prompt_len: 32, decode_len: 8, arrival: 0.1, prefix: None },
            RequestSpec { prompt_len: 32, decode_len: 8, arrival: 0.2, prefix: None },
        ]);
        let mut kv = KvManager::paged(8, 16);
        let adm = Admission::default().with_infeasible(InfeasiblePolicy::Reject);
        let n = adm.admit_fcfs(&mut pool, &mut kv, 1.0);
        assert_eq!(n, 2, "feasible requests behind the rejected one are admitted");
        assert_eq!(pool.rejected_count(), 1);
        assert_eq!(pool.get(0).rejected_at, Some(1.0));
        assert!(pool.get(1).is_admitted() && pool.get(2).is_admitted());
        // can_admit on an infeasible id must not panic under Reject
        let probe = RequestPool::from_specs(&[RequestSpec {
            prompt_len: 256,
            decode_len: 8,
            arrival: 0.0,
            prefix: None,
        }]);
        assert!(!adm.can_admit(&probe, &kv, 0));
    }

    #[test]
    #[should_panic(expected = "undersized paged KV pool")]
    fn decode_heavy_request_that_cannot_complete_is_rejected_up_front() {
        // tiny prompt, huge decode: the prompt footprint (2 blocks) fits a
        // 12-block pool, but the lifetime peak (32 + 200 − 1 tokens = 15
        // blocks) never will — reject at admission, not after burning the
        // whole run and preempting every co-running request
        let mut pool = RequestPool::from_specs(&[RequestSpec {
            prompt_len: 32,
            decode_len: 200,
            arrival: 0.0,
            prefix: None,
        }]);
        let mut kv = KvManager::paged(12, 16);
        Admission::default().try_admit_one(&mut pool, &mut kv, 0, 0.0);
    }
}
