//! Memory-aware, watermark-based admission over the paged KV pool.
//!
//! Admission is the first half of every scheduling step (the second is
//! batch composition — see [`super::Scheduler`]). The gate reserves the
//! request's prompt footprint up front and its live KV on swap-in (see
//! [`Admission::blocks_required`]); only decode growth extends the table
//! later, which is what the watermark buffers. Under the degenerate block
//! size everything collapses to the seed's one-slot-per-request rule, so
//! the paper experiments reproduce unchanged.
//!
//! The watermark reserves free blocks for decode growth of already-running
//! requests (vLLM-style): admitting greedily to zero free blocks would
//! force a preemption on the very next decode step.

use super::super::kv::KvManager;
use super::super::pool::RequestPool;

/// What the gate does with a request that could NEVER complete in this
/// pool (its lifetime KV peak exceeds capacity even when empty).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum InfeasiblePolicy {
    /// Panic loudly — the right behavior for figure-repro / closed-loop
    /// runs, where an undersized pool means the experiment itself is
    /// misconfigured.
    #[default]
    Panic,
    /// Reject the request into a terminal [`Rejected`] state
    /// ([`RequestPool::reject`]) and keep serving co-running traffic —
    /// the right behavior for `serve`/open-loop paths, where one oversized
    /// request must not crash the server.
    ///
    /// [`Rejected`]: crate::coordinator::request::Phase::Rejected
    Reject,
}

#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Admission {
    /// Free blocks kept in reserve for decode growth of running requests.
    pub watermark_blocks: usize,
    /// Cap on concurrently admitted sequences (Sarathi-Serve's
    /// `max_num_seqs`). `None` bounds admission by memory alone — the seed
    /// policies' behavior, where the slot pool itself is the cap.
    pub max_active: Option<usize>,
    /// Panic or reject on requests that can never fit the pool.
    pub infeasible: InfeasiblePolicy,
}

impl Admission {
    pub fn with_watermark(watermark_blocks: usize) -> Self {
        Admission { watermark_blocks, ..Self::default() }
    }

    pub fn with_max_active(mut self, max_active: usize) -> Self {
        self.max_active = Some(max_active);
        self
    }

    pub fn with_infeasible(mut self, policy: InfeasiblePolicy) -> Self {
        self.infeasible = policy;
        self
    }

    /// Blocks request `id` needs to be admitted right now: the full prompt
    /// is reserved up front (vLLM-style — prefill length is known, so a
    /// running chunked prefill never has to grab blocks mid-flight and the
    /// watermark only has to absorb decode growth); a swapped-out request
    /// needs its whole KV footprint plus the next token back.
    pub fn blocks_required(&self, pool: &RequestPool, kv: &KvManager, id: usize) -> usize {
        let r = pool.get(id);
        kv.blocks_needed(r.spec.prompt_len.max(r.kv_len() + 1)).max(1)
    }

    /// True when `id` could run to COMPLETION in an empty pool: its
    /// lifetime KV peak (`prompt + decode − 1` tokens, both known in the
    /// spec) plus the watermark fits the pool. Shared by
    /// [`can_admit`](Self::can_admit) and
    /// [`try_admit_one`](Self::try_admit_one) so the two entry points
    /// cannot disagree about an infeasible request.
    pub fn is_feasible(&self, pool: &RequestPool, kv: &KvManager, id: usize) -> bool {
        let spec = pool.get(id).spec;
        let peak = spec.prompt_len + spec.decode_len.saturating_sub(1);
        let lifetime = kv.blocks_needed(peak.max(1));
        lifetime.saturating_add(self.watermark_blocks) <= kv.capacity()
    }

    /// Under [`InfeasiblePolicy::Panic`], panic loudly on an infeasible
    /// request. Without that guard an oversized request is admitted on its
    /// prompt footprint, grows to the memory wall, preempts every
    /// co-running request, and only then wedges the engine with no hint at
    /// the cause.
    fn panic_infeasible(&self, pool: &RequestPool, kv: &KvManager, id: usize) -> ! {
        let spec = pool.get(id).spec;
        let peak = spec.prompt_len + spec.decode_len.saturating_sub(1);
        let lifetime = kv.blocks_needed(peak.max(1));
        panic!(
            "request {id} can never complete: its KV peaks at {peak} tokens = {lifetime} blocks \
             (+{} watermark) but the pool only has {} — undersized paged KV pool for this workload",
            self.watermark_blocks,
            kv.capacity()
        );
    }

    /// True if the gate passes for `id` without allocating. Panics (like
    /// [`try_admit_one`](Self::try_admit_one)) when the request could never
    /// be admitted at all and the policy is [`InfeasiblePolicy::Panic`];
    /// under [`InfeasiblePolicy::Reject`] it returns false without
    /// mutating anything.
    pub fn can_admit(&self, pool: &RequestPool, kv: &KvManager, id: usize) -> bool {
        if let Some(cap) = self.max_active {
            if pool.active_count() >= cap {
                return false;
            }
        }
        if !self.is_feasible(pool, kv, id) {
            match self.infeasible {
                InfeasiblePolicy::Panic => self.panic_infeasible(pool, kv, id),
                InfeasiblePolicy::Reject => return false,
            }
        }
        let need = self.blocks_required(pool, kv, id);
        kv.available() >= need.saturating_add(self.watermark_blocks)
    }

    /// Admit `id` if the gate passes, allocating its initial block table.
    ///
    /// An infeasible request panics under [`InfeasiblePolicy::Panic`]
    /// (loudly, like the allocator's double-free); under
    /// [`InfeasiblePolicy::Reject`] it is moved to the terminal
    /// `Rejected` state and false is returned.
    pub fn try_admit_one(
        &self,
        pool: &mut RequestPool,
        kv: &mut KvManager,
        id: usize,
        now: f64,
    ) -> bool {
        if self.infeasible == InfeasiblePolicy::Reject && !self.is_feasible(pool, kv, id) {
            pool.reject(id, now);
            return false;
        }
        if !self.can_admit(pool, kv, id) {
            return false;
        }
        let need = self.blocks_required(pool, kv, id);
        let blocks = kv.alloc_n(need).expect("admission gate checked availability");
        pool.admit(id, blocks, now);
        true
    }

    /// Admit arrived, queued requests FCFS while the gate passes (the
    /// shared iteration-level admission rule). Returns how many were
    /// admitted. Under [`InfeasiblePolicy::Reject`], infeasible requests
    /// are rejected and skipped so they never head-of-line-block the
    /// co-running traffic behind them.
    pub fn admit_fcfs(&self, pool: &mut RequestPool, kv: &mut KvManager, now: f64) -> usize {
        let mut admitted = 0;
        while let Some(id) = pool.next_queued(now) {
            if !self.try_admit_one(pool, kv, id, now) {
                if pool.get(id).rejected_at.is_some() {
                    continue; // rejected as infeasible: keep draining FCFS
                }
                break;
            }
            admitted += 1;
        }
        admitted
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::RequestSpec;

    fn pool_of(n: usize) -> RequestPool {
        let specs: Vec<RequestSpec> =
            (0..n).map(|_| RequestSpec { prompt_len: 64, decode_len: 8, arrival: 0.0 }).collect();
        RequestPool::from_specs(&specs)
    }

    #[test]
    fn degenerate_admission_is_one_slot_per_request() {
        let mut pool = pool_of(5);
        let mut kv = KvManager::new(3);
        let n = Admission::default().admit_fcfs(&mut pool, &mut kv, 0.0);
        assert_eq!(n, 3);
        assert_eq!(kv.available(), 0);
        assert_eq!(pool.active_count(), 3);
        for id in 0..3 {
            assert_eq!(pool.get(id).blocks.len(), 1);
        }
    }

    #[test]
    fn admission_reserves_the_full_prompt() {
        let mut pool = pool_of(2);
        let mut kv = KvManager::paged(8, 16);
        let adm = Admission::default();
        // 64-token prompt = 4 blocks reserved at admission, so chunked
        // prefill never needs to allocate mid-flight
        assert_eq!(adm.blocks_required(&pool, &kv, 0), 4);
        assert!(adm.try_admit_one(&mut pool, &mut kv, 0, 0.0));
        assert_eq!(pool.get(0).blocks.len(), 4);
        let mut table = std::mem::take(&mut pool.get_mut(0).blocks);
        assert!(kv.extend_to(&mut table, 64), "prefill growth is a no-op");
        assert_eq!(table.len(), 4);
        pool.get_mut(0).blocks = table;
    }

    #[test]
    fn watermark_holds_back_headroom() {
        let mut pool = pool_of(5);
        let mut kv = KvManager::paged(8, 16);
        // each 64-token prompt needs 4 blocks; with a 2-block watermark
        // only one request fits (the second would leave < 2 free)
        let n = Admission::with_watermark(2).admit_fcfs(&mut pool, &mut kv, 0.0);
        assert_eq!(n, 1, "second admission would eat the growth headroom");
        assert_eq!(kv.available(), 4);
    }

    #[test]
    fn preempted_request_needs_its_full_footprint() {
        let mut pool = pool_of(2);
        let mut kv = KvManager::paged(8, 16);
        let adm = Admission::default();
        assert!(adm.try_admit_one(&mut pool, &mut kv, 0, 0.0));
        // progress past the prompt (64 prefilled + 9 decoded), then preempt
        {
            let r = pool.get_mut(0);
            r.prefilled = 64;
            r.decoded = 10;
        }
        assert!(kv.extend_to(&mut pool.get_mut(0).blocks, 73));
        let blocks = pool.preempt(0, 1.0);
        kv.release_seq(blocks);
        // swap-in needs the whole live footprint: 74 tokens = 5 blocks
        assert_eq!(adm.blocks_required(&pool, &kv, 0), 5);
        // with only 2 free blocks the swap-in must NOT pass
        let held = kv.alloc_n(6).unwrap();
        assert!(!adm.can_admit(&pool, &kv, 0));
        kv.release_seq(held);
        assert!(adm.try_admit_one(&mut pool, &mut kv, 0, 2.0));
        assert_eq!(pool.get(0).blocks.len(), 5);
    }

    #[test]
    #[should_panic(expected = "undersized paged KV pool")]
    fn oversized_request_is_rejected_loudly() {
        // a 64-token prompt needs 4 blocks; a 3-block pool can never admit
        // it — better an immediate, named panic than a silent engine wedge
        let mut pool = pool_of(1);
        let mut kv = KvManager::paged(3, 16);
        Admission::default().try_admit_one(&mut pool, &mut kv, 0, 0.0);
    }

    #[test]
    fn reject_policy_drops_the_oversized_request_and_serves_the_rest() {
        // same oversized request as the panic test, but co-running traffic
        // behind it must keep flowing in serve/open-loop mode
        let mut pool = RequestPool::from_specs(&[
            RequestSpec { prompt_len: 256, decode_len: 8, arrival: 0.0 }, // 16 blocks: never fits
            RequestSpec { prompt_len: 32, decode_len: 8, arrival: 0.1 },
            RequestSpec { prompt_len: 32, decode_len: 8, arrival: 0.2 },
        ]);
        let mut kv = KvManager::paged(8, 16);
        let adm = Admission::default().with_infeasible(InfeasiblePolicy::Reject);
        let n = adm.admit_fcfs(&mut pool, &mut kv, 1.0);
        assert_eq!(n, 2, "feasible requests behind the rejected one are admitted");
        assert_eq!(pool.rejected_count(), 1);
        assert_eq!(pool.get(0).rejected_at, Some(1.0));
        assert!(pool.get(1).is_admitted() && pool.get(2).is_admitted());
        // can_admit on an infeasible id must not panic under Reject
        let probe = RequestPool::from_specs(&[RequestSpec {
            prompt_len: 256,
            decode_len: 8,
            arrival: 0.0,
        }]);
        assert!(!adm.can_admit(&probe, &kv, 0));
    }

    #[test]
    #[should_panic(expected = "undersized paged KV pool")]
    fn decode_heavy_request_that_cannot_complete_is_rejected_up_front() {
        // tiny prompt, huge decode: the prompt footprint (2 blocks) fits a
        // 12-block pool, but the lifetime peak (32 + 200 − 1 tokens = 15
        // blocks) never will — reject at admission, not after burning the
        // whole run and preempting every co-running request
        let mut pool = RequestPool::from_specs(&[RequestSpec {
            prompt_len: 32,
            decode_len: 200,
            arrival: 0.0,
        }]);
        let mut kv = KvManager::paged(12, 16);
        Admission::default().try_admit_one(&mut pool, &mut kv, 0, 0.0);
    }
}
