//! Memory-aware, watermark-based admission over the paged KV pool, with
//! copy-on-write prefix sharing.
//!
//! Admission is the first half of every scheduling step (the second is
//! batch composition — see [`super::Scheduler`]). The gate reserves the
//! request's prompt footprint up front and its live KV on swap-in (see
//! [`Admission::blocks_required`]); only decode growth extends the table
//! later, which is what the watermark buffers. Under the degenerate block
//! size everything collapses to the seed's one-slot-per-request rule, so
//! the paper experiments reproduce unchanged.
//!
//! With [`Admission::prefix_share`] on (and a paged pool), a request whose
//! [`PrefixSpec`] names a prefix already resident in the allocator's index
//! reserves only its NON-shared tokens: the resident run is ref-count
//! shared into the head of its block table, the partially-filled last
//! prefix block is copy-on-write forked ([`KvManager::fork_block`]) so the
//! request can append without mutating shared content, and the prefill
//! compute for the covered tokens is skipped (their KV already exists).
//! A miss admits normally and then *registers* the request's table head as
//! the template's resident run, so every later arrival of the template
//! hits. Watermark math and swap-in costing both work on the private
//! footprint — shared blocks are neither reserved twice nor moved.
//!
//! The watermark reserves free blocks for decode growth of already-running
//! requests (vLLM-style): admitting greedily to zero free blocks would
//! force a preemption on the very next decode step.
//!
//! [`PrefixSpec`]: crate::workload::PrefixSpec

use super::super::kv::KvManager;
use super::super::pool::RequestPool;

/// What the gate does with a request that could NEVER complete in this
/// pool (its lifetime KV peak exceeds capacity even when empty).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum InfeasiblePolicy {
    /// Panic loudly — the right behavior for figure-repro / closed-loop
    /// runs, where an undersized pool means the experiment itself is
    /// misconfigured.
    #[default]
    Panic,
    /// Reject the request into a terminal [`Rejected`] state
    /// ([`RequestPool::reject`]) and keep serving co-running traffic —
    /// the right behavior for `serve`/open-loop paths, where one oversized
    /// request must not crash the server.
    ///
    /// [`Rejected`]: crate::coordinator::request::Phase::Rejected
    Reject,
}

#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Admission {
    /// Free blocks kept in reserve for decode growth of running requests.
    pub watermark_blocks: usize,
    /// Cap on concurrently admitted sequences (Sarathi-Serve's
    /// `max_num_seqs`). `None` bounds admission by memory alone — the seed
    /// policies' behavior, where the slot pool itself is the cap.
    pub max_active: Option<usize>,
    /// Panic or reject on requests that can never fit the pool.
    pub infeasible: InfeasiblePolicy,
    /// Serve prefix-tagged requests from the allocator's resident-prefix
    /// index (copy-on-write sharing). Off by default: the baseline pays
    /// for every prompt token, prefix-tagged or not.
    pub prefix_share: bool,
}

/// How admission will cover one request's KV footprint: what it can share
/// from a resident prefix run, what must be copy-on-write forked, and how
/// many fresh blocks the gate has to reserve.
#[derive(Clone, Debug, Default)]
struct SharePlan {
    /// Resident run blocks to ref-share into the table head (empty = no
    /// sharing: a miss, an untagged request, or a degenerate pool).
    run: Vec<usize>,
    /// Leading table blocks that stay SHARED after the fork below — the
    /// head of the request's split block table.
    shared_head: usize,
    /// Tokens resident in those shared head blocks (`shared_head` full
    /// blocks' worth).
    shared_tokens: usize,
    /// Prompt tokens whose prefill compute the resident KV serves.
    skip_tokens: usize,
    /// Copy-on-write fork the partially-filled last prefix block (the
    /// request appends into that block's token range).
    fork: bool,
    /// Fresh blocks to allocate: private tail + any COW fork copy.
    new_blocks: usize,
    /// On a miss of a prefix-tagged request: register `(hash, tokens)`
    /// from the new table's head, pinning the run for later sharers.
    register: Option<(u64, usize)>,
    /// The template's run is registered but its KV is still being
    /// computed by the registrant: this request waits (cache-aware
    /// admission) instead of paying full price for KV about to exist.
    blocked: bool,
}

impl Admission {
    pub fn with_watermark(watermark_blocks: usize) -> Self {
        Admission { watermark_blocks, ..Self::default() }
    }

    pub fn with_max_active(mut self, max_active: usize) -> Self {
        self.max_active = Some(max_active);
        self
    }

    pub fn with_infeasible(mut self, policy: InfeasiblePolicy) -> Self {
        self.infeasible = policy;
        self
    }

    /// Enable (or disable) copy-on-write prefix sharing at this gate.
    pub fn with_prefix_share(mut self, on: bool) -> Self {
        self.prefix_share = on;
        self
    }

    /// Tokens request `id` must cover at admission: the full prompt up
    /// front (vLLM-style), or a swapped-out request's whole live KV plus
    /// the next token.
    fn target_tokens(pool: &RequestPool, id: usize) -> usize {
        let r = pool.get(id);
        r.spec.prompt_len.max(r.kv_len() + 1).max(1)
    }

    /// Plan to share `run` (covering `tokens` prompt tokens, clamped to
    /// `cap`) into the head of a table needing `total` blocks. `skip`
    /// grants the compute skip (a servable hit); the resuming filler
    /// re-shares without one. `None` when nothing is coverable.
    fn share_from_run(
        kv: &KvManager,
        run: &[usize],
        tokens: usize,
        cap: usize,
        total: usize,
        skip: bool,
    ) -> Option<SharePlan> {
        let cov = tokens.min(cap);
        let n_run = kv.blocks_needed(cov);
        if n_run == 0 {
            return None;
        }
        // the run's partial last block holds prefix tokens (the filler
        // writes them there in place); a sharer about to APPEND its own
        // tokens into that block's range COW-forks a private copy first
        let fork = cov % kv.block_size() != 0;
        Some(SharePlan {
            run: run[..n_run].to_vec(),
            shared_head: n_run - fork as usize,
            shared_tokens: cov - cov % kv.block_size(),
            skip_tokens: if skip { cov } else { 0 },
            fork,
            new_blocks: total - n_run + fork as usize,
            register: None,
            blocked: false,
        })
    }

    /// Build the share plan for admitting `id` right now. Pure: allocates
    /// nothing, so the gate and the admit path cannot disagree.
    fn plan(&self, pool: &RequestPool, kv: &KvManager, id: usize) -> SharePlan {
        let total = kv.blocks_needed(Self::target_tokens(pool, id)).max(1);
        let plain = SharePlan { new_blocks: total, ..SharePlan::default() };
        if !self.prefix_share || kv.is_degenerate() {
            return plain;
        }
        let Some(pfx) = pool.get(id).spec.prefix else {
            return plain;
        };
        // never cover the full prompt: the final prefill chunk must run to
        // produce the request's first output token
        let cap = pool.get(id).spec.prompt_len.saturating_sub(1);
        let bs = kv.block_size();
        if let Some((tokens, run)) = kv.lookup_servable(pfx.id) {
            // servable hit: share the resident head, skip its compute
            Self::share_from_run(kv, run, tokens, cap, total, true).unwrap_or(plain)
        } else if let Some((tokens, run)) = kv.lookup_prefix(pfx.id) {
            // registered but not yet computed (the fill is in flight or
            // its filler is swapped out).
            let prefilled = pool.get(id).prefilled;
            if prefilled >= tokens {
                // already produced every covered token itself (a resumed
                // request whose original run was since reclaimed): the
                // whole footprint swaps back in at full price
                plain
            } else if prefilled > 0 {
                // the preempted filler: re-share the pinned head it was
                // filling — its computed KV lives THERE, so swap-in only
                // moves its private tail, and holding the head again
                // lets its prefill flip the run servable when it crosses
                // the covered tokens (liveness: without this, a filler
                // preempted mid-fill could never ready its run and every
                // fresh same-template arrival would wait forever). No
                // compute skip: the fill resumes for real.
                Self::share_from_run(kv, run, tokens, cap, total, false).unwrap_or(plain)
            } else {
                // fresh same-template arrivals WAIT for the in-flight
                // fill instead of paying full price for KV about to
                // exist (cache-aware admission). FCFS-fair like the
                // memory gate: a waiting head holds the queue.
                SharePlan { blocked: true, ..plain }
            }
        } else {
            // miss: admit normally, then register the table head as the
            // template's resident run. Content contract: the registrant
            // prefills every COVERED token (1..=cov) into the pinned run
            // in place — including the partial last block — and its OWN
            // suffix tokens go into the +1 COW fork taken at admission,
            // so the pinned partial always ends up holding exactly the
            // prefix content sharers later fork-copy from. Nobody reads
            // the run before the fill completes (readiness gate).
            // Sub-block prefixes are never cached (no full block to
            // share).
            let cov = pfx.len.min(cap);
            if cov < bs {
                return plain;
            }
            let fork = cov % bs != 0;
            SharePlan {
                run: Vec::new(),
                shared_head: kv.blocks_needed(cov) - fork as usize,
                shared_tokens: cov - cov % bs,
                skip_tokens: 0,
                fork,
                new_blocks: total + fork as usize,
                register: Some((pfx.id, cov)),
                blocked: false,
            }
        }
    }

    /// Fresh blocks request `id` needs to be admitted right now: the full
    /// prompt is reserved up front (vLLM-style — prefill length is known,
    /// so a running chunked prefill never has to grab blocks mid-flight
    /// and the watermark only has to absorb decode growth); a swapped-out
    /// request needs its whole KV footprint plus the next token back.
    /// Tokens covered by a resident shared prefix are NOT reserved — that
    /// is the admission-side win of prefix sharing.
    pub fn blocks_required(&self, pool: &RequestPool, kv: &KvManager, id: usize) -> usize {
        self.plan(pool, kv, id).new_blocks
    }

    /// True when `id` could run to COMPLETION in an empty pool: its
    /// lifetime KV peak (`prompt + decode − 1` tokens, both known in the
    /// spec) plus the watermark fits the pool. Shared by
    /// [`can_admit`](Self::can_admit) and
    /// [`try_admit_one`](Self::try_admit_one) so the two entry points
    /// cannot disagree about an infeasible request.
    pub fn is_feasible(&self, pool: &RequestPool, kv: &KvManager, id: usize) -> bool {
        let spec = pool.get(id).spec;
        let peak = spec.prompt_len + spec.decode_len.saturating_sub(1);
        let lifetime = kv.blocks_needed(peak.max(1));
        lifetime.saturating_add(self.watermark_blocks) <= kv.capacity()
    }

    /// Under [`InfeasiblePolicy::Panic`], panic loudly on an infeasible
    /// request. Without that guard an oversized request is admitted on its
    /// prompt footprint, grows to the memory wall, preempts every
    /// co-running request, and only then wedges the engine with no hint at
    /// the cause.
    fn panic_infeasible(&self, pool: &RequestPool, kv: &KvManager, id: usize) -> ! {
        let spec = pool.get(id).spec;
        let peak = spec.prompt_len + spec.decode_len.saturating_sub(1);
        let lifetime = kv.blocks_needed(peak.max(1));
        panic!(
            "request {id} can never complete: its KV peaks at {peak} tokens = {lifetime} blocks \
             (+{} watermark) but the pool only has {} — undersized paged KV pool for this workload",
            self.watermark_blocks,
            kv.capacity()
        );
    }

    /// True if the gate passes for `id` without allocating. Panics (like
    /// [`try_admit_one`](Self::try_admit_one)) when the request could never
    /// be admitted at all and the policy is [`InfeasiblePolicy::Panic`];
    /// under [`InfeasiblePolicy::Reject`] it returns false without
    /// mutating anything.
    pub fn can_admit(&self, pool: &RequestPool, kv: &KvManager, id: usize) -> bool {
        if let Some(cap) = self.max_active {
            if pool.active_count() >= cap {
                return false;
            }
        }
        if !self.is_feasible(pool, kv, id) {
            match self.infeasible {
                InfeasiblePolicy::Panic => self.panic_infeasible(pool, kv, id),
                InfeasiblePolicy::Reject => return false,
            }
        }
        let plan = self.plan(pool, kv, id);
        if plan.blocked {
            return false; // waiting on an in-flight prefix fill
        }
        // funds = free blocks + cold prefixes the allocator would reclaim
        // under pressure — EXCLUDING the run this admission is about to
        // share (sharing pins it hot, so its blocks can't be funds).
        // try_admit_one shares first, allocates second, so a checked gate
        // can never fail to allocate below.
        let exclude = if plan.run.is_empty() {
            None
        } else {
            pool.get(id).spec.prefix.map(|p| p.id)
        };
        let funds = kv.available() + kv.reclaimable_excluding(exclude);
        funds >= plan.new_blocks.saturating_add(self.watermark_blocks)
    }

    /// Admit `id` if the gate passes, allocating its initial block table —
    /// sharing the head from a resident prefix run (COW-forking its
    /// partial last block) when the plan says so, and registering the run
    /// on a prefix miss.
    ///
    /// An infeasible request panics under [`InfeasiblePolicy::Panic`]
    /// (loudly, like the allocator's double-free); under
    /// [`InfeasiblePolicy::Reject`] it is moved to the terminal
    /// `Rejected` state and false is returned.
    pub fn try_admit_one(
        &self,
        pool: &mut RequestPool,
        kv: &mut KvManager,
        id: usize,
        now: f64,
    ) -> bool {
        if self.infeasible == InfeasiblePolicy::Reject && !self.is_feasible(pool, kv, id) {
            pool.reject(id, now);
            return false;
        }
        if !self.can_admit(pool, kv, id) {
            return false;
        }
        let plan = self.plan(pool, kv, id);
        let target = Self::target_tokens(pool, id);
        // 1. the shared head: reference the resident run, then COW-fork
        //    its partial last block before this request can append into it
        let mut blocks = kv.share_seq(&plan.run);
        if plan.fork && plan.register.is_none() {
            let last = blocks.len() - 1;
            blocks[last] =
                kv.fork_block(blocks[last]).expect("admission gate checked availability");
        }
        // 2. the private tail
        let grown = kv.extend_to(&mut blocks, target);
        assert!(grown, "admission gate checked availability");
        // 3. a miss registers the head as the template's resident run,
        //    then forks the (now shared) partial block for its own tail
        if let Some((hash, tokens)) = plan.register {
            let n_run = kv.blocks_needed(tokens);
            kv.register_prefix(hash, tokens, &blocks[..n_run]);
            if plan.fork {
                blocks[n_run - 1] =
                    kv.fork_block(blocks[n_run - 1]).expect("admission gate checked availability");
            }
            // a re-registrant that already computed the covered tokens
            // (its original run was reclaimed while it was swapped out)
            // restores them with this admission's swap-in: the run is
            // servable immediately, not gated on a prefill it will
            // never run again
            if pool.get(id).prefilled >= tokens {
                kv.mark_prefix_ready(hash);
            }
        }
        // the split goes on the request BEFORE admit() so swap-in costing
        // sees only the private tokens — except for a (re-)registrant,
        // whose "shared" head tokens did cross the host link (nothing was
        // resident), so they must stay in the swap-in count
        if plan.register.is_none() {
            let r = pool.get_mut(id);
            r.shared_blocks = plan.shared_head;
            r.shared_tokens = plan.shared_tokens;
        }
        pool.admit(id, blocks, now);
        // 4. skip prefill compute for covered tokens (first admission
        //    only: a resumed request's progress already includes them)
        let r = pool.get_mut(id);
        if plan.register.is_some() {
            r.shared_blocks = plan.shared_head;
            r.shared_tokens = plan.shared_tokens;
        }
        if r.prefilled < plan.skip_tokens {
            r.prefix_skipped_tokens += plan.skip_tokens - r.prefilled;
            r.prefilled = plan.skip_tokens;
        }
        if !plan.run.is_empty() {
            r.prefix_hits += 1;
            pool.note_prefix_hit();
        }
        true
    }

    /// Admit arrived, queued requests FCFS while the gate passes (the
    /// shared iteration-level admission rule). Returns how many were
    /// admitted. Under [`InfeasiblePolicy::Reject`], infeasible requests
    /// are rejected and skipped so they never head-of-line-block the
    /// co-running traffic behind them.
    pub fn admit_fcfs(&self, pool: &mut RequestPool, kv: &mut KvManager, now: f64) -> usize {
        let mut admitted = 0;
        while let Some(id) = pool.next_queued(now) {
            if !self.try_admit_one(pool, kv, id, now) {
                if pool.get(id).rejected_at.is_some() {
                    continue; // rejected as infeasible: keep draining FCFS
                }
                break;
            }
            admitted += 1;
        }
        admitted
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::RequestSpec;

    fn pool_of(n: usize) -> RequestPool {
        let specs: Vec<RequestSpec> =
            (0..n)
                .map(|_| RequestSpec { prompt_len: 64, decode_len: 8, arrival: 0.0, prefix: None })
                .collect();
        RequestPool::from_specs(&specs)
    }

    #[test]
    fn degenerate_admission_is_one_slot_per_request() {
        let mut pool = pool_of(5);
        let mut kv = KvManager::new(3);
        let n = Admission::default().admit_fcfs(&mut pool, &mut kv, 0.0);
        assert_eq!(n, 3);
        assert_eq!(kv.available(), 0);
        assert_eq!(pool.active_count(), 3);
        for id in 0..3 {
            assert_eq!(pool.get(id).blocks.len(), 1);
        }
    }

    #[test]
    fn admission_reserves_the_full_prompt() {
        let mut pool = pool_of(2);
        let mut kv = KvManager::paged(8, 16);
        let adm = Admission::default();
        // 64-token prompt = 4 blocks reserved at admission, so chunked
        // prefill never needs to allocate mid-flight
        assert_eq!(adm.blocks_required(&pool, &kv, 0), 4);
        assert!(adm.try_admit_one(&mut pool, &mut kv, 0, 0.0));
        assert_eq!(pool.get(0).blocks.len(), 4);
        let mut table = std::mem::take(&mut pool.get_mut(0).blocks);
        assert!(kv.extend_to(&mut table, 64), "prefill growth is a no-op");
        assert_eq!(table.len(), 4);
        pool.get_mut(0).blocks = table;
    }

    #[test]
    fn watermark_holds_back_headroom() {
        let mut pool = pool_of(5);
        let mut kv = KvManager::paged(8, 16);
        // each 64-token prompt needs 4 blocks; with a 2-block watermark
        // only one request fits (the second would leave < 2 free)
        let n = Admission::with_watermark(2).admit_fcfs(&mut pool, &mut kv, 0.0);
        assert_eq!(n, 1, "second admission would eat the growth headroom");
        assert_eq!(kv.available(), 4);
    }

    #[test]
    fn preempted_request_needs_its_full_footprint() {
        let mut pool = pool_of(2);
        let mut kv = KvManager::paged(8, 16);
        let adm = Admission::default();
        assert!(adm.try_admit_one(&mut pool, &mut kv, 0, 0.0));
        // progress past the prompt (64 prefilled + 9 decoded), then preempt
        {
            let r = pool.get_mut(0);
            r.prefilled = 64;
            r.decoded = 10;
        }
        assert!(kv.extend_to(&mut pool.get_mut(0).blocks, 73));
        let blocks = pool.preempt(0, 1.0);
        kv.release_seq(blocks);
        // swap-in needs the whole live footprint: 74 tokens = 5 blocks
        assert_eq!(adm.blocks_required(&pool, &kv, 0), 5);
        // with only 2 free blocks the swap-in must NOT pass
        let held = kv.alloc_n(6).unwrap();
        assert!(!adm.can_admit(&pool, &kv, 0));
        kv.release_seq(held);
        assert!(adm.try_admit_one(&mut pool, &mut kv, 0, 2.0));
        assert_eq!(pool.get(0).blocks.len(), 5);
    }

    #[test]
    fn prefix_miss_registers_and_hit_reserves_only_private_blocks() {
        use crate::workload::PrefixSpec;
        // template: 40-token prefix (3 blocks of 16, last partial), each
        // request adds 24 unique prompt tokens → prompt 64 = 4 blocks
        let spec = RequestSpec {
            prompt_len: 64,
            decode_len: 8,
            arrival: 0.0,
            prefix: Some(PrefixSpec { id: 7, len: 40 }),
        };
        let mut pool = RequestPool::from_specs(&[spec, spec, spec]);
        let mut kv = KvManager::paged(16, 16);
        let adm = Admission::default().with_prefix_share(true);

        // miss: full prompt (4 blocks) + 1 COW fork block for the
        // registrant's own suffix
        assert_eq!(adm.blocks_required(&pool, &kv, 0), 5);
        assert!(adm.try_admit_one(&mut pool, &mut kv, 0, 0.0));
        assert_eq!(kv.num_prefixes(), 1);
        let r0 = pool.get(0);
        assert_eq!(r0.blocks.len(), 4);
        assert_eq!(r0.shared_blocks, 2, "two FULL prefix blocks stay shared");
        assert_eq!(r0.shared_tokens, 32);
        assert_eq!(r0.prefix_hits, 0, "the registrant is a miss");
        assert_eq!(r0.prefilled, 0, "the registrant computes its whole prompt");
        let r0_head: Vec<usize> = r0.blocks[..2].to_vec();
        // 4 table blocks + the pinned partial original = 5 allocated
        assert_eq!(kv.allocated(), 5);

        // while the registrant is still computing the prefix, the run is
        // indexed but not servable: same-template arrivals WAIT
        assert!(!kv.is_prefix_ready(7));
        assert!(!adm.can_admit(&pool, &kv, 1), "must wait for the in-flight fill");
        assert!(!adm.try_admit_one(&mut pool, &mut kv, 1, 0.05));
        assert!(pool.get(1).rejected_at.is_none(), "waiting is not rejection");
        // the registrant's prefill crosses the covered tokens → servable
        // (the engine flips this through StepApplier; unit-flip here)
        kv.mark_prefix_ready(7);

        // hit: only the non-shared footprint is reserved — 4 total minus
        // 3 run blocks plus 1 fork = 2 fresh blocks
        assert_eq!(adm.blocks_required(&pool, &kv, 1), 2);
        assert!(adm.try_admit_one(&mut pool, &mut kv, 1, 0.1));
        let r1_blocks = {
            let r1 = pool.get(1);
            assert_eq!(r1.blocks.len(), 4);
            assert_eq!(r1.shared_blocks, 2);
            assert_eq!(r1.shared_tokens, 32);
            assert_eq!(r1.prefix_hits, 1);
            assert_eq!(r1.prefilled, 40, "resident KV serves all but the prompt tail");
            assert_eq!(r1.prefix_skipped_tokens, 40);
            // skipped prompt tokens stay inside the prefix coverage
            assert!(r1.prefilled < 64);
            r1.blocks.clone()
        };
        assert_eq!(pool.take_prefix_hits(), 1);
        // sharer adds its fork copy + 1 private block
        assert_eq!(kv.allocated(), 7);
        // the shared head is the SAME physical run for both sharers
        assert_eq!(r0_head[..], r1_blocks[..2]);
        assert!(kv.is_shared(r1_blocks[0]));
        // tails are private, refcount 1
        for &b in &r1_blocks[2..] {
            assert_eq!(kv.ref_count(b), 1);
        }
        // occupancy counts each shared block once: fragmentation over
        // private live + resident prefix tokens never underflows
        let frag = kv.internal_fragmentation(pool.live_private_kv_tokens());
        assert!(frag <= kv.allocated() * 16);
    }

    #[test]
    fn prefix_share_off_ignores_tags_and_degenerate_pools_never_share() {
        use crate::workload::PrefixSpec;
        let spec = RequestSpec {
            prompt_len: 64,
            decode_len: 8,
            arrival: 0.0,
            prefix: Some(PrefixSpec { id: 3, len: 48 }),
        };
        // sharing off: the tag is inert, baseline reservation applies
        let mut pool = RequestPool::from_specs(&[spec, spec]);
        let mut kv = KvManager::paged(16, 16);
        let adm = Admission::default();
        assert_eq!(adm.blocks_required(&pool, &kv, 0), 4);
        assert!(adm.try_admit_one(&mut pool, &mut kv, 0, 0.0));
        assert_eq!(kv.num_prefixes(), 0);
        assert_eq!(pool.get(0).shared_blocks, 0);
        assert_eq!(adm.blocks_required(&pool, &kv, 1), 4, "second pays full price");
        // degenerate pool: sharing on is a no-op (slots hold private KV)
        let mut pool = RequestPool::from_specs(&[spec, spec]);
        let mut kv = KvManager::new(4);
        let adm = Admission::default().with_prefix_share(true);
        assert!(adm.try_admit_one(&mut pool, &mut kv, 0, 0.0));
        assert_eq!(kv.num_prefixes(), 0);
        assert!(adm.try_admit_one(&mut pool, &mut kv, 1, 0.0));
        assert_eq!(pool.get(1).prefix_hits, 0);
        assert_eq!(pool.get(1).prefilled, 0);
    }

    #[test]
    fn block_aligned_prefix_shares_without_a_fork() {
        use crate::workload::PrefixSpec;
        // 32-token prefix on 16-token blocks: no partial block, no fork
        let spec = RequestSpec {
            prompt_len: 48,
            decode_len: 4,
            arrival: 0.0,
            prefix: Some(PrefixSpec { id: 9, len: 32 }),
        };
        let mut pool = RequestPool::from_specs(&[spec, spec]);
        let mut kv = KvManager::paged(8, 16);
        let adm = Admission::default().with_prefix_share(true);
        // registrant: exactly the prompt footprint, no fork block
        assert_eq!(adm.blocks_required(&pool, &kv, 0), 3);
        assert!(adm.try_admit_one(&mut pool, &mut kv, 0, 0.0));
        assert_eq!(pool.get(0).shared_blocks, 2);
        assert_eq!(pool.get(0).shared_tokens, 32);
        assert_eq!(kv.allocated(), 3);
        kv.mark_prefix_ready(9);
        // hit: 3 total − 2 shared = 1 fresh block
        assert_eq!(adm.blocks_required(&pool, &kv, 1), 1);
        assert!(adm.try_admit_one(&mut pool, &mut kv, 1, 0.0));
        assert_eq!(pool.get(1).prefilled, 32);
        assert_eq!(kv.allocated(), 4);
    }

    #[test]
    fn watermark_math_uses_the_shared_aware_reservation() {
        use crate::workload::PrefixSpec;
        let spec = RequestSpec {
            prompt_len: 64,
            decode_len: 8,
            arrival: 0.0,
            prefix: Some(PrefixSpec { id: 1, len: 48 }),
        };
        let mut pool = RequestPool::from_specs(&[spec, spec, spec]);
        // 7 blocks: the registrant takes 4, leaving 3 free
        let mut kv = KvManager::paged(7, 16);
        let adm = Admission::with_watermark(2).with_prefix_share(true);
        assert!(adm.try_admit_one(&mut pool, &mut kv, 0, 0.0));
        assert_eq!(kv.available(), 3);
        kv.mark_prefix_ready(1);
        // a full-price admission would need 4 + 2 watermark > 3 free; the
        // hit needs only 1 fresh block (4 − 3 run) + 2 watermark = 3 ✓
        assert_eq!(adm.blocks_required(&pool, &kv, 1), 1);
        assert!(adm.try_admit_one(&mut pool, &mut kv, 1, 0.1));
        assert_eq!(kv.available(), 2);
        // the next hit fails the watermark check without panicking
        assert!(!adm.can_admit(&pool, &kv, 2));
    }

    #[test]
    #[should_panic(expected = "undersized paged KV pool")]
    fn oversized_request_is_rejected_loudly() {
        // a 64-token prompt needs 4 blocks; a 3-block pool can never admit
        // it — better an immediate, named panic than a silent engine wedge
        let mut pool = pool_of(1);
        let mut kv = KvManager::paged(3, 16);
        Admission::default().try_admit_one(&mut pool, &mut kv, 0, 0.0);
    }

    #[test]
    fn reject_policy_drops_the_oversized_request_and_serves_the_rest() {
        // same oversized request as the panic test, but co-running traffic
        // behind it must keep flowing in serve/open-loop mode
        let mut pool = RequestPool::from_specs(&[
            // 16 blocks: never fits
            RequestSpec { prompt_len: 256, decode_len: 8, arrival: 0.0, prefix: None },
            RequestSpec { prompt_len: 32, decode_len: 8, arrival: 0.1, prefix: None },
            RequestSpec { prompt_len: 32, decode_len: 8, arrival: 0.2, prefix: None },
        ]);
        let mut kv = KvManager::paged(8, 16);
        let adm = Admission::default().with_infeasible(InfeasiblePolicy::Reject);
        let n = adm.admit_fcfs(&mut pool, &mut kv, 1.0);
        assert_eq!(n, 2, "feasible requests behind the rejected one are admitted");
        assert_eq!(pool.rejected_count(), 1);
        assert_eq!(pool.get(0).rejected_at, Some(1.0));
        assert!(pool.get(1).is_admitted() && pool.get(2).is_admitted());
        // can_admit on an infeasible id must not panic under Reject
        let probe = RequestPool::from_specs(&[RequestSpec {
            prompt_len: 256,
            decode_len: 8,
            arrival: 0.0,
            prefix: None,
        }]);
        assert!(!adm.can_admit(&probe, &kv, 0));
    }

    #[test]
    #[should_panic(expected = "undersized paged KV pool")]
    fn decode_heavy_request_that_cannot_complete_is_rejected_up_front() {
        // tiny prompt, huge decode: the prompt footprint (2 blocks) fits a
        // 12-block pool, but the lifetime peak (32 + 200 − 1 tokens = 15
        // blocks) never will — reject at admission, not after burning the
        // whole run and preempting every co-running request
        let mut pool = RequestPool::from_specs(&[RequestSpec {
            prompt_len: 32,
            decode_len: 200,
            arrival: 0.0,
            prefix: None,
        }]);
        let mut kv = KvManager::paged(12, 16);
        Admission::default().try_admit_one(&mut pool, &mut kv, 0, 0.0);
    }
}
