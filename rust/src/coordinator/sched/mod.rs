//! Batching policies under comparison (§5):
//!
//! * [`RequestLevelScheduler`] — FasterTransformer-style baseline.
//! * [`OrcaScheduler`] — iteration-level scheduling, best/worst case.
//! * [`SarathiScheduler`] — chunked-prefills + decode-maximal batching.

pub mod autotune;
mod orca;
mod request_level;
mod sarathi;

pub use autotune::{candidate_chunks, tune_chunk_size, ChunkTuneResult};
pub use orca::OrcaScheduler;
pub use request_level::RequestLevelScheduler;
pub use sarathi::SarathiScheduler;

use super::batch::Batch;
use super::kv::KvManager;
use super::pool::RequestPool;
use crate::config::{SchedulerConfig, SchedulerKind};

/// A batching policy. Admission (KV-slot assignment) is part of the policy:
/// request-level batching deliberately delays admission, iteration-level
/// policies admit as soon as a slot frees.
pub trait Scheduler {
    /// Compose the next iteration's batch at time `now`. An empty batch
    /// means the scheduler has nothing runnable (engine idles to the next
    /// arrival).
    fn schedule(&mut self, pool: &mut RequestPool, kv: &mut KvManager, now: f64) -> Batch;

    fn name(&self) -> &'static str;
}

/// Admit arrived, queued requests FCFS while slots are free (the shared
/// iteration-level admission rule).
pub(crate) fn admit_fcfs(pool: &mut RequestPool, kv: &mut KvManager, now: f64) {
    while let Some(id) = pool.next_queued(now) {
        match kv.alloc() {
            Some(slot) => pool.admit(id, slot, now),
            None => break,
        }
    }
}

/// Build the policy named by a [`SchedulerConfig`].
pub fn make_scheduler(cfg: &SchedulerConfig) -> Box<dyn Scheduler> {
    match cfg.kind {
        SchedulerKind::RequestLevel => Box::new(RequestLevelScheduler::new(cfg.max_batch)),
        SchedulerKind::OrcaBest => Box::new(OrcaScheduler::best(cfg.max_batch)),
        SchedulerKind::OrcaWorst => Box::new(OrcaScheduler::worst(cfg.max_batch)),
        SchedulerKind::Sarathi => {
            Box::new(SarathiScheduler::new(cfg.chunk_size, cfg.max_batch, cfg.tile_align))
        }
    }
}
