//! Batching policies under comparison (§5), over a composable
//! admission + composition split:
//!
//! * [`RequestLevelScheduler`] — FasterTransformer-style baseline.
//! * [`OrcaScheduler`] — iteration-level scheduling, best/worst case.
//! * [`SarathiScheduler`] — chunked-prefills + decode-maximal batching.
//! * [`HybridScheduler`] — Sarathi-Serve-style stall-free batching: a
//!   per-iteration token budget shared by all running prefill chunks and
//!   decodes, over the token-granular paged KV pool.
//!
//! A scheduling step has two halves: **admission** (which queued requests
//! get KV blocks — see [`Admission`]) and **composition** (which admitted
//! requests contribute work items to the next batch). The [`Scheduler`]
//! trait separates them so policies can mix and match; `schedule()` is the
//! provided glue the engine calls.

pub mod admission;
pub mod autotune;
mod hybrid;
mod orca;
mod request_level;
mod sarathi;

pub use admission::{Admission, InfeasiblePolicy};
pub use autotune::{candidate_chunks, tune_chunk_size, ChunkTuneResult};
pub use hybrid::HybridScheduler;
pub use orca::OrcaScheduler;
pub use request_level::RequestLevelScheduler;
pub use sarathi::SarathiScheduler;

use super::batch::Batch;
use super::kv::KvManager;
use super::pool::RequestPool;
use crate::config::{SchedulerConfig, SchedulerKind};

/// A batching policy, split into composable admission + batch composition.
/// Admission is part of the policy: request-level batching deliberately
/// delays admission, iteration-level policies admit as soon as memory
/// frees, the hybrid policy holds back a watermark for decode growth.
pub trait Scheduler {
    /// The admission gate this policy runs (memory-aware, watermark-based).
    fn admission(&self) -> Admission {
        Admission::default()
    }

    /// Admit arrived, queued requests. Default: FCFS while the gate passes.
    fn admit(&mut self, pool: &mut RequestPool, kv: &mut KvManager, now: f64) {
        self.admit_capped(pool, kv, now, None);
    }

    /// [`admit`](Self::admit) with an EXTRA cap on concurrently-admitted
    /// sequences — the pipeline simulator's per-stream bound when several
    /// streams share one replica KV pool. This is the override point for
    /// policies with custom admission (see `RequestLevelScheduler`), so
    /// every driver — engine or pipeline — dispatches through the same
    /// logic.
    fn admit_capped(
        &mut self,
        pool: &mut RequestPool,
        kv: &mut KvManager,
        now: f64,
        extra_cap: Option<usize>,
    ) {
        let mut adm = self.admission();
        if let Some(cap) = extra_cap {
            adm.max_active = Some(adm.max_active.map_or(cap, |m| m.min(cap)));
        }
        adm.admit_fcfs(pool, kv, now);
    }

    /// Compose the next iteration's batch from admitted requests at time
    /// `now`. An empty batch means the scheduler has nothing runnable
    /// (engine idles to the next arrival).
    fn compose(&mut self, pool: &mut RequestPool, kv: &mut KvManager, now: f64) -> Batch;

    /// One scheduling step = admission then composition.
    fn schedule(&mut self, pool: &mut RequestPool, kv: &mut KvManager, now: f64) -> Batch {
        self.admit(pool, kv, now);
        self.compose(pool, kv, now)
    }

    /// Retarget the per-iteration token budget at runtime (the online SLO
    /// control loop's main actuator — Sarathi-Serve arXiv 2403.02310 §5:
    /// the budget trades TBT against TTFT). Returns false (default) for
    /// policies without a token budget; implementations clamp internally
    /// and return true even when the clamp left the value unchanged.
    fn set_token_budget(&mut self, _budget: usize) -> bool {
        false
    }

    /// Retarget the bounded prefix-wait window at runtime (control loop's
    /// secondary actuator). Returns false for policies without one.
    fn set_max_prefix_wait(&mut self, _iters: usize) -> bool {
        false
    }

    /// The per-iteration fused-token budget, when this policy has one —
    /// the trace layer marks batch spans that composed right up to it as
    /// `budget_capped` (the chunking cap bounded the batch, not a lack of
    /// runnable work). `None` for policies without a token budget.
    fn token_budget(&self) -> Option<usize> {
        None
    }

    fn name(&self) -> &'static str;
}

/// Build the policy named by a [`SchedulerConfig`]. When
/// `cfg.reject_infeasible` is set (the `serve`/open-loop stance), every
/// policy's admission gate REJECTS requests that could never fit the pool
/// — terminal `Rejected` state plus a `Metrics` counter — instead of
/// panicking; figure-repro / closed-loop runs keep the loud panic.
/// `cfg.prefix_share` (hybrid-only: sharing needs the paged, memory-aware
/// gate) turns on copy-on-write prefix sharing at admission.
///
/// The box is `Send` (every policy is plain data) so one builder serves
/// the engine and the multi-threaded cluster dispatcher alike.
pub fn make_scheduler(cfg: &SchedulerConfig) -> Box<dyn Scheduler + Send> {
    assert!(
        !cfg.prefix_share || cfg.kind == SchedulerKind::Hybrid,
        "prefix sharing requires the hybrid scheduler's paged admission gate"
    );
    let infeasible = if cfg.reject_infeasible {
        InfeasiblePolicy::Reject
    } else {
        InfeasiblePolicy::Panic
    };
    match cfg.kind {
        SchedulerKind::RequestLevel => {
            Box::new(RequestLevelScheduler::new(cfg.max_batch).with_infeasible(infeasible))
        }
        SchedulerKind::OrcaBest => {
            Box::new(OrcaScheduler::best(cfg.max_batch).with_infeasible(infeasible))
        }
        SchedulerKind::OrcaWorst => {
            Box::new(OrcaScheduler::worst(cfg.max_batch).with_infeasible(infeasible))
        }
        SchedulerKind::Sarathi => Box::new(
            SarathiScheduler::new(cfg.chunk_size, cfg.max_batch, cfg.tile_align)
                .with_infeasible(infeasible),
        ),
        // no silent clamping: a budget below max_batch is a config error
        // and HybridScheduler::new rejects it loudly, so the label a
        // harness prints from cfg.token_budget always matches what runs
        SchedulerKind::Hybrid => Box::new(
            HybridScheduler::new(cfg.token_budget, cfg.max_batch, cfg.watermark_blocks)
                .with_tile(cfg.tile_align)
                .with_infeasible(infeasible)
                .with_prefix_share(cfg.prefix_share)
                .with_max_prefix_wait(cfg.max_prefix_wait)
                .with_bypass_window(cfg.bypass_window),
        ),
    }
}
