//! Stall-free token-budget batching (Sarathi-Serve, arXiv 2403.02310).
//!
//! Where [`super::SarathiScheduler`] chunks ONE prefill at a time and caps
//! the fused token count at the chunk size C, the hybrid policy budgets
//! **every** iteration at `token_budget` tokens shared by all work:
//!
//! 1. every running decode gets its token first (decodes are never
//!    stalled behind prefill work — the "stall-free" rule);
//! 2. the remaining budget is split across ALL admitted mid-prefill
//!    requests FCFS, so multiple prefills progress concurrently instead of
//!    head-of-line blocking behind the oldest prompt;
//! 3. admission is memory-aware and watermark-based over the paged KV
//!    pool ([`Admission`]), so concurrency is bounded by *actual* sequence
//!    lengths, not the §4.3.1 worst-case slot formula.
//!
//! The budget bounds every iteration's fused token count, which bounds
//! iteration latency — and therefore time-between-tokens — regardless of
//! how many prompts are queued.

use super::super::batch::{Batch, WorkItem};
use super::super::kv::KvManager;
use super::super::pool::RequestPool;
use super::super::request::Phase;
use super::admission::InfeasiblePolicy;
use super::{Admission, Scheduler};

pub struct HybridScheduler {
    /// Per-iteration budget on fused tokens (prefill chunk tokens + one per
    /// decode lane). Must be ≥ `max_batch` so the stall-free rule can give
    /// every running decode its token.
    token_budget: usize,
    /// Max sequences per iteration.
    max_batch: usize,
    /// Admission watermark: free blocks reserved for decode growth.
    watermark_blocks: usize,
    /// Hardware tile for the §4.4 alignment rule (0 = no alignment): when
    /// prefill work rides along, the fused token target shrinks to the
    /// largest tile multiple ≤ budget so saturated iterations don't pay
    /// the Fig.-7 quantization padding.
    tile: usize,
    /// Panic (closed-loop default) or reject (open-loop serving) requests
    /// whose lifetime KV can never fit the pool.
    infeasible: InfeasiblePolicy,
    /// Serve prefix-tagged requests from the resident-prefix index
    /// (copy-on-write sharing over the paged pool). Off by default.
    prefix_share: bool,
    /// Bounded cache-aware waiting: consecutive no-progress admission
    /// attempts before a prefix waiter degrades to a full-price miss
    /// (the fallback-policy knob; [`Admission::max_prefix_wait`]).
    max_prefix_wait: usize,
    /// Head-of-line bypass window behind a stalled prefix waiter
    /// ([`Admission::bypass_window`]).
    bypass_window: usize,
}

impl HybridScheduler {
    pub fn new(token_budget: usize, max_batch: usize, watermark_blocks: usize) -> Self {
        assert!(token_budget > 0, "token budget must be positive");
        assert!(max_batch > 0, "max batch must be positive");
        assert!(
            token_budget >= max_batch,
            "token budget {token_budget} cannot cover {max_batch} decode lanes"
        );
        HybridScheduler {
            token_budget,
            max_batch,
            watermark_blocks,
            tile: 0,
            infeasible: InfeasiblePolicy::Panic,
            prefix_share: false,
            max_prefix_wait: Admission::DEFAULT_MAX_PREFIX_WAIT,
            bypass_window: Admission::DEFAULT_BYPASS_WINDOW,
        }
    }

    pub fn with_tile(mut self, tile: usize) -> Self {
        self.tile = tile;
        self
    }

    pub fn with_infeasible(mut self, policy: InfeasiblePolicy) -> Self {
        self.infeasible = policy;
        self
    }

    /// Enable copy-on-write prefix sharing at the admission gate.
    pub fn with_prefix_share(mut self, on: bool) -> Self {
        self.prefix_share = on;
        self
    }

    /// Bounded-wait fallback knob: consecutive no-progress attempts
    /// before a prefix waiter admits as a full-price miss.
    pub fn with_max_prefix_wait(mut self, k: usize) -> Self {
        self.max_prefix_wait = k;
        self
    }

    /// Head-of-line bypass window behind a stalled prefix waiter.
    pub fn with_bypass_window(mut self, window: usize) -> Self {
        self.bypass_window = window;
        self
    }

    pub fn token_budget(&self) -> usize {
        self.token_budget
    }

    pub fn max_prefix_wait(&self) -> usize {
        self.max_prefix_wait
    }
}

impl Scheduler for HybridScheduler {
    /// Memory-aware, watermark-based, and capped at `max_batch` sequences
    /// (Sarathi-Serve's `max_num_seqs`): admitting decodes the budget
    /// cannot serve each iteration would stall them, defeating the policy.
    fn admission(&self) -> Admission {
        Admission::with_watermark(self.watermark_blocks)
            .with_max_active(self.max_batch)
            .with_infeasible(self.infeasible)
            .with_prefix_share(self.prefix_share)
            .with_max_prefix_wait(self.max_prefix_wait)
            .with_bypass_window(self.bypass_window)
    }

    fn compose(&mut self, pool: &mut RequestPool, _kv: &mut KvManager, _now: f64) -> Batch {
        let mut items = Vec::with_capacity(self.max_batch);

        // 1. stall-free: every running decode rides along (1 token each;
        //    max_batch ≤ token_budget is asserted at construction)
        for id in pool.in_phase_iter(Phase::Decode) {
            if items.len() >= self.max_batch {
                break;
            }
            if pool.get(id).remaining_decode() == 0 {
                continue;
            }
            items.push(WorkItem::Decode { req: id });
        }

        // 2. all running prefills share the remaining budget, FCFS. §4.4
        //    alignment: shrink the fused target to a tile multiple when
        //    that still leaves room past the decodes (decodes are never
        //    dropped for alignment).
        let n_d = items.len();
        let mut budget = if self.tile > 0 {
            let aligned = (self.token_budget / self.tile) * self.tile;
            if aligned > n_d {
                aligned - n_d
            } else {
                self.token_budget - n_d
            }
        } else {
            self.token_budget - n_d
        };
        for id in pool.in_phase_iter(Phase::Prefill) {
            if budget == 0 || items.len() >= self.max_batch {
                break;
            }
            let r = pool.get(id);
            let len = budget.min(r.remaining_prompt());
            debug_assert!(len > 0, "Prefill phase implies remaining prompt");
            items.push(WorkItem::PrefillChunk { req: id, start: r.prefilled, len });
            budget -= len;
        }

        Batch::new(items)
    }

    /// Runtime budget retarget (the control loop's actuator). Clamped to
    /// `max_batch` so the stall-free invariant — every running decode gets
    /// its token — survives any controller excursion.
    fn set_token_budget(&mut self, budget: usize) -> bool {
        self.token_budget = budget.max(self.max_batch);
        true
    }

    fn token_budget(&self) -> Option<usize> {
        Some(self.token_budget)
    }

    /// Runtime bounded-wait retarget. Clamped to ≥ 1: a zero window would
    /// demote every waiter on its first attempt, making the prefix cache
    /// inert rather than adaptive.
    fn set_max_prefix_wait(&mut self, iters: usize) -> bool {
        self.max_prefix_wait = iters.max(1);
        true
    }

    fn name(&self) -> &'static str {
        "hybrid"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::RequestSpec;

    /// Pool with `n_decoding` requests mid-decode and `prompts` queued
    /// prompts, over a paged KV pool.
    fn setup(n_decoding: usize, prompts: &[usize], kv: &mut KvManager) -> RequestPool {
        let mut pool = RequestPool::new();
        for _ in 0..n_decoding {
            let spec = RequestSpec { prompt_len: 32, decode_len: 20, arrival: 0.0, prefix: None };
            let id = pool.push(spec);
            let blocks = kv.alloc_n(kv.blocks_needed(33)).unwrap();
            pool.admit(id, blocks, 0.0);
            let r = pool.get_mut(id);
            r.prefilled = 32;
            r.decoded = 1;
        }
        for &p in prompts {
            pool.push(RequestSpec { prompt_len: p, decode_len: 20, arrival: 0.0, prefix: None });
        }
        pool
    }

    #[test]
    fn budget_shared_by_decodes_then_prefills() {
        let mut kv = KvManager::paged(64, 16);
        let mut pool = setup(3, &[40, 100], &mut kv);
        let mut s = HybridScheduler::new(64, 8, 0);
        let b = s.schedule(&mut pool, &mut kv, 0.0);
        // 3 decodes (3 tokens), then prefills split the remaining 61:
        // 40 for the first prompt, 21 for the second
        assert_eq!(b.n_decodes(), 3);
        assert_eq!(b.n_prefill_chunks(), 2);
        assert_eq!(b.total_tokens(), 64, "budget fully used");
        assert!(b.validate(&pool, 8).is_ok());
    }

    #[test]
    fn multiple_concurrent_chunked_prefills() {
        // unlike SarathiScheduler's one-prompt-at-a-time FCFS, a second
        // prompt starts prefilling in the same iteration once the first no
        // longer fills the budget
        let mut kv = KvManager::paged(64, 16);
        let mut pool = setup(0, &[100, 300], &mut kv);
        let mut s = HybridScheduler::new(128, 8, 0);
        let b = s.schedule(&mut pool, &mut kv, 0.0);
        let chunks: Vec<_> = b.prefill_items().collect();
        assert_eq!(chunks.len(), 2, "both prompts progress concurrently");
        assert_eq!(chunks[0].2, 100, "first prompt finishes its prefill");
        assert_eq!(chunks[1].2, 28, "second takes the leftover budget");
        assert_eq!(b.total_tokens(), 128);
    }

    #[test]
    fn long_head_prompt_takes_whole_budget() {
        let mut kv = KvManager::paged(64, 16);
        let mut pool = setup(0, &[300, 300], &mut kv);
        let mut s = HybridScheduler::new(128, 8, 0);
        let b = s.schedule(&mut pool, &mut kv, 0.0);
        let chunks: Vec<_> = b.prefill_items().collect();
        assert_eq!(chunks.len(), 1, "no budget left for the second prompt");
        assert_eq!(chunks[0].2, 128);
    }

    #[test]
    fn decodes_never_stall_behind_prefills() {
        let mut kv = KvManager::paged(64, 16);
        let mut pool = setup(6, &[500], &mut kv);
        let mut s = HybridScheduler::new(32, 8, 0);
        let b = s.schedule(&mut pool, &mut kv, 0.0);
        assert_eq!(b.n_decodes(), 6, "every decode included before any prefill");
        assert_eq!(b.prefill_tokens(), 32 - 6);
    }

    #[test]
    fn iteration_tokens_never_exceed_budget() {
        let mut kv = KvManager::paged(64, 16);
        let mut pool = setup(4, &[500, 500, 500], &mut kv);
        let mut s = HybridScheduler::new(48, 16, 0);
        let b = s.schedule(&mut pool, &mut kv, 0.0);
        assert!(b.total_tokens() <= 48);
        assert_eq!(b.total_tokens(), 48, "4 decodes + a 44-token chunk");
    }

    #[test]
    fn respects_max_batch() {
        let mut kv = KvManager::paged(64, 16);
        let mut pool = setup(6, &[64, 64], &mut kv);
        let mut s = HybridScheduler::new(64, 4, 0);
        let b = s.schedule(&mut pool, &mut kv, 0.0);
        assert_eq!(b.len(), 4);
    }

    #[test]
    fn memory_aware_admission_beats_worst_case_formula() {
        // worst-case slot formula: capacity_tokens / max_seq = 128/64 = 2
        // slots; actual sequences are 33 tokens, so paging admits 3+
        let mut kv = KvManager::paged(8, 16); // 128 tokens
        let mut pool = RequestPool::new();
        for _ in 0..4 {
            pool.push(RequestSpec { prompt_len: 32, decode_len: 16, arrival: 0.0, prefix: None });
        }
        let mut s = HybridScheduler::new(64, 8, 0);
        let _ = s.schedule(&mut pool, &mut kv, 0.0);
        assert!(pool.active_count() > 2, "admitted {}", pool.active_count());
    }

    #[test]
    fn misaligned_budget_shrinks_to_tile_multiple() {
        // budget 200 with tile 128: the fused total lands on 128 (3 decodes
        // + a 125-token chunk) instead of paying ~28% tile padding at 200
        let mut kv = KvManager::paged(64, 16);
        let mut pool = setup(3, &[500], &mut kv);
        let mut s = HybridScheduler::new(200, 8, 0).with_tile(128);
        let b = s.schedule(&mut pool, &mut kv, 0.0);
        assert_eq!(b.n_decodes(), 3);
        assert_eq!(b.total_tokens(), 128);
        // without the tile the full budget is used
        let mut kv = KvManager::paged(64, 16);
        let mut pool = setup(3, &[500], &mut kv);
        let mut s = HybridScheduler::new(200, 8, 0);
        let b = s.schedule(&mut pool, &mut kv, 0.0);
        assert_eq!(b.total_tokens(), 200);
    }

    #[test]
    #[should_panic(expected = "cannot cover")]
    fn budget_below_batch_is_rejected() {
        let _ = HybridScheduler::new(4, 8, 0);
    }

    #[test]
    fn runtime_setters_retarget_and_clamp() {
        let mut s = HybridScheduler::new(64, 8, 0);
        assert!(s.set_token_budget(128));
        assert_eq!(s.token_budget(), 128);
        // a controller excursion below max_batch clamps, never panics —
        // the stall-free invariant survives
        assert!(s.set_token_budget(2));
        assert_eq!(s.token_budget(), 8);
        assert!(s.set_max_prefix_wait(5));
        assert_eq!(s.max_prefix_wait(), 5);
        assert!(s.set_max_prefix_wait(0));
        assert_eq!(s.max_prefix_wait(), 1, "zero would disable waiting entirely");
        // the retargeted wait threads through to the admission gate
        assert_eq!(s.admission().max_prefix_wait, 1);
        // policies without a budget refuse
        let mut orca = crate::coordinator::sched::OrcaScheduler::best(4);
        assert!(!orca.set_token_budget(64));
        assert!(!orca.set_max_prefix_wait(4));
    }
}
