//! Chunk-size auto-tuner — the paper's §6 future-work item ("we leave it
//! to future work to explore how to pick an optimal chunk size as it
//! depends on the hardware, model characteristics, sequence length, and
//! the composition of prefill-decode tokens").
//!
//! Given a deployment and an expected workload (sequence length, P:D
//! ratio), the tuner sweeps tile-aligned candidate chunk sizes through the
//! serving engine on the calibrated cost model and returns the
//! throughput-maximizing one. Candidates are bounded below by the tile and
//! above by the saturation point ×2 — outside that range §4.2/§4.4 already
//! rule the chunk out.

use crate::config::{Deployment, SchedulerConfig};
use crate::coordinator::{make_scheduler, Engine, KvManager, RequestPool, SimExecutor};
use crate::costmodel::CostModel;
use crate::workload::uniform_population;

#[derive(Clone, Debug)]
pub struct ChunkTuneResult {
    /// The winning chunk size.
    pub chunk: usize,
    /// Its end-to-end throughput (tokens/s) on the probe workload.
    pub throughput: f64,
    /// Every evaluated (chunk, throughput) pair, ascending chunk.
    pub evaluated: Vec<(usize, f64)>,
}

/// Tile-aligned candidate chunk sizes for a deployment.
pub fn candidate_chunks(d: &Deployment) -> Vec<usize> {
    let cm = CostModel::for_deployment(d);
    let tile = cm.gpu.tile;
    let hi = (2 * cm.saturation_tokens()).min(d.max_seq_len);
    let mut out = Vec::new();
    let mut c = tile;
    while c <= hi {
        out.push(c);
        c += tile;
    }
    if out.is_empty() {
        out.push(tile);
    }
    out
}

/// Sweep candidates on a steady-state probe workload and return the best.
pub fn tune_chunk_size(d: &Deployment, seq_len: usize, pd: f64, waves: usize) -> ChunkTuneResult {
    let b = d.max_batch_size();
    let pop = uniform_population(b * waves.max(2), seq_len, pd);
    let cm = CostModel::for_deployment(d);
    let mut evaluated = Vec::new();
    let mut best = (0usize, 0.0f64);
    for chunk in candidate_chunks(d) {
        let cfg = SchedulerConfig::sarathi(chunk, b);
        let mut engine = Engine::new(
            RequestPool::from_specs(&pop),
            KvManager::new(b),
            make_scheduler(&cfg),
            Box::new(SimExecutor::new(cm.clone())),
        );
        engine.run();
        let thpt = engine.metrics.throughput();
        evaluated.push((chunk, thpt));
        if thpt > best.1 {
            best = (chunk, thpt);
        }
    }
    ChunkTuneResult { chunk: best.0, throughput: best.1, evaluated }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuConfig, ModelConfig};

    fn a6000_1k() -> Deployment {
        Deployment::new(ModelConfig::llama13b(), GpuConfig::a6000(), 1024)
    }

    #[test]
    fn candidates_are_tile_aligned_and_bounded() {
        let d = a6000_1k();
        let cs = candidate_chunks(&d);
        assert!(!cs.is_empty());
        assert!(cs.iter().all(|c| c % 128 == 0));
        assert!(cs.windows(2).all(|w| w[0] < w[1]));
        assert!(*cs.last().unwrap() <= 1024);
    }

    #[test]
    fn tuner_picks_a_mid_range_chunk_at_balanced_pd() {
        // at P:D = C/(B−1) ≈ 15 (B=18), §5.1.3 says 256 is optimal; the
        // tuner must land in the 256–512 band, never at the tiny or huge
        // extremes.
        let d = a6000_1k();
        let r = tune_chunk_size(&d, 1024, 15.0, 3);
        assert!(
            (256..=512).contains(&r.chunk),
            "tuned chunk {} (evaluated {:?})",
            r.chunk,
            r.evaluated
        );
        assert!(r.throughput > 0.0);
    }

    #[test]
    fn tuned_chunk_beats_extremes() {
        let d = a6000_1k();
        let r = tune_chunk_size(&d, 1024, 15.0, 3);
        let at = |c: usize| r.evaluated.iter().find(|&&(cc, _)| cc == c).map(|&(_, t)| t);
        if let Some(t128) = at(128) {
            assert!(r.throughput >= t128);
        }
        if let Some(t1024) = at(1024) {
            assert!(r.throughput >= t1024);
        }
    }

    #[test]
    fn higher_pd_prefers_bigger_chunks() {
        // §5.1.3: the optimal P:D grows with chunk size — dually, a higher
        // P:D workload tunes to a chunk at least as large.
        let d = a6000_1k();
        let low = tune_chunk_size(&d, 1024, 5.0, 3);
        let high = tune_chunk_size(&d, 1024, 60.0, 3);
        assert!(high.chunk >= low.chunk, "low {} high {}", low.chunk, high.chunk);
    }
}
