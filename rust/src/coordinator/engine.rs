//! The serving engine: admission → schedule → execute → advance.
//!
//! Generic over [`Executor`] so the same loop drives (a) the calibrated
//! cost-model simulator for the paper's large-model experiments and (b) the
//! real PJRT runtime serving the tiny model (rust/src/runtime).
//!
//! The state transition itself — progress counters, token stamping,
//! completion release, token-granular block growth and LIFO preemption —
//! lives in [`StepApplier`] (coordinator/step.rs), SHARED with the
//! pipeline simulator so the two can never drift. Schedulers stay
//! oblivious to growth; only their admission gate is memory-aware. Under
//! the degenerate block size a request's single block always covers its
//! sequence, so growth is a no-op and preemption never fires — the seed
//! behavior.
//!
//! Preemption is costed through the applier's [`SwapCost`]: swap-out
//! transfer time extends the iteration, and a resumed victim's swap-in
//! (or recompute) charge delays the iteration that re-admits it. The
//! default [`SwapCost::free`] keeps the seed's zero-cost semantics.

use super::batch::Batch;
use super::kv::KvManager;
use super::metrics::{IterationRecord, Metrics};
use super::pool::RequestPool;
use super::sched::Scheduler;
use super::step::{StepApplier, SwapCost};
use crate::costmodel::CostModel;

/// Result of executing one batch.
#[derive(Clone, Debug)]
pub struct StepOutcome {
    /// Wall-clock (or simulated) seconds the iteration took.
    pub elapsed: f64,
    /// Cost of the same iteration with decode lanes stripped, when the
    /// executor can provide it (for §5.1.1 marginal attribution).
    pub prefill_alone: Option<f64>,
    /// Optional per-op breakdown.
    pub breakdown: Option<crate::costmodel::OpBreakdown>,
}

/// Executes scheduled batches. Implementations: [`SimExecutor`] (cost
/// model) and `runtime::RealExecutor` (PJRT).
pub trait Executor {
    fn execute(&mut self, batch: &Batch, pool: &RequestPool) -> StepOutcome;

    /// Downcast hook so callers can recover concrete executor state after a
    /// run (e.g. generated tokens from the PJRT executor).
    fn as_any(&self) -> &dyn std::any::Any;
}

/// Cost-model-backed executor (the simulated testbed).
pub struct SimExecutor {
    pub cm: CostModel,
}

impl SimExecutor {
    pub fn new(cm: CostModel) -> Self {
        SimExecutor { cm }
    }
}

impl Executor for SimExecutor {
    fn execute(&mut self, batch: &Batch, pool: &RequestPool) -> StepOutcome {
        let shape = batch.shape(pool);
        let bd = self.cm.iteration(&shape);
        let prefill_alone = if !shape.prefill.is_empty() && !shape.decode.is_empty() {
            let alone = crate::costmodel::BatchShape { prefill: shape.prefill.clone(), decode: vec![] };
            Some(self.cm.iteration_time(&alone))
        } else {
            None
        };
        StepOutcome { elapsed: bd.total(), prefill_alone, breakdown: Some(bd) }
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

/// The serving loop.
pub struct Engine<'a> {
    pub pool: RequestPool,
    pub kv: KvManager,
    pub scheduler: Box<dyn Scheduler + 'a>,
    pub executor: Box<dyn Executor + 'a>,
    pub metrics: Metrics,
    pub now: f64,
    /// The shared state transition (also driven by the pipeline
    /// simulator); carries the preemption [`SwapCost`].
    pub applier: StepApplier,
    /// Validate every batch against the structural invariants (cheap; on by
    /// default — a scheduler bug must not silently corrupt an experiment).
    pub validate: bool,
    /// Hard cap on iterations as a runaway guard.
    pub max_iterations: usize,
}

impl<'a> Engine<'a> {
    pub fn new(
        pool: RequestPool,
        kv: KvManager,
        scheduler: Box<dyn Scheduler + 'a>,
        executor: Box<dyn Executor + 'a>,
    ) -> Self {
        Engine {
            pool,
            kv,
            scheduler,
            executor,
            metrics: Metrics::new(),
            now: 0.0,
            applier: StepApplier::new(),
            validate: true,
            max_iterations: 10_000_000,
        }
    }

    /// Price the preemption path (seed default: free swaps).
    pub fn with_swap_cost(mut self, swap: SwapCost) -> Self {
        self.applier = StepApplier::with_cost(swap);
        self
    }

    /// Run one iteration. Returns false when there is no work left at all.
    pub fn step(&mut self) -> bool {
        let batch = self.scheduler.schedule(&mut self.pool, &mut self.kv, self.now);
        // admission may have rejected infeasible requests (open-loop
        // policy), served prefix-cache hits, or swapped preempted victims
        // back in — account for all three. Rejections/hits ride on this
        // iteration's record (Metrics::record accumulates them); an idle
        // step has no record, so count directly.
        let rejections = self.pool.take_rejected_events();
        let prefix_hits = self.pool.take_prefix_hits();
        let prefix_partial_hits = self.pool.take_prefix_partial_hits();
        let prefix_partial_hit_tokens = self.pool.take_prefix_partial_hit_tokens();
        let prefix_fallbacks = self.pool.take_prefix_fallbacks();
        let prefix_wait_iters = self.pool.take_prefix_wait_ticks();
        let swap_in = self.applier.swap.swap_in_time(self.pool.take_swapped_in_tokens());
        if batch.is_empty() {
            self.metrics.rejections += rejections;
            self.metrics.prefix_hits += prefix_hits;
            self.metrics.prefix_partial_hits += prefix_partial_hits;
            self.metrics.prefix_partial_hit_tokens += prefix_partial_hit_tokens;
            self.metrics.prefix_fallbacks += prefix_fallbacks;
            self.metrics.prefix_wait_iterations += prefix_wait_iters;
            // idle: jump to the next arrival if one exists
            if let Some(t) = self.pool.next_arrival(self.now) {
                if self.pool.trace.is_enabled() && t > self.now {
                    // classify the bubble: arrived work stuck in the queue
                    // means admission (KV blocks) is the blocker; an empty
                    // queue is genuine open-loop idleness
                    let class = if self.pool.next_queued(self.now).is_some() {
                        super::trace::BubbleClass::KvStarved
                    } else {
                        super::trace::BubbleClass::NoWork
                    };
                    self.pool
                        .trace
                        .emit(self.now, super::trace::EventKind::Bubble { end: t, class });
                }
                self.now = t;
                return true;
            }
            return false;
        }
        if self.validate {
            // a legal batch touches each ADMITTED request at most once, so
            // the admitted count is the tight size bound in both the
            // degenerate (slots == admitted cap) and paged layouts — the
            // seed's kv.capacity() would be the meaningless block count
            // under paging
            let max_batch = self.pool.active_count();
            if let Err(e) = batch.validate(&self.pool, max_batch) {
                panic!("scheduler {} produced invalid batch: {e}", self.scheduler.name());
            }
        }
        let outcome = self.executor.execute(&batch, &self.pool);
        let shape = batch.shape(&self.pool);
        // the iteration's tokens/completions land at now + swap-in +
        // elapsed — NOT at `now` (the seed stamped them one iteration
        // early, skewing every latency sample); a resumed victim's KV must
        // finish its host transfer before the batch can run
        let done_at = self.now + swap_in + outcome.elapsed;
        let batch_id = self.metrics.recorded_count() as u64;
        if self.pool.trace.is_enabled() {
            self.pool.trace.emit(
                self.now,
                super::trace::EventKind::BatchSpan {
                    batch: batch_id,
                    end: done_at,
                    prefill_tokens: shape.prefill_tokens(),
                    decode_tokens: shape.decode_tokens(),
                    n_prefill: shape.prefill.len(),
                    n_decode: shape.decode.len(),
                    budget_capped: self
                        .scheduler
                        .token_budget()
                        .is_some_and(|b| shape.total_tokens() >= b),
                },
            );
        }
        let effects = self.applier.apply_traced(
            std::slice::from_mut(&mut self.pool),
            0,
            &mut self.kv,
            &batch,
            done_at,
            &[],
            batch_id,
        );
        self.metrics.record(IterationRecord {
            started_at: self.now,
            elapsed: outcome.elapsed,
            shape,
            prefill_alone: outcome.prefill_alone,
            breakdown: outcome.breakdown,
            kv_blocks_in_use: self.kv.allocated(),
            kv_blocks_total: self.kv.capacity(),
            n_active: self.pool.active_count(),
            preemptions: effects.preemptions,
            // occupancy counts shared-prefix content once (the private sum
            // plus the allocator's resident-prefix tokens), not per sharer
            kv_frag_tokens: self.kv.internal_fragmentation(self.pool.live_private_kv_tokens()),
            swap_time: swap_in + effects.swap_time,
            rejections,
            prefix_hits,
            prefix_partial_hits,
            prefix_partial_hit_tokens,
            prefix_fallbacks,
            prefix_wait_iters,
            shared_kv_tokens: self.pool.shared_kv_tokens(),
        });
        // swap-out transfers of this iteration's victims delay the next
        self.now = done_at + effects.swap_time;
        true
    }

    /// Drive to completion of every request.
    ///
    /// Wedge demotion: when a step finds no work at all but some queued
    /// request is still waiting on an in-flight prefix fill, the engine is
    /// not actually wedged — the wait is the only thing stopping
    /// admission. The oldest waiter is forced to its full-price fallback
    /// ([`RequestPool::force_prefix_fallback`]) and the loop continues;
    /// only a stall with NO prefix waiter panics. Each demotion retires
    /// one waiter permanently, so the loop terminates.
    pub fn run(&mut self) -> &Metrics {
        let mut iters = 0usize;
        while !self.pool.all_complete() {
            iters += 1;
            assert!(iters <= self.max_iterations, "engine exceeded iteration cap");
            if !self.step() {
                if let Some(id) = self.pool.oldest_prefix_waiter() {
                    // demote to the deepest READY ancestor on the waiter's
                    // content path (0 = plain full-price miss) — same rule
                    // as the bounded-wait stall fallback in admission
                    let ready = match self.pool.get(id).spec.prefix.as_ref() {
                        Some(pfx) if !pfx.path.is_empty() => {
                            let bs = self.kv.block_size().max(1);
                            let cap = self.pool.get(id).spec.prompt_len.saturating_sub(1);
                            let kb = (pfx.len.min(cap) / bs).min(pfx.path.len());
                            if kb > 0 {
                                self.kv.lookup_path_match(&pfx.path[..kb]).ready_tokens
                            } else {
                                0
                            }
                        }
                        _ => 0,
                    };
                    self.pool.force_prefix_fallback(id, self.now, ready);
                    continue;
                }
                panic!(
                    "engine wedged: {} queued ({} blocked on a prefix fill), {} incomplete; \
                     kv {}/{} blocks in use ({} free + {} reclaimable)",
                    self.pool.arrived_queued(self.now).len(),
                    self.pool.prefix_waiting_count(),
                    self.pool.iter().filter(|r| r.completed_at.is_none()).count(),
                    self.kv.allocated(),
                    self.kv.capacity(),
                    self.kv.available(),
                    self.kv.reclaimable(),
                );
            }
        }
        &self.metrics
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuConfig, ModelConfig};
    use crate::coordinator::sched::{
        HybridScheduler, OrcaScheduler, RequestLevelScheduler, SarathiScheduler,
    };
    use crate::workload::{uniform_population, RequestSpec};

    fn sim() -> Box<SimExecutor> {
        Box::new(SimExecutor::new(CostModel::new(ModelConfig::llama13b(), GpuConfig::a6000())))
    }

    fn run_with(sched: Box<dyn Scheduler>, specs: &[RequestSpec], slots: usize) -> Engine<'static> {
        let mut e = Engine::new(RequestPool::from_specs(specs), KvManager::new(slots), sched, sim());
        e.run();
        e
    }

    #[test]
    fn sarathi_completes_all_requests() {
        let pop = uniform_population(6, 1024, 50.0);
        let e = run_with(Box::new(SarathiScheduler::new(256, 6, 128)), &pop, 6);
        assert!(e.pool.all_complete());
        // every request produced its full decode budget
        for r in e.pool.iter() {
            assert_eq!(r.decoded, r.spec.decode_len);
            assert_eq!(r.prefilled, r.spec.prompt_len);
            assert!(r.blocks.is_empty());
        }
        // all blocks returned
        assert_eq!(e.kv.available(), 6);
        // degenerate mode never preempts
        assert_eq!(e.metrics.preemptions, 0);
    }

    #[test]
    fn all_schedulers_conserve_tokens() {
        let pop = uniform_population(4, 512, 10.0);
        let total_p: usize = pop.iter().map(|r| r.prompt_len).sum();
        // decode tokens scheduled as Decode items = decode_len − 1 (first
        // token comes from the final prefill chunk)
        let total_d: usize = pop.iter().map(|r| r.decode_len - 1).sum();
        for sched in [
            Box::new(RequestLevelScheduler::new(4)) as Box<dyn Scheduler>,
            Box::new(OrcaScheduler::best(4)),
            Box::new(OrcaScheduler::worst(4)),
            Box::new(SarathiScheduler::new(128, 4, 128)),
            Box::new(HybridScheduler::new(128, 4, 0)),
        ] {
            let e = run_with(sched, &pop, 4);
            assert_eq!(e.metrics.total_prefill_tokens(), total_p);
            assert_eq!(e.metrics.total_decode_tokens(), total_d);
        }
    }

    #[test]
    fn sarathi_beats_baseline_throughput() {
        // the headline effect: at the balanced P:D ratio (C/(B−1), §5.1.3)
        // SARATHI's end-to-end throughput exceeds the prefill-only/
        // decode-only baseline. Steady-state: 24 requests over 6 slots so
        // there is always a next prompt whose chunks carry the decodes.
        let pop = uniform_population(24, 1024, 256.0 / 5.0);
        let base = run_with(Box::new(RequestLevelScheduler::new(6)), &pop, 6);
        let sar = run_with(Box::new(SarathiScheduler::new(256, 6, 128)), &pop, 6);
        let gain = sar.metrics.throughput() / base.metrics.throughput();
        assert!(gain > 1.1, "gain={gain}");
    }

    #[test]
    fn sarathi_decode_speedup_order_of_magnitude() {
        // Fig. 8: piggybacked decodes are several times cheaper per token
        // (§5.1.1 marginal attribution); steady-state population.
        let pop = uniform_population(24, 1024, 256.0 / 5.0);
        let base = run_with(Box::new(RequestLevelScheduler::new(6)), &pop, 6);
        let sar = run_with(Box::new(SarathiScheduler::new(256, 6, 128)), &pop, 6);
        let speedup = base.metrics.decode_time_per_token() / sar.metrics.decode_time_per_token();
        assert!(speedup > 2.5, "decode speedup={speedup}");
    }

    #[test]
    fn staggered_arrivals_are_served() {
        let specs: Vec<RequestSpec> = (0..4)
            .map(|i| RequestSpec {
                prompt_len: 256,
                decode_len: 8,
                arrival: i as f64 * 0.05,
                prefix: None,
            })
            .collect();
        let e = run_with(Box::new(SarathiScheduler::new(128, 4, 128)), &specs, 4);
        assert!(e.pool.all_complete());
        for r in e.pool.iter() {
            assert!(r.completed_at.unwrap() >= r.arrival);
        }
    }

    #[test]
    fn slot_pressure_queues_requests() {
        // more requests than slots: engine must still finish everything
        let pop = uniform_population(9, 512, 20.0);
        let e = run_with(Box::new(SarathiScheduler::new(128, 3, 128)), &pop, 3);
        assert!(e.pool.all_complete());
        assert_eq!(e.kv.available(), 3);
    }

    #[test]
    fn sarathi_iteration_times_are_more_uniform_than_orca() {
        // the §3.3 uniformity claim, which drives the pipeline-bubble win
        let mut pop = uniform_population(8, 1024, 20.0);
        // de-synchronize arrivals so Orca mixes phases
        for (i, r) in pop.iter_mut().enumerate() {
            r.arrival = i as f64 * 0.02;
        }
        let orca = run_with(Box::new(OrcaScheduler::best(8)), &pop, 8);
        let sar = run_with(Box::new(SarathiScheduler::new(256, 8, 128)), &pop, 8);
        let spread = |e: &Engine| {
            let s = e.metrics.iteration_time_summary();
            (s.percentile(95.0) - s.percentile(5.0)) / s.mean()
        };
        assert!(spread(&sar) < spread(&orca), "{} !< {}", spread(&sar), spread(&orca));
    }

    #[test]
    fn tokens_are_stamped_at_iteration_end() {
        // the satellite fix: a single request's first token must land at
        // now + elapsed of the iteration that produced it, not at its start
        let specs = [RequestSpec { prompt_len: 64, decode_len: 3, arrival: 0.0, prefix: None }];
        let e = run_with(Box::new(SarathiScheduler::new(128, 1, 128)), &specs, 1);
        let r = e.pool.get(0);
        let it0 = e.metrics.record_at(0);
        assert!((r.first_token_at.unwrap() - (it0.started_at + it0.elapsed)).abs() < 1e-12);
        // completion coincides with the END of the last iteration
        let last = e.metrics.last_record().unwrap();
        assert!((r.completed_at.unwrap() - (last.started_at + last.elapsed)).abs() < 1e-12);
        // and every token stamp is strictly positive (none at t=0)
        assert!(r.first_token_at.unwrap() > 0.0);
        assert!(r.last_token_at.unwrap() > 0.0);
        assert!(e.pool.tbt_summary().min() > 0.0, "no gap measured from t=0");
    }

    #[test]
    fn costed_preemption_charges_swap_time_and_stretches_the_clock() {
        use crate::coordinator::step::{PreemptionMode, SwapCost};
        let specs: Vec<RequestSpec> = (0..4)
            .map(|_| RequestSpec { prompt_len: 32, decode_len: 40, arrival: 0.0, prefix: None })
            .collect();
        let run = |swap: SwapCost| {
            let mut e = Engine::new(
                RequestPool::from_specs(&specs),
                KvManager::paged(12, 16),
                Box::new(HybridScheduler::new(64, 8, 0)),
                sim(),
            )
            .with_swap_cost(swap);
            e.run();
            e
        };
        let free = run(SwapCost::free());
        assert!(free.metrics.preemptions > 0);
        assert_eq!(free.metrics.total_swap_time(), 0.0, "free swaps cost nothing");
        let costed = run(SwapCost {
            kv_bytes_per_token: 819_200.0, // llama-13b m_kv
            host_bw: 25.0e9,
            recompute_s_per_token: 0.0,
            mode: PreemptionMode::Swap,
        });
        assert!(costed.metrics.preemptions > 0);
        assert!(costed.metrics.total_swap_time() > 0.0, "swaps must be priced");
        // the transfer time lands on the simulated clock
        assert!(costed.now > free.now, "costed {} !> free {}", costed.now, free.now);
        // and everyone still finishes with all blocks returned
        assert!(costed.pool.all_complete());
        assert_eq!(costed.kv.available(), 12);
    }

    #[test]
    fn open_loop_rejects_oversized_requests_and_serves_the_rest() {
        use crate::coordinator::sched::admission::InfeasiblePolicy;
        // request 1 can never fit the 12-block pool (peak 32+200−1 = 15
        // blocks); under the Reject policy it must not crash the engine or
        // stall the co-running traffic behind it
        let specs = [
            RequestSpec { prompt_len: 32, decode_len: 8, arrival: 0.0, prefix: None },
            RequestSpec { prompt_len: 32, decode_len: 200, arrival: 0.0, prefix: None },
            RequestSpec { prompt_len: 32, decode_len: 8, arrival: 0.0, prefix: None },
        ];
        let mut e = Engine::new(
            RequestPool::from_specs(&specs),
            KvManager::paged(12, 16),
            Box::new(
                HybridScheduler::new(64, 8, 0).with_infeasible(InfeasiblePolicy::Reject),
            ),
            sim(),
        );
        e.run();
        assert!(e.pool.all_complete(), "rejection is terminal");
        assert_eq!(e.metrics.rejections, 1);
        assert_eq!(e.pool.rejected_count(), 1);
        assert!(e.pool.get(1).rejected_at.is_some());
        assert!(e.pool.get(0).completed_at.is_some());
        assert!(e.pool.get(2).completed_at.is_some());
    }

    #[test]
    fn prefix_sharing_completes_and_conserves_tokens_including_skips() {
        use crate::util::Rng;
        use crate::workload::shared_prefix_population;
        let mut rng = Rng::new(21);
        let pop = shared_prefix_population(&mut rng, 24, 3, 0.8, 96, 16, 48, 3.0);
        let mut e = Engine::new(
            RequestPool::from_specs(&pop),
            KvManager::paged(64, 16),
            Box::new(HybridScheduler::new(128, 16, 2).with_prefix_share(true)),
            sim(),
        );
        e.run();
        assert!(e.pool.all_complete());
        assert!(e.metrics.prefix_hits > 0, "template traffic must hit the cache");
        let per_req_hits: usize = e.pool.iter().map(|r| r.prefix_hits).sum();
        assert_eq!(e.metrics.prefix_hits, per_req_hits);
        // token conservation with compute skips: scheduled prefill tokens
        // plus cache-served tokens equal the workload's prompts exactly
        let skipped: usize = e.pool.iter().map(|r| r.prefix_skipped_tokens).sum();
        let total_p: usize = pop.iter().map(|s| s.prompt_len).sum();
        let total_d: usize = pop.iter().map(|s| s.decode_len - 1).sum();
        assert_eq!(e.metrics.total_prefill_tokens() + skipped, total_p);
        assert_eq!(e.metrics.total_decode_tokens(), total_d);
        assert!(skipped > 0, "hits must skip resident prefill work");
        // every request fully decoded, all private blocks returned: only
        // resident prefix pins may remain
        for r in e.pool.iter() {
            assert_eq!(r.decoded, r.spec.decode_len);
            assert!(r.blocks.is_empty());
        }
        let pinned: usize =
            e.kv.registered_prefixes().map(|(_, _, run)| run.len()).sum();
        assert_eq!(e.kv.available() + pinned, 64, "only prefix pins outlive the run");
        // shared occupancy showed up in the per-iteration records
        assert!(e.metrics.peak_shared_kv_tokens() > 0);
    }

    /// Tentpole guarantee (3), engine side. A registrant preempted before
    /// its fill produced a single token WAITS ON ITS OWN RUN at
    /// re-admission (prefilled = 0 looks like a fresh arrival) — the
    /// ROADMAP liveness hole. PR-3 panicked "engine wedged" here; now the
    /// driver demotes the wedge by forcing the oldest waiter's fallback
    /// and every request completes at full price.
    #[test]
    fn wedge_demotion_forces_fallback_instead_of_panicking() {
        use crate::coordinator::sched::Admission;
        use crate::workload::PrefixSpec;
        let spec = RequestSpec {
            prompt_len: 64,
            decode_len: 4,
            arrival: 0.0,
            prefix: Some(PrefixSpec::whole(9, 48)),
        };
        let mut e = Engine::new(
            RequestPool::from_specs(&[spec.clone(), spec]),
            KvManager::paged(16, 16),
            Box::new(HybridScheduler::new(128, 8, 0).with_prefix_share(true)),
            sim(),
        );
        // stage the hole: the registrant admits (registering the run,
        // unready) and is preempted at zero progress
        let adm = Admission::default().with_prefix_share(true);
        assert!(adm.try_admit_one(&mut e.pool, &mut e.kv, 0, 0.0));
        let blocks = e.pool.preempt(0, 0.0);
        e.kv.release_seq(blocks);
        assert!(!e.kv.is_prefix_ready(9));
        // the run demotes both stranded waiters instead of panicking
        e.run();
        assert!(e.pool.all_complete());
        assert_eq!(e.metrics.prefix_fallbacks, 2, "both template requests fell back");
        assert_eq!(e.metrics.prefix_hits, 0, "nobody can hit the never-filled run");
        assert!(e.metrics.prefix_wait_iterations > 0);
        for r in e.pool.iter() {
            assert!(r.prefix_fallback);
            assert_eq!(r.decoded, r.spec.decode_len);
        }
        // the wait-time histogram saw both waits
        let lat = crate::coordinator::LatencyReport::from_pool(&e.pool);
        assert_eq!(lat.prefix_wait.count(), 2);
        // only the stale pinned run remains allocated
        let pinned: usize = e.kv.registered_prefixes().map(|(_, _, run)| run.len()).sum();
        assert_eq!(e.kv.available() + pinned, 16);
    }

    /// A scheduler that admits but never composes: with no prefix waiter
    /// to demote, the engine must still fail loudly — now with KV
    /// occupancy and wait diagnostics in the message.
    struct NullScheduler;
    impl Scheduler for NullScheduler {
        fn compose(&mut self, _: &mut RequestPool, _: &mut KvManager, _: f64) -> Batch {
            Batch::default()
        }
        fn name(&self) -> &'static str {
            "null"
        }
    }

    #[test]
    #[should_panic(expected = "blocked on a prefix fill")]
    fn true_wedge_without_waiters_still_panics_with_diagnostics() {
        let specs = [RequestSpec { prompt_len: 8, decode_len: 1, arrival: 0.0, prefix: None }];
        let mut e = Engine::new(
            RequestPool::from_specs(&specs),
            KvManager::new(1),
            Box::new(NullScheduler),
            sim(),
        );
        e.run();
    }

    #[test]
    fn paged_engine_preempts_and_still_completes() {
        // 4 requests × (32 prompt + 40 decode) = 288 peak KV tokens over a
        // 12-block × 16-token pool (192 tokens): decode growth must force
        // preemptions, yet everyone finishes and all blocks come back.
        let specs: Vec<RequestSpec> = (0..4)
            .map(|_| RequestSpec { prompt_len: 32, decode_len: 40, arrival: 0.0, prefix: None })
            .collect();
        let mut e = Engine::new(
            RequestPool::from_specs(&specs),
            KvManager::paged(12, 16),
            Box::new(HybridScheduler::new(64, 8, 0)),
            sim(),
        );
        e.run();
        assert!(e.pool.all_complete());
        assert!(e.metrics.preemptions > 0, "undersized pool must preempt");
        assert_eq!(e.kv.available(), 12, "all blocks returned");
        for r in e.pool.iter() {
            assert_eq!(r.decoded, r.spec.decode_len);
        }
        // token conservation holds under preemption (swap, not recompute)
        let total_p: usize = specs.iter().map(|s| s.prompt_len).sum();
        let total_d: usize = specs.iter().map(|s| s.decode_len - 1).sum();
        assert_eq!(e.metrics.total_prefill_tokens(), total_p);
        assert_eq!(e.metrics.total_decode_tokens(), total_d);
    }
}
