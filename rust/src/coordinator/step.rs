//! The shared request-state transition — ONE implementation of
//! "a scheduled batch finished executing, advance the world".
//!
//! Both [`crate::coordinator::Engine`] and
//! [`crate::simulator::PipelineSim`] drive their iterations through
//! [`StepApplier::apply`]: progress counters, token-time stamping,
//! completion release, token-granular KV growth and LIFO preemption all
//! live here, so the engine and the pipeline simulator can never drift
//! apart again (the seed shipped a hand-copied `PipelineSim::apply` that
//! had already lost token stamping and the whole growth/preemption path).
//!
//! Preemption is **costed**: a victim's live KV must cross the host link
//! (PCIe) on the way out and back in, or be recomputed on resume —
//! [`SwapCost`] prices both, following DistServe's KV-movement accounting
//! (arXiv 2401.09670). The default [`SwapCost::free`] keeps the seed's
//! zero-cost semantics so every existing experiment reproduces unchanged.
//!
//! Cross-pool preemption: `apply` takes a *slice* of request pools and the
//! index of the pool that owns the executed batch. The engine passes its
//! single pool; the pipeline simulator passes one pool per stream so a
//! stream that runs out of blocks can evict the most-recently-arrived
//! request of ANY stream sharing the replica's paged pool.

use super::batch::Batch;
use super::kv::KvManager;
use super::pool::RequestPool;
use super::request::RequestId;
use crate::config::Deployment;

// Defined in config (it is a scheduling-policy knob); re-exported here
// because the costing lives in this module.
pub use crate::config::PreemptionMode;

/// Prices the preemption path. Time is charged to the stream that *caused*
/// the preemption (its iteration waits for the transfer) and to the
/// swap-in of a resumed victim (its first iteration back waits).
#[derive(Clone, Copy, Debug)]
pub struct SwapCost {
    /// KV-cache bytes per token per GPU — what each GPU moves over PCIe.
    pub kv_bytes_per_token: f64,
    /// Host-link (PCIe) bandwidth, bytes/s.
    pub host_bw: f64,
    /// Seconds per token to rebuild KV under [`PreemptionMode::Recompute`]
    /// (saturated-prefill rate from the cost model).
    pub recompute_s_per_token: f64,
    pub mode: PreemptionMode,
}

impl SwapCost {
    /// The seed semantics: preemption moves no bytes and costs no time.
    pub fn free() -> Self {
        SwapCost {
            kv_bytes_per_token: 0.0,
            host_bw: 1.0,
            recompute_s_per_token: 0.0,
            mode: PreemptionMode::Swap,
        }
    }

    /// Price swaps for a deployment: per-GPU KV bytes over the GPU's host
    /// link, with the recompute rate taken from the calibrated cost model's
    /// saturated prefill throughput.
    pub fn for_deployment(d: &Deployment, mode: PreemptionMode) -> Self {
        let cm = crate::costmodel::CostModel::for_deployment(d);
        SwapCost {
            kv_bytes_per_token: d.kv_bytes_per_token_per_gpu(),
            host_bw: d.gpu.host_bw_gbps * 1e9,
            recompute_s_per_token: cm.recompute_time_per_token(),
            mode,
        }
    }

    /// Time to evict `tokens` of live KV (free under Recompute — the cache
    /// is simply dropped).
    pub fn swap_out_time(&self, tokens: usize) -> f64 {
        match self.mode {
            PreemptionMode::Swap => tokens as f64 * self.kv_bytes_per_token / self.host_bw,
            PreemptionMode::Recompute => 0.0,
        }
    }

    /// Time to bring `tokens` of KV back before a resumed request can run:
    /// a host-to-device transfer under Swap, a prefill recompute charge
    /// under Recompute. (Token accounting is unchanged either way — the
    /// recompute is modeled as a time charge, not re-scheduled work, so
    /// token-conservation invariants keep holding.)
    pub fn swap_in_time(&self, tokens: usize) -> f64 {
        match self.mode {
            PreemptionMode::Swap => tokens as f64 * self.kv_bytes_per_token / self.host_bw,
            PreemptionMode::Recompute => tokens as f64 * self.recompute_s_per_token,
        }
    }

    pub fn is_free(&self) -> bool {
        self.kv_bytes_per_token == 0.0 && self.recompute_s_per_token == 0.0
    }
}

/// What one applied batch did to the world.
#[derive(Clone, Debug, Default)]
pub struct StepEffects {
    /// Requests (ids local to the owning pool) that completed at `done_at`.
    pub finished: Vec<RequestId>,
    /// Preemption events fired while growing block tables.
    pub preemptions: usize,
    /// Tokens of live KV evicted by those preemptions.
    pub swapped_out_tokens: usize,
    /// Swap-out transfer time charged to the owning stream.
    pub swap_time: f64,
}

/// The shared state transition. Construct with [`StepApplier::new`] for
/// seed-compatible free swaps, or [`StepApplier::with_cost`] to price the
/// preemption path.
#[derive(Clone, Copy, Debug)]
pub struct StepApplier {
    pub swap: SwapCost,
}

impl Default for StepApplier {
    fn default() -> Self {
        Self::new()
    }
}

impl StepApplier {
    pub fn new() -> Self {
        StepApplier { swap: SwapCost::free() }
    }

    pub fn with_cost(swap: SwapCost) -> Self {
        StepApplier { swap }
    }

    /// Advance request state for an executed batch owned by
    /// `pools[owner]`: progress counters and token stamps, completions
    /// (blocks released), then token-granular KV growth with LIFO
    /// preemption across ALL pools as the fallback when `kv` runs dry.
    ///
    /// `done_at` is the simulated time the batch finished (tokens and
    /// completions are stamped there). Victims are chosen
    /// most-recently-arrived-first across every pool sharing `kv`
    /// (ties broken by pool index then request id), falling back to
    /// self-preemption when the growing request is the only one admitted.
    pub fn apply(
        &self,
        pools: &mut [RequestPool],
        owner: usize,
        kv: &mut KvManager,
        batch: &Batch,
        done_at: f64,
    ) -> StepEffects {
        self.apply_guarded(pools, owner, kv, batch, done_at, &[])
    }

    /// [`apply`](Self::apply) with a preemption guard: `in_flight` lists
    /// `(pool, request)` pairs currently executing in OTHER streams'
    /// micro-batches — a request mid-iteration is not preemptible (its
    /// KV is being read by the running kernel; evicting it would also
    /// corrupt that batch's pending state transition). The pipeline
    /// simulator passes its in-flight batches; the engine, whose single
    /// batch is always the one being applied, passes none.
    pub fn apply_guarded(
        &self,
        pools: &mut [RequestPool],
        owner: usize,
        kv: &mut KvManager,
        batch: &Batch,
        done_at: f64,
        in_flight: &[(usize, RequestId)],
    ) -> StepEffects {
        self.apply_traced(pools, owner, kv, batch, done_at, in_flight, 0)
    }

    /// [`apply_guarded`](Self::apply_guarded) carrying the driver's batch
    /// id so per-chunk trace events ([`EventKind::ChunkScheduled`]) name
    /// the iteration that ran them. The id is trace-only — state
    /// transitions are identical for every value.
    ///
    /// [`EventKind::ChunkScheduled`]: super::trace::EventKind::ChunkScheduled
    #[allow(clippy::too_many_arguments)]
    pub fn apply_traced(
        &self,
        pools: &mut [RequestPool],
        owner: usize,
        kv: &mut KvManager,
        batch: &Batch,
        done_at: f64,
        in_flight: &[(usize, RequestId)],
        batch_id: u64,
    ) -> StepEffects {
        let mut effects = StepEffects::default();
        // 1. progress + token stamping
        {
            let pool = &mut pools[owner];
            for (req, start, len) in batch.prefill_items() {
                if pool.trace.is_enabled() {
                    pool.trace.emit(
                        done_at,
                        super::trace::EventKind::ChunkScheduled {
                            request: req,
                            batch: batch_id,
                            start,
                            len,
                        },
                    );
                }
                let r = pool.get_mut(req);
                r.prefilled += len;
                let prompt_done = r.prefilled == r.spec.prompt_len;
                if prompt_done {
                    // the final chunk's logits yield the first output token
                    r.decoded = 1;
                    r.first_token_at = Some(done_at);
                }
                let (prefilled, sharing) = (r.prefilled, r.shared_blocks > 0);
                let pfx_id = r.spec.prefix.as_ref().map(|p| p.id);
                if prompt_done {
                    pool.stamp_token(req, done_at);
                }
                // cache fill: once the registrant's prefill crosses the
                // pinned run's covered tokens, the run's KV exists and the
                // template becomes servable to waiting sharers. Only the
                // request actually holding the run's head fills it — a
                // plain-resumed filler writes its own fresh blocks, so it
                // never flips a stale husk ready. Short of ready, the
                // progress note resets waiters' bounded-wait stall clocks
                // (a fill that keeps advancing is worth waiting for).
                if let Some(id) = pfx_id {
                    if sharing && !kv.is_prefix_ready(id) {
                        kv.note_prefix_fill(id, prefilled);
                        let covered = kv.lookup_prefix_tokens(id);
                        if covered.is_some_and(|tokens| prefilled >= tokens) {
                            kv.mark_prefix_ready(id);
                        }
                    }
                }
            }
            for req in batch.decode_items() {
                pool.get_mut(req).decoded += 1;
                pool.stamp_token(req, done_at);
            }
            // 2. completions first: their blocks fund the growth below
            for req in batch.requests() {
                let r = pool.get(req);
                if r.completed_at.is_none()
                    && r.prefilled == r.spec.prompt_len
                    && r.decoded >= r.spec.decode_len
                {
                    let blocks = pool.complete(req, done_at);
                    kv.release_seq(blocks);
                    effects.finished.push(req);
                }
            }
        }
        // 3. token-granular growth: every surviving touched request's block
        // table must cover its KV plus one token of lookahead for the next
        // step. Degenerate blocks make this a no-op.
        for req in batch.requests() {
            loop {
                let r = pools[owner].get(req);
                if !r.is_admitted() {
                    break; // completed above, or preempted as a victim
                }
                let target = r.kv_len() + 1;
                if kv.extend_to(&mut pools[owner].get_mut(req).blocks, target) {
                    break;
                }
                // out of blocks: preempt the most-recently-arrived OTHER
                // admitted request across all pools sharing this KvManager
                // (LIFO victims, FCFS resume), skipping requests running in
                // other streams' in-flight micro-batches; fall back to
                // self-preemption when no one else is evictable
                let victim = pools
                    .iter()
                    .enumerate()
                    .flat_map(|(pi, p)| p.active_ids().iter().map(move |&id| (pi, id)))
                    .filter(|&(pi, id)| !(pi == owner && id == req))
                    .filter(|pair| !in_flight.contains(pair))
                    .max_by(|&(pa, a), &(pb, b)| {
                        let (ra, rb) = (pools[pa].get(a), pools[pb].get(b));
                        ra.arrival.total_cmp(&rb.arrival).then(pa.cmp(&pb)).then(a.cmp(&b))
                    })
                    .unwrap_or((owner, req));
                let (vp, vid) = victim;
                // evicting the request mid-fill of an unready run stalls
                // that fill: bump the run's stall counter so its waiters'
                // bounded-wait clocks tick even while other work keeps
                // the system busy (preemption is first-class progress
                // information, DistServe-style)
                {
                    let vr = pools[vp].get(vid);
                    if vr.shared_blocks > 0 {
                        if let Some(pfx) = vr.spec.prefix.as_ref() {
                            if !kv.is_prefix_ready(pfx.id) {
                                kv.note_prefix_filler_preempted(pfx.id);
                            }
                        }
                    }
                }
                // only the victim's PRIVATE tokens cross the host link:
                // shared prefix blocks stay resident (the index pin and/or
                // co-sharers keep their refcount up), so release below
                // only decrements them — preempting one sharer can never
                // free blocks another sharer still reads
                let evicted_tokens = pools[vp].get(vid).private_kv_tokens();
                let blocks = pools[vp].preempt(vid, done_at);
                kv.release_seq(blocks);
                effects.preemptions += 1;
                effects.swapped_out_tokens += evicted_tokens;
                effects.swap_time += self.swap.swap_out_time(evicted_tokens);
                if victim == (owner, req) {
                    break; // swapped itself out; it resumes via admission
                }
            }
        }
        effects
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batch::WorkItem;
    use crate::workload::RequestSpec;

    fn spec(p: usize, d: usize, arrival: f64) -> RequestSpec {
        RequestSpec { prompt_len: p, decode_len: d, arrival, prefix: None }
    }

    #[test]
    fn stamps_tokens_and_releases_completions() {
        let mut pool = RequestPool::from_specs(&[spec(8, 1, 0.0)]);
        let mut kv = KvManager::new(2);
        let b = kv.alloc().unwrap();
        pool.admit(0, vec![b], 0.0);
        let batch = Batch::new(vec![WorkItem::PrefillChunk { req: 0, start: 0, len: 8 }]);
        let applier = StepApplier::new();
        let fx = applier.apply(std::slice::from_mut(&mut pool), 0, &mut kv, &batch, 2.5);
        assert_eq!(fx.finished, vec![0]);
        assert_eq!(fx.preemptions, 0);
        assert_eq!(fx.swap_time, 0.0);
        let r = pool.get(0);
        assert_eq!(r.first_token_at, Some(2.5));
        assert_eq!(r.last_token_at, Some(2.5));
        assert_eq!(r.tbt_count, 0, "the first token has no gap");
        assert_eq!(r.completed_at, Some(2.5));
        assert_eq!(kv.available(), 2, "completion returned its block");
    }

    #[test]
    fn cross_pool_preemption_picks_latest_arrival_anywhere() {
        // two pools over one shared paged KvManager; growth in pool 0 must
        // evict pool 1's later-arrived request, not pool 0's own earlier one
        let mut pools = vec![
            RequestPool::from_specs(&[spec(16, 8, 0.0)]),
            RequestPool::from_specs(&[spec(16, 8, 1.0)]),
        ];
        let mut kv = KvManager::paged(4, 16);
        let t0 = kv.alloc_n(1).unwrap();
        pools[0].admit(0, t0, 0.0);
        let t1 = kv.alloc_n(3).unwrap();
        pools[1].admit(0, t1, 1.0);
        {
            let r = pools[0].get_mut(0);
            r.prefilled = 16;
            r.decoded = 1; // kv_len = 16: next decode needs a 2nd block
        }
        {
            let r = pools[1].get_mut(0);
            r.prefilled = 16;
            r.decoded = 17;
        }
        let batch = Batch::new(vec![WorkItem::Decode { req: 0 }]);
        let cost = SwapCost {
            kv_bytes_per_token: 1e9, // 1 GB per token over 1 GB/s = 1 s/token
            host_bw: 1e9,
            recompute_s_per_token: 0.0,
            mode: PreemptionMode::Swap,
        };
        let fx = StepApplier::with_cost(cost).apply(&mut pools, 0, &mut kv, &batch, 5.0);
        assert_eq!(fx.preemptions, 1);
        // victim is pool 1's request (arrival 1.0 > 0.0), 32 live KV tokens
        assert_eq!(fx.swapped_out_tokens, 32);
        assert!((fx.swap_time - 32.0).abs() < 1e-9);
        assert!(!pools[1].get(0).is_admitted());
        assert_eq!(pools[1].get(0).preemptions, 1);
        // the grower got its block
        assert_eq!(pools[0].get(0).blocks.len(), 2);
    }

    #[test]
    fn in_flight_requests_are_not_preemptible() {
        // same setup as above, but pool 1's request is mid-iteration in
        // another stream's micro-batch: the grower must NOT evict it and
        // falls back to self-preemption
        let mut pools = vec![
            RequestPool::from_specs(&[spec(16, 8, 0.0)]),
            RequestPool::from_specs(&[spec(16, 8, 1.0)]),
        ];
        let mut kv = KvManager::paged(4, 16);
        let t0 = kv.alloc_n(1).unwrap();
        pools[0].admit(0, t0, 0.0);
        let t1 = kv.alloc_n(3).unwrap();
        pools[1].admit(0, t1, 1.0);
        {
            let r = pools[0].get_mut(0);
            r.prefilled = 16;
            r.decoded = 1;
        }
        let batch = Batch::new(vec![WorkItem::Decode { req: 0 }]);
        let fx = StepApplier::new().apply_guarded(
            &mut pools,
            0,
            &mut kv,
            &batch,
            5.0,
            &[(1, 0)], // pool 1's request is in flight elsewhere
        );
        assert_eq!(fx.preemptions, 1);
        assert!(pools[1].get(0).is_admitted(), "in-flight victim untouched");
        assert!(!pools[0].get(0).is_admitted(), "grower swapped itself out");
        assert_eq!(pools[0].get(0).preemptions, 1);
    }

    /// Regression (PR 3): preempting a request that shares a prefix run
    /// must leave every co-sharer's block table valid — the shared head
    /// blocks are only decremented, never freed, and the evicted-token
    /// swap charge covers the victim's PRIVATE tokens alone.
    #[test]
    fn preempting_a_sharer_leaves_co_sharers_tables_valid() {
        use crate::coordinator::sched::Admission;
        use crate::workload::PrefixSpec;
        // one pool, 32-token block-aligned prefix over 16-token blocks;
        // each request: 40-token prompt (2 shared + 1 private block), a
        // long decode tail so growth hits the memory wall
        let spec = |arrival: f64| RequestSpec {
            prompt_len: 40,
            decode_len: 60,
            arrival,
            prefix: Some(PrefixSpec::whole(5, 32)),
        };
        let mut pool = RequestPool::from_specs(&[spec(0.0), spec(1.0)]);
        // 6 blocks: registrant takes 3 (2 pinned+shared, 1 private), the
        // sharer adds 1 private; 2 free for growth
        let mut kv = KvManager::paged(6, 16);
        let adm = Admission::default().with_prefix_share(true);
        assert!(adm.try_admit_one(&mut pool, &mut kv, 0, 0.0));
        kv.mark_prefix_ready(5); // the registrant's fill, unit-flipped
        assert!(adm.try_admit_one(&mut pool, &mut kv, 1, 1.0));
        assert_eq!(kv.available(), 2);
        let head: Vec<usize> = pool.get(0).blocks[..2].to_vec();
        assert_eq!(head, pool.get(1).blocks[..2].to_vec());
        assert_eq!(kv.ref_count(head[0]), 3, "pin + two sharers");
        // request 0 deep into decode: this iteration's token pushes its
        // table demand to 6 blocks (kv 95 + 1) with only 3 held, 2 free
        {
            let r = pool.get_mut(0);
            r.prefilled = 40;
            r.decoded = 55;
        }
        // request 1 just finished its prompt: 8 private tokens live
        // (40 kv − 32 shared)
        {
            let r = pool.get_mut(1);
            r.prefilled = 40;
            r.decoded = 1;
        }
        let cost = SwapCost {
            kv_bytes_per_token: 1.0, // 1 B/token over 1 B/s = 1 s/token
            host_bw: 1.0,
            recompute_s_per_token: 0.0,
            mode: PreemptionMode::Swap,
        };
        let batch = Batch::new(vec![WorkItem::Decode { req: 0 }]);
        let fx = StepApplier::with_cost(cost).apply(
            std::slice::from_mut(&mut pool),
            0,
            &mut kv,
            &batch,
            5.0,
        );
        // growth demanded 3 fresh blocks with 2 free → victim = request 1
        // (latest arrival). Only its 8 PRIVATE tokens are charged to the
        // swap — the 32 shared prefix tokens never leave the GPU.
        assert_eq!(fx.preemptions, 1);
        assert!(!pool.get(1).is_admitted());
        assert_eq!(fx.swapped_out_tokens, 8, "swap charge must exclude shared KV");
        assert!((fx.swap_time - 8.0).abs() < 1e-9);
        // co-sharer (request 0) table intact: grown to 6 blocks, every
        // block still allocated, shared head still pin + itself
        assert_eq!(pool.get(0).blocks.len(), 6);
        for &b in &pool.get(0).blocks {
            assert!(kv.is_allocated(b), "co-sharer block {b} freed by preemption");
        }
        assert_eq!(kv.ref_count(head[0]), 2, "pin + surviving sharer");
        assert_eq!(kv.ref_count(head[1]), 2);
        assert_eq!(pool.get(0).shared_blocks, 2, "survivor's split untouched");
        // the prefix stays resident, so the victim's eventual swap-in
        // re-shares the head instead of re-reserving it: 3-block demand,
        // 1 fresh block
        assert!(kv.lookup_prefix(5).is_some());
        assert_eq!(adm.blocks_required(&pool, &kv, 1), 1);
    }

    #[test]
    fn registrants_prefill_makes_the_run_servable_and_sharers_release_cleanly() {
        use crate::coordinator::sched::Admission;
        use crate::workload::PrefixSpec;
        let spec = |decode_len: usize| RequestSpec {
            prompt_len: 40,
            decode_len,
            arrival: 0.0,
            prefix: Some(PrefixSpec::whole(2, 32)),
        };
        let mut pool = RequestPool::from_specs(&[spec(4), spec(1)]);
        let mut kv = KvManager::paged(8, 16);
        let adm = Admission::default().with_prefix_share(true);
        assert!(adm.try_admit_one(&mut pool, &mut kv, 0, 0.0));
        // a fresh same-template arrival WAITS while the run is unready
        assert!(!adm.try_admit_one(&mut pool, &mut kv, 1, 0.0));
        assert!(!pool.get(1).is_admitted());
        // the registrant's prefill crossing the 32 covered tokens flips
        // the run servable — through the SHARED state transition
        let batch = Batch::new(vec![WorkItem::PrefillChunk { req: 0, start: 0, len: 40 }]);
        let fx = StepApplier::new().apply(std::slice::from_mut(&mut pool), 0, &mut kv, &batch, 1.0);
        assert!(fx.finished.is_empty());
        assert!(kv.is_prefix_ready(2), "crossing the covered tokens fills the cache");
        // now the waiter admits as a hit, skipping the resident prefill
        assert!(adm.try_admit_one(&mut pool, &mut kv, 1, 1.0));
        assert_eq!(pool.get(1).prefix_hits, 1);
        assert_eq!(pool.get(1).prefilled, 32);
        let head: Vec<usize> = pool.get(0).blocks[..2].to_vec();
        assert_eq!(head, pool.get(1).blocks[..2].to_vec());
        // the sharer finishes its prompt tail and completes (decode 1)
        let remaining = pool.get(1).remaining_prompt();
        let start = pool.get(1).prefilled;
        let batch = Batch::new(vec![WorkItem::PrefillChunk { req: 1, start, len: remaining }]);
        let fx = StepApplier::new().apply(std::slice::from_mut(&mut pool), 0, &mut kv, &batch, 2.0);
        assert_eq!(fx.finished, vec![1]);
        // the completed sharer's private tail is freed; the shared head
        // survives for the registrant and the pin
        assert_eq!(kv.ref_count(head[0]), 2, "pin + registrant remain");
        for &b in &pool.get(0).blocks {
            assert!(kv.is_allocated(b));
        }
        assert!(kv.lookup_prefix(2).is_some());
    }

    /// Liveness regression: a filler preempted MID-FILL must re-share the
    /// pinned head it was filling on resume — its computed KV is resident
    /// there (swap-in moves nothing), and holding the head again is what
    /// lets its prefill flip the run servable. Resuming it at full price
    /// instead would leave the run unready forever and wedge every fresh
    /// same-template arrival behind the cache-wait gate.
    #[test]
    fn preempted_filler_resumes_by_resharing_and_still_readies_the_run() {
        use crate::coordinator::sched::Admission;
        use crate::workload::PrefixSpec;
        let spec = RequestSpec {
            prompt_len: 40,
            decode_len: 8,
            arrival: 0.0,
            prefix: Some(PrefixSpec::whole(4, 32)),
        };
        let mut pool = RequestPool::from_specs(&[spec.clone(), spec]);
        let mut kv = KvManager::paged(5, 16);
        let adm = Admission::default().with_prefix_share(true);
        assert!(adm.try_admit_one(&mut pool, &mut kv, 0, 0.0));
        let head: Vec<usize> = pool.get(0).blocks[..2].to_vec();
        // half the prefix prefilled, then the filler is preempted
        let batch = Batch::new(vec![WorkItem::PrefillChunk { req: 0, start: 0, len: 16 }]);
        StepApplier::new().apply(std::slice::from_mut(&mut pool), 0, &mut kv, &batch, 1.0);
        assert!(!kv.is_prefix_ready(4), "mid-fill run is not servable");
        let blocks = pool.preempt(0, 1.5);
        kv.release_seq(blocks);
        assert!(kv.lookup_prefix(4).is_some(), "the pin keeps the half-filled run");
        // resume: the filler re-shares the head — only 1 fresh block, and
        // NO swap-in charge (all 16 computed tokens stayed GPU-resident)
        assert_eq!(adm.blocks_required(&pool, &kv, 0), 1);
        pool.take_swapped_in_tokens();
        assert!(adm.try_admit_one(&mut pool, &mut kv, 0, 2.0));
        assert_eq!(pool.take_swapped_in_tokens(), 0, "head KV never left the GPU");
        assert_eq!(pool.get(0).blocks[..2].to_vec(), head);
        assert_eq!(pool.get(0).prefilled, 16, "no skip: the fill resumes for real");
        // its prefill crossing the covered tokens flips the run servable
        let batch = Batch::new(vec![WorkItem::PrefillChunk { req: 0, start: 16, len: 16 }]);
        StepApplier::new().apply(std::slice::from_mut(&mut pool), 0, &mut kv, &batch, 3.0);
        assert!(kv.is_prefix_ready(4), "the resumed fill readies the run");
        // and the waiting same-template arrival now admits as a hit
        assert!(adm.try_admit_one(&mut pool, &mut kv, 1, 3.5));
        assert_eq!(pool.get(1).prefix_hits, 1);
        assert_eq!(pool.get(1).prefilled, 32);
    }

    /// Growth-preempting the request mid-fill of an unready run must bump
    /// the run's stall-event counter (waiters' bounded-wait clocks tick),
    /// and the shared transition notes fill progress while it advances.
    #[test]
    fn preempting_the_filler_mid_fill_wakes_waiters_stall_clocks() {
        use crate::coordinator::sched::Admission;
        use crate::workload::PrefixSpec;
        let plain = RequestSpec { prompt_len: 32, decode_len: 20, arrival: 0.0, prefix: None };
        let tpl = RequestSpec {
            prompt_len: 40,
            decode_len: 8,
            arrival: 1.0,
            prefix: Some(PrefixSpec::whole(6, 32)),
        };
        let mut pool = RequestPool::from_specs(&[plain, tpl]);
        let mut kv = KvManager::paged(5, 16);
        let adm = Admission::default().with_prefix_share(true);
        assert!(adm.try_admit_one(&mut pool, &mut kv, 0, 0.0));
        {
            let r = pool.get_mut(0);
            r.prefilled = 32;
            r.decoded = 1;
        }
        assert!(adm.try_admit_one(&mut pool, &mut kv, 1, 1.0));
        // half the fill lands through the shared transition: progress noted
        let batch = Batch::new(vec![WorkItem::PrefillChunk { req: 1, start: 0, len: 16 }]);
        StepApplier::new().apply(std::slice::from_mut(&mut pool), 0, &mut kv, &batch, 1.5);
        assert_eq!(kv.prefix_fill_state(6), Some((16, 0)));
        assert!(!kv.is_prefix_ready(6));
        // request 0's decode growth runs the pool dry: the filler (latest
        // arrival) is evicted, which must count one stall event
        let batch = Batch::new(vec![WorkItem::Decode { req: 0 }]);
        let fx =
            StepApplier::new().apply(std::slice::from_mut(&mut pool), 0, &mut kv, &batch, 2.0);
        assert_eq!(fx.preemptions, 1);
        assert!(!pool.get(1).is_admitted(), "the filler was the victim");
        assert_eq!(
            kv.prefix_fill_state(6),
            Some((16, 1)),
            "preempting the filler is one stall event"
        );
    }

    #[test]
    fn recompute_mode_prices_resume_not_eviction() {
        let cost = SwapCost {
            kv_bytes_per_token: 2.0,
            host_bw: 1.0,
            recompute_s_per_token: 0.5,
            mode: PreemptionMode::Recompute,
        };
        assert_eq!(cost.swap_out_time(100), 0.0);
        assert!((cost.swap_in_time(100) - 50.0).abs() < 1e-12);
        let swap = SwapCost { mode: PreemptionMode::Swap, ..cost };
        assert!((swap.swap_out_time(100) - 200.0).abs() < 1e-12);
        assert!((swap.swap_in_time(100) - 200.0).abs() < 1e-12);
        assert!(SwapCost::free().is_free());
    }
}
