//! Work items and batch composition.

use super::pool::RequestPool;
use super::request::RequestId;
use crate::costmodel::BatchShape;

/// One unit of scheduled work inside an iteration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkItem {
    /// Prefill `len` prompt tokens of `req` starting at offset `start`.
    PrefillChunk { req: RequestId, start: usize, len: usize },
    /// Generate one token for `req`.
    Decode { req: RequestId },
}

impl WorkItem {
    pub fn request(&self) -> RequestId {
        match *self {
            WorkItem::PrefillChunk { req, .. } | WorkItem::Decode { req } => req,
        }
    }
}

/// The batch one iteration executes.
#[derive(Clone, Debug, Default)]
pub struct Batch {
    pub items: Vec<WorkItem>,
}

impl Batch {
    pub fn new(items: Vec<WorkItem>) -> Self {
        Batch { items }
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn prefill_items(&self) -> impl Iterator<Item = (RequestId, usize, usize)> + '_ {
        self.items.iter().filter_map(|it| match *it {
            WorkItem::PrefillChunk { req, start, len } => Some((req, start, len)),
            _ => None,
        })
    }

    pub fn decode_items(&self) -> impl Iterator<Item = RequestId> + '_ {
        self.items.iter().filter_map(|it| match *it {
            WorkItem::Decode { req } => Some(req),
            _ => None,
        })
    }

    pub fn n_prefill_chunks(&self) -> usize {
        self.prefill_items().count()
    }

    pub fn n_decodes(&self) -> usize {
        self.decode_items().count()
    }

    pub fn prefill_tokens(&self) -> usize {
        self.prefill_items().map(|(_, _, len)| len).sum()
    }

    /// Rows of the fused linear-operator matrix this batch produces.
    pub fn total_tokens(&self) -> usize {
        self.prefill_tokens() + self.n_decodes()
    }

    /// Decode-maximal composition (§4.3): exactly one prefill chunk and at
    /// least one piggybacked decode.
    pub fn is_decode_maximal(&self) -> bool {
        self.n_prefill_chunks() == 1 && self.n_decodes() > 0
    }

    /// Distinct requests touched (each request may appear at most once).
    pub fn requests(&self) -> Vec<RequestId> {
        self.request_iter().collect()
    }

    /// [`requests`](Self::requests) without the allocation — the per-event
    /// pipeline hot path iterates batch membership thousands of times per
    /// run and must not collect a fresh Vec each time.
    pub fn request_iter(&self) -> impl Iterator<Item = RequestId> + '_ {
        self.items.iter().map(|it| it.request())
    }

    /// The compute shape the cost model / profiler consumes. `pool`
    /// supplies per-request history and KV lengths.
    pub fn shape(&self, pool: &RequestPool) -> BatchShape {
        let mut shape = BatchShape::default();
        for (req, start, len) in self.prefill_items() {
            debug_assert_eq!(pool.get(req).prefilled, start);
            shape.prefill.push(crate::costmodel::PrefillItem { chunk: len, history: start });
        }
        for req in self.decode_items() {
            shape.decode.push(crate::costmodel::DecodeItem { kv_len: pool.get(req).kv_len() });
        }
        shape
    }

    /// Structural invariants every scheduler must uphold. Returns Err with
    /// the violated rule; exercised heavily by the property tests.
    pub fn validate(&self, pool: &RequestPool, max_batch: usize) -> Result<(), String> {
        let mut seen = std::collections::HashSet::new();
        for it in &self.items {
            if !seen.insert(it.request()) {
                return Err(format!("request {} appears twice in one batch", it.request()));
            }
        }
        if self.len() > max_batch {
            return Err(format!("batch size {} exceeds B={}", self.len(), max_batch));
        }
        for (req, start, len) in self.prefill_items() {
            let r = pool.get(req);
            if !r.is_admitted() {
                return Err(format!("prefill of unadmitted request {req}"));
            }
            if len == 0 {
                return Err(format!("empty prefill chunk for request {req}"));
            }
            if start != r.prefilled {
                return Err(format!(
                    "chunk start {start} != prefilled {} for request {req}",
                    r.prefilled
                ));
            }
            if start + len > r.spec.prompt_len {
                return Err(format!("chunk overruns prompt for request {req}"));
            }
        }
        for req in self.decode_items() {
            let r = pool.get(req);
            if !r.is_admitted() {
                return Err(format!("decode of unadmitted request {req}"));
            }
            if !r.is_decode_ready() {
                return Err(format!("decode of request {req} still in prefill"));
            }
            if r.remaining_decode() == 0 {
                return Err(format!("decode of completed request {req}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::RequestSpec;

    fn pool() -> RequestPool {
        let mut p = RequestPool::new();
        // 0: mid-prefill, 1: decoding, 2: queued
        p.push(RequestSpec { prompt_len: 100, decode_len: 5, arrival: 0.0, prefix: None });
        p.push(RequestSpec { prompt_len: 50, decode_len: 5, arrival: 0.0, prefix: None });
        p.push(RequestSpec { prompt_len: 10, decode_len: 5, arrival: 0.0, prefix: None });
        p.admit(0, vec![0], 0.0);
        p.get_mut(0).prefilled = 32;
        p.admit(1, vec![1], 0.0);
        p.get_mut(1).prefilled = 50;
        p.get_mut(1).decoded = 2;
        p
    }

    #[test]
    fn accounting_and_shape() {
        let p = pool();
        let b = Batch::new(vec![
            WorkItem::PrefillChunk { req: 0, start: 32, len: 30 },
            WorkItem::Decode { req: 1 },
        ]);
        assert!(b.is_decode_maximal());
        assert_eq!(b.total_tokens(), 31);
        let shape = b.shape(&p);
        assert_eq!(shape.prefill[0].history, 32);
        assert_eq!(shape.decode[0].kv_len, 51);
        assert!(b.validate(&p, 4).is_ok());
    }

    #[test]
    fn validation_catches_violations() {
        let p = pool();
        // duplicate request
        let b = Batch::new(vec![WorkItem::Decode { req: 1 }, WorkItem::Decode { req: 1 }]);
        assert!(b.validate(&p, 4).unwrap_err().contains("twice"));
        // wrong chunk start
        let b = Batch::new(vec![WorkItem::PrefillChunk { req: 0, start: 0, len: 10 }]);
        assert!(b.validate(&p, 4).unwrap_err().contains("chunk start"));
        // chunk overrun
        let b = Batch::new(vec![WorkItem::PrefillChunk { req: 0, start: 32, len: 100 }]);
        assert!(b.validate(&p, 4).unwrap_err().contains("overruns"));
        // decode of request still prefilling
        let b = Batch::new(vec![WorkItem::Decode { req: 0 }]);
        assert!(b.validate(&p, 4).unwrap_err().contains("still in prefill"));
        // unadmitted
        let b = Batch::new(vec![WorkItem::Decode { req: 2 }]);
        assert!(b.validate(&p, 4).unwrap_err().contains("unadmitted"));
        // over capacity
        let b = Batch::new(vec![WorkItem::Decode { req: 1 }]);
        assert!(b.validate(&p, 0).unwrap_err().contains("exceeds"));
    }
}
