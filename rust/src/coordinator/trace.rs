//! Event-sourced request tracing: the coordinator-level event bus.
//!
//! Every layer of the stack (pool, step applier, engine, pipeline,
//! cluster, soak driver) emits typed lifecycle events into a per-pool
//! [`TraceSink`]. The sink is **zero-cost when disabled** — the default
//! sink is a `None` and every `emit` is an inlined early return — and
//! allocation-bounded when enabled: a pre-sized ring that drops the
//! newest events past its capacity (counting them) and is drained at
//! flush boundaries, mirroring the soak harness's windowed telemetry.
//!
//! Determinism: events carry a `(time, replica, lane, seq)` key, where
//! `seq` is the sink's own monotone counter. Per-replica event
//! generation is sequential and independent of `--threads`, so the
//! canonical merge ([`merge_streams`]) produces a bitwise-identical
//! stream at every thread count (the PR-5/6 invariant, extended to the
//! trace layer).
//!
//! Two exports derive from the one stream: the Chrome trace-event /
//! Perfetto timeline ([`crate::report::timeline`]) and the per-request
//! latency decomposition ([`LatencyBreakdown`]), which carries the
//! measured TTFT / end-to-end latency bitwise and whose compute/decode
//! components are conservation-checked residuals: the component re-sum
//! reproduces the measured value bitwise except on round-to-even ties
//! (within one ULP then — see [`LatencyBreakdown`]).

use super::pool::RequestPool;
use super::request::RequestId;
use super::step::SwapCost;

/// Why a replica/stream was idle for an interval — the bubble taxonomy
/// of the timeline export (SARATHI §5.3's PB1/PB2/PB3 generalized to
/// the serving stack).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BubbleClass {
    /// Nothing to run and nothing queued: genuine idleness (open-loop
    /// arrival gaps).
    NoWork,
    /// Work is queued but could not be admitted/composed — blocked on
    /// KV blocks or admission gates.
    KvStarved,
    /// The iteration's token budget capped composition below the
    /// available work.
    BudgetCapped,
    /// A pipeline stage waited for an upstream micro-batch (the Fig. 5
    /// pipeline bubble).
    BarrierWait,
}

impl BubbleClass {
    pub fn as_str(&self) -> &'static str {
        match self {
            BubbleClass::NoWork => "no-work",
            BubbleClass::KvStarved => "kv-starved",
            BubbleClass::BudgetCapped => "budget-capped",
            BubbleClass::BarrierWait => "barrier-wait",
        }
    }
}

/// Typed per-request lifecycle events plus per-iteration batch spans,
/// idle (bubble) intervals and KV handoff spans.
#[derive(Clone, Debug, PartialEq)]
pub enum EventKind {
    /// The request entered the system (workload arrival).
    Arrived { request: RequestId },
    /// The request joined the admission queue (same instant as
    /// `Arrived` today; kept distinct so deferred enqueue can diverge).
    Queued { request: RequestId },
    /// Retroactively emitted when a prefix wait resolves: the request
    /// began waiting on template `hash`'s in-flight fill at this
    /// event's time.
    PrefixWaitStart { request: RequestId, hash: u64 },
    /// The wait resolved — as a hit (`fallback: false`) or by
    /// degrading to a full-price miss.
    PrefixWaitEnd { request: RequestId, hash: u64, fallback: bool },
    /// First admission: the request got its KV table, split into
    /// shared (prefix-resident) and private tokens.
    Admitted { request: RequestId, shared_tokens: usize, private_tokens: usize },
    /// Re-admission of a preempted request; `swap_tokens` crossed the
    /// host link (0 when a resident prefix covered everything).
    Resumed { request: RequestId, swap_tokens: usize },
    /// One prefill chunk `[start, start+len)` ran in batch `batch`.
    ChunkScheduled { request: RequestId, batch: u64, start: usize, len: usize },
    /// Evicted to free KV blocks; `evicted_tokens` of private KV moved
    /// (or were dropped for recompute).
    Preempted { request: RequestId, evicted_tokens: usize },
    /// KV handoff span over the interconnect: `[at, end]` on the
    /// `(src → dst)` fabric lane.
    KvTransfer { request: usize, src: usize, dst: usize, end: f64 },
    FirstToken { request: RequestId },
    TokenEmitted { request: RequestId },
    Completed { request: RequestId },
    Rejected { request: RequestId },
    /// One executed iteration: `[at, end]`, with its composition.
    BatchSpan {
        batch: u64,
        end: f64,
        prefill_tokens: usize,
        decode_tokens: usize,
        n_prefill: usize,
        n_decode: usize,
        budget_capped: bool,
    },
    /// Idle interval `[at, end]` on this lane, classified.
    Bubble { end: f64, class: BubbleClass },
}

/// One event on the bus. `at` is the simulated time; `(at, replica,
/// lane, seq)` is the canonical merge key. `lane` is the display
/// thread: the pp stream for engine/lifecycle events, the stage index
/// for pipeline stage spans and barrier bubbles.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    pub at: f64,
    pub replica: u32,
    pub lane: u32,
    pub seq: u64,
    pub kind: EventKind,
}

/// The enabled sink's state: a pre-sized ring of events plus the
/// drain/leak counters the soak harness reports.
#[derive(Clone, Debug)]
struct SinkBuf {
    events: Vec<TraceEvent>,
    cap: usize,
    replica: u32,
    lane: u32,
    seq: u64,
    emitted: u64,
    dropped: u64,
    high_water: usize,
}

/// Default ring capacity between drains (events, not bytes).
pub const DEFAULT_TRACE_CAP: usize = 1 << 16;

/// The per-pool event bus. Disabled (the default) it is a single
/// `None` — `emit` is an inlined early return, preserving the PR-6
/// allocation-free hot path bit for bit. Enabled, it buffers into a
/// pre-sized ring drained at flush boundaries.
#[derive(Clone, Debug, Default)]
pub struct TraceSink(Option<Box<SinkBuf>>);

impl TraceSink {
    /// The no-op sink (also `Default`).
    pub fn disabled() -> Self {
        TraceSink(None)
    }

    /// An enabled sink with ring capacity `cap` (events past it are
    /// dropped newest-first and counted).
    pub fn enabled(cap: usize) -> Self {
        let cap = cap.max(1);
        TraceSink(Some(Box::new(SinkBuf {
            events: Vec::with_capacity(cap.min(DEFAULT_TRACE_CAP)),
            cap,
            replica: 0,
            lane: 0,
            seq: 0,
            emitted: 0,
            dropped: 0,
            high_water: 0,
        })))
    }

    /// Stamp every future event with this replica/lane identity.
    pub fn set_identity(&mut self, replica: u32, lane: u32) {
        if let Some(b) = &mut self.0 {
            b.replica = replica;
            b.lane = lane;
        }
    }

    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.0.is_some()
    }

    /// Emit `kind` at time `at` on the sink's default lane.
    #[inline]
    pub fn emit(&mut self, at: f64, kind: EventKind) {
        if let Some(b) = &mut self.0 {
            let lane = b.lane;
            Self::push(b, at, lane, kind);
        }
    }

    /// Emit on an explicit lane (pipeline stage spans/bubbles).
    #[inline]
    pub fn emit_on(&mut self, at: f64, lane: u32, kind: EventKind) {
        if let Some(b) = &mut self.0 {
            Self::push(b, at, lane, kind);
        }
    }

    fn push(b: &mut SinkBuf, at: f64, lane: u32, kind: EventKind) {
        let seq = b.seq;
        b.seq += 1;
        b.emitted += 1;
        if b.events.len() >= b.cap {
            b.dropped += 1;
            return;
        }
        b.events.push(TraceEvent { at, replica: b.replica, lane, seq, kind });
        b.high_water = b.high_water.max(b.events.len());
    }

    /// Take the buffered events out (emission order), keeping the
    /// counters — the flush-boundary drain.
    pub fn drain(&mut self) -> Vec<TraceEvent> {
        match &mut self.0 {
            Some(b) => std::mem::take(&mut b.events),
            None => Vec::new(),
        }
    }

    /// Drain into `out` (the cluster's merge accumulator).
    pub fn drain_into(&mut self, out: &mut Vec<TraceEvent>) {
        if let Some(b) = &mut self.0 {
            out.append(&mut b.events);
        }
    }

    /// Events currently buffered.
    pub fn len(&self) -> usize {
        self.0.as_ref().map_or(0, |b| b.events.len())
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events ever emitted (dropped ones included).
    pub fn emitted(&self) -> u64 {
        self.0.as_ref().map_or(0, |b| b.emitted)
    }

    /// Events the ring dropped for want of capacity.
    pub fn dropped(&self) -> u64 {
        self.0.as_ref().map_or(0, |b| b.dropped)
    }

    /// Peak buffered events between drains — the soak leak detector's
    /// trace-ring counter.
    pub fn high_water(&self) -> usize {
        self.0.as_ref().map_or(0, |b| b.high_water)
    }
}

/// Canonically merge per-sink event streams into ONE deterministic
/// stream, ordered by `(time, replica, lane, seq)`. Each sink's events
/// are generated sequentially regardless of `--threads`, so the merged
/// stream is bitwise identical at every thread count.
pub fn merge_streams(streams: Vec<Vec<TraceEvent>>) -> Vec<TraceEvent> {
    let mut all: Vec<TraceEvent> = streams.into_iter().flatten().collect();
    all.sort_by(|a, b| {
        a.at.total_cmp(&b.at)
            .then(a.replica.cmp(&b.replica))
            .then(a.lane.cmp(&b.lane))
            .then(a.seq.cmp(&b.seq))
    });
    all
}

/// Step one ULP toward +∞ (finite inputs; 0.0 steps to the smallest
/// subnormal).
fn next_up(x: f64) -> f64 {
    if x.is_nan() || x == f64::INFINITY {
        return x;
    }
    if x == 0.0 {
        return f64::from_bits(1);
    }
    let bits = x.to_bits();
    if x > 0.0 {
        f64::from_bits(bits + 1)
    } else {
        f64::from_bits(bits - 1)
    }
}

fn next_down(x: f64) -> f64 {
    -next_up(-x)
}

/// The residual `r` such that `partial + r` reproduces `target`
/// BITWISE whenever one exists. `target - partial` is the right answer
/// to within one ULP; the walk fixes the rounding of the re-sum. No
/// such `r` exists when `partial` sits exactly half an ULP(target) off
/// target's grid: every candidate sum is a round-to-even tie and only
/// even-parity results are representable — the fallback is then within
/// one ULP of `target` (which is why [`LatencyBreakdown`] carries the
/// measured totals instead of relying on the re-sum).
fn conserved_residual(target: f64, partial: f64) -> f64 {
    let mut r = target - partial;
    for _ in 0..64 {
        let s = partial + r;
        if s.to_bits() == target.to_bits() {
            return r;
        }
        r = if s < target { next_up(r) } else { next_down(r) };
    }
    target - partial
}

/// Per-request causal latency decomposition:
/// `ttft = queue_wait + prefix_wait + swap + kv_transfer + compute`
/// and `e2e = ttft + decode`, conserved against the pool-measured
/// `first_token_at − arrival` / `completed_at − arrival`.
///
/// Conservation is two-layered. The breakdown CARRIES the measured
/// totals (`ttft`, `e2e` — what [`total_ttft`](Self::total_ttft) /
/// [`total_e2e`](Self::total_e2e) return), so reported totals are the
/// measured latencies bitwise by construction. `compute` and `decode`
/// are ULP-walked residuals chosen so the left-to-right component
/// re-sum ([`resummed_ttft`](Self::resummed_ttft)) reproduces the
/// measured value bitwise wherever IEEE-754 permits; when the wait sum
/// sits exactly half an ULP off the target's grid every candidate sum
/// is a round-to-even tie and the target's parity can be unreachable —
/// the re-sum is then within one ULP (see
/// `round_to_even_ties_cap_the_resum_error_at_one_ulp`).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LatencyBreakdown {
    /// Request id — pool-local for an engine run, global (cluster
    /// dispatch order) for cluster runs.
    pub request: usize,
    pub arrival: f64,
    /// Measured first-token latency (`first_token_at − arrival`).
    pub ttft: f64,
    /// Measured end-to-end latency (`completed_at − arrival`); equals
    /// `ttft` while the request is incomplete.
    pub e2e: f64,
    /// Time queued without KV blocks before the first token, net of
    /// the prefix wait below.
    pub queue_wait: f64,
    /// Time blocked on an in-flight prefix fill.
    pub prefix_wait: f64,
    /// Host-link swap-in charge for KV this request moved back before
    /// its first token.
    pub swap: f64,
    /// Interconnect KV-handoff latency (disaggregated runs; 0
    /// elsewhere). Charged to the decode side of `e2e`, not to TTFT,
    /// when the first token is produced prefill-side.
    pub kv_transfer: f64,
    /// Residual of TTFT over the waits: execution plus in-batch
    /// contention until the first token.
    pub compute: f64,
    /// Residual of end-to-end latency over TTFT + kv_transfer: the
    /// decode phase (0 for incomplete requests).
    pub decode: f64,
    /// Whether `completed_at` existed (decode/e2e are meaningful).
    pub completed: bool,
    /// Preemptions this request suffered (TBT stall attribution).
    pub preemptions: usize,
    /// Largest token gap (the TBT that goodput SLOs check).
    pub max_tbt: f64,
    /// Output tokens budgeted (normalized-latency denominator).
    pub decode_len: usize,
}

impl LatencyBreakdown {
    /// The conserved TTFT: the measured first-token latency, bitwise.
    pub fn total_ttft(&self) -> f64 {
        self.ttft
    }

    /// The conserved end-to-end latency (arrival → completion).
    pub fn total_e2e(&self) -> f64 {
        self.e2e
    }

    /// The component re-sum in fixed left-to-right order — bitwise
    /// equal to [`total_ttft`](Self::total_ttft) except on
    /// round-to-even ties, where it is within one ULP.
    pub fn resummed_ttft(&self) -> f64 {
        (((self.queue_wait + self.prefix_wait) + self.swap) + self.kv_transfer) + self.compute
    }

    /// Component re-sum of e2e (`resummed_ttft + decode`); same
    /// one-ULP tie caveat as [`resummed_ttft`](Self::resummed_ttft).
    pub fn resummed_e2e(&self) -> f64 {
        self.resummed_ttft() + self.decode
    }

    /// Normalized latency from the conserved e2e — bitwise equal to
    /// the report's `(completed_at − arrival) / decode_len` because
    /// the numerators are bitwise equal.
    pub fn normalized(&self) -> f64 {
        self.total_e2e() / self.decode_len.max(1) as f64
    }

    /// Coarse cause for this request's worst token gap.
    pub fn stall_cause(&self) -> &'static str {
        if self.preemptions > 0 {
            "preemption"
        } else {
            "contention"
        }
    }

    /// Build the decomposition for one request from its pool-tracked
    /// accumulators. `swap_cost` prices the pre-first-token swap-in
    /// tokens; `kv_transfer` is the driver-level handoff latency
    /// (disaggregation) and 0 elsewhere. Returns `None` for requests
    /// that never produced a first token.
    pub fn for_request(
        r: &super::request::Request,
        swap_cost: &SwapCost,
        kv_transfer: f64,
    ) -> Option<Self> {
        let first = r.first_token_at?;
        let ttft = first - r.arrival;
        let prefix_wait = r.prefix_wait_time.min(r.queue_wait);
        let queue_wait = (r.queue_wait - prefix_wait).max(0.0);
        let swap = swap_cost.swap_in_time(r.swapped_in_tokens_pre_first);
        // disaggregation stitches the first token prefill-side, so the
        // handoff belongs to the decode phase of e2e, never to TTFT
        let partial = ((queue_wait + prefix_wait) + swap) + 0.0;
        let compute = conserved_residual(ttft, partial);
        let mut bd = LatencyBreakdown {
            request: r.id,
            arrival: r.arrival,
            ttft,
            e2e: ttft,
            queue_wait,
            prefix_wait,
            swap,
            kv_transfer,
            compute,
            decode: 0.0,
            completed: false,
            preemptions: r.preemptions,
            max_tbt: r.max_tbt,
            decode_len: r.spec.decode_len,
        };
        // fold the handoff into the TTFT re-sum chain: compute was
        // made the residual of (partial + 0.0); re-derive it against
        // the 4-term partial including kv_transfer so resummed_ttft()
        // still reproduces ttft (bitwise, modulo rounding ties)
        if kv_transfer != 0.0 {
            let partial4 = ((queue_wait + prefix_wait) + swap) + kv_transfer;
            bd.compute = conserved_residual(ttft, partial4);
        }
        if let Some(done) = r.completed_at {
            let e2e = done - r.arrival;
            bd.e2e = e2e;
            bd.decode = conserved_residual(e2e, bd.resummed_ttft());
            bd.completed = true;
        }
        Some(bd)
    }

    /// Re-stitch a prefill-side breakdown with the disaggregation
    /// handoff: fold `kv_transfer` into the TTFT re-sum chain
    /// (`compute` re-derived against the measured first-token latency)
    /// and re-derive `decode` against the DECODE-side completion — the
    /// prefill copy's own completion is just its first token.
    pub fn with_handoff(mut self, kv_transfer: f64, completed_at: Option<f64>) -> Self {
        let ttft = self.ttft;
        self.kv_transfer = kv_transfer;
        let partial = ((self.queue_wait + self.prefix_wait) + self.swap) + self.kv_transfer;
        self.compute = conserved_residual(ttft, partial);
        self.completed = false;
        self.decode = 0.0;
        self.e2e = ttft;
        if let Some(done) = completed_at {
            let e2e = done - self.arrival;
            self.e2e = e2e;
            self.decode = conserved_residual(e2e, self.resummed_ttft());
            self.completed = true;
        }
        self
    }

    /// One JSON-Lines record (`"request"`-tagged so iteration records
    /// and transfer records coexist in the same trace).
    pub fn to_jsonl(&self) -> String {
        format!(
            "{{\"request\":{{\"id\":{},\"arrival\":{:.6},\"ttft\":{:.9},\
             \"queue_wait\":{:.9},\"prefix_wait\":{:.9},\"swap\":{:.9},\
             \"kv_transfer\":{:.9},\"compute\":{:.9},\"decode\":{:.9},\
             \"e2e\":{:.9},\"normalized\":{:.9},\"completed\":{},\
             \"preemptions\":{},\"max_tbt\":{:.9},\"stall_cause\":\"{}\",\
             \"schema_version\":{}}}}}",
            self.request,
            self.arrival,
            self.total_ttft(),
            self.queue_wait,
            self.prefix_wait,
            self.swap,
            self.kv_transfer,
            self.compute,
            self.decode,
            self.total_e2e(),
            self.normalized(),
            self.completed,
            self.preemptions,
            self.max_tbt,
            self.stall_cause(),
            crate::coordinator::metrics::JSONL_SCHEMA_VERSION,
        )
    }
}

/// Decompositions for every first-token request across `pools`
/// (pool/emission order). `kv_transfer` looks up the per-request
/// handoff latency by request id (None ⇒ 0 everywhere).
pub fn breakdowns_from_pools(
    pools: &[RequestPool],
    swap_cost: &SwapCost,
    kv_transfer: Option<&dyn Fn(RequestId) -> f64>,
) -> Vec<LatencyBreakdown> {
    let mut out = Vec::new();
    for p in pools {
        for r in p.iter() {
            let kt = kv_transfer.map_or(0.0, |f| f(r.id));
            if let Some(bd) = LatencyBreakdown::for_request(r, swap_cost, kt) {
                out.push(bd);
            }
        }
    }
    out
}

/// Mean-of-components summary line for the report (over `n` requests).
pub fn breakdown_summary(bds: &[LatencyBreakdown]) -> String {
    if bds.is_empty() {
        return "ttft decomposition: (no first tokens)".to_string();
    }
    let n = bds.len() as f64;
    let mean = |f: &dyn Fn(&LatencyBreakdown) -> f64| bds.iter().map(|b| f(b)).sum::<f64>() / n;
    format!(
        "ttft decomposition (mean over {} requests): queue_wait={:.4}s prefix_wait={:.4}s \
         swap={:.4}s kv_transfer={:.4}s compute={:.4}s | decode={:.4}s stalls(preempt={} \
         contention={})",
        bds.len(),
        mean(&|b| b.queue_wait),
        mean(&|b| b.prefix_wait),
        mean(&|b| b.swap),
        mean(&|b| b.kv_transfer),
        mean(&|b| b.compute),
        mean(&|b| b.decode),
        bds.iter().filter(|b| b.preemptions > 0).count(),
        bds.iter().filter(|b| b.preemptions == 0).count(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::RequestSpec;

    #[test]
    fn disabled_sink_is_inert_and_costless() {
        let mut s = TraceSink::default();
        assert!(!s.is_enabled());
        s.emit(1.0, EventKind::Arrived { request: 0 });
        assert_eq!(s.len(), 0);
        assert_eq!(s.emitted(), 0);
        assert_eq!(s.high_water(), 0);
        assert!(s.drain().is_empty());
    }

    #[test]
    fn enabled_sink_buffers_counts_and_drains() {
        let mut s = TraceSink::enabled(8);
        s.set_identity(2, 1);
        s.emit(0.5, EventKind::Arrived { request: 3 });
        s.emit_on(0.7, 4, EventKind::FirstToken { request: 3 });
        assert_eq!(s.len(), 2);
        assert_eq!(s.emitted(), 2);
        assert_eq!(s.high_water(), 2);
        let evs = s.drain();
        assert_eq!(evs.len(), 2);
        assert_eq!((evs[0].replica, evs[0].lane, evs[0].seq), (2, 1, 0));
        assert_eq!((evs[1].replica, evs[1].lane, evs[1].seq), (2, 4, 1));
        assert_eq!(s.len(), 0, "drain empties the ring");
        assert_eq!(s.emitted(), 2, "counters survive the drain");
        s.emit(1.0, EventKind::Completed { request: 3 });
        assert_eq!(s.drain()[0].seq, 2, "seq keeps counting across drains");
    }

    #[test]
    fn overflow_drops_newest_and_counts() {
        let mut s = TraceSink::enabled(2);
        for i in 0..5 {
            s.emit(i as f64, EventKind::TokenEmitted { request: 0 });
        }
        assert_eq!(s.len(), 2);
        assert_eq!(s.emitted(), 5);
        assert_eq!(s.dropped(), 3);
        assert_eq!(s.high_water(), 2);
        let evs = s.drain();
        assert_eq!(evs[0].at, 0.0, "oldest events are the ones kept");
        assert_eq!(evs[1].at, 1.0);
    }

    #[test]
    fn merge_is_canonical_over_time_replica_lane_seq() {
        let a = vec![
            TraceEvent { at: 1.0, replica: 1, lane: 0, seq: 0, kind: EventKind::Arrived { request: 0 } },
            TraceEvent { at: 2.0, replica: 1, lane: 0, seq: 1, kind: EventKind::Completed { request: 0 } },
        ];
        let b = vec![
            TraceEvent { at: 1.0, replica: 0, lane: 0, seq: 0, kind: EventKind::Arrived { request: 1 } },
            TraceEvent { at: 1.5, replica: 0, lane: 0, seq: 1, kind: EventKind::FirstToken { request: 1 } },
        ];
        let m1 = merge_streams(vec![a.clone(), b.clone()]);
        let m2 = merge_streams(vec![b, a]);
        assert_eq!(m1, m2, "merge order is independent of stream order");
        assert_eq!(m1[0].replica, 0, "replica breaks the time tie");
        assert_eq!(m1[1].replica, 1);
    }

    fn request_with(first: f64, done: Option<f64>) -> crate::coordinator::request::Request {
        let spec = RequestSpec { prompt_len: 64, decode_len: 8, arrival: 0.125, prefix: None };
        let mut r = crate::coordinator::request::Request::new(7, spec);
        r.first_token_at = Some(first);
        r.completed_at = done;
        r.queue_wait = 0.0625;
        r.prefix_wait_time = 0.03125;
        r.queue_wait += r.prefix_wait_time;
        r
    }

    #[test]
    fn breakdown_conserves_ttft_and_e2e_bitwise() {
        let r = request_with(1.0471975511965976, Some(3.141592653589793));
        let bd = LatencyBreakdown::for_request(&r, &SwapCost::free(), 0.0).unwrap();
        let ttft = r.first_token_at.unwrap() - r.arrival;
        let e2e = r.completed_at.unwrap() - r.arrival;
        assert_eq!(bd.total_ttft().to_bits(), ttft.to_bits());
        assert_eq!(bd.total_e2e().to_bits(), e2e.to_bits());
        // these magnitudes avoid the round-to-even tie, so the
        // component re-sum reproduces the measured values bitwise too
        assert_eq!(bd.resummed_ttft().to_bits(), ttft.to_bits());
        assert_eq!(bd.resummed_e2e().to_bits(), e2e.to_bits());
        let norm = e2e / r.spec.decode_len as f64;
        assert_eq!(bd.normalized().to_bits(), norm.to_bits());
        assert!(bd.queue_wait > 0.0 && bd.prefix_wait > 0.0);
        assert!(bd.compute > 0.0 && bd.decode > 0.0);
    }

    #[test]
    fn breakdown_conserves_with_kv_transfer_component() {
        let r = request_with(0.7071067811865476, Some(2.718281828459045));
        let bd = LatencyBreakdown::for_request(&r, &SwapCost::free(), 0.2).unwrap();
        let ttft = r.first_token_at.unwrap() - r.arrival;
        let e2e = r.completed_at.unwrap() - r.arrival;
        assert_eq!(bd.total_ttft().to_bits(), ttft.to_bits());
        assert_eq!(bd.total_e2e().to_bits(), e2e.to_bits());
        assert_eq!(bd.resummed_ttft().to_bits(), ttft.to_bits());
        assert_eq!(bd.resummed_e2e().to_bits(), e2e.to_bits());
        assert_eq!(bd.kv_transfer, 0.2);
    }

    #[test]
    fn conserved_residual_survives_awkward_magnitudes() {
        for (target, partial) in [
            (1e-9, 1e-9 * 0.3),
            (12345.678901234567, 0.000012345),
            (0.0, 0.0),
        ] {
            let r = conserved_residual(target, partial);
            assert_eq!((partial + r).to_bits(), target.to_bits(), "target={target}");
        }
    }

    #[test]
    fn round_to_even_ties_cap_the_resum_error_at_one_ulp() {
        // `partial` sits exactly half an ULP(target) off target's
        // grid, so every candidate sum is a round-to-even tie landing
        // on even parity — `target` (odd last mantissa bit) is NOT
        // representable as fl(partial + r) for ANY r. The fallback
        // must stay within one ULP; this is why the breakdown carries
        // the measured totals rather than relying on the re-sum.
        for (target, partial) in [
            (1.0 + f64::EPSILON, f64::EPSILON / 2.0),
            (7.903759123055942, 3.6126524462651655),
        ] {
            let r = conserved_residual(target, partial);
            let resum = partial + r;
            assert_ne!(resum.to_bits(), target.to_bits(), "tie case became reachable");
            let ulp = next_up(target) - target;
            assert!((resum - target).abs() <= ulp, "fallback drifted past one ULP");
        }
    }

    #[test]
    fn breakdown_jsonl_has_every_field_and_the_schema_version() {
        let r = request_with(1.0, Some(2.0));
        let bd = LatencyBreakdown::for_request(&r, &SwapCost::free(), 0.0).unwrap();
        let line = bd.to_jsonl();
        for field in [
            "\"id\":7",
            "\"ttft\":",
            "\"queue_wait\":",
            "\"prefix_wait\":",
            "\"swap\":",
            "\"kv_transfer\":",
            "\"compute\":",
            "\"decode\":",
            "\"e2e\":",
            "\"normalized\":",
            "\"stall_cause\":\"contention\"",
            "\"schema_version\":",
        ] {
            assert!(line.contains(field), "missing {field} in {line}");
        }
        assert!(line.starts_with("{\"request\":{\"id\":7,"));
        assert!(line.ends_with("}}"));
    }

    #[test]
    fn incomplete_requests_decompose_ttft_only() {
        let r = request_with(1.0, None);
        let bd = LatencyBreakdown::for_request(&r, &SwapCost::free(), 0.0).unwrap();
        assert!(!bd.completed);
        assert_eq!(bd.decode, 0.0);
        assert_eq!(bd.total_e2e().to_bits(), bd.total_ttft().to_bits());
        let mut r2 = r;
        r2.first_token_at = None;
        assert!(LatencyBreakdown::for_request(&r2, &SwapCost::free(), 0.0).is_none());
    }
}
