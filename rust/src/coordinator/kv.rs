//! Token-granular paged KV-cache block allocator.
//!
//! The seed reserved one whole-request *slot* per admitted request, sized
//! for the worst-case sequence length (§4.3.1) — which caps concurrency at
//! `B = M / (L_max · m_kv)` even when actual sequences are far shorter.
//! This module replaces slots with fixed-size **blocks** of `block_size`
//! tokens (vLLM-style paging): a request holds a growing block table,
//! blocks are allocated as its KV actually grows (chunked prefill, then one
//! token per decode), and released on completion or preemption.
//!
//! The old slot semantics are the degenerate case `block_size =
//! DEGENERATE_BLOCK` (one block covers any sequence): [`KvManager::new`]
//! builds exactly that, so every seed experiment reproduces unchanged.
//!
//! Invariants (enforced with loud panics, exercised by
//! `tests/kv_properties.rs`):
//! * a block is held by at most one owner at a time,
//! * `allocated() + available() == capacity()` always,
//! * releasing a free block (double free) panics.

/// Block size that makes one block cover any sequence — the seed's
/// whole-request slot semantics.
pub const DEGENERATE_BLOCK: usize = usize::MAX;

#[derive(Clone, Debug)]
pub struct KvManager {
    /// Tokens per block.
    block_size: usize,
    /// Total blocks in the pool.
    num_blocks: usize,
    /// Free block ids (stack; lowest ids on top).
    free: Vec<usize>,
    /// in_use[block] = true while allocated.
    in_use: Vec<bool>,
}

impl KvManager {
    /// Degenerate (seed-compatible) pool: `capacity` whole-request slots,
    /// i.e. blocks big enough that any sequence needs exactly one.
    pub fn new(capacity: usize) -> Self {
        Self::paged(capacity, DEGENERATE_BLOCK)
    }

    /// Paged pool: `num_blocks` blocks of `block_size` tokens each.
    pub fn paged(num_blocks: usize, block_size: usize) -> Self {
        assert!(block_size > 0, "block size must be positive");
        KvManager {
            block_size,
            num_blocks,
            free: (0..num_blocks).rev().collect(),
            in_use: vec![false; num_blocks],
        }
    }

    /// Total blocks in the pool.
    pub fn capacity(&self) -> usize {
        self.num_blocks
    }

    /// Total token capacity of the pool (saturating in degenerate mode).
    pub fn capacity_tokens(&self) -> usize {
        self.num_blocks.saturating_mul(self.block_size)
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn available(&self) -> usize {
        self.free.len()
    }

    pub fn allocated(&self) -> usize {
        self.num_blocks - self.free.len()
    }

    /// Blocks required to hold `tokens` KV entries (0 for 0 tokens;
    /// overflow-safe for the degenerate block size).
    pub fn blocks_needed(&self, tokens: usize) -> usize {
        if tokens == 0 {
            0
        } else {
            1 + (tokens - 1) / self.block_size
        }
    }

    /// Allocate one block, lowest-index first.
    pub fn alloc(&mut self) -> Option<usize> {
        let block = self.free.pop()?;
        debug_assert!(!self.in_use[block]);
        self.in_use[block] = true;
        Some(block)
    }

    /// Allocate `n` blocks all-or-nothing.
    pub fn alloc_n(&mut self, n: usize) -> Option<Vec<usize>> {
        if self.free.len() < n {
            return None;
        }
        Some((0..n).map(|_| self.alloc().expect("checked free count")).collect())
    }

    /// Grow `blocks` until it covers `tokens` KV entries. All-or-nothing:
    /// on failure the table is left untouched and `false` is returned.
    pub fn extend_to(&mut self, blocks: &mut Vec<usize>, tokens: usize) -> bool {
        let need = self.blocks_needed(tokens);
        if blocks.len() >= need {
            return true;
        }
        match self.alloc_n(need - blocks.len()) {
            Some(more) => {
                blocks.extend(more);
                true
            }
            None => false,
        }
    }

    /// Release one block. Panics on double-free — that is a scheduler bug
    /// we want loud.
    pub fn release(&mut self, block: usize) {
        assert!(self.in_use[block], "double free of KV block {block}");
        self.in_use[block] = false;
        self.free.push(block);
    }

    /// Release a whole block table (completion or preemption).
    pub fn release_seq(&mut self, blocks: Vec<usize>) {
        for b in blocks {
            self.release(b);
        }
    }

    pub fn is_allocated(&self, block: usize) -> bool {
        self.in_use[block]
    }

    /// True for the seed-compatible whole-request-slot layout.
    pub fn is_degenerate(&self) -> bool {
        self.block_size == DEGENERATE_BLOCK
    }

    /// Internal fragmentation: tokens of allocated-but-unused capacity,
    /// given the number of live KV tokens across all owners. Reports 0 in
    /// degenerate mode — the sentinel block size is nominal, not memory.
    pub fn internal_fragmentation(&self, live_tokens: usize) -> usize {
        if self.is_degenerate() {
            return 0;
        }
        self.allocated().saturating_mul(self.block_size).saturating_sub(live_tokens)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_cycle() {
        let mut kv = KvManager::new(3);
        assert_eq!(kv.available(), 3);
        let a = kv.alloc().unwrap();
        let b = kv.alloc().unwrap();
        let c = kv.alloc().unwrap();
        assert_ne!(a, b);
        assert_ne!(b, c);
        assert!(kv.alloc().is_none());
        kv.release(b);
        assert_eq!(kv.available(), 1);
        assert_eq!(kv.alloc(), Some(b));
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut kv = KvManager::new(2);
        let a = kv.alloc().unwrap();
        kv.release(a);
        kv.release(a);
    }

    #[test]
    fn lowest_index_first() {
        let mut kv = KvManager::new(4);
        assert_eq!(kv.alloc(), Some(0));
        assert_eq!(kv.alloc(), Some(1));
    }

    #[test]
    fn degenerate_needs_one_block_for_any_length() {
        let kv = KvManager::new(4);
        assert_eq!(kv.blocks_needed(0), 0);
        assert_eq!(kv.blocks_needed(1), 1);
        assert_eq!(kv.blocks_needed(1_000_000), 1);
    }

    #[test]
    fn paged_block_arithmetic() {
        let kv = KvManager::paged(8, 16);
        assert_eq!(kv.blocks_needed(0), 0);
        assert_eq!(kv.blocks_needed(1), 1);
        assert_eq!(kv.blocks_needed(16), 1);
        assert_eq!(kv.blocks_needed(17), 2);
        assert_eq!(kv.blocks_needed(128), 8);
        assert_eq!(kv.capacity_tokens(), 128);
    }

    #[test]
    fn alloc_n_is_all_or_nothing() {
        let mut kv = KvManager::paged(4, 16);
        let got = kv.alloc_n(3).unwrap();
        assert_eq!(got.len(), 3);
        assert_eq!(kv.available(), 1);
        assert!(kv.alloc_n(2).is_none());
        assert_eq!(kv.available(), 1, "failed alloc must not leak");
        kv.release_seq(got);
        assert_eq!(kv.available(), 4);
    }

    #[test]
    fn extend_grows_table_token_granularly() {
        let mut kv = KvManager::paged(4, 16);
        let mut table = Vec::new();
        assert!(kv.extend_to(&mut table, 10));
        assert_eq!(table.len(), 1);
        assert!(kv.extend_to(&mut table, 16)); // still fits the block
        assert_eq!(table.len(), 1);
        assert!(kv.extend_to(&mut table, 17)); // crosses a block boundary
        assert_eq!(table.len(), 2);
        assert!(kv.extend_to(&mut table, 64)); // grows to the whole pool
        assert_eq!(table.len(), 4);
        assert!(!kv.extend_to(&mut table, 65), "over capacity must fail");
        assert_eq!(table.len(), 4, "failed extend must not change the table");
        kv.release_seq(table);
        assert_eq!(kv.available(), 4);
    }

    #[test]
    fn fragmentation_accounting() {
        let mut kv = KvManager::paged(8, 16);
        let mut table = Vec::new();
        assert!(kv.extend_to(&mut table, 20)); // 2 blocks = 32 tokens for 20 live
        assert_eq!(kv.internal_fragmentation(20), 12);
        assert!(kv.extend_to(&mut table, 32));
        assert_eq!(kv.internal_fragmentation(32), 0);
        kv.release_seq(table);
        // degenerate slots are nominal reservations, not wasted memory
        let kv = KvManager::new(2);
        assert!(kv.is_degenerate());
        assert_eq!(kv.internal_fragmentation(100), 0);
    }
}
