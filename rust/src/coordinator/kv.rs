//! Token-granular paged KV-cache block allocator with ref-counted,
//! copy-on-write prefix sharing over a **radix tree of token blocks**.
//!
//! The seed reserved one whole-request *slot* per admitted request, sized
//! for the worst-case sequence length (§4.3.1) — which caps concurrency at
//! `B = M / (L_max · m_kv)` even when actual sequences are far shorter.
//! This module replaces slots with fixed-size **blocks** of `block_size`
//! tokens (vLLM-style paging): a request holds a growing block table,
//! blocks are allocated as its KV actually grows (chunked prefill, then one
//! token per decode), and released on completion or preemption.
//!
//! On top of paging, blocks are **ref-counted** so identical prompt
//! prefixes (shared system prompts, few-shot templates) can be shared
//! across requests instead of paying for their KV once per sharer
//! (PagedAttention §4.3, arXiv 2309.06180):
//!
//! * [`share_seq`](KvManager::share_seq) hands a second (third, ...)
//!   reference to an existing block run; `release` decrements and only
//!   frees at zero, so preempting or completing one sharer can never free
//!   blocks another sharer still reads.
//! * [`fork_block`](KvManager::fork_block) is the copy-on-write edge: a
//!   sharer that must *append into* a partially-filled shared block gets a
//!   private copy; the shared original is never mutated while its
//!   refcount exceeds one.
//!
//! The prefix index itself is no longer a flat `hash → whole block-run`
//! map. It is a **radix tree** (SGLang RadixAttention-style, arXiv
//! 2312.07104) whose nodes own block-aligned runs:
//!
//! * Each [`PrefixNode`] covers a contiguous token span `[start,
//!   start+tokens)`; its `path` holds the *cumulative per-block content
//!   hash* at every full block boundary it covers. Because the hashes are
//!   cumulative, a path entry identifies the entire token prefix up to
//!   that block — two requests agreeing on entry `k` agree on all
//!   `(k+1)·block_size` leading tokens.
//! * [`register_prefix`](KvManager::register_prefix) (whole-template,
//!   `{id,len}` form) lowers to a single-node tree via a
//!   [`derived_path`]; re-registration is an idempotent no-op instead of
//!   an assertion. [`register_path_prefix`](KvManager::register_path_prefix)
//!   attaches a new tail under the deepest resident match, **splitting**
//!   an existing node when the divergence point falls inside it.
//! * [`lookup_path_match`](KvManager::lookup_path_match) returns the
//!   **longest resident match** of a request's content path: the
//!   contiguous-from-root READY coverage (servable now) plus the total
//!   attach depth (registered, possibly still filling). Partial overlaps
//!   between templates — shared system prompt, divergent few-shot tails,
//!   multi-turn conversation extensions — share KV proportionally to
//!   their common path instead of all-or-nothing.
//! * Readiness, fill progress and stall events are **per node**: a node
//!   registers unready and becomes servable when the registrant's prefill
//!   crosses its covered blocks ([`mark_prefix_ready`]
//!   (KvManager::mark_prefix_ready) readies a whole chain; interior
//!   nodes auto-ready when a fill note covers them completely). Filling
//!   pin-shared blocks in place is the one sanctioned write to a block
//!   with refcount > 1, safe because the readiness gate keeps every
//!   reader out until the fill completes.
//! * LRU reclaim evicts cold **subtrees leaf-first**: a node is a victim
//!   only when it has no live children and no sharer besides the index
//!   pin on any of its own blocks — a node with live descendants or
//!   sharers is never reclaimed. Evicting a leaf exposes its parent as a
//!   candidate for the next round, so cold subtrees drain bottom-up.
//! * [`residency_digest`](KvManager::residency_digest) summarizes the
//!   READY tree as a bounded set of `(cumulative hash, token depth)`
//!   entries, deepest-first — the router's view of what is *actually*
//!   resident on a replica ([`ResidencyDigest::coverage`] scores a
//!   request path against it).
//!
//! The old slot semantics are the degenerate case `block_size =
//! DEGENERATE_BLOCK` (one block covers any sequence): [`KvManager::new`]
//! builds exactly that, so every seed experiment reproduces unchanged.
//! Prefix sharing is meaningless there (one block holds private tokens
//! too), so all lookups miss on degenerate pools.
//!
//! Invariants (enforced with loud panics, exercised by
//! `tests/kv_properties.rs` and `tests/prefix_properties.rs`; see
//! [`assert_radix_invariants`](KvManager::assert_radix_invariants)):
//! * a block's refcount equals its holders (request tables + node pins),
//! * `allocated() + available() == capacity()` always,
//! * node block runs are disjoint; children attach only at a parent's
//!   full-block end; a node with a partial tail block is childless,
//! * releasing a free block (double free) panics,
//! * `fork_block` never hands out a block whose refcount exceeds one.

use crate::util::mix64;

/// Block size that makes one block cover any sequence — the seed's
/// whole-request slot semantics.
pub const DEGENERATE_BLOCK: usize = usize::MAX;

/// Entries a [`ResidencyDigest`] can carry. Chosen so a digest stays one
/// cache line-ish and copies freely through dispatch barriers; deepest
/// entries win the cut because they encode the largest shareable spans.
pub const DIGEST_CAP: usize = 16;

const GOLDEN: u64 = 0x9E37_79B9_7F4A_7C15;

/// Synthetic content path for a whole-template `{id, len}` prefix spec:
/// a deterministic hash chain seeded by the template hash. Nested by
/// construction — `derived_path(h, a)` is a prefix of `derived_path(h, b)`
/// for `a <= b` — so the `{id,len}` form lowers to a single-path radix
/// tree and the router can score template requests against digests
/// without a real content path.
pub fn derived_path(hash: u64, blocks: usize) -> Vec<u64> {
    let mut h = hash;
    (0..blocks)
        .map(|_| {
            h = mix64(h ^ GOLDEN);
            h
        })
        .collect()
}

/// One block-aligned span of a resident prefix chain.
#[derive(Clone, Debug)]
struct PrefixNode {
    /// Cumulative content hash at each full block boundary this node
    /// covers, in order: `path[k]` identifies tokens
    /// `[0, start + (k+1)·block_size)`. `path.len() == tokens /
    /// block_size`; a partial tail block has a `blocks` entry but no path
    /// entry.
    path: Vec<u64>,
    /// The owned block run, table order; the last block may be partial.
    /// Every block carries one index-owned reference (the pin).
    blocks: Vec<usize>,
    /// Token offset where this node's span begins (block-aligned; equals
    /// the parent chain's full-block token count).
    start: usize,
    /// Tokens this node covers from `start`.
    tokens: usize,
    parent: Option<usize>,
    children: Vec<usize>,
    /// False until the registrant's prefill has actually computed the
    /// covered tokens. Hits gate on this: KV that has not been produced
    /// yet cannot serve anyone — registration only reserves and indexes.
    ready: bool,
    /// Tokens of this node's span the filler has computed so far
    /// (node-relative). Waiters compare the chain total across admission
    /// attempts: a fill that stops advancing means the registrant
    /// stalled, and bounded prefix-waits degrade the waiter to the
    /// deepest ready match instead of blocking forever.
    filled: usize,
    /// Bumped whenever the request filling this span is preempted
    /// mid-fill — waiters count the bump as an immediate stall tick even
    /// if the fill also advanced in the same interval.
    stall_events: u64,
    /// LRU stamp: the allocator's logical clock at registration and at
    /// every servable hit. Cold-subtree reclaim evicts the smallest
    /// stamp first, leaf-first.
    last_touch: u64,
}

impl PrefixNode {
    /// True when the node ends on a partial block — such nodes are
    /// terminal content and never take children.
    fn has_partial_tail(&self, block_size: usize) -> bool {
        self.tokens > self.path.len() * block_size
    }
}

/// Longest resident match of a content path against the radix tree.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct PathMatch {
    /// Tokens servable RIGHT NOW: the contiguous-from-root span of READY
    /// matched blocks. A sharer can skip exactly these.
    pub ready_tokens: usize,
    /// The block run backing `ready_tokens` (all full blocks, table
    /// order) — what a sharer's table starts from.
    pub ready_run: Vec<usize>,
    /// Total matched depth in tokens, ready or not. `attach_tokens >
    /// ready_tokens` means the frontier node is still being filled by its
    /// registrant (a wait candidate); extensions registered past
    /// `attach_tokens` grow the tree.
    pub attach_tokens: usize,
}

/// A replica's resident-prefix summary for the router: up to
/// [`DIGEST_CAP`] `(cumulative block hash, token depth)` entries drawn
/// from the READY tree, deepest-first. `Copy` so dispatch barriers can
/// refresh per-replica views without allocation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ResidencyDigest {
    len: u8,
    entries: [(u64, u32); DIGEST_CAP],
}

impl Default for ResidencyDigest {
    fn default() -> Self {
        ResidencyDigest { len: 0, entries: [(0, 0); DIGEST_CAP] }
    }
}

impl ResidencyDigest {
    pub fn entries(&self) -> &[(u64, u32)] {
        &self.entries[..self.len as usize]
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Deepest token depth at which any digest entry appears in `path` —
    /// the replica demonstrably holds (at least) that many of the
    /// request's leading tokens ready. 0 when nothing matches. Because
    /// path entries are cumulative content hashes, one matching entry
    /// certifies the whole token prefix below it.
    pub fn coverage(&self, path: &[u64]) -> u32 {
        let mut best = 0u32;
        for &(h, depth) in self.entries() {
            if depth > best && path.contains(&h) {
                best = depth;
            }
        }
        best
    }

    /// Build a digest from explicit `(hash, depth)` entries (router tests
    /// and adapters; truncates at [`DIGEST_CAP`]).
    pub fn from_entries(entries: &[(u64, u32)]) -> Self {
        let mut d = ResidencyDigest::default();
        for &e in entries.iter().take(DIGEST_CAP) {
            d.entries[d.len as usize] = e;
            d.len += 1;
        }
        d
    }
}

#[derive(Clone, Debug)]
pub struct KvManager {
    /// Tokens per block.
    block_size: usize,
    /// Total blocks in the pool.
    num_blocks: usize,
    /// Free block ids (stack; lowest ids on top).
    free: Vec<usize>,
    /// ref_count[block] = live references (request tables + node pins);
    /// 0 while free.
    ref_count: Vec<u32>,
    /// Radix-node slab; `None` slots are free (recycled via
    /// `free_nodes`). Few templates are live at once, so linear scans
    /// beat maps here — the tree bounds *matching* work, not slab walks.
    nodes: Vec<Option<PrefixNode>>,
    free_nodes: Vec<usize>,
    /// Tree roots (nodes with `start == 0`), registration order.
    roots: Vec<usize>,
    /// `hash → terminal node` of each registered prefix: the chain from
    /// a root to the terminal covers exactly that prefix's tokens.
    by_hash: Vec<(u64, usize)>,
    /// Logical clock for the LRU stamps.
    touch_clock: u64,
}

impl KvManager {
    /// Degenerate (seed-compatible) pool: `capacity` whole-request slots,
    /// i.e. blocks big enough that any sequence needs exactly one.
    pub fn new(capacity: usize) -> Self {
        Self::paged(capacity, DEGENERATE_BLOCK)
    }

    /// Paged pool: `num_blocks` blocks of `block_size` tokens each.
    pub fn paged(num_blocks: usize, block_size: usize) -> Self {
        assert!(block_size > 0, "block size must be positive");
        KvManager {
            block_size,
            num_blocks,
            free: (0..num_blocks).rev().collect(),
            ref_count: vec![0; num_blocks],
            nodes: Vec::new(),
            free_nodes: Vec::new(),
            roots: Vec::new(),
            by_hash: Vec::new(),
            touch_clock: 0,
        }
    }

    /// Total blocks in the pool.
    pub fn capacity(&self) -> usize {
        self.num_blocks
    }

    /// Total token capacity of the pool (saturating in degenerate mode).
    pub fn capacity_tokens(&self) -> usize {
        self.num_blocks.saturating_mul(self.block_size)
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn available(&self) -> usize {
        self.free.len()
    }

    /// Allocated blocks — each counted ONCE no matter how many sharers
    /// reference it (`allocated() + available() == capacity()`).
    pub fn allocated(&self) -> usize {
        self.num_blocks - self.free.len()
    }

    /// Blocks required to hold `tokens` KV entries (0 for 0 tokens;
    /// overflow-safe for the degenerate block size).
    pub fn blocks_needed(&self, tokens: usize) -> usize {
        if tokens == 0 {
            0
        } else {
            1 + (tokens - 1) / self.block_size
        }
    }

    // ---- node slab plumbing -------------------------------------------

    fn node(&self, i: usize) -> &PrefixNode {
        self.nodes[i].as_ref().expect("dead radix node")
    }

    fn node_mut(&mut self, i: usize) -> &mut PrefixNode {
        self.nodes[i].as_mut().expect("dead radix node")
    }

    fn alloc_node(&mut self, n: PrefixNode) -> usize {
        if let Some(i) = self.free_nodes.pop() {
            self.nodes[i] = Some(n);
            i
        } else {
            self.nodes.push(Some(n));
            self.nodes.len() - 1
        }
    }

    fn live_nodes(&self) -> impl Iterator<Item = usize> + '_ {
        (0..self.nodes.len()).filter(|&i| self.nodes[i].is_some())
    }

    fn hash_node(&self, hash: u64) -> Option<usize> {
        self.by_hash.iter().find(|&&(h, _)| h == hash).map(|&(_, i)| i)
    }

    /// True when some registered hash terminates at node `i` — terminal
    /// nodes never auto-ready on fill (the explicit
    /// [`mark_prefix_ready`](Self::mark_prefix_ready) from the state
    /// transition is what flips a whole registration servable, exactly as
    /// the flat index behaved).
    fn is_terminal(&self, i: usize) -> bool {
        self.by_hash.iter().any(|&(_, t)| t == i)
    }

    /// Root-first chain of nodes ending at `i`.
    fn chain_of(&self, i: usize) -> Vec<usize> {
        let mut chain = vec![i];
        let mut cur = i;
        while let Some(p) = self.node(cur).parent {
            chain.push(p);
            cur = p;
        }
        chain.reverse();
        chain
    }

    /// Unlink node `i` from its parent/roots and free its slab slot. The
    /// caller has already dealt with its blocks and children.
    fn detach_node(&mut self, i: usize) {
        match self.node(i).parent {
            Some(p) => self.node_mut(p).children.retain(|&c| c != i),
            None => self.roots.retain(|&r| r != i),
        }
        self.nodes[i] = None;
        self.free_nodes.push(i);
    }

    /// Walk `path` from the roots: the matched chain as `(node,
    /// matched path entries)`, root-first. Stops at the first divergence,
    /// exhausted path, or partial-tail node.
    fn walk_path(&self, path: &[u64]) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        let mut pos = 0usize;
        let mut cands: &[usize] = &self.roots;
        while pos < path.len() {
            let Some(&next) =
                cands.iter().find(|&&i| self.node(i).path.first() == Some(&path[pos]))
            else {
                break;
            };
            let n = self.node(next);
            let m = n
                .path
                .iter()
                .zip(path[pos..].iter())
                .take_while(|(a, b)| a == b)
                .count();
            out.push((next, m));
            pos += m;
            if m < n.path.len() {
                break;
            }
            cands = &n.children;
        }
        out
    }

    /// Split node `i` so it ends exactly at `m` full blocks, returning
    /// the head (= `i`). The remainder — later path entries and/or the
    /// partial tail — moves to a fresh child that inherits `i`'s
    /// children, terminal mappings, unfilled progress and stall events.
    /// No-op when `i` already ends at `m` full blocks. Path entries are
    /// absolute cumulative hashes, so the tail needs no rebasing.
    fn split_node_at(&mut self, i: usize, m: usize) -> usize {
        let bs = self.block_size;
        let (plen, tokens, start) = {
            let n = self.node(i);
            (n.path.len(), n.tokens, n.start)
        };
        assert!(m > 0 && m <= plen, "split point {m} outside node path {plen}");
        if m == plen && tokens == plen * bs {
            return i;
        }
        let head = self.node_mut(i);
        let tail_path = head.path.split_off(m);
        let tail_blocks = head.blocks.split_off(m);
        let tail_tokens = tokens - m * bs;
        head.tokens = m * bs;
        let head_filled = head.filled.min(m * bs);
        let tail_filled = head.filled - head_filled;
        head.filled = head_filled;
        let tail_stalls = std::mem::take(&mut head.stall_events);
        let tail_children = std::mem::take(&mut head.children);
        let (ready, touch) = (head.ready, head.last_touch);
        // A fully-filled interior head is servable even if its (moved)
        // terminal is not: the fill wrote its KV into pinned blocks.
        if !head.ready && head.filled == head.tokens {
            head.ready = true;
        }
        let tail = self.alloc_node(PrefixNode {
            path: tail_path,
            blocks: tail_blocks,
            start: start + m * bs,
            tokens: tail_tokens,
            parent: Some(i),
            children: tail_children,
            ready,
            filled: tail_filled,
            stall_events: tail_stalls,
            last_touch: touch,
        });
        for c in self.node(tail).children.clone() {
            self.node_mut(c).parent = Some(tail);
        }
        self.node_mut(i).children.push(tail);
        for e in self.by_hash.iter_mut() {
            if e.1 == i {
                e.1 = tail;
            }
        }
        i
    }

    // ---- reclaim ------------------------------------------------------

    /// LRU-coldest cold **leaf**: a childless node with no reference
    /// besides the index pin on any of its own blocks. Nodes with live
    /// descendants or sharers are never victims — cold subtrees drain
    /// bottom-up as each eviction exposes the parent.
    fn cold_leaf_pos(&self) -> Option<usize> {
        self.live_nodes()
            .filter(|&i| {
                let n = self.node(i);
                n.children.is_empty() && n.blocks.iter().all(|&b| self.ref_count[b] == 1)
            })
            .min_by_key(|&i| self.node(i).last_touch)
    }

    /// Blocks recoverable by evicting cold subtrees.
    pub fn reclaimable(&self) -> usize {
        self.reclaimable_excluding(&[])
    }

    /// [`reclaimable`](Self::reclaimable), excluding any node that owns a
    /// block of `pinned_run` — an admission gate about to SHARE that run
    /// must not count its blocks as funds (sharing pins them hot).
    /// Counted as the cold **closure**: a node's blocks count only when
    /// every descendant's do too, matching what leaf-first eviction can
    /// actually free.
    pub fn reclaimable_excluding(&self, pinned_run: &[usize]) -> usize {
        let mut total = 0;
        for &r in &self.roots {
            self.evictable_blocks(r, pinned_run, &mut total);
        }
        total
    }

    /// Post-order: whether subtree `i` is fully evictable; evictable
    /// descendants' blocks are added to `total` even under a hot parent
    /// (leaf-first eviction frees them regardless).
    fn evictable_blocks(&self, i: usize, pinned: &[usize], total: &mut usize) -> bool {
        let n = self.node(i);
        let mut all_children = true;
        for &c in &n.children {
            if !self.evictable_blocks(c, pinned, total) {
                all_children = false;
            }
        }
        let ok = all_children
            && n.blocks.iter().all(|&b| self.ref_count[b] == 1)
            && !n.blocks.iter().any(|b| pinned.contains(b));
        if ok {
            *total += n.blocks.len();
        }
        ok
    }

    /// Evict the LRU-coldest cold leaf, freeing its pinned blocks and
    /// unmapping any hash that terminated there. Callers guarantee one
    /// exists.
    fn reclaim_one_cold(&mut self) {
        let i = self.cold_leaf_pos().expect("reclaim without a cold prefix");
        self.by_hash.retain(|&(_, t)| t != i);
        let blocks = std::mem::take(&mut self.node_mut(i).blocks);
        for b in blocks {
            self.release(b);
        }
        self.detach_node(i);
    }

    /// Drain every cold subtree (teardown / leak audits): repeatedly
    /// evicts cold leaves until only nodes with live sharers remain.
    pub fn reclaim_all_cold(&mut self) {
        while self.cold_leaf_pos().is_some() {
            self.reclaim_one_cold();
        }
    }

    // ---- block allocator ----------------------------------------------

    /// Allocate one block, lowest-index first, evicting a cold leaf if
    /// the free list is empty. Failure changes nothing.
    pub fn alloc(&mut self) -> Option<usize> {
        if self.free.is_empty() {
            if self.reclaimable() == 0 {
                return None;
            }
            self.reclaim_one_cold();
        }
        let block = self.free.pop()?;
        debug_assert_eq!(self.ref_count[block], 0);
        self.ref_count[block] = 1;
        Some(block)
    }

    /// Allocate `n` blocks all-or-nothing (cold subtrees are reclaimed
    /// under pressure; failure changes nothing).
    pub fn alloc_n(&mut self, n: usize) -> Option<Vec<usize>> {
        if self.free.len() + self.reclaimable() < n {
            return None;
        }
        while self.free.len() < n {
            self.reclaim_one_cold();
        }
        Some((0..n).map(|_| self.alloc().expect("checked free count")).collect())
    }

    /// Grow `blocks` until it covers `tokens` KV entries. All-or-nothing:
    /// on failure the table is left untouched and `false` is returned.
    pub fn extend_to(&mut self, blocks: &mut Vec<usize>, tokens: usize) -> bool {
        let need = self.blocks_needed(tokens);
        if blocks.len() >= need {
            return true;
        }
        match self.alloc_n(need - blocks.len()) {
            Some(more) => {
                blocks.extend(more);
                true
            }
            None => false,
        }
    }

    /// Release one reference. Frees the block only when the last reference
    /// drops, so releasing one sharer's table never frees a co-sharer's
    /// blocks. Panics on double-free — that is a scheduler bug we want
    /// loud.
    pub fn release(&mut self, block: usize) {
        assert!(self.ref_count[block] > 0, "double free of KV block {block}");
        self.ref_count[block] -= 1;
        if self.ref_count[block] == 0 {
            self.free.push(block);
        }
    }

    /// Release a whole block table (completion or preemption).
    pub fn release_seq(&mut self, blocks: Vec<usize>) {
        for b in blocks {
            self.release(b);
        }
    }

    /// Add one reference to an allocated block.
    pub fn share(&mut self, block: usize) {
        assert!(self.ref_count[block] > 0, "sharing a free KV block {block}");
        self.ref_count[block] += 1;
    }

    /// Add a reference to every block of `run` and return the shared table
    /// prefix a new sharer should start from.
    pub fn share_seq(&mut self, run: &[usize]) -> Vec<usize> {
        for &b in run {
            self.share(b);
        }
        run.to_vec()
    }

    /// Copy-on-write: the caller is about to append tokens into `block`.
    /// With a single reference the block is private and returned as-is;
    /// with sharers a fresh private copy is allocated and the caller's
    /// reference on the shared original is dropped — the original is never
    /// mutated while shared. `None` when the pool cannot supply the copy.
    pub fn fork_block(&mut self, block: usize) -> Option<usize> {
        assert!(self.ref_count[block] > 0, "fork of a free KV block {block}");
        if self.ref_count[block] == 1 {
            return Some(block);
        }
        let fresh = self.alloc()?;
        self.ref_count[block] -= 1;
        Some(fresh)
    }

    pub fn ref_count(&self, block: usize) -> usize {
        self.ref_count[block] as usize
    }

    /// True when `block` has more than one live reference.
    pub fn is_shared(&self, block: usize) -> bool {
        self.ref_count[block] > 1
    }

    // ---- prefix registration ------------------------------------------

    /// Register a whole-template prefix block-run under `hash`, pinning
    /// every block so the run stays resident while sharers come and go.
    /// `run` must be the caller's already-allocated table head covering
    /// exactly `tokens` prompt tokens. Lowers to a single-node tree on a
    /// [`derived_path`]; re-registering a live hash is an idempotent
    /// no-op (conversation turns can race to the same content).
    pub fn register_prefix(&mut self, hash: u64, tokens: usize, run: &[usize]) {
        assert!(!self.is_degenerate(), "prefix sharing requires a paged pool");
        assert!(tokens > 0, "registering an empty prefix");
        if self.hash_node(hash).is_some() {
            return;
        }
        let path = derived_path(hash, tokens / self.block_size);
        self.register_path_prefix(hash, &path, 0, tokens, run);
    }

    /// Register the tail `(start_tokens, cov_tokens]` of a content path
    /// under `hash`: the head `path[..start_tokens/bs]` must already be
    /// resident (the caller shares it); `run` is the registrant's
    /// already-allocated table slice covering exactly the tail. Splits
    /// the node containing the attach point when it falls mid-node.
    /// Idempotent when `hash` is already live.
    pub fn register_path_prefix(
        &mut self,
        hash: u64,
        path: &[u64],
        start_tokens: usize,
        cov_tokens: usize,
        run: &[usize],
    ) {
        let bs = self.block_size;
        assert!(!self.is_degenerate(), "prefix sharing requires a paged pool");
        assert_eq!(start_tokens % bs, 0, "prefix tail must start block-aligned");
        assert!(cov_tokens > start_tokens, "registering an empty prefix tail");
        assert!(
            start_tokens == 0 || cov_tokens / bs > start_tokens / bs,
            "a prefix extension must cover at least one full block"
        );
        assert_eq!(
            run.len(),
            self.blocks_needed(cov_tokens - start_tokens),
            "prefix run does not cover its tail tokens"
        );
        if self.hash_node(hash).is_some() {
            return;
        }
        let sb = start_tokens / bs;
        let cb = cov_tokens / bs;
        assert!(path.len() >= cb, "content path shorter than covered blocks");
        let parent = if sb == 0 {
            None
        } else {
            let walked = self.walk_path(&path[..sb]);
            let matched: usize = walked.iter().map(|&(_, m)| m).sum();
            assert_eq!(matched, sb, "prefix tail attach point is not resident");
            let &(last, m) = walked.last().expect("non-empty walk");
            Some(self.split_node_at(last, m))
        };
        for &b in run {
            self.share(b);
        }
        self.touch_clock += 1;
        let idx = self.alloc_node(PrefixNode {
            path: path[sb..cb].to_vec(),
            blocks: run.to_vec(),
            start: start_tokens,
            tokens: cov_tokens - start_tokens,
            parent,
            children: Vec::new(),
            ready: false,
            filled: 0,
            stall_events: 0,
            last_touch: self.touch_clock,
        });
        match parent {
            Some(p) => self.node_mut(p).children.push(idx),
            None => self.roots.push(idx),
        }
        self.by_hash.push((hash, idx));
    }

    // ---- lookups ------------------------------------------------------

    /// Longest resident match of a content path — ready coverage (with
    /// its block run), plus total attach depth. Empty on degenerate
    /// pools. Ready coverage is contiguous-from-root: it stops at the
    /// first unready node even when deeper spans are ready, because a
    /// sharer cannot skip over KV that does not exist yet.
    pub fn lookup_path_match(&self, path: &[u64]) -> PathMatch {
        let mut out = PathMatch::default();
        if self.is_degenerate() {
            return out;
        }
        let mut frontier_ready = true;
        for (i, matched) in self.walk_path(path) {
            let n = self.node(i);
            out.attach_tokens += matched * self.block_size;
            if frontier_ready && n.ready {
                out.ready_tokens += matched * self.block_size;
                out.ready_run.extend_from_slice(&n.blocks[..matched]);
            } else {
                frontier_ready = false;
            }
        }
        out
    }

    /// Resident run for `hash`, ready or not: `(covered tokens, root-to-
    /// terminal block run)`. Always a miss on degenerate pools (a slot
    /// holds private tokens too). Admission hits must use
    /// [`lookup_servable`](Self::lookup_servable) — an unready span's KV
    /// is still being computed by its registrant.
    pub fn lookup_prefix(&self, hash: u64) -> Option<(usize, Vec<usize>)> {
        if self.is_degenerate() {
            return None;
        }
        let t = self.hash_node(hash)?;
        let term = self.node(t);
        let cov = term.start + term.tokens;
        let mut blocks = Vec::new();
        for i in self.chain_of(t) {
            blocks.extend_from_slice(&self.node(i).blocks);
        }
        Some((cov, blocks))
    }

    /// Covered tokens of `hash`'s registration without materializing the
    /// block run — the hot-path form for coverage-only callers.
    pub fn lookup_prefix_tokens(&self, hash: u64) -> Option<usize> {
        if self.is_degenerate() {
            return None;
        }
        let t = self.hash_node(hash)?;
        let term = self.node(t);
        Some(term.start + term.tokens)
    }

    /// [`lookup_prefix`](Self::lookup_prefix) restricted to fully-READY
    /// chains — the only ones whose KV exists end to end and can serve a
    /// whole-template sharer.
    pub fn lookup_servable(&self, hash: u64) -> Option<(usize, Vec<usize>)> {
        if self.is_degenerate() {
            return None;
        }
        let t = self.hash_node(hash)?;
        if !self.chain_of(t).iter().all(|&i| self.node(i).ready) {
            return None;
        }
        self.lookup_prefix(hash)
    }

    /// True once every node on `hash`'s chain is ready.
    pub fn is_prefix_ready(&self, hash: u64) -> bool {
        match self.hash_node(hash) {
            Some(t) => self.chain_of(t).iter().all(|&i| self.node(i).ready),
            None => false,
        }
    }

    /// Mark `hash`'s whole chain servable — called by the state
    /// transition when the prefill that fills the span crosses its
    /// covered tokens.
    pub fn mark_prefix_ready(&mut self, hash: u64) {
        if let Some(t) = self.hash_node(hash) {
            for i in self.chain_of(t) {
                self.node_mut(i).ready = true;
            }
        }
    }

    /// Registrant progress notification: the prefill filling `hash`'s
    /// chain has computed `prefilled` prompt tokens (absolute). Driven by
    /// the shared state transition; waiters compare this across admission
    /// attempts to detect a stalled fill. A NON-terminal node readies
    /// itself when the note covers it completely — its KV now exists in
    /// pinned blocks — while the terminal keeps waiting for the explicit
    /// [`mark_prefix_ready`](Self::mark_prefix_ready), exactly as the
    /// flat index behaved for whole registrations.
    pub fn note_prefix_fill(&mut self, hash: u64, prefilled: usize) {
        let Some(t) = self.hash_node(hash) else {
            return;
        };
        for i in self.chain_of(t) {
            let terminal = self.is_terminal(i);
            let n = self.node_mut(i);
            if n.ready {
                continue;
            }
            let rel = prefilled.saturating_sub(n.start).min(n.tokens);
            n.filled = n.filled.max(rel);
            if !terminal && n.filled == n.tokens {
                n.ready = true;
            }
        }
    }

    /// The request filling `hash`'s (unready) span was preempted: bump
    /// the terminal's stall-event counter so every waiter's bounded-wait
    /// clock ticks — even if the fill also advanced in the same interval.
    pub fn note_prefix_filler_preempted(&mut self, hash: u64) {
        if let Some(t) = self.hash_node(hash) {
            let n = self.node_mut(t);
            if !n.ready {
                n.stall_events += 1;
            }
        }
    }

    /// The waiter-visible progress of `hash`'s fill: `(tokens computed so
    /// far — contiguous from the chain root, stall events across the
    /// chain)`. `None` when the prefix is not registered.
    pub fn prefix_fill_state(&self, hash: u64) -> Option<(usize, u64)> {
        let t = self.hash_node(hash)?;
        let chain = self.chain_of(t);
        let stalls: u64 = chain.iter().map(|&i| self.node(i).stall_events).sum();
        let mut filled = 0;
        for &i in &chain {
            let n = self.node(i);
            if n.ready {
                filled = n.start + n.tokens;
            } else {
                filled = n.start + n.filled;
                if n.filled < n.tokens {
                    break;
                }
            }
        }
        Some((filled, stalls))
    }

    /// Waiter-visible progress along a content path when the waiter knows
    /// content, not the filler's hash: `(tokens computed so far —
    /// contiguous from the root, stall events at the unready frontier)`.
    /// The path-wait counterpart of
    /// [`prefix_fill_state`](Self::prefix_fill_state); a request whose
    /// wait is bound to an unready ancestor compares this across
    /// admission attempts.
    pub fn path_fill_state(&self, path: &[u64]) -> (usize, u64) {
        if self.is_degenerate() {
            return (0, 0);
        }
        let mut filled = 0;
        let mut stalls = 0;
        for (i, matched) in self.walk_path(path) {
            let n = self.node(i);
            if n.ready {
                filled = n.start + matched * self.block_size;
            } else {
                filled = n.start + n.filled.min(matched * self.block_size);
                stalls = n.stall_events;
                break;
            }
        }
        (filled, stalls)
    }

    /// Stamp `hash`'s chain as recently used (LRU reclaim order).
    /// Admission calls this on every share from a resident run.
    pub fn touch_prefix(&mut self, hash: u64) {
        match self.hash_node(hash) {
            Some(t) => {
                for i in self.chain_of(t) {
                    self.touch_clock += 1;
                    let clock = self.touch_clock;
                    self.node_mut(i).last_touch = clock;
                }
            }
            None => self.touch_clock += 1,
        }
    }

    /// Stamp the matched chain of a content path as recently used — the
    /// partial-hit counterpart of [`touch_prefix`](Self::touch_prefix).
    pub fn touch_path(&mut self, path: &[u64]) {
        if self.is_degenerate() {
            return;
        }
        let matched: Vec<usize> = self.walk_path(path).into_iter().map(|(i, _)| i).collect();
        for i in matched {
            self.touch_clock += 1;
            let clock = self.touch_clock;
            self.node_mut(i).last_touch = clock;
        }
    }

    /// Drop the index mapping for `hash` (manual eviction; the allocator
    /// also reclaims cold subtrees itself under pressure). Returns
    /// whether the prefix was registered. Nodes still needed by OTHER
    /// registrations or live descendants stay resident; the unpinnable
    /// suffix of the chain is released bottom-up. Blocks still referenced
    /// by live sharers stay allocated until those sharers release.
    pub fn evict_prefix(&mut self, hash: u64) -> bool {
        let Some(pos) = self.by_hash.iter().position(|&(h, _)| h == hash) else {
            return false;
        };
        let (_, mut i) = self.by_hash.remove(pos);
        loop {
            let n = self.node(i);
            if !n.children.is_empty() || self.is_terminal(i) {
                return true;
            }
            let parent = n.parent;
            let blocks = std::mem::take(&mut self.node_mut(i).blocks);
            for b in blocks {
                self.release(b);
            }
            self.detach_node(i);
            match parent {
                Some(p) => i = p,
                None => return true,
            }
        }
    }

    /// Number of registered prefixes (live hash mappings).
    pub fn num_prefixes(&self) -> usize {
        self.by_hash.len()
    }

    /// Iterate resident spans as `(hash, tokens, own block run)` — one
    /// item per NODE (terminal nodes report their registered hash,
    /// interior nodes their deepest cumulative path hash), so metrics and
    /// the property suites see every pinned block exactly once.
    pub fn registered_prefixes(&self) -> impl Iterator<Item = (u64, usize, &[usize])> + '_ {
        self.live_nodes().map(move |i| {
            let n = self.node(i);
            let hash = self
                .by_hash
                .iter()
                .find(|&&(_, t)| t == i)
                .map(|&(h, _)| h)
                .or_else(|| n.path.last().copied())
                .unwrap_or(i as u64);
            (hash, n.tokens, n.blocks.as_slice())
        })
    }

    /// The registered hashes (terminal mappings) — teardown loops evict
    /// through this instead of guessing node identities.
    pub fn registered_hashes(&self) -> Vec<u64> {
        self.by_hash.iter().map(|&(h, _)| h).collect()
    }

    /// Tokens of KV content held resident by the prefix tree (counted
    /// once each, however many sharers reference them).
    pub fn resident_prefix_tokens(&self) -> usize {
        self.live_nodes().map(|i| self.node(i).tokens).sum()
    }

    /// The replica's resident-prefix summary for the router: READY nodes
    /// only (descent stops at the first unready node — deeper spans are
    /// unreachable for a sharer anyway), deepest-first, capped at
    /// [`DIGEST_CAP`]. Ties break on hash for determinism.
    pub fn residency_digest(&self) -> ResidencyDigest {
        let mut cands: Vec<(u64, u32)> = Vec::new();
        if !self.is_degenerate() {
            let mut stack = self.roots.clone();
            while let Some(i) = stack.pop() {
                let n = self.node(i);
                if !n.ready {
                    continue;
                }
                if let Some(&h) = n.path.last() {
                    cands.push((h, (n.start + n.path.len() * self.block_size) as u32));
                }
                stack.extend_from_slice(&n.children);
            }
        }
        cands.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        let mut d = ResidencyDigest::default();
        for &(h, depth) in cands.iter().take(DIGEST_CAP) {
            d.entries[d.len as usize] = (h, depth);
            d.len += 1;
        }
        d
    }

    /// Structural radix invariants, loud. The property suites call this
    /// after every engine step; unit tests call it around mutations.
    pub fn assert_radix_invariants(&self) {
        if self.is_degenerate() {
            assert!(self.nodes.iter().all(|n| n.is_none()), "degenerate pools index nothing");
            return;
        }
        let bs = self.block_size;
        let mut owned: Vec<usize> = Vec::new();
        for i in self.live_nodes() {
            let n = self.node(i);
            assert!(n.tokens > 0, "node {i} covers no tokens");
            assert_eq!(n.start % bs, 0, "node {i} start not block-aligned");
            assert_eq!(n.path.len(), n.tokens / bs, "node {i} path/token mismatch");
            assert_eq!(
                n.blocks.len(),
                self.blocks_needed(n.tokens),
                "node {i} run does not cover its tokens"
            );
            assert!(n.filled <= n.tokens, "node {i} overfilled");
            if n.has_partial_tail(bs) {
                assert!(n.children.is_empty(), "partial-tail node {i} has children");
            }
            for &c in &n.children {
                let child = self.node(c);
                assert_eq!(child.parent, Some(i), "child {c} disowns parent {i}");
                assert_eq!(
                    child.start,
                    n.start + n.tokens,
                    "child {c} does not start at parent {i}'s end"
                );
            }
            match n.parent {
                Some(p) => assert!(
                    self.node(p).children.contains(&i),
                    "node {i} not in parent {p}'s children"
                ),
                None => {
                    assert_eq!(n.start, 0, "root {i} starts past 0");
                    assert!(self.roots.contains(&i), "orphan root {i}");
                }
            }
            for &b in &n.blocks {
                assert!(self.ref_count[b] >= 1, "node {i} owns free block {b}");
                owned.push(b);
            }
        }
        let total = owned.len();
        owned.sort_unstable();
        owned.dedup();
        assert_eq!(owned.len(), total, "a block is owned by two radix nodes");
        for &(h, t) in &self.by_hash {
            assert!(self.nodes[t].is_some(), "hash {h:#x} maps to a dead node");
        }
        // every live node is reachable from the roots
        let mut reach = 0usize;
        let mut stack = self.roots.clone();
        while let Some(i) = stack.pop() {
            reach += 1;
            stack.extend_from_slice(&self.node(i).children);
        }
        assert_eq!(reach, self.live_nodes().count(), "unreachable radix nodes");
    }

    pub fn is_allocated(&self, block: usize) -> bool {
        self.ref_count[block] > 0
    }

    /// True for the seed-compatible whole-request-slot layout.
    pub fn is_degenerate(&self) -> bool {
        self.block_size == DEGENERATE_BLOCK
    }

    /// Serialize a finished block table into a transfer descriptor,
    /// releasing this pool's references — the disaggregation handoff edge:
    /// a prefill replica exports the prompt's KV, the descriptor crosses
    /// the interconnect (costed by `simulator::transfer::CopyFabric`), and
    /// the decode replica [`import_seq`](Self::import_seq)s it into its
    /// own pool. Shared blocks follow normal refcount rules: exporting one
    /// sharer's table never frees a co-sharer's blocks.
    pub fn export_seq(&mut self, blocks: Vec<usize>, kv_tokens: usize) -> KvExport {
        let n = blocks.len();
        self.release_seq(blocks);
        KvExport { kv_tokens, blocks: n }
    }

    /// Materialize a transfer descriptor into this pool: allocate a fresh
    /// block table covering the exported tokens, all-or-nothing (`None`
    /// under memory pressure — the caller retries admission later, it
    /// never wedges).
    pub fn import_seq(&mut self, export: &KvExport) -> Option<Vec<usize>> {
        self.alloc_n(self.blocks_needed(export.kv_tokens))
    }

    /// Internal fragmentation: tokens of allocated-but-unused capacity.
    /// `private_live_tokens` is the pool-wide count of live KV tokens in
    /// PRIVATE (unshared) block territory — callers pass
    /// `RequestPool::live_private_kv_tokens`, NOT the raw per-request sum,
    /// so a shared prefix block's content is counted once (via
    /// [`resident_prefix_tokens`](Self::resident_prefix_tokens)) rather
    /// than once per sharer. Reports 0 in degenerate mode — the sentinel
    /// block size is nominal, not memory.
    pub fn internal_fragmentation(&self, private_live_tokens: usize) -> usize {
        if self.is_degenerate() {
            return 0;
        }
        self.allocated()
            .saturating_mul(self.block_size)
            .saturating_sub(private_live_tokens + self.resident_prefix_tokens())
    }
}

/// A block table serialized for transfer between KV pools: what a prefill
/// replica hands a decode replica at disaggregation handoff. Carries the
/// logical content size (`kv_tokens`) and the source-side block count; the
/// destination re-blocks under its own `block_size`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KvExport {
    /// KV entries the exported table covered.
    pub kv_tokens: usize,
    /// Blocks the table held on the exporting pool.
    pub blocks: usize,
}

/// Per-stage KV ownership for pipeline parallelism: each of `stages`
/// pipeline stages holds only its own `layers / stages` layers' KV, so a
/// replica's KV memory is `stages` equal pools rather than one monolith.
///
/// Because a token's KV exists on EVERY stage (each stage's layers attend
/// over the full sequence) and every pool has the same block size and the
/// same per-stage capacity, the stages' block tables grow, fork and free
/// in lock-step — stage `k`'s allocator state is block-for-block identical
/// to stage 0's at all times. `StageKv` therefore keeps ONE canonical pool
/// and the stage count: allocation decisions made against the canonical
/// pool are exact for all stages, which is what keeps the pp=1 path (and
/// every existing pp>1 experiment) byte-identical to the single-pool
/// refactor predecessor. Byte accounting (`bytes_for_tokens`) is where the
/// split shows: each stage moves only its layer share over the wire.
#[derive(Clone, Debug)]
pub struct StageKv {
    pool: KvManager,
    stages: usize,
}

impl StageKv {
    /// Wrap a per-stage pool, mirrored across `stages` stages.
    pub fn mirrored(pool: KvManager, stages: usize) -> Self {
        assert!(stages > 0, "a replica has at least one pipeline stage");
        StageKv { pool, stages }
    }

    pub fn stages(&self) -> usize {
        self.stages
    }

    /// The canonical per-stage pool (stage 0; all stages are identical).
    pub fn pool(&self) -> &KvManager {
        &self.pool
    }

    pub fn pool_mut(&mut self) -> &mut KvManager {
        &mut self.pool
    }

    /// Blocks across all stages (each stage holds its own copy of the
    /// canonical pool's layout).
    pub fn total_blocks(&self) -> usize {
        self.pool.capacity() * self.stages
    }

    /// Blocks in use across all stages.
    pub fn total_allocated(&self) -> usize {
        self.pool.allocated() * self.stages
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_cycle() {
        let mut kv = KvManager::new(3);
        assert_eq!(kv.available(), 3);
        let a = kv.alloc().unwrap();
        let b = kv.alloc().unwrap();
        let c = kv.alloc().unwrap();
        assert_ne!(a, b);
        assert_ne!(b, c);
        assert!(kv.alloc().is_none());
        kv.release(b);
        assert_eq!(kv.available(), 1);
        assert_eq!(kv.alloc(), Some(b));
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut kv = KvManager::new(2);
        let a = kv.alloc().unwrap();
        kv.release(a);
        kv.release(a);
    }

    #[test]
    fn lowest_index_first() {
        let mut kv = KvManager::new(4);
        assert_eq!(kv.alloc(), Some(0));
        assert_eq!(kv.alloc(), Some(1));
    }

    #[test]
    fn degenerate_needs_one_block_for_any_length() {
        let kv = KvManager::new(4);
        assert_eq!(kv.blocks_needed(0), 0);
        assert_eq!(kv.blocks_needed(1), 1);
        assert_eq!(kv.blocks_needed(1_000_000), 1);
    }

    #[test]
    fn paged_block_arithmetic() {
        let kv = KvManager::paged(8, 16);
        assert_eq!(kv.blocks_needed(0), 0);
        assert_eq!(kv.blocks_needed(1), 1);
        assert_eq!(kv.blocks_needed(16), 1);
        assert_eq!(kv.blocks_needed(17), 2);
        assert_eq!(kv.blocks_needed(128), 8);
        assert_eq!(kv.capacity_tokens(), 128);
    }

    #[test]
    fn alloc_n_is_all_or_nothing() {
        let mut kv = KvManager::paged(4, 16);
        let got = kv.alloc_n(3).unwrap();
        assert_eq!(got.len(), 3);
        assert_eq!(kv.available(), 1);
        assert!(kv.alloc_n(2).is_none());
        assert_eq!(kv.available(), 1, "failed alloc must not leak");
        kv.release_seq(got);
        assert_eq!(kv.available(), 4);
    }

    #[test]
    fn extend_grows_table_token_granularly() {
        let mut kv = KvManager::paged(4, 16);
        let mut table = Vec::new();
        assert!(kv.extend_to(&mut table, 10));
        assert_eq!(table.len(), 1);
        assert!(kv.extend_to(&mut table, 16)); // still fits the block
        assert_eq!(table.len(), 1);
        assert!(kv.extend_to(&mut table, 17)); // crosses a block boundary
        assert_eq!(table.len(), 2);
        assert!(kv.extend_to(&mut table, 64)); // grows to the whole pool
        assert_eq!(table.len(), 4);
        assert!(!kv.extend_to(&mut table, 65), "over capacity must fail");
        assert_eq!(table.len(), 4, "failed extend must not change the table");
        kv.release_seq(table);
        assert_eq!(kv.available(), 4);
    }

    #[test]
    fn shared_blocks_survive_one_sharers_release() {
        let mut kv = KvManager::paged(4, 16);
        let run = kv.alloc_n(2).unwrap();
        let copy = kv.share_seq(&run);
        assert_eq!(copy, run);
        assert!(kv.is_shared(run[0]));
        assert_eq!(kv.ref_count(run[0]), 2);
        // one sharer releases: blocks stay allocated for the other
        kv.release_seq(copy);
        assert!(kv.is_allocated(run[0]) && kv.is_allocated(run[1]));
        assert_eq!(kv.available(), 2);
        kv.release_seq(run);
        assert_eq!(kv.available(), 4, "last release frees");
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn refcounted_release_still_panics_past_zero() {
        let mut kv = KvManager::paged(2, 16);
        let a = kv.alloc().unwrap();
        kv.share(a);
        kv.release(a);
        kv.release(a); // refcount hits 0: block is free
        kv.release(a); // one release too many
    }

    #[test]
    fn fork_is_identity_when_private_and_copies_when_shared() {
        let mut kv = KvManager::paged(4, 16);
        let a = kv.alloc().unwrap();
        // private: no copy, same block back
        assert_eq!(kv.fork_block(a), Some(a));
        assert_eq!(kv.ref_count(a), 1);
        // shared: a fresh private block, original keeps its other sharer
        kv.share(a);
        let b = kv.fork_block(a).unwrap();
        assert_ne!(b, a, "COW must not hand out a shared block");
        assert_eq!(kv.ref_count(a), 1, "caller's reference moved to the copy");
        assert_eq!(kv.ref_count(b), 1);
        kv.release(a);
        kv.release(b);
        assert_eq!(kv.available(), 4);
    }

    #[test]
    fn prefix_register_lookup_evict() {
        let mut kv = KvManager::paged(8, 16);
        assert!(kv.lookup_prefix(7).is_none());
        let run = kv.alloc_n(3).unwrap(); // covers 40 tokens (partial last)
        kv.register_prefix(7, 40, &run);
        kv.assert_radix_invariants();
        assert_eq!(kv.num_prefixes(), 1);
        assert_eq!(kv.resident_prefix_tokens(), 40);
        let (tokens, resident) = kv.lookup_prefix(7).unwrap();
        assert_eq!(tokens, 40);
        assert_eq!(resident, run);
        assert_eq!(kv.lookup_prefix_tokens(7), Some(40));
        // a freshly registered run is indexed but NOT servable: its KV is
        // still being computed by the registrant
        assert!(!kv.is_prefix_ready(7));
        assert!(kv.lookup_servable(7).is_none());
        kv.mark_prefix_ready(7);
        assert!(kv.is_prefix_ready(7));
        assert_eq!(kv.lookup_servable(7).unwrap().0, 40);
        // re-registration is an idempotent no-op, not a panic
        kv.register_prefix(7, 40, &run);
        assert_eq!(kv.num_prefixes(), 1);
        // the registrant releases; the pin keeps the run resident
        kv.release_seq(run.clone());
        assert!(kv.lookup_prefix(7).is_some());
        assert_eq!(kv.allocated(), 3);
        assert!(kv.evict_prefix(7));
        assert!(!kv.evict_prefix(7));
        assert!(kv.lookup_servable(7).is_none());
        assert_eq!(kv.available(), 8);
        kv.assert_radix_invariants();
    }

    #[test]
    fn cold_prefixes_are_reclaimed_under_pressure() {
        let mut kv = KvManager::paged(4, 16);
        let run = kv.alloc_n(2).unwrap();
        kv.register_prefix(1, 32, &run);
        kv.release_seq(run); // prefix now cold (pin only)
        assert_eq!(kv.available(), 2);
        assert_eq!(kv.reclaimable(), 2);
        // demanding more than the free list forces the cold eviction
        let got = kv.alloc_n(4).expect("reclaim funds the allocation");
        assert_eq!(got.len(), 4);
        assert_eq!(kv.num_prefixes(), 0, "cold prefix evicted");
        assert!(kv.lookup_prefix(1).is_none());
        kv.release_seq(got);
        // a HOT prefix (live sharer) is never reclaimed
        let run = kv.alloc_n(2).unwrap();
        kv.register_prefix(2, 32, &run);
        assert_eq!(kv.reclaimable(), 0);
        assert!(kv.alloc_n(3).is_none(), "hot prefix blocks stay pinned");
        assert_eq!(kv.num_prefixes(), 1);
        kv.release_seq(run);
    }

    /// The smarter-eviction satellite: cold-prefix reclaim is LRU by last
    /// hit, replacing PR-3's oldest-registered-first order. A hit on the
    /// OLDER registration must make the newer, never-hit run the victim —
    /// oldest-first would have evicted the hot template instead.
    #[test]
    fn cold_prefix_reclaim_is_lru_by_last_hit_not_oldest_first() {
        let mut kv = KvManager::paged(6, 16);
        let run_a = kv.alloc_n(2).unwrap();
        kv.register_prefix(1, 32, &run_a);
        let run_b = kv.alloc_n(2).unwrap();
        kv.register_prefix(2, 32, &run_b);
        kv.release_seq(run_a);
        kv.release_seq(run_b); // both cold (pin-only references)
        assert_eq!(kv.reclaimable(), 4);
        // a later hit stamps the OLDER registration hot
        kv.touch_prefix(1);
        // demanding past the 2 free blocks reclaims the LRU-coldest run
        let got = kv.alloc_n(4).expect("reclaim funds the allocation");
        assert!(kv.lookup_prefix(1).is_some(), "recently-hit run survives");
        assert!(
            kv.lookup_prefix(2).is_none(),
            "LRU-coldest run evicted (oldest-first would have kept it)"
        );
        kv.release_seq(got);
        assert!(kv.evict_prefix(1));
        assert_eq!(kv.available(), 6);
    }

    /// Fill-progress bookkeeping for bounded prefix-waits: notes advance
    /// the waiter-visible state, filler preemption bumps the stall
    /// counter, and a ready run stops tracking.
    #[test]
    fn fill_state_tracks_progress_and_filler_preemptions() {
        let mut kv = KvManager::paged(8, 16);
        assert_eq!(kv.prefix_fill_state(3), None);
        let run = kv.alloc_n(3).unwrap();
        kv.register_prefix(3, 40, &run);
        assert_eq!(kv.prefix_fill_state(3), Some((0, 0)));
        kv.note_prefix_fill(3, 16);
        assert_eq!(kv.prefix_fill_state(3), Some((16, 0)));
        // progress never regresses, and is capped at the covered tokens
        kv.note_prefix_fill(3, 8);
        kv.note_prefix_fill(3, 100);
        assert_eq!(kv.prefix_fill_state(3), Some((40, 0)));
        kv.note_prefix_filler_preempted(3);
        assert_eq!(kv.prefix_fill_state(3), Some((40, 1)));
        // a ready run no longer counts stalls (nobody waits on it)
        kv.mark_prefix_ready(3);
        kv.note_prefix_filler_preempted(3);
        assert_eq!(kv.prefix_fill_state(3), Some((40, 1)));
        kv.release_seq(run);
        kv.evict_prefix(3);
    }

    /// Export/import round-trip: the source pool's blocks come back to its
    /// free list, the descriptor carries the content size, and the
    /// destination re-blocks under its own block size — all-or-nothing
    /// under pressure.
    #[test]
    fn export_import_round_trip_conserves_blocks() {
        let mut src = KvManager::paged(8, 16);
        let mut table = Vec::new();
        assert!(src.extend_to(&mut table, 40)); // 3 blocks
        let ex = src.export_seq(table, 40);
        assert_eq!(ex, KvExport { kv_tokens: 40, blocks: 3 });
        assert_eq!(src.available(), 8, "export releases the source table");
        // destination uses a different block size: 40 tokens → 2×32
        let mut dst = KvManager::paged(4, 32);
        let imported = dst.import_seq(&ex).expect("fits");
        assert_eq!(imported.len(), 2);
        assert_eq!(dst.allocated(), 2);
        dst.release_seq(imported);
        // a full destination refuses whole, changing nothing
        let mut tiny = KvManager::paged(1, 16);
        assert!(tiny.import_seq(&ex).is_none());
        assert_eq!(tiny.available(), 1);
    }

    /// Exporting a sharer's table follows refcount rules — the co-sharer's
    /// blocks stay allocated.
    #[test]
    fn export_of_shared_table_never_frees_the_co_sharer() {
        let mut kv = KvManager::paged(4, 16);
        let run = kv.alloc_n(2).unwrap();
        let other = kv.share_seq(&run);
        let ex = kv.export_seq(other, 32);
        assert_eq!(ex.blocks, 2);
        assert!(kv.is_allocated(run[0]) && kv.is_allocated(run[1]));
        kv.release_seq(run);
        assert_eq!(kv.available(), 4);
    }

    /// StageKv mirrors one canonical pool across the stage count: the
    /// pp=1 wrapper is transparent, and multi-stage accounting multiplies.
    #[test]
    fn stage_kv_mirrors_the_canonical_pool() {
        let mut skv = StageKv::mirrored(KvManager::paged(8, 16), 4);
        assert_eq!(skv.stages(), 4);
        assert_eq!(skv.total_blocks(), 32);
        let table = skv.pool_mut().alloc_n(3).unwrap();
        assert_eq!(skv.total_allocated(), 12, "every stage holds its copy");
        assert_eq!(skv.pool().allocated(), 3);
        skv.pool_mut().release_seq(table);
        assert_eq!(skv.total_allocated(), 0);
    }

    #[test]
    fn degenerate_pools_never_hit_the_prefix_index() {
        let kv = KvManager::new(4);
        assert!(kv.lookup_prefix(0).is_none());
        assert!(kv.lookup_path_match(&[1, 2, 3]).ready_run.is_empty());
        assert!(kv.residency_digest().is_empty());
    }

    #[test]
    fn fragmentation_accounting() {
        let mut kv = KvManager::paged(8, 16);
        let mut table = Vec::new();
        assert!(kv.extend_to(&mut table, 20)); // 2 blocks = 32 tokens for 20 live
        assert_eq!(kv.internal_fragmentation(20), 12);
        assert!(kv.extend_to(&mut table, 32));
        assert_eq!(kv.internal_fragmentation(32), 0);
        kv.release_seq(table);
        // degenerate slots are nominal reservations, not wasted memory
        let kv = KvManager::new(2);
        assert!(kv.is_degenerate());
        assert_eq!(kv.internal_fragmentation(100), 0);
    }

    /// The shared-block occupancy fix: a block referenced by N sharers is
    /// one block of memory, so `allocated()` and fragmentation count it
    /// once — summing per-sharer footprints would overstate occupancy.
    #[test]
    fn shared_blocks_count_once_in_occupancy_and_fragmentation() {
        let mut kv = KvManager::paged(8, 16);
        // a 32-token prefix run, registered (pin) + two sharers
        let run = kv.alloc_n(2).unwrap();
        kv.register_prefix(9, 32, &run);
        let other = kv.share_seq(&run);
        // each sharer also holds one private block with 10 live tokens
        let mut a = run.clone();
        let mut b = other.clone();
        assert!(kv.extend_to(&mut a, 42));
        assert!(kv.extend_to(&mut b, 42));
        // memory truth: 2 shared + 2 private blocks, NOT 2 × (2 + 1)
        assert_eq!(kv.allocated(), 4);
        // fragmentation: private live = 2 × 10, shared content counted once
        // via the prefix index → 4 × 16 − (20 + 32) = 12
        assert_eq!(kv.internal_fragmentation(20), 12);
        kv.release_seq(a);
        kv.release_seq(b);
        assert!(kv.evict_prefix(9));
        assert_eq!(kv.available(), 8);
    }

    // ---- radix-tree specific tests ------------------------------------

    /// A shared content path: template B diverges from template A after 2
    /// of A's 4 blocks. Registering B's tail splits A's node, B shares
    /// A's ready head, and both templates stay fully resident.
    #[test]
    fn partial_match_splits_the_node_and_shares_the_head() {
        let mut kv = KvManager::paged(16, 16);
        let mut path_a = vec![101, 102, 103, 104];
        let run_a = kv.alloc_n(4).unwrap();
        kv.register_path_prefix(0xA, &path_a, 0, 64, &run_a);
        kv.mark_prefix_ready(0xA);
        kv.assert_radix_invariants();
        // B agrees on blocks 0..2, then diverges
        let path_b = vec![101, 102, 203, 204];
        let m = kv.lookup_path_match(&path_b);
        assert_eq!(m.ready_tokens, 32, "longest resident match is 2 blocks");
        assert_eq!(m.attach_tokens, 32);
        assert_eq!(m.ready_run, &run_a[..2]);
        // B shares the head and registers its private tail
        let shared = kv.share_seq(&m.ready_run);
        let run_b = kv.alloc_n(2).unwrap();
        kv.register_path_prefix(0xB, &path_b, 32, 64, &run_b);
        kv.assert_radix_invariants();
        assert_eq!(kv.num_prefixes(), 2);
        // the split kept A's full chain intact and ready
        let (cov_a, blocks_a) = kv.lookup_servable(0xA).expect("A stays servable");
        assert_eq!(cov_a, 64);
        assert_eq!(blocks_a, run_a);
        // B's chain = shared head + private tail, unready until marked
        assert!(kv.lookup_servable(0xB).is_none());
        kv.mark_prefix_ready(0xB);
        let (cov_b, blocks_b) = kv.lookup_servable(0xB).unwrap();
        assert_eq!(cov_b, 64);
        assert_eq!(blocks_b[..2], run_a[..2]);
        assert_eq!(blocks_b[2..], run_b[..]);
        // the head blocks are counted once but pinned by one node only
        assert_eq!(kv.allocated(), 6, "4 A blocks + 2 B tail blocks");
        // both full paths now match end to end
        path_a.push(999); // longer query than residency
        assert_eq!(kv.lookup_path_match(&path_a).ready_tokens, 64);
        assert_eq!(kv.lookup_path_match(&path_b).ready_tokens, 64);
        kv.release_seq(shared);
        kv.release_seq(run_a);
        kv.release_seq(run_b);
        kv.assert_radix_invariants();
    }

    /// A multi-turn conversation: each turn extends its own prior path.
    /// The chain lookup concatenates node runs; evicting the extension
    /// hash cascades only over nodes no other registration needs.
    #[test]
    fn chain_extension_and_cascading_evict() {
        let mut kv = KvManager::paged(16, 16);
        let path = vec![11, 12, 13, 14];
        let run0 = kv.alloc_n(2).unwrap();
        kv.register_path_prefix(0x1, &path, 0, 32, &run0);
        kv.mark_prefix_ready(0x1);
        let run1 = kv.alloc_n(2).unwrap();
        kv.register_path_prefix(0x2, &path, 32, 64, &run1);
        kv.mark_prefix_ready(0x2);
        kv.assert_radix_invariants();
        let (cov, blocks) = kv.lookup_servable(0x2).unwrap();
        assert_eq!(cov, 64);
        assert_eq!(blocks[..2], run0[..]);
        assert_eq!(blocks[2..], run1[..]);
        kv.release_seq(run0);
        kv.release_seq(run1);
        // evicting the head hash keeps its node: the extension chains
        // through it
        assert!(kv.evict_prefix(0x1));
        assert_eq!(kv.lookup_path_match(&path).ready_tokens, 64);
        assert_eq!(kv.allocated(), 4);
        // evicting the extension cascades: its node frees, then the now
        // childless unmapped head frees too
        assert!(kv.evict_prefix(0x2));
        assert_eq!(kv.available(), 16);
        assert_eq!(kv.num_prefixes(), 0);
        kv.assert_radix_invariants();
    }

    /// Subtree LRU reclaim is leaf-first: a parent with live children is
    /// never a victim, and among cold leaves the LRU-coldest goes first.
    #[test]
    fn subtree_reclaim_is_leaf_first_and_lru() {
        let mut kv = KvManager::paged(8, 16);
        let path = vec![21, 22];
        let run_p = kv.alloc_n(1).unwrap();
        kv.register_path_prefix(0x10, &path, 0, 16, &run_p);
        kv.mark_prefix_ready(0x10);
        let run_a = kv.alloc_n(1).unwrap();
        kv.register_path_prefix(0x11, &[21, 31], 16, 32, &run_a);
        let run_b = kv.alloc_n(1).unwrap();
        kv.register_path_prefix(0x12, &[21, 41], 16, 32, &run_b);
        kv.release_seq(run_p);
        kv.release_seq(run_a);
        kv.release_seq(run_b);
        kv.assert_radix_invariants();
        // everything is cold; the parent is NOT reclaimable directly but
        // the closure counts all 3 blocks (leaf-first drain)
        assert_eq!(kv.reclaimable(), 3);
        // touch leaf A: leaf B becomes the LRU victim
        kv.touch_prefix(0x11);
        let got = kv.alloc_n(6).expect("reclaim funds the allocation");
        assert!(kv.lookup_prefix(0x12).is_none(), "cold leaf B evicted first");
        assert!(kv.lookup_prefix(0x11).is_some(), "touched leaf survives");
        assert!(kv.lookup_prefix(0x10).is_some(), "parent outlives its child");
        kv.release_seq(got);
        kv.assert_radix_invariants();
        kv.reclaim_all_cold();
        assert_eq!(kv.available(), 8);
        assert_eq!(kv.num_prefixes(), 0);
    }

    /// Ready coverage is contiguous from the root: an unready frontier
    /// node contributes attach depth (a wait candidate) but zero ready
    /// tokens, and nothing deeper can serve either.
    #[test]
    fn unready_frontier_blocks_ready_coverage() {
        let mut kv = KvManager::paged(8, 16);
        let path = vec![51, 52, 53];
        let run = kv.alloc_n(3).unwrap();
        kv.register_path_prefix(0x7, &path, 0, 48, &run);
        let m = kv.lookup_path_match(&path);
        assert_eq!(m.ready_tokens, 0, "unready nodes cannot serve");
        assert!(m.ready_run.is_empty());
        assert_eq!(m.attach_tokens, 48, "but the span is attached");
        // fill notes ready interior spans only after a split; the whole-
        // node terminal stays gated on the explicit mark
        kv.note_prefix_fill(0x7, 48);
        assert_eq!(kv.lookup_path_match(&path).ready_tokens, 0);
        kv.mark_prefix_ready(0x7);
        let m = kv.lookup_path_match(&path);
        assert_eq!(m.ready_tokens, 48);
        assert_eq!(m.ready_run, run);
        kv.release_seq(run);
        kv.evict_prefix(0x7);
    }

    /// The `{id,len}` lowering: a whole-template registration is
    /// queryable through its derived content path, and the derived path
    /// nests (longer queries still match the resident span).
    #[test]
    fn derived_path_matches_whole_template_registrations() {
        let mut kv = KvManager::paged(8, 16);
        let run = kv.alloc_n(2).unwrap();
        kv.register_prefix(0xFEED, 32, &run);
        kv.mark_prefix_ready(0xFEED);
        let q = derived_path(0xFEED, 4); // deeper query than residency
        let m = kv.lookup_path_match(&q);
        assert_eq!(m.ready_tokens, 32);
        assert_eq!(m.ready_run, run);
        // nesting: the short path is a strict prefix of the long one
        assert_eq!(derived_path(0xFEED, 2)[..], q[..2]);
        assert_ne!(derived_path(0xBEEF, 2)[0], q[0]);
        kv.release_seq(run);
        kv.evict_prefix(0xFEED);
    }

    /// The residency digest reports READY nodes only, deepest-first, and
    /// `coverage` certifies the deepest matching token depth.
    #[test]
    fn residency_digest_reports_ready_spans_deepest_first() {
        let mut kv = KvManager::paged(16, 16);
        let path = vec![61, 62, 63];
        let run = kv.alloc_n(3).unwrap();
        kv.register_path_prefix(0x20, &path, 0, 48, &run);
        assert!(kv.residency_digest().is_empty(), "unready spans stay out");
        kv.mark_prefix_ready(0x20);
        // a divergent unready sibling under the (split) ready head
        let run_b = kv.alloc_n(1).unwrap();
        kv.register_path_prefix(0x21, &[61, 62, 73], 32, 48, &run_b);
        kv.assert_radix_invariants();
        let d = kv.residency_digest();
        let depths: Vec<u32> = d.entries().iter().map(|&(_, t)| t).collect();
        assert!(depths.windows(2).all(|w| w[0] >= w[1]), "deepest-first");
        assert_eq!(d.coverage(&path), 48, "full ready path certified");
        assert_eq!(d.coverage(&[61, 62, 73]), 32, "shared head only");
        assert_eq!(d.coverage(&[99, 98]), 0, "foreign path misses");
        kv.release_seq(run);
        kv.release_seq(run_b);
        kv.evict_prefix(0x20);
        kv.evict_prefix(0x21);
        kv.reclaim_all_cold();
        assert_eq!(kv.available(), 16);
    }

    /// `reclaimable_excluding` by run: nodes owning any excluded block
    /// contribute no funds — the admission gate must not spend blocks it
    /// is about to share.
    #[test]
    fn reclaimable_excluding_pins_the_share_target() {
        let mut kv = KvManager::paged(8, 16);
        let run_a = kv.alloc_n(2).unwrap();
        kv.register_prefix(1, 32, &run_a);
        let run_b = kv.alloc_n(2).unwrap();
        kv.register_prefix(2, 32, &run_b);
        kv.release_seq(run_a.clone());
        kv.release_seq(run_b);
        assert_eq!(kv.reclaimable(), 4);
        assert_eq!(kv.reclaimable_excluding(&run_a), 2);
        assert_eq!(kv.reclaimable_excluding(&run_a[..1]), 2, "any owned block pins the node");
        kv.reclaim_all_cold();
    }
}
