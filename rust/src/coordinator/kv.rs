//! Token-granular paged KV-cache block allocator with ref-counted,
//! copy-on-write prefix sharing.
//!
//! The seed reserved one whole-request *slot* per admitted request, sized
//! for the worst-case sequence length (§4.3.1) — which caps concurrency at
//! `B = M / (L_max · m_kv)` even when actual sequences are far shorter.
//! This module replaces slots with fixed-size **blocks** of `block_size`
//! tokens (vLLM-style paging): a request holds a growing block table,
//! blocks are allocated as its KV actually grows (chunked prefill, then one
//! token per decode), and released on completion or preemption.
//!
//! On top of paging, blocks are **ref-counted** so identical prompt
//! prefixes (shared system prompts, few-shot templates) can be shared
//! across requests instead of paying for their KV once per sharer
//! (PagedAttention §4.3, arXiv 2309.06180):
//!
//! * [`share_seq`](KvManager::share_seq) hands a second (third, ...)
//!   reference to an existing block run; `release` decrements and only
//!   frees at zero, so preempting or completing one sharer can never free
//!   blocks another sharer still reads.
//! * [`fork_block`](KvManager::fork_block) is the copy-on-write edge: a
//!   sharer that must *append into* a partially-filled shared block gets a
//!   private copy; the shared original is never mutated while its
//!   refcount exceeds one.
//! * [`register_prefix`](KvManager::register_prefix) /
//!   [`lookup_prefix`](KvManager::lookup_prefix) index resident prefix
//!   block-runs by prefix hash. A registered prefix holds one reference
//!   ("pin") on its run so it stays resident across sharer churn; a
//!   *cold* prefix (pin is the only reference) is reclaimed automatically
//!   when the allocator runs out of free blocks, oldest-registered first.
//!   A run registers **unready** and becomes servable
//!   ([`mark_prefix_ready`](KvManager::mark_prefix_ready), driven by the
//!   shared state transition) only after the registrant's prefill has
//!   computed the covered tokens INTO the run — filling pin-shared blocks
//!   in place is the one sanctioned write to a block with refcount > 1,
//!   safe because the readiness gate keeps every reader out until the
//!   fill completes.
//!
//! The old slot semantics are the degenerate case `block_size =
//! DEGENERATE_BLOCK` (one block covers any sequence): [`KvManager::new`]
//! builds exactly that, so every seed experiment reproduces unchanged.
//! Prefix sharing is meaningless there (one block holds private tokens
//! too), so `lookup_prefix` always misses on degenerate pools.
//!
//! Invariants (enforced with loud panics, exercised by
//! `tests/kv_properties.rs` and `tests/prefix_properties.rs`):
//! * a block's refcount equals its holders (request tables + prefix pins),
//! * `allocated() + available() == capacity()` always,
//! * releasing a free block (double free) panics,
//! * `fork_block` never hands out a block whose refcount exceeds one.

/// Block size that makes one block cover any sequence — the seed's
/// whole-request slot semantics.
pub const DEGENERATE_BLOCK: usize = usize::MAX;

/// A resident, pinned prefix block-run in the prefix index.
#[derive(Clone, Debug)]
struct PrefixEntry {
    /// Prefix identity (template hash).
    hash: u64,
    /// Prompt tokens the run covers.
    tokens: usize,
    /// The block run, in table order; the last block may be partial.
    blocks: Vec<usize>,
    /// False until the registrant's prefill has actually computed the
    /// covered tokens ([`KvManager::mark_prefix_ready`], driven by the
    /// shared state transition). Hits gate on this: KV that has not been
    /// produced yet cannot serve anyone — registration at admission only
    /// reserves and indexes the run.
    ready: bool,
    /// Prompt tokens the (re-)registrant's prefill has computed into the
    /// run so far ([`KvManager::note_prefix_fill`]). Waiters compare this
    /// across admission attempts: a fill that stops advancing means the
    /// registrant stalled, and bounded prefix-waits degrade the waiter to
    /// a full-price miss instead of blocking forever.
    filled: usize,
    /// Bumped whenever the request filling this run is preempted mid-fill
    /// ([`KvManager::note_prefix_filler_preempted`]) — waiters count the
    /// bump as an immediate stall tick even if the fill also advanced in
    /// the same interval.
    stall_events: u64,
    /// LRU stamp: the allocator's logical clock at registration and at
    /// every servable hit ([`KvManager::touch_prefix`]). Cold-prefix
    /// reclaim evicts the smallest stamp first.
    last_touch: u64,
}

#[derive(Clone, Debug)]
pub struct KvManager {
    /// Tokens per block.
    block_size: usize,
    /// Total blocks in the pool.
    num_blocks: usize,
    /// Free block ids (stack; lowest ids on top).
    free: Vec<usize>,
    /// ref_count[block] = live references (request tables + prefix pins);
    /// 0 while free.
    ref_count: Vec<u32>,
    /// Registered prefix runs, registration order. Few templates are live
    /// at once, so linear lookup beats a map here. Reclaim order is LRU by
    /// `last_touch`, not list position.
    prefixes: Vec<PrefixEntry>,
    /// Logical clock for the prefix LRU stamps.
    touch_clock: u64,
}

impl KvManager {
    /// Degenerate (seed-compatible) pool: `capacity` whole-request slots,
    /// i.e. blocks big enough that any sequence needs exactly one.
    pub fn new(capacity: usize) -> Self {
        Self::paged(capacity, DEGENERATE_BLOCK)
    }

    /// Paged pool: `num_blocks` blocks of `block_size` tokens each.
    pub fn paged(num_blocks: usize, block_size: usize) -> Self {
        assert!(block_size > 0, "block size must be positive");
        KvManager {
            block_size,
            num_blocks,
            free: (0..num_blocks).rev().collect(),
            ref_count: vec![0; num_blocks],
            prefixes: Vec::new(),
            touch_clock: 0,
        }
    }

    /// Total blocks in the pool.
    pub fn capacity(&self) -> usize {
        self.num_blocks
    }

    /// Total token capacity of the pool (saturating in degenerate mode).
    pub fn capacity_tokens(&self) -> usize {
        self.num_blocks.saturating_mul(self.block_size)
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn available(&self) -> usize {
        self.free.len()
    }

    /// Allocated blocks — each counted ONCE no matter how many sharers
    /// reference it (`allocated() + available() == capacity()`).
    pub fn allocated(&self) -> usize {
        self.num_blocks - self.free.len()
    }

    /// Blocks required to hold `tokens` KV entries (0 for 0 tokens;
    /// overflow-safe for the degenerate block size).
    pub fn blocks_needed(&self, tokens: usize) -> usize {
        if tokens == 0 {
            0
        } else {
            1 + (tokens - 1) / self.block_size
        }
    }

    /// Position of the LRU-coldest *cold* prefix: registered but with no
    /// live sharer (the pin is the only reference on every block), least
    /// recently hit first (`last_touch`; registration counts as a touch).
    /// The PR-3 policy reclaimed oldest-registered first, which could
    /// evict a template still taking hits while an abandoned one stayed
    /// resident.
    fn cold_prefix_pos(&self) -> Option<usize> {
        self.prefixes
            .iter()
            .enumerate()
            .filter(|(_, p)| p.blocks.iter().all(|&b| self.ref_count[b] == 1))
            .min_by_key(|(_, p)| p.last_touch)
            .map(|(i, _)| i)
    }

    /// Blocks recoverable by evicting cold prefixes.
    pub fn reclaimable(&self) -> usize {
        self.reclaimable_excluding(None)
    }

    /// [`reclaimable`](Self::reclaimable), excluding the prefix `hash` —
    /// an admission gate about to SHARE that run must not count its
    /// blocks as funds (sharing pins them hot).
    pub fn reclaimable_excluding(&self, hash: Option<u64>) -> usize {
        self.prefixes
            .iter()
            .filter(|p| Some(p.hash) != hash)
            .filter(|p| p.blocks.iter().all(|&b| self.ref_count[b] == 1))
            .map(|p| p.blocks.len())
            .sum()
    }

    /// Evict the oldest cold prefix, freeing its pinned blocks. Callers
    /// guarantee one exists.
    fn reclaim_one_cold(&mut self) {
        let pos = self.cold_prefix_pos().expect("reclaim without a cold prefix");
        let entry = self.prefixes.remove(pos);
        for b in entry.blocks {
            self.release(b);
        }
    }

    /// Allocate one block, lowest-index first, evicting a cold prefix if
    /// the free list is empty. Failure changes nothing.
    pub fn alloc(&mut self) -> Option<usize> {
        if self.free.is_empty() {
            if self.reclaimable() == 0 {
                return None;
            }
            self.reclaim_one_cold();
        }
        let block = self.free.pop()?;
        debug_assert_eq!(self.ref_count[block], 0);
        self.ref_count[block] = 1;
        Some(block)
    }

    /// Allocate `n` blocks all-or-nothing (cold prefixes are reclaimed
    /// under pressure; failure changes nothing).
    pub fn alloc_n(&mut self, n: usize) -> Option<Vec<usize>> {
        if self.free.len() + self.reclaimable() < n {
            return None;
        }
        while self.free.len() < n {
            self.reclaim_one_cold();
        }
        Some((0..n).map(|_| self.alloc().expect("checked free count")).collect())
    }

    /// Grow `blocks` until it covers `tokens` KV entries. All-or-nothing:
    /// on failure the table is left untouched and `false` is returned.
    pub fn extend_to(&mut self, blocks: &mut Vec<usize>, tokens: usize) -> bool {
        let need = self.blocks_needed(tokens);
        if blocks.len() >= need {
            return true;
        }
        match self.alloc_n(need - blocks.len()) {
            Some(more) => {
                blocks.extend(more);
                true
            }
            None => false,
        }
    }

    /// Release one reference. Frees the block only when the last reference
    /// drops, so releasing one sharer's table never frees a co-sharer's
    /// blocks. Panics on double-free — that is a scheduler bug we want
    /// loud.
    pub fn release(&mut self, block: usize) {
        assert!(self.ref_count[block] > 0, "double free of KV block {block}");
        self.ref_count[block] -= 1;
        if self.ref_count[block] == 0 {
            self.free.push(block);
        }
    }

    /// Release a whole block table (completion or preemption).
    pub fn release_seq(&mut self, blocks: Vec<usize>) {
        for b in blocks {
            self.release(b);
        }
    }

    /// Add one reference to an allocated block.
    pub fn share(&mut self, block: usize) {
        assert!(self.ref_count[block] > 0, "sharing a free KV block {block}");
        self.ref_count[block] += 1;
    }

    /// Add a reference to every block of `run` and return the shared table
    /// prefix a new sharer should start from.
    pub fn share_seq(&mut self, run: &[usize]) -> Vec<usize> {
        for &b in run {
            self.share(b);
        }
        run.to_vec()
    }

    /// Copy-on-write: the caller is about to append tokens into `block`.
    /// With a single reference the block is private and returned as-is;
    /// with sharers a fresh private copy is allocated and the caller's
    /// reference on the shared original is dropped — the original is never
    /// mutated while shared. `None` when the pool cannot supply the copy.
    pub fn fork_block(&mut self, block: usize) -> Option<usize> {
        assert!(self.ref_count[block] > 0, "fork of a free KV block {block}");
        if self.ref_count[block] == 1 {
            return Some(block);
        }
        let fresh = self.alloc()?;
        self.ref_count[block] -= 1;
        Some(fresh)
    }

    pub fn ref_count(&self, block: usize) -> usize {
        self.ref_count[block] as usize
    }

    /// True when `block` has more than one live reference.
    pub fn is_shared(&self, block: usize) -> bool {
        self.ref_count[block] > 1
    }

    /// Register a prefix block-run under `hash`, pinning every block (one
    /// index-owned reference) so the run stays resident while sharers come
    /// and go. `run` must be the caller's already-allocated table head
    /// covering exactly `tokens` prompt tokens.
    pub fn register_prefix(&mut self, hash: u64, tokens: usize, run: &[usize]) {
        assert!(!self.is_degenerate(), "prefix sharing requires a paged pool");
        assert!(tokens > 0, "registering an empty prefix");
        assert_eq!(
            run.len(),
            self.blocks_needed(tokens),
            "prefix run does not cover its {tokens} tokens"
        );
        assert!(self.lookup_prefix(hash).is_none(), "prefix {hash:#x} already registered");
        for &b in run {
            self.share(b);
        }
        self.touch_clock += 1;
        self.prefixes.push(PrefixEntry {
            hash,
            tokens,
            blocks: run.to_vec(),
            ready: false,
            filled: 0,
            stall_events: 0,
            last_touch: self.touch_clock,
        });
    }

    /// Resident run for `hash`, ready or not: `(covered tokens, block
    /// run)`. Always a miss on degenerate pools (a slot holds private
    /// tokens too). Admission hits must use
    /// [`lookup_servable`](Self::lookup_servable) — an unready run's KV
    /// is still being computed by its registrant.
    pub fn lookup_prefix(&self, hash: u64) -> Option<(usize, &[usize])> {
        if self.is_degenerate() {
            return None;
        }
        self.prefixes.iter().find(|p| p.hash == hash).map(|p| (p.tokens, p.blocks.as_slice()))
    }

    /// [`lookup_prefix`](Self::lookup_prefix) restricted to READY runs —
    /// the only ones whose KV exists and can serve a sharer.
    pub fn lookup_servable(&self, hash: u64) -> Option<(usize, &[usize])> {
        if self.is_degenerate() {
            return None;
        }
        self.prefixes
            .iter()
            .find(|p| p.hash == hash && p.ready)
            .map(|p| (p.tokens, p.blocks.as_slice()))
    }

    /// True once the registrant's prefill has produced the run's KV.
    pub fn is_prefix_ready(&self, hash: u64) -> bool {
        self.prefixes.iter().any(|p| p.hash == hash && p.ready)
    }

    /// Mark `hash`'s run servable — called by the state transition when
    /// the prefill that fills the run crosses its covered tokens.
    pub fn mark_prefix_ready(&mut self, hash: u64) {
        if let Some(p) = self.prefixes.iter_mut().find(|p| p.hash == hash) {
            p.ready = true;
        }
    }

    /// Registrant progress notification: the prefill filling `hash`'s run
    /// has computed `prefilled` prompt tokens. Driven by the shared state
    /// transition; waiters compare this across admission attempts to
    /// detect a stalled fill. No-op once the run is ready.
    pub fn note_prefix_fill(&mut self, hash: u64, prefilled: usize) {
        if let Some(p) = self.prefixes.iter_mut().find(|p| p.hash == hash && !p.ready) {
            p.filled = p.filled.max(prefilled.min(p.tokens));
        }
    }

    /// The request filling `hash`'s (unready) run was preempted: bump the
    /// run's stall-event counter so every waiter's bounded-wait clock
    /// ticks — even if the fill also advanced in the same interval.
    pub fn note_prefix_filler_preempted(&mut self, hash: u64) {
        if let Some(p) = self.prefixes.iter_mut().find(|p| p.hash == hash && !p.ready) {
            p.stall_events += 1;
        }
    }

    /// The waiter-visible progress of `hash`'s fill: `(tokens computed so
    /// far, stall events)`. `None` when the prefix is not registered.
    pub fn prefix_fill_state(&self, hash: u64) -> Option<(usize, u64)> {
        self.prefixes.iter().find(|p| p.hash == hash).map(|p| (p.filled, p.stall_events))
    }

    /// Stamp `hash`'s run as recently used (LRU reclaim order). Admission
    /// calls this on every share from the resident run.
    pub fn touch_prefix(&mut self, hash: u64) {
        self.touch_clock += 1;
        let clock = self.touch_clock;
        if let Some(p) = self.prefixes.iter_mut().find(|p| p.hash == hash) {
            p.last_touch = clock;
        }
    }

    /// Drop the index pin for `hash` (manual eviction; the allocator also
    /// reclaims cold prefixes itself under pressure). Returns whether the
    /// prefix was registered. Blocks still referenced by live sharers stay
    /// allocated until those sharers release.
    pub fn evict_prefix(&mut self, hash: u64) -> bool {
        let Some(pos) = self.prefixes.iter().position(|p| p.hash == hash) else {
            return false;
        };
        let entry = self.prefixes.remove(pos);
        for b in entry.blocks {
            self.release(b);
        }
        true
    }

    /// Number of registered (resident) prefixes.
    pub fn num_prefixes(&self) -> usize {
        self.prefixes.len()
    }

    /// Iterate registered prefixes as `(hash, tokens, run)` — metrics and
    /// the property suites introspect pins through this.
    pub fn registered_prefixes(&self) -> impl Iterator<Item = (u64, usize, &[usize])> {
        self.prefixes.iter().map(|p| (p.hash, p.tokens, p.blocks.as_slice()))
    }

    /// Tokens of KV content held resident by registered prefix runs
    /// (counted once each, however many sharers reference them).
    pub fn resident_prefix_tokens(&self) -> usize {
        self.prefixes.iter().map(|p| p.tokens).sum()
    }

    pub fn is_allocated(&self, block: usize) -> bool {
        self.ref_count[block] > 0
    }

    /// True for the seed-compatible whole-request-slot layout.
    pub fn is_degenerate(&self) -> bool {
        self.block_size == DEGENERATE_BLOCK
    }

    /// Serialize a finished block table into a transfer descriptor,
    /// releasing this pool's references — the disaggregation handoff edge:
    /// a prefill replica exports the prompt's KV, the descriptor crosses
    /// the interconnect (costed by `simulator::transfer::CopyFabric`), and
    /// the decode replica [`import_seq`](Self::import_seq)s it into its
    /// own pool. Shared blocks follow normal refcount rules: exporting one
    /// sharer's table never frees a co-sharer's blocks.
    pub fn export_seq(&mut self, blocks: Vec<usize>, kv_tokens: usize) -> KvExport {
        let n = blocks.len();
        self.release_seq(blocks);
        KvExport { kv_tokens, blocks: n }
    }

    /// Materialize a transfer descriptor into this pool: allocate a fresh
    /// block table covering the exported tokens, all-or-nothing (`None`
    /// under memory pressure — the caller retries admission later, it
    /// never wedges).
    pub fn import_seq(&mut self, export: &KvExport) -> Option<Vec<usize>> {
        self.alloc_n(self.blocks_needed(export.kv_tokens))
    }

    /// Internal fragmentation: tokens of allocated-but-unused capacity.
    /// `private_live_tokens` is the pool-wide count of live KV tokens in
    /// PRIVATE (unshared) block territory — callers pass
    /// `RequestPool::live_private_kv_tokens`, NOT the raw per-request sum,
    /// so a shared prefix block's content is counted once (via
    /// [`resident_prefix_tokens`](Self::resident_prefix_tokens)) rather
    /// than once per sharer. Reports 0 in degenerate mode — the sentinel
    /// block size is nominal, not memory.
    pub fn internal_fragmentation(&self, private_live_tokens: usize) -> usize {
        if self.is_degenerate() {
            return 0;
        }
        self.allocated()
            .saturating_mul(self.block_size)
            .saturating_sub(private_live_tokens + self.resident_prefix_tokens())
    }
}

/// A block table serialized for transfer between KV pools: what a prefill
/// replica hands a decode replica at disaggregation handoff. Carries the
/// logical content size (`kv_tokens`) and the source-side block count; the
/// destination re-blocks under its own `block_size`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KvExport {
    /// KV entries the exported table covered.
    pub kv_tokens: usize,
    /// Blocks the table held on the exporting pool.
    pub blocks: usize,
}

/// Per-stage KV ownership for pipeline parallelism: each of `stages`
/// pipeline stages holds only its own `layers / stages` layers' KV, so a
/// replica's KV memory is `stages` equal pools rather than one monolith.
///
/// Because a token's KV exists on EVERY stage (each stage's layers attend
/// over the full sequence) and every pool has the same block size and the
/// same per-stage capacity, the stages' block tables grow, fork and free
/// in lock-step — stage `k`'s allocator state is block-for-block identical
/// to stage 0's at all times. `StageKv` therefore keeps ONE canonical pool
/// and the stage count: allocation decisions made against the canonical
/// pool are exact for all stages, which is what keeps the pp=1 path (and
/// every existing pp>1 experiment) byte-identical to the single-pool
/// refactor predecessor. Byte accounting (`bytes_for_tokens`) is where the
/// split shows: each stage moves only its layer share over the wire.
#[derive(Clone, Debug)]
pub struct StageKv {
    pool: KvManager,
    stages: usize,
}

impl StageKv {
    /// Wrap a per-stage pool, mirrored across `stages` stages.
    pub fn mirrored(pool: KvManager, stages: usize) -> Self {
        assert!(stages > 0, "a replica has at least one pipeline stage");
        StageKv { pool, stages }
    }

    pub fn stages(&self) -> usize {
        self.stages
    }

    /// The canonical per-stage pool (stage 0; all stages are identical).
    pub fn pool(&self) -> &KvManager {
        &self.pool
    }

    pub fn pool_mut(&mut self) -> &mut KvManager {
        &mut self.pool
    }

    /// Blocks across all stages (each stage holds its own copy of the
    /// canonical pool's layout).
    pub fn total_blocks(&self) -> usize {
        self.pool.capacity() * self.stages
    }

    /// Blocks in use across all stages.
    pub fn total_allocated(&self) -> usize {
        self.pool.allocated() * self.stages
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_cycle() {
        let mut kv = KvManager::new(3);
        assert_eq!(kv.available(), 3);
        let a = kv.alloc().unwrap();
        let b = kv.alloc().unwrap();
        let c = kv.alloc().unwrap();
        assert_ne!(a, b);
        assert_ne!(b, c);
        assert!(kv.alloc().is_none());
        kv.release(b);
        assert_eq!(kv.available(), 1);
        assert_eq!(kv.alloc(), Some(b));
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut kv = KvManager::new(2);
        let a = kv.alloc().unwrap();
        kv.release(a);
        kv.release(a);
    }

    #[test]
    fn lowest_index_first() {
        let mut kv = KvManager::new(4);
        assert_eq!(kv.alloc(), Some(0));
        assert_eq!(kv.alloc(), Some(1));
    }

    #[test]
    fn degenerate_needs_one_block_for_any_length() {
        let kv = KvManager::new(4);
        assert_eq!(kv.blocks_needed(0), 0);
        assert_eq!(kv.blocks_needed(1), 1);
        assert_eq!(kv.blocks_needed(1_000_000), 1);
    }

    #[test]
    fn paged_block_arithmetic() {
        let kv = KvManager::paged(8, 16);
        assert_eq!(kv.blocks_needed(0), 0);
        assert_eq!(kv.blocks_needed(1), 1);
        assert_eq!(kv.blocks_needed(16), 1);
        assert_eq!(kv.blocks_needed(17), 2);
        assert_eq!(kv.blocks_needed(128), 8);
        assert_eq!(kv.capacity_tokens(), 128);
    }

    #[test]
    fn alloc_n_is_all_or_nothing() {
        let mut kv = KvManager::paged(4, 16);
        let got = kv.alloc_n(3).unwrap();
        assert_eq!(got.len(), 3);
        assert_eq!(kv.available(), 1);
        assert!(kv.alloc_n(2).is_none());
        assert_eq!(kv.available(), 1, "failed alloc must not leak");
        kv.release_seq(got);
        assert_eq!(kv.available(), 4);
    }

    #[test]
    fn extend_grows_table_token_granularly() {
        let mut kv = KvManager::paged(4, 16);
        let mut table = Vec::new();
        assert!(kv.extend_to(&mut table, 10));
        assert_eq!(table.len(), 1);
        assert!(kv.extend_to(&mut table, 16)); // still fits the block
        assert_eq!(table.len(), 1);
        assert!(kv.extend_to(&mut table, 17)); // crosses a block boundary
        assert_eq!(table.len(), 2);
        assert!(kv.extend_to(&mut table, 64)); // grows to the whole pool
        assert_eq!(table.len(), 4);
        assert!(!kv.extend_to(&mut table, 65), "over capacity must fail");
        assert_eq!(table.len(), 4, "failed extend must not change the table");
        kv.release_seq(table);
        assert_eq!(kv.available(), 4);
    }

    #[test]
    fn shared_blocks_survive_one_sharers_release() {
        let mut kv = KvManager::paged(4, 16);
        let run = kv.alloc_n(2).unwrap();
        let copy = kv.share_seq(&run);
        assert_eq!(copy, run);
        assert!(kv.is_shared(run[0]));
        assert_eq!(kv.ref_count(run[0]), 2);
        // one sharer releases: blocks stay allocated for the other
        kv.release_seq(copy);
        assert!(kv.is_allocated(run[0]) && kv.is_allocated(run[1]));
        assert_eq!(kv.available(), 2);
        kv.release_seq(run);
        assert_eq!(kv.available(), 4, "last release frees");
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn refcounted_release_still_panics_past_zero() {
        let mut kv = KvManager::paged(2, 16);
        let a = kv.alloc().unwrap();
        kv.share(a);
        kv.release(a);
        kv.release(a); // refcount hits 0: block is free
        kv.release(a); // one release too many
    }

    #[test]
    fn fork_is_identity_when_private_and_copies_when_shared() {
        let mut kv = KvManager::paged(4, 16);
        let a = kv.alloc().unwrap();
        // private: no copy, same block back
        assert_eq!(kv.fork_block(a), Some(a));
        assert_eq!(kv.ref_count(a), 1);
        // shared: a fresh private block, original keeps its other sharer
        kv.share(a);
        let b = kv.fork_block(a).unwrap();
        assert_ne!(b, a, "COW must not hand out a shared block");
        assert_eq!(kv.ref_count(a), 1, "caller's reference moved to the copy");
        assert_eq!(kv.ref_count(b), 1);
        kv.release(a);
        kv.release(b);
        assert_eq!(kv.available(), 4);
    }

    #[test]
    fn prefix_register_lookup_evict() {
        let mut kv = KvManager::paged(8, 16);
        assert!(kv.lookup_prefix(7).is_none());
        let run = kv.alloc_n(3).unwrap(); // covers 40 tokens (partial last)
        kv.register_prefix(7, 40, &run);
        assert_eq!(kv.num_prefixes(), 1);
        assert_eq!(kv.resident_prefix_tokens(), 40);
        let (tokens, resident) = kv.lookup_prefix(7).unwrap();
        assert_eq!(tokens, 40);
        assert_eq!(resident, &run[..]);
        // a freshly registered run is indexed but NOT servable: its KV is
        // still being computed by the registrant
        assert!(!kv.is_prefix_ready(7));
        assert!(kv.lookup_servable(7).is_none());
        kv.mark_prefix_ready(7);
        assert!(kv.is_prefix_ready(7));
        assert_eq!(kv.lookup_servable(7).unwrap().0, 40);
        // the registrant releases; the pin keeps the run resident
        kv.release_seq(run.clone());
        assert!(kv.lookup_prefix(7).is_some());
        assert_eq!(kv.allocated(), 3);
        assert!(kv.evict_prefix(7));
        assert!(!kv.evict_prefix(7));
        assert!(kv.lookup_servable(7).is_none());
        assert_eq!(kv.available(), 8);
    }

    #[test]
    fn cold_prefixes_are_reclaimed_under_pressure() {
        let mut kv = KvManager::paged(4, 16);
        let run = kv.alloc_n(2).unwrap();
        kv.register_prefix(1, 32, &run);
        kv.release_seq(run); // prefix now cold (pin only)
        assert_eq!(kv.available(), 2);
        assert_eq!(kv.reclaimable(), 2);
        // demanding more than the free list forces the cold eviction
        let got = kv.alloc_n(4).expect("reclaim funds the allocation");
        assert_eq!(got.len(), 4);
        assert_eq!(kv.num_prefixes(), 0, "cold prefix evicted");
        assert!(kv.lookup_prefix(1).is_none());
        kv.release_seq(got);
        // a HOT prefix (live sharer) is never reclaimed
        let run = kv.alloc_n(2).unwrap();
        kv.register_prefix(2, 32, &run);
        assert_eq!(kv.reclaimable(), 0);
        assert!(kv.alloc_n(3).is_none(), "hot prefix blocks stay pinned");
        assert_eq!(kv.num_prefixes(), 1);
        kv.release_seq(run);
    }

    /// The smarter-eviction satellite: cold-prefix reclaim is LRU by last
    /// hit, replacing PR-3's oldest-registered-first order. A hit on the
    /// OLDER registration must make the newer, never-hit run the victim —
    /// oldest-first would have evicted the hot template instead.
    #[test]
    fn cold_prefix_reclaim_is_lru_by_last_hit_not_oldest_first() {
        let mut kv = KvManager::paged(6, 16);
        let run_a = kv.alloc_n(2).unwrap();
        kv.register_prefix(1, 32, &run_a);
        let run_b = kv.alloc_n(2).unwrap();
        kv.register_prefix(2, 32, &run_b);
        kv.release_seq(run_a);
        kv.release_seq(run_b); // both cold (pin-only references)
        assert_eq!(kv.reclaimable(), 4);
        // a later hit stamps the OLDER registration hot
        kv.touch_prefix(1);
        // demanding past the 2 free blocks reclaims the LRU-coldest run
        let got = kv.alloc_n(4).expect("reclaim funds the allocation");
        assert!(kv.lookup_prefix(1).is_some(), "recently-hit run survives");
        assert!(
            kv.lookup_prefix(2).is_none(),
            "LRU-coldest run evicted (oldest-first would have kept it)"
        );
        kv.release_seq(got);
        assert!(kv.evict_prefix(1));
        assert_eq!(kv.available(), 6);
    }

    /// Fill-progress bookkeeping for bounded prefix-waits: notes advance
    /// the waiter-visible state, filler preemption bumps the stall
    /// counter, and a ready run stops tracking.
    #[test]
    fn fill_state_tracks_progress_and_filler_preemptions() {
        let mut kv = KvManager::paged(8, 16);
        assert_eq!(kv.prefix_fill_state(3), None);
        let run = kv.alloc_n(3).unwrap();
        kv.register_prefix(3, 40, &run);
        assert_eq!(kv.prefix_fill_state(3), Some((0, 0)));
        kv.note_prefix_fill(3, 16);
        assert_eq!(kv.prefix_fill_state(3), Some((16, 0)));
        // progress never regresses, and is capped at the covered tokens
        kv.note_prefix_fill(3, 8);
        kv.note_prefix_fill(3, 100);
        assert_eq!(kv.prefix_fill_state(3), Some((40, 0)));
        kv.note_prefix_filler_preempted(3);
        assert_eq!(kv.prefix_fill_state(3), Some((40, 1)));
        // a ready run no longer counts stalls (nobody waits on it)
        kv.mark_prefix_ready(3);
        kv.note_prefix_filler_preempted(3);
        assert_eq!(kv.prefix_fill_state(3), Some((40, 1)));
        kv.release_seq(run);
        kv.evict_prefix(3);
    }

    /// Export/import round-trip: the source pool's blocks come back to its
    /// free list, the descriptor carries the content size, and the
    /// destination re-blocks under its own block size — all-or-nothing
    /// under pressure.
    #[test]
    fn export_import_round_trip_conserves_blocks() {
        let mut src = KvManager::paged(8, 16);
        let mut table = Vec::new();
        assert!(src.extend_to(&mut table, 40)); // 3 blocks
        let ex = src.export_seq(table, 40);
        assert_eq!(ex, KvExport { kv_tokens: 40, blocks: 3 });
        assert_eq!(src.available(), 8, "export releases the source table");
        // destination uses a different block size: 40 tokens → 2×32
        let mut dst = KvManager::paged(4, 32);
        let imported = dst.import_seq(&ex).expect("fits");
        assert_eq!(imported.len(), 2);
        assert_eq!(dst.allocated(), 2);
        dst.release_seq(imported);
        // a full destination refuses whole, changing nothing
        let mut tiny = KvManager::paged(1, 16);
        assert!(tiny.import_seq(&ex).is_none());
        assert_eq!(tiny.available(), 1);
    }

    /// Exporting a sharer's table follows refcount rules — the co-sharer's
    /// blocks stay allocated.
    #[test]
    fn export_of_shared_table_never_frees_the_co_sharer() {
        let mut kv = KvManager::paged(4, 16);
        let run = kv.alloc_n(2).unwrap();
        let other = kv.share_seq(&run);
        let ex = kv.export_seq(other, 32);
        assert_eq!(ex.blocks, 2);
        assert!(kv.is_allocated(run[0]) && kv.is_allocated(run[1]));
        kv.release_seq(run);
        assert_eq!(kv.available(), 4);
    }

    /// StageKv mirrors one canonical pool across the stage count: the
    /// pp=1 wrapper is transparent, and multi-stage accounting multiplies.
    #[test]
    fn stage_kv_mirrors_the_canonical_pool() {
        let mut skv = StageKv::mirrored(KvManager::paged(8, 16), 4);
        assert_eq!(skv.stages(), 4);
        assert_eq!(skv.total_blocks(), 32);
        let table = skv.pool_mut().alloc_n(3).unwrap();
        assert_eq!(skv.total_allocated(), 12, "every stage holds its copy");
        assert_eq!(skv.pool().allocated(), 3);
        skv.pool_mut().release_seq(table);
        assert_eq!(skv.total_allocated(), 0);
    }

    #[test]
    fn degenerate_pools_never_hit_the_prefix_index() {
        let kv = KvManager::new(4);
        assert!(kv.lookup_prefix(0).is_none());
    }

    #[test]
    fn fragmentation_accounting() {
        let mut kv = KvManager::paged(8, 16);
        let mut table = Vec::new();
        assert!(kv.extend_to(&mut table, 20)); // 2 blocks = 32 tokens for 20 live
        assert_eq!(kv.internal_fragmentation(20), 12);
        assert!(kv.extend_to(&mut table, 32));
        assert_eq!(kv.internal_fragmentation(32), 0);
        kv.release_seq(table);
        // degenerate slots are nominal reservations, not wasted memory
        let kv = KvManager::new(2);
        assert!(kv.is_degenerate());
        assert_eq!(kv.internal_fragmentation(100), 0);
    }

    /// The shared-block occupancy fix: a block referenced by N sharers is
    /// one block of memory, so `allocated()` and fragmentation count it
    /// once — summing per-sharer footprints would overstate occupancy.
    #[test]
    fn shared_blocks_count_once_in_occupancy_and_fragmentation() {
        let mut kv = KvManager::paged(8, 16);
        // a 32-token prefix run, registered (pin) + two sharers
        let run = kv.alloc_n(2).unwrap();
        kv.register_prefix(9, 32, &run);
        let other = kv.share_seq(&run);
        // each sharer also holds one private block with 10 live tokens
        let mut a = run.clone();
        let mut b = other.clone();
        assert!(kv.extend_to(&mut a, 42));
        assert!(kv.extend_to(&mut b, 42));
        // memory truth: 2 shared + 2 private blocks, NOT 2 × (2 + 1)
        assert_eq!(kv.allocated(), 4);
        // fragmentation: private live = 2 × 10, shared content counted once
        // via the prefix index → 4 × 16 − (20 + 32) = 12
        assert_eq!(kv.internal_fragmentation(20), 12);
        kv.release_seq(a);
        kv.release_seq(b);
        assert!(kv.evict_prefix(9));
        assert_eq!(kv.available(), 8);
    }
}
