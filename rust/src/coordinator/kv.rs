//! KV-cache slot manager.
//!
//! Capacity comes from the §4.3.1 formula (see
//! [`crate::config::Deployment::max_batch_size`]); this module owns the
//! slot free-list and the invariants: a slot is held by at most one request,
//! and every admitted request holds exactly one slot.

#[derive(Clone, Debug)]
pub struct KvManager {
    capacity: usize,
    free: Vec<usize>,
    /// in_use[slot] = true while allocated.
    in_use: Vec<bool>,
}

impl KvManager {
    pub fn new(capacity: usize) -> Self {
        KvManager { capacity, free: (0..capacity).rev().collect(), in_use: vec![false; capacity] }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn available(&self) -> usize {
        self.free.len()
    }

    pub fn allocated(&self) -> usize {
        self.capacity - self.free.len()
    }

    /// Allocate a slot, lowest-index first.
    pub fn alloc(&mut self) -> Option<usize> {
        let slot = self.free.pop()?;
        debug_assert!(!self.in_use[slot]);
        self.in_use[slot] = true;
        Some(slot)
    }

    /// Release a slot. Panics on double-free — that is a scheduler bug we
    /// want loud.
    pub fn release(&mut self, slot: usize) {
        assert!(self.in_use[slot], "double free of KV slot {slot}");
        self.in_use[slot] = false;
        self.free.push(slot);
    }

    pub fn is_allocated(&self, slot: usize) -> bool {
        self.in_use[slot]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_cycle() {
        let mut kv = KvManager::new(3);
        assert_eq!(kv.available(), 3);
        let a = kv.alloc().unwrap();
        let b = kv.alloc().unwrap();
        let c = kv.alloc().unwrap();
        assert_ne!(a, b);
        assert_ne!(b, c);
        assert!(kv.alloc().is_none());
        kv.release(b);
        assert_eq!(kv.available(), 1);
        assert_eq!(kv.alloc(), Some(b));
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut kv = KvManager::new(2);
        let a = kv.alloc().unwrap();
        kv.release(a);
        kv.release(a);
    }

    #[test]
    fn lowest_index_first() {
        let mut kv = KvManager::new(4);
        assert_eq!(kv.alloc(), Some(0));
        assert_eq!(kv.alloc(), Some(1));
    }
}
