//! Serving metrics: per-iteration records plus per-request latencies.
//!
//! Decode time attribution follows the paper's §5.1.1 methodology: for a
//! decode-maximal batch the *marginal* decode time is the difference between
//! the hybrid batch and a prefill-only batch with the same chunk; the figure
//! harness derives decode throughput from these records.
//!
//! Per-request latency follows the DistServe/Sarathi-Serve evaluation
//! frame (arXiv 2401.09670, 2403.02310): **TTFT** (time to first token),
//! **TBT** (time between tokens) and **normalized latency** (end-to-end
//! latency per output token) are first-class, percentile-queryable
//! summaries — see [`LatencyReport`]. Preemptions (KV blocks ran out and a
//! request was swapped out) are counted both per iteration and in total.

use std::collections::VecDeque;
use std::io::Write as _;
use std::path::Path;

use super::pool::RequestPool;
use crate::costmodel::{BatchShape, OpBreakdown};
use crate::util::Summary;

/// Version stamped into every JSONL record this crate emits (iteration
/// records, transfer records, per-request breakdowns, the Chrome-trace
/// export) so consumers stop guessing the schema by PR vintage. Bump on
/// any field addition/removal/rename.
pub const JSONL_SCHEMA_VERSION: u32 = 2;

/// One executed iteration.
#[derive(Clone, Debug)]
pub struct IterationRecord {
    pub started_at: f64,
    pub elapsed: f64,
    pub shape: BatchShape,
    /// What the iteration would have cost with the decode lanes removed
    /// (None for non-hybrid batches). `elapsed − prefill_alone` is the
    /// marginal cost of the piggybacked decodes.
    pub prefill_alone: Option<f64>,
    /// Per-op split when the executor provides one (the simulator does).
    pub breakdown: Option<OpBreakdown>,
    /// KV blocks in use after this iteration's growth/release.
    pub kv_blocks_in_use: usize,
    /// Total KV blocks in the pool.
    pub kv_blocks_total: usize,
    /// Admitted, incomplete requests after this iteration.
    pub n_active: usize,
    /// Requests preempted (swapped out) during this iteration.
    pub preemptions: usize,
    /// Internal fragmentation after this iteration: allocated-but-unused
    /// KV tokens across all block tables (0 under degenerate slots).
    pub kv_frag_tokens: usize,
    /// Preemption transfer time charged this iteration: swap-in of resumed
    /// victims plus swap-out of evicted ones (KV bytes over the host link,
    /// or the recompute charge — see [`crate::coordinator::SwapCost`]).
    /// Not part of `elapsed` (pure execution time).
    pub swap_time: f64,
    /// Requests rejected as infeasible during this iteration's admission
    /// (only under [`InfeasiblePolicy::Reject`]).
    ///
    /// [`InfeasiblePolicy::Reject`]: crate::coordinator::sched::admission::InfeasiblePolicy
    pub rejections: usize,
    /// Admissions served from a resident shared prefix run during this
    /// iteration (copy-on-write prefix sharing). Partial hits — a radix
    /// match shallower than the request's full tagged prefix — count
    /// here too; `prefix_partial_hits` isolates them.
    pub prefix_hits: usize,
    /// The subset of `prefix_hits` served from a PARTIAL radix match
    /// (an ancestor of the request's content path, not its whole tagged
    /// prefix).
    pub prefix_partial_hits: usize,
    /// Prompt tokens those partial hits skipped — with
    /// `prefix_partial_hits` this gives the mean partial-hit depth.
    pub prefix_partial_hit_tokens: usize,
    /// Prefix waits that degraded to a full-price miss during this
    /// iteration's admission — the registrant made no progress for the
    /// gate's bounded-wait window, or the driver demoted a wedge.
    pub prefix_fallbacks: usize,
    /// Admission attempts spent waiting on an in-flight prefix fill
    /// during this iteration (cache-aware admission wait pressure).
    pub prefix_wait_iters: usize,
    /// KV tokens active requests are serving from shared prefix blocks
    /// after this iteration — memory that sharing saves versus private
    /// copies. (Shared blocks themselves are counted once in
    /// `kv_blocks_in_use`.)
    pub shared_kv_tokens: usize,
}

impl IterationRecord {
    /// Minimal record for tests/adapters that have no KV statistics.
    pub fn bare(started_at: f64, elapsed: f64, shape: BatchShape) -> Self {
        IterationRecord {
            started_at,
            elapsed,
            shape,
            prefill_alone: None,
            breakdown: None,
            kv_blocks_in_use: 0,
            kv_blocks_total: 0,
            n_active: 0,
            preemptions: 0,
            kv_frag_tokens: 0,
            swap_time: 0.0,
            rejections: 0,
            prefix_hits: 0,
            prefix_partial_hits: 0,
            prefix_partial_hit_tokens: 0,
            prefix_fallbacks: 0,
            prefix_wait_iters: 0,
            shared_kv_tokens: 0,
        }
    }

    /// End of this iteration on the simulated clock, including the swap
    /// charge (the next iteration cannot start before the transfer ends).
    pub fn ended_at(&self) -> f64 {
        self.started_at + self.elapsed + self.swap_time
    }

    /// One JSON-Lines record. `replica` appends the cluster trace's
    /// `"replica"` tag; `None` keeps the engine schema byte-identical.
    pub fn to_jsonl(&self, idx: usize, replica: Option<usize>) -> String {
        let core = format!(
            "{{\"iter\":{},\"schema_version\":{},\"start\":{:.6},\"elapsed\":{:.6},\
             \"prefill_chunks\":{},\"prefill_tokens\":{},\"decodes\":{},\
             \"total_tokens\":{},\"kv_blocks_in_use\":{},\"kv_blocks_total\":{},\
             \"kv_frag_tokens\":{},\"active\":{},\"preemptions\":{},\
             \"swap_time\":{:.6},\"rejections\":{},\"prefix_hits\":{},\
             \"prefix_fallbacks\":{},\"prefix_wait_iters\":{},\
             \"shared_kv_tokens\":{},\"prefix_partial_hits\":{},\
             \"prefix_partial_hit_tokens\":{}",
            idx,
            JSONL_SCHEMA_VERSION,
            self.started_at,
            self.elapsed,
            self.shape.prefill.len(),
            self.shape.prefill_tokens(),
            self.shape.decode_tokens(),
            self.shape.total_tokens(),
            self.kv_blocks_in_use,
            self.kv_blocks_total,
            self.kv_frag_tokens,
            self.n_active,
            self.preemptions,
            self.swap_time,
            self.rejections,
            self.prefix_hits,
            self.prefix_fallbacks,
            self.prefix_wait_iters,
            self.shared_kv_tokens,
            self.prefix_partial_hits,
            self.prefix_partial_hit_tokens,
        );
        match replica {
            Some(ri) => format!("{core},\"replica\":{ri}}}"),
            None => format!("{core}}}"),
        }
    }
}

/// Percentile-queryable per-request latency summaries, computed from the
/// request pool after (or during) a run.
#[derive(Clone, Debug, Default)]
pub struct LatencyReport {
    /// Time to first token: `first_token_at − arrival` per request.
    pub ttft: Summary,
    /// Time between tokens: every gap between consecutive output tokens.
    pub tbt: Summary,
    /// Normalized latency: `(completed_at − arrival) / decode_len`.
    pub normalized: Summary,
    /// Time each cache-waiting request spent blocked on an in-flight
    /// prefix fill before resolving (as a hit or as the fallback miss) —
    /// the wait-time histogram of bounded cache-aware admission. One
    /// sample per request that ever waited.
    pub prefix_wait: Summary,
}

impl LatencyReport {
    /// Aggregate over every completed request in the pool.
    pub fn from_pool(pool: &RequestPool) -> Self {
        Self::from_pools(std::slice::from_ref(pool))
    }

    /// Aggregate across several pools (e.g. one per pipeline stream —
    /// correct because token stamping is shared via
    /// [`crate::coordinator::StepApplier`]).
    pub fn from_pools(pools: &[RequestPool]) -> Self {
        let mut rep = LatencyReport::default();
        for p in pools {
            // TBT gaps are streamed into the pool's distribution at stamp
            // time (the per-request gap list no longer exists — it grew
            // without bound over long horizons), so merge, don't rescan.
            rep.tbt.merge(p.tbt_summary());
            for r in p.iter() {
                if let Some(first) = r.first_token_at {
                    rep.ttft.add(first - r.arrival);
                }
                if let Some(done) = r.completed_at {
                    rep.normalized.add((done - r.arrival) / r.spec.decode_len.max(1) as f64);
                }
                if r.prefix_wait_iters > 0 {
                    rep.prefix_wait.add(r.prefix_wait_time);
                }
            }
        }
        rep
    }
}

/// SLO-attainment count — the numerator of **goodput** (DistServe's
/// serving metric, arXiv 2401.09670 §2): a request counts iff it
/// completed (non-NaN completion), met the TTFT SLO and never exceeded
/// the TBT SLO on any token gap. NaN TTFT (request produced no first
/// token) fails the comparison and is excluded, as intended. The three
/// slices are indexed per request and must have equal length.
pub fn goodput_pass(
    ttft: &[f64],
    max_tbt: &[f64],
    completions: &[f64],
    ttft_slo: f64,
    tbt_slo: f64,
) -> usize {
    assert_eq!(ttft.len(), completions.len());
    assert_eq!(max_tbt.len(), completions.len());
    completions
        .iter()
        .zip(ttft.iter().zip(max_tbt.iter()))
        .filter(|(done, (t, g))| !done.is_nan() && **t <= ttft_slo && **g <= tbt_slo)
        .count()
}

/// Create a trace file's parent directory if it names one (shared by
/// every JSONL writer — engine metrics and the cluster's merged trace).
pub fn ensure_parent_dir(path: &Path) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    Ok(())
}

#[derive(Clone, Debug, Default)]
pub struct Metrics {
    /// Retained per-iteration records. Under the default retain-all mode
    /// this is the full history (index = global iteration index); a soak
    /// run caps it with [`set_retain_limit`](Self::set_retain_limit) and
    /// periodically [`drain_retained`](Self::drain_retained)s into a
    /// [`JsonlStream`], so memory stays bounded however long the horizon.
    /// Aggregate queries never rescan this — they read the streaming
    /// accumulators below, which see every record exactly once.
    iterations: VecDeque<IterationRecord>,
    /// Global index of `iterations[0]`: records `0..first_retained` were
    /// drained (flushed to a stream) or evicted by the retention cap.
    first_retained: usize,
    /// Retention cap (`None` = keep everything, the historical behavior).
    retain_limit: Option<usize>,
    /// Total preemption events across the run.
    pub preemptions: usize,
    /// Total requests rejected as infeasible across the run.
    pub rejections: usize,
    /// Total prefix-cache-hit admissions across the run (partial radix
    /// hits included).
    pub prefix_hits: usize,
    /// Total partial-radix-hit admissions across the run.
    pub prefix_partial_hits: usize,
    /// Total prompt tokens served by those partial hits.
    pub prefix_partial_hit_tokens: usize,
    /// Total prefix waits degraded to full-price misses across the run
    /// (bounded-wait expiry + wedge demotion).
    pub prefix_fallbacks: usize,
    /// Total admission attempts spent waiting on a prefix fill.
    pub prefix_wait_iterations: usize,
    // Streaming accumulators, folded in by `record`: the aggregate
    // queries below used to rescan `iterations` per call, which turned
    // every per-iteration stat lookup on the simulator hot path into an
    // O(history) walk.
    time_acc: f64,
    swap_acc: f64,
    first_started: Option<f64>,
    last_ended: f64,
    prefill_tokens_acc: usize,
    decode_tokens_acc: usize,
    decode_time_acc: f64,
    decode_attr_tokens: usize,
    peak_active_acc: usize,
    peak_kv_blocks_acc: usize,
    peak_shared_kv_acc: usize,
    op_acc: OpBreakdown,
    iter_time: Summary,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, rec: IterationRecord) {
        self.preemptions += rec.preemptions;
        self.rejections += rec.rejections;
        self.prefix_hits += rec.prefix_hits;
        self.prefix_partial_hits += rec.prefix_partial_hits;
        self.prefix_partial_hit_tokens += rec.prefix_partial_hit_tokens;
        self.prefix_fallbacks += rec.prefix_fallbacks;
        self.prefix_wait_iterations += rec.prefix_wait_iters;
        self.time_acc += rec.elapsed;
        self.swap_acc += rec.swap_time;
        if self.first_started.is_none() {
            self.first_started = Some(rec.started_at);
        }
        // max, not overwrite: interleaved streams (pipeline micro-batches,
        // merged cluster traces) record out of start order, and a late
        // record for an EARLIER iteration used to truncate the span.
        self.last_ended = self.last_ended.max(rec.ended_at());
        self.prefill_tokens_acc += rec.shape.prefill_tokens();
        let d = rec.shape.decode_tokens();
        self.decode_tokens_acc += d;
        if d > 0 {
            // §5.1.1 attribution: marginal over prefill-alone for hybrid
            // batches, all-in otherwise
            self.decode_time_acc += match rec.prefill_alone {
                Some(alone) => (rec.elapsed - alone).max(0.0),
                None => rec.elapsed,
            };
            self.decode_attr_tokens += d;
        }
        self.peak_active_acc = self.peak_active_acc.max(rec.n_active);
        self.peak_kv_blocks_acc = self.peak_kv_blocks_acc.max(rec.kv_blocks_in_use);
        self.peak_shared_kv_acc = self.peak_shared_kv_acc.max(rec.shared_kv_tokens);
        if let Some(b) = &rec.breakdown {
            self.op_acc.preproj += b.preproj;
            self.op_acc.attn_prefill += b.attn_prefill;
            self.op_acc.attn_decode += b.attn_decode;
            self.op_acc.postproj += b.postproj;
            self.op_acc.ffn_ln1 += b.ffn_ln1;
            self.op_acc.ffn_ln2 += b.ffn_ln2;
            self.op_acc.others += b.others;
            self.op_acc.comm += b.comm;
        }
        self.iter_time.add(rec.elapsed);
        self.iterations.push_back(rec);
        if let Some(cap) = self.retain_limit {
            while self.iterations.len() > cap {
                self.iterations.pop_front();
                self.first_retained += 1;
            }
        }
    }

    /// Cap retained [`IterationRecord`]s at `cap` (oldest evicted first);
    /// `None` restores keep-everything. Aggregates are unaffected — they
    /// stream. Drain-before-evict (e.g. into a [`JsonlStream`]) is the
    /// caller's job if the trace must be lossless.
    pub fn set_retain_limit(&mut self, cap: Option<usize>) {
        self.retain_limit = cap;
        if let Some(cap) = cap {
            while self.iterations.len() > cap {
                self.iterations.pop_front();
                self.first_retained += 1;
            }
        }
    }

    /// Total iterations ever recorded (drained/evicted ones included).
    pub fn recorded_count(&self) -> usize {
        self.first_retained + self.iterations.len()
    }

    /// Records still held in memory.
    pub fn retained_len(&self) -> usize {
        self.iterations.len()
    }

    /// Global index of the oldest retained record.
    pub fn first_retained(&self) -> usize {
        self.first_retained
    }

    /// Iterate the retained records, oldest first.
    pub fn iter_records(&self) -> impl Iterator<Item = &IterationRecord> {
        self.iterations.iter()
    }

    /// The most recent record, if any is retained.
    pub fn last_record(&self) -> Option<&IterationRecord> {
        self.iterations.back()
    }

    /// Record for GLOBAL iteration index `idx`. Panics if that record was
    /// drained or evicted — callers indexing history must retain it.
    pub fn record_at(&self, idx: usize) -> &IterationRecord {
        assert!(
            idx >= self.first_retained,
            "iteration record {idx} was drained (oldest retained: {})",
            self.first_retained
        );
        &self.iterations[idx - self.first_retained]
    }

    /// Take every retained record out (oldest first), advancing the
    /// retained window past them — the soak flush path: drain to a
    /// [`JsonlStream`], keep the accumulators, free the memory.
    pub fn drain_retained(&mut self) -> Vec<IterationRecord> {
        self.first_retained += self.iterations.len();
        self.iterations.drain(..).collect()
    }

    /// Busy time: sum of iteration execution times (idle gaps and swap
    /// transfers excluded).
    pub fn total_time(&self) -> f64 {
        self.time_acc
    }

    /// Total preemption transfer time (swap-out + swap-in / recompute)
    /// across the run.
    pub fn total_swap_time(&self) -> f64 {
        self.swap_acc
    }

    /// Wall-clock span of the run on the simulated clock: first iteration
    /// start to last iteration end, INCLUDING idle gaps (open-loop
    /// arrivals) and swap transfers. This is the honest denominator for
    /// serving throughput — [`total_time`](Self::total_time) counts only
    /// busy iterations, so Poisson idle gaps would vanish from it and
    /// overstate throughput.
    pub fn wall_clock_span(&self) -> f64 {
        match self.first_started {
            Some(first) => self.last_ended - first,
            None => 0.0,
        }
    }

    pub fn total_prefill_tokens(&self) -> usize {
        self.prefill_tokens_acc
    }

    pub fn total_decode_tokens(&self) -> usize {
        self.decode_tokens_acc
    }

    /// Busy-time throughput, tokens per second over iteration time only
    /// (prefill + decode tokens — the paper's normalized-throughput
    /// metric for closed-loop, always-busy experiments).
    pub fn throughput(&self) -> f64 {
        let t = self.total_time();
        if t == 0.0 {
            0.0
        } else {
            (self.total_prefill_tokens() + self.total_decode_tokens()) as f64 / t
        }
    }

    /// Wall-clock throughput: tokens over [`wall_clock_span`]
    /// (idle gaps and swap transfers in the denominator) — the right
    /// number for open-loop `serve`/`simulate` runs.
    ///
    /// [`wall_clock_span`]: Self::wall_clock_span
    pub fn wall_throughput(&self) -> f64 {
        let t = self.wall_clock_span();
        if t == 0.0 {
            0.0
        } else {
            (self.total_prefill_tokens() + self.total_decode_tokens()) as f64 / t
        }
    }

    /// Mean time to produce one decode token, §5.1.1 attribution:
    /// decode-only iterations contribute elapsed/lanes; hybrid iterations
    /// contribute their marginal cost over the prefill-alone run.
    pub fn decode_time_per_token(&self) -> f64 {
        if self.decode_attr_tokens == 0 {
            0.0
        } else {
            self.decode_time_acc / self.decode_attr_tokens as f64
        }
    }

    /// Decode throughput (tokens/s) under the same attribution.
    pub fn decode_throughput(&self) -> f64 {
        let t = self.decode_time_per_token();
        if t == 0.0 {
            0.0
        } else {
            1.0 / t
        }
    }

    /// Aggregate per-op breakdown across all iterations ever recorded
    /// (streamed at record time; retention does not lose op time).
    pub fn op_totals(&self) -> OpBreakdown {
        self.op_acc.clone()
    }

    /// Iteration-time spread — uniform work units (SARATHI's goal) show a
    /// tight distribution. Streamed at record time, so it covers every
    /// iteration ever recorded and is bounded-memory past
    /// [`Summary::EXACT_CAP`](crate::util::Summary::EXACT_CAP) samples.
    pub fn iteration_time_summary(&self) -> Summary {
        self.iter_time.clone()
    }

    /// Peak concurrently-admitted requests across the run.
    pub fn peak_active(&self) -> usize {
        self.peak_active_acc
    }

    /// Peak KV occupancy across the run, in blocks — a shared block counts
    /// once however many requests reference it (the allocator's refcounted
    /// `allocated()` feeds the per-iteration records).
    pub fn peak_kv_blocks_in_use(&self) -> usize {
        self.peak_kv_blocks_acc
    }

    /// Peak KV tokens served from shared prefix blocks at any iteration.
    pub fn peak_shared_kv_tokens(&self) -> usize {
        self.peak_shared_kv_acc
    }

    /// Write one JSON object per RETAINED iteration (JSON-Lines) — the
    /// simulator trace idiom: shape, elapsed time, KV occupancy and
    /// preemptions per record, consumable by any ad-hoc analysis script.
    /// Indices are global, so a windowed trace's `iter` fields still name
    /// the true iteration numbers. Long-horizon runs should stream with
    /// [`JsonlStream`] + [`drain_retained`](Self::drain_retained) instead.
    pub fn write_jsonl(&self, path: &Path) -> std::io::Result<()> {
        ensure_parent_dir(path)?;
        let mut out = std::io::BufWriter::new(std::fs::File::create(path)?);
        for (i, r) in self.iterations.iter().enumerate() {
            writeln!(out, "{}", r.to_jsonl(self.first_retained + i, None))?;
        }
        Ok(())
    }
}

/// Append-mode JSON-Lines trace writer for long-horizon runs: records are
/// written as they are [`drain_retained`](Metrics::drain_retained)ed, so
/// the full trace lands on disk while memory holds only the current
/// window. Global indices are assigned here, monotonically.
#[derive(Debug)]
pub struct JsonlStream {
    out: std::io::BufWriter<std::fs::File>,
    next_idx: usize,
    replica: Option<usize>,
}

impl JsonlStream {
    /// Create (truncate) `path` and stream records to it. `replica` tags
    /// every record like the cluster trace schema; `None` keeps the engine
    /// schema byte-identical to [`Metrics::write_jsonl`].
    pub fn create(path: &Path, replica: Option<usize>) -> std::io::Result<Self> {
        ensure_parent_dir(path)?;
        let out = std::io::BufWriter::new(std::fs::File::create(path)?);
        Ok(JsonlStream { out, next_idx: 0, replica })
    }

    /// Append one record under the next global index.
    pub fn append(&mut self, rec: &IterationRecord) -> std::io::Result<()> {
        writeln!(self.out, "{}", rec.to_jsonl(self.next_idx, self.replica))?;
        self.next_idx += 1;
        Ok(())
    }

    /// Records written so far.
    pub fn written(&self) -> usize {
        self.next_idx
    }

    /// Flush buffered lines to disk (progress checkpoints; also called on
    /// drop by the BufWriter).
    pub fn flush(&mut self) -> std::io::Result<()> {
        self.out.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::BatchShape;

    fn rec(elapsed: f64, shape: BatchShape, alone: Option<f64>) -> IterationRecord {
        IterationRecord { prefill_alone: alone, ..IterationRecord::bare(0.0, elapsed, shape) }
    }

    #[test]
    fn throughput_counts_all_tokens() {
        let mut m = Metrics::new();
        m.record(rec(1.0, BatchShape::prefill_only(&[(100, 0)]), None));
        m.record(rec(1.0, BatchShape::decode_only(&[10, 10]), None));
        assert_eq!(m.total_prefill_tokens(), 100);
        assert_eq!(m.total_decode_tokens(), 2);
        assert!((m.throughput() - 51.0).abs() < 1e-9);
    }

    #[test]
    fn marginal_attribution_for_hybrid() {
        let mut m = Metrics::new();
        // hybrid cost 1.2, prefill alone 1.0 -> 0.2 over 4 decodes = 0.05/tok
        m.record(rec(1.2, BatchShape::hybrid(96, 0, &[5; 4]), Some(1.0)));
        assert!((m.decode_time_per_token() - 0.05).abs() < 1e-9);
        // decode-only batch: whole time attributed
        m.record(rec(0.8, BatchShape::decode_only(&[5; 4]), None));
        assert!((m.decode_time_per_token() - (0.2 + 0.8) / 8.0).abs() < 1e-9);
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = Metrics::new();
        assert_eq!(m.throughput(), 0.0);
        assert_eq!(m.decode_time_per_token(), 0.0);
        assert_eq!(m.preemptions, 0);
        assert_eq!(m.peak_active(), 0);
    }

    #[test]
    fn preemptions_accumulate() {
        let mut m = Metrics::new();
        let mut r = rec(1.0, BatchShape::decode_only(&[4]), None);
        r.preemptions = 2;
        m.record(r);
        let mut r = rec(1.0, BatchShape::decode_only(&[4]), None);
        r.preemptions = 1;
        r.n_active = 7;
        m.record(r);
        assert_eq!(m.preemptions, 3);
        assert_eq!(m.peak_active(), 7);
    }

    #[test]
    fn wall_clock_span_includes_idle_and_swap_time() {
        let mut m = Metrics::new();
        // iteration 0: [0, 1], then a 3s idle gap, then [4, 5] + 0.5s swap
        m.record(rec(1.0, BatchShape::prefill_only(&[(100, 0)]), None));
        let mut r = rec(1.0, BatchShape::decode_only(&[10, 10]), None);
        r.started_at = 4.0;
        r.swap_time = 0.5;
        m.record(r);
        assert!((m.total_time() - 2.0).abs() < 1e-12, "busy time sums elapsed only");
        assert!((m.wall_clock_span() - 5.5).abs() < 1e-12);
        assert!((m.total_swap_time() - 0.5).abs() < 1e-12);
        // 102 tokens: busy throughput 51/s, wall throughput pays idle+swap
        assert!((m.throughput() - 51.0).abs() < 1e-9);
        assert!((m.wall_throughput() - 102.0 / 5.5).abs() < 1e-9);
    }

    #[test]
    fn rejections_accumulate() {
        let mut m = Metrics::new();
        let mut r = rec(1.0, BatchShape::decode_only(&[4]), None);
        r.rejections = 2;
        m.record(r);
        assert_eq!(m.rejections, 2);
    }

    #[test]
    fn prefix_hits_and_shared_occupancy_accumulate() {
        let mut m = Metrics::new();
        let mut r = rec(1.0, BatchShape::decode_only(&[4]), None);
        r.prefix_hits = 3;
        r.shared_kv_tokens = 96;
        r.kv_blocks_in_use = 7;
        m.record(r);
        let mut r = rec(1.0, BatchShape::decode_only(&[4]), None);
        r.prefix_hits = 1;
        r.prefix_partial_hits = 1;
        r.prefix_partial_hit_tokens = 32;
        r.shared_kv_tokens = 64;
        r.kv_blocks_in_use = 5;
        m.record(r);
        assert_eq!(m.prefix_hits, 4);
        assert_eq!(m.prefix_partial_hits, 1);
        assert_eq!(m.prefix_partial_hit_tokens, 32);
        assert_eq!(m.peak_shared_kv_tokens(), 96);
        assert_eq!(m.peak_kv_blocks_in_use(), 7);
        // the partial-hit counters land in the JSONL schema
        let line = m.last_record().unwrap().to_jsonl(1, None);
        assert!(line.contains("\"prefix_partial_hits\":1"));
        assert!(line.contains("\"prefix_partial_hit_tokens\":32"));
    }

    #[test]
    fn prefix_fallbacks_and_wait_iterations_accumulate_and_land_in_jsonl() {
        let mut m = Metrics::new();
        let mut r = rec(1.0, BatchShape::decode_only(&[4]), None);
        r.prefix_fallbacks = 1;
        r.prefix_wait_iters = 3;
        m.record(r);
        let mut r = rec(1.0, BatchShape::decode_only(&[4]), None);
        r.prefix_wait_iters = 2;
        m.record(r);
        assert_eq!(m.prefix_fallbacks, 1);
        assert_eq!(m.prefix_wait_iterations, 5);
        let path = std::env::temp_dir().join("sarathi_test_fallback_trace.jsonl");
        m.write_jsonl(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let first = text.lines().next().unwrap();
        assert!(first.contains("\"prefix_fallbacks\":1"));
        assert!(first.contains("\"prefix_wait_iters\":3"));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn latency_report_includes_the_prefix_wait_histogram() {
        use crate::workload::RequestSpec;
        let mut pool = RequestPool::new();
        pool.push(RequestSpec { prompt_len: 4, decode_len: 2, arrival: 0.0, prefix: None });
        pool.push(RequestSpec { prompt_len: 4, decode_len: 2, arrival: 0.0, prefix: None });
        {
            let r = pool.get_mut(0);
            r.prefix_wait_iters = 3;
            r.prefix_wait_time = 0.75;
        }
        let rep = LatencyReport::from_pool(&pool);
        assert_eq!(rep.prefix_wait.count(), 1, "only requests that waited contribute");
        assert!((rep.prefix_wait.mean() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn latency_report_from_pool() {
        use crate::workload::RequestSpec;
        let mut pool = RequestPool::new();
        pool.push(RequestSpec { prompt_len: 4, decode_len: 2, arrival: 1.0, prefix: None });
        pool.admit(0, vec![0], 1.0);
        {
            let r = pool.get_mut(0);
            r.prefilled = 4;
            r.decoded = 2;
            r.first_token_at = Some(1.5);
        }
        pool.stamp_token(0, 1.5);
        pool.stamp_token(0, 1.7);
        pool.complete(0, 1.7);
        let rep = LatencyReport::from_pool(&pool);
        assert_eq!(rep.ttft.count(), 1);
        assert!((rep.ttft.mean() - 0.5).abs() < 1e-12);
        assert_eq!(rep.tbt.count(), 1);
        assert!((rep.tbt.mean() - 0.2).abs() < 1e-9);
        assert!((rep.normalized.mean() - 0.35).abs() < 1e-9);
    }

    /// Satellite regression: `record` used to OVERWRITE `last_ended` with
    /// each record's end, so an out-of-start-order record for an earlier
    /// iteration (pipeline micro-batches, merged cluster traces) shrank
    /// the wall-clock span.
    #[test]
    fn out_of_order_records_never_shrink_the_wall_clock_span() {
        let mut m = Metrics::new();
        let mut late = rec(1.0, BatchShape::decode_only(&[4]), None);
        late.started_at = 10.0; // ends at 11.0
        m.record(late);
        let mut early = rec(2.0, BatchShape::decode_only(&[4]), None);
        early.started_at = 3.0; // ends at 5.0 — must NOT truncate the span
        m.record(early);
        assert!((m.wall_clock_span() - (11.0 - 3.0)).abs() < 1e-12, "span takes the max end");
        // first_started still tracks the first RECORDED start, as before
        let mut m2 = Metrics::new();
        let mut a = rec(1.0, BatchShape::decode_only(&[4]), None);
        a.started_at = 3.0;
        m2.record(a);
        let mut b = rec(1.0, BatchShape::decode_only(&[4]), None);
        b.started_at = 10.0;
        m2.record(b);
        assert!((m2.wall_clock_span() - 8.0).abs() < 1e-12);
    }

    #[test]
    fn retention_cap_bounds_records_but_keeps_aggregates() {
        let mut m = Metrics::new();
        m.set_retain_limit(Some(3));
        for i in 0..10 {
            let mut r = rec(1.0, BatchShape::decode_only(&[4]), None);
            r.started_at = i as f64;
            m.record(r);
        }
        assert_eq!(m.retained_len(), 3);
        assert_eq!(m.recorded_count(), 10);
        assert_eq!(m.first_retained(), 7);
        // aggregates still cover all 10 iterations
        assert_eq!(m.total_decode_tokens(), 10);
        assert!((m.total_time() - 10.0).abs() < 1e-12);
        assert_eq!(m.iteration_time_summary().count(), 10);
        assert!((m.wall_clock_span() - 10.0).abs() < 1e-12);
        // global indexing: record 7 is the oldest retained
        assert!((m.record_at(7).started_at - 7.0).abs() < 1e-12);
        assert!((m.last_record().unwrap().started_at - 9.0).abs() < 1e-12);
        // the windowed JSONL keeps global indices
        let path = std::env::temp_dir().join("sarathi_test_windowed_trace.jsonl");
        m.write_jsonl(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.lines().next().unwrap().starts_with("{\"iter\":7,"));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn drain_retained_feeds_a_jsonl_stream_losslessly() {
        let mut m = Metrics::new();
        let path = std::env::temp_dir().join("sarathi_test_streamed_trace.jsonl");
        let mut stream = JsonlStream::create(&path, None).unwrap();
        for chunk in 0..3 {
            for i in 0..4 {
                let mut r = rec(0.5, BatchShape::decode_only(&[4]), None);
                r.started_at = (chunk * 4 + i) as f64;
                m.record(r);
            }
            for r in m.drain_retained() {
                stream.append(&r).unwrap();
            }
            assert_eq!(m.retained_len(), 0, "drain empties the window");
        }
        stream.flush().unwrap();
        assert_eq!(stream.written(), 12);
        assert_eq!(m.recorded_count(), 12);
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 12);
        assert!(lines[0].starts_with("{\"iter\":0,"));
        assert!(lines[11].starts_with("{\"iter\":11,"));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn goodput_counts_only_completed_requests_inside_both_slos() {
        let ttft = [0.5, 2.0, 0.5, 0.5, f64::NAN];
        let max_tbt = [0.1, 0.1, 0.5, 0.1, 0.1];
        let done = [10.0, 10.0, 10.0, f64::NAN, 10.0];
        // req 0 passes; 1 misses TTFT; 2 misses TBT; 3 never completed;
        // 4 has no first token (NaN TTFT fails the comparison)
        assert_eq!(goodput_pass(&ttft, &max_tbt, &done, 1.0, 0.2), 1);
        assert_eq!(goodput_pass(&ttft, &max_tbt, &done, 5.0, 1.0), 3);
        assert_eq!(goodput_pass(&[], &[], &[], 1.0, 1.0), 0);
    }

    #[test]
    fn jsonl_record_takes_an_optional_replica_tag() {
        let r = rec(0.5, BatchShape::decode_only(&[4]), None);
        let plain = r.to_jsonl(3, None);
        assert!(plain.starts_with("{\"iter\":3,"));
        assert!(!plain.contains("replica"), "engine schema is unchanged");
        assert!(plain.ends_with('}'));
        let tagged = r.to_jsonl(3, Some(2));
        assert!(tagged.ends_with(",\"replica\":2}"));
        // the tag is strictly additive: identical record prefix
        assert_eq!(tagged[..plain.len() - 1], plain[..plain.len() - 1]);
    }

    #[test]
    fn jsonl_writes_one_record_per_iteration() {
        let mut m = Metrics::new();
        m.record(rec(0.5, BatchShape::hybrid(96, 0, &[5; 2]), Some(0.4)));
        m.record(rec(0.25, BatchShape::decode_only(&[6; 3]), None));
        let path = std::env::temp_dir().join("sarathi_test_trace.jsonl");
        m.write_jsonl(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].starts_with("{\"iter\":0,"));
        assert!(lines[0].contains("\"prefill_tokens\":96"));
        assert!(lines[1].contains("\"decodes\":3"));
        assert!(lines[1].ends_with('}'));
    }
}
