//! Serving metrics: per-iteration records plus per-request latencies.
//!
//! Decode time attribution follows the paper's §5.1.1 methodology: for a
//! decode-maximal batch the *marginal* decode time is the difference between
//! the hybrid batch and a prefill-only batch with the same chunk; the figure
//! harness derives decode throughput from these records.

use crate::costmodel::{BatchShape, OpBreakdown};
use crate::util::Summary;

/// One executed iteration.
#[derive(Clone, Debug)]
pub struct IterationRecord {
    pub started_at: f64,
    pub elapsed: f64,
    pub shape: BatchShape,
    /// What the iteration would have cost with the decode lanes removed
    /// (None for non-hybrid batches). `elapsed − prefill_alone` is the
    /// marginal cost of the piggybacked decodes.
    pub prefill_alone: Option<f64>,
    /// Per-op split when the executor provides one (the simulator does).
    pub breakdown: Option<OpBreakdown>,
}

#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub iterations: Vec<IterationRecord>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, rec: IterationRecord) {
        self.iterations.push(rec);
    }

    pub fn total_time(&self) -> f64 {
        self.iterations.iter().map(|r| r.elapsed).sum()
    }

    pub fn total_prefill_tokens(&self) -> usize {
        self.iterations.iter().map(|r| r.shape.prefill_tokens()).sum()
    }

    pub fn total_decode_tokens(&self) -> usize {
        self.iterations.iter().map(|r| r.shape.decode_tokens()).sum()
    }

    /// End-to-end throughput, tokens per second (prefill + decode tokens —
    /// the paper's normalized-throughput metric).
    pub fn throughput(&self) -> f64 {
        let t = self.total_time();
        if t == 0.0 {
            0.0
        } else {
            (self.total_prefill_tokens() + self.total_decode_tokens()) as f64 / t
        }
    }

    /// Mean time to produce one decode token, §5.1.1 attribution:
    /// decode-only iterations contribute elapsed/lanes; hybrid iterations
    /// contribute their marginal cost over the prefill-alone run.
    pub fn decode_time_per_token(&self) -> f64 {
        let mut time = 0.0;
        let mut tokens = 0usize;
        for r in &self.iterations {
            let d = r.shape.decode_tokens();
            if d == 0 {
                continue;
            }
            match r.prefill_alone {
                Some(alone) => time += (r.elapsed - alone).max(0.0),
                None if r.shape.prefill.is_empty() => time += r.elapsed,
                None => time += r.elapsed, // hybrid without attribution: all-in
            }
            tokens += d;
        }
        if tokens == 0 {
            0.0
        } else {
            time / tokens as f64
        }
    }

    /// Decode throughput (tokens/s) under the same attribution.
    pub fn decode_throughput(&self) -> f64 {
        let t = self.decode_time_per_token();
        if t == 0.0 {
            0.0
        } else {
            1.0 / t
        }
    }

    /// Aggregate per-op breakdown across all iterations.
    pub fn op_totals(&self) -> OpBreakdown {
        let mut acc = OpBreakdown::default();
        for r in &self.iterations {
            if let Some(b) = &r.breakdown {
                acc.preproj += b.preproj;
                acc.attn_prefill += b.attn_prefill;
                acc.attn_decode += b.attn_decode;
                acc.postproj += b.postproj;
                acc.ffn_ln1 += b.ffn_ln1;
                acc.ffn_ln2 += b.ffn_ln2;
                acc.others += b.others;
                acc.comm += b.comm;
            }
        }
        acc
    }

    /// Iteration-time spread — uniform work units (SARATHI's goal) show a
    /// tight distribution.
    pub fn iteration_time_summary(&self) -> Summary {
        let mut s = Summary::new();
        for r in &self.iterations {
            s.add(r.elapsed);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::BatchShape;

    fn rec(elapsed: f64, shape: BatchShape, alone: Option<f64>) -> IterationRecord {
        IterationRecord { started_at: 0.0, elapsed, shape, prefill_alone: alone, breakdown: None }
    }

    #[test]
    fn throughput_counts_all_tokens() {
        let mut m = Metrics::new();
        m.record(rec(1.0, BatchShape::prefill_only(&[(100, 0)]), None));
        m.record(rec(1.0, BatchShape::decode_only(&[10, 10]), None));
        assert_eq!(m.total_prefill_tokens(), 100);
        assert_eq!(m.total_decode_tokens(), 2);
        assert!((m.throughput() - 51.0).abs() < 1e-9);
    }

    #[test]
    fn marginal_attribution_for_hybrid() {
        let mut m = Metrics::new();
        // hybrid cost 1.2, prefill alone 1.0 -> 0.2 over 4 decodes = 0.05/tok
        m.record(rec(1.2, BatchShape::hybrid(96, 0, &[5; 4]), Some(1.0)));
        assert!((m.decode_time_per_token() - 0.05).abs() < 1e-9);
        // decode-only batch: whole time attributed
        m.record(rec(0.8, BatchShape::decode_only(&[5; 4]), None));
        assert!((m.decode_time_per_token() - (0.2 + 0.8) / 8.0).abs() < 1e-9);
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = Metrics::new();
        assert_eq!(m.throughput(), 0.0);
        assert_eq!(m.decode_time_per_token(), 0.0);
    }
}
