//! L3 coordinator — the paper's system contribution.
//!
//! * [`request`] / [`pool`] — request lifecycle (with a preemption edge)
//!   and the request table.
//! * [`kv`] — token-granular paged KV block allocator; the seed's
//!   whole-request slots are the degenerate `block_size = max_seq_len`
//!   case (§4.3.1 capacity formula upstream in
//!   [`crate::config::Deployment`]).
//! * [`batch`] — work items and batch composition/validation.
//! * [`sched`] — composable admission ([`sched::Admission`]) + batch
//!   composition, and the policies under comparison: request-level
//!   baseline, Orca best/worst iteration-level, SARATHI (chunked-prefills
//!   + decode-maximal batching), and the Sarathi-Serve-style stall-free
//!   [`sched::HybridScheduler`].
//! * [`step`] — the SHARED request-state transition ([`StepApplier`]):
//!   progress, token stamping, completion release, token-granular KV
//!   growth and costed LIFO preemption — driven by both the engine and
//!   the pipeline simulator so they cannot drift.
//! * [`engine`] — the serving loop: admission → schedule → execute →
//!   advance (via [`StepApplier`]); generic over simulated or real (PJRT)
//!   executors.
//! * [`metrics`] — bounded-memory per-iteration and per-request accounting
//!   (throughput, TTFT/TBT/normalized-latency percentiles, preemptions,
//!   windowed retention, streaming JSONL) the figure harness consumes.
//! * [`control`] — the online SLO control loop: AIMD retargeting of the
//!   hybrid token budget toward a target P99 TBT, plus prefix-wait
//!   adaptation, through the [`Scheduler`] runtime actuators.

pub mod batch;
pub mod control;
pub mod engine;
pub mod kv;
pub mod metrics;
pub mod pool;
pub mod request;
pub mod sched;
pub mod step;
pub mod trace;

pub use batch::{Batch, WorkItem};
pub use control::{ControllerConfig, SloController, TickOutcome};
pub use engine::{Engine, Executor, SimExecutor, StepOutcome};
pub use kv::{
    derived_path, KvExport, KvManager, PathMatch, ResidencyDigest, StageKv, DEGENERATE_BLOCK,
    DIGEST_CAP,
};
pub use metrics::{
    IterationRecord, JsonlStream, LatencyReport, Metrics, JSONL_SCHEMA_VERSION,
};
pub use pool::RequestPool;
pub use request::{Phase, PrefixWaitState, Request, RequestId};
pub use sched::{
    make_scheduler, Admission, HybridScheduler, InfeasiblePolicy, OrcaScheduler,
    RequestLevelScheduler, SarathiScheduler, Scheduler,
};
pub use step::{PreemptionMode, StepApplier, StepEffects, SwapCost};
// NOTE: trace::TraceEvent is deliberately NOT re-exported bare — the
// pipeline simulator already exports its Fig.-5 schedule TraceEvent under
// `crate::simulator::TraceEvent`; qualify `trace::TraceEvent` instead.
pub use trace::{BubbleClass, EventKind, LatencyBreakdown, TraceSink};
