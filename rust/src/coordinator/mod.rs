//! L3 coordinator — the paper's system contribution.
//!
//! * [`request`] / [`pool`] — request lifecycle and the request table.
//! * [`kv`] — KV-cache slot manager (§4.3.1 capacity formula upstream in
//!   [`crate::config::Deployment`]).
//! * [`batch`] — work items and batch composition/validation.
//! * [`sched`] — the batching policies under comparison: request-level
//!   baseline, Orca best/worst iteration-level, and SARATHI
//!   (chunked-prefills + decode-maximal batching).
//! * [`engine`] — the serving loop: admission → schedule → execute →
//!   advance, generic over simulated or real (PJRT) executors.
//! * [`metrics`] — per-iteration and per-request accounting the figure
//!   harness consumes.

pub mod batch;
pub mod engine;
pub mod kv;
pub mod metrics;
pub mod pool;
pub mod request;
pub mod sched;

pub use batch::{Batch, WorkItem};
pub use engine::{Engine, Executor, SimExecutor, StepOutcome};
pub use kv::KvManager;
pub use metrics::{IterationRecord, Metrics};
pub use pool::RequestPool;
pub use request::{Phase, Request, RequestId};
pub use sched::{make_scheduler, OrcaScheduler, RequestLevelScheduler, SarathiScheduler, Scheduler};
