//! Online SLO control loop (Sarathi-Serve arXiv 2403.02310 §5).
//!
//! The hybrid scheduler's `token_budget` IS the TBT/TTFT trade-off: a big
//! budget lands big prefill chunks per iteration (fast first tokens, long
//! iterations → high time-between-tokens for the decodes riding along); a
//! small budget bounds iteration time (tight TBT) but drips prompts in
//! slowly (TTFT suffers, queues grow). No static setting survives a
//! workload whose load shifts — so [`SloController`] retargets the budget
//! at runtime from the OBSERVED windowed P99 TBT, AIMD-style:
//!
//! * P99 over target → multiplicative decrease (back off hard; latency
//!   SLOs punish sustained violation, not brief excursions);
//! * P99 comfortably under target → additive increase (creep back up and
//!   spend the slack on prefill throughput / TTFT).
//!
//! A second, slower actuator adapts the admission gate's bounded
//! prefix-wait window to the observed fill economics: waits that keep
//! degrading to fallbacks are wasted queueing (shrink the window); waits
//! that keep resolving as hits are paying for themselves (stretch it).
//!
//! The controller is policy-agnostic — it speaks through
//! [`Scheduler::set_token_budget`] / [`Scheduler::set_max_prefix_wait`],
//! which default to refusing; policies without the knob are simply left
//! alone (ticks still count the window, adjustments stay 0).

use super::sched::Scheduler;
use crate::util::Summary;

/// Tuning for [`SloController`]. Defaults follow AIMD practice: halve-ish
/// on violation (×0.8 per tick — several consecutive violating windows
/// compound), creep up additively when comfortably under target.
#[derive(Clone, Copy, Debug)]
pub struct ControllerConfig {
    /// The P99 time-between-tokens target, seconds.
    pub target_p99_tbt: f64,
    /// Budget floor (keep ≥ the scheduler's `max_batch`; the scheduler
    /// clamps there anyway, this keeps the controller's view honest).
    pub min_budget: usize,
    /// Budget ceiling (the workload's saturation chunk — growing past it
    /// buys no TTFT and only risks TBT).
    pub max_budget: usize,
    /// Multiplicative decrease factor on violation, in (0, 1).
    pub decrease: f64,
    /// Additive increase (tokens) when comfortably under target.
    pub increase: usize,
    /// "Comfortably under" = P99 < `headroom × target` — the dead band
    /// between decrease and increase prevents oscillation around the SLO.
    pub headroom: f64,
    /// Minimum token gaps in a window before the budget actuator acts
    /// (tiny windows make P99 noise, not signal).
    pub min_window: usize,
}

impl ControllerConfig {
    pub fn new(target_p99_tbt: f64, min_budget: usize, max_budget: usize) -> Self {
        assert!(target_p99_tbt > 0.0, "TBT target must be positive");
        assert!(
            min_budget > 0 && min_budget <= max_budget,
            "budget range [{min_budget}, {max_budget}] is empty"
        );
        ControllerConfig {
            target_p99_tbt,
            min_budget,
            max_budget,
            decrease: 0.8,
            increase: 16,
            headroom: 0.7,
            min_window: 8,
        }
    }
}

/// What one control tick observed and did (progress lines + reports).
#[derive(Clone, Copy, Debug, Default)]
pub struct TickOutcome {
    /// Windowed P99 TBT the tick acted on (0.0 when the window was empty).
    pub p99_tbt: f64,
    /// Token budget after the tick.
    pub token_budget: usize,
    /// Prefix-wait window after the tick.
    pub max_prefix_wait: usize,
    /// Actuator changes applied this tick (0, 1 or 2).
    pub adjusted: usize,
}

/// AIMD controller holding both actuators' current setpoints. Feed it one
/// drained TBT window per flush interval via [`tick`](Self::tick).
#[derive(Clone, Debug)]
pub struct SloController {
    cfg: ControllerConfig,
    token_budget: usize,
    max_prefix_wait: usize,
    adjustments: usize,
    ticks: usize,
}

/// Bounds for the prefix-wait actuator: a window of 1 demotes waiters at
/// the first stall; 32 attempts is past any fill a budgeted iteration
/// stream can sustain — longer waits are queueing, not caching.
const WAIT_MIN: usize = 1;
const WAIT_MAX: usize = 32;

impl SloController {
    /// `initial_budget` / `initial_wait` must be the values the scheduler
    /// was constructed with, so the controller's view starts in sync.
    pub fn new(cfg: ControllerConfig, initial_budget: usize, initial_wait: usize) -> Self {
        SloController {
            cfg,
            token_budget: initial_budget.clamp(cfg.min_budget, cfg.max_budget),
            max_prefix_wait: initial_wait.clamp(WAIT_MIN, WAIT_MAX),
            adjustments: 0,
            ticks: 0,
        }
    }

    /// One control tick over the TBT gaps observed since the last tick
    /// (`window`, drained from the pool) plus the window's prefix-cache
    /// deltas. Applies any retargeting through `sched`; returns what it
    /// saw and did.
    pub fn tick(
        &mut self,
        window: &Summary,
        prefix_hits: usize,
        prefix_fallbacks: usize,
        sched: &mut dyn Scheduler,
    ) -> TickOutcome {
        self.ticks += 1;
        let mut adjusted = 0;
        let p99 = window.percentile(99.0);
        if window.count() >= self.cfg.min_window {
            let next = if p99 > self.cfg.target_p99_tbt {
                // violation: multiplicative back-off toward the floor
                ((self.token_budget as f64 * self.cfg.decrease) as usize)
                    .max(self.cfg.min_budget)
            } else if p99 < self.cfg.headroom * self.cfg.target_p99_tbt {
                // comfortable: additive creep toward the ceiling
                (self.token_budget + self.cfg.increase).min(self.cfg.max_budget)
            } else {
                self.token_budget // dead band: hold
            };
            if next != self.token_budget && sched.set_token_budget(next) {
                self.token_budget = next;
                adjusted += 1;
            }
        }
        // prefix-wait economics: every fallback is a wait that expired
        // worthless — shrink the window; hits with no fallbacks mean the
        // fills are landing inside the current window — stretch it so
        // borderline waiters stop demoting early. Both move one step per
        // tick (this actuator must be slower than the budget's).
        let next_wait = if prefix_fallbacks > prefix_hits {
            self.max_prefix_wait.saturating_sub(1).max(WAIT_MIN)
        } else if prefix_hits > 0 && prefix_fallbacks == 0 {
            (self.max_prefix_wait + 1).min(WAIT_MAX)
        } else {
            self.max_prefix_wait
        };
        if next_wait != self.max_prefix_wait && sched.set_max_prefix_wait(next_wait) {
            self.max_prefix_wait = next_wait;
            adjusted += 1;
        }
        self.adjustments += adjusted;
        TickOutcome {
            p99_tbt: p99,
            token_budget: self.token_budget,
            max_prefix_wait: self.max_prefix_wait,
            adjusted,
        }
    }

    /// Total actuator changes across all ticks.
    pub fn adjustments(&self) -> usize {
        self.adjustments
    }

    /// Control ticks run so far.
    pub fn ticks(&self) -> usize {
        self.ticks
    }

    /// Current budget setpoint (mirrors the scheduler's).
    pub fn token_budget(&self) -> usize {
        self.token_budget
    }

    /// Current prefix-wait setpoint.
    pub fn max_prefix_wait(&self) -> usize {
        self.max_prefix_wait
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sched::{HybridScheduler, OrcaScheduler};

    fn window(gaps: &[f64]) -> Summary {
        let mut s = Summary::new();
        for &g in gaps {
            s.add(g);
        }
        s
    }

    #[test]
    fn violation_backs_off_multiplicatively_and_comfort_creeps_up() {
        let cfg = ControllerConfig::new(0.1, 8, 512);
        let mut sched = HybridScheduler::new(256, 8, 2);
        let mut ctl = SloController::new(cfg, 256, 4);
        // 16 gaps, all over target: ×0.8 → 204
        let out = ctl.tick(&window(&[0.5; 16]), 0, 0, &mut sched);
        assert_eq!(out.token_budget, 204);
        assert_eq!(sched.token_budget(), 204);
        assert_eq!(out.adjusted, 1);
        // repeated violation keeps compounding toward the floor
        for _ in 0..40 {
            ctl.tick(&window(&[0.5; 16]), 0, 0, &mut sched);
        }
        assert_eq!(ctl.token_budget(), 8, "floor holds");
        assert_eq!(sched.token_budget(), 8);
        // comfortable windows creep back additively
        let out = ctl.tick(&window(&[0.01; 16]), 0, 0, &mut sched);
        assert_eq!(out.token_budget, 8 + 16);
        // inside the dead band: hold
        let before = ctl.adjustments();
        let out = ctl.tick(&window(&[0.09; 16]), 0, 0, &mut sched);
        assert_eq!(out.token_budget, 8 + 16);
        assert_eq!(ctl.adjustments(), before);
    }

    #[test]
    fn small_windows_are_noise_not_signal() {
        let cfg = ControllerConfig::new(0.1, 8, 512);
        let mut sched = HybridScheduler::new(256, 8, 2);
        let mut ctl = SloController::new(cfg, 256, 4);
        let out = ctl.tick(&window(&[9.0; 3]), 0, 0, &mut sched);
        assert_eq!(out.token_budget, 256, "3 gaps cannot move the budget");
        assert_eq!(out.adjusted, 0);
        assert_eq!(ctl.ticks(), 1);
    }

    #[test]
    fn wait_window_follows_the_fill_economics() {
        let cfg = ControllerConfig::new(0.1, 8, 512);
        let mut sched = HybridScheduler::new(256, 8, 2);
        let mut ctl = SloController::new(cfg, 256, 4);
        let w = window(&[0.09; 16]); // dead band: isolate the wait actuator
        // fallbacks dominate → shrink one step per tick down to the floor
        for _ in 0..10 {
            ctl.tick(&w, 0, 3, &mut sched);
        }
        assert_eq!(ctl.max_prefix_wait(), 1);
        // pure hits → stretch
        let out = ctl.tick(&w, 5, 0, &mut sched);
        assert_eq!(out.max_prefix_wait, 2);
        // mixed (hits but also fallbacks ≤ hits, fallbacks > 0) → hold
        let out = ctl.tick(&w, 5, 2, &mut sched);
        assert_eq!(out.max_prefix_wait, 2);
        assert_eq!(out.adjusted, 0);
    }

    #[test]
    fn policies_without_the_knobs_are_left_alone() {
        let cfg = ControllerConfig::new(0.1, 8, 512);
        let mut sched = OrcaScheduler::best(8);
        let mut ctl = SloController::new(cfg, 256, 4);
        let out = ctl.tick(&window(&[0.5; 16]), 0, 5, &mut sched);
        assert_eq!(out.adjusted, 0, "refused setters adjust nothing");
        assert_eq!(ctl.adjustments(), 0);
        assert_eq!(ctl.token_budget(), 256, "setpoint stays in sync with reality");
    }
}
