//! Request lifecycle: Queued → Prefill → Decode → Complete, with a
//! preemption edge back to Queued (blocks released, progress retained).

use crate::workload::RequestSpec;

pub type RequestId = usize;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Waiting for admission (no KV blocks). Includes preempted requests
    /// waiting to be swapped back in.
    Queued,
    /// Admitted; prompt not fully prefilled.
    Prefill,
    /// Prompt prefilled; generating output tokens.
    Decode,
    /// All output tokens generated; blocks released.
    Complete,
    /// Rejected at admission as infeasible for the pool — terminal, never
    /// ran (open-loop serving counts these instead of crashing on them).
    Rejected,
}

/// A tracked wait-for edge: this (queued) request is waiting for the
/// in-flight fill of its template's registered prefix run. The edge
/// carries the waiter's view of the registrant's progress so admission can
/// detect a stalled fill — the registrant preempted, starved in another
/// stream, or gone — and degrade the wait to a full-price miss instead of
/// blocking forever (the PR-3 "pipeline wedged" liveness hole).
#[derive(Clone, Copy, Debug)]
pub struct PrefixWaitState {
    /// Template hash this request is waiting on.
    pub hash: u64,
    /// Fill progress of the run ([`KvManager::prefix_fill_state`]) at the
    /// waiter's last admission attempt.
    ///
    /// [`KvManager::prefix_fill_state`]:
    ///     super::kv::KvManager::prefix_fill_state
    pub last_fill: usize,
    /// The run's stall-event counter (bumped when its filler is
    /// preempted) at the last attempt.
    pub last_stall_events: u64,
    /// Consecutive attempts without registrant progress. Reaching the
    /// gate's `max_prefix_wait` forces the fallback.
    pub stalled_iters: usize,
    /// When the wait began (feeds the wait-time histogram).
    pub since: f64,
}

#[derive(Clone, Debug)]
pub struct Request {
    pub id: RequestId,
    pub spec: RequestSpec,
    /// Prompt tokens prefilled so far (chunked prefill advances this).
    /// A prefix-cache hit pre-advances this past the shared tokens — their
    /// KV is already resident, so their prefill compute is skipped.
    pub prefilled: usize,
    /// Output tokens generated so far. The final prefill chunk produces the
    /// first output token, so this becomes 1 when prefill completes.
    pub decoded: usize,
    /// KV block table while admitted, in allocation order. Under the
    /// degenerate block size this is exactly one block — the seed's "slot".
    /// Split view: the first [`shared_blocks`](Self::shared_blocks) entries
    /// are a shared prefix run (ref-counted with co-sharers and the prefix
    /// index); the tail is private to this request.
    pub blocks: Vec<usize>,
    /// Leading blocks of `blocks` shared with a resident prefix run — the
    /// head of the split block table. 0 while queued / without a hit.
    pub shared_blocks: usize,
    /// KV tokens resident in those shared blocks (full blocks only; a
    /// partially-filled last prefix block is copy-on-write-forked into the
    /// private tail at admission). Counted ONCE pool-wide for occupancy.
    pub shared_tokens: usize,
    /// Admissions of this request served from a resident prefix run
    /// (re-admission after preemption hits again).
    pub prefix_hits: usize,
    /// Prompt tokens whose prefill compute was skipped because their KV
    /// was already resident when this request was first admitted.
    pub prefix_skipped_tokens: usize,
    /// Live wait-for edge while this request is queued behind an
    /// in-flight prefix fill (cache-aware admission). `None` when not
    /// waiting; cleared on admission or fallback.
    pub prefix_wait: Option<PrefixWaitState>,
    /// Total admission attempts this request spent waiting on a prefix
    /// fill (metrics: `prefix_wait_iterations`).
    pub prefix_wait_iters: usize,
    /// Total simulated time spent waiting on a prefix fill, finalized
    /// when the wait resolves as a hit or degrades to the fallback.
    pub prefix_wait_time: f64,
    /// The bounded wait degraded to a full-price MISS: from then on the
    /// prefix tag is inert for this request (it never waits again, never
    /// shares, never registers) — a fallback is never worse than never
    /// having cached.
    pub prefix_fallback: bool,
    /// Ready-match tokens observed when the wait degraded: the fallback
    /// plan may still share up to this much of the request's content
    /// path (the deepest READY ancestor at demotion time). 0 means the
    /// demotion is to a plain full-price miss — always the case for
    /// path-less (flat whole-template) tags.
    pub fallback_ready_tokens: usize,
    /// True while this request's KV is in flight to (or just arrived at)
    /// this replica over the INTERCONNECT rather than the host link — a
    /// disaggregation handoff. The first admission after import skips the
    /// swap-in charge (the transfer was already costed on the copy
    /// stream) and clears the flag; later preemption/resume cycles charge
    /// the host link as usual.
    pub imported: bool,
    /// True between admission and completion/preemption. Progress counters
    /// survive preemption (swap-style: KV is released, not recomputed).
    pub admitted: bool,
    /// Times this request was preempted to free KV blocks.
    pub preemptions: usize,
    /// When the current queued stint began: arrival at first, reset to the
    /// preemption time on eviction. Feeds the queue-wait component of the
    /// per-request latency decomposition.
    pub queued_since: f64,
    /// Accumulated time spent queued without KV blocks before the first
    /// token (includes any prefix wait; the decomposition nets that out).
    pub queue_wait: f64,
    /// KV tokens this request swapped back over the host link before its
    /// first token — prices the decomposition's swap component.
    pub swapped_in_tokens_pre_first: usize,
    pub arrival: f64,
    pub admitted_at: Option<f64>,
    pub first_token_at: Option<f64>,
    pub completed_at: Option<f64>,
    /// Set when admission rejected the request as infeasible (terminal).
    pub rejected_at: Option<f64>,
    /// Timestamp of the most recent output token (first from the final
    /// prefill chunk, rest from decode iterations). Token-gap statistics
    /// are computed INCREMENTALLY from this at stamp time — the seed's
    /// per-request `token_times` vec retained every stamp forever, which
    /// made long-horizon soak runs a memory leak by construction.
    pub last_token_at: Option<f64>,
    /// Gaps between consecutive output tokens so far (time-between-tokens
    /// count for this request).
    pub tbt_count: usize,
    /// Sum of those gaps (mean TBT = `tbt_sum / tbt_count`).
    pub tbt_sum: f64,
    /// Largest gap so far — the per-request TBT that goodput SLOs check.
    pub max_tbt: f64,
}

impl Request {
    pub fn new(id: RequestId, spec: RequestSpec) -> Self {
        let arrival = spec.arrival;
        Request {
            id,
            spec,
            prefilled: 0,
            decoded: 0,
            blocks: Vec::new(),
            shared_blocks: 0,
            shared_tokens: 0,
            prefix_hits: 0,
            prefix_skipped_tokens: 0,
            prefix_wait: None,
            prefix_wait_iters: 0,
            prefix_wait_time: 0.0,
            prefix_fallback: false,
            fallback_ready_tokens: 0,
            imported: false,
            admitted: false,
            preemptions: 0,
            queued_since: arrival,
            queue_wait: 0.0,
            swapped_in_tokens_pre_first: 0,
            arrival,
            admitted_at: None,
            first_token_at: None,
            completed_at: None,
            rejected_at: None,
            last_token_at: None,
            tbt_count: 0,
            tbt_sum: 0.0,
            max_tbt: 0.0,
        }
    }

    /// Stamp one produced output token at `at`, folding the gap since the
    /// previous token into this request's streaming TBT statistics.
    /// Returns the gap for the second and later tokens (`None` for the
    /// first — its latency is TTFT, not TBT) so the caller can feed a
    /// pool-level distribution. A long gap is a decode stall caused by a
    /// scheduler running other work.
    pub fn note_token(&mut self, at: f64) -> Option<f64> {
        let gap = self.last_token_at.map(|prev| {
            debug_assert!(at >= prev, "token stamps must be monotone: {at} < {prev}");
            at - prev
        });
        self.last_token_at = Some(at);
        if let Some(g) = gap {
            self.tbt_count += 1;
            self.tbt_sum += g;
            self.max_tbt = self.max_tbt.max(g);
        }
        gap
    }

    pub fn is_admitted(&self) -> bool {
        self.admitted
    }

    /// True while this queued request holds a wait-for edge on an
    /// in-flight prefix fill.
    pub fn is_prefix_waiting(&self) -> bool {
        self.prefix_wait.is_some()
    }

    /// First block of the table — the physical KV row under the degenerate
    /// (one-block-per-request) layout the real PJRT runtime serves from.
    pub fn slot(&self) -> Option<usize> {
        self.blocks.first().copied()
    }

    pub fn phase(&self) -> Phase {
        if self.rejected_at.is_some() {
            Phase::Rejected
        } else if self.completed_at.is_some() {
            Phase::Complete
        } else if !self.admitted {
            Phase::Queued
        } else if self.prefilled < self.spec.prompt_len {
            Phase::Prefill
        } else {
            Phase::Decode
        }
    }

    /// Prompt tokens still to prefill.
    pub fn remaining_prompt(&self) -> usize {
        self.spec.prompt_len - self.prefilled
    }

    /// Output tokens still to generate.
    pub fn remaining_decode(&self) -> usize {
        self.spec.decode_len.saturating_sub(self.decoded)
    }

    /// Tokens currently in the KV cache (context length for the *next*
    /// decode step): full prompt + generated tokens except the one about to
    /// be produced.
    pub fn kv_len(&self) -> usize {
        self.prefilled + self.decoded.saturating_sub(1)
    }

    /// Live KV tokens in this request's PRIVATE block territory — its
    /// [`kv_len`](Self::kv_len) minus the tokens served from shared prefix
    /// blocks. This is what a preemption actually has to move off the GPU
    /// (shared blocks stay resident for co-sharers / the prefix index) and
    /// what occupancy accounting may attribute to this request alone.
    pub fn private_kv_tokens(&self) -> usize {
        self.kv_len().saturating_sub(self.shared_tokens)
    }

    pub fn is_decode_ready(&self) -> bool {
        self.phase() == Phase::Decode
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(p: usize, d: usize) -> RequestSpec {
        RequestSpec { prompt_len: p, decode_len: d, arrival: 0.0, prefix: None }
    }

    #[test]
    fn lifecycle_phases() {
        let mut r = Request::new(0, spec(100, 10));
        assert_eq!(r.phase(), Phase::Queued);
        r.admitted = true;
        r.blocks = vec![3];
        assert_eq!(r.phase(), Phase::Prefill);
        assert_eq!(r.slot(), Some(3));
        r.prefilled = 100;
        r.decoded = 1; // first token from the final prefill chunk
        assert_eq!(r.phase(), Phase::Decode);
        r.completed_at = Some(1.0);
        assert_eq!(r.phase(), Phase::Complete);
    }

    #[test]
    fn preempted_request_looks_queued_but_keeps_progress() {
        let mut r = Request::new(0, spec(100, 10));
        r.admitted = true;
        r.blocks = vec![0, 1];
        r.prefilled = 100;
        r.decoded = 4;
        // swap out
        r.admitted = false;
        r.blocks.clear();
        r.preemptions += 1;
        assert_eq!(r.phase(), Phase::Queued);
        assert_eq!(r.kv_len(), 103, "progress survives preemption");
        assert_eq!(r.preemptions, 1);
    }

    #[test]
    fn accounting() {
        let mut r = Request::new(0, spec(100, 10));
        r.admitted = true;
        r.blocks = vec![0];
        r.prefilled = 60;
        assert_eq!(r.remaining_prompt(), 40);
        r.prefilled = 100;
        r.decoded = 3;
        assert_eq!(r.remaining_decode(), 7);
        // kv holds the prompt + 2 generated tokens (3rd is being produced)
        assert_eq!(r.kv_len(), 102);
    }

    #[test]
    fn token_stamps_accumulate_streaming_tbt() {
        let mut r = Request::new(0, spec(4, 3));
        assert_eq!(r.note_token(1.0), None, "first token has no gap");
        assert_eq!(r.note_token(1.5), Some(0.5));
        assert_eq!(r.note_token(2.5), Some(1.0));
        assert_eq!(r.tbt_count, 2);
        assert!((r.tbt_sum - 1.5).abs() < 1e-12);
        assert!((r.max_tbt - 1.0).abs() < 1e-12);
        assert_eq!(r.last_token_at, Some(2.5));
    }
}
