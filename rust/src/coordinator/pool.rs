//! The request table: every request the system has seen, indexed by id.
//!
//! Perf note (EXPERIMENTS.md §Perf, L3 iteration 1): the pool maintains
//! arrival-sorted `pending` and `active` index lists so the per-iteration
//! scheduler queries are O(B + admissible) instead of O(total requests) —
//! the difference between the Fig.-12 10K-request simulation scaling
//! linearly vs quadratically. Admission, completion and preemption
//! therefore go through [`RequestPool::admit`] / [`RequestPool::complete`]
//! / [`RequestPool::preempt`], never by poking `admitted`/`completed_at`
//! directly.

use std::collections::VecDeque;

use super::request::{Phase, Request, RequestId};
use super::trace::{EventKind, TraceSink};
use crate::util::Summary;
use crate::workload::RequestSpec;

#[derive(Clone, Debug, Default)]
pub struct RequestPool {
    /// Retained requests: ids `base..base + requests.len()`. Terminal
    /// requests can be retired from the FRONT
    /// ([`retire_terminal`](Self::retire_terminal)) so a regenerating soak
    /// run holds O(live) request state instead of O(history).
    requests: VecDeque<Request>,
    /// Ids below this have been retired (they were terminal and harvested
    /// by the soak driver). Id `i` lives at `requests[i - base]`.
    base: RequestId,
    /// Not-yet-admitted ids, sorted by (arrival, id). Preempted requests
    /// re-enter here at their original arrival position (FCFS resume).
    pending: Vec<RequestId>,
    /// Cursor into `pending`: everything before it has been admitted.
    pending_head: usize,
    /// Admitted, not complete (id-sorted).
    active: Vec<RequestId>,
    /// Terminal requests: completed + rejected (drives `all_complete`).
    n_terminal: usize,
    /// Requests rejected as infeasible (never admitted, never completed).
    n_rejected: usize,
    /// Rejection events since the last [`take_rejected_events`] drain.
    rejected_events: usize,
    /// Live KV tokens swapped back in by re-admissions since the last
    /// [`take_swapped_in_tokens`] drain — the engine/pipeline charge the
    /// swap-in transfer from this. Shared prefix tokens are excluded:
    /// those blocks never left the GPU (the prefix index / co-sharers kept
    /// them resident).
    swapped_in_tokens: usize,
    /// Prefix-cache-hit admissions since the last [`take_prefix_hits`]
    /// drain (metrics accounting).
    prefix_hit_events: usize,
    /// Partial (radix) hits among those since the last
    /// [`take_prefix_partial_hits`] drain: admissions served from a
    /// longest-match of the request's content path rather than a
    /// whole-template replay.
    prefix_partial_hit_events: usize,
    /// Prompt tokens served by those partial hits since the last
    /// [`take_prefix_partial_hit_tokens`] drain (hit-depth accounting:
    /// mean hit depth = tokens / hits).
    prefix_partial_hit_tokens: usize,
    /// Prefix-wait fallbacks (bounded wait degraded to a full-price miss)
    /// since the last [`take_prefix_fallbacks`] drain.
    prefix_fallback_events: usize,
    /// Admission attempts spent waiting on a prefix fill since the last
    /// [`take_prefix_wait_ticks`] drain.
    prefix_wait_tick_events: usize,
    /// Pool-level time-between-tokens distribution, fed incrementally at
    /// token-stamp time ([`stamp_token`](Self::stamp_token)) — bounded by
    /// [`Summary`]'s sketch instead of retaining every token timestamp.
    tbt: Summary,
    /// Drainable TBT window for the online SLO controller (enabled by
    /// [`enable_tbt_window`](Self::enable_tbt_window); `None` costs
    /// nothing on non-soak runs).
    tbt_window: Option<Summary>,
    /// Lifecycle event bus: every admission/completion/preemption/token
    /// chokepoint below emits into it. Disabled (the default) it is a
    /// single `None` check — the hot path stays allocation-free.
    pub trace: TraceSink,
}

impl RequestPool {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn from_specs(specs: &[RequestSpec]) -> Self {
        let mut p = Self::new();
        for s in specs {
            p.push(s.clone());
        }
        p
    }

    /// Insert `id` into the pending tail keeping (arrival, id) order.
    fn enqueue_pending(&mut self, id: RequestId) {
        let arrival = self.requests[id - self.base].arrival;
        let base = self.base;
        let requests = &self.requests;
        let tail = &self.pending[self.pending_head..];
        let pos = tail.partition_point(|&q| {
            let a = requests[q - base].arrival;
            a < arrival || (a == arrival && q < id)
        });
        self.pending.insert(self.pending_head + pos, id);
    }

    pub fn push(&mut self, spec: RequestSpec) -> RequestId {
        let id = self.base + self.requests.len();
        let arrival = spec.arrival;
        self.requests.push_back(Request::new(id, spec));
        // typical workloads push in arrival order so this is O(1) amortized
        self.enqueue_pending(id);
        if self.trace.is_enabled() {
            self.trace.emit(arrival, EventKind::Arrived { request: id });
            self.trace.emit(arrival, EventKind::Queued { request: id });
        }
        id
    }

    pub fn get(&self, id: RequestId) -> &Request {
        &self.requests[id - self.base]
    }

    /// Mutable access for progress fields (`prefilled`, `decoded`, ...).
    /// Admission/completion/preemption must use [`admit`](Self::admit) /
    /// [`complete`](Self::complete) / [`preempt`](Self::preempt) so the
    /// index lists stay coherent.
    pub fn get_mut(&mut self, id: RequestId) -> &mut Request {
        let base = self.base;
        &mut self.requests[id - base]
    }

    /// Stamp one produced output token for `id` at time `at`: updates the
    /// request's streaming TBT stats and feeds the gap (second token
    /// onward) into the pool-level TBT distribution. The ONE entry point
    /// for token stamping — [`super::StepApplier`] and the pipeline's
    /// disaggregation import both go through it.
    pub fn stamp_token(&mut self, id: RequestId, at: f64) {
        let base = self.base;
        match self.requests[id - base].note_token(at) {
            Some(gap) => {
                self.tbt.add(gap);
                if let Some(w) = &mut self.tbt_window {
                    w.add(gap);
                }
                self.trace.emit(at, EventKind::TokenEmitted { request: id });
            }
            None => self.trace.emit(at, EventKind::FirstToken { request: id }),
        }
    }

    /// Pool-level time-between-tokens distribution (every gap stamped so
    /// far, bounded memory).
    pub fn tbt_summary(&self) -> &Summary {
        &self.tbt
    }

    /// Start collecting the drainable TBT window (soak control loop).
    pub fn enable_tbt_window(&mut self) {
        if self.tbt_window.is_none() {
            self.tbt_window = Some(Summary::new());
        }
    }

    /// Drain the TBT window accumulated since the last call (empty if
    /// [`enable_tbt_window`](Self::enable_tbt_window) was never called).
    pub fn take_tbt_window(&mut self) -> Summary {
        match &mut self.tbt_window {
            Some(w) => std::mem::take(w),
            None => Summary::new(),
        }
    }

    /// Admit a queued request, handing it its initial KV block table.
    pub fn admit(&mut self, id: RequestId, blocks: Vec<usize>, now: f64) {
        let slot = id - self.base;
        debug_assert!({
            let r = &self.requests[slot];
            !r.admitted && r.completed_at.is_none() && r.rejected_at.is_none()
        });
        // a re-admitted preempted request carries live KV that must be
        // swapped back in; expose the token count for the cost charge.
        // Only its PRIVATE tokens move — admission sets `shared_tokens`
        // before calling us when a resident prefix run covers the head.
        // Exception: an imported request's KV arrived over the
        // interconnect (already costed on the copy stream), so its first
        // admission here moves nothing over the host link.
        let swap_tokens = if self.requests[slot].imported {
            self.requests[slot].imported = false;
            0
        } else {
            let t = self.requests[slot].private_kv_tokens();
            self.swapped_in_tokens += t;
            t
        };
        let r = &mut self.requests[slot];
        let first_admission = r.admitted_at.is_none();
        // decomposition accounting: queued stints and swap-ins that happen
        // before the first token are TTFT components
        if r.first_token_at.is_none() {
            r.queue_wait += (now - r.queued_since).max(0.0);
            r.swapped_in_tokens_pre_first += swap_tokens;
        }
        r.admitted = true;
        r.blocks = blocks;
        if r.admitted_at.is_none() {
            r.admitted_at = Some(now);
        }
        let (shared_tokens, private_tokens) = (r.shared_tokens, r.private_kv_tokens());
        // ids are admitted FCFS from the pending head in practice; fall
        // back to a scan for out-of-order admissions (tests).
        if self.pending.get(self.pending_head) == Some(&id) {
            self.pending_head += 1;
        } else if let Some(pos) = self.pending[self.pending_head..].iter().position(|&q| q == id) {
            self.pending.remove(self.pending_head + pos);
        }
        // keep `active` id-sorted so phase queries need no per-call sort
        let pos = self.active.partition_point(|&a| a < id);
        self.active.insert(pos, id);
        if self.trace.is_enabled() {
            let kind = if first_admission {
                EventKind::Admitted { request: id, shared_tokens, private_tokens }
            } else {
                EventKind::Resumed { request: id, swap_tokens }
            };
            self.trace.emit(now, kind);
        }
    }

    /// Mark a request complete; returns its released KV block table.
    pub fn complete(&mut self, id: RequestId, now: f64) -> Vec<usize> {
        let base = self.base;
        let r = &mut self.requests[id - base];
        debug_assert!(r.completed_at.is_none());
        r.completed_at = Some(now);
        r.admitted = false;
        r.shared_blocks = 0;
        r.shared_tokens = 0;
        let blocks = std::mem::take(&mut r.blocks);
        let pos = self.active.binary_search(&id).expect("complete of inactive request");
        self.active.remove(pos);
        self.n_terminal += 1;
        self.trace.emit(now, EventKind::Completed { request: id });
        blocks
    }

    /// Reject a queued request that can never be served (its lifetime KV
    /// footprint exceeds the pool — see
    /// [`super::sched::Admission`]). Terminal: it leaves the queue, never
    /// holds blocks, and counts toward [`all_complete`](Self::all_complete)
    /// so open-loop serving drains instead of wedging on it.
    pub fn reject(&mut self, id: RequestId, now: f64) {
        let base = self.base;
        let r = &mut self.requests[id - base];
        debug_assert!(!r.admitted && r.completed_at.is_none() && r.rejected_at.is_none());
        r.rejected_at = Some(now);
        if self.pending.get(self.pending_head) == Some(&id) {
            self.pending_head += 1;
        } else if let Some(pos) = self.pending[self.pending_head..].iter().position(|&q| q == id) {
            self.pending.remove(self.pending_head + pos);
        }
        self.n_terminal += 1;
        self.n_rejected += 1;
        self.rejected_events += 1;
        self.trace.emit(now, EventKind::Rejected { request: id });
    }

    /// Total requests rejected as infeasible so far.
    pub fn rejected_count(&self) -> usize {
        self.n_rejected
    }

    /// Rejection events since the last drain (metrics accounting).
    pub fn take_rejected_events(&mut self) -> usize {
        std::mem::take(&mut self.rejected_events)
    }

    /// Live KV tokens swapped back in by re-admissions since the last
    /// drain (swap-in cost accounting).
    pub fn take_swapped_in_tokens(&mut self) -> usize {
        std::mem::take(&mut self.swapped_in_tokens)
    }

    /// Note one prefix-cache-hit admission (called by the admission gate).
    pub fn note_prefix_hit(&mut self) {
        self.prefix_hit_events += 1;
    }

    /// Prefix-cache-hit admissions since the last drain (metrics).
    pub fn take_prefix_hits(&mut self) -> usize {
        std::mem::take(&mut self.prefix_hit_events)
    }

    /// Note one PARTIAL (radix longest-match) hit serving `tokens` prompt
    /// tokens (called by the admission gate alongside
    /// [`note_prefix_hit`](Self::note_prefix_hit)).
    pub fn note_prefix_partial_hit(&mut self, tokens: usize) {
        self.prefix_partial_hit_events += 1;
        self.prefix_partial_hit_tokens += tokens;
    }

    /// Partial-hit admissions since the last drain (metrics).
    pub fn take_prefix_partial_hits(&mut self) -> usize {
        std::mem::take(&mut self.prefix_partial_hit_events)
    }

    /// Prompt tokens served by partial hits since the last drain
    /// (metrics; hit-depth statistics divide by the hit count).
    pub fn take_prefix_partial_hit_tokens(&mut self) -> usize {
        std::mem::take(&mut self.prefix_partial_hit_tokens)
    }

    /// Note one admission attempt spent waiting on a prefix fill (called
    /// by the admission gate's wait tick).
    pub fn note_prefix_wait_tick(&mut self) {
        self.prefix_wait_tick_events += 1;
    }

    /// Prefix-wait admission attempts since the last drain (metrics).
    pub fn take_prefix_wait_ticks(&mut self) -> usize {
        std::mem::take(&mut self.prefix_wait_tick_events)
    }

    /// Prefix-wait fallback events since the last drain (metrics).
    pub fn take_prefix_fallbacks(&mut self) -> usize {
        std::mem::take(&mut self.prefix_fallback_events)
    }

    /// Degrade `id`'s prefix wait to a full-price MISS: the wait-for edge
    /// is dropped, its elapsed time is finalized into the wait histogram,
    /// and the request's prefix tag goes inert ([`Request::prefix_fallback`]
    /// is sticky). Called by the admission gate when the registrant made
    /// no progress for `max_prefix_wait` attempts, and by the drivers'
    /// wedge demotion ([`Engine::run`] / `PipelineSim`) on the oldest
    /// waiter when nothing else can make progress.
    ///
    /// [`Request::prefix_fallback`]: super::request::Request::prefix_fallback
    /// [`Engine::run`]: super::engine::Engine::run
    /// `ready_tokens` is the deepest READY content-path match observed at
    /// demotion time: the fallback plan may still share that much
    /// ([`Request::fallback_ready_tokens`]); 0 demotes to a plain
    /// full-price miss (always the case for flat whole-template tags).
    ///
    /// [`Request::fallback_ready_tokens`]:
    ///     super::request::Request::fallback_ready_tokens
    pub fn force_prefix_fallback(&mut self, id: RequestId, now: f64, ready_tokens: usize) {
        if self.requests[id - self.base].prefix_fallback {
            return;
        }
        self.requests[id - self.base].prefix_fallback = true;
        self.requests[id - self.base].fallback_ready_tokens = ready_tokens;
        self.finalize_prefix_wait(id, now);
        self.prefix_fallback_events += 1;
    }

    /// Finalize `id`'s prefix wait, if any: drop the wait-for edge and add
    /// its elapsed time to the per-request wait histogram. Called wherever
    /// a wait resolves — admission (hit, re-registration, or fallback
    /// admit), the forced fallback, or the fill completing while the
    /// request is still memory-gated behind the funds check.
    pub fn finalize_prefix_wait(&mut self, id: RequestId, now: f64) {
        let base = self.base;
        let r = &mut self.requests[id - base];
        if let Some(w) = r.prefix_wait.take() {
            r.prefix_wait_time += (now - w.since).max(0.0);
            if self.trace.is_enabled() {
                // the wait's start is only known retroactively: emit both
                // edges here (the merge re-orders them by time)
                let fallback = r.prefix_fallback;
                let (hash, since) = (w.hash, w.since);
                self.trace.emit(since, EventKind::PrefixWaitStart { request: id, hash });
                self.trace.emit(now, EventKind::PrefixWaitEnd { request: id, hash, fallback });
            }
        }
    }

    /// Queued requests currently holding a wait-for edge on an in-flight
    /// prefix fill (wedge diagnostics).
    pub fn prefix_waiting_count(&self) -> usize {
        self.pending[self.pending_head..]
            .iter()
            .filter(|&&id| self.requests[id - self.base].is_prefix_waiting())
            .count()
    }

    /// Oldest-arrival queued request waiting on a prefix fill — the wedge
    /// demotion victim. The pending list is (arrival, id)-sorted, so the
    /// first waiting entry is the oldest.
    pub fn oldest_prefix_waiter(&self) -> Option<RequestId> {
        self.pending[self.pending_head..]
            .iter()
            .copied()
            .find(|&id| self.requests[id - self.base].is_prefix_waiting())
    }

    /// Preempt an active request: release its block table (returned to the
    /// caller to free), keep its progress counters, and re-queue it at its
    /// original arrival position so it resumes FCFS.
    pub fn preempt(&mut self, id: RequestId, now: f64) -> Vec<usize> {
        let base = self.base;
        let r = &mut self.requests[id - base];
        debug_assert!(r.admitted && r.completed_at.is_none());
        let evicted_tokens = r.private_kv_tokens();
        r.admitted = false;
        r.preemptions += 1;
        r.queued_since = now;
        // the split table is gone with the blocks; a re-admission
        // re-shares from the prefix index if the run is still resident
        r.shared_blocks = 0;
        r.shared_tokens = 0;
        let blocks = std::mem::take(&mut r.blocks);
        let pos = self.active.binary_search(&id).expect("preempt of inactive request");
        self.active.remove(pos);
        self.enqueue_pending(id);
        self.trace.emit(now, EventKind::Preempted { request: id, evicted_tokens });
        blocks
    }

    /// Total requests EVER pushed (retired ones included) — ids are
    /// `0..len()`, of which only `base()..len()` are still retained.
    pub fn len(&self) -> usize {
        self.base + self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// First still-retained id (everything below was retired).
    pub fn base(&self) -> RequestId {
        self.base
    }

    /// Requests currently held in memory — the soak leak-detector's
    /// counter: flat between checkpoints while completions keep rising.
    pub fn retained_count(&self) -> usize {
        self.requests.len()
    }

    /// Pop terminal (completed / rejected) requests off the FRONT of the
    /// table and return them for harvesting — the regenerating soak
    /// driver's retirement path. Only a contiguous terminal prefix can
    /// retire (ids stay dense); anything still queued or running stops the
    /// sweep. Retired ids must never be dereferenced again.
    pub fn retire_terminal(&mut self) -> Vec<Request> {
        let mut out = Vec::new();
        while let Some(front) = self.requests.front() {
            if !matches!(front.phase(), Phase::Complete | Phase::Rejected) {
                break;
            }
            out.push(self.requests.pop_front().unwrap());
            self.base += 1;
        }
        out
    }

    /// Retained requests (retired ones are gone).
    pub fn iter(&self) -> impl Iterator<Item = &Request> {
        self.requests.iter()
    }

    /// Admitted ids in `phase` (Prefill or Decode), FCFS (id) order.
    pub fn in_phase(&self, phase: Phase) -> Vec<RequestId> {
        match phase {
            Phase::Prefill | Phase::Decode => self
                .active
                .iter()
                .copied()
                .filter(|&id| self.requests[id - self.base].phase() == phase)
                .collect(),
            Phase::Queued => self.pending[self.pending_head..]
                .iter()
                .copied()
                .filter(|&id| self.requests[id - self.base].phase() == Phase::Queued)
                .collect(),
            Phase::Complete | Phase::Rejected => (self.base..self.len())
                .filter(|&id| self.requests[id - self.base].phase() == phase)
                .collect(),
        }
    }

    /// Admitted ids in `phase` (Prefill or Decode only), FCFS (id) order,
    /// without materializing a Vec — batch composition filters the active
    /// list every scheduling iteration, which must not allocate.
    pub fn in_phase_iter(&self, phase: Phase) -> impl Iterator<Item = RequestId> + '_ {
        debug_assert!(matches!(phase, Phase::Prefill | Phase::Decode));
        self.active
            .iter()
            .copied()
            .filter(move |&id| self.requests[id - self.base].phase() == phase)
    }

    /// All queued (unadmitted, non-terminal) ids, arrival-sorted — the
    /// allocation-free counterpart of `in_phase(Phase::Queued)` (every
    /// pending entry is Queued: admission, rejection and completion all
    /// remove ids from the pending list).
    pub fn queued_ids(&self) -> &[RequestId] {
        &self.pending[self.pending_head..]
    }

    /// Queued requests that have arrived by `now`, FCFS by arrival.
    /// O(result) thanks to the arrival-sorted pending list.
    pub fn arrived_queued(&self, now: f64) -> Vec<RequestId> {
        self.pending[self.pending_head..]
            .iter()
            .copied()
            .take_while(|&id| self.requests[id - self.base].arrival <= now)
            .collect()
    }

    /// Lowest-id admitted request in `phase` without materializing the
    /// whole list (the SARATHI/Orca schedulers only chunk ONE prefill per
    /// iteration).
    pub fn first_in_phase(&self, phase: Phase) -> Option<RequestId> {
        self.active.iter().copied().find(|&id| self.requests[id - self.base].phase() == phase)
    }

    /// Next admissible request, if any — O(1) peek at the pending head
    /// (admission loops use this instead of materializing
    /// [`arrived_queued`](Self::arrived_queued), which is O(backlog)).
    pub fn next_queued(&self, now: f64) -> Option<RequestId> {
        let &id = self.pending.get(self.pending_head)?;
        (self.requests[id - self.base].arrival <= now).then_some(id)
    }

    /// True when every request is terminal (completed or rejected).
    /// `n_terminal` is an all-time count, so retired requests (terminal by
    /// definition) stay counted.
    pub fn all_complete(&self) -> bool {
        self.n_terminal == self.len()
    }

    /// True while any request is admitted (holds KV blocks).
    pub fn any_active(&self) -> bool {
        !self.active.is_empty()
    }

    /// Number of admitted, incomplete requests.
    pub fn active_count(&self) -> usize {
        self.active.len()
    }

    /// Admitted, incomplete ids (id-sorted).
    pub fn active_ids(&self) -> &[RequestId] {
        &self.active
    }

    /// Live KV tokens across all admitted requests. NOTE: with prefix
    /// sharing this counts a shared token once PER SHARER — occupancy /
    /// fragmentation accounting must use
    /// [`live_private_kv_tokens`](Self::live_private_kv_tokens) plus the
    /// allocator's resident-prefix count instead.
    pub fn live_kv_tokens(&self) -> usize {
        self.active.iter().map(|&id| self.requests[id - self.base].kv_len()).sum()
    }

    /// Live KV tokens in PRIVATE block territory across admitted requests
    /// (each shared prefix token excluded here; it is counted once by
    /// [`KvManager::resident_prefix_tokens`]).
    ///
    /// [`KvManager::resident_prefix_tokens`]:
    ///     super::kv::KvManager::resident_prefix_tokens
    pub fn live_private_kv_tokens(&self) -> usize {
        self.active.iter().map(|&id| self.requests[id - self.base].private_kv_tokens()).sum()
    }

    /// KV tokens currently served to admitted requests from shared prefix
    /// blocks — the memory sharing saves versus private copies.
    pub fn shared_kv_tokens(&self) -> usize {
        self.active.iter().map(|&id| self.requests[id - self.base].shared_tokens).sum()
    }

    /// Earliest arrival among still-queued requests (drives idle-advance).
    pub fn next_arrival(&self, now: f64) -> Option<f64> {
        self.pending[self.pending_head..]
            .iter()
            .map(|&id| self.requests[id - self.base].arrival)
            .find(|&a| a > now)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fcfs_order_and_phase_queries() {
        let mut p = RequestPool::new();
        for i in 0..3 {
            p.push(RequestSpec {
                prompt_len: 10 * (i + 1),
                decode_len: 2,
                arrival: i as f64,
                prefix: None,
            });
        }
        assert_eq!(p.arrived_queued(0.5), vec![0]);
        assert_eq!(p.arrived_queued(5.0), vec![0, 1, 2]);
        assert_eq!(p.queued_ids(), &[0, 1, 2]);
        p.admit(1, vec![0], 1.0);
        assert_eq!(p.in_phase(Phase::Prefill), vec![1]);
        assert_eq!(p.queued_ids(), &[0, 2], "admission leaves the pending list");
        // request 1 was admitted; the next *queued* arrival is request 2
        assert_eq!(p.next_arrival(0.0), Some(2.0));
        assert!(!p.all_complete());
        assert_eq!(p.arrived_queued(5.0), vec![0, 2]);
    }

    #[test]
    fn admit_complete_cycle_maintains_indexes() {
        let mut p = RequestPool::new();
        for _ in 0..4 {
            p.push(RequestSpec { prompt_len: 8, decode_len: 1, arrival: 0.0, prefix: None });
        }
        p.admit(0, vec![5], 0.0);
        p.admit(1, vec![6], 0.0);
        assert!(p.any_active());
        assert_eq!(p.active_count(), 2);
        assert_eq!(p.arrived_queued(0.0), vec![2, 3]);
        p.get_mut(0).prefilled = 8;
        p.get_mut(0).decoded = 1;
        let blocks = p.complete(0, 1.0);
        assert_eq!(blocks, vec![5]);
        assert_eq!(p.in_phase(Phase::Complete), vec![0]);
        assert_eq!(p.in_phase(Phase::Prefill), vec![1]);
        assert!(!p.all_complete());
        p.get_mut(1).prefilled = 8;
        p.get_mut(1).decoded = 1;
        p.complete(1, 2.0);
        p.admit(2, vec![0], 2.0);
        p.admit(3, vec![1], 2.0);
        for id in [2, 3] {
            p.get_mut(id).prefilled = 8;
            p.get_mut(id).decoded = 1;
            p.complete(id, 3.0);
        }
        assert!(p.all_complete());
        assert!(!p.any_active());
    }

    #[test]
    fn unsorted_arrivals_are_served_in_arrival_order() {
        let mut p = RequestPool::new();
        p.push(RequestSpec { prompt_len: 1, decode_len: 1, arrival: 0.5, prefix: None });
        p.push(RequestSpec { prompt_len: 1, decode_len: 1, arrival: 0.1, prefix: None });
        p.push(RequestSpec { prompt_len: 1, decode_len: 1, arrival: 0.3, prefix: None });
        assert_eq!(p.arrived_queued(1.0), vec![1, 2, 0]);
        assert_eq!(p.next_arrival(0.2), Some(0.3));
    }

    #[test]
    fn reject_is_terminal_and_leaves_the_queue() {
        let mut p = RequestPool::new();
        p.push(RequestSpec { prompt_len: 8, decode_len: 2, arrival: 0.0, prefix: None });
        p.push(RequestSpec { prompt_len: 1 << 20, decode_len: 2, arrival: 0.1, prefix: None });
        p.push(RequestSpec { prompt_len: 8, decode_len: 2, arrival: 0.2, prefix: None });
        p.reject(1, 0.5);
        assert_eq!(p.rejected_count(), 1);
        assert_eq!(p.take_rejected_events(), 1);
        assert_eq!(p.take_rejected_events(), 0, "events drain");
        assert_eq!(p.in_phase(Phase::Rejected), vec![1]);
        // the rejected request no longer blocks the FCFS queue
        assert_eq!(p.arrived_queued(1.0), vec![0, 2]);
        assert!(!p.all_complete());
        for id in [0, 2] {
            p.admit(id, vec![id], 1.0);
            p.get_mut(id).prefilled = 8;
            p.get_mut(id).decoded = 2;
            p.complete(id, 2.0);
        }
        assert!(p.all_complete(), "rejected counts as terminal");
        assert_eq!(p.get(1).rejected_at, Some(0.5));
        assert!(p.get(1).completed_at.is_none());
    }

    #[test]
    fn readmission_accumulates_swapped_in_tokens() {
        let mut p = RequestPool::new();
        p.push(RequestSpec { prompt_len: 8, decode_len: 4, arrival: 0.0, prefix: None });
        p.admit(0, vec![0], 0.0);
        assert_eq!(p.take_swapped_in_tokens(), 0, "fresh admission moves no KV");
        p.get_mut(0).prefilled = 8;
        p.get_mut(0).decoded = 3;
        p.preempt(0, 1.0);
        p.admit(0, vec![1], 2.0);
        assert_eq!(p.take_swapped_in_tokens(), 10, "kv_len at swap-in");
        assert_eq!(p.take_swapped_in_tokens(), 0, "drained");
    }

    #[test]
    fn imported_admission_skips_the_host_link_charge_once() {
        let mut p = RequestPool::new();
        p.push(RequestSpec { prompt_len: 8, decode_len: 4, arrival: 0.0, prefix: None });
        // state after a disaggregation handoff: prompt KV arrived over the
        // interconnect, first token already produced on the prefill side
        {
            let r = p.get_mut(0);
            r.prefilled = 8;
            r.decoded = 1;
            r.imported = true;
        }
        p.admit(0, vec![0], 1.0);
        assert_eq!(p.take_swapped_in_tokens(), 0, "transfer was costed on the copy stream");
        assert!(!p.get(0).imported, "the exemption is one-shot");
        // a later preemption/resume cycle charges the host link as usual
        p.get_mut(0).decoded = 3;
        p.preempt(0, 2.0);
        p.admit(0, vec![1], 3.0);
        assert_eq!(p.take_swapped_in_tokens(), 10);
    }

    #[test]
    fn swap_in_accounting_excludes_shared_prefix_tokens() {
        let mut p = RequestPool::new();
        p.push(RequestSpec { prompt_len: 40, decode_len: 8, arrival: 0.0, prefix: None });
        p.admit(0, vec![0, 1, 2], 0.0);
        p.get_mut(0).prefilled = 40;
        p.get_mut(0).decoded = 5;
        p.preempt(0, 1.0);
        // re-admission with 32 of the 44 live tokens covered by a resident
        // prefix run: only the 12 private tokens cross the host link.
        // Admission sets the split BEFORE handing the table to admit().
        {
            let r = p.get_mut(0);
            r.shared_blocks = 2;
            r.shared_tokens = 32;
        }
        p.admit(0, vec![5, 6, 7], 2.0);
        assert_eq!(p.take_swapped_in_tokens(), 12, "shared tokens never left the GPU");
        assert_eq!(p.shared_kv_tokens(), 32);
        assert_eq!(p.live_kv_tokens(), 44);
        assert_eq!(p.live_private_kv_tokens(), 12);
    }

    #[test]
    fn preempt_and_complete_reset_the_share_split() {
        let mut p = RequestPool::new();
        p.push(RequestSpec { prompt_len: 8, decode_len: 4, arrival: 0.0, prefix: None });
        p.push(RequestSpec { prompt_len: 8, decode_len: 4, arrival: 0.0, prefix: None });
        p.admit(0, vec![0, 1], 0.0);
        {
            let r = p.get_mut(0);
            r.shared_blocks = 1;
            r.shared_tokens = 8;
            r.prefilled = 8;
            r.decoded = 2;
        }
        p.preempt(0, 1.0);
        assert_eq!(p.get(0).shared_blocks, 0, "preempted request holds no shared run");
        assert_eq!(p.get(0).shared_tokens, 0);
        p.admit(1, vec![2, 3], 1.0);
        {
            let r = p.get_mut(1);
            r.shared_blocks = 1;
            r.shared_tokens = 8;
            r.prefilled = 8;
            r.decoded = 4;
        }
        p.complete(1, 2.0);
        assert_eq!(p.get(1).shared_blocks, 0);
        assert_eq!(p.get(1).shared_tokens, 0);
    }

    #[test]
    fn forced_fallback_finalizes_the_wait_and_drains_once() {
        use super::super::request::PrefixWaitState;
        use crate::workload::PrefixSpec;
        let mut p = RequestPool::new();
        p.push(RequestSpec {
            prompt_len: 8,
            decode_len: 2,
            arrival: 0.0,
            prefix: Some(PrefixSpec::whole(4, 8)),
        });
        p.get_mut(0).prefix_wait = Some(PrefixWaitState {
            hash: 4,
            last_fill: 0,
            last_stall_events: 0,
            stalled_iters: 2,
            since: 1.0,
        });
        assert_eq!(p.prefix_waiting_count(), 1);
        assert_eq!(p.oldest_prefix_waiter(), Some(0));
        p.force_prefix_fallback(0, 3.5, 0);
        {
            let r = p.get(0);
            assert!(r.prefix_fallback);
            assert!(r.prefix_wait.is_none(), "the wait-for edge is dropped");
            assert!((r.prefix_wait_time - 2.5).abs() < 1e-12, "wait time finalized");
        }
        assert_eq!(p.prefix_waiting_count(), 0);
        assert_eq!(p.oldest_prefix_waiter(), None);
        assert_eq!(p.take_prefix_fallbacks(), 1);
        assert_eq!(p.take_prefix_fallbacks(), 0, "events drain");
        // idempotent: a second force neither re-counts nor re-times
        p.force_prefix_fallback(0, 4.0, 0);
        assert_eq!(p.take_prefix_fallbacks(), 0);
        p.note_prefix_wait_tick();
        assert_eq!(p.take_prefix_wait_ticks(), 1);
        assert_eq!(p.take_prefix_wait_ticks(), 0);
    }

    #[test]
    fn prefix_hit_events_drain_like_rejections() {
        let mut p = RequestPool::new();
        assert_eq!(p.take_prefix_hits(), 0);
        p.note_prefix_hit();
        p.note_prefix_hit();
        assert_eq!(p.take_prefix_hits(), 2);
        assert_eq!(p.take_prefix_hits(), 0, "events drain");
    }

    #[test]
    fn stamp_token_feeds_the_pool_tbt_distribution() {
        let mut p = RequestPool::new();
        p.push(RequestSpec { prompt_len: 4, decode_len: 3, arrival: 0.0, prefix: None });
        p.enable_tbt_window();
        p.stamp_token(0, 1.0); // first token: TTFT territory, no gap
        p.stamp_token(0, 1.4);
        p.stamp_token(0, 1.5);
        assert_eq!(p.tbt_summary().count(), 2);
        assert!((p.tbt_summary().max() - 0.4).abs() < 1e-12);
        assert!((p.get(0).max_tbt - 0.4).abs() < 1e-12);
        let w = p.take_tbt_window();
        assert_eq!(w.count(), 2, "window mirrors the gaps since the last drain");
        assert_eq!(p.take_tbt_window().count(), 0, "window drains");
        assert_eq!(p.tbt_summary().count(), 2, "cumulative summary survives the drain");
    }

    #[test]
    fn retire_terminal_pops_only_the_terminal_prefix_and_keeps_ids_stable() {
        let mut p = RequestPool::new();
        for i in 0..4 {
            p.push(RequestSpec {
                prompt_len: 8,
                decode_len: 1,
                arrival: i as f64 * 0.1,
                prefix: None,
            });
        }
        // complete 0 and 2; 1 stays queued so retirement must stop at it
        for id in [0, 2] {
            p.admit(id, vec![id], 0.5);
            p.get_mut(id).prefilled = 8;
            p.get_mut(id).decoded = 1;
            p.complete(id, 1.0);
        }
        let retired = p.retire_terminal();
        assert_eq!(retired.len(), 1, "only the contiguous terminal prefix retires");
        assert_eq!(retired[0].id, 0);
        assert_eq!(p.base(), 1);
        assert_eq!(p.len(), 4, "len() keeps counting retired requests");
        assert_eq!(p.retained_count(), 3);
        // surviving ids keep resolving through the offset
        assert_eq!(p.get(2).completed_at, Some(1.0));
        assert_eq!(p.arrived_queued(1.0), vec![1, 3]);
        assert!(!p.all_complete());
        // finishing the rest retires everything and all_complete holds
        for id in [1, 3] {
            p.admit(id, vec![id], 1.0);
            p.get_mut(id).prefilled = 8;
            p.get_mut(id).decoded = 1;
            p.complete(id, 2.0);
        }
        assert!(p.all_complete());
        let retired = p.retire_terminal();
        assert_eq!(retired.len(), 3);
        assert_eq!(p.retained_count(), 0);
        assert_eq!(p.base(), 4);
        assert!(p.all_complete(), "all_complete survives full retirement");
        // a fresh push after retirement gets the next dense id
        let id = p.push(RequestSpec { prompt_len: 8, decode_len: 1, arrival: 3.0, prefix: None });
        assert_eq!(id, 4);
        assert_eq!(p.get(4).arrival, 3.0);
    }

    #[test]
    fn preempt_requeues_at_arrival_position() {
        let mut p = RequestPool::new();
        p.push(RequestSpec { prompt_len: 8, decode_len: 4, arrival: 0.0, prefix: None });
        p.push(RequestSpec { prompt_len: 8, decode_len: 4, arrival: 0.1, prefix: None });
        p.push(RequestSpec { prompt_len: 8, decode_len: 4, arrival: 0.2, prefix: None });
        p.admit(0, vec![0], 0.0);
        p.admit(1, vec![1, 2], 0.1);
        p.get_mut(1).prefilled = 8;
        p.get_mut(1).decoded = 2;
        // preempt the later request: its blocks come back, it rejoins the
        // queue AHEAD of request 2 (earlier arrival), progress intact
        let blocks = p.preempt(1, 0.5);
        assert_eq!(blocks, vec![1, 2]);
        assert_eq!(p.active_ids(), &[0]);
        assert_eq!(p.arrived_queued(1.0), vec![1, 2]);
        assert_eq!(p.get(1).kv_len(), 9);
        assert_eq!(p.get(1).preemptions, 1);
        // re-admission works through the normal path
        p.admit(1, vec![3, 4], 0.6);
        assert_eq!(p.active_ids(), &[0, 1]);
        assert_eq!(p.arrived_queued(1.0), vec![2]);
        // admitted_at keeps the FIRST admission time
        assert_eq!(p.get(1).admitted_at, Some(0.1));
    }
}
