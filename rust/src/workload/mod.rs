//! Workload generation: the request populations the paper evaluates on.
//!
//! §5.1 uses fixed (sequence length, P:D ratio) populations; §5.3 samples
//! sequence lengths from Zipf(θ=0.4) over [1K, 4K] and splits each into
//! prefill/decode at a fixed P:D ratio of 10.
//!
//! [`shared_prefix_population`] models production template traffic
//! (shared system prompts, few-shot scaffolds): N templates, each a fixed
//! prompt prefix, with request fanout Zipf-skewed across templates — the
//! workload class copy-on-write prefix sharing exists for.

use crate::util::Rng;

/// Identity of a shared prompt prefix: requests carrying the same `id`
/// open with the same `len` prompt tokens, so their KV for those tokens is
/// byte-identical and shareable across the paged block map.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PrefixSpec {
    /// Prefix hash — the template's identity in the KV prefix index.
    pub id: u64,
    /// Shared prefix length in tokens (a strict prefix of the prompt).
    pub len: usize,
}

/// A request before it enters the system: prompt length and the number of
/// output tokens it will generate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RequestSpec {
    pub prompt_len: usize,
    pub decode_len: usize,
    /// Arrival time, seconds (0.0 ⇒ present at start).
    pub arrival: f64,
    /// Shared-template identity of the prompt's opening tokens, if any.
    /// `None` (the default everywhere outside template workloads) means
    /// the whole prompt is unique to this request.
    pub prefix: Option<PrefixSpec>,
}

impl RequestSpec {
    pub fn total_len(&self) -> usize {
        self.prompt_len + self.decode_len
    }

    pub fn pd_ratio(&self) -> f64 {
        self.prompt_len as f64 / self.decode_len.max(1) as f64
    }
}

/// Split a total sequence length into (prefill, decode) tokens satisfying a
/// target P:D ratio (decode ≥ 1, prefill ≥ 1).
pub fn split_by_pd_ratio(total: usize, pd: f64) -> (usize, usize) {
    let d = ((total as f64) / (pd + 1.0)).round().max(1.0) as usize;
    let d = d.min(total - 1).max(1);
    (total - d, d)
}

/// §5.1-style population: `n` identical requests of `seq_len` tokens at the
/// given P:D ratio, all present at t=0.
pub fn uniform_population(n: usize, seq_len: usize, pd: f64) -> Vec<RequestSpec> {
    let (p, d) = split_by_pd_ratio(seq_len, pd);
    (0..n)
        .map(|_| RequestSpec { prompt_len: p, decode_len: d, arrival: 0.0, prefix: None })
        .collect()
}

/// §5.3-style population: sequence lengths from Zipf(θ) over
/// [min_len, max_len], split at the fixed P:D ratio.
pub fn zipf_population(
    rng: &mut Rng,
    n: usize,
    theta: f64,
    min_len: usize,
    max_len: usize,
    pd: f64,
) -> Vec<RequestSpec> {
    (0..n)
        .map(|_| {
            let total = rng.zipf(theta, min_len as u64, max_len as u64) as usize;
            let (p, d) = split_by_pd_ratio(total, pd);
            RequestSpec { prompt_len: p, decode_len: d, arrival: 0.0, prefix: None }
        })
        .collect()
}

/// Template traffic: `num_templates` shared prompt prefixes of
/// `prefix_len` tokens each, request fanout Zipf(θ)-skewed across
/// templates (template 1 hottest). Every request opens with its template's
/// prefix and appends a unique part of `[min_unique, max_unique]` tokens,
/// split into (prompt suffix, decode) at the P:D ratio `pd` — so
/// `prompt_len = prefix_len + suffix` and the prefix is always a *strict*
/// prefix of the prompt (at least one unique prompt token remains to
/// produce the first output logits).
pub fn shared_prefix_population(
    rng: &mut Rng,
    n: usize,
    num_templates: usize,
    theta: f64,
    prefix_len: usize,
    min_unique: usize,
    max_unique: usize,
    pd: f64,
) -> Vec<RequestSpec> {
    assert!(num_templates > 0, "need at least one template");
    assert!(min_unique >= 2 && min_unique <= max_unique, "unique part needs prompt + decode");
    (0..n)
        .map(|_| {
            let t = rng.zipf(theta, 1, num_templates as u64) - 1;
            let unique = rng.usize(min_unique, max_unique);
            let (p, d) = split_by_pd_ratio(unique, pd);
            RequestSpec {
                prompt_len: prefix_len + p,
                decode_len: d,
                arrival: 0.0,
                prefix: Some(PrefixSpec { id: t, len: prefix_len }),
            }
        })
        .collect()
}

/// Poisson arrivals at `rate` req/s layered over any population.
pub fn with_poisson_arrivals(rng: &mut Rng, mut pop: Vec<RequestSpec>, rate: f64) -> Vec<RequestSpec> {
    let mut t = 0.0;
    for r in pop.iter_mut() {
        t += rng.exp(rate);
        r.arrival = t;
    }
    pop
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_respects_ratio() {
        let (p, d) = split_by_pd_ratio(1024, 50.0);
        assert_eq!(p + d, 1024);
        let ratio = p as f64 / d as f64;
        assert!((45.0..56.0).contains(&ratio), "p={p} d={d}");
    }

    #[test]
    fn split_degenerate_cases() {
        // tiny P:D still leaves at least one prefill token
        let (p, d) = split_by_pd_ratio(16, 0.01);
        assert!(p >= 1 && d >= 1 && p + d == 16);
        // huge P:D leaves at least one decode token
        let (p, d) = split_by_pd_ratio(16, 1e9);
        assert_eq!((p, d), (15, 1));
    }

    #[test]
    fn uniform_population_is_uniform() {
        let pop = uniform_population(6, 1024, 10.0);
        assert_eq!(pop.len(), 6);
        assert!(pop.iter().all(|r| r.total_len() == 1024 && r.arrival == 0.0));
        assert!(pop.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn zipf_population_within_bounds() {
        let mut rng = Rng::new(1);
        let pop = zipf_population(&mut rng, 500, 0.4, 1024, 4096, 10.0);
        assert!(pop.iter().all(|r| (1024..=4096).contains(&r.total_len())));
        // P:D ≈ 10 for every request
        assert!(pop.iter().all(|r| (6.0..16.0).contains(&r.pd_ratio())));
    }

    #[test]
    fn shared_prefix_population_is_template_shaped() {
        let mut rng = Rng::new(3);
        let pop = shared_prefix_population(&mut rng, 400, 8, 0.8, 512, 32, 256, 5.0);
        assert_eq!(pop.len(), 400);
        let mut fanout = [0usize; 8];
        for r in &pop {
            let pfx = r.prefix.expect("every request carries its template");
            assert_eq!(pfx.len, 512);
            assert!(pfx.id < 8);
            fanout[pfx.id as usize] += 1;
            // the prefix is a STRICT prefix of the prompt
            assert!(r.prompt_len > pfx.len);
            assert!(r.prompt_len - pfx.len + r.decode_len <= 256);
            assert!(r.decode_len >= 1);
        }
        // Zipf fanout: the hottest template dominates the coldest
        assert!(fanout[0] > 2 * fanout[7], "fanout {fanout:?} not skewed");
        assert!(fanout.iter().all(|&c| c > 0), "every template sees traffic");
    }

    #[test]
    fn poisson_arrivals_are_increasing() {
        let mut rng = Rng::new(2);
        let pop = with_poisson_arrivals(&mut rng, uniform_population(50, 512, 5.0), 10.0);
        assert!(pop.windows(2).all(|w| w[0].arrival < w[1].arrival));
        assert!(pop[0].arrival > 0.0);
    }
}
