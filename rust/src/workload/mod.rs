//! Workload generation: the request populations the paper evaluates on.
//!
//! §5.1 uses fixed (sequence length, P:D ratio) populations; §5.3 samples
//! sequence lengths from Zipf(θ=0.4) over [1K, 4K] and splits each into
//! prefill/decode at a fixed P:D ratio of 10.
//!
//! [`shared_prefix_population`] models production template traffic
//! (shared system prompts, few-shot scaffolds): N templates, each a fixed
//! prompt prefix, with request fanout Zipf-skewed across templates — the
//! workload class copy-on-write prefix sharing exists for.
//! [`conversation_tree_population`] goes further: a shared system prompt
//! fans into divergent branches and multi-turn follow-ups that extend
//! their own prior path — the agentic workload class only a radix-tree
//! prefix store (partial, subtree-granular matches) can serve.

use crate::util::{mix64, Rng};

/// Identity of a shared prompt prefix: requests carrying the same `id`
/// open with the same `len` prompt tokens, so their KV for those tokens is
/// byte-identical and shareable across the paged block map.
///
/// Two forms. The whole-template form ([`PrefixSpec::whole`], empty
/// `path`) matches all-or-nothing on `id` — the radix store lowers it to
/// a single-path tree via `kv::derived_path`, reproducing the flat-index
/// behavior bit for bit. The content form ([`PrefixSpec::with_path`])
/// carries the cumulative per-block hash of the prefix's tokens, so the
/// KV layer can share the **longest resident match** even when two
/// requests' prefixes diverge mid-way (conversation trees, templates
/// sharing a system prompt).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PrefixSpec {
    /// Prefix hash — the template's identity in the KV prefix index.
    pub id: u64,
    /// Shared prefix length in tokens (a strict prefix of the prompt).
    pub len: usize,
    /// Cumulative content hash at every full block boundary of the
    /// prefix (`path[k]` identifies tokens `[0, (k+1)·block_size)`).
    /// Empty for whole-template specs.
    pub path: Vec<u64>,
}

impl PrefixSpec {
    /// Whole-template prefix: one opaque hash covering `len` tokens.
    pub fn whole(id: u64, len: usize) -> Self {
        PrefixSpec { id, len, path: Vec::new() }
    }

    /// Block-granular content prefix: `path` holds the cumulative hash at
    /// each full block boundary of the first `len` prompt tokens.
    pub fn with_path(id: u64, len: usize, path: Vec<u64>) -> Self {
        PrefixSpec { id, len, path }
    }
}

/// A request before it enters the system: prompt length and the number of
/// output tokens it will generate.
#[derive(Clone, Debug, PartialEq)]
pub struct RequestSpec {
    pub prompt_len: usize,
    pub decode_len: usize,
    /// Arrival time, seconds (0.0 ⇒ present at start).
    pub arrival: f64,
    /// Shared-template identity of the prompt's opening tokens, if any.
    /// `None` (the default everywhere outside template workloads) means
    /// the whole prompt is unique to this request.
    pub prefix: Option<PrefixSpec>,
}

impl RequestSpec {
    pub fn total_len(&self) -> usize {
        self.prompt_len + self.decode_len
    }

    pub fn pd_ratio(&self) -> f64 {
        self.prompt_len as f64 / self.decode_len.max(1) as f64
    }
}

/// Split a total sequence length into (prefill, decode) tokens satisfying a
/// target P:D ratio (decode ≥ 1, prefill ≥ 1).
pub fn split_by_pd_ratio(total: usize, pd: f64) -> (usize, usize) {
    let d = ((total as f64) / (pd + 1.0)).round().max(1.0) as usize;
    let d = d.min(total - 1).max(1);
    (total - d, d)
}

/// §5.1-style population: `n` identical requests of `seq_len` tokens at the
/// given P:D ratio, all present at t=0.
pub fn uniform_population(n: usize, seq_len: usize, pd: f64) -> Vec<RequestSpec> {
    let (p, d) = split_by_pd_ratio(seq_len, pd);
    (0..n)
        .map(|_| RequestSpec { prompt_len: p, decode_len: d, arrival: 0.0, prefix: None })
        .collect()
}

/// §5.3-style population: sequence lengths from Zipf(θ) over
/// [min_len, max_len], split at the fixed P:D ratio.
pub fn zipf_population(
    rng: &mut Rng,
    n: usize,
    theta: f64,
    min_len: usize,
    max_len: usize,
    pd: f64,
) -> Vec<RequestSpec> {
    (0..n)
        .map(|_| {
            let total = rng.zipf(theta, min_len as u64, max_len as u64) as usize;
            let (p, d) = split_by_pd_ratio(total, pd);
            RequestSpec { prompt_len: p, decode_len: d, arrival: 0.0, prefix: None }
        })
        .collect()
}

/// Template traffic: `num_templates` shared prompt prefixes of
/// `prefix_len` tokens each, request fanout Zipf(θ)-skewed across
/// templates (template 1 hottest). Every request opens with its template's
/// prefix and appends a unique part of `[min_unique, max_unique]` tokens,
/// split into (prompt suffix, decode) at the P:D ratio `pd` — so
/// `prompt_len = prefix_len + suffix` and the prefix is always a *strict*
/// prefix of the prompt (at least one unique prompt token remains to
/// produce the first output logits).
pub fn shared_prefix_population(
    rng: &mut Rng,
    n: usize,
    num_templates: usize,
    theta: f64,
    prefix_len: usize,
    min_unique: usize,
    max_unique: usize,
    pd: f64,
) -> Vec<RequestSpec> {
    assert!(num_templates > 0, "need at least one template");
    assert!(min_unique >= 2 && min_unique <= max_unique, "unique part needs prompt + decode");
    (0..n)
        .map(|_| {
            let t = rng.zipf(theta, 1, num_templates as u64) - 1;
            let unique = rng.usize(min_unique, max_unique);
            let (p, d) = split_by_pd_ratio(unique, pd);
            RequestSpec {
                prompt_len: prefix_len + p,
                decode_len: d,
                arrival: 0.0,
                prefix: Some(PrefixSpec::whole(t, prefix_len)),
            }
        })
        .collect()
}

/// Cumulative per-block content hashing for conversation-tree prompts:
/// fold one `mix64` per token, snapshotting the running hash at every
/// full `block_size` boundary. Cloning a builder forks the conversation —
/// both forks agree on every block hash up to the fork point, which is
/// exactly the property the radix prefix store keys on.
#[derive(Clone, Debug)]
pub struct PathBuilder {
    h: u64,
    tokens: usize,
    block_size: usize,
    path: Vec<u64>,
}

impl PathBuilder {
    pub fn new(block_size: usize) -> Self {
        assert!(block_size > 0, "content paths need a block size");
        PathBuilder { h: 0x9E37_79B9_7F4A_7C15, tokens: 0, block_size, path: Vec::new() }
    }

    /// Append `count` tokens of content derived from `seed`.
    pub fn extend(&mut self, seed: u64, count: usize) {
        for off in 0..count as u64 {
            self.h = mix64(self.h ^ mix64(seed.wrapping_add(off.wrapping_mul(0x1_0000_0001_B3))));
            self.tokens += 1;
            if self.tokens % self.block_size == 0 {
                self.path.push(self.h);
            }
        }
    }

    /// Tokens folded so far.
    pub fn tokens(&self) -> usize {
        self.tokens
    }

    /// Running content hash over ALL folded tokens (block-aligned or not).
    pub fn hash(&self) -> u64 {
        self.h
    }

    /// Cumulative hash at each full block boundary, in order.
    pub fn path(&self) -> &[u64] {
        &self.path
    }
}

/// Conversation-tree traffic — the agentic/multi-turn workload class: a
/// shared `system_len`-token system prompt fans into `branches` divergent
/// scaffolds (tool-call preambles, few-shot variants) of `branch_len`
/// tokens; each of `conversations` conversations picks a branch, then
/// runs `turns` turns. Turn `k`'s request carries the conversation's
/// accumulated content path as its prefix (everything before this turn's
/// unique part is KV some earlier turn already computed), appends a
/// unique prompt part of `[min_unique, max_unique]` tokens and decodes
/// `[min_decode, max_decode]` tokens — and the follow-up's path extends
/// through BOTH, because the next turn re-reads the whole transcript.
///
/// Emission is turn-major (every conversation's turn 0, then every turn
/// 1, …), matching how concurrent sessions interleave. Whole-template
/// stores share only exact-id re-hits here (each turn's `id` is unique);
/// a radix store shares the system prompt, the branch scaffold and every
/// prior turn — the gap the acceptance test measures.
#[allow(clippy::too_many_arguments)]
pub fn conversation_tree_population(
    rng: &mut Rng,
    conversations: usize,
    branches: usize,
    system_len: usize,
    branch_len: usize,
    turns: usize,
    min_unique: usize,
    max_unique: usize,
    min_decode: usize,
    max_decode: usize,
    block_size: usize,
) -> Vec<RequestSpec> {
    assert!(conversations > 0 && branches > 0 && turns > 0, "an empty tree is no workload");
    assert!(system_len > 0, "the shared system prompt is the point");
    assert!(min_unique >= 1 && min_unique <= max_unique, "bad unique range");
    assert!(min_decode >= 1 && min_decode <= max_decode, "bad decode range");
    let mut sys = PathBuilder::new(block_size);
    sys.extend(mix64(0xABCD), system_len);
    let branch_pbs: Vec<PathBuilder> = (0..branches)
        .map(|b| {
            let mut pb = sys.clone();
            pb.extend(mix64(0xB000 + b as u64), branch_len);
            pb
        })
        .collect();
    let mut conv_pb: Vec<PathBuilder> = (0..conversations)
        .map(|_| branch_pbs[rng.usize(0, branches - 1)].clone())
        .collect();
    let mut out = Vec::with_capacity(conversations * turns);
    for k in 0..turns {
        for pb in conv_pb.iter_mut() {
            let plen = pb.tokens();
            let path = pb.path().to_vec();
            let unique = rng.usize(min_unique, max_unique);
            let decode = rng.usize(min_decode, max_decode);
            // the turn's identity folds the conversation's content hash
            // with its depth — unique per (conversation, turn)
            let rid = mix64(pb.hash() ^ (plen as u64 + 17 * k as u64 + 1));
            out.push(RequestSpec {
                prompt_len: plen + unique,
                decode_len: decode,
                arrival: 0.0,
                prefix: Some(PrefixSpec::with_path(rid, plen, path)),
            });
            // the follow-up extends through this turn's unique prompt
            // part and its decoded response
            pb.extend(mix64(rid ^ 0x11), unique);
            pb.extend(mix64(rid ^ 0x22), decode);
        }
    }
    out
}

/// Poisson arrivals at `rate` req/s layered over any population.
pub fn with_poisson_arrivals(rng: &mut Rng, mut pop: Vec<RequestSpec>, rate: f64) -> Vec<RequestSpec> {
    let mut t = 0.0;
    for r in pop.iter_mut() {
        t += rng.exp(rate);
        r.arrival = t;
    }
    pop
}

/// Per-template arrival skew over a template population: a single global
/// Poisson(`rate`) slot timeline whose slots are assigned to templates in
/// round-robin **bursts** of `burst_len`, so consecutive arrivals share a
/// template (the session/tenant temporal locality real template traffic
/// has, and the signal a prefix-affinity router exploits — its home
/// replica stays warm through a burst). The marginal arrival process is
/// exactly `with_poisson_arrivals`; only which request owns which slot
/// changes. Untagged requests form one bucket of their own. Request order
/// within a template is preserved; the returned vector keeps its input
/// order (arrivals are NOT sorted — dispatch layers order by arrival).
pub fn with_template_burst_arrivals(
    rng: &mut Rng,
    mut pop: Vec<RequestSpec>,
    rate: f64,
    burst_len: usize,
) -> Vec<RequestSpec> {
    let n = pop.len();
    let burst = burst_len.max(1);
    let mut times = Vec::with_capacity(n);
    let mut t = 0.0;
    for _ in 0..n {
        t += rng.exp(rate);
        times.push(t);
    }
    // group request indices by template, in order of first appearance
    let mut keys: Vec<Option<u64>> = Vec::new();
    let mut groups: Vec<Vec<usize>> = Vec::new();
    for (i, s) in pop.iter().enumerate() {
        let k = s.prefix.as_ref().map(|p| p.id);
        match keys.iter().position(|&q| q == k) {
            Some(gi) => groups[gi].push(i),
            None => {
                keys.push(k);
                groups.push(vec![i]);
            }
        }
    }
    // hand out the time slots in round-robin bursts across templates
    let mut heads = vec![0usize; groups.len()];
    let mut slot = 0usize;
    while slot < n {
        for (gi, group) in groups.iter().enumerate() {
            let take = burst.min(group.len() - heads[gi]);
            for _ in 0..take {
                pop[group[heads[gi]]].arrival = times[slot];
                heads[gi] += 1;
                slot += 1;
            }
            if slot >= n {
                break;
            }
        }
    }
    pop
}

/// One independent RNG stream per replica, derived by [`Rng::split`] from
/// a single root generator. Replica `i`'s stream depends only on the root
/// seed and `i`, never on how many replicas the sweep uses — so growing a
/// sweep from 8 to 64 replicas leaves the first 8 replicas' workloads
/// bit-identical instead of reshuffling one shared sequence.
pub fn per_replica_rngs(root: &Rng, replicas: usize) -> Vec<Rng> {
    (0..replicas).map(|ri| root.split(ri as u64)).collect()
}

/// Per-replica shared-prefix shards with Poisson arrivals, each drawn
/// from its own split stream (template ids salted per replica so shards
/// don't collide in a shared prefix index). Returns one shard per
/// replica; shard `i` is stable under changes to `replicas`.
#[allow(clippy::too_many_arguments)]
pub fn sharded_shared_prefix_population(
    root: &Rng,
    replicas: usize,
    per_replica: usize,
    num_templates: usize,
    theta: f64,
    prefix_len: usize,
    min_unique: usize,
    max_unique: usize,
    pd: f64,
    rate: f64,
) -> Vec<Vec<RequestSpec>> {
    per_replica_rngs(root, replicas)
        .iter_mut()
        .enumerate()
        .map(|(ri, rng)| {
            let mut shard = shared_prefix_population(
                rng,
                per_replica,
                num_templates,
                theta,
                prefix_len,
                min_unique,
                max_unique,
                pd,
            );
            for s in shard.iter_mut() {
                if let Some(p) = s.prefix.as_mut() {
                    p.id += ri as u64 * 1_000_003;
                }
            }
            with_poisson_arrivals(rng, shard, rate)
        })
        .collect()
}

/// Time-varying arrival intensity for soak runs: a diurnal sinusoid with
/// periodic flash-crowd bursts layered on top. All closed-loop populations
/// above draw a FIXED request list up front; a soak horizon instead asks
/// "what is the rate right now" and regenerates forever.
#[derive(Clone, Copy, Debug)]
pub struct RateCurve {
    /// Mean arrival rate, req/s.
    pub base_rate: f64,
    /// Diurnal swing as a fraction of `base_rate`, in [0, 1): rate moves
    /// through `base × (1 ± amp)` over each period.
    pub diurnal_amp: f64,
    /// Diurnal period, seconds of simulated time.
    pub diurnal_period: f64,
    /// A flash crowd starts every `flash_every` seconds (0 disables).
    pub flash_every: f64,
    /// Flash-crowd duration, seconds.
    pub flash_dur: f64,
    /// Rate multiplier while a flash crowd is live (≥ 1).
    pub flash_mult: f64,
}

impl RateCurve {
    /// Constant `rate` req/s — no diurnal swing, no flash crowds.
    pub fn steady(rate: f64) -> Self {
        assert!(rate > 0.0, "arrival rate must be positive");
        RateCurve {
            base_rate: rate,
            diurnal_amp: 0.0,
            diurnal_period: 1.0,
            flash_every: 0.0,
            flash_dur: 0.0,
            flash_mult: 1.0,
        }
    }

    pub fn with_diurnal(mut self, amp: f64, period: f64) -> Self {
        assert!((0.0..1.0).contains(&amp), "diurnal amplitude must be in [0, 1)");
        assert!(period > 0.0, "diurnal period must be positive");
        self.diurnal_amp = amp;
        self.diurnal_period = period;
        self
    }

    pub fn with_flash(mut self, every: f64, dur: f64, mult: f64) -> Self {
        assert!(every > 0.0 && dur > 0.0 && dur < every, "flash window must fit its period");
        assert!(mult >= 1.0, "a flash crowd cannot lower the rate");
        self.flash_every = every;
        self.flash_dur = dur;
        self.flash_mult = mult;
        self
    }

    /// Is a flash crowd live at time `t`?
    pub fn in_flash(&self, t: f64) -> bool {
        self.flash_every > 0.0 && t.rem_euclid(self.flash_every) < self.flash_dur
    }

    /// Instantaneous arrival rate at time `t` (always strictly positive:
    /// the sinusoid is bounded by `amp < 1` and the flash only multiplies).
    pub fn rate_at(&self, t: f64) -> f64 {
        let phase = 2.0 * std::f64::consts::PI * t / self.diurnal_period;
        let mut r = self.base_rate * (1.0 + self.diurnal_amp * phase.sin());
        if self.in_flash(t) {
            r *= self.flash_mult;
        }
        r
    }

    /// A tight upper bound on [`rate_at`](Self::rate_at) over all `t` —
    /// the majorizing rate exact nonhomogeneous-Poisson thinning draws
    /// candidates at.
    pub fn rate_max(&self) -> f64 {
        self.base_rate * (1.0 + self.diurnal_amp) * self.flash_mult.max(1.0)
    }
}

/// A regenerating workload for wall-clock soak horizons: nonhomogeneous
/// Poisson arrivals following a [`RateCurve`], prompt/output lengths that
/// drift sinusoidally over time, and (optionally) template traffic whose
/// flash crowds all pile onto the hottest template — the pattern that
/// makes a static `token_budget` / `max_prefix_wait` setting fail.
///
/// Unlike the population builders above, this never materialises the whole
/// request list: [`fill_until`](Self::fill_until) generates just far
/// enough ahead of the engine clock, so a soak run's workload memory is
/// O(1) no matter the horizon.
#[derive(Clone, Debug)]
pub struct SoakWorkload {
    rng: Rng,
    curve: RateCurve,
    /// Arrival clock: time of the last generated arrival.
    t: f64,
    prompt_range: (usize, usize),
    decode_range: (usize, usize),
    /// Length-drift swing as a fraction of the drawn length, in [0, 1).
    drift_amp: f64,
    drift_period: f64,
    /// Template traffic: (num_templates, prefix_len, zipf theta).
    templates: Option<(usize, usize, f64)>,
    /// Exact nonhomogeneous-Poisson arrivals by thinning (draw candidate
    /// gaps at the majorizing `rate_max`, accept with probability
    /// `rate_at(t)/rate_max`). Off by default: the legacy stepwise
    /// approximation stays the bit-stable path every soak pin rides on.
    exact_arrivals: bool,
    /// One-spec lookahead: the first arrival PAST the previous horizon,
    /// held back so no draw is ever discarded between fill calls.
    pending: Option<RequestSpec>,
    generated: usize,
}

impl SoakWorkload {
    pub fn new(seed: u64, curve: RateCurve) -> Self {
        SoakWorkload {
            rng: Rng::new(seed),
            curve,
            t: 0.0,
            prompt_range: (64, 512),
            decode_range: (32, 256),
            drift_amp: 0.0,
            drift_period: 1.0,
            templates: None,
            exact_arrivals: false,
            pending: None,
            generated: 0,
        }
    }

    pub fn with_lengths(mut self, prompt: (usize, usize), decode: (usize, usize)) -> Self {
        assert!(prompt.0 >= 1 && prompt.0 <= prompt.1, "bad prompt range");
        assert!(decode.0 >= 1 && decode.0 <= decode.1, "bad decode range");
        self.prompt_range = prompt;
        self.decode_range = decode;
        self
    }

    pub fn with_drift(mut self, amp: f64, period: f64) -> Self {
        assert!((0.0..1.0).contains(&amp), "drift amplitude must be in [0, 1)");
        assert!(period > 0.0, "drift period must be positive");
        self.drift_amp = amp;
        self.drift_period = period;
        self
    }

    pub fn with_templates(mut self, n: usize, prefix_len: usize, theta: f64) -> Self {
        assert!(n > 0 && prefix_len > 0, "template traffic needs templates");
        self.templates = Some((n, prefix_len, theta));
        self
    }

    /// Switch to exact nonhomogeneous-Poisson arrivals by thinning. The
    /// default stepwise path (rate frozen at the previous arrival)
    /// overshoots downswings and undershoots upswings when gaps are long
    /// relative to the curve period; thinning is exact at any rate.
    pub fn with_exact_arrivals(mut self) -> Self {
        self.exact_arrivals = true;
        self
    }

    pub fn curve(&self) -> &RateCurve {
        &self.curve
    }

    /// Arrivals generated so far (including one possibly still pending).
    pub fn generated(&self) -> usize {
        self.generated
    }

    /// Time of the most recently generated arrival.
    pub fn clock(&self) -> f64 {
        self.t
    }

    fn drifted(&mut self, range: (usize, usize)) -> usize {
        let raw = self.rng.usize(range.0, range.1);
        let phase = 2.0 * std::f64::consts::PI * self.t / self.drift_period;
        let scale = 1.0 + self.drift_amp * phase.sin();
        ((raw as f64 * scale).round() as usize).max(1)
    }

    /// Draw the next arrival. Default: stepwise approximation (each gap
    /// uses the rate at the previous arrival, which tracks the curve for
    /// gaps ≪ the period). With [`with_exact_arrivals`]
    /// (Self::with_exact_arrivals): exact thinning — candidates at the
    /// majorizing `rate_max`, accepted with probability
    /// `rate_at(t)/rate_max`, which samples the nonhomogeneous process
    /// exactly regardless of how the gaps compare to the period.
    fn next_spec(&mut self) -> RequestSpec {
        if self.exact_arrivals {
            let rate_max = self.curve.rate_max();
            loop {
                self.t += self.rng.exp(rate_max);
                if self.rng.f64() < self.curve.rate_at(self.t) / rate_max {
                    break;
                }
            }
        } else {
            let rate = self.curve.rate_at(self.t);
            self.t += self.rng.exp(rate);
        }
        let prefix = self.templates.map(|(n, len, theta)| {
            // flash crowds are template-correlated: everyone hits the
            // same hot template (id 0), which is what makes them both a
            // prefix-cache gift and a budget hazard
            let id = if self.curve.in_flash(self.t) {
                0
            } else {
                self.rng.zipf(theta, 1, n as u64) - 1
            };
            PrefixSpec::whole(id, len)
        });
        let unique = self.drifted(self.prompt_range);
        let prompt_len = match &prefix {
            // the template prefix must stay a STRICT prefix of the prompt
            Some(p) => p.len + unique.max(1),
            None => unique,
        };
        let decode_len = self.drifted(self.decode_range);
        self.generated += 1;
        RequestSpec { prompt_len, decode_len, arrival: self.t, prefix }
    }

    /// Push every arrival with `arrival ≤ horizon` into `pool`; returns
    /// how many were pushed. The first draw past the horizon is retained
    /// for the next call, so consecutive fills partition the timeline.
    pub fn fill_until(&mut self, pool: &mut crate::coordinator::RequestPool, horizon: f64) -> usize {
        let mut pushed = 0;
        if let Some(spec) = self.pending.as_ref() {
            if spec.arrival > horizon {
                return 0;
            }
        }
        if let Some(spec) = self.pending.take() {
            pool.push(spec);
            pushed += 1;
        }
        loop {
            let spec = self.next_spec();
            if spec.arrival > horizon {
                self.pending = Some(spec);
                return pushed;
            }
            pool.push(spec);
            pushed += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_respects_ratio() {
        let (p, d) = split_by_pd_ratio(1024, 50.0);
        assert_eq!(p + d, 1024);
        let ratio = p as f64 / d as f64;
        assert!((45.0..56.0).contains(&ratio), "p={p} d={d}");
    }

    #[test]
    fn split_degenerate_cases() {
        // tiny P:D still leaves at least one prefill token
        let (p, d) = split_by_pd_ratio(16, 0.01);
        assert!(p >= 1 && d >= 1 && p + d == 16);
        // huge P:D leaves at least one decode token
        let (p, d) = split_by_pd_ratio(16, 1e9);
        assert_eq!((p, d), (15, 1));
    }

    #[test]
    fn uniform_population_is_uniform() {
        let pop = uniform_population(6, 1024, 10.0);
        assert_eq!(pop.len(), 6);
        assert!(pop.iter().all(|r| r.total_len() == 1024 && r.arrival == 0.0));
        assert!(pop.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn zipf_population_within_bounds() {
        let mut rng = Rng::new(1);
        let pop = zipf_population(&mut rng, 500, 0.4, 1024, 4096, 10.0);
        assert!(pop.iter().all(|r| (1024..=4096).contains(&r.total_len())));
        // P:D ≈ 10 for every request
        assert!(pop.iter().all(|r| (6.0..16.0).contains(&r.pd_ratio())));
    }

    #[test]
    fn shared_prefix_population_is_template_shaped() {
        let mut rng = Rng::new(3);
        let pop = shared_prefix_population(&mut rng, 400, 8, 0.8, 512, 32, 256, 5.0);
        assert_eq!(pop.len(), 400);
        let mut fanout = [0usize; 8];
        for r in &pop {
            let pfx = r.prefix.as_ref().expect("every request carries its template");
            assert_eq!(pfx.len, 512);
            assert!(pfx.id < 8);
            fanout[pfx.id as usize] += 1;
            // the prefix is a STRICT prefix of the prompt
            assert!(r.prompt_len > pfx.len);
            assert!(r.prompt_len - pfx.len + r.decode_len <= 256);
            assert!(r.decode_len >= 1);
        }
        // Zipf fanout: the hottest template dominates the coldest
        assert!(fanout[0] > 2 * fanout[7], "fanout {fanout:?} not skewed");
        assert!(fanout.iter().all(|&c| c > 0), "every template sees traffic");
    }

    #[test]
    fn poisson_arrivals_are_increasing() {
        let mut rng = Rng::new(2);
        let pop = with_poisson_arrivals(&mut rng, uniform_population(50, 512, 5.0), 10.0);
        assert!(pop.windows(2).all(|w| w[0].arrival < w[1].arrival));
        assert!(pop[0].arrival > 0.0);
    }

    #[test]
    fn template_bursts_cluster_same_template_arrivals() {
        let mut rng = Rng::new(9);
        let pop = shared_prefix_population(&mut rng, 240, 6, 0.6, 128, 16, 64, 5.0);
        let pop = with_template_burst_arrivals(&mut rng, pop, 20.0, 5);
        // the slot timeline is a strict Poisson draw: all arrivals unique,
        // positive, and a permutation ordered by time covers every request
        let mut by_time: Vec<&RequestSpec> = pop.iter().collect();
        by_time.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
        assert!(by_time[0].arrival > 0.0);
        assert!(by_time.windows(2).all(|w| w[0].arrival < w[1].arrival));
        // temporal locality: consecutive arrivals share a template far
        // more often than an interleaved shuffle would (burst 5 ⇒ ≥ ~3/5
        // of adjacent pairs are same-template; random ≈ Σ share² ≈ 0.2)
        let same = by_time
            .windows(2)
            .filter(|w| {
                w[0].prefix.as_ref().map(|p| p.id) == w[1].prefix.as_ref().map(|p| p.id)
            })
            .count();
        assert!(
            same * 2 >= by_time.len(),
            "only {same}/{} adjacent same-template pairs",
            by_time.len() - 1
        );
        // per-template request order is preserved
        let mut rng2 = Rng::new(9);
        let orig = shared_prefix_population(&mut rng2, 240, 6, 0.6, 128, 16, 64, 5.0);
        for (a, b) in pop.iter().zip(orig.iter()) {
            assert_eq!(a.prompt_len, b.prompt_len);
            assert_eq!(a.prefix, b.prefix);
        }
    }

    #[test]
    fn per_replica_shards_are_stable_under_replica_count() {
        let root = Rng::new(17);
        let small = sharded_shared_prefix_population(&root, 4, 40, 6, 0.6, 128, 16, 64, 5.0, 20.0);
        let large = sharded_shared_prefix_population(&root, 16, 40, 6, 0.6, 128, 16, 64, 5.0, 20.0);
        assert_eq!(small.len(), 4);
        assert_eq!(large.len(), 16);
        // growing the sweep leaves existing shards bit-identical
        for (a, b) in small.iter().zip(&large) {
            assert_eq!(a, b);
        }
        // shards are genuinely different streams, with disjoint template ids
        assert_ne!(large[0], large[1]);
        let ids = |shard: &[RequestSpec]| {
            shard.iter().filter_map(|s| s.prefix.as_ref().map(|p| p.id)).collect::<Vec<_>>()
        };
        assert!(ids(&large[0]).iter().all(|id| !ids(&large[1]).contains(id)));
    }

    #[test]
    fn rate_curve_swings_and_flashes() {
        let c = RateCurve::steady(10.0).with_diurnal(0.5, 100.0).with_flash(40.0, 5.0, 3.0);
        // diurnal peak at t = period/4, trough at 3·period/4
        assert!((c.rate_at(25.0) - 15.0).abs() < 1e-9);
        assert!((c.rate_at(75.0) - 5.0).abs() < 1e-9);
        // flash windows: [0,5), [40,45), … multiply whatever the sinusoid says
        assert!(c.in_flash(42.0) && !c.in_flash(46.0));
        assert!((c.rate_at(0.0) - 30.0).abs() < 1e-9);
        // the curve never touches zero anywhere on a dense scan
        let steady = RateCurve::steady(2.0).with_diurnal(0.99, 10.0);
        for i in 0..1000 {
            assert!(steady.rate_at(i as f64 * 0.01) > 0.0);
        }
    }

    #[test]
    fn soak_fill_partitions_the_timeline_losslessly() {
        use crate::coordinator::RequestPool;
        let curve = RateCurve::steady(20.0).with_diurnal(0.4, 60.0);
        let mut w = SoakWorkload::new(11, curve).with_lengths((32, 128), (8, 64));
        let mut pool = RequestPool::new();
        let a = w.fill_until(&mut pool, 10.0);
        let b = w.fill_until(&mut pool, 20.0);
        assert!(a > 0 && b > 0);
        assert_eq!(pool.len(), a + b);
        // every pushed arrival lands in its window; arrivals are increasing
        let arrivals: Vec<f64> = pool.iter().map(|r| r.spec.arrival).collect();
        assert!(arrivals.windows(2).all(|p| p[0] < p[1]));
        assert!(arrivals[..a].iter().all(|&t| t <= 10.0));
        assert!(arrivals[a..].iter().all(|&t| (10.0..=20.0).contains(&t)));
        // the lookahead spec survives between calls: exactly one draw is
        // in flight beyond what the pool holds
        assert_eq!(w.generated(), pool.len() + 1);
        // a horizon before the pending arrival pushes nothing
        assert_eq!(w.fill_until(&mut pool, arrivals[a + b - 1] + 1e-12), 0);
    }

    #[test]
    fn flash_crowds_pile_onto_the_hot_template() {
        let curve = RateCurve::steady(50.0).with_flash(30.0, 6.0, 4.0);
        let mut w = SoakWorkload::new(5, curve)
            .with_lengths((16, 64), (8, 32))
            .with_templates(8, 256, 0.6);
        let mut pool = crate::coordinator::RequestPool::new();
        w.fill_until(&mut pool, 90.0);
        let mut flash_ids = Vec::new();
        let mut calm_ids = Vec::new();
        for r in pool.iter() {
            let pfx = r.spec.prefix.as_ref().expect("template workload tags every request");
            assert!(r.spec.prompt_len > pfx.len, "prefix must be strict");
            if curve.in_flash(r.spec.arrival) {
                flash_ids.push(pfx.id);
            } else {
                calm_ids.push(pfx.id);
            }
        }
        assert!(flash_ids.len() > 20, "flash windows must see traffic");
        assert!(flash_ids.iter().all(|&id| id == 0), "flash pins the hot template");
        assert!(calm_ids.iter().any(|&id| id != 0), "calm traffic spreads out");
    }

    #[test]
    fn length_drift_moves_the_mean_over_time() {
        let curve = RateCurve::steady(40.0);
        let mut w = SoakWorkload::new(7, curve)
            .with_lengths((100, 100), (50, 50))
            .with_drift(0.5, 100.0);
        let mut pool = crate::coordinator::RequestPool::new();
        w.fill_until(&mut pool, 100.0);
        // first half-period rides the +sin lobe, second the −sin lobe
        let (mut hi, mut nhi, mut lo, mut nlo) = (0usize, 0usize, 0usize, 0usize);
        for r in pool.iter() {
            if r.spec.arrival < 50.0 {
                hi += r.spec.prompt_len;
                nhi += 1;
            } else {
                lo += r.spec.prompt_len;
                nlo += 1;
            }
        }
        assert!(nhi > 100 && nlo > 100);
        let (mh, ml) = (hi as f64 / nhi as f64, lo as f64 / nlo as f64);
        assert!(mh > 110.0 && ml < 90.0, "drift lobes not visible: {mh} vs {ml}");
    }

    #[test]
    fn conversation_tree_paths_share_and_diverge() {
        let mut rng = Rng::new(21);
        let bs = 32;
        let pop =
            conversation_tree_population(&mut rng, 12, 4, 256, 128, 3, 64, 256, 32, 128, bs);
        assert_eq!(pop.len(), 36, "turn-major: conversations × turns");
        let sys_blocks = 256 / bs;
        let scaffold_blocks = (256 + 128) / bs;
        let turn0 = &pop[..12];
        for r in turn0 {
            let pfx = r.prefix.as_ref().expect("every turn carries its path");
            assert_eq!(pfx.len, 384, "turn 0 prefix = system + branch");
            assert_eq!(pfx.path.len(), pfx.len / bs);
            assert!(r.prompt_len > pfx.len, "prefix must stay strict");
            // every conversation agrees on the system-prompt blocks
            assert_eq!(pfx.path[..sys_blocks], turn0[0].prefix.as_ref().unwrap().path[..sys_blocks]);
        }
        // branches diverge after the system prompt but at most 4 distinct
        // scaffolds exist
        let mut scaffolds: Vec<&[u64]> = turn0
            .iter()
            .map(|r| &r.prefix.as_ref().unwrap().path[..scaffold_blocks])
            .collect();
        scaffolds.sort();
        scaffolds.dedup();
        assert!(scaffolds.len() > 1 && scaffolds.len() <= 4, "{} scaffolds", scaffolds.len());
        // follow-up turns extend their own conversation's prior path:
        // turn 1 of conversation c starts with turn 0's whole path
        for c in 0..12 {
            let t0 = pop[c].prefix.as_ref().unwrap();
            let t1 = pop[12 + c].prefix.as_ref().unwrap();
            assert!(t1.len > t0.len, "the transcript only grows");
            assert_eq!(t1.path[..t0.path.len()], t0.path[..]);
            assert_ne!(t1.id, t0.id, "each turn registers its own tail");
        }
        // all turn ids are distinct (they key the radix terminal map)
        let mut ids: Vec<u64> =
            pop.iter().map(|r| r.prefix.as_ref().unwrap().id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 36);
    }

    /// Exact thinning tracks the rate curve where the stepwise
    /// approximation drifts: over many diurnal periods the peak half of
    /// each cycle must hold ~ (1+amp)/(1−amp) × the trough half's
    /// arrivals, and the default path stays bit-identical to the legacy
    /// generator (the soak pins ride on it).
    #[test]
    fn exact_thinning_tracks_the_diurnal_curve() {
        use crate::coordinator::RequestPool;
        let curve = RateCurve::steady(30.0).with_diurnal(0.8, 40.0);
        let mut w = SoakWorkload::new(13, curve).with_lengths((32, 64), (8, 16)).with_exact_arrivals();
        let mut pool = RequestPool::new();
        w.fill_until(&mut pool, 400.0);
        let (mut peak, mut trough) = (0usize, 0usize);
        for r in pool.iter() {
            // +sin lobe of each 40 s period vs −sin lobe
            if r.spec.arrival.rem_euclid(40.0) < 20.0 {
                peak += 1;
            } else {
                trough += 1;
            }
        }
        assert!(peak + trough > 5000, "rate 30/s over 400 s");
        let ratio = peak as f64 / trough.max(1) as f64;
        // exact: E[peak/trough] ≈ ∫(1+0.8 sin)/∫(1−0.8 sin) ≈ 3.0; the
        // stepwise path skews low (long trough gaps overshoot into the
        // peak at the stale trough rate)
        assert!(ratio > 2.4, "thinned arrivals don't track the curve: {ratio}");
        // arrivals remain strictly increasing and the lookahead invariant
        // holds under thinning too
        let arrivals: Vec<f64> = pool.iter().map(|r| r.spec.arrival).collect();
        assert!(arrivals.windows(2).all(|p| p[0] < p[1]));
        assert_eq!(w.generated(), pool.len() + 1);
        // the default (approximate) generator is untouched by the flag's
        // existence: same seed ⇒ same first arrival as a fresh legacy run
        let mut a = SoakWorkload::new(99, RateCurve::steady(5.0));
        let mut b = SoakWorkload::new(99, RateCurve::steady(5.0));
        let (mut pa, mut pb) = (RequestPool::new(), RequestPool::new());
        a.fill_until(&mut pa, 20.0);
        b.fill_until(&mut pb, 20.0);
        let sa: Vec<_> = pa.iter().map(|r| r.spec.clone()).collect();
        let sb: Vec<_> = pb.iter().map(|r| r.spec.clone()).collect();
        assert_eq!(sa, sb);
    }

    #[test]
    fn template_bursts_degenerate_inputs() {
        let mut rng = Rng::new(4);
        // untagged population: one bucket, arrivals are plain Poisson
        let pop = with_template_burst_arrivals(&mut rng, uniform_population(20, 64, 5.0), 10.0, 4);
        assert!(pop.windows(2).all(|w| w[0].arrival < w[1].arrival));
        // burst 0 is clamped to 1; empty population is a no-op
        let pop = with_template_burst_arrivals(&mut rng, Vec::new(), 10.0, 0);
        assert!(pop.is_empty());
    }
}
