//! Replica-level cluster simulation (§5.3's third scenario: 8 independent
//! TP-8 replicas on the same 64 GPUs as the TP8×PP8 deployment), now
//! driven by **arrival-order request routing** over interleaved replica
//! execution.
//!
//! The seed assigned requests to replicas statically (`g % R` at
//! construction) and ran each replica's whole partition to completion in
//! isolation, so no dispatch policy could react to observed load or cache
//! residency. [`ClusterSim::run_routed`] instead advances all replicas'
//! event clocks together under one global time order and dispatches each
//! request at its arrival instant through a [`RoutePolicy`] that sees a
//! consistent snapshot of every replica's cache-aware outstanding work —
//! the cluster-scale composition point for everything the per-replica
//! stack already does (paged KV, hybrid scheduling, COW prefix sharing,
//! bounded waits with fallback). [`RoundRobin`] routing reproduces the
//! old static partition byte-for-byte on arrival-sorted workloads, so the
//! Fig.-12 comparisons are unchanged.
//!
//! Stall resolution is cluster-aware: a replica whose streams all stall
//! mid-run is left dormant while arrivals remain (a future dispatch may
//! wake it — under the old static partition the replica could *see* its
//! future arrivals and idle on them); once the arrival stream is
//! exhausted, each stalled replica resolves exactly like the
//! single-replica driver — demote the oldest prefix waiter to a
//! full-price fallback, else panic "pipeline wedged".
//!
//! Deployment [`Topology`] makes prefill/decode **disaggregation** a
//! first-class mode (DistServe, arXiv 2401.09670): under
//! `Disagg { prefill_replicas: K }` replicas `0..K` run chunked prefills
//! only and hand each finished prompt's KV to a decode replica over the
//! costed [`CopyFabric`]; decode admission waits on the transfer's
//! arrival edge — never on a wedge — and the handoff target is the
//! decode replica with the least outstanding work at the handoff
//! instant. `Split` keeps both phases on every replica but partitions
//! its compute between a prefill lane and a decode lane (RAPID-Serve
//! style), with a zero-byte intra-replica handoff. `Colocated` is the
//! unchanged hybrid baseline — byte-identical to the routed driver.

use super::pipeline::{PipelineResult, PipelineRun, PipelineSim, StallOutcome};
use super::router::{LeastOutstandingTokens, ReplicaView, RoundRobin, RoutePolicy};
use super::transfer::CopyFabric;
use crate::config::Deployment;
use crate::coordinator::trace as ctrace;
use crate::coordinator::{KvExport, KvManager, Scheduler};
use crate::costmodel::CostModel;
use crate::profiler::Profiler;
use crate::workload::RequestSpec;

/// How the cluster's replicas divide the two inference phases.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Topology {
    /// Every replica serves both phases through one hybrid scheduler —
    /// the pre-disaggregation cluster, byte-identical to the routed
    /// driver.
    Colocated,
    /// Replicas `0..prefill_replicas` run chunked prefills only and hand
    /// each finished prompt's KV to a decode replica (`prefill_replicas..`)
    /// over the costed copy fabric. Requires `1 <= prefill_replicas <
    /// replicas` and `pp = 1` (each stage owns whole model replicas).
    Disagg { prefill_replicas: usize },
    /// Every replica partitions its compute between a prefill lane and a
    /// decode lane (RAPID-Serve-style intra-replica split); the handoff
    /// stays on-device and moves zero fabric bytes.
    Split,
}

impl Topology {
    pub fn name(&self) -> &'static str {
        match self {
            Topology::Colocated => "colocated",
            Topology::Disagg { .. } => "disagg",
            Topology::Split => "split",
        }
    }

    /// Parse a CLI name (the inverse of [`name`](Self::name)).
    /// `prefill_replicas` shapes only `disagg`.
    pub fn parse(s: &str, prefill_replicas: usize) -> Option<Self> {
        Some(match s {
            "colocated" => Topology::Colocated,
            "disagg" | "disaggregated" => Topology::Disagg { prefill_replicas },
            "split" => Topology::Split,
            _ => return None,
        })
    }
}

/// Result of a cluster run: merged view over all replicas.
#[derive(Clone, Debug, Default)]
pub struct ClusterResult {
    pub per_replica: Vec<PipelineResult>,
    pub completions: Vec<f64>,
    pub makespan: f64,
    /// Per-request TTFT (first token − arrival; NaN when the request
    /// never produced one). On handoff topologies the first token comes
    /// from the prefill side, so TTFT is independent of the transfer.
    pub ttft: Vec<f64>,
    /// Per-request maximum time-between-tokens gap, stitched across a
    /// handoff (the gap from the prefill-side first token to the first
    /// decode-side token includes transfer + queueing) — what the TBT
    /// SLO checks.
    pub max_tbt: Vec<f64>,
    /// Per-request KV handoff latency (queueing + wire); 0.0 on
    /// colocated topologies and intra-replica handoffs.
    pub kv_transfer_time: Vec<f64>,
    /// The copy fabric after the run — per-transfer records, busy time,
    /// conservation books. `None` on colocated topologies.
    pub fabric: Option<CopyFabric>,
    /// Total overlapped copy-stream busy time: fabric wire time plus
    /// preemption swap traffic the handoff driver routed off the compute
    /// clock.
    pub transfer_busy: f64,
    /// Name of the topology that produced this result.
    pub topology: &'static str,
    /// Replaces the per-replica latency merge on handoff topologies:
    /// decode pools see transfer-relative arrivals, so normalized
    /// latency must be rebuilt against true arrivals by the driver.
    pub latency_override: Option<crate::coordinator::LatencyReport>,
    /// Which replica served each request (original spec order). On
    /// `disagg` this is the PREFILL replica the router chose; the decode
    /// side is recoverable from the fabric's transfer records.
    pub replica_of: Vec<usize>,
    /// Dispatch-sampled mean outstanding work per replica: after every
    /// routing decision the driver snapshots each replica's cache-aware
    /// outstanding tokens; these are the per-replica means over all
    /// samples — the basis of [`load_imbalance`](Self::load_imbalance).
    pub mean_outstanding: Vec<f64>,
    /// Name of the routing policy that produced this result.
    pub router: &'static str,
    /// Canonically-merged lifecycle event stream across all replicas
    /// (plus synthesized `KvTransfer` spans from the fabric on handoff
    /// topologies). Empty unless the cluster ran with
    /// [`ClusterSim::with_trace_cap`]. Request ids inside events are
    /// stream-pool-local; `(replica, lane)` identifies the pool.
    pub events: Vec<ctrace::TraceEvent>,
    /// Per-request causal latency decomposition, `request` remapped to
    /// the ORIGINAL spec index; on handoff topologies the prefill-side
    /// decomposition is stitched with the fabric's per-request transfer
    /// latency and the decode-side completion. Populated only when
    /// tracing was enabled (untraced runs stay byte-identical).
    pub breakdowns: Vec<ctrace::LatencyBreakdown>,
    /// Lazily-computed sort of `completions` — an internal memo so curve
    /// and `time_to_complete` queries stop cloning + sorting per call.
    /// Public only so external struct literals with `..Default::default()`
    /// keep compiling; leave it untouched when building results by hand.
    pub sorted_completions: std::sync::OnceLock<Vec<f64>>,
}

impl ClusterResult {
    /// Completions sorted ascending, computed once per result. NaN
    /// completions (dropped requests) sort last under `total_cmp`.
    fn sorted(&self) -> &[f64] {
        self.sorted_completions.get_or_init(|| {
            let mut c = self.completions.clone();
            c.sort_by(f64::total_cmp);
            c
        })
    }

    /// Sorted (requests completed, time) curve across all replicas —
    /// Fig. 12b's x/y series.
    pub fn completion_curve(&self) -> Vec<(usize, f64)> {
        self.sorted().iter().enumerate().map(|(i, &t)| (i + 1, t)).collect()
    }

    /// Time at which `n` requests have completed. `n = 0` is "no work
    /// yet": 0.0, not the first completion time (the seed's saturating_sub
    /// silently aliased n=0 onto n=1).
    pub fn time_to_complete(&self, n: usize) -> f64 {
        if n == 0 {
            return 0.0;
        }
        self.sorted().get(n - 1).copied().unwrap_or(f64::NAN)
    }

    /// Merged latency report across replicas — sample-exact (every
    /// replica's samples concatenated, so merged percentiles equal
    /// percentiles over the pooled samples; replicas need no common
    /// clock origin). Regression note: this used to drop the
    /// `prefix_wait` histogram on the floor.
    pub fn latency(&self) -> crate::coordinator::LatencyReport {
        if let Some(rep) = &self.latency_override {
            return rep.clone();
        }
        let mut merged = crate::coordinator::LatencyReport::default();
        for rep in &self.per_replica {
            merged.ttft.merge(&rep.latency.ttft);
            merged.tbt.merge(&rep.latency.tbt);
            merged.normalized.merge(&rep.latency.normalized);
            merged.prefix_wait.merge(&rep.latency.prefix_wait);
        }
        merged
    }

    /// **Goodput** under (TTFT, TBT) SLOs — DistServe's serving metric:
    /// the fraction of requests that completed within both SLOs, and the
    /// attained rate of such requests per second of makespan.
    pub fn goodput(&self, ttft_slo: f64, tbt_slo: f64) -> (f64, f64) {
        let pass = crate::coordinator::metrics::goodput_pass(
            &self.ttft,
            &self.max_tbt,
            &self.completions,
            ttft_slo,
            tbt_slo,
        );
        let n = self.completions.len();
        let frac = if n == 0 { 0.0 } else { pass as f64 / n as f64 };
        let rate = if self.makespan > 0.0 { pass as f64 / self.makespan } else { 0.0 };
        (frac, rate)
    }

    /// Total preemption events across replicas.
    pub fn preemptions(&self) -> usize {
        self.per_replica.iter().map(|r| r.metrics.preemptions).sum()
    }

    /// Total preemption transfer time across replicas.
    pub fn total_swap_time(&self) -> f64 {
        self.per_replica.iter().map(|r| r.metrics.total_swap_time()).sum()
    }

    /// Aggregate prefix-cache-hit admissions across replicas.
    pub fn prefix_hits(&self) -> usize {
        self.per_replica.iter().map(|r| r.metrics.prefix_hits).sum()
    }

    /// Aggregate bounded-wait fallbacks across replicas.
    pub fn prefix_fallbacks(&self) -> usize {
        self.per_replica.iter().map(|r| r.metrics.prefix_fallbacks).sum()
    }

    /// Cross-replica prefix-hit rate: hit admissions per dispatched
    /// request (> 1.0 is possible under heavy preemption re-admission).
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.completions.is_empty() {
            0.0
        } else {
            self.prefix_hits() as f64 / self.completions.len() as f64
        }
    }

    /// Peak KV occupancy (blocks) per replica.
    pub fn peak_kv_blocks_per_replica(&self) -> Vec<usize> {
        self.per_replica.iter().map(|r| r.metrics.peak_kv_blocks_in_use()).collect()
    }

    /// Total stage-idle (bubble) time per replica — the per-replica view
    /// the simulate report prints next to utilization.
    pub fn replica_bubbles(&self) -> Vec<f64> {
        self.per_replica.iter().map(|r| r.total_bubble).collect()
    }

    /// Load imbalance: max / mean of the per-replica mean outstanding
    /// work ([`mean_outstanding`](Self::mean_outstanding)). 1.0 is perfect
    /// balance; an idle cluster (all means zero) reports 1.0.
    pub fn load_imbalance(&self) -> f64 {
        let n = self.mean_outstanding.len();
        if n == 0 {
            return 1.0;
        }
        let sum: f64 = self.mean_outstanding.iter().sum();
        if sum <= 0.0 {
            return 1.0;
        }
        let max = self.mean_outstanding.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        max / (sum / n as f64)
    }

    /// Write the merged per-micro-batch trace as JSON-Lines, each record
    /// tagged with its `replica` (the engine's schema plus that one
    /// field), ordered by record start time across replicas.
    pub fn write_jsonl(&self, path: &std::path::Path) -> std::io::Result<()> {
        use std::io::Write as _;
        crate::coordinator::metrics::ensure_parent_dir(path)?;
        // (start, replica, replica-local index) orders the merged trace
        let mut order: Vec<(f64, usize, usize)> = Vec::new();
        for (ri, rep) in self.per_replica.iter().enumerate() {
            for (i, rec) in rep.metrics.iter_records().enumerate() {
                order.push((rec.started_at, ri, rep.metrics.first_retained() + i));
            }
        }
        order.sort_by(|a, b| {
            a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)).then(a.2.cmp(&b.2))
        });
        let mut out = std::io::BufWriter::new(std::fs::File::create(path)?);
        for (_, ri, i) in order {
            let rec = self.per_replica[ri].metrics.record_at(i);
            writeln!(out, "{}", rec.to_jsonl(i, Some(ri)))?;
        }
        // handoff topologies append the transfer trace; colocated runs
        // (no fabric / no records) stay byte-identical to the old schema
        if let Some(fabric) = &self.fabric {
            if !fabric.records.is_empty() {
                for rec in &fabric.records {
                    writeln!(out, "{}", rec.to_jsonl())?;
                }
                writeln!(out, "{}", fabric.summary_jsonl(self.makespan))?;
            }
        }
        // traced runs append the per-request latency decomposition;
        // untraced runs carry no breakdowns and stay byte-identical
        for bd in &self.breakdowns {
            writeln!(out, "{}", bd.to_jsonl())?;
        }
        Ok(())
    }

    /// Total records across replicas (the merged JSONL line count).
    pub fn total_iterations(&self) -> usize {
        self.per_replica.iter().map(|r| r.metrics.recorded_count()).sum()
    }
}

/// Min-heap key for the cluster event queue: the tie-breaking the linear
/// scan used to bury inside a `min_by` chain — earliest time first, then
/// lowest replica index — is the explicit heap ordering here. Event times
/// come from the cost model and must be real numbers; a NaN is asserted
/// away loudly at construction instead of silently corrupting the heap
/// order (`total_cmp` would place it, but no valid schedule produces one).
#[derive(Clone, Copy, Debug, PartialEq)]
struct EventKey {
    t: f64,
    ri: usize,
}

impl EventKey {
    fn new(t: f64, ri: usize) -> Self {
        assert!(!t.is_nan(), "replica {ri} produced a NaN event time");
        EventKey { t, ri }
    }
}

impl Eq for EventKey {}

impl Ord for EventKey {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // reversed so std's max-heap pops the minimum (time, replica)
        other.t.total_cmp(&self.t).then_with(|| other.ri.cmp(&self.ri))
    }
}

impl PartialOrd for EventKey {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// A deployment of `replicas` identical tp×pp groups serving a shared
/// workload through a routing policy.
pub struct ClusterSim {
    pub deployment: Deployment,
    pub sims: Vec<PipelineSim>,
    /// Per-stream lifecycle-trace sink capacity; `None` (default) keeps
    /// every pool's sink disabled — the zero-cost path, bitwise
    /// identical to pre-trace runs.
    pub trace_cap: Option<usize>,
}

impl ClusterSim {
    pub fn new(deployment: Deployment) -> Self {
        let cm = CostModel::for_deployment(&deployment);
        let profiler = Profiler::build(cm, deployment.max_seq_len, deployment.max_batch_size() + 1);
        let sims = (0..deployment.parallel.replicas)
            .map(|_| PipelineSim::new(profiler.clone(), deployment.parallel.pp))
            .collect();
        ClusterSim { deployment, sims, trace_cap: None }
    }

    /// Capture lifecycle events on every replica (sink capacity `cap`
    /// events per stream) and populate [`ClusterResult::events`] /
    /// [`ClusterResult::breakdowns`].
    pub fn with_trace_cap(mut self, cap: usize) -> Self {
        self.trace_cap = Some(cap);
        self
    }

    /// Price the preemption path on every replica's simulator (seed
    /// default: free swaps).
    pub fn with_swap_cost(mut self, swap: crate::coordinator::SwapCost) -> Self {
        for sim in &mut self.sims {
            sim.applier = crate::coordinator::StepApplier::with_cost(swap);
        }
        self
    }

    /// Run the workload over the seed-compatible degenerate layout: each
    /// replica shares one pool of `pp × B` whole-request slots across its
    /// streams (per-stream cap B). Requests are dispatched round-robin in
    /// arrival order; `make_sched` builds one scheduler per stream.
    pub fn run<'a, F>(&self, specs: &[RequestSpec], mut make_sched: F) -> ClusterResult
    where
        F: FnMut() -> Box<dyn Scheduler + Send + 'a>,
    {
        let slots = self.deployment.max_batch_size();
        let pp = self.deployment.parallel.pp.max(1);
        self.run_with_kv(specs, || KvManager::new(pp * slots), Some(slots), &mut make_sched)
    }

    /// Run over one shared **paged** pool per replica, sized from the
    /// deployment's actual KV memory budget — the pool a real stage
    /// holds, NOT the seed's pp×-overcommitted per-stream slots. Streams
    /// stay capped at B sequences each; cross-stream preemption and the
    /// engine-shared state transition come from the shared `PipelineRun`.
    pub fn run_paged<'a, F>(
        &self,
        specs: &[RequestSpec],
        block_size: usize,
        mut make_sched: F,
    ) -> ClusterResult
    where
        F: FnMut() -> Box<dyn Scheduler + Send + 'a>,
    {
        let blocks = self.deployment.kv_blocks(block_size);
        let cap = self.deployment.max_batch_size();
        self.run_with_kv(
            specs,
            || KvManager::paged(blocks, block_size),
            Some(cap),
            &mut make_sched,
        )
    }

    /// Round-robin compatibility driver: one fresh KV pool per replica
    /// from `make_kv`, dispatch in arrival order. Identical to the old
    /// static `g % R` partition for arrival-sorted workloads. Load
    /// tracking is OFF on this path — round-robin reads no views, and
    /// the figure-harness workloads (all arrivals at t=0) would pay an
    /// O(N²) backlog scan for statistics nobody reads; `mean_outstanding`
    /// stays zero and `load_imbalance()` reports the degenerate 1.0.
    pub fn run_with_kv<'a, F, K>(
        &self,
        specs: &[RequestSpec],
        make_kv: K,
        per_stream_cap: Option<usize>,
        make_sched: F,
    ) -> ClusterResult
    where
        F: FnMut() -> Box<dyn Scheduler + Send + 'a>,
        K: FnMut() -> KvManager,
    {
        let mut rr = RoundRobin::new();
        self.dispatch(specs, &mut rr, make_kv, per_stream_cap, make_sched, false, 1)
    }

    /// The routed cluster driver. Requests are dispatched ONE AT A TIME in
    /// arrival order (stable on ties by spec index): the driver advances
    /// whichever replica has the earliest pending event until the next
    /// arrival instant is reached, snapshots every replica's cache-aware
    /// outstanding work, and asks `router` for the target replica — so a
    /// policy always sees replica state as of the arrival, never the
    /// future. Per-replica execution is the engine-shared `PipelineRun`
    /// (per-stream schedulers over ONE shared pool from `make_kv`).
    pub fn run_routed<'a, F, K>(
        &self,
        specs: &[RequestSpec],
        router: &mut dyn RoutePolicy,
        make_kv: K,
        per_stream_cap: Option<usize>,
        make_sched: F,
    ) -> ClusterResult
    where
        F: FnMut() -> Box<dyn Scheduler + Send + 'a>,
        K: FnMut() -> KvManager,
    {
        self.dispatch(specs, router, make_kv, per_stream_cap, make_sched, true, 1)
    }

    /// [`run_routed`](Self::run_routed) with replica execution spread over
    /// `threads` OS threads (0 = one per available core). Replicas only
    /// synchronize at dispatch instants and share no state in between
    /// (each owns its pools, KV and schedulers), so every thread count —
    /// including 1, which skips spawning entirely — produces bitwise-
    /// identical results; the router still sees each arrival's consistent
    /// cluster snapshot.
    pub fn run_routed_threads<'a, F, K>(
        &self,
        specs: &[RequestSpec],
        router: &mut dyn RoutePolicy,
        make_kv: K,
        per_stream_cap: Option<usize>,
        make_sched: F,
        threads: usize,
    ) -> ClusterResult
    where
        F: FnMut() -> Box<dyn Scheduler + Send + 'a>,
        K: FnMut() -> KvManager,
    {
        self.dispatch(specs, router, make_kv, per_stream_cap, make_sched, true, threads)
    }

    /// Shared dispatch loop. `track_load` gates the per-dispatch replica
    /// snapshots (views + imbalance samples): the routed entry point pays
    /// for them, the round-robin compatibility path skips them.
    ///
    /// `threads` (0 = one per core) spreads replica execution between
    /// dispatch instants over a persistent scoped worker pool; `1` runs
    /// the heap-driven serial loop with no spawning. Both paths process
    /// each replica's events in the same per-replica order and replicas
    /// share no state between dispatch barriers, so results are bitwise
    /// independent of the thread count.
    #[allow(clippy::too_many_arguments)]
    fn dispatch<'a, F, K>(
        &self,
        specs: &[RequestSpec],
        router: &mut dyn RoutePolicy,
        mut make_kv: K,
        per_stream_cap: Option<usize>,
        mut make_sched: F,
        track_load: bool,
        threads: usize,
    ) -> ClusterResult
    where
        F: FnMut() -> Box<dyn Scheduler + Send + 'a>,
        K: FnMut() -> KvManager,
    {
        let r = self.sims.len();
        assert!(r > 0, "cluster needs at least one replica");
        let threads = if threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            threads
        };
        let mut runs: Vec<PipelineRun> = Vec::with_capacity(r);
        for sim in &self.sims {
            runs.push(PipelineRun::new(sim, make_kv(), per_stream_cap, &mut make_sched));
        }
        if let Some(cap) = self.trace_cap {
            for (ri, run) in runs.iter_mut().enumerate() {
                run.enable_trace(ri as u32, cap);
            }
        }
        // per-replica: run-local result index → original spec index
        let mut globals: Vec<Vec<usize>> = vec![Vec::new(); r];
        let mut replica_of = vec![0usize; specs.len()];
        // dispatch order: (arrival, spec index), stable on 0.0 ties
        let mut order: Vec<usize> = (0..specs.len()).collect();
        order.sort_by(|&a, &b| specs[a].arrival.total_cmp(&specs[b].arrival).then(a.cmp(&b)));
        let mut out_sums = vec![0.0f64; r];
        let mut samples = 0usize;
        // what a views-blind policy (round-robin compatibility path) sees:
        // hoisted so the untracked dispatch loop never allocates
        let blank_views = vec![ReplicaView::default(); r];

        if threads > 1 && r > 1 {
            dispatch_parallel(
                specs,
                router,
                &order,
                &mut runs,
                &mut globals,
                &mut replica_of,
                track_load,
                &mut out_sums,
                &mut samples,
                &blank_views,
                threads,
            );
        } else {
            dispatch_serial(
                specs,
                router,
                &order,
                &mut runs,
                &mut globals,
                &mut replica_of,
                track_load,
                &mut out_sums,
                &mut samples,
                &blank_views,
            );
        }

        let mut result = ClusterResult {
            completions: vec![f64::NAN; specs.len()],
            ttft: vec![f64::NAN; specs.len()],
            max_tbt: vec![0.0; specs.len()],
            kv_transfer_time: vec![0.0; specs.len()],
            topology: Topology::Colocated.name(),
            replica_of,
            mean_outstanding: out_sums
                .into_iter()
                .map(|s| s / samples.max(1) as f64)
                .collect(),
            router: router.name(),
            ..Default::default()
        };
        let mut event_streams: Vec<Vec<ctrace::TraceEvent>> = Vec::new();
        for (ri, run) in runs.into_iter().enumerate() {
            let mut res = run.finish();
            for (local, &g) in globals[ri].iter().enumerate() {
                result.completions[g] = res.completions[local];
                // NaN first token (rejected request) propagates into TTFT
                result.ttft[g] = res.first_tokens[local] - specs[g].arrival;
                result.max_tbt[g] = res.max_tbt[local];
            }
            if self.trace_cap.is_some() {
                event_streams.push(std::mem::take(&mut res.events));
                for mut bd in std::mem::take(&mut res.breakdowns) {
                    bd.request = globals[ri][bd.request];
                    result.breakdowns.push(bd);
                }
            }
            result.makespan = result.makespan.max(res.makespan);
            result.per_replica.push(res);
        }
        if self.trace_cap.is_some() {
            result.events = ctrace::merge_streams(event_streams);
            result.breakdowns.sort_by_key(|b| b.request);
        }
        result
    }

    /// Run `specs` under a deployment [`Topology`]. `Colocated` is the
    /// routed driver unchanged (byte-identical results); `Disagg`/`Split`
    /// run the round-based handoff driver, which is bitwise independent
    /// of `threads` by construction (replicas advance between barriers
    /// and share nothing but the driver-owned fabric).
    #[allow(clippy::too_many_arguments)]
    pub fn run_topology<'a, F, K>(
        &self,
        topology: Topology,
        specs: &[RequestSpec],
        router: &mut dyn RoutePolicy,
        make_kv: K,
        per_stream_cap: Option<usize>,
        make_sched: F,
        threads: usize,
    ) -> ClusterResult
    where
        F: FnMut() -> Box<dyn Scheduler + Send + 'a>,
        K: FnMut() -> KvManager,
    {
        match topology {
            Topology::Colocated => {
                self.dispatch(specs, router, make_kv, per_stream_cap, make_sched, true, threads)
            }
            _ => self.dispatch_handoff(
                topology,
                specs,
                router,
                make_kv,
                per_stream_cap,
                make_sched,
                threads,
            ),
        }
    }

    /// The prefill/decode handoff driver (`Disagg` and `Split`).
    ///
    /// Round structure: the cluster advances all replicas to each arrival
    /// instant (events strictly before it), then runs a **handoff
    /// fixpoint** — drain finished prefills, start their transfers on the
    /// fabric, push the imported decode work (arrival = transfer finish),
    /// and re-advance, since an import may enable events before the
    /// horizon. Arrivals are routed to prefill replicas only; the decode
    /// target is the least-outstanding decode replica at the handoff
    /// instant. Preemption swap traffic rides the same overlapped copy
    /// stream ([`PipelineRun::set_overlap_swaps`]). Replicas advance
    /// independently between barriers, so any thread count — chunked
    /// scoped workers or the serial loop — is bitwise identical.
    #[allow(clippy::too_many_arguments)]
    fn dispatch_handoff<'a, F, K>(
        &self,
        topology: Topology,
        specs: &[RequestSpec],
        router: &mut dyn RoutePolicy,
        mut make_kv: K,
        per_stream_cap: Option<usize>,
        mut make_sched: F,
        threads: usize,
    ) -> ClusterResult
    where
        F: FnMut() -> Box<dyn Scheduler + Send + 'a>,
        K: FnMut() -> KvManager,
    {
        let r = self.sims.len();
        assert!(r > 0, "cluster needs at least one replica");
        assert_eq!(
            self.deployment.parallel.pp, 1,
            "handoff topologies assign whole model replicas per phase (pp = 1); \
             combine pipeline parallelism with the colocated topology instead"
        );
        let split = matches!(topology, Topology::Split);
        let prefill_replicas = match topology {
            Topology::Disagg { prefill_replicas } => {
                assert!(
                    prefill_replicas >= 1 && prefill_replicas < r,
                    "disagg needs 1 <= prefill replicas ({prefill_replicas}) < replicas ({r})"
                );
                prefill_replicas
            }
            // split: every replica hosts a prefill lane
            _ => r,
        };
        let threads = if threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            threads
        };

        let mut runs: Vec<PipelineRun> = Vec::with_capacity(r);
        for sim in &self.sims {
            let mut run = if split {
                PipelineRun::with_streams(sim, make_kv(), per_stream_cap, &mut make_sched, 2)
            } else {
                PipelineRun::new(sim, make_kv(), per_stream_cap, &mut make_sched)
            };
            // preemption transfers join the KV handoffs on the copy stream
            run.set_overlap_swaps(true);
            runs.push(run);
        }
        if let Some(cap) = self.trace_cap {
            for (ri, run) in runs.iter_mut().enumerate() {
                run.enable_trace(ri as u32, cap);
            }
        }
        let mut fabric = CopyFabric::for_deployment(&self.deployment, r);
        // run-local push index → role (which global request, which phase)
        let mut locals: Vec<Vec<HandoffRole>> = vec![Vec::new(); r];

        let n = specs.len();
        let mut completions = vec![f64::NAN; n];
        let mut ttft = vec![f64::NAN; n];
        let mut kv_transfer_time = vec![0.0f64; n];
        let mut replica_of = vec![0usize; n];
        let mut out_sums = vec![0.0f64; r];
        let mut samples = 0usize;
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &b| specs[a].arrival.total_cmp(&specs[b].arrival).then(a.cmp(&b)));

        for &g in &order {
            // bring the cluster to the arrival instant, delivering every
            // handoff that lands before it
            loop {
                advance_all_runs(&mut runs, specs[g].arrival, threads);
                let delivered = deliver_handoffs(
                    &mut runs,
                    &mut locals,
                    &mut fabric,
                    specs,
                    split,
                    prefill_replicas,
                    &mut ttft,
                    &mut kv_transfer_time,
                    &mut completions,
                );
                if delivered == 0 {
                    break;
                }
            }
            let wants_digest = router.wants_digest();
            let views: Vec<ReplicaView> = runs[..prefill_replicas]
                .iter()
                .map(|run| ReplicaView {
                    outstanding_tokens: run.outstanding_tokens(),
                    digest: if wants_digest {
                        run.residency_digest()
                    } else {
                        Default::default()
                    },
                })
                .collect();
            let ri = router.route(&specs[g], &views).min(prefill_replicas - 1);
            // the prefill-side copy: completes exactly at first-token time
            // (the final chunk's token), keeping the prefix tag so prefill
            // replicas still share/pin templates
            let pspec = RequestSpec { decode_len: 1, ..specs[g].clone() };
            let local = runs[ri].push_to(0, pspec);
            debug_assert_eq!(local, locals[ri].len());
            locals[ri].push(HandoffRole::Prefill(g));
            replica_of[g] = ri;
            for (i, run) in runs.iter().enumerate() {
                out_sums[i] += run.outstanding_tokens() as f64;
            }
            samples += 1;
        }

        // arrivals exhausted: drain to the handoff fixpoint, then resolve
        // stalls like the routed driver until nothing progresses
        loop {
            loop {
                advance_all_runs(&mut runs, f64::INFINITY, threads);
                let delivered = deliver_handoffs(
                    &mut runs,
                    &mut locals,
                    &mut fabric,
                    specs,
                    split,
                    prefill_replicas,
                    &mut ttft,
                    &mut kv_transfer_time,
                    &mut completions,
                );
                if delivered == 0 {
                    break;
                }
            }
            let mut progressed = false;
            for run in runs.iter_mut() {
                match run.resolve_stall() {
                    StallOutcome::Demoted => progressed = true,
                    StallOutcome::Wedged => run.panic_wedged(),
                    StallOutcome::Idle => {}
                }
            }
            if !progressed {
                break;
            }
        }
        assert!(fabric.is_conserved(), "every KV export must land exactly once");

        let mut result = ClusterResult {
            completions,
            ttft,
            max_tbt: vec![0.0; n],
            kv_transfer_time,
            topology: topology.name(),
            replica_of,
            mean_outstanding: out_sums
                .into_iter()
                .map(|s| s / samples.max(1) as f64)
                .collect(),
            router: router.name(),
            ..Default::default()
        };
        let mut rep = crate::coordinator::LatencyReport::default();
        let mut copy_busy = 0.0;
        let mut event_streams: Vec<Vec<ctrace::TraceEvent>> = Vec::new();
        // raw prefill-side breakdowns, stitched after the loop once every
        // replica's max_tbt / completion data has landed in `result`
        let mut raw_bds: Vec<(usize, Vec<ctrace::LatencyBreakdown>)> = Vec::new();
        for (ri, run) in runs.into_iter().enumerate() {
            let mut res = run.finish();
            for (local, role) in locals[ri].iter().enumerate() {
                if let HandoffRole::Decode(g) = *role {
                    // the stitched max gap: push_imported stamped the
                    // prefill-side first token, so transfer + queueing
                    // shows up in the first decode gap
                    result.max_tbt[g] = res.max_tbt[local];
                }
            }
            if self.trace_cap.is_some() {
                event_streams.push(std::mem::take(&mut res.events));
                raw_bds.push((ri, std::mem::take(&mut res.breakdowns)));
            }
            // TTFT lives on prefill pools (true arrivals), TBT on decode
            // pools (stitched gaps); normalized is rebuilt below because
            // decode pools saw transfer-relative arrivals
            rep.ttft.merge(&res.latency.ttft);
            rep.tbt.merge(&res.latency.tbt);
            rep.prefix_wait.merge(&res.latency.prefix_wait);
            copy_busy += res.copy_busy;
            result.makespan = result.makespan.max(res.makespan);
            result.per_replica.push(res);
        }
        for g in 0..n {
            if !result.completions[g].is_nan() {
                rep.normalized.add(
                    (result.completions[g] - specs[g].arrival)
                        / specs[g].decode_len.max(1) as f64,
                );
            }
        }
        result.latency_override = Some(rep);
        result.transfer_busy = fabric.busy_time() + copy_busy;
        if self.trace_cap.is_some() {
            // stitch the cross-stage decomposition: the prefill-side
            // breakdown carries queue/prefix/swap/compute, the fabric
            // record the wire time, the decode replica the completion
            for (ri, bds) in raw_bds {
                for bd in bds {
                    if let HandoffRole::Prefill(g) = locals[ri][bd.request] {
                        let done = result.completions[g];
                        let mut bd = bd.with_handoff(
                            result.kv_transfer_time[g],
                            (!done.is_nan()).then_some(done),
                        );
                        bd.request = g;
                        bd.decode_len = specs[g].decode_len;
                        bd.max_tbt = result.max_tbt[g];
                        result.breakdowns.push(bd);
                    }
                }
            }
            result.breakdowns.sort_by_key(|b| b.request);
            // the fabric's transfer records become spans on the source
            // replica's transfer lane — one synthesized stream, merged
            // under the same canonical (time, replica, lane, seq) order
            let mut wire: Vec<ctrace::TraceEvent> = Vec::with_capacity(fabric.records.len());
            for (i, rec) in fabric.records.iter().enumerate() {
                wire.push(ctrace::TraceEvent {
                    at: rec.start,
                    replica: rec.src as u32,
                    lane: 0,
                    seq: i as u64,
                    kind: ctrace::EventKind::KvTransfer {
                        request: rec.request,
                        src: rec.src,
                        dst: rec.dst,
                        end: rec.finish,
                    },
                });
            }
            event_streams.push(wire);
            result.events = ctrace::merge_streams(event_streams);
        }
        result.fabric = Some(fabric);
        result
    }
}

/// Role of one run-local push in the handoff driver: the prefill-side
/// copy of global request `g`, or its imported decode-side remainder.
#[derive(Clone, Copy, Debug)]
enum HandoffRole {
    Prefill(usize),
    Decode(usize),
}

/// Advance every replica's events strictly before `h`. With `threads > 1`
/// the runs are split into contiguous chunks over scoped workers; replicas
/// share nothing, so the partition cannot affect results.
fn advance_all_runs(runs: &mut [PipelineRun], h: f64, threads: usize) {
    if threads > 1 && runs.len() > 1 {
        let per = runs.len().div_ceil(threads.min(runs.len()));
        std::thread::scope(|scope| {
            for chunk in runs.chunks_mut(per) {
                scope.spawn(move || {
                    for run in chunk {
                        run.advance_until(h);
                    }
                });
            }
        });
    } else {
        for run in runs {
            run.advance_until(h);
        }
    }
}

/// One handoff round: drain every replica's newly finished requests in a
/// canonical (time, replica, local) order; record decode completions;
/// for each finished prefill, stamp TTFT and either complete the request
/// (no decode work) or start its KV transfer and push the imported
/// decode remainder at the transfer's finish. Returns the number of
/// events drained (0 = fixpoint reached).
#[allow(clippy::too_many_arguments)]
fn deliver_handoffs(
    runs: &mut [PipelineRun],
    locals: &mut [Vec<HandoffRole>],
    fabric: &mut CopyFabric,
    specs: &[RequestSpec],
    split: bool,
    prefill_replicas: usize,
    ttft: &mut [f64],
    kv_transfer_time: &mut [f64],
    completions: &mut [f64],
) -> usize {
    let mut finished: Vec<(f64, usize, usize)> = Vec::new();
    for (ri, run) in runs.iter_mut().enumerate() {
        for (local, t) in run.take_finished() {
            finished.push((t, ri, local));
        }
    }
    finished.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)).then(a.2.cmp(&b.2)));
    let drained = finished.len();
    for (t, src, local) in finished {
        match locals[src][local] {
            HandoffRole::Decode(g) => completions[g] = t,
            HandoffRole::Prefill(g) => {
                ttft[g] = t - specs[g].arrival;
                if specs[g].decode_len <= 1 {
                    // the prefill's token was the whole request
                    completions[g] = t;
                    continue;
                }
                let (dst, lane) = if split {
                    // intra-replica: decode lane of the same replica
                    (src, 1)
                } else {
                    let views: Vec<ReplicaView> = runs[prefill_replicas..]
                        .iter()
                        .map(|run| ReplicaView {
                            outstanding_tokens: run.outstanding_tokens(),
                            ..Default::default()
                        })
                        .collect();
                    (prefill_replicas + LeastOutstandingTokens::least(&views), 0)
                };
                let arrive = if dst == src {
                    t // on-device handoff moves no fabric bytes
                } else {
                    // the driver-level descriptor prices the wire by KV
                    // tokens; the source run already recycled the block
                    // table on prefill completion
                    let export = KvExport { kv_tokens: specs[g].prompt_len, blocks: 0 };
                    let finish = fabric.begin(g, src, dst, &export, t);
                    kv_transfer_time[g] = finish - t;
                    finish
                };
                let dspec = RequestSpec {
                    prompt_len: specs[g].prompt_len,
                    decode_len: specs[g].decode_len,
                    arrival: arrive,
                    prefix: None,
                };
                let local2 = runs[dst].push_imported(lane, dspec, t);
                debug_assert_eq!(local2, locals[dst].len());
                locals[dst].push(HandoffRole::Decode(g));
                if dst != src {
                    fabric.deliver(g);
                }
            }
        }
    }
    drained
}

/// The single-threaded dispatch loop over a lazily-deleted binary-heap
/// event queue keyed by [`EventKey`]. Heap entries are refreshed (pushed,
/// never removed in place) whenever a replica steps or receives a push;
/// a popped entry is validated against the replica's CURRENT next event
/// time and discarded when stale, so duplicates are sound. This replaces
/// the O(replicas) `min_by` rescan the seed ran on every loop turn.
#[allow(clippy::too_many_arguments)]
fn dispatch_serial(
    specs: &[RequestSpec],
    router: &mut dyn RoutePolicy,
    order: &[usize],
    runs: &mut [PipelineRun],
    globals: &mut [Vec<usize>],
    replica_of: &mut [usize],
    track_load: bool,
    out_sums: &mut [f64],
    samples: &mut usize,
    blank_views: &[ReplicaView],
) {
    let r = runs.len();
    // digest refreshes happen only at these dispatch barriers, and only
    // for policies that read them — round-robin / JSQ / history affinity
    // stay bitwise-identical to their pre-digest behavior
    let wants_digest = router.wants_digest();
    let mut heap: std::collections::BinaryHeap<EventKey> =
        std::collections::BinaryHeap::with_capacity(2 * r);
    let mut cursor = 0usize;
    loop {
        // earliest replica event vs next arrival; arrivals win ties so
        // admission at time t always sees requests that arrived at t
        let next_ev: Option<(f64, usize)> = loop {
            match heap.peek().copied() {
                None => break None,
                Some(e) => {
                    if runs[e.ri].next_event_time() == Some(e.t) {
                        break Some((e.t, e.ri));
                    }
                    heap.pop(); // stale entry: the replica moved past it
                }
            }
        };
        let next_arr = (cursor < order.len()).then(|| specs[order[cursor]].arrival);

        let route_now = match (next_ev, next_arr) {
            (_, None) => false,
            (None, Some(_)) => true,
            (Some((t, _)), Some(arr)) => arr <= t,
        };
        if route_now {
            let g = order[cursor];
            cursor += 1;
            let scans = track_load.then(|| {
                runs.iter()
                    .map(|run| ReplicaView {
                        outstanding_tokens: run.outstanding_tokens(),
                        digest: if wants_digest {
                            run.residency_digest()
                        } else {
                            Default::default()
                        },
                    })
                    .collect::<Vec<_>>()
            });
            let views: &[ReplicaView] = scans.as_deref().unwrap_or(blank_views);
            let ri = router.route(&specs[g], views).min(r - 1);
            let local = runs[ri].push(specs[g].clone());
            debug_assert_eq!(local, globals[ri].len());
            globals[ri].push(g);
            replica_of[g] = ri;
            if track_load {
                // imbalance statistic: post-dispatch snapshot. Only
                // the routed replica changed, so reuse the routing
                // views for the rest instead of rescanning.
                for (i, view) in views.iter().enumerate() {
                    out_sums[i] += if i == ri {
                        runs[ri].outstanding_tokens() as f64
                    } else {
                        view.outstanding_tokens as f64
                    };
                }
                *samples += 1;
            }
            // the push may have woken the replica (or moved its wake-up
            // earlier): refresh its heap entry
            if let Some(t) = runs[ri].next_event_time() {
                heap.push(EventKey::new(t, ri));
            }
        } else if let Some((_, ri)) = next_ev {
            heap.pop(); // consume the entry we validated above
            runs[ri].step();
            if let Some(t) = runs[ri].next_event_time() {
                heap.push(EventKey::new(t, ri));
            }
        } else {
            // no timed events anywhere and no arrivals left: resolve
            // per-replica stalls like the single-replica driver (each
            // demotion retires one waiter, so this terminates)
            let mut progressed = false;
            for (ri, run) in runs.iter_mut().enumerate() {
                match run.resolve_stall() {
                    StallOutcome::Demoted => progressed = true,
                    StallOutcome::Wedged => run.panic_wedged(),
                    StallOutcome::Idle => {}
                }
                if let Some(t) = run.next_event_time() {
                    heap.push(EventKey::new(t, ri));
                }
            }
            if !progressed {
                break;
            }
        }
    }
}

/// The multi-threaded dispatch loop: a persistent pool of scoped workers
/// advances disjoint replica subsets (replica `i` belongs to worker
/// `i % workers`) up to a shared horizon between two barrier waits per
/// round, while the driver routes at most one arrival per round with the
/// workers parked. Replicas share nothing between dispatch instants, so
/// any interleaving of their event processing — including this one —
/// yields results bitwise identical to the serial loop.
#[allow(clippy::too_many_arguments)]
fn dispatch_parallel(
    specs: &[RequestSpec],
    router: &mut dyn RoutePolicy,
    order: &[usize],
    runs: &mut [PipelineRun],
    globals: &mut [Vec<usize>],
    replica_of: &mut [usize],
    track_load: bool,
    out_sums: &mut [f64],
    samples: &mut usize,
    blank_views: &[ReplicaView],
    threads: usize,
) {
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
    use std::sync::{Barrier, Mutex};

    let r = runs.len();
    let wants_digest = router.wants_digest();
    let workers = threads.min(r);
    let cells: Vec<Mutex<&mut PipelineRun>> = runs.iter_mut().map(Mutex::new).collect();
    let barrier = Barrier::new(workers + 1);
    // the advance horizon, as f64 bits (an AtomicU64 is the dependency-free
    // way to publish a float); written by the driver strictly before the
    // round barrier that releases the workers
    let horizon_bits = AtomicU64::new(f64::INFINITY.to_bits());
    let done = AtomicBool::new(false);
    // a worker panic (an internal invariant tripping inside step()) must
    // not strand the driver at the round barrier: workers catch it, park
    // it here, and still hit the barrier; the driver re-raises it
    let worker_panic: Mutex<Option<Box<dyn std::any::Any + Send>>> = Mutex::new(None);

    std::thread::scope(|scope| {
        for w in 0..workers {
            let cells = &cells;
            let barrier = &barrier;
            let horizon_bits = &horizon_bits;
            let done = &done;
            let worker_panic = &worker_panic;
            scope.spawn(move || loop {
                barrier.wait();
                if done.load(Ordering::Acquire) {
                    break;
                }
                let h = f64::from_bits(horizon_bits.load(Ordering::Acquire));
                let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    let mut ri = w;
                    while ri < cells.len() {
                        if let Ok(mut run) = cells[ri].lock() {
                            run.advance_until(h);
                        }
                        ri += workers;
                    }
                }));
                if let Err(p) = outcome {
                    *worker_panic.lock().unwrap() = Some(p);
                }
                barrier.wait();
            });
        }

        // One advance round: all replicas process every event strictly
        // before `h`. Arrival-beats-event tie-breaking is the strict `<`
        // inside `advance_until`.
        let advance_all = |h: f64| {
            horizon_bits.store(h.to_bits(), Ordering::Release);
            barrier.wait(); // release the round
            barrier.wait(); // every replica reached the horizon
            if let Some(p) = worker_panic.lock().unwrap().take() {
                done.store(true, Ordering::Release);
                barrier.wait(); // let the surviving workers observe `done`
                std::panic::resume_unwind(p);
            }
        };

        // workers are parked at the round barrier whenever driver code
        // below runs, so every lock here is uncontended by construction
        for &g in order {
            advance_all(specs[g].arrival);
            let scans = track_load.then(|| {
                cells
                    .iter()
                    .map(|c| {
                        let run = c.lock().unwrap();
                        ReplicaView {
                            outstanding_tokens: run.outstanding_tokens(),
                            digest: if wants_digest {
                                run.residency_digest()
                            } else {
                                Default::default()
                            },
                        }
                    })
                    .collect::<Vec<_>>()
            });
            let views: &[ReplicaView] = scans.as_deref().unwrap_or(blank_views);
            let ri = router.route(&specs[g], views).min(r - 1);
            {
                let mut run = cells[ri].lock().unwrap();
                let local = run.push(specs[g].clone());
                debug_assert_eq!(local, globals[ri].len());
                if track_load {
                    for (i, view) in views.iter().enumerate() {
                        out_sums[i] += if i == ri {
                            run.outstanding_tokens() as f64
                        } else {
                            view.outstanding_tokens as f64
                        };
                    }
                    *samples += 1;
                }
            }
            globals[ri].push(g);
            replica_of[g] = ri;
        }

        // arrivals exhausted: drain every replica, then resolve stalls
        // exactly like the serial driver until nothing progresses
        loop {
            advance_all(f64::INFINITY);
            let mut progressed = false;
            let mut wedged = None;
            for (ri, cell) in cells.iter().enumerate() {
                match cell.lock().unwrap().resolve_stall() {
                    StallOutcome::Demoted => progressed = true,
                    StallOutcome::Wedged => {
                        wedged = Some(ri);
                        break;
                    }
                    StallOutcome::Idle => {}
                }
            }
            if let Some(ri) = wedged {
                // release the parked workers before panicking, or the
                // scope's implicit join would deadlock on the barrier
                done.store(true, Ordering::Release);
                barrier.wait();
                cells[ri].lock().unwrap().panic_wedged();
            }
            if !progressed {
                break;
            }
        }
        done.store(true, Ordering::Release);
        barrier.wait();
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuConfig, ModelConfig, ParallelConfig};
    use crate::coordinator::sched::{OrcaScheduler, SarathiScheduler};
    use crate::util::Rng;
    use crate::workload::zipf_population;

    fn workload(n: usize) -> Vec<RequestSpec> {
        let mut rng = Rng::new(7);
        zipf_population(&mut rng, n, 0.4, 1024, 4096, 10.0)
    }

    fn tp_pp_deployment() -> Deployment {
        Deployment::new(ModelConfig::gpt3(), GpuConfig::a100(), 4096)
            .with_parallel(ParallelConfig::tp_pp(8, 8))
            .with_batch_cap(27)
    }

    fn tp_only_deployment() -> Deployment {
        Deployment::new(ModelConfig::gpt3(), GpuConfig::a100(), 4096)
            .with_parallel(ParallelConfig::tp_pp(8, 1).with_replicas(8))
            .with_batch_cap(11)
    }

    #[test]
    fn all_requests_complete_across_replicas() {
        let cluster = ClusterSim::new(tp_only_deployment());
        let specs = workload(64);
        let res = cluster.run(&specs, || Box::new(OrcaScheduler::best(11)));
        assert!(res.completions.iter().all(|t| !t.is_nan()));
        assert_eq!(res.per_replica.len(), 8);
        assert_eq!(res.router, "rr");
        // round-robin dispatch in arrival order == g % R on this all-at-0
        // workload
        assert!(res.replica_of.iter().enumerate().all(|(g, &ri)| ri == g % 8));
        let curve = res.completion_curve();
        assert_eq!(curve.len(), 64);
        assert!(curve.windows(2).all(|w| w[0].1 <= w[1].1));
    }

    /// Regression: `time_to_complete(0)` used to return the FIRST
    /// completion time (saturating_sub aliased 0 onto 1) instead of 0.0.
    #[test]
    fn time_to_complete_zero_is_zero() {
        let cluster = ClusterSim::new(tp_only_deployment());
        let specs = workload(16);
        let res = cluster.run(&specs, || Box::new(OrcaScheduler::best(11)));
        assert_eq!(res.time_to_complete(0), 0.0);
        let first = res.completion_curve()[0].1;
        assert!(first > 0.0);
        assert_eq!(res.time_to_complete(1), first);
        assert!(res.time_to_complete(usize::MAX).is_nan(), "beyond the workload stays NaN");
    }

    #[test]
    fn paged_cluster_serves_hybrid_over_shared_replica_pools() {
        use crate::coordinator::sched::HybridScheduler;
        let cluster = ClusterSim::new(tp_pp_deployment());
        let specs = workload(64);
        let res =
            cluster.run_paged(&specs, 128, || Box::new(HybridScheduler::new(256, 27, 2)));
        assert!(res.completions.iter().all(|t| !t.is_nan()));
        // latency is aggregated across replicas (stamping via StepApplier)
        assert_eq!(res.latency().ttft.count(), 64);
        assert!(res.latency().tbt.count() > 0);
    }

    /// Prefix sharing rides the same paged per-replica pools: each replica
    /// keeps its own resident-prefix index (round-robin splits a template's
    /// fanout across replicas, so every replica registers it once — the
    /// dispatch-layer waste `PrefixAffinity` exists to remove).
    #[test]
    fn paged_cluster_serves_shared_prefix_templates() {
        use crate::coordinator::sched::HybridScheduler;
        use crate::workload::shared_prefix_population;
        let cluster = ClusterSim::new(tp_pp_deployment());
        let mut rng = Rng::new(13);
        let specs = shared_prefix_population(&mut rng, 48, 4, 0.8, 256, 32, 128, 5.0);
        let res = cluster.run_paged(&specs, 128, || {
            Box::new(HybridScheduler::new(256, 27, 2).with_prefix_share(true))
        });
        assert!(res.completions.iter().all(|t| !t.is_nan()));
        assert!(res.prefix_hits() > 0, "template fanout must hit every replica's index");
        assert!(res.prefix_hit_rate() > 0.0);
    }

    /// §5.3's ordering: SARATHI TP-PP beats TP-only, which beats Orca TP-PP.
    /// Needs a steady-state workload (requests ≫ in-flight capacity).
    #[test]
    fn fig12_scenario_ordering() {
        let specs = workload(600);
        let tp_pp = ClusterSim::new(tp_pp_deployment());
        let orca = tp_pp.run(&specs, || Box::new(OrcaScheduler::best(27)));
        let sarathi = tp_pp.run(&specs, || Box::new(SarathiScheduler::new(256, 27, 128)));
        let tp_only = ClusterSim::new(tp_only_deployment())
            .run(&specs, || Box::new(OrcaScheduler::best(11)));
        assert!(
            sarathi.makespan < tp_only.makespan && tp_only.makespan < orca.makespan,
            "sarathi={} tp_only={} orca={}",
            sarathi.makespan,
            tp_only.makespan,
            orca.makespan
        );
    }

    fn handoff_deployment(replicas: usize) -> Deployment {
        Deployment::new(ModelConfig::gpt3(), GpuConfig::a100(), 4096)
            .with_parallel(ParallelConfig::tp_pp(8, 1).with_replicas(replicas))
            .with_batch_cap(11)
    }

    #[test]
    fn topology_parse_round_trips() {
        assert_eq!(Topology::parse("colocated", 0), Some(Topology::Colocated));
        assert_eq!(
            Topology::parse("disagg", 2),
            Some(Topology::Disagg { prefill_replicas: 2 })
        );
        assert_eq!(Topology::parse("disaggregated", 3).unwrap().name(), "disagg");
        assert_eq!(Topology::parse("split", 9), Some(Topology::Split));
        assert_eq!(Topology::parse("nope", 1), None);
        assert_eq!(Topology::Colocated.name(), "colocated");
        assert_eq!(Topology::Split.name(), "split");
    }

    /// The colocated topology IS the routed driver — same entry point the
    /// determinism suites pin, bitwise.
    #[test]
    fn colocated_topology_is_the_routed_driver_bitwise() {
        let cluster = ClusterSim::new(handoff_deployment(4));
        let specs = workload(32);
        let mut rr_a = RoundRobin::new();
        let a = cluster.run_topology(
            Topology::Colocated,
            &specs,
            &mut rr_a,
            || KvManager::new(11),
            Some(11),
            || Box::new(SarathiScheduler::new(256, 11, 128)),
            1,
        );
        let mut rr_b = RoundRobin::new();
        let b = cluster.run_routed_threads(
            &specs,
            &mut rr_b,
            || KvManager::new(11),
            Some(11),
            || Box::new(SarathiScheduler::new(256, 11, 128)),
            2,
        );
        let bits = |v: &[f64]| v.iter().map(|t| t.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a.completions), bits(&b.completions));
        assert_eq!(bits(&a.ttft), bits(&b.ttft));
        assert_eq!(bits(&a.max_tbt), bits(&b.max_tbt));
        assert_eq!(a.topology, "colocated");
        assert!(a.fabric.is_none(), "no copy fabric on colocated runs");
        assert!(a.kv_transfer_time.iter().all(|&t| t == 0.0));
        // goodput with infinite SLOs counts every completed request
        let (frac, rate) = a.goodput(f64::INFINITY, f64::INFINITY);
        assert!((frac - 1.0).abs() < 1e-12);
        assert!((rate - 32.0 / a.makespan).abs() < 1e-9);
    }

    /// Disagg end-to-end bookkeeping: every prompt with decode work makes
    /// exactly one fabric crossing, lands before its request's decode
    /// completes, and the stitched per-request latencies carry the
    /// transfer (max TBT ≥ the handoff latency).
    #[test]
    fn disagg_hands_every_decode_prompt_over_the_fabric() {
        let cluster = ClusterSim::new(handoff_deployment(4));
        let specs = workload(48);
        let mut rr = RoundRobin::new();
        let res = cluster.run_topology(
            Topology::Disagg { prefill_replicas: 2 },
            &specs,
            &mut rr,
            || KvManager::new(11),
            Some(11),
            || Box::new(SarathiScheduler::new(256, 11, 128)),
            1,
        );
        assert_eq!(res.topology, "disagg");
        assert!(res.completions.iter().all(|t| !t.is_nan()));
        assert!(res.ttft.iter().all(|t| t.is_finite()));
        assert!(res.replica_of.iter().all(|&ri| ri < 2), "arrivals go to prefill replicas");
        let fabric = res.fabric.as_ref().expect("disagg runs carry the fabric");
        let expect = specs.iter().filter(|s| s.decode_len > 1).count();
        assert_eq!(fabric.records.len(), expect, "one transfer per decoded prompt");
        assert_eq!(fabric.delivered(), expect);
        assert!(fabric.is_conserved());
        assert!(res.transfer_busy > 0.0);
        for rec in &fabric.records {
            assert!(rec.src < 2 && rec.dst >= 2, "prefill → decode only");
            assert!(
                res.completions[rec.request] > rec.finish,
                "no decode token before its KV lands"
            );
            assert!(
                res.max_tbt[rec.request] >= res.kv_transfer_time[rec.request] - 1e-12,
                "the transfer must be visible in the stitched TBT"
            );
            assert!(res.kv_transfer_time[rec.request] > 0.0);
        }
    }

    /// Split keeps both phases on-device: lanes partition compute, the
    /// fabric never moves a byte, and every request still completes.
    #[test]
    fn split_topology_keeps_the_handoff_on_device() {
        let cluster = ClusterSim::new(handoff_deployment(2));
        let specs = workload(24);
        let mut rr = RoundRobin::new();
        let res = cluster.run_topology(
            Topology::Split,
            &specs,
            &mut rr,
            || KvManager::new(11),
            Some(11),
            || Box::new(SarathiScheduler::new(256, 11, 128)),
            1,
        );
        assert_eq!(res.topology, "split");
        assert!(res.completions.iter().all(|t| !t.is_nan()));
        assert!(res.ttft.iter().all(|t| t.is_finite()));
        let fabric = res.fabric.as_ref().expect("handoff runs carry the fabric");
        assert!(fabric.records.is_empty(), "on-device handoffs move no fabric bytes");
        assert_eq!(fabric.busy_time(), 0.0);
        assert!(res.kv_transfer_time.iter().all(|&t| t == 0.0));
        // decoded requests still stitch a positive gap (lane switch)
        assert!(
            specs
                .iter()
                .enumerate()
                .filter(|(_, s)| s.decode_len > 1)
                .all(|(g, _)| res.max_tbt[g] > 0.0)
        );
    }

    #[test]
    #[should_panic(expected = "disagg needs")]
    fn disagg_rejects_a_prefill_only_cluster() {
        let cluster = ClusterSim::new(handoff_deployment(2));
        let specs = workload(2);
        let mut rr = RoundRobin::new();
        cluster.run_topology(
            Topology::Disagg { prefill_replicas: 2 },
            &specs,
            &mut rr,
            || KvManager::new(11),
            Some(11),
            || Box::new(SarathiScheduler::new(256, 11, 128)),
            1,
        );
    }

    #[test]
    fn load_imbalance_degenerate_cases() {
        let res = ClusterResult::default();
        assert_eq!(res.load_imbalance(), 1.0, "no replicas = balanced");
        let res = ClusterResult {
            mean_outstanding: vec![0.0, 0.0],
            ..Default::default()
        };
        assert_eq!(res.load_imbalance(), 1.0, "idle cluster = balanced");
        let res = ClusterResult {
            mean_outstanding: vec![300.0, 100.0, 100.0, 100.0],
            ..Default::default()
        };
        assert!((res.load_imbalance() - 2.0).abs() < 1e-12, "300 / mean 150");
    }
}
