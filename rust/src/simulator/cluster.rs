//! Replica-level cluster simulation (§5.3's third scenario: 8 independent
//! TP-8 replicas on the same 64 GPUs as the TP8×PP8 deployment).

use super::pipeline::{PipelineResult, PipelineSim};
use crate::config::Deployment;
use crate::coordinator::{KvManager, Scheduler};
use crate::costmodel::CostModel;
use crate::profiler::Profiler;
use crate::workload::RequestSpec;

/// Result of a cluster run: merged view over all replicas.
#[derive(Clone, Debug, Default)]
pub struct ClusterResult {
    pub per_replica: Vec<PipelineResult>,
    pub completions: Vec<f64>,
    pub makespan: f64,
}

impl ClusterResult {
    /// Sorted (requests completed, time) curve across all replicas —
    /// Fig. 12b's x/y series.
    pub fn completion_curve(&self) -> Vec<(usize, f64)> {
        let mut c = self.completions.clone();
        c.sort_by(|a, b| a.partial_cmp(b).unwrap());
        c.into_iter().enumerate().map(|(i, t)| (i + 1, t)).collect()
    }

    /// Time at which `n` requests have completed. `n = 0` is "no work
    /// yet": 0.0, not the first completion time (the seed's saturating_sub
    /// silently aliased n=0 onto n=1).
    pub fn time_to_complete(&self, n: usize) -> f64 {
        if n == 0 {
            return 0.0;
        }
        let curve = self.completion_curve();
        curve.get(n - 1).map(|&(_, t)| t).unwrap_or(f64::NAN)
    }

    /// Merged latency report across replicas.
    pub fn latency(&self) -> crate::coordinator::LatencyReport {
        let mut merged = crate::coordinator::LatencyReport::default();
        for rep in &self.per_replica {
            merged.ttft.merge(&rep.latency.ttft);
            merged.tbt.merge(&rep.latency.tbt);
            merged.normalized.merge(&rep.latency.normalized);
        }
        merged
    }

    /// Total preemption events across replicas.
    pub fn preemptions(&self) -> usize {
        self.per_replica.iter().map(|r| r.metrics.preemptions).sum()
    }

    /// Total preemption transfer time across replicas.
    pub fn total_swap_time(&self) -> f64 {
        self.per_replica.iter().map(|r| r.metrics.total_swap_time()).sum()
    }
}

/// A deployment of `replicas` identical tp×pp groups sharing a workload
/// round-robin.
pub struct ClusterSim {
    pub deployment: Deployment,
    pub sims: Vec<PipelineSim>,
}

impl ClusterSim {
    pub fn new(deployment: Deployment) -> Self {
        let cm = CostModel::for_deployment(&deployment);
        let profiler = Profiler::build(cm, deployment.max_seq_len, deployment.max_batch_size() + 1);
        let sims = (0..deployment.parallel.replicas)
            .map(|_| PipelineSim::new(profiler.clone(), deployment.parallel.pp))
            .collect();
        ClusterSim { deployment, sims }
    }

    /// Price the preemption path on every replica's simulator (seed
    /// default: free swaps).
    pub fn with_swap_cost(mut self, swap: crate::coordinator::SwapCost) -> Self {
        for sim in &mut self.sims {
            sim.applier = crate::coordinator::StepApplier::with_cost(swap);
        }
        self
    }

    /// Run the workload over the seed-compatible degenerate layout: each
    /// replica shares one pool of `pp × B` whole-request slots across its
    /// streams (per-stream cap B). Requests are assigned to replicas
    /// round-robin; `make_sched` builds one scheduler per stream.
    pub fn run<'a, F>(&self, specs: &[RequestSpec], mut make_sched: F) -> ClusterResult
    where
        F: FnMut() -> Box<dyn Scheduler + 'a>,
    {
        let slots = self.deployment.max_batch_size();
        let pp = self.deployment.parallel.pp.max(1);
        self.run_with_kv(specs, || KvManager::new(pp * slots), Some(slots), &mut make_sched)
    }

    /// Run over one shared **paged** pool per replica, sized from the
    /// deployment's actual KV memory budget — the pool a real stage
    /// holds, NOT the seed's pp×-overcommitted per-stream slots. Streams
    /// stay capped at B sequences each; cross-stream preemption and the
    /// engine-shared state transition come from `PipelineSim::run_shared`.
    pub fn run_paged<'a, F>(
        &self,
        specs: &[RequestSpec],
        block_size: usize,
        mut make_sched: F,
    ) -> ClusterResult
    where
        F: FnMut() -> Box<dyn Scheduler + 'a>,
    {
        let blocks = self.deployment.kv_blocks(block_size);
        let cap = self.deployment.max_batch_size();
        self.run_with_kv(
            specs,
            || KvManager::paged(blocks, block_size),
            Some(cap),
            &mut make_sched,
        )
    }

    /// Shared driver: one fresh KV pool per replica from `make_kv`.
    pub fn run_with_kv<'a, F, K>(
        &self,
        specs: &[RequestSpec],
        mut make_kv: K,
        per_stream_cap: Option<usize>,
        mut make_sched: F,
    ) -> ClusterResult
    where
        F: FnMut() -> Box<dyn Scheduler + 'a>,
        K: FnMut() -> KvManager,
    {
        let r = self.sims.len();
        let mut result = ClusterResult {
            completions: vec![f64::NAN; specs.len()],
            ..Default::default()
        };
        for (ri, sim) in self.sims.iter().enumerate() {
            let mut local: Vec<RequestSpec> = Vec::new();
            let mut globals: Vec<usize> = Vec::new();
            for (g, &s) in specs.iter().enumerate() {
                if g % r == ri {
                    local.push(s);
                    globals.push(g);
                }
            }
            let res = sim.run_shared(&local, make_kv(), per_stream_cap, &mut make_sched);
            for (li, &g) in globals.iter().enumerate() {
                result.completions[g] = res.completions[li];
            }
            result.makespan = result.makespan.max(res.makespan);
            result.per_replica.push(res);
        }
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuConfig, ModelConfig, ParallelConfig};
    use crate::coordinator::sched::{OrcaScheduler, SarathiScheduler};
    use crate::util::Rng;
    use crate::workload::zipf_population;

    fn workload(n: usize) -> Vec<RequestSpec> {
        let mut rng = Rng::new(7);
        zipf_population(&mut rng, n, 0.4, 1024, 4096, 10.0)
    }

    fn tp_pp_deployment() -> Deployment {
        Deployment::new(ModelConfig::gpt3(), GpuConfig::a100(), 4096)
            .with_parallel(ParallelConfig::tp_pp(8, 8))
            .with_batch_cap(27)
    }

    fn tp_only_deployment() -> Deployment {
        Deployment::new(ModelConfig::gpt3(), GpuConfig::a100(), 4096)
            .with_parallel(ParallelConfig::tp_pp(8, 1).with_replicas(8))
            .with_batch_cap(11)
    }

    #[test]
    fn all_requests_complete_across_replicas() {
        let cluster = ClusterSim::new(tp_only_deployment());
        let specs = workload(64);
        let res = cluster.run(&specs, || Box::new(OrcaScheduler::best(11)));
        assert!(res.completions.iter().all(|t| !t.is_nan()));
        assert_eq!(res.per_replica.len(), 8);
        let curve = res.completion_curve();
        assert_eq!(curve.len(), 64);
        assert!(curve.windows(2).all(|w| w[0].1 <= w[1].1));
    }

    /// Regression: `time_to_complete(0)` used to return the FIRST
    /// completion time (saturating_sub aliased 0 onto 1) instead of 0.0.
    #[test]
    fn time_to_complete_zero_is_zero() {
        let cluster = ClusterSim::new(tp_only_deployment());
        let specs = workload(16);
        let res = cluster.run(&specs, || Box::new(OrcaScheduler::best(11)));
        assert_eq!(res.time_to_complete(0), 0.0);
        let first = res.completion_curve()[0].1;
        assert!(first > 0.0);
        assert_eq!(res.time_to_complete(1), first);
        assert!(res.time_to_complete(usize::MAX).is_nan(), "beyond the workload stays NaN");
    }

    #[test]
    fn paged_cluster_serves_hybrid_over_shared_replica_pools() {
        use crate::coordinator::sched::HybridScheduler;
        let cluster = ClusterSim::new(tp_pp_deployment());
        let specs = workload(64);
        let res =
            cluster.run_paged(&specs, 128, || Box::new(HybridScheduler::new(256, 27, 2)));
        assert!(res.completions.iter().all(|t| !t.is_nan()));
        // latency is aggregated across replicas (stamping via StepApplier)
        assert_eq!(res.latency().ttft.count(), 64);
        assert!(res.latency().tbt.count() > 0);
    }

    /// Prefix sharing rides the same paged per-replica pools: each replica
    /// keeps its own resident-prefix index (round-robin splits a template's
    /// fanout across replicas, so every replica registers it once).
    #[test]
    fn paged_cluster_serves_shared_prefix_templates() {
        use crate::coordinator::sched::HybridScheduler;
        use crate::workload::shared_prefix_population;
        let cluster = ClusterSim::new(tp_pp_deployment());
        let mut rng = Rng::new(13);
        let specs = shared_prefix_population(&mut rng, 48, 4, 0.8, 256, 32, 128, 5.0);
        let res = cluster.run_paged(&specs, 128, || {
            Box::new(HybridScheduler::new(256, 27, 2).with_prefix_share(true))
        });
        assert!(res.completions.iter().all(|t| !t.is_nan()));
        let hits: usize = res.per_replica.iter().map(|r| r.metrics.prefix_hits).sum();
        assert!(hits > 0, "template fanout must hit every replica's index");
    }

    /// §5.3's ordering: SARATHI TP-PP beats TP-only, which beats Orca TP-PP.
    /// Needs a steady-state workload (requests ≫ in-flight capacity).
    #[test]
    fn fig12_scenario_ordering() {
        let specs = workload(600);
        let tp_pp = ClusterSim::new(tp_pp_deployment());
        let orca = tp_pp.run(&specs, || Box::new(OrcaScheduler::best(27)));
        let sarathi = tp_pp.run(&specs, || Box::new(SarathiScheduler::new(256, 27, 128)));
        let tp_only = ClusterSim::new(tp_only_deployment())
            .run(&specs, || Box::new(OrcaScheduler::best(11)));
        assert!(
            sarathi.makespan < tp_only.makespan && tp_only.makespan < orca.makespan,
            "sarathi={} tp_only={} orca={}",
            sarathi.makespan,
            tp_only.makespan,
            orca.makespan
        );
    }
}
